from .elastic import best_mesh_shape, elastic_mesh
from .fault import FailureInjector, SimulatedFailure, run_with_restarts
from .straggler import StragglerDetector

__all__ = ["FailureInjector", "SimulatedFailure", "run_with_restarts",
           "StragglerDetector", "best_mesh_shape", "elastic_mesh"]
