import os

import numpy as np
import pytest

# Tests run on the single host CPU device; ONLY the dry-run subprocesses
# spawn a placeholder fleet (REPRO_DRYRUN_DEVICES) — never set XLA_FLAGS
# here (smoke tests and benches must see 1 device).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

try:
    from hypothesis import settings
except ModuleNotFoundError:
    # hypothesis is optional: the property-based tests skip themselves via
    # tests/hypo_compat.py, the rest of the suite runs normally.
    pass
else:
    settings.register_profile("ci", deadline=None, max_examples=25,
                              derandomize=True)
    settings.load_profile("ci")


@pytest.fixture
def rng():
    return np.random.default_rng(0)
