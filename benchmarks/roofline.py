"""§Roofline table from the dry-run JSONs (results/dryrun by default).

Reads every per-cell record the dry-run wrote, prints the three roofline
terms + dominant bottleneck + useful-compute ratio per (arch x shape x
mesh) and flags cells whose HBM footprint exceeds a v5e chip."""
from __future__ import annotations

import argparse
import glob
import json
import os

from .common import print_csv


def load(dirname: str, tag: str = ""):
    rows = []
    for path in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        rec = json.load(open(path))
        name = os.path.basename(path)[:-5]
        want_tagged = name.endswith("_roofline")
        if (tag == "roofline") != want_tagged:
            continue
        if rec.get("status") != "ok":
            if rec.get("status") == "skipped":
                rows.append({
                    "arch": rec["arch"], "shape": rec["shape"],
                    "mesh": "mp" if rec.get("multi_pod") else "sp",
                    "compute_s": 0.0, "memory_s": 0.0, "collective_s": 0.0,
                    "dominant": "SKIPPED", "useful_ratio": 0.0,
                    "fits_hbm": True, "peak_gb": 0.0})
            continue
        r = rec["roofline"]
        rows.append({
            "arch": rec["arch"], "shape": rec["shape"],
            "mesh": "mp" if rec.get("multi_pod") else "sp",
            "compute_s": r["compute_s"], "memory_s": r["memory_s"],
            "collective_s": r["collective_s"], "dominant": r["dominant"],
            "useful_ratio": rec.get("useful_compute_ratio", 0.0),
            "fits_hbm": rec.get("fits_hbm", False),
            "peak_gb": rec["memory"]["peak_bytes"] / 1e9,
        })
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--tag", default="", help="'' (fit pass) | roofline")
    args = ap.parse_args()
    rows = load(args.dir, args.tag)
    if not rows:
        print("# no dry-run records found — run "
              "`python -m repro.launch.dryrun --all --out-dir results/dryrun`")
        return
    print_csv(rows, ["arch", "shape", "mesh", "compute_s", "memory_s",
                     "collective_s", "dominant", "useful_ratio",
                     "fits_hbm", "peak_gb"])


if __name__ == "__main__":
    main()
