"""Schedule simulator — paper Algorithm 2 ("map from a particle to DNN
layers offloading").

Given an assignment vector ``x`` (server index per layer) the simulator
replays the offloading: layers execute in a fixed topological order (the
paper freezes the order genes φ at initialization — §IV-B.3 "the value of
the order φ_j for each layer remains the same"), each server is a serial
queue, incoming datasets pay ``∂ / ℓ`` transfer time, and the server stays
busy for its outgoing transfers (Alg. 2 line 21).

Two fidelity modes (see DESIGN.md §2):
  * ``faithful=True``  — the printed recurrence, verbatim:
        T_start = T_lease(s) + maxTrans            (lines 4/11)
        T_lease(s) += exe + transfer_out           (line 21)
    (the incoming wait is *not* added to the server busy time, exactly as
    printed in the paper).
  * ``faithful=False`` — "corrected": serial processing is preserved and
    a layer cannot start before its parents finished and shipped:
        T_start = max(T_lease(s), max_p(T_end(p) + trans_p))
        T_lease(s) = T_end + transfer_out

Cost model (Eq. 8): per-server rental  c_com · (T_off − T_on)  with
T_on = first T_start on the server, T_off = final lease (includes trailing
outgoing transfers), plus per-edge transmission  c_tran · ∂  for every
edge crossing two distinct servers.

Missing links (ℓ = 0, e.g. device↔device) are clamped to ``MIN_BW`` MB/s
so infeasible placements get enormous-but-finite times — this keeps the
paper's Case-2 fitness (compare total completion times of two infeasible
particles) a meaningful total order instead of inf == inf.

Both a pure-numpy reference (`simulate_np`) and a jit/vmap-able JAX
implementation (`build_simulator`) are provided; tests assert they agree.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .dag import LayerDAG, topological_order
from .environment import Environment

MIN_BW = 1e-9   # MB/s stand-in for "no link"
__all__ = ["SimResult", "SimProblem", "simulate_np", "build_simulator",
           "MIN_BW"]


class SimResult(NamedTuple):
    """All fields are jnp/np arrays; scalar fields are 0-d."""
    end_times: jnp.ndarray        # (p,) per-layer completion time
    app_completion: jnp.ndarray   # (n_apps,) T_i^comp
    comp_cost: jnp.ndarray        # $ rental
    trans_cost: jnp.ndarray       # $ transmission
    total_cost: jnp.ndarray       # Eq. 8
    feasible: jnp.ndarray         # bool: all deadlines met AND pins honored
    makespan: jnp.ndarray         # max end time


@dataclasses.dataclass(frozen=True)
class SimProblem:
    """Static, device-ready arrays describing (dag, env) for the simulator."""
    compute: np.ndarray       # (p,)
    order: np.ndarray         # (p,) topological order
    parent_idx: np.ndarray    # (p, max_in) padded -1
    parent_mb: np.ndarray     # (p, max_in)
    child_idx: np.ndarray     # (p, max_out) padded -1
    child_mb: np.ndarray      # (p, max_out)
    app_id: np.ndarray        # (p,)
    deadline: np.ndarray      # (n_apps,)
    pinned: np.ndarray        # (p,)
    power: np.ndarray         # (S,)
    cost_per_sec: np.ndarray  # (S,)
    inv_bw: np.ndarray        # (S, S) seconds per MB (0 on diagonal)
    tran_cost: np.ndarray     # (S, S) $/MB (0 on diagonal)
    link_ok: np.ndarray       # (S, S) bool

    @property
    def num_layers(self) -> int:
        return int(self.compute.shape[0])

    @property
    def num_servers(self) -> int:
        return int(self.power.shape[0])

    @property
    def num_apps(self) -> int:
        return int(self.deadline.shape[0])

    @staticmethod
    def build(dag: LayerDAG, env: Environment) -> "SimProblem":
        pi, pm, ci, cm = dag.padded_relatives()
        bw = np.where(env.bandwidth <= 0.0, MIN_BW, env.bandwidth)
        inv_bw = 1.0 / bw                     # diagonal is 1/inf = 0
        return SimProblem(
            compute=dag.compute, order=topological_order(dag),
            parent_idx=pi, parent_mb=pm, child_idx=ci, child_mb=cm,
            app_id=dag.app_id, deadline=dag.deadline, pinned=dag.pinned,
            power=env.power, cost_per_sec=env.cost_per_sec,
            inv_bw=inv_bw, tran_cost=env.tran_cost,
            link_ok=env.bandwidth > 0.0)


# ---------------------------------------------------------------------------
# numpy reference (oracle for tests)
# ---------------------------------------------------------------------------

def simulate_np(prob: SimProblem, x: np.ndarray, faithful: bool = True
                ) -> SimResult:
    x = np.asarray(x, np.int64)
    p, s = prob.num_layers, prob.num_servers
    lease = np.zeros(s)
    t_on = np.full(s, np.inf)
    used = np.zeros(s, bool)
    end = np.zeros(p)
    trans_cost = 0.0
    link_violation = False

    for j in prob.order:
        srv = x[j]
        exe = prob.compute[j] / prob.power[srv]
        pars = prob.parent_idx[j]
        mask = pars >= 0
        max_trans = 0.0
        parent_gate = 0.0
        for k in np.nonzero(mask)[0]:
            pj = pars[k]
            mb = prob.parent_mb[j, k]
            t = mb * prob.inv_bw[x[pj], srv]
            if not prob.link_ok[x[pj], srv] and x[pj] != srv:
                link_violation = True
            max_trans = max(max_trans, t)
            parent_gate = max(parent_gate, end[pj] + t)
            trans_cost += prob.tran_cost[x[pj], srv] * mb
        if faithful:
            start = lease[srv] + max_trans
        else:
            start = max(lease[srv], parent_gate)
        t_end = start + exe
        end[j] = t_end
        t_on[srv] = min(t_on[srv], start)
        used[srv] = True
        transfer_out = 0.0
        cidx = prob.child_idx[j]
        for k in np.nonzero(cidx >= 0)[0]:
            transfer_out += prob.child_mb[j, k] * prob.inv_bw[srv, x[cidx[k]]]
        if faithful:
            lease[srv] = lease[srv] + exe + transfer_out   # line 21, verbatim
        else:
            lease[srv] = t_end + transfer_out

    app_completion = np.zeros(prob.num_apps)
    np.maximum.at(app_completion, prob.app_id, end)
    comp_cost = float(np.sum(np.where(used, prob.cost_per_sec * (lease - np.where(np.isinf(t_on), 0.0, t_on)), 0.0)))
    pin_ok = np.all((prob.pinned < 0) | (x == prob.pinned))
    feasible = bool(np.all(app_completion <= prob.deadline) and pin_ok
                    and not link_violation)
    total = comp_cost + trans_cost
    return SimResult(end_times=end, app_completion=app_completion,
                     comp_cost=np.float64(comp_cost),
                     trans_cost=np.float64(trans_cost),
                     total_cost=np.float64(total),
                     feasible=np.bool_(feasible),
                     makespan=np.float64(end.max() if p else 0.0))


# ---------------------------------------------------------------------------
# JAX implementation — lax.scan over layers, vmap over particles
# ---------------------------------------------------------------------------

def build_simulator(prob: SimProblem, faithful: bool = True):
    """Returns a jit-able ``sim(x) -> SimResult`` closed over static arrays.

    ``x``: (p,) int32 server assignment. vmap over a swarm:
    ``jax.vmap(sim)(X)`` with X (P, p).
    """
    compute = jnp.asarray(prob.compute)
    order = jnp.asarray(prob.order)
    parent_idx = jnp.asarray(prob.parent_idx)
    parent_mb = jnp.asarray(prob.parent_mb)
    child_idx = jnp.asarray(prob.child_idx)
    child_mb = jnp.asarray(prob.child_mb)
    app_id = jnp.asarray(prob.app_id)
    deadline = jnp.asarray(prob.deadline)
    pinned = jnp.asarray(prob.pinned)
    power = jnp.asarray(prob.power)
    cost_per_sec = jnp.asarray(prob.cost_per_sec)
    inv_bw = jnp.asarray(prob.inv_bw)
    tran_cost = jnp.asarray(prob.tran_cost)
    link_ok = jnp.asarray(prob.link_ok)
    n_apps = prob.num_apps
    p = prob.num_layers
    s = prob.num_servers

    def sim(x: jnp.ndarray) -> SimResult:
        x = jnp.asarray(x).astype(jnp.int32)

        def step(carry, j):
            lease, t_on, used, end, trans_cost, link_bad = carry
            srv = x[j]
            exe = compute[j] / power[srv]
            pars = parent_idx[j]                  # (max_in,)
            pmask = pars >= 0
            psafe = jnp.where(pmask, pars, 0)
            psrv = x[psafe]
            mb = parent_mb[j]
            tt = mb * inv_bw[psrv, srv]           # (max_in,)
            max_trans = jnp.max(jnp.where(pmask, tt, 0.0), initial=0.0)
            parent_gate = jnp.max(jnp.where(pmask, end[psafe] + tt, 0.0),
                                  initial=0.0)
            trans_cost = trans_cost + jnp.sum(
                jnp.where(pmask, tran_cost[psrv, srv] * mb, 0.0))
            link_bad = link_bad | jnp.any(
                pmask & ~link_ok[psrv, srv] & (psrv != srv))
            if faithful:
                start = lease[srv] + max_trans
            else:
                start = jnp.maximum(lease[srv], parent_gate)
            t_end = start + exe
            end = end.at[j].set(t_end)
            t_on = t_on.at[srv].min(start)
            used = used.at[srv].set(True)
            kids = child_idx[j]
            kmask = kids >= 0
            ksafe = jnp.where(kmask, kids, 0)
            out_t = jnp.sum(jnp.where(kmask,
                                      child_mb[j] * inv_bw[srv, x[ksafe]],
                                      0.0))
            link_bad = link_bad | jnp.any(
                kmask & ~link_ok[srv, x[ksafe]] & (x[ksafe] != srv))
            if faithful:
                new_lease = lease[srv] + exe + out_t
            else:
                new_lease = t_end + out_t
            lease = lease.at[srv].set(new_lease)
            return (lease, t_on, used, end, trans_cost, link_bad), None

        init = (jnp.zeros(s), jnp.full(s, jnp.inf), jnp.zeros(s, bool),
                jnp.zeros(p), jnp.asarray(0.0), jnp.asarray(False))
        (lease, t_on, used, end, trans_cost, link_bad), _ = jax.lax.scan(
            step, init, order)

        app_completion = jax.ops.segment_max(end, app_id, num_segments=n_apps)
        t_on_safe = jnp.where(jnp.isinf(t_on), 0.0, t_on)
        comp_cost = jnp.sum(jnp.where(used,
                                      cost_per_sec * (lease - t_on_safe), 0.0))
        pin_ok = jnp.all((pinned < 0) | (x == pinned))
        feasible = (jnp.all(app_completion <= deadline) & pin_ok & ~link_bad)
        total = comp_cost + trans_cost
        return SimResult(end_times=end, app_completion=app_completion,
                         comp_cost=comp_cost, trans_cost=trans_cost,
                         total_cost=total, feasible=feasible,
                         makespan=jnp.max(end, initial=0.0))

    return sim
