"""PSO-GA engine throughput: jitted swarm-iterations/second and particle
evaluations/second vs problem size — the performance of the paper's
algorithm as a vmapped/jitted JAX program (the reproduction's own compute
layer; the paper ran seconds-per-iteration on a Pentium G3250).

Also benchmarks fleet planning: the sequential per-problem loop (one
re-traced ``run_pso_ga`` per problem) vs the batched fleet solver
(``run_pso_ga_batch``, DESIGN.md §4) at N ∈ {1, 8, 64} heterogeneous
problems (EXPERIMENTS.md §Perf).

``--backend {scan,pallas}`` selects the swarm-fitness backend
(DESIGN.md §8; pallas runs in interpret mode off-TPU, so its CPU numbers
measure correctness plumbing, not kernel speed). Every run writes a
machine-readable ``BENCH_pso.json`` (per-net µs/iter, fleet speedups) so
the perf trajectory is tracked across PRs (EXPERIMENTS.md §Perf)."""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import numpy as np

from repro.core import (PSOGAConfig, heft_makespan, paper_environment,
                        run_pso_ga, run_pso_ga_batch, zoo)
from repro.core.pso_ga import _SwarmState, _make_step, init_swarm
from repro.core.simulator import SimProblem

from .common import bench_metadata, print_csv

#: moderate budget so the N=64 fleet stays CPU-friendly
FLEET_CFG = PSOGAConfig(pop_size=32, max_iters=80, stall_iters=25)

#: tiny budget for the N>=1024 mixed-size fleet (the bench measures
#: bucketed-vs-global PACKING overhead, not solution quality)
MIXED_CFG = PSOGAConfig(pop_size=16, max_iters=12, stall_iters=6)


def make_fleet(n: int, env=None):
    """N heterogeneous problems: mixed nets, pins, and deadline ratios."""
    env = env or paper_environment()
    problems = []
    for i in range(n):
        net = ("alexnet", "vgg19", "googlenet")[i % 3]
        dag = zoo.build(net, pin_server=i % 10)
        h, _ = heft_makespan(dag, env)
        ratio = (1.5, 3.0, 5.0, 8.0)[i % 4]
        problems.append((dag.with_deadline(np.array([ratio * h])), env))
    return problems


def make_mixed_fleet(n: int, env=None):
    """A mostly-small fleet with a long tail — the regime DESIGN.md §12
    buckets for: ~72% alexnet (11 layers -> bucket 16), ~20% vgg19
    (25 -> 32), ~7% googlenet (83 -> 128), and one resnet101 per 128
    problems (338 -> 512) that used to drag EVERY problem to the global
    512-gene padding."""
    env = env or paper_environment()
    problems = []
    for i in range(n):
        if i % 128 == 0:
            net = "resnet101"
        elif i % 16 == 8:
            net = "googlenet"
        elif i % 5 == 1:
            net = "vgg19"
        else:
            net = "alexnet"
        dag = zoo.build(net, pin_server=i % 10)
        h, _ = heft_makespan(dag, env)
        problems.append((dag.with_deadline(np.array([3.0 * h])), env))
    return problems


def bench_mixed_fleet(n: int, mesh=None, cfg: PSOGAConfig = MIXED_CFG):
    """Bucketed vs global-padding packing at N>=1024, optionally sharded
    over a device mesh (DESIGN.md §12). Reports the bucketed-vs-global
    speedup, per-device throughput, and fitness parity between the two
    packings (bucket shape must never change a gene)."""
    import jax as _jax

    from repro.launch.mesh import data_shard_count

    problems = make_mixed_fleet(n)
    t0 = time.perf_counter()
    r_bucket = run_pso_ga_batch(problems, cfg, seed=0, bucket=True,
                                mesh=mesh)
    t_bucket = time.perf_counter() - t0
    t0 = time.perf_counter()                # warm: all runners compiled
    run_pso_ga_batch(problems, cfg, seed=0, bucket=True, mesh=mesh)
    t_bucket_warm = time.perf_counter() - t0
    t0 = time.perf_counter()
    r_global = run_pso_ga_batch(problems, cfg, seed=0, bucket=False,
                                mesh=mesh)
    t_global = time.perf_counter() - t0
    match = sum(a.best_fitness == b.best_fitness
                for a, b in zip(r_bucket, r_global))
    shards = data_shard_count(mesh) if mesh is not None else 1
    return {
        "n_problems": n,
        "devices": int(_jax.device_count()),
        "data_shards": shards,
        "bucketed_s": t_bucket,
        "bucketed_warm_s": t_bucket_warm,
        "global_pad_s": t_global,
        "bucket_speedup": t_global / t_bucket_warm,
        "problems_per_s": n / t_bucket_warm,
        "problems_per_s_per_shard": n / t_bucket_warm / shards,
        "fitness_match": f"{match}/{n}",
    }


def bench_fleet(n: int, cfg: PSOGAConfig = FLEET_CFG, mesh=None):
    problems = make_fleet(n)
    t0 = time.perf_counter()
    seq = [run_pso_ga(dag, env, cfg, seed=i)
           for i, (dag, env) in enumerate(problems)]
    t_seq = time.perf_counter() - t0
    t0 = time.perf_counter()
    bat = run_pso_ga_batch(problems, cfg, seed=list(range(n)), mesh=mesh)
    t_batch = time.perf_counter() - t0
    t0 = time.perf_counter()                 # second call hits the compiled cache
    run_pso_ga_batch(problems, cfg, seed=list(range(n)), mesh=mesh)
    t_cached = time.perf_counter() - t0
    match = sum(a.best_fitness == b.best_fitness
                for a, b in zip(seq, bat))
    return {
        "n_problems": n,
        "seq_s": t_seq,
        "batch_s": t_batch,
        "batch_cached_s": t_cached,
        "speedup": t_seq / t_batch,
        "speedup_cached": t_seq / t_cached,
        "fitness_match": f"{match}/{n}",
    }


def bench_net(net: str, pop: int = 100, iters: int = 50,
              backend: str = "scan"):
    env = paper_environment()
    dag = zoo.build(net, deadline=1e9)
    prob = SimProblem.build(dag, env)
    cfg = PSOGAConfig(pop_size=pop, max_iters=iters,
                      fitness_backend=backend)
    step, fit = _make_step(prob, cfg)
    key = jax.random.PRNGKey(0)
    X0 = init_swarm(key, prob, cfg)
    f0 = fit(X0)
    state = _SwarmState(key=key, X=X0, pbest_x=X0, pbest_f=f0,
                        gbest_x=X0[0], gbest_f=f0[0],
                        it=jax.numpy.asarray(0),
                        stall=jax.numpy.asarray(0))
    jstep = jax.jit(step)
    state = jstep(state)                       # compile + warmup
    jax.block_until_ready(state.X)
    t0 = time.perf_counter()
    for _ in range(iters):
        state = jstep(state)
    jax.block_until_ready(state.X)
    dt = (time.perf_counter() - t0) / iters
    return {
        "net": net, "layers": dag.num_layers, "pop": pop,
        "backend": backend,
        "us_per_iter": dt * 1e6,
        "evals_per_s": pop / dt,
        "layersteps_per_s": pop * dag.num_layers / dt,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--pop", type=int, default=100)
    ap.add_argument("--backend", default="scan",
                    choices=("scan", "pallas"),
                    help="swarm-fitness backend (DESIGN.md §8); pallas "
                         "runs in interpret mode off-TPU")
    ap.add_argument("--json", default="BENCH_pso.json",
                    help="write machine-readable results here "
                         "('' to disable)")
    ap.add_argument("--skip-fleet", action="store_true",
                    help="skip the sequential-vs-batched fleet benchmark")
    ap.add_argument("--fleet-sizes", type=int, nargs="*", default=[1, 8, 64])
    ap.add_argument("--mesh", default="none",
                    choices=("none", "host", "prod"),
                    help="shard the fleet solves over this device mesh "
                         "(DESIGN.md §12); 'host' uses the visible "
                         "devices (set XLA_FLAGS="
                         "--xla_force_host_platform_device_count=8 to "
                         "simulate 8 on one host)")
    ap.add_argument("--mixed-fleet", type=int, default=0, metavar="N",
                    help="also run the N>=1024 mixed-size fleet bench: "
                         "bucketed vs global padding, per-device "
                         "scaling (DESIGN.md §12); 0 skips")
    ap.add_argument("--skip-nets", action="store_true",
                    help="skip the per-net swarm-iteration microbench")
    args = ap.parse_args()
    from repro.launch.mesh import resolve_mesh
    mesh = resolve_mesh(args.mesh)
    rows = []
    if not args.skip_nets:
        rows = [bench_net(n, pop=args.pop, backend=args.backend)
                for n in ("alexnet", "vgg19", "googlenet", "resnet101")]
        print_csv(rows, ["net", "layers", "pop", "backend", "us_per_iter",
                         "evals_per_s", "layersteps_per_s"])
    fleet_rows = []
    if not args.skip_fleet:
        fleet_cfg = dataclasses.replace(FLEET_CFG,
                                        fitness_backend=args.backend)
        for n in args.fleet_sizes:
            row = bench_fleet(n, fleet_cfg, mesh=mesh)
            print(f"# fleet N={n}: seq {row['seq_s']:.2f}s, "
                  f"batch {row['batch_s']:.2f}s "
                  f"({row['speedup']:.1f}x; cached "
                  f"{row['speedup_cached']:.1f}x), "
                  f"fitness match {row['fitness_match']}", flush=True)
            fleet_rows.append(row)
        print_csv(fleet_rows, ["n_problems", "seq_s", "batch_s",
                               "batch_cached_s", "speedup",
                               "speedup_cached", "fitness_match"])
    mixed_row = None
    if args.mixed_fleet:
        mixed_cfg = dataclasses.replace(MIXED_CFG,
                                        fitness_backend=args.backend)
        mixed_row = bench_mixed_fleet(args.mixed_fleet, mesh=mesh,
                                      cfg=mixed_cfg)
        print(f"# mixed fleet N={mixed_row['n_problems']} on "
              f"{mixed_row['devices']} devices "
              f"({mixed_row['data_shards']} shards): bucketed "
              f"{mixed_row['bucketed_warm_s']:.2f}s warm vs global-pad "
              f"{mixed_row['global_pad_s']:.2f}s "
              f"({mixed_row['bucket_speedup']:.1f}x), "
              f"{mixed_row['problems_per_s']:.0f} problems/s, "
              f"fitness match {mixed_row['fitness_match']}", flush=True)
    if args.json:
        # merge into an existing BENCH_pso.json so a mixed-fleet-only or
        # fleet-only run updates ITS entries without dropping the rest
        payload = {}
        try:
            with open(args.json) as f:
                payload = json.load(f)
        except (OSError, ValueError):
            pass
        payload.update({
            "bench": "bench_pso",
            "meta": bench_metadata(seeds=[0], mesh=mesh),
            "backend": args.backend,
            "pop": args.pop,
            "device": jax.devices()[0].platform,
        })
        if rows:
            payload["nets"] = rows
        if fleet_rows:
            payload["fleet"] = fleet_rows
        if mixed_row is not None:
            payload["mixed_fleet"] = mixed_row
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"# wrote {args.json}")


if __name__ == "__main__":
    main()
