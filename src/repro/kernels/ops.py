"""Jit'd public wrappers around the Pallas kernels.

Each op reshapes model-layout tensors into the kernel's folded layout,
dispatches, and restores the layout. ``interpret`` auto-selects: compiled
on TPU, interpret elsewhere (this container is CPU-only; TPU is the
TARGET — DESIGN.md §3).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .decode_attention import decode_attention_folded
from .flash_attention import flash_attention_folded
from .ssd_scan import ssd_intra_folded

__all__ = ["flash_attention", "ssd_intra", "decode_attention",
           "interpret_default"]


def interpret_default() -> bool:
    return jax.devices()[0].platform != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    causal: bool = True, window: int = 0) -> jnp.ndarray:
    """q: (B,S,K,G,hd); k/v: (B,S,K,hd) -> (B,S,K,G,hd)."""
    b, s, kh, g, hd = q.shape
    qf = q.transpose(0, 2, 3, 1, 4).reshape(b * kh, g, s, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(b * kh, s, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(b * kh, s, hd)
    of = flash_attention_folded(qf, kf, vf, causal=causal, window=window,
                                interpret=interpret_default())
    return of.reshape(b, kh, g, s, hd).transpose(0, 3, 1, 2, 4)


@jax.jit
def ssd_intra(xc: jnp.ndarray, cum: jnp.ndarray, Bc: jnp.ndarray,
              Cc: jnp.ndarray) -> jnp.ndarray:
    """xc: (b,c,q,h,p); cum: (b,c,q,h); Bc/Cc: (b,c,q,n) -> (b,c,q,h,p)."""
    b, c, q, h, p = xc.shape
    n = Bc.shape[-1]
    out = ssd_intra_folded(
        xc.reshape(b * c, q, h, p).astype(jnp.float32),
        cum.reshape(b * c, q, h).astype(jnp.float32),
        Bc.reshape(b * c, q, n).astype(jnp.float32),
        Cc.reshape(b * c, q, n).astype(jnp.float32),
        interpret=interpret_default())
    return out.reshape(b, c, q, h, p)


@jax.jit
def decode_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                     valid_len: jnp.ndarray) -> jnp.ndarray:
    """q: (B,K,G,hd); k/v: (B,C,K,hd); valid_len: () int32 -> (B,K,G,hd)."""
    b, kh, g, hd = q.shape
    c = k.shape[1]
    qf = q.reshape(b * kh, g, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(b * kh, c, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(b * kh, c, hd)
    vl = jnp.asarray(valid_len, jnp.int32).reshape(1, 1)
    of = decode_attention_folded(qf, kf, vf, vl,
                                 interpret=interpret_default())
    return of.reshape(b, kh, g, hd)
