"""Server integration: batched generate on reduced configs."""
import numpy as np
import pytest

from repro.configs import get
from repro.launch.serve import Server


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "mamba2-2.7b",
                                  "gemma3-27b"])
def test_generate(arch):
    cfg = get(arch).reduced()
    srv = Server(cfg, batch=2, prompt_len=16, max_new=6, eos_id=-1)
    params = srv.init_params()
    rng = np.random.default_rng(0)
    batch = {"tokens": rng.integers(2, cfg.vocab, (2, 16)).astype(np.int32)}
    out = srv.generate(params, batch)
    assert out["tokens"].shape == (2, 6)
    assert out["tokens_generated"] == 12
    assert (out["tokens"] >= 0).all() and (out["tokens"] < cfg.vocab).all()


def test_generate_greedy_deterministic():
    cfg = get("qwen3-0.6b").reduced()
    srv = Server(cfg, batch=2, prompt_len=8, max_new=4, eos_id=-1)
    params = srv.init_params(seed=1)
    rng = np.random.default_rng(1)
    batch = {"tokens": rng.integers(2, cfg.vocab, (2, 8)).astype(np.int32)}
    a = srv.generate(params, batch)["tokens"]
    b = srv.generate(params, batch)["tokens"]
    np.testing.assert_array_equal(a, b)
