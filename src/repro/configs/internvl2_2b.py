"""internvl2-2b — InternViT STUB + InternLM2 backbone. [arXiv:2404.16821; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b", family="vlm", n_layers=24, d_model=2048,
    n_heads=16, n_kv_heads=8, head_dim=128, d_ff=8192, vocab=92_553,
    act="swiglu", vision_tokens=1024, rope_theta=1_000_000.0)
