from .pipeline import (DataConfig, SyntheticStream, byte_tokenize,
                       host_slice, make_stream)

__all__ = ["DataConfig", "SyntheticStream", "byte_tokenize", "host_slice",
           "make_stream"]
