"""Unified telemetry layer: metrics registry + span tracer (DESIGN.md §13).

The planning-service stack produces all the signal an operator needs —
per-round time-to-plan, cache hit rates, ladder rung mix, solver
convergence curves, ingestion backpressure — but before this module it
was scattered across ad-hoc dicts (``ServiceReport.counters``,
``PlanCache.stats()``, ``ArrivalQueue.counters()``,
``runner_cache_stats()``) and bare ``time.perf_counter()`` calls. This
module is the ONE pipeline from event to export:

  * **MetricsRegistry** — counters (monotonic), gauges (last value),
    bounded-reservoir histograms (exact count/sum/min/max, sampled
    p50/p95/p99), and timestamped series (the solver's gBest curve).
    Thread-safe (one lock, every op O(1)), injectable clock so tests
    assert on timings deterministically, snapshot exporters to JSONL
    and Prometheus text exposition format.
  * **SpanTracer** — ``with tracer.span("replan_round", round=k)``
    emits Chrome trace-event JSON (``ph``/``ts``/``pid``/``tid``/
    ``name``) loadable in Perfetto or ``chrome://tracing``. Spans are
    B/E pairs on per-service tracks (``set_track``), point events are
    instants; nesting follows the with-statement, so a round span
    contains its cache-lookup, solve, and ladder children.
  * **Telemetry** — the facade bundling one registry + one tracer on a
    shared clock; every producer in the stack takes an optional
    ``telemetry`` argument defaulting to ``None``. With it unset,
    every instrumented path takes a no-telemetry branch that is
    bit-identical to the pre-telemetry behavior (the off-parity
    invariant, tests/test_telemetry.py).

A process-global default (``set_telemetry`` / ``get_telemetry`` /
``telemetry_scope``) lets deep layers that have no config path — the
compiled-runner cache in ``core.batch``, ``run_pso_ga``'s history
recorder — emit into the session's telemetry without threading an
argument through every call site. The global is a convenience channel:
explicit arguments always win, and ``run_service`` never mutates it.
"""
from __future__ import annotations

import contextlib
import json
import os
import threading
import time
from typing import Any, Callable, Dict, Iterator, List, Optional, Sequence

import numpy as np

__all__ = ["Counter", "Gauge", "Histogram", "Series", "MetricsRegistry",
           "SpanTracer", "Telemetry", "get_telemetry", "set_telemetry",
           "telemetry_scope", "maybe_span"]

#: reservoir size of a Histogram unless overridden — large enough that
#: p99 over a service run is stable, small enough that a hot path never
#: grows without bound.
DEFAULT_RESERVOIR = 512

#: points a Series keeps (FIFO once full) — a gBest curve is max_iters
#: long (≤ a few hundred), so full solves fit; runaway producers don't.
DEFAULT_SERIES_POINTS = 4096


class Counter:
    """Monotonic counter. ``inc`` only — decrements are a gauge's job."""

    __slots__ = ("name", "_value", "_lock")

    def __init__(self, name: str) -> None:
        self.name = name
        self._value = 0
        self._lock = threading.Lock()

    def inc(self, n: int = 1) -> None:
        if n < 0:
            raise ValueError(f"counter {self.name!r} cannot decrease "
                             f"(inc {n!r}); use a gauge")
        with self._lock:
            self._value += int(n)

    @property
    def value(self) -> int:
        with self._lock:
            return self._value


class Gauge:
    """Last-value-wins gauge."""

    __slots__ = ("name", "_value", "_lock")

    def __init__(self, name: str) -> None:
        self.name = name
        self._value = 0.0
        self._lock = threading.Lock()

    def set(self, v: float) -> None:
        with self._lock:
            self._value = float(v)

    @property
    def value(self) -> float:
        with self._lock:
            return self._value


class Histogram:
    """Bounded-reservoir histogram: exact count/sum/min/max, quantiles
    estimated from a fixed-size uniform sample (Vitter's algorithm R,
    seeded per metric name so two identical runs sample identically).
    """

    __slots__ = ("name", "_res", "_size", "_count", "_sum", "_min",
                 "_max", "_rng", "_lock")

    def __init__(self, name: str,
                 reservoir: int = DEFAULT_RESERVOIR) -> None:
        if int(reservoir) < 1:
            raise ValueError(f"reservoir must be >= 1, got {reservoir!r}")
        self.name = name
        self._res: List[float] = []
        self._size = int(reservoir)
        self._count = 0
        self._sum = 0.0
        self._min = float("inf")
        self._max = float("-inf")
        # deterministic per-name seed: parity runs sample identically
        self._rng = np.random.default_rng(
            np.frombuffer(name.encode()[:32].ljust(32, b"\0"), np.uint64))
        self._lock = threading.Lock()

    def observe(self, v: float) -> None:
        v = float(v)
        with self._lock:
            self._count += 1
            self._sum += v
            self._min = min(self._min, v)
            self._max = max(self._max, v)
            if len(self._res) < self._size:
                self._res.append(v)
            else:
                j = int(self._rng.integers(self._count))
                if j < self._size:
                    self._res[j] = v

    @property
    def count(self) -> int:
        with self._lock:
            return self._count

    def quantile(self, q: float) -> float:
        """q ∈ [0, 100]: percentile over the reservoir (0.0 if empty)."""
        with self._lock:
            if not self._res:
                return 0.0
            return float(np.percentile(self._res, q))

    def summary(self) -> Dict[str, float]:
        with self._lock:
            if self._count == 0:
                return {"count": 0, "sum": 0.0, "min": 0.0, "max": 0.0,
                        "p50": 0.0, "p95": 0.0, "p99": 0.0}
            res = np.asarray(self._res)
            p50, p95, p99 = np.percentile(res, [50, 95, 99])
            return {"count": self._count, "sum": self._sum,
                    "min": self._min, "max": self._max,
                    "p50": float(p50), "p95": float(p95),
                    "p99": float(p99)}


class Series:
    """Bounded timestamped value stream (e.g. the solver's per-iteration
    gBest key). FIFO once full — the tail of a convergence curve is the
    interesting part."""

    __slots__ = ("name", "_t", "_v", "_maxlen", "_dropped", "_lock")

    def __init__(self, name: str,
                 max_points: int = DEFAULT_SERIES_POINTS) -> None:
        if int(max_points) < 1:
            raise ValueError(f"max_points must be >= 1, "
                             f"got {max_points!r}")
        self.name = name
        self._t: List[float] = []
        self._v: List[float] = []
        self._maxlen = int(max_points)
        self._dropped = 0
        self._lock = threading.Lock()

    def append(self, t: float, v: float) -> None:
        with self._lock:
            self._t.append(float(t))
            self._v.append(float(v))
            if len(self._v) > self._maxlen:
                del self._t[0], self._v[0]
                self._dropped += 1

    def extend(self, t0: float, values: Sequence[float]) -> None:
        """Append a whole curve at a common timestamp ``t0`` with the
        index as the sub-tick (one solve's history in one call)."""
        for i, v in enumerate(np.asarray(values, float).ravel()):
            self.append(t0 + i * 1e-9, float(v))

    def points(self) -> List[tuple]:
        with self._lock:
            return list(zip(self._t, self._v))

    def summary(self) -> Dict[str, Any]:
        with self._lock:
            return {"n": len(self._v), "dropped": self._dropped,
                    "last": self._v[-1] if self._v else None}


def _prom_name(name: str) -> str:
    """Prometheus metric names allow [a-zA-Z0-9_:] only."""
    return "".join(c if c.isalnum() or c in "_:" else "_" for c in name)


class MetricsRegistry:
    """Name → metric registry with get-or-create accessors and snapshot
    exporters. All accessors are thread-safe; a name is bound to one
    metric kind for the registry's lifetime (re-registering it as
    another kind raises)."""

    def __init__(self, clock: Callable[[], float] = time.perf_counter
                 ) -> None:
        self.clock = clock
        self._metrics: Dict[str, Any] = {}
        self._lock = threading.Lock()

    def _get(self, name: str, kind, *args):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = kind(name, *args)
                self._metrics[name] = m
            elif not isinstance(m, kind):
                raise TypeError(f"metric {name!r} is a "
                                f"{type(m).__name__}, not a "
                                f"{kind.__name__}")
            return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str,
                  reservoir: int = DEFAULT_RESERVOIR) -> Histogram:
        return self._get(name, Histogram, reservoir)

    def series(self, name: str,
               max_points: int = DEFAULT_SERIES_POINTS) -> Series:
        return self._get(name, Series, max_points)

    # -- convenience one-liners ---------------------------------------
    def inc(self, name: str, n: int = 1) -> None:
        self.counter(name).inc(n)

    def set_gauge(self, name: str, v: float) -> None:
        self.gauge(name).set(v)

    def observe(self, name: str, v: float) -> None:
        self.histogram(name).observe(v)

    def record_series(self, name: str, values: Sequence[float]) -> None:
        self.series(name).extend(self.clock(), values)

    # -- export --------------------------------------------------------
    def snapshot(self) -> Dict[str, Dict[str, Any]]:
        """One nested dict: {counters, gauges, histograms, series}."""
        with self._lock:
            items = list(self._metrics.items())
        out: Dict[str, Dict[str, Any]] = {
            "counters": {}, "gauges": {}, "histograms": {}, "series": {}}
        for name, m in items:
            if isinstance(m, Counter):
                out["counters"][name] = m.value
            elif isinstance(m, Gauge):
                out["gauges"][name] = m.value
            elif isinstance(m, Histogram):
                out["histograms"][name] = m.summary()
            else:
                out["series"][name] = m.summary()
        return out

    def to_jsonl(self) -> str:
        """One JSON object per line per metric — the machine-readable
        snapshot (series include their points)."""
        with self._lock:
            items = list(self._metrics.items())
        lines = []
        for name, m in items:
            if isinstance(m, Counter):
                rec = {"type": "counter", "name": name, "value": m.value}
            elif isinstance(m, Gauge):
                rec = {"type": "gauge", "name": name, "value": m.value}
            elif isinstance(m, Histogram):
                rec = {"type": "histogram", "name": name, **m.summary()}
            else:
                rec = {"type": "series", "name": name, **m.summary(),
                       "points": m.points()}
            lines.append(json.dumps(rec, allow_nan=False,
                                    default=float))
        return "\n".join(lines) + ("\n" if lines else "")

    def to_prometheus(self) -> str:
        """Prometheus text exposition format: counters as ``_total``,
        histograms as summaries (quantile labels + _count/_sum), series
        as a last-value gauge."""
        with self._lock:
            items = list(self._metrics.items())
        out = []
        for name, m in items:
            pn = _prom_name(name)
            if isinstance(m, Counter):
                out.append(f"# TYPE {pn}_total counter")
                out.append(f"{pn}_total {m.value}")
            elif isinstance(m, Gauge):
                out.append(f"# TYPE {pn} gauge")
                out.append(f"{pn} {m.value}")
            elif isinstance(m, Histogram):
                s = m.summary()
                out.append(f"# TYPE {pn} summary")
                for q, key in ((0.5, "p50"), (0.95, "p95"),
                               (0.99, "p99")):
                    out.append(f'{pn}{{quantile="{q}"}} {s[key]}')
                out.append(f"{pn}_count {s['count']}")
                out.append(f"{pn}_sum {s['sum']}")
            else:
                s = m.summary()
                last = s["last"] if s["last"] is not None else 0.0
                out.append(f"# TYPE {pn}_last gauge")
                out.append(f"{pn}_last {last}")
        return "\n".join(out) + ("\n" if out else "")

    def write(self, out_dir: str) -> Dict[str, str]:
        """Write ``metrics.jsonl`` + ``metrics.prom`` under ``out_dir``
        (created if missing); returns the paths."""
        os.makedirs(out_dir, exist_ok=True)
        paths = {"jsonl": os.path.join(out_dir, "metrics.jsonl"),
                 "prom": os.path.join(out_dir, "metrics.prom")}
        with open(paths["jsonl"], "w") as f:
            f.write(self.to_jsonl())
        with open(paths["prom"], "w") as f:
            f.write(self.to_prometheus())
        return paths


class SpanTracer:
    """Chrome-trace-event span recorder (Perfetto / chrome://tracing).

    Spans are emitted as ``B``/``E`` duration pairs, point events as
    ``i`` instants, and track labels as ``M`` metadata; every event
    carries the required ``ph``/``ts``/``pid``/``tid``/``name`` fields
    (``ts`` in microseconds on the tracer's clock, relative to tracer
    creation so traces start near 0). The current *track* (Perfetto
    row) is thread-local: ``set_track(j, "service-j")`` routes every
    span this thread opens onto track ``j`` — that is how N concurrent
    ``run_service`` loops get one timeline row each.
    """

    def __init__(self, clock: Callable[[], float] = time.perf_counter,
                 pid: int = 0) -> None:
        self.clock = clock
        self.pid = int(pid)
        self._events: List[Dict[str, Any]] = []
        self._lock = threading.Lock()
        self._tls = threading.local()
        self._t0 = clock()

    def _ts(self) -> float:
        return (self.clock() - self._t0) * 1e6

    def _tid(self, tid: Optional[int]) -> int:
        if tid is not None:
            return int(tid)
        return int(getattr(self._tls, "track", 0))

    def _emit(self, ev: Dict[str, Any]) -> None:
        with self._lock:
            self._events.append(ev)

    def set_track(self, track: int, label: Optional[str] = None) -> None:
        """Route this thread's spans onto Perfetto row ``track``; with
        ``label``, also name the row (a ``thread_name`` metadata event).
        """
        self._tls.track = int(track)
        if label is not None:
            self._emit({"ph": "M", "ts": 0.0, "pid": self.pid,
                        "tid": int(track), "name": "thread_name",
                        "args": {"name": str(label)}})

    @contextlib.contextmanager
    def span(self, name: str, tid: Optional[int] = None,
             **args: Any) -> Iterator[None]:
        """``with tracer.span("replan_round", round=k): ...`` — a B/E
        duration pair on the current (or given) track; nested spans
        nest on the timeline exactly like the with-statements do."""
        t = self._tid(tid)
        self._emit({"ph": "B", "ts": self._ts(), "pid": self.pid,
                    "tid": t, "name": name,
                    "args": {k: _arg(v) for k, v in args.items()}})
        try:
            yield
        finally:
            self._emit({"ph": "E", "ts": self._ts(), "pid": self.pid,
                        "tid": t, "name": name})

    def instant(self, name: str, tid: Optional[int] = None,
                **args: Any) -> None:
        """A zero-duration point event (breaker opened, cache hit...)."""
        self._emit({"ph": "i", "ts": self._ts(), "pid": self.pid,
                    "tid": self._tid(tid), "name": name, "s": "t",
                    "args": {k: _arg(v) for k, v in args.items()}})

    def events(self) -> List[Dict[str, Any]]:
        with self._lock:
            return list(self._events)

    def to_chrome_trace(self) -> Dict[str, Any]:
        return {"traceEvents": self.events(),
                "displayTimeUnit": "ms"}

    def export(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_chrome_trace(), f, allow_nan=False,
                      default=float)


def _arg(v: Any) -> Any:
    """JSON-safe span-arg coercion (numpy scalars, tuples, ...)."""
    if isinstance(v, (bool, int, float, str)) or v is None:
        return v
    if isinstance(v, (np.integer,)):
        return int(v)
    if isinstance(v, (np.floating,)):
        return float(v)
    return str(v)


class Telemetry:
    """The facade every instrumented layer takes: one registry + one
    tracer on one shared (injectable) clock. ``Telemetry()`` is wall
    clock; ``Telemetry(clock=fake)`` makes every ``ts``, histogram
    observation timestamp, and ``run_service`` wall measurement
    deterministic."""

    def __init__(self, clock: Optional[Callable[[], float]] = None,
                 pid: int = 0) -> None:
        self.clock = clock if clock is not None else time.perf_counter
        self.registry = MetricsRegistry(clock=self.clock)
        self.tracer = SpanTracer(clock=self.clock, pid=pid)

    # tracer delegates
    def span(self, name: str, tid: Optional[int] = None, **args: Any):
        return self.tracer.span(name, tid=tid, **args)

    def instant(self, name: str, tid: Optional[int] = None,
                **args: Any) -> None:
        self.tracer.instant(name, tid=tid, **args)

    def set_track(self, track: int, label: Optional[str] = None) -> None:
        self.tracer.set_track(track, label)

    # registry delegates
    def inc(self, name: str, n: int = 1) -> None:
        self.registry.inc(name, n)

    def set_gauge(self, name: str, v: float) -> None:
        self.registry.set_gauge(name, v)

    def observe(self, name: str, v: float) -> None:
        self.registry.observe(name, v)

    def record_series(self, name: str, values: Sequence[float]) -> None:
        self.registry.record_series(name, values)

    # export
    def export_trace(self, path: str) -> None:
        self.tracer.export(path)

    def export_metrics(self, out_dir: str) -> Dict[str, str]:
        return self.registry.write(out_dir)


def maybe_span(tel: Optional[Telemetry], name: str, **args: Any):
    """``with maybe_span(tel, "solve", rung=r):`` — a real span when
    telemetry is on, a free ``nullcontext`` when it is off (the
    off-path stays untouched)."""
    if tel is None:
        return contextlib.nullcontext()
    return tel.span(name, **args)


# ---------------------------------------------------------------------------
# process-global default (the convenience channel for layers with no
# config path: the runner cache, run_pso_ga's history recorder)
# ---------------------------------------------------------------------------

_GLOBAL: Optional[Telemetry] = None
_GLOBAL_LOCK = threading.Lock()


def set_telemetry(tel: Optional[Telemetry]) -> Optional[Telemetry]:
    """Install ``tel`` as the process-global default; returns the
    previous one. Explicit ``telemetry=`` arguments always win over the
    global."""
    global _GLOBAL
    with _GLOBAL_LOCK:
        prev, _GLOBAL = _GLOBAL, tel
        return prev


def get_telemetry() -> Optional[Telemetry]:
    with _GLOBAL_LOCK:
        return _GLOBAL


@contextlib.contextmanager
def telemetry_scope(tel: Optional[Telemetry]) -> Iterator[None]:
    """Temporarily install ``tel`` as the global default."""
    prev = set_telemetry(tel)
    try:
        yield
    finally:
        set_telemetry(prev)
