"""The paper's primary contribution: cost-driven offloading of DNN layers
over cloud / edge / end devices via PSO-GA (Lin et al., 2019).

Public surface:
  * LayerDAG / preprocess / merge_dags      — paper §III-A, Alg. 1
  * Environment / paper_environment / ...   — paper §III-A, Tables II-IV
  * SimProblem / simulate_np / build_simulator — paper Alg. 2
  * run_pso_ga / PSOGAConfig                — paper §IV (Eq. 17-23)
  * greedy_offload / run_ga / run_pso_linear / heft_makespan / pre_pso
                                            — paper §V-B competitors
  * zoo                                     — AlexNet/VGG19/GoogleNet/ResNet101
  * placement / partition                   — TPU-fleet bridge (DESIGN.md §3)
  * batch / run_pso_ga_batch                — fleet-scale batched solver
                                              (DESIGN.md §4)
  * online / EnvTrace / replan_fleet        — online re-planning for
                                              drifting fleets (DESIGN.md §9)
  * traffic / sample_arrivals / traffic_replay — request-stream workload
                                              engine and contention-aware
                                              planning (DESIGN.md §10)
  * service / run_service                   — fault-tolerant always-on
                                              planning service
                                              (DESIGN.md §11)
  * telemetry / Telemetry / MetricsRegistry — unified metrics + span
                                              tracing with Perfetto
                                              export (DESIGN.md §13)
"""
from .dag import LayerDAG, merge_dags, preprocess, topological_order
from .environment import (CLOUD, DEVICE, EDGE, Environment,
                          paper_environment, sample_environment,
                          tpu_fleet_environment)
from .fitness import (INFEASIBLE_OFFSET, fitness_key, make_swarm_fitness,
                      migration_cost, resolve_fitness_backend)
from .simulator import (PaddedProblem, SimProblem, SimResult,
                        build_simulator, pad_problem, simulate_np,
                        simulate_padded, simulate_swarm)
from .pso_ga import PSOGAConfig, PSOGAResult, run_pso_ga, swarm_step
from .batch import (FleetBucket, PackedFleet, pack_arrivals, pack_fleet,
                    pack_problems, run_pso_ga_batch,
                    runner_cache_stats, reset_runner_cache_stats)
from .online import (DriftEvent, EnvTrace, OnlineReport, ReplanConfig,
                     RoundLog, TRACE_KINDS, plan_is_valid, replan_fleet,
                     replan_round, sample_trace, zero_drift_trace)
from .plancache import PlanCache, PlanCacheConfig, dag_fingerprint
from .seeding import coerce_seed, rng_entropy
from .telemetry import (MetricsRegistry, SpanTracer, Telemetry,
                        get_telemetry, maybe_span, set_telemetry,
                        telemetry_scope)
from .service import (ChaosConfig, LADDER_RUNGS, ServiceConfig,
                      ServiceReport, ServiceRoundLog, run_service,
                      run_services)
from .traffic import (ArrivalQueue, ArrivalTrace, IngestConfig,
                      TRAFFIC_KINDS, TrafficConfig,
                      TrafficResult, sample_arrivals,
                      simulate_traffic_swarm, traffic_replay,
                      traffic_stats, zero_contention_arrivals)
from .baselines import (GAConfig, greedy_offload, heft_makespan, pre_pso,
                        run_ga, run_pso_linear)
from .partition import Stage, contiguous_stages, stage_cut_cost, \
    uniform_stages
from .placement import (OffloadPlan, arch_to_dag, block_flops, plan_offload,
                        plan_offload_batch)
from . import zoo

__all__ = [
    "LayerDAG", "merge_dags", "preprocess", "topological_order",
    "Environment", "paper_environment", "sample_environment",
    "tpu_fleet_environment", "CLOUD", "EDGE", "DEVICE",
    "INFEASIBLE_OFFSET", "fitness_key", "make_swarm_fitness",
    "migration_cost", "resolve_fitness_backend",
    "SimProblem", "SimResult", "build_simulator", "simulate_np",
    "PaddedProblem", "pad_problem", "simulate_padded", "simulate_swarm",
    "PSOGAConfig", "PSOGAResult", "run_pso_ga", "swarm_step",
    "FleetBucket", "PackedFleet", "pack_arrivals", "pack_fleet",
    "pack_problems", "run_pso_ga_batch",
    "runner_cache_stats", "reset_runner_cache_stats",
    "DriftEvent", "EnvTrace", "OnlineReport", "ReplanConfig", "RoundLog",
    "TRACE_KINDS", "plan_is_valid", "replan_fleet", "replan_round",
    "sample_trace", "zero_drift_trace",
    "ChaosConfig", "LADDER_RUNGS", "ServiceConfig", "ServiceReport",
    "ServiceRoundLog", "run_service", "run_services",
    "PlanCache", "PlanCacheConfig", "dag_fingerprint",
    "coerce_seed", "rng_entropy",
    "MetricsRegistry", "SpanTracer", "Telemetry", "get_telemetry",
    "maybe_span", "set_telemetry", "telemetry_scope",
    "ArrivalQueue", "ArrivalTrace", "IngestConfig",
    "TRAFFIC_KINDS", "TrafficConfig", "TrafficResult",
    "sample_arrivals", "simulate_traffic_swarm", "traffic_replay",
    "traffic_stats", "zero_contention_arrivals",
    "GAConfig", "greedy_offload", "heft_makespan", "pre_pso", "run_ga",
    "run_pso_linear", "zoo",
    "Stage", "contiguous_stages", "stage_cut_cost", "uniform_stages",
    "OffloadPlan", "arch_to_dag", "block_flops", "plan_offload",
    "plan_offload_batch",
]
