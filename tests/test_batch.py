"""Fleet-scale batched PSO-GA (repro.core.batch, DESIGN.md §4):
sequential parity, padding masks, and per-problem convergence freezing."""
import numpy as np
import pytest

from repro.core import (PSOGAConfig, SimProblem, pack_problems,
                        pad_problem, paper_environment, run_pso_ga,
                        run_pso_ga_batch, sample_environment,
                        simulate_np, simulate_padded, zoo)
from repro.core.batch import bucket_size
from repro.core.dag import LayerDAG

FAST = PSOGAConfig(pop_size=24, max_iters=80, stall_iters=25)


def fig2_dag(env):
    return LayerDAG(
        compute=np.array([1.1, 1.92, 2.35, 2.12]) * env.power[0],
        edges=np.array([[0, 1], [0, 2], [1, 3], [2, 3]]),
        edge_mb=np.array([1.0, 1.0, 0.5, 0.5]),
        app_id=np.zeros(4, np.int32), deadline=np.array([3.7]),
        pinned=np.array([0, -1, -1, -1], np.int32))


@pytest.fixture(scope="module")
def fleet3():
    """Three heterogeneous problems: different DAGs, envs, pins, deadlines."""
    env_s = sample_environment()
    env_p = paper_environment()
    return [(fig2_dag(env_s), env_s),
            (zoo.alexnet(pin_server=0, deadline=6.0), env_p),
            (zoo.vgg19(pin_server=1, deadline=40.0), env_p)]


# ---------------------------------------------------------------------------
# padded simulator == unpadded numpy oracle, regardless of padding amount
# ---------------------------------------------------------------------------

def test_padded_sim_matches_np_oracle(rng):
    """Fitness is invariant under (arbitrary) padding: the padded JAX sim
    reproduces the unpadded numpy oracle bit-for-bit in every field."""
    env = sample_environment()
    dag = zoo.alexnet(pin_server=0, deadline=6.0)
    prob = SimProblem.build(dag, env)
    pp = pad_problem(prob, max_p=32, max_S=11, max_in=4, max_out=5,
                     max_apps=3)
    for faithful in (True, False):
        for _ in range(5):
            x = rng.integers(0, env.num_servers, size=dag.num_layers)
            xp = np.zeros(32, np.int32)
            xp[:dag.num_layers] = x
            ref = simulate_np(prob, x, faithful=faithful)
            out = simulate_padded(pp, xp, faithful=faithful)
            np.testing.assert_allclose(
                np.asarray(out.end_times)[:dag.num_layers],
                ref.end_times, rtol=1e-6)
            np.testing.assert_allclose(float(out.total_cost),
                                       float(ref.total_cost), rtol=1e-6)
            assert bool(out.feasible) == bool(ref.feasible)
            np.testing.assert_allclose(float(out.makespan),
                                       float(ref.makespan), rtol=1e-6)
            # padded layers are no-ops: end time stays 0
            assert np.all(np.asarray(out.end_times)[dag.num_layers:] == 0.0)


# ---------------------------------------------------------------------------
# batched == sequential, gene for gene
# ---------------------------------------------------------------------------

def test_batched_matches_sequential(fleet3):
    """N=3 heterogeneous problems, same seeds: the batched fleet returns
    the sequential solver's gbest exactly — fitness, genes, iterations."""
    seeds = [0, 1, 2]
    seq = [run_pso_ga(dag, env, FAST, seed=s)
           for (dag, env), s in zip(fleet3, seeds)]
    bat = run_pso_ga_batch(fleet3, FAST, seed=seeds)
    for a, b in zip(seq, bat):
        assert a.best_fitness == b.best_fitness
        assert np.array_equal(a.best_x, b.best_x)
        assert a.iterations == b.iterations
        assert a.feasible == b.feasible
        assert a.best_cost == b.best_cost


def test_batched_scalar_seed_broadcasts(fleet3):
    one = run_pso_ga(*fleet3[0], FAST, seed=7)
    bat = run_pso_ga_batch(fleet3, FAST, seed=7)
    assert bat[0].best_fitness == one.best_fitness


# ---------------------------------------------------------------------------
# padding masks: padded layers / servers are never selected
# ---------------------------------------------------------------------------

def test_padding_never_selected(fleet3):
    results, state = run_pso_ga_batch(fleet3, FAST, seed=0,
                                      return_state=True)
    X = np.asarray(state.X)                    # (N, P, max_p)
    gbest = np.asarray(state.gbest_x)
    for i, (dag, env) in enumerate(fleet3):
        p, s = dag.num_layers, env.num_servers
        # real genes only ever name real servers (padded servers would be
        # unreachable: link_ok false, power 1)
        assert np.all(X[i, :, :p] < s)
        assert np.all(gbest[i, :p] < s)
        assert np.all(results[i].best_x < s)
        # padded genes were never mutated away from their init value 0
        assert np.all(X[i, :, p:] == 0)
        assert np.all(gbest[i, p:] == 0)
        assert results[i].best_x.shape == (p,)


def test_pack_problems_buckets_shapes(fleet3):
    ppb = pack_problems(fleet3, bucket=True)
    n_layers = max(d.num_layers for d, _ in fleet3)
    n_srv = max(e.num_servers for _, e in fleet3)
    assert ppb.compute.shape == (3, bucket_size(n_layers))
    assert ppb.power.shape[1] == bucket_size(n_srv, floor=4)
    assert np.array_equal(np.asarray(ppb.num_layers),
                          [d.num_layers for d, _ in fleet3])
    # padded deadlines are +inf -> never violated
    assert np.all(np.isinf(np.asarray(ppb.deadline)[:, 1:]))


def test_bucket_size():
    assert bucket_size(3) == 8
    assert bucket_size(8) == 8
    assert bucket_size(9) == 16
    assert bucket_size(341) == 512
    assert bucket_size(3, floor=4) == 4


# ---------------------------------------------------------------------------
# convergence freeze: an early-converged problem stops evolving
# ---------------------------------------------------------------------------

def test_convergence_freeze(fleet3):
    """A trivially-converged problem (everything pinned home, gbest found
    at init) freezes at stall_iters while harder problems keep iterating —
    and its frozen gbest equals its sequential solution."""
    env = paper_environment()
    alex = zoo.alexnet(pin_server=0, deadline=1e9)
    trivial = LayerDAG(compute=alex.compute, edges=alex.edges,
                       edge_mb=alex.edge_mb, app_id=alex.app_id,
                       deadline=alex.deadline,
                       pinned=np.zeros(alex.num_layers, np.int32))
    hard_dag, hard_env = fleet3[0]
    results = run_pso_ga_batch([(trivial, env), (hard_dag, hard_env)],
                               FAST, seed=0)
    triv, hard = results
    seq = run_pso_ga(trivial, env, FAST, seed=0)
    # converged immediately: gbest never improved after init, so the stall
    # counter ran straight to the stopping rule
    assert triv.iterations == FAST.stall_iters
    assert triv.best_fitness == seq.best_fitness == 0.0
    assert np.array_equal(triv.best_x, seq.best_x)
    # the harder problem kept iterating after the trivial one froze
    assert hard.iterations > triv.iterations
    # and matches ITS sequential run too (freeze leaked nothing across)
    seq_hard = run_pso_ga(hard_dag, hard_env, FAST, seed=0)
    assert hard.best_fitness == seq_hard.best_fitness


def test_runner_cache_reused(fleet3):
    from repro.core.batch import runner_cache_info
    run_pso_ga_batch(fleet3, FAST, seed=0)
    n_before = len(runner_cache_info())
    run_pso_ga_batch(fleet3, FAST, seed=3)     # same shapes, new seeds
    assert len(runner_cache_info()) == n_before


def test_batch_seed_count_mismatch(fleet3):
    with pytest.raises(ValueError):
        run_pso_ga_batch(fleet3, FAST, seed=[0, 1])


def test_batch_seed_int_like_scalars(fleet3):
    """np.int64 / 0-d arrays broadcast like python ints (regression:
    np.isscalar rejects 0-d arrays, so these used to crash or misfire)."""
    ref = run_pso_ga_batch(fleet3, FAST, seed=7)
    for seed in (np.int64(7), np.array(7), np.asarray(7, np.int32)):
        out = run_pso_ga_batch(fleet3, FAST, seed=seed)
        for a, b in zip(ref, out):
            assert a.best_fitness == b.best_fitness
            assert np.array_equal(a.best_x, b.best_x)


def test_batch_seed_array_sequence(fleet3):
    """Per-problem seeds as a numpy array behave like the list form."""
    ref = run_pso_ga_batch(fleet3, FAST, seed=[3, 4, 5])
    out = run_pso_ga_batch(fleet3, FAST, seed=np.array([3, 4, 5]))
    for a, b in zip(ref, out):
        assert a.best_fitness == b.best_fitness


def test_batch_seed_rejects_non_int(fleet3):
    with pytest.raises(TypeError):
        run_pso_ga_batch(fleet3, FAST, seed=0.5)
    with pytest.raises(ValueError):
        run_pso_ga_batch(fleet3, FAST, seed=np.zeros((2, 2), np.int32))
