"""Schedule simulator — paper Algorithm 2 ("map from a particle to DNN
layers offloading").

Given an assignment vector ``x`` (server index per layer) the simulator
replays the offloading: layers execute in a fixed topological order (the
paper freezes the order genes φ at initialization — §IV-B.3 "the value of
the order φ_j for each layer remains the same"), each server is a serial
queue, incoming datasets pay ``∂ / ℓ`` transfer time, and the server stays
busy for its outgoing transfers (Alg. 2 line 21).

Two fidelity modes (see DESIGN.md §2):
  * ``faithful=True``  — the printed recurrence, verbatim:
        T_start = T_lease(s) + maxTrans            (lines 4/11)
        T_lease(s) += exe + transfer_out           (line 21)
    (the incoming wait is *not* added to the server busy time, exactly as
    printed in the paper).
  * ``faithful=False`` — "corrected": serial processing is preserved and
    a layer cannot start before its parents finished and shipped:
        T_start = max(T_lease(s), max_p(T_end(p) + trans_p))
        T_lease(s) = T_end + transfer_out

Cost model (Eq. 8): per-server rental  c_com · (T_off − T_on)  with
T_on = first T_start on the server, T_off = final lease (includes trailing
outgoing transfers), plus per-edge transmission  c_tran · ∂  for every
edge crossing two distinct servers.

Missing links (ℓ = 0, e.g. device↔device) are clamped to ``MIN_BW`` MB/s
so infeasible placements get enormous-but-finite times — this keeps the
paper's Case-2 fitness (compare total completion times of two infeasible
particles) a meaningful total order instead of inf == inf.

Both a pure-numpy reference (`simulate_np`) and a jit/vmap-able JAX
implementation (`build_simulator`) are provided; tests assert they agree.

The JAX path operates on a *padded* representation (``PaddedProblem`` +
``simulate_padded``): layers are padded to ``max_p`` (padded ``order``
entries are -1 and execute as zero-cost no-ops), servers to ``max_S``
(padded servers are unreachable: ``link_ok`` false, never selected by the
solver), apps to ``max_apps`` (deadline +inf). ``build_simulator`` is the
zero-padding special case; ``repro.core.batch`` stacks N heterogeneous
``PaddedProblem``s along a leading axis and vmaps ``simulate_padded`` over
the whole fleet (DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .dag import LayerDAG, topological_order
from .environment import Environment

MIN_BW = 1e-9   # MB/s stand-in for "no link"
__all__ = ["SimResult", "SimProblem", "PaddedProblem", "pad_problem",
           "simulate_padded", "simulate_np", "build_simulator", "MIN_BW"]


class SimResult(NamedTuple):
    """All fields are jnp/np arrays; scalar fields are 0-d."""
    end_times: jnp.ndarray        # (p,) per-layer completion time
    app_completion: jnp.ndarray   # (n_apps,) T_i^comp
    comp_cost: jnp.ndarray        # $ rental
    trans_cost: jnp.ndarray       # $ transmission
    total_cost: jnp.ndarray       # Eq. 8
    feasible: jnp.ndarray         # bool: all deadlines met AND pins honored
    makespan: jnp.ndarray         # max end time


@dataclasses.dataclass(frozen=True)
class SimProblem:
    """Static, device-ready arrays describing (dag, env) for the simulator."""
    compute: np.ndarray       # (p,)
    order: np.ndarray         # (p,) topological order
    parent_idx: np.ndarray    # (p, max_in) padded -1
    parent_mb: np.ndarray     # (p, max_in)
    child_idx: np.ndarray     # (p, max_out) padded -1
    child_mb: np.ndarray      # (p, max_out)
    app_id: np.ndarray        # (p,)
    deadline: np.ndarray      # (n_apps,)
    pinned: np.ndarray        # (p,)
    power: np.ndarray         # (S,)
    cost_per_sec: np.ndarray  # (S,)
    inv_bw: np.ndarray        # (S, S) seconds per MB (0 on diagonal)
    tran_cost: np.ndarray     # (S, S) $/MB (0 on diagonal)
    link_ok: np.ndarray       # (S, S) bool

    @property
    def num_layers(self) -> int:
        return int(self.compute.shape[0])

    @property
    def num_servers(self) -> int:
        return int(self.power.shape[0])

    @property
    def num_apps(self) -> int:
        return int(self.deadline.shape[0])

    @staticmethod
    def build(dag: LayerDAG, env: Environment) -> "SimProblem":
        pi, pm, ci, cm = dag.padded_relatives()
        bw = np.where(env.bandwidth <= 0.0, MIN_BW, env.bandwidth)
        inv_bw = 1.0 / bw                     # diagonal is 1/inf = 0
        return SimProblem(
            compute=dag.compute, order=topological_order(dag),
            parent_idx=pi, parent_mb=pm, child_idx=ci, child_mb=cm,
            app_id=dag.app_id, deadline=dag.deadline, pinned=dag.pinned,
            power=env.power, cost_per_sec=env.cost_per_sec,
            inv_bw=inv_bw, tran_cost=env.tran_cost,
            link_ok=env.bandwidth > 0.0)


# ---------------------------------------------------------------------------
# numpy reference (oracle for tests)
# ---------------------------------------------------------------------------

def simulate_np(prob: SimProblem, x: np.ndarray, faithful: bool = True
                ) -> SimResult:
    x = np.asarray(x, np.int64)
    p, s = prob.num_layers, prob.num_servers
    lease = np.zeros(s)
    t_on = np.full(s, np.inf)
    used = np.zeros(s, bool)
    end = np.zeros(p)
    trans_cost = 0.0
    link_violation = False

    for j in prob.order:
        srv = x[j]
        exe = prob.compute[j] / prob.power[srv]
        pars = prob.parent_idx[j]
        mask = pars >= 0
        max_trans = 0.0
        parent_gate = 0.0
        for k in np.nonzero(mask)[0]:
            pj = pars[k]
            mb = prob.parent_mb[j, k]
            t = mb * prob.inv_bw[x[pj], srv]
            if not prob.link_ok[x[pj], srv] and x[pj] != srv:
                link_violation = True
            max_trans = max(max_trans, t)
            parent_gate = max(parent_gate, end[pj] + t)
            trans_cost += prob.tran_cost[x[pj], srv] * mb
        if faithful:
            start = lease[srv] + max_trans
        else:
            start = max(lease[srv], parent_gate)
        t_end = start + exe
        end[j] = t_end
        t_on[srv] = min(t_on[srv], start)
        used[srv] = True
        transfer_out = 0.0
        cidx = prob.child_idx[j]
        for k in np.nonzero(cidx >= 0)[0]:
            transfer_out += prob.child_mb[j, k] * prob.inv_bw[srv, x[cidx[k]]]
        if faithful:
            lease[srv] = lease[srv] + exe + transfer_out   # line 21, verbatim
        else:
            lease[srv] = t_end + transfer_out

    app_completion = np.zeros(prob.num_apps)
    np.maximum.at(app_completion, prob.app_id, end)
    comp_cost = float(np.sum(np.where(used, prob.cost_per_sec * (lease - np.where(np.isinf(t_on), 0.0, t_on)), 0.0)))
    pin_ok = np.all((prob.pinned < 0) | (x == prob.pinned))
    feasible = bool(np.all(app_completion <= prob.deadline) and pin_ok
                    and not link_violation)
    total = comp_cost + trans_cost
    return SimResult(end_times=end, app_completion=app_completion,
                     comp_cost=np.float64(comp_cost),
                     trans_cost=np.float64(trans_cost),
                     total_cost=np.float64(total),
                     feasible=np.bool_(feasible),
                     makespan=np.float64(end.max() if p else 0.0))


# ---------------------------------------------------------------------------
# JAX implementation — padded representation, lax.scan over layers,
# vmap over particles (and, in repro.core.batch, over problems)
# ---------------------------------------------------------------------------


class PaddedProblem(NamedTuple):
    """Device-ready padded arrays for one problem (DESIGN.md §4).

    Every field is a jnp array; ``repro.core.batch`` stacks N of these
    along a leading axis and vmaps the simulator/step over it. Padding
    conventions (all padding is appended AFTER the real entries so float
    reductions accumulate identical partial sums):
      * layers  -> ``max_p``:   ``order`` padded -1 (scan no-op), compute 0,
        pinned -1, parent/child idx -1.
      * servers -> ``max_S``:   power 1 (no div-by-0), cost 0, link_ok
        False, inv_bw 1/MIN_BW — and the solver never emits genes >=
        ``num_servers``, so padded servers are unreachable by construction.
      * apps    -> ``max_apps``: deadline +inf (never violated; an empty
        app's completion clamps to 0).
    ``num_layers`` / ``num_servers`` / ``num_apps`` are the TRUE counts as
    0-d int32 arrays — traced per problem under vmap, so PSO-GA mutation
    and crossover draw bounds from the real sizes, not the padded ones.
    """
    compute: jnp.ndarray        # (max_p,)
    order: jnp.ndarray          # (max_p,) topo order, padded -1
    parent_idx: jnp.ndarray     # (max_p, max_in) padded -1
    parent_mb: jnp.ndarray      # (max_p, max_in)
    child_idx: jnp.ndarray      # (max_p, max_out) padded -1
    child_mb: jnp.ndarray       # (max_p, max_out)
    app_id: jnp.ndarray         # (max_p,)
    deadline: jnp.ndarray       # (max_apps,) padded +inf
    pinned: jnp.ndarray         # (max_p,) padded -1
    power: jnp.ndarray          # (max_S,)
    cost_per_sec: jnp.ndarray   # (max_S,)
    inv_bw: jnp.ndarray         # (max_S, max_S)
    tran_cost: jnp.ndarray      # (max_S, max_S)
    link_ok: jnp.ndarray        # (max_S, max_S) bool
    num_layers: jnp.ndarray     # () int32 — true p
    num_servers: jnp.ndarray    # () int32 — true S
    num_apps: jnp.ndarray       # () int32 — true n_apps

    @property
    def max_layers(self) -> int:
        return int(self.compute.shape[-1])

    @property
    def max_servers(self) -> int:
        return int(self.power.shape[-1])


def pad_problem(prob: SimProblem,
                max_p: Optional[int] = None,
                max_S: Optional[int] = None,
                max_in: Optional[int] = None,
                max_out: Optional[int] = None,
                max_apps: Optional[int] = None) -> PaddedProblem:
    """Embed a ``SimProblem`` into the padded representation.

    With all sizes None this is the identity embedding (zero padding) —
    ``build_simulator`` uses exactly that, so the unbatched solver is the
    N=1 case of the batched machinery.
    """
    p, s, a = prob.num_layers, prob.num_servers, prob.num_apps
    in0, out0 = prob.parent_idx.shape[1], prob.child_idx.shape[1]
    max_p = p if max_p is None else max_p
    max_S = s if max_S is None else max_S
    max_in = in0 if max_in is None else max_in
    max_out = out0 if max_out is None else max_out
    max_apps = a if max_apps is None else max_apps
    if max_p < p or max_S < s or max_in < in0 or max_out < out0 \
            or max_apps < a:
        raise ValueError("padded sizes smaller than problem sizes")

    def pad(arr, shape, fill):
        out = np.full(shape, fill, dtype=arr.dtype)
        out[tuple(slice(0, n) for n in arr.shape)] = arr
        return jnp.asarray(out)

    return PaddedProblem(
        compute=pad(prob.compute, (max_p,), 0.0),
        order=pad(prob.order, (max_p,), -1),
        parent_idx=pad(prob.parent_idx, (max_p, max_in), -1),
        parent_mb=pad(prob.parent_mb, (max_p, max_in), 0.0),
        child_idx=pad(prob.child_idx, (max_p, max_out), -1),
        child_mb=pad(prob.child_mb, (max_p, max_out), 0.0),
        app_id=pad(prob.app_id, (max_p,), 0),
        deadline=pad(prob.deadline, (max_apps,), np.inf),
        pinned=pad(prob.pinned, (max_p,), -1),
        power=pad(prob.power, (max_S,), 1.0),
        cost_per_sec=pad(prob.cost_per_sec, (max_S,), 0.0),
        inv_bw=pad(prob.inv_bw, (max_S, max_S), 1.0 / MIN_BW),
        tran_cost=pad(prob.tran_cost, (max_S, max_S), 0.0),
        link_ok=pad(prob.link_ok, (max_S, max_S), False),
        num_layers=jnp.asarray(p, jnp.int32),
        num_servers=jnp.asarray(s, jnp.int32),
        num_apps=jnp.asarray(a, jnp.int32))


def simulate_padded(pp: PaddedProblem, x: jnp.ndarray,
                    faithful: bool = True) -> SimResult:
    """Algorithm 2 on the padded representation. Pure — vmap over particles
    (``x`` axis) and/or problems (leading ``pp`` axis) freely.

    Padded ``order`` entries (-1) leave every piece of carry state
    untouched, so a padded layer is a zero-cost no-op and the result is
    bit-identical to the unpadded simulation of the embedded problem.
    """
    x = jnp.asarray(x).astype(jnp.int32)
    max_p = pp.compute.shape[0]
    max_S = pp.power.shape[0]
    max_apps = pp.deadline.shape[0]

    def step(carry, j):
        lease, t_on, used, end, trans_cost, link_bad = carry
        valid = j >= 0
        jsafe = jnp.where(valid, j, 0)
        srv = x[jsafe]
        exe = pp.compute[jsafe] / pp.power[srv]
        pars = pp.parent_idx[jsafe]               # (max_in,)
        pmask = (pars >= 0) & valid
        psafe = jnp.where(pmask, pars, 0)
        psrv = x[psafe]
        mb = pp.parent_mb[jsafe]
        tt = mb * pp.inv_bw[psrv, srv]            # (max_in,)
        max_trans = jnp.max(jnp.where(pmask, tt, 0.0), initial=0.0)
        parent_gate = jnp.max(jnp.where(pmask, end[psafe] + tt, 0.0),
                              initial=0.0)
        trans_cost = trans_cost + jnp.sum(
            jnp.where(pmask, pp.tran_cost[psrv, srv] * mb, 0.0))
        link_bad = link_bad | jnp.any(
            pmask & ~pp.link_ok[psrv, srv] & (psrv != srv))
        if faithful:
            start = lease[srv] + max_trans
        else:
            start = jnp.maximum(lease[srv], parent_gate)
        t_end = start + exe
        end = end.at[jsafe].set(jnp.where(valid, t_end, end[jsafe]))
        t_on = t_on.at[srv].min(jnp.where(valid, start, jnp.inf))
        used = used.at[srv].set(used[srv] | valid)
        kids = pp.child_idx[jsafe]
        kmask = (kids >= 0) & valid
        ksafe = jnp.where(kmask, kids, 0)
        out_t = jnp.sum(jnp.where(kmask,
                                  pp.child_mb[jsafe] * pp.inv_bw[srv, x[ksafe]],
                                  0.0))
        link_bad = link_bad | jnp.any(
            kmask & ~pp.link_ok[srv, x[ksafe]] & (x[ksafe] != srv))
        if faithful:
            new_lease = lease[srv] + exe + out_t
        else:
            new_lease = t_end + out_t
        lease = lease.at[srv].set(jnp.where(valid, new_lease, lease[srv]))
        return (lease, t_on, used, end, trans_cost, link_bad), None

    init = (jnp.zeros(max_S), jnp.full(max_S, jnp.inf),
            jnp.zeros(max_S, bool), jnp.zeros(max_p),
            jnp.asarray(0.0), jnp.asarray(False))
    (lease, t_on, used, end, trans_cost, link_bad), _ = jax.lax.scan(
        step, init, pp.order)

    # Empty (padded) apps reduce to -inf under segment_max; clamp to 0 —
    # real completions are >= 0, so this changes nothing for real apps.
    app_completion = jnp.maximum(
        jax.ops.segment_max(end, pp.app_id, num_segments=max_apps), 0.0)
    t_on_safe = jnp.where(jnp.isinf(t_on), 0.0, t_on)
    comp_cost = jnp.sum(jnp.where(used,
                                  pp.cost_per_sec * (lease - t_on_safe), 0.0))
    pin_ok = jnp.all((pp.pinned < 0) | (x == pp.pinned))
    feasible = (jnp.all(app_completion <= pp.deadline) & pin_ok & ~link_bad)
    total = comp_cost + trans_cost
    return SimResult(end_times=end, app_completion=app_completion,
                     comp_cost=comp_cost, trans_cost=trans_cost,
                     total_cost=total, feasible=feasible,
                     makespan=jnp.max(end, initial=0.0))


def build_simulator(prob: SimProblem, faithful: bool = True):
    """Returns a jit-able ``sim(x) -> SimResult`` closed over static arrays.

    ``x``: (p,) int32 server assignment. vmap over a swarm:
    ``jax.vmap(sim)(X)`` with X (P, p). This is the zero-padding case of
    ``simulate_padded``.
    """
    pp = pad_problem(prob)
    return partial(simulate_padded, pp, faithful=faithful)
