"""int8 error-feedback gradient compression (1-bit-Adam-style residual).

For bandwidth-bound data-parallel training the gradient all-reduce can be
compressed ~4x (bf16 -> int8) if the quantization error is fed back into
the next step's gradient instead of being dropped (error feedback keeps
SGD/Adam convergence — Seide et al. 2014, Karimireddy et al. 2019).

Per-tensor symmetric quantization: scale = max|g| / 127. The residual
buffer lives alongside the optimizer state (same pspecs as the grads).

Plugging point: inside the microbatch-accumulation loop the *local* grad
contribution is compressed before entering the running sum that GSPMD
reduces across data ranks; the wire format is int8 + one fp32 scale per
tensor. The dry-run's collective-bytes term drops accordingly (§Perf logs
the measured delta).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

Params = Any

__all__ = ["CompressionState", "compress_error_feedback", "quantize_int8",
           "dequantize_int8"]


class CompressionState(NamedTuple):
    error: Params       # fp32 residual per parameter


def quantize_int8(g: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    g32 = g.astype(jnp.float32)
    scale = jnp.max(jnp.abs(g32)) / 127.0
    scale = jnp.maximum(scale, 1e-30)
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def init_compression(params: Params) -> CompressionState:
    return CompressionState(error=jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params))


def compress_error_feedback(grads: Params, state: CompressionState
                            ) -> Tuple[Params, CompressionState]:
    """Returns (decompressed grads as they appear after the wire,
    new residual state). Identity in expectation; residual carries the
    per-step quantization error forward."""
    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        q, scale = quantize_int8(corrected)
        deq = dequantize_int8(q, scale)
        return deq.astype(g.dtype), corrected - deq

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(state.error)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    new_g = treedef.unflatten([o[0] for o in outs])
    new_e = treedef.unflatten([o[1] for o in outs])
    return new_g, CompressionState(error=new_e)
