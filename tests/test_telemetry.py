"""Unified telemetry layer (repro.core.telemetry, DESIGN.md §13): the
metric primitives, the registry's thread-safety and exporters, the span
tracer's Chrome trace-event schema, and the integration invariants the
layer rests on — telemetry OFF is bit-identical to the seed behavior,
telemetry ON agrees with every legacy counter surface
(``ServiceReport.counters`` / ``PlanCache.stats()`` /
``runner_cache_stats()``), and a chaos run exports a trace that the CI
validator (scripts/check_trace.py) accepts."""
import importlib.util
import json
import os
import threading
import time

import numpy as np
import pytest

from repro.core import (ChaosConfig, PSOGAConfig, PlanCacheConfig,
                        ReplanConfig, ServiceConfig, Telemetry,
                        get_telemetry, maybe_span, run_service,
                        run_services, sample_environment, sample_trace,
                        set_telemetry, telemetry_scope,
                        zero_drift_trace)
from repro.core.dag import LayerDAG
from repro.core.telemetry import (Counter, Gauge, Histogram,
                                  MetricsRegistry, Series, SpanTracer)

#: distinct from every other test config so this file's first solve is a
#: fresh runner-cache entry
FAST = PSOGAConfig(pop_size=19, max_iters=40, stall_iters=15)
RCFG = ReplanConfig(pso=FAST)


def _tiny_dag(env, pin):
    return LayerDAG(
        compute=np.array([1.1, 1.92, 2.35, 2.12]) * env.power[0],
        edges=np.array([[0, 1], [0, 2], [1, 3], [2, 3]]),
        edge_mb=np.array([1.0, 1.0, 0.5, 0.5]),
        app_id=np.zeros(4, np.int32), deadline=np.array([3.7]),
        pinned=np.array([pin, -1, -1, -1], np.int32))


@pytest.fixture(scope="module")
def tiny_fleet():
    env = sample_environment()
    return env, [_tiny_dag(env, 0), _tiny_dag(env, 1)]


def _check_trace_module():
    """Import scripts/check_trace.py — the schema tests exercise the CI
    gate itself instead of a parallel reimplementation."""
    path = os.path.join(os.path.dirname(__file__), os.pardir,
                        "scripts", "check_trace.py")
    spec = importlib.util.spec_from_file_location("check_trace", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


class FakeClock:
    """Deterministic clock: advances by ``step`` on every call."""

    def __init__(self, step: float = 0.001):
        self.t = 0.0
        self.step = step

    def __call__(self) -> float:
        self.t += self.step
        return self.t


# ---------------------------------------------------------------------------
# metric primitives
# ---------------------------------------------------------------------------

def test_counter_is_monotonic():
    c = Counter("x")
    c.inc()
    c.inc(4)
    assert c.value == 5
    with pytest.raises(ValueError, match="cannot decrease"):
        c.inc(-1)
    assert c.value == 5


def test_gauge_last_value_wins():
    g = Gauge("x")
    g.set(3.5)
    g.set(-1.0)
    assert g.value == -1.0


def test_histogram_exact_moments_and_quantiles():
    h = Histogram("lat")
    for v in range(1, 101):
        h.observe(float(v))
    s = h.summary()
    assert s["count"] == 100 and s["sum"] == pytest.approx(5050.0)
    assert s["min"] == 1.0 and s["max"] == 100.0
    # reservoir holds everything below capacity: quantiles are exact
    assert s["p50"] == pytest.approx(50.5)
    assert s["p95"] == pytest.approx(np.percentile(np.arange(1, 101), 95))
    assert h.quantile(0) == 1.0 and h.quantile(100) == 100.0


def test_histogram_reservoir_is_bounded_and_deterministic():
    h1, h2 = Histogram("b", reservoir=64), Histogram("b", reservoir=64)
    for v in range(10_000):
        h1.observe(float(v))
        h2.observe(float(v))
    assert len(h1._res) == 64                 # bounded under pressure
    assert h1.count == 10_000                 # exact count survives
    assert h1.summary()["sum"] == pytest.approx(sum(range(10_000)))
    # per-name seeded sampling: identical runs sample identically
    assert h1.summary() == h2.summary()
    with pytest.raises(ValueError, match="reservoir"):
        Histogram("bad", reservoir=0)


def test_series_bounds_and_extend():
    s = Series("gbest", max_points=8)
    s.extend(100.0, np.arange(12.0))
    assert s.summary() == {"n": 8, "dropped": 4, "last": 11.0}
    ts = [t for t, _ in s.points()]
    assert ts == sorted(ts)                   # sub-ticks keep order


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_registry_get_or_create_and_kind_conflict():
    r = MetricsRegistry()
    assert r.counter("a") is r.counter("a")
    with pytest.raises(TypeError, match="Counter"):
        r.gauge("a")


def test_registry_thread_safety():
    r = MetricsRegistry()
    n_threads, n_ops = 8, 1000

    def work():
        for i in range(n_ops):
            r.inc("c")
            r.observe("h", float(i))
            r.set_gauge("g", float(i))

    threads = [threading.Thread(target=work) for _ in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert r.counter("c").value == n_threads * n_ops
    assert r.histogram("h").count == n_threads * n_ops


def test_registry_exports_parse():
    r = MetricsRegistry()
    r.inc("svc.rounds", 3)
    r.set_gauge("svc.depth", 2.0)
    r.observe("svc.wall", 0.25)
    r.record_series("svc.gbest", [3.0, 2.0, 1.0])
    for line in r.to_jsonl().splitlines():
        rec = json.loads(line)
        assert {"type", "name"} <= set(rec)
    prom = r.to_prometheus()
    assert "svc_rounds_total 3" in prom
    assert 'svc_wall{quantile="0.5"}' in prom
    assert "svc_gbest_last 1.0" in prom
    snap = r.snapshot()
    assert snap["counters"]["svc.rounds"] == 3
    assert snap["series"]["svc.gbest"]["n"] == 3


def test_registry_write_files(tmp_path):
    r = MetricsRegistry()
    r.inc("a")
    paths = r.write(str(tmp_path / "m"))
    assert json.loads(open(paths["jsonl"]).read())["name"] == "a"
    assert "# TYPE" in open(paths["prom"]).read()


# ---------------------------------------------------------------------------
# span tracer
# ---------------------------------------------------------------------------

def test_tracer_emits_paired_nested_spans():
    clk = FakeClock()
    tr = SpanTracer(clock=clk)
    with tr.span("outer", round=1):
        with tr.span("inner"):
            tr.instant("hit", key="k")
    evs = tr.events()
    assert [e["ph"] for e in evs] == ["B", "B", "i", "E", "E"]
    assert [e["name"] for e in evs] == ["outer", "inner", "hit",
                                       "inner", "outer"]
    for e in evs:
        assert {"ph", "ts", "pid", "tid", "name"} <= set(e)
    ts = [e["ts"] for e in evs]
    assert ts == sorted(ts) and ts[0] >= 0.0
    assert evs[0]["args"] == {"round": 1}
    assert evs[2]["s"] == "t"


def test_tracer_span_closes_on_exception():
    tr = SpanTracer(clock=FakeClock())
    with pytest.raises(RuntimeError):
        with tr.span("risky"):
            raise RuntimeError("boom")
    assert [e["ph"] for e in tr.events()] == ["B", "E"]


def test_tracer_tracks_are_thread_local():
    tr = SpanTracer(clock=time.perf_counter)
    tr.set_track(7, label="service-7")

    def other():
        tr.set_track(9)
        with tr.span("theirs"):
            pass

    t = threading.Thread(target=other)
    t.start()
    t.join()
    with tr.span("mine"):
        pass
    by_name = {e["name"]: e for e in tr.events() if e["ph"] == "B"}
    assert by_name["theirs"]["tid"] == 9
    assert by_name["mine"]["tid"] == 7
    meta = [e for e in tr.events() if e["ph"] == "M"]
    assert meta[0]["args"]["name"] == "service-7"
    assert meta[0]["tid"] == 7


def test_tracer_export_is_chrome_trace(tmp_path):
    tr = SpanTracer(clock=FakeClock())
    with tr.span("round"):
        pass
    path = str(tmp_path / "t.json")
    tr.export(path)
    doc = json.load(open(path))
    assert doc["displayTimeUnit"] == "ms"
    assert len(doc["traceEvents"]) == 2


def test_maybe_span_off_is_nullcontext():
    with maybe_span(None, "anything", round=3):
        pass  # no telemetry: must be free and silent
    tel = Telemetry(clock=FakeClock())
    with maybe_span(tel, "real"):
        pass
    assert len(tel.tracer.events()) == 2


def test_global_telemetry_scope():
    assert get_telemetry() is None
    tel = Telemetry(clock=FakeClock())
    with telemetry_scope(tel):
        assert get_telemetry() is tel
        with telemetry_scope(None):
            assert get_telemetry() is None
        assert get_telemetry() is tel
    assert get_telemetry() is None


# ---------------------------------------------------------------------------
# service integration: parity, agreement, determinism, schema
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def chaos_pair(tiny_fleet):
    """One 10-round chaos service run with telemetry, one without —
    shared by the parity / agreement / schema tests."""
    env, dags = tiny_fleet
    trace = sample_trace("wifi-fade", env, rounds=10, seed=3)
    cfg = ServiceConfig(
        replan=RCFG, plan_cache=PlanCacheConfig(),
        # the straggler detector flags on MEASURED walls, which a loaded
        # host can skew differently across the two paired runs — keep it
        # in warmup so every counter compared below is deterministic
        straggler_warmup=100,
        chaos=ChaosConfig(crash_rounds=(2,), nan_env_rounds=(4,),
                          mid_round_down={6: 1}))
    tel = Telemetry()
    with_tel = run_service(dags, trace, cfg, seed=7, telemetry=tel)
    without = run_service(dags, trace, cfg, seed=7)
    return tel, with_tel, without


def test_service_telemetry_off_parity(chaos_pair):
    """The off-parity invariant: telemetry observes, never steers."""
    _, a, b = chaos_pair
    assert a.counters == b.counters
    assert a.fallback_counts == b.fallback_counts
    assert a.cache_stats == b.cache_stats
    for ra, rb in zip(a.rounds, b.rounds):
        assert ra.rung == rb.rung and ra.label == rb.label
        assert ra.breaker_state == rb.breaker_state
        assert ra.solver_failed == rb.solver_failed
        assert ra.stale_env == rb.stale_env
        assert ra.cache_hit == rb.cache_hit
    for pa, pb in zip(a.plans, b.plans):
        assert np.array_equal(pa, pb)


def test_service_counters_agree_with_registry(chaos_pair):
    """ONE pipeline: the registry snapshot and the legacy dict surfaces
    must tell the same story."""
    tel, rep, _ = chaos_pair
    snap = tel.registry.snapshot()
    for name, v in rep.counters.items():
        assert snap["counters"].get(f"service.{name}", 0) == v, name
    for rung, v in rep.fallback_counts.items():
        assert snap["counters"].get(f"service.rung.{rung}", 0) == v, rung
    for name, v in rep.cache_stats.items():
        assert snap["counters"].get(f"plancache.{name}", 0) == v, name


def test_service_trace_passes_ci_validator(chaos_pair, tmp_path):
    """Satellite: every span of a 10-round chaos run validates against
    the Chrome trace-event schema — via the actual CI gate."""
    tel, _, _ = chaos_pair
    path = str(tmp_path / "chaos_trace.json")
    tel.export_trace(path)
    tel.export_metrics(str(tmp_path / "m"))
    ct = _check_trace_module()
    n = ct.check_trace(path, require=["round", "solve", "cache_lookup",
                                      "ladder", "replan_round",
                                      "fleet_solve", "cold_solve"])
    assert n > 0
    ct.check_metrics(str(tmp_path / "m"))


def test_service_ingest_counters_always_present(chaos_pair):
    """Satellite regression: the ingest_* keys are part of the stable
    counter schema even with ingestion unconfigured."""
    _, rep, without = chaos_pair
    for r in (rep, without):
        for k in ("ingest_enqueued", "ingest_dropped",
                  "ingest_drained", "ingest_leftover"):
            assert k in r.counters and r.counters[k] == 0


def test_service_walls_use_injectable_clock(tiny_fleet):
    """Satellite: with a fake telemetry clock every wall measurement is
    a deterministic multiple of the tick — and replays identically."""
    env, dags = tiny_fleet
    trace = zero_drift_trace(env, rounds=3)
    cfg = ServiceConfig(replan=RCFG)

    def run():
        tel = Telemetry(clock=FakeClock(step=0.001))
        rep = run_service(dags, trace, cfg, seed=7, telemetry=tel)
        return [r.wall_s for r in rep.rounds]

    walls_a, walls_b = run(), run()
    assert walls_a == walls_b                     # replayable timings
    for w in walls_a:
        assert w > 0.0
        assert round(w / 0.001) == pytest.approx(w / 0.001)


def test_run_services_shared_telemetry_tracks(tiny_fleet):
    """Thread-safety under run_services: two concurrent services share
    one telemetry and land on their own Perfetto tracks."""
    env, dags = tiny_fleet
    trace = zero_drift_trace(env, rounds=2)
    cfg = ServiceConfig(replan=RCFG)
    tel = Telemetry()
    reports = run_services([dags, dags], trace, cfg, seeds=5,
                           telemetry=tel)
    solo = run_service(dags, trace, cfg, seed=5)
    for rep in reports:
        assert rep.counters == solo.counters
        for x, x_solo in zip(rep.plans, solo.plans):
            assert np.array_equal(x, x_solo)
    evs = tel.tracer.events()
    labels = {e["tid"]: e["args"]["name"] for e in evs
              if e["ph"] == "M"}
    assert labels == {0: "service-0", 1: "service-1"}
    span_tids = {e["tid"] for e in evs if e["ph"] == "B"}
    assert span_tids == {0, 1}
    # per-track B/E pairing survives the interleaving
    for tid in (0, 1):
        stack = []
        for e in evs:
            if e["tid"] != tid:
                continue
            if e["ph"] == "B":
                stack.append(e["name"])
            elif e["ph"] == "E":
                assert stack and stack.pop() == e["name"]
        assert stack == []
    # both services' rounds aggregate into one registry
    snap = tel.registry.snapshot()
    assert snap["histograms"]["service.round_wall_s"]["count"] == \
        2 * len(solo.rounds)


def test_telemetry_overhead_is_small(tiny_fleet):
    """Telemetry ON must not meaningfully slow the service. The bench
    (benchmarks/bench_service.py) stamps the precise number; here we
    only guard against a pathological regression."""
    env, dags = tiny_fleet
    trace = zero_drift_trace(env, rounds=3)
    cfg = ServiceConfig(replan=RCFG)
    run_service(dags, trace, cfg, seed=9)         # warm the jit caches
    t0 = time.perf_counter()
    run_service(dags, trace, cfg, seed=9)
    base = time.perf_counter() - t0
    t0 = time.perf_counter()
    run_service(dags, trace, cfg, seed=9, telemetry=Telemetry())
    instrumented = time.perf_counter() - t0
    assert instrumented < base * 1.25 + 0.05


def test_solver_history_becomes_series(tiny_fleet):
    """record_history publishes the gBest convergence curve as the
    ``solver.gbest`` metric series."""
    from repro.core import run_pso_ga
    env, dags = tiny_fleet
    cfg = PSOGAConfig(pop_size=8, max_iters=12, stall_iters=12)
    tel = Telemetry()
    res = run_pso_ga(dags[0], env, cfg, seed=1, record_history=True,
                     telemetry=tel)
    pts = tel.registry.series("solver.gbest").points()
    assert [v for _, v in pts] == [float(v) for v in res.history]
    assert tel.registry.counter("solver.history_runs").value == 1


def test_global_channel_reaches_deep_layers(tiny_fleet):
    """The runner cache and solver history have no config path: the
    process-global channel is how they join the session's telemetry."""
    env, dags = tiny_fleet
    trace = zero_drift_trace(env, rounds=2)
    tel = Telemetry()
    with telemetry_scope(tel):
        run_service(dags, trace, ServiceConfig(replan=RCFG), seed=3)
    snap = tel.registry.snapshot()
    lookups = (snap["counters"].get("runner_cache.lookup_hits", 0)
               + snap["counters"].get("runner_cache.lookup_misses", 0))
    assert lookups > 0
    assert "service.round_wall_s" in snap["histograms"]
    assert set_telemetry(None) is None            # scope restored
