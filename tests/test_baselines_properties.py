"""Property coverage for ``baselines.greedy_offload`` / ``heft_makespan``
(ISSUE 4): emitted placements are always *feasible by construction* —
pinning honored, link reachability respected — and every reported cost
matches a gene-for-gene simulator replay.

Runs twice: a seeded sweep that always executes (hypothesis is optional
in this environment, tests/hypo_compat.py), plus ``@given`` property
tests over the same checkers when hypothesis is installed.
"""
import numpy as np
import pytest

from repro.core import (SimProblem, greedy_offload, heft_makespan,
                        paper_environment, sample_environment,
                        simulate_np)
from repro.core.dag import LayerDAG

from hypo_compat import given, st


def random_case(seed: int):
    """A random multi-parent DAG + env + HEFT-derived deadline."""
    rng = np.random.default_rng(seed)
    env = paper_environment() if seed % 2 else sample_environment()
    p = int(rng.integers(4, 13))
    edges, mbs = [], []
    for j in range(1, p):
        parents = rng.choice(j, size=min(j, int(rng.integers(1, 3))),
                             replace=False)
        for u in parents:
            edges.append((int(u), j))
            mbs.append(float(rng.uniform(0.01, 2.0)))
    pinned = np.full(p, -1, np.int32)
    devices = np.nonzero(env.tier == 2)[0]
    pinned[0] = int(rng.choice(devices))
    dag = LayerDAG(compute=rng.uniform(0.05, 3.0, p),
                   edges=np.asarray(edges, np.int32).reshape(-1, 2),
                   edge_mb=np.asarray(mbs),
                   app_id=np.zeros(p, np.int32),
                   deadline=np.asarray([np.inf]),
                   pinned=pinned)
    h, _ = heft_makespan(dag, env)
    ratio = float(rng.choice([1.2, 1.5, 3.0, 8.0, np.inf]))
    dl = ratio * h if np.isfinite(ratio) else np.inf
    return dag.with_deadline(np.asarray([dl])), env


def check_greedy(dag: LayerDAG, env, faithful: bool) -> None:
    prob = SimProblem.build(dag, env)
    res = greedy_offload(dag, env, faithful=faithful)
    p, s = dag.num_layers, env.num_servers
    # well-formed placement
    assert res.best_x.shape == (p,)
    assert np.all((res.best_x >= 0) & (res.best_x < s))
    # pinning respected even when the schedule is infeasible
    pin = dag.pinned >= 0
    assert np.all(res.best_x[pin] == dag.pinned[pin])
    # reported numbers == gene-for-gene simulator replay
    replay = simulate_np(prob, res.best_x, faithful=faithful)
    if res.feasible:
        assert bool(replay.feasible)
        np.testing.assert_allclose(res.best_cost,
                                   float(replay.total_cost), rtol=1e-9)
        np.testing.assert_allclose(res.best_fitness,
                                   float(replay.total_cost), rtol=1e-9)
        # link reachability: every used edge crosses a real link
        for (u, v) in dag.edges:
            a, b = res.best_x[int(u)], res.best_x[int(v)]
            assert a == b or prob.link_ok[a, b]
    else:
        assert res.best_cost == float("inf")


def check_heft(dag: LayerDAG, env) -> None:
    prob = SimProblem.build(dag, env)
    makespan, x = heft_makespan(dag, env)
    p, s = dag.num_layers, env.num_servers
    assert x.shape == (p,)
    assert np.all((x >= 0) & (x < s))
    pin = dag.pinned >= 0
    assert np.all(x[pin] == dag.pinned[pin])
    assert makespan > 0.0
    if np.isfinite(makespan):
        # HEFT placements are link-feasible: with the deadline relaxed,
        # a gene-for-gene replay violates nothing
        relaxed = SimProblem.build(
            dag.with_deadline(np.asarray([np.inf])), env)
        replay = simulate_np(relaxed, x, faithful=False)
        assert bool(replay.feasible)


# --------------------------------------------------------------------------
# seeded sweep — always runs, hypothesis or not
# --------------------------------------------------------------------------

@pytest.mark.parametrize("faithful", [False, True])
def test_greedy_properties_seeded_sweep(faithful):
    for seed in range(24):
        dag, env = random_case(seed)
        check_greedy(dag, env, faithful)


def test_heft_properties_seeded_sweep():
    for seed in range(24):
        dag, env = random_case(seed)
        check_heft(dag, env)


def test_greedy_zoo_nets_replay_consistent():
    """The four paper DNNs: the greedy properties hold at tight AND
    loose deadlines, and with the deadline effectively removed greedy
    recovers the all-home zero-cost plan (everything on the free pinned
    device — nothing cheaper exists)."""
    from repro.core import zoo
    env = paper_environment()
    for net in zoo.NAMES:
        base = zoo.build(net, pin_server=0)
        h, _ = heft_makespan(base, env)
        for ratio in (1.5, 5.0):
            check_greedy(base.with_deadline(np.asarray([ratio * h])),
                         env, faithful=False)
        loose = base.with_deadline(np.asarray([1e9]))
        check_greedy(loose, env, faithful=False)
        res = greedy_offload(loose, env)
        assert res.feasible
        assert res.best_cost == 0.0
        assert np.all(res.best_x == 0)


# --------------------------------------------------------------------------
# hypothesis properties — run when hypothesis is installed
# --------------------------------------------------------------------------

@given(seed=st.integers(0, 2**31 - 1), faithful=st.booleans())
def test_greedy_properties_hypothesis(seed, faithful):
    dag, env = random_case(seed)
    check_greedy(dag, env, faithful)


@given(seed=st.integers(0, 2**31 - 1))
def test_heft_properties_hypothesis(seed):
    dag, env = random_case(seed)
    check_heft(dag, env)
