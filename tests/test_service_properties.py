"""Property coverage for rescue under COMPOUND drift (DESIGN.md §11):
a round that loses a node AND surges the request load at once. The
invariants, over random fleets, both fitness backends:

  * feasible-by-construction — surviving plans are well-formed, honor
    pins, and (when the log says feasible) pass the full stale-plan
    guard ``plan_is_valid`` under the drifted environment, downed links
    included;
  * replay-exact — the logged per-problem cost is reproduced by an
    independent ``incumbent_keys`` replay of the final plans under the
    same environment and arrival draws (infeasible rounds key at or
    above ``INFEASIBLE_OFFSET``).

Runs as a seeded sweep that always executes plus ``@given`` property
tests when hypothesis is installed (tests/hypo_compat.py).
"""
import dataclasses

import numpy as np
import pytest

from repro.core import (DriftEvent, EnvTrace, INFEASIBLE_OFFSET,
                        PSOGAConfig, ReplanConfig, SimProblem,
                        TrafficConfig, heft_makespan, paper_environment,
                        plan_is_valid, replan_fleet, simulate_np)
from repro.core.dag import LayerDAG
from repro.core.online import _identity_event, _round_arrivals, incumbent_keys

from hypo_compat import given, st

TINY = PSOGAConfig(pop_size=12, max_iters=24, stall_iters=10)
TP = TrafficConfig(rate=0.5, max_requests=3, mc_solver=1, mc_eval=2)


def compound_case(seed: int):
    """Two random pinned DAGs + a 2-round trace whose drift round churns
    out a (non-pinned) server AND surges the arrival rate."""
    rng = np.random.default_rng(seed)
    env = paper_environment()
    s = env.num_servers
    dags = []
    for _ in range(2):
        p = int(rng.integers(4, 9))
        edges, mbs = [], []
        for j in range(1, p):
            parents = rng.choice(j, size=min(j, int(rng.integers(1, 3))),
                                 replace=False)
            for u in parents:
                edges.append((int(u), j))
                mbs.append(float(rng.uniform(0.01, 1.0)))
        pinned = np.full(p, -1, np.int32)
        devices = np.nonzero(np.asarray(env.tier) == 2)[0]
        pinned[0] = int(rng.choice(devices))
        dag = LayerDAG(compute=rng.uniform(0.05, 2.0, p),
                       edges=np.asarray(edges, np.int32).reshape(-1, 2),
                       edge_mb=np.asarray(mbs),
                       app_id=np.zeros(p, np.int32),
                       deadline=np.asarray([np.inf]),
                       pinned=pinned)
        h, _ = heft_makespan(dag, env)
        dl = float(rng.choice([1.5, 3.0, 8.0])) * h
        dags.append(dag.with_deadline(np.asarray([dl])))
    pinned_servers = {int(d.pinned[0]) for d in dags}
    down = np.zeros(s, bool)
    down[int(rng.choice([i for i in range(s)
                         if i not in pinned_servers]))] = True
    surge = float(rng.uniform(1.5, 3.0))
    ev = DriftEvent(t=60.0, label=f"compound[{surge:.2f}]",
                    bw_scale=np.ones((s, s)), power_scale=np.ones(s),
                    price_scale=np.ones(s), down=down, load_scale=surge)
    trace = EnvTrace(base=env, events=(_identity_event(s, 0.0, "base"), ev))
    return dags, trace


def check_compound_rescue(seed: int, backend: str,
                          traffic: bool = True) -> None:
    dags, trace = compound_case(seed)
    pso = dataclasses.replace(TINY, fitness_backend=backend)
    cfg = ReplanConfig(pso=pso, traffic=TP if traffic else None)
    rep = replan_fleet(dags, trace, cfg, seed=seed)
    log = rep.rounds[0]
    probs = [SimProblem.build(d, trace.env_at(1)) for d in dags]
    arr = _round_arrivals(cfg, dags, trace.events[1], seed + 1000)

    for i, (pr, x) in enumerate(zip(probs, rep.plans)):
        # feasible-by-construction: well-formed, pins honored, and when
        # the round claims feasibility the plan survives the full guard
        # (every edge on a live link) under the POST-churn environment.
        x = np.asarray(x)
        assert x.shape == (pr.num_layers,)
        assert np.all((x >= 0) & (x < pr.num_servers))
        pin = np.asarray(pr.pinned) >= 0
        assert np.all(x[pin] == np.asarray(pr.pinned)[pin])
        if log.feasible[i]:
            assert plan_is_valid(pr, x)

    # replay-exact: an independent key replay of the surviving plans
    # reproduces the logged costs (same env, same arrival draws).
    keys = incumbent_keys(probs, list(rep.plans), pso, arrivals=arr)
    for i in range(len(dags)):
        if log.feasible[i]:
            assert keys[i] == pytest.approx(float(log.cost[i]), rel=1e-5)
        else:
            assert not np.isfinite(log.cost[i])
            assert keys[i] >= INFEASIBLE_OFFSET


# --------------------------------------------------------------------------
# seeded sweep — always runs, hypothesis or not
# --------------------------------------------------------------------------

def test_compound_rescue_scan_sweep():
    for seed in range(8):
        check_compound_rescue(seed, "scan")


def test_compound_rescue_scan_no_traffic_sweep():
    """Node churn alone (no request stream): the logged cost must equal
    a plain simulator replay of the surviving plan."""
    for seed in range(6):
        dags, trace = compound_case(seed)
        cfg = ReplanConfig(pso=TINY)
        rep = replan_fleet(dags, trace, cfg, seed=seed)
        log = rep.rounds[0]
        probs = [SimProblem.build(d, trace.env_at(1)) for d in dags]
        for i, (pr, x) in enumerate(zip(probs, rep.plans)):
            if log.feasible[i]:
                assert plan_is_valid(pr, x)
                replay = simulate_np(pr, np.asarray(x, np.int64),
                                     faithful=TINY.faithful_sim)
                assert float(log.cost[i]) == \
                    pytest.approx(float(replay.total_cost), rel=1e-6)
            else:
                assert not np.isfinite(log.cost[i])


@pytest.mark.slow
def test_compound_rescue_pallas_sweep():
    for seed in range(2):
        check_compound_rescue(seed, "pallas")


# --------------------------------------------------------------------------
# hypothesis properties — run when hypothesis is installed
# --------------------------------------------------------------------------

@given(seed=st.integers(0, 2**31 - 1))
def test_compound_rescue_hypothesis(seed):
    check_compound_rescue(seed, "scan")
