"""Multi-bucket fleet packing + mesh-sharded solving (DESIGN.md §12):
bucket grouping and order restoration, gene-for-gene parity of bucketed
and sharded solves against the single-shape/single-device path, runner-
cache discipline per (cfg, shape-bucket), and the mesh satellites.

The mesh parity tests scale with the visible device count: under the CI
variant job (XLA_FLAGS=--xla_force_host_platform_device_count=8) they
run the defining N=64-on-8-devices invariant; on a 1-device host they
still exercise the shard_map path at trivial shard count.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.core import (PSOGAConfig, SimProblem, heft_makespan,
                        pack_fleet, paper_environment, run_pso_ga,
                        run_pso_ga_batch, zoo)
from repro.core.batch import (bucket_size, reset_runner_cache_stats,
                              runner_cache_stats)
from repro.core.online import incumbent_keys
from repro.launch.mesh import (data_axes_of, data_shard_count,
                               make_test_mesh, resolve_mesh)

# distinct configs per test file: fresh fleet-runner cache entries, so
# cache-counter assertions here never collide with other suites
FLEET_CFG = PSOGAConfig(pop_size=24, max_iters=82, stall_iters=25)
MESH_CFG = PSOGAConfig(pop_size=16, max_iters=30, stall_iters=12)


def _mk(net, pin, ratio, env):
    dag = zoo.build(net, pin_server=pin)
    h, _ = heft_makespan(dag, env)
    return (dag.with_deadline(np.array([ratio * h])), env)


@pytest.fixture(scope="module")
def env():
    return paper_environment()


@pytest.fixture(scope="module")
def mixed6(env):
    """Six problems over three shape buckets: alexnet (11 -> 16),
    vgg19 (25 -> 32), googlenet (83 -> 128)."""
    nets = ["alexnet", "vgg19", "alexnet", "googlenet", "vgg19",
            "alexnet"]
    return [_mk(n, i % 10, (1.5, 3.0, 5.0)[i % 3], env)
            for i, n in enumerate(nets)]


@pytest.fixture(scope="module")
def mesh_fleet(env):
    """The mesh-parity fleet: mostly small with vgg19/googlenet tails,
    sized so bucket populations are NOT multiples of the shard count
    (the dummy-padding path must engage on multi-device hosts)."""
    n = 64 if jax.device_count() >= 8 else 8
    problems = []
    for i in range(n):
        net = "googlenet" if i % 16 == 0 else \
            "vgg19" if i % 4 == 1 else "alexnet"
        problems.append(_mk(net, i % 10, (1.5, 3.0)[i % 2], env))
    return problems


@pytest.fixture(scope="module")
def mesh_cold(mesh_fleet):
    """Single-device reference solve of the mesh fleet."""
    return run_pso_ga_batch(mesh_fleet, MESH_CFG, seed=list(
        range(len(mesh_fleet))))


# ---------------------------------------------------------------------------
# PackedFleet: grouping, order restoration, single-bucket fallback
# ---------------------------------------------------------------------------

def test_pack_fleet_groups_by_own_size(mixed6):
    probs = [SimProblem.build(d, e) for d, e in mixed6]
    fleet = pack_fleet(probs)
    keys = {(b.max_p, b.max_S) for b in fleet.buckets}
    assert keys == {(16, 32), (32, 32), (128, 32)}
    # the index permutation partitions the fleet exactly
    all_idx = np.sort(np.concatenate([b.idx for b in fleet.buckets]))
    np.testing.assert_array_equal(all_idx, np.arange(6))
    for b in fleet.buckets:
        assert b.ppb.compute.shape == (len(b.idx), b.max_p)
        assert b.ppb.power.shape == (len(b.idx), b.max_S)
        for i, j in enumerate(b.idx):
            # each member's true sizes ride with it into its bucket
            assert int(b.ppb.num_layers[i]) == probs[j].num_layers
            assert (b.max_p, b.max_S) == (
                bucket_size(probs[j].num_layers),
                bucket_size(probs[j].num_servers, floor=4))


def test_pack_fleet_global_padding_is_one_bucket(mixed6):
    probs = [SimProblem.build(d, e) for d, e in mixed6]
    fleet = pack_fleet(probs, bucket=False)
    assert len(fleet.buckets) == 1
    b = fleet.buckets[0]
    assert (b.max_p, b.max_S) == (max(p.num_layers for p in probs),
                                  probs[0].num_servers)
    np.testing.assert_array_equal(np.sort(b.idx), np.arange(6))


# ---------------------------------------------------------------------------
# gene-for-gene parity: buckets vs sequential, buckets vs global padding
# ---------------------------------------------------------------------------

def test_multi_bucket_matches_sequential(env):
    """Problems split across two buckets still match the sequential
    solver gene-for-gene — the PR 1 bar, now per bucket."""
    fleet = [_mk("alexnet", 0, 3.0, env), _mk("vgg19", 1, 3.0, env),
             _mk("alexnet", 2, 1.5, env), _mk("vgg19", 3, 5.0, env)]
    seq = [run_pso_ga(d, e, FLEET_CFG, seed=i)
           for i, (d, e) in enumerate(fleet)]
    bat = run_pso_ga_batch(fleet, FLEET_CFG, seed=list(range(4)))
    for a, b in zip(seq, bat):
        assert a.best_fitness == b.best_fitness
        np.testing.assert_array_equal(a.best_x, b.best_x)
        assert a.iterations == b.iterations


def test_bucketed_equals_global_padding(mixed6):
    """Bucket shape is invisible in results: per-group power-of-two
    padding and fleet-global padding agree bit-for-bit."""
    a = run_pso_ga_batch(mixed6, FLEET_CFG, seed=7, bucket=True)
    b = run_pso_ga_batch(mixed6, FLEET_CFG, seed=7, bucket=False)
    for ra, rb in zip(a, b):
        assert ra.best_fitness == rb.best_fitness
        assert ra.best_cost == rb.best_cost
        np.testing.assert_array_equal(ra.best_x, rb.best_x)


def test_result_order_bit_stable_under_permutation(mixed6):
    """Solving the same fleet in a random input order returns the same
    per-problem genes — bucket assignment routes by problem identity,
    never by input position."""
    base = run_pso_ga_batch(mixed6, FLEET_CFG, seed=[10 + i
                                                    for i in range(6)])
    rng = np.random.default_rng(3)
    perm = rng.permutation(6)
    shuffled = run_pso_ga_batch([mixed6[p] for p in perm], FLEET_CFG,
                                seed=[10 + int(p) for p in perm])
    for k, p in enumerate(perm):
        assert shuffled[k].best_fitness == base[p].best_fitness
        np.testing.assert_array_equal(shuffled[k].best_x, base[p].best_x)


def test_return_state_restores_order_across_buckets(mixed6):
    """The re-assembled state is fleet-ordered at the largest bucket's
    max_p, with genes beyond each problem's own bucket left zero."""
    res, state = run_pso_ga_batch(mixed6, FLEET_CFG, seed=5,
                                  return_state=True)
    assert state.X.shape == (6, FLEET_CFG.pop_size, 128)
    probs = [SimProblem.build(d, e) for d, e in mixed6]
    for i, (pr, r) in enumerate(zip(probs, res)):
        assert float(state.gbest_f[i]) == r.best_fitness
        np.testing.assert_array_equal(
            np.asarray(state.gbest_x[i])[:pr.num_layers], r.best_x)
        bp = bucket_size(pr.num_layers)
        assert not np.asarray(state.X[i, :, bp:]).any()
        assert not np.asarray(state.gbest_x[i])[pr.num_layers:].any()


# ---------------------------------------------------------------------------
# runner-cache discipline under bucketing
# ---------------------------------------------------------------------------

def test_one_trace_per_cfg_bucket_and_repeat_hits(mixed6):
    """Exactly one miss+trace per distinct (cfg, shape-bucket); a repeat
    fleet is ALL hits with zero new traces."""
    cfg = dataclasses.replace(FLEET_CFG, max_iters=83)   # fresh entries
    reset_runner_cache_stats()
    run_pso_ga_batch(mixed6, cfg, seed=0)
    s1 = runner_cache_stats()
    assert s1["misses"] == 3                     # three shape buckets
    assert s1["traces"] == 3
    assert s1["hits"] == 0
    run_pso_ga_batch(mixed6, cfg, seed=1)
    s2 = runner_cache_stats()
    assert s2["hits"] == 3
    assert s2["misses"] == 3
    assert s2["traces"] == 3


# ---------------------------------------------------------------------------
# warm incumbents and arrivals route with their problem
# ---------------------------------------------------------------------------

def test_warm_incumbents_route_through_buckets(mixed6):
    probs = [SimProblem.build(d, e) for d, e in mixed6]
    cold = run_pso_ga_batch(mixed6, FLEET_CFG, seed=2)
    plans = [r.best_x for r in cold]
    # the incumbent's key re-keys bit-equal through the bucketed
    # evaluator — solver and evaluator pad identically per bucket
    keys = incumbent_keys(probs, plans, FLEET_CFG)
    for r, k in zip(cold, keys):
        assert r.best_fitness == pytest.approx(float(k), rel=0, abs=0)
    # a demoted entry (None incumbent) inside a warm fleet solves cold —
    # bit-identical to the cold fleet — regardless of which bucket the
    # demoted problem lives in (here: the lone googlenet bucket)
    warm_inc = list(plans)
    warm_inc[3] = None
    warm = run_pso_ga_batch(mixed6, FLEET_CFG, seed=2,
                            incumbent=warm_inc, migration_weight=1.0)
    assert warm[3].best_fitness == cold[3].best_fitness
    np.testing.assert_array_equal(warm[3].best_x, cold[3].best_x)


def test_arrivals_route_through_buckets(mixed6):
    rng = np.random.default_rng(11)
    arrivals = [np.sort(rng.uniform(0.0, 10.0, size=(2, 1, 3)), axis=-1)
                for _ in mixed6]
    a = run_pso_ga_batch(mixed6, MESH_CFG, seed=4, arrivals=arrivals,
                         bucket=True)
    b = run_pso_ga_batch(mixed6, MESH_CFG, seed=4, arrivals=arrivals,
                         bucket=False)
    for ra, rb in zip(a, b):
        assert ra.best_fitness == rb.best_fitness
        np.testing.assert_array_equal(ra.best_x, rb.best_x)


# ---------------------------------------------------------------------------
# mesh sharding: gene-for-gene identical to the single-device solve
# ---------------------------------------------------------------------------

def _assert_same_results(a, b):
    assert len(a) == len(b)
    for ra, rb in zip(a, b):
        assert ra.best_fitness == rb.best_fitness
        assert ra.best_cost == rb.best_cost
        assert ra.iterations == rb.iterations
        np.testing.assert_array_equal(ra.best_x, rb.best_x)


def test_mesh_sharded_parity_cold(mesh_fleet, mesh_cold):
    mesh = make_test_mesh()
    sharded = run_pso_ga_batch(mesh_fleet, MESH_CFG,
                               seed=list(range(len(mesh_fleet))),
                               mesh=mesh)
    _assert_same_results(mesh_cold, sharded)


def test_mesh_sharded_parity_warm(mesh_fleet, mesh_cold):
    mesh = make_test_mesh()
    inc = [r.best_x for r in mesh_cold]
    ref = run_pso_ga_batch(mesh_fleet, MESH_CFG, seed=9, incumbent=inc,
                           migration_weight=1.0)
    sharded = run_pso_ga_batch(mesh_fleet, MESH_CFG, seed=9,
                               incumbent=inc, migration_weight=1.0,
                               mesh=mesh)
    _assert_same_results(ref, sharded)


def test_mesh_sharded_parity_traffic(mesh_fleet):
    rng = np.random.default_rng(23)
    arrivals = [np.sort(rng.uniform(0.0, 8.0, size=(2, 1, 3)), axis=-1)
                for _ in mesh_fleet]
    mesh = make_test_mesh()
    ref = run_pso_ga_batch(mesh_fleet, MESH_CFG, seed=6,
                           arrivals=arrivals)
    sharded = run_pso_ga_batch(mesh_fleet, MESH_CFG, seed=6,
                               arrivals=arrivals, mesh=mesh)
    _assert_same_results(ref, sharded)


def test_mesh_pads_non_divisible_buckets(env):
    """N=3 in one bucket: on a multi-shard mesh the runner pads with
    dummy problems; results must be identical to the unsharded solve
    (and to a solo solve of each problem)."""
    fleet = [_mk("alexnet", i, 3.0, env) for i in range(3)]
    mesh = make_test_mesh()
    ref = run_pso_ga_batch(fleet, MESH_CFG, seed=[1, 2, 3])
    sharded = run_pso_ga_batch(fleet, MESH_CFG, seed=[1, 2, 3],
                               mesh=mesh)
    _assert_same_results(ref, sharded)


# ---------------------------------------------------------------------------
# mesh construction satellites
# ---------------------------------------------------------------------------

def test_multipod_test_mesh_min_devices():
    if jax.device_count() < 4:
        with pytest.raises(ValueError, match="at least 4 devices"):
            make_test_mesh(multi_pod=True)
    else:
        m = make_test_mesh(multi_pod=True)
        assert m.axis_names == ("pod", "data", "model")
        assert data_axes_of(m) == ("pod", "data")
        assert data_shard_count(m) == m.devices.size // 2


def test_resolve_mesh():
    assert resolve_mesh(None) is None
    assert resolve_mesh("none") is None
    m = resolve_mesh("host")
    assert isinstance(m, jax.sharding.Mesh)
    assert data_shard_count(m) >= 1
    with pytest.raises(ValueError, match="unknown mesh"):
        resolve_mesh("bogus")


def test_bench_metadata_stamps_devices():
    # tier-1 runs `python -m pytest` from the repo root, so the
    # benchmarks package resolves from the cwd
    from benchmarks.common import bench_metadata
    meta = bench_metadata(seeds=[0])
    assert meta["device_count"] == jax.device_count()
    assert "mesh" not in meta
    m = make_test_mesh()
    meta = bench_metadata(mesh=m)
    assert meta["mesh"]["axes"] == list(m.axis_names)
    assert tuple(meta["mesh"]["shape"]) == m.devices.shape
