"""Plan-cache smoke gate for CI (DESIGN.md §11 phase 2).

Runs the planning service over a repeat-scenario (zero-drift) trace
with the plan cache on and asserts the two invariants the cache story
rests on:

  * hit rate — every round after the first recurs the same scenario,
    so the cache must serve it: hits / lookups >= --threshold;
  * availability — cached rounds still walk the ladder's promotion
    gate, so serving from cache never costs a round: exactly 1.0.

Everything is seeded and single-threaded, so a failure here is a real
regression, not flake. Exits non-zero (via assert) on a miss.
"""
import argparse
import sys

import numpy as np

sys.path.insert(0, "src")

from repro.core import (PlanCacheConfig, PSOGAConfig,  # noqa: E402
                        ReplanConfig, ServiceConfig, run_service,
                        sample_environment, zero_drift_trace)
from repro.core.dag import LayerDAG  # noqa: E402


def tiny_dag(env, pin):
    """The quickstart's 4-layer DAG: small enough that warm PSO keeps
    the optimum from round 1 (the converged-repeat scenario)."""
    return LayerDAG(
        compute=np.array([1.1, 1.92, 2.35, 2.12]) * env.power[0],
        edges=np.array([[0, 1], [0, 2], [1, 3], [2, 3]]),
        edge_mb=np.array([1.0, 1.0, 0.5, 0.5]),
        app_id=np.zeros(4, np.int32), deadline=np.array([3.7]),
        pinned=np.array([pin, -1, -1, -1], np.int32))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=6)
    ap.add_argument("--seed", type=int, default=11)
    ap.add_argument("--threshold", type=float, default=0.6,
                    help="minimum cache hit rate over the run")
    args = ap.parse_args()

    env = sample_environment()
    dags = [tiny_dag(env, 0), tiny_dag(env, 1)]
    trace = zero_drift_trace(env, rounds=args.rounds)
    cfg = ServiceConfig(
        replan=ReplanConfig(pso=PSOGAConfig(pop_size=24, max_iters=60,
                                            stall_iters=20)),
        plan_cache=PlanCacheConfig())
    rep = run_service(dags, trace, cfg, seed=args.seed)

    cs = rep.cache_stats
    n_look = cs["hits"] + cs["misses"]
    hit_rate = cs["hits"] / n_look if n_look else 0.0
    avail = rep.availability()
    cached_rounds = sum(1 for r in rep.rounds if r.cache_hit)
    print(f"[cache-smoke] {len(rep.rounds)} rounds, {cached_rounds} "
          f"served from cache, hit rate {hit_rate:.2f} "
          f"(bar >= {args.threshold}), availability {avail:.4f}, "
          f"stats {cs}")
    assert avail == 1.0, f"availability {avail} != 1.0"
    assert hit_rate >= args.threshold, \
        f"hit rate {hit_rate:.2f} below {args.threshold}"
    assert cs["revalidation_failures"] == 0, \
        "replay-exact gate fired on a zero-drift trace"
    print("[cache-smoke] PASS")


if __name__ == "__main__":
    main()
