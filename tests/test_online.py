"""Online re-planning (repro.core.online, DESIGN.md §9): trace
generators, the migration-cost term, incumbent swarm seeding, the
accept-if-better replan loop — and the two ISSUE-4 acceptance
invariants: a zero-drift replan keeps the cold solve bit-for-bit, and
every round after the first hits the compiled fleet runner (no retrace),
asserted via the ``batch.runner_cache_stats`` counters."""
import numpy as np
import pytest

from repro.core import (DEVICE, EDGE, PSOGAConfig, ReplanConfig,
                        SimProblem, TRACE_KINDS, heft_makespan,
                        migration_cost, paper_environment, replan_fleet,
                        run_pso_ga_batch, runner_cache_stats,
                        sample_environment, sample_trace, simulate_np,
                        zero_drift_trace, zoo)
from repro.core.online import incumbent_keys, migration_cost_np
from repro.core.pso_ga import init_swarm
from repro.core.simulator import pad_problem

#: distinct from every other test config so this file's first solve is a
#: fresh runner-cache entry (the counters below rely on that)
FAST = PSOGAConfig(pop_size=24, max_iters=81, stall_iters=25)


@pytest.fixture(scope="module")
def fleet():
    env = paper_environment()
    dags = []
    for i, net in enumerate(("alexnet", "googlenet", "vgg19")):
        dag = zoo.build(net, pin_server=i)
        h, _ = heft_makespan(dag, env)
        dags.append(dag.with_deadline(np.array([1.5 * h])))
    return env, dags


# ---------------------------------------------------------------------------
# traces
# ---------------------------------------------------------------------------

def test_zero_drift_trace_is_identity():
    env = paper_environment()
    trace = zero_drift_trace(env, rounds=3)
    assert trace.num_rounds == 3
    for k in range(3):
        assert trace.events[k].is_identity()
        e = trace.env_at(k)
        np.testing.assert_array_equal(e.bandwidth, env.bandwidth)
        np.testing.assert_array_equal(e.power, env.power)
        np.testing.assert_array_equal(e.cost_per_sec, env.cost_per_sec)


@pytest.mark.parametrize("kind", TRACE_KINDS)
def test_sample_trace_families(kind):
    """Each family drifts only its own knob, keeps shapes, and round 0 is
    always the base environment."""
    env = paper_environment()
    trace = sample_trace(kind, env, rounds=5, seed=3)
    assert trace.num_rounds == 5
    assert trace.events[0].is_identity()
    s = env.num_servers
    saw_drift = False
    for k in range(1, 5):
        ev = trace.events[k]
        e = trace.env_at(k)
        assert e.num_servers == s                 # churn never resizes
        saw_drift |= not ev.is_identity()
        if kind == "wifi-fade":
            # only device<->edge entries may scale; others untouched
            d = np.asarray(env.tier) == DEVICE
            g = np.asarray(env.tier) == EDGE
            m = d[:, None] & g[None, :] | g[:, None] & d[None, :]
            np.testing.assert_array_equal(e.bandwidth[~m],
                                          env.bandwidth[~m])
            assert np.all(e.bandwidth[m] <= env.bandwidth[m])
            np.testing.assert_array_equal(e.cost_per_sec, env.cost_per_sec)
        elif kind == "spot-price":
            np.testing.assert_array_equal(e.bandwidth, env.bandwidth)
            dev_edge = np.asarray(env.tier) != 0
            np.testing.assert_array_equal(e.cost_per_sec[dev_edge],
                                          env.cost_per_sec[dev_edge])
        elif kind == "node-loss":
            down = ev.down
            assert down.sum() == 1
            assert env.tier[np.nonzero(down)[0][0]] != DEVICE
            off = ~np.eye(s, dtype=bool)
            dead = down[:, None] | down[None, :]
            assert np.all(e.bandwidth[dead & off] == 0.0)
    assert saw_drift


def test_sample_trace_rejects_unknown_kind():
    with pytest.raises(ValueError):
        sample_trace("meteor-strike", paper_environment(), rounds=2)


@pytest.mark.parametrize("kind", TRACE_KINDS)
def test_trace_shapes_never_change_across_rounds(kind):
    """The compiled-runner reuse invariant's precondition (DESIGN.md §9):
    every round's environment AND every event's arrays keep the round-0
    shapes — drift changes values only."""
    env = paper_environment()
    trace = sample_trace(kind, env, rounds=6, seed=11)
    e0 = trace.env_at(0)
    for k in range(trace.num_rounds):
        ev = trace.events[k]
        assert ev.bw_scale.shape == (env.num_servers, env.num_servers)
        assert ev.power_scale.shape == (env.num_servers,)
        assert ev.price_scale.shape == (env.num_servers,)
        assert ev.down.shape == (env.num_servers,)
        e = trace.env_at(k)
        for field in ("power", "cost_per_sec", "tier", "bandwidth",
                      "tran_cost"):
            assert getattr(e, field).shape == getattr(e0, field).shape


@pytest.mark.parametrize("kind", TRACE_KINDS)
def test_identity_events_keep_env_bit_equal(kind):
    """Wherever an event reports is_identity(), env_at(k) must be the
    base environment BIT-equal — a replan round then keeps incumbents
    byte-for-byte (the zero-drift parity invariant's other half)."""
    env = paper_environment()
    trace = sample_trace(kind, env, rounds=5, seed=4)
    for k in range(trace.num_rounds):
        if not trace.events[k].is_identity():
            continue
        e = trace.env_at(k)
        np.testing.assert_array_equal(e.bandwidth, env.bandwidth)
        np.testing.assert_array_equal(e.power, env.power)
        np.testing.assert_array_equal(e.cost_per_sec, env.cost_per_sec)
        np.testing.assert_array_equal(e.tran_cost, env.tran_cost)
    assert trace.events[0].is_identity()       # round 0 always identity


def test_node_loss_never_strands_pinned_home_servers():
    """Node churn may never kill a DEVICE-tier server: pinned input
    layers live there, and severing the pinned server's own links would
    make EVERY placement of that app permanently link-infeasible. Links
    that don't touch the victim must stay bit-equal."""
    env = paper_environment()
    device = np.asarray(env.tier) == DEVICE
    for seed in range(5):
        trace = sample_trace("node-loss", env, rounds=5, seed=seed)
        for k in range(1, trace.num_rounds):
            ev = trace.events[k]
            assert not ev.down[device].any()
            e = trace.env_at(k)
            alive = ~(ev.down[:, None] | ev.down[None, :])
            np.testing.assert_array_equal(e.bandwidth[alive],
                                          env.bandwidth[alive])


def test_load_surge_drifts_workload_not_environment():
    """load-surge epochs scale ONLY the arrival intensity: the
    environment stays bit-equal while load_scale drifts >= 1."""
    env = paper_environment()
    trace = sample_trace("load-surge", env, rounds=5, seed=3)
    saw_surge = False
    for k in range(trace.num_rounds):
        ev = trace.events[k]
        e = trace.env_at(k)
        np.testing.assert_array_equal(e.bandwidth, env.bandwidth)
        np.testing.assert_array_equal(e.power, env.power)
        np.testing.assert_array_equal(e.cost_per_sec, env.cost_per_sec)
        assert ev.load_scale >= 1.0
        saw_surge |= ev.load_scale > 1.0
    assert saw_surge
    assert trace.events[0].load_scale == 1.0


def test_sample_trace_seeded_deterministic():
    env = paper_environment()
    a = sample_trace("congestion", env, rounds=4, seed=9)
    b = sample_trace("congestion", env, rounds=4, seed=9)
    for ea, eb in zip(a.events, b.events):
        np.testing.assert_array_equal(ea.bw_scale, eb.bw_scale)


# ---------------------------------------------------------------------------
# migration cost term
# ---------------------------------------------------------------------------

def test_migration_cost_zero_when_unmoved(rng):
    env = sample_environment()
    dag = zoo.alexnet(pin_server=0, deadline=6.0)
    prob = SimProblem.build(dag, env)
    pp = pad_problem(prob, max_p=16)
    x = rng.integers(0, env.num_servers, size=(3, 16)).astype(np.int32)
    assert np.all(np.asarray(migration_cost(pp, x, x[0]))[0] == 0.0)
    assert migration_cost_np(prob, x[0, :dag.num_layers],
                             x[0, :dag.num_layers]) == 0.0


def test_migration_cost_matches_np_oracle(rng):
    env = sample_environment()
    dag = zoo.alexnet(pin_server=0, deadline=6.0)
    prob = SimProblem.build(dag, env)
    p = dag.num_layers
    pp = pad_problem(prob, max_p=16)
    for _ in range(5):
        old = rng.integers(0, env.num_servers, size=16).astype(np.int32)
        new = rng.integers(0, env.num_servers, size=16).astype(np.int32)
        old[p:] = new[p:] = 0            # padded genes never move
        got = float(np.asarray(migration_cost(pp, new[None, :], old))[0])
        want = migration_cost_np(prob, old[:p], new[:p])
        np.testing.assert_allclose(got, want, rtol=1e-6)


def test_warm_fitness_penalizes_moves():
    """A warm solve with a huge migration weight keeps the incumbent; the
    same solve with weight 0 is free to move (and the cold key is then
    bit-identical to a cold solve)."""
    env = paper_environment()
    dag = zoo.alexnet(pin_server=0)
    h, _ = heft_makespan(dag, env)
    dag = dag.with_deadline(np.array([1.5 * h]))
    cold = run_pso_ga_batch([(dag, env)], FAST, seed=0)[0]
    # a deliberately bad-but-feasible incumbent: the cold optimum with
    # its most expensive offloaded layer forced elsewhere would do, but
    # the home-pinned all-device plan is simplest (infeasible at tight
    # deadlines is fine too: the candidate then always wins).
    inc = np.asarray(cold.best_x, np.int32)
    free = run_pso_ga_batch([(dag, env)], FAST, seed=1,
                            incumbent=[inc], migration_weight=0.0)[0]
    heavy = run_pso_ga_batch([(dag, env)], FAST, seed=1,
                             incumbent=[inc], migration_weight=1e6)[0]
    # weight 0: the warm key reduces to the cold key of its best genes
    prob = SimProblem.build(dag, env)
    replay = simulate_np(prob, free.best_x, faithful=FAST.faithful_sim)
    np.testing.assert_allclose(free.best_fitness,
                               np.float32(replay.total_cost), rtol=1e-6)
    # overwhelming weight: nothing beats staying put
    assert np.array_equal(heavy.best_x, inc)


# ---------------------------------------------------------------------------
# incumbent swarm seeding
# ---------------------------------------------------------------------------

def test_init_swarm_incumbent_mode():
    env = paper_environment()
    dag = zoo.googlenet(pin_server=0, deadline=10.0)
    prob = SimProblem.build(dag, env)
    import jax
    key = jax.random.PRNGKey(0)
    inc = np.full(dag.num_layers, 11, np.int32)
    inc[0] = 0                                   # honor the pin
    X = np.asarray(init_swarm(key, prob, FAST, incumbent=inc))
    n_elite = FAST.warm_elite
    n_neigh = int(round(FAST.warm_fraction * FAST.pop_size))
    # elite clones are exact
    assert np.all(X[:n_elite] == inc[None, :])
    # neighborhood rows differ from the incumbent in only a few genes
    frac = (X[n_elite:n_elite + n_neigh] != inc[None, :]).mean(axis=1)
    assert np.all(frac <= 3 * FAST.warm_mutation + 0.05)
    # the random tail is NOT incumbent-dominated (diversity preserved)
    tail = X[n_elite + n_neigh:]
    assert (tail != inc[None, :]).mean() > 0.3
    # pins hold everywhere
    assert np.all(X[:, 0] == 0)
    # rescue mode: the tail re-gains the cold anchors, single-server
    # placements ordered by descending power (strongest escape first)
    Xr = np.asarray(init_swarm(key, prob, FAST, incumbent=inc,
                               rescue=True))
    t0 = n_elite + n_neigh
    by_power = np.argsort(-env.power, kind="stable")
    assert np.all(Xr[t0][1:] == 0)               # all-home anchor
    assert np.all(Xr[t0 + 1][1:] == by_power[0])
    assert np.all(Xr[t0 + 2][1:] == by_power[1])
    # elites/neighborhood identical in both modes
    np.testing.assert_array_equal(Xr[:t0], X[:t0])
    # cold init is bit-identical to the pre-warm-start behaviour
    a = np.asarray(init_swarm(key, prob, FAST))
    b = np.asarray(init_swarm(key, prob, FAST, incumbent=None))
    np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# the replan loop: zero-drift parity + runner-cache reuse (acceptance)
# ---------------------------------------------------------------------------

def test_zero_drift_replan_bit_exact_and_cache_hit(fleet):
    """ISSUE-4 acceptance: with a zero-drift trace, one replan round
    reproduces the cold solve bit-for-bit (same genes, same fitness) AND
    hits the compiled fleet runner — no jit retrace — per the PR-1 cache
    counters."""
    env, dags = fleet
    cfg = ReplanConfig(pso=FAST)
    trace = zero_drift_trace(env, rounds=2)

    # cold solve first: pays the (at most one) compile for this config
    probs0 = [SimProblem.build(d, trace.env_at(0)) for d in dags]
    cold = run_pso_ga_batch(probs0, cfg.pso, seed=0)
    before = runner_cache_stats()

    report = replan_fleet(dags, trace, cfg, seed=0, initial=cold)
    after = runner_cache_stats()

    # bit-exact: the replan kept every incumbent gene and key
    (log,) = report.rounds
    assert not log.replanned.any()
    for i, r in enumerate(cold):
        np.testing.assert_array_equal(report.plans[i], r.best_x)
        np.testing.assert_allclose(log.incumbent_key[i], r.best_fitness,
                                   rtol=0, atol=0)
    # cache hit, no retrace: the warm round reused the compiled runner
    assert after["traces"] == before["traces"]
    assert after["hits"] > before["hits"]
    assert after["misses"] == before["misses"]


def test_drift_replan_improves_on_stale_plan(fleet):
    """Under real drift the replanner must do at least as well as
    carrying the stale incumbent, and every accepted plan must strictly
    beat its incumbent's key under the drifted environment."""
    env, dags = fleet
    cfg = ReplanConfig(pso=FAST, migration_weight=0.1)
    trace = sample_trace("congestion", env, rounds=4, seed=5,
                         severity=0.8)
    report = replan_fleet(dags, trace, cfg, seed=0)
    assert len(report.rounds) == 3
    for log in report.rounds:
        accepted = np.nonzero(log.replanned)[0]
        assert np.all(log.candidate_key[accepted]
                      < log.incumbent_key[accepted])
        kept = np.nonzero(~log.replanned & log.feasible)[0]
        np.testing.assert_allclose(log.cost[kept],
                                   log.incumbent_key[kept], rtol=1e-6)
        # infeasible kept plans report inf cost (no pretend-$ numbers)
        kept_bad = np.nonzero(~log.replanned & ~log.feasible)[0]
        assert np.all(np.isinf(log.cost[kept_bad]))
    # final plans replay to the reported last-round cost
    last = report.rounds[-1]
    env_last = trace.env_at(trace.num_rounds - 1)
    for i, d in enumerate(dags):
        prob = SimProblem.build(d, env_last)
        r = simulate_np(prob, report.plans[i],
                        faithful=cfg.pso.faithful_sim)
        if last.feasible[i]:
            np.testing.assert_allclose(last.cost[i], float(r.total_cost),
                                       rtol=1e-5)


def test_node_loss_forces_migration_off_dead_server(fleet):
    """Churning out the server an incumbent uses makes the stale plan
    link-infeasible; the replanner must move off it and restore
    feasibility (the node-loss drift family's whole point)."""
    env, dags = fleet
    cfg = ReplanConfig(pso=FAST, migration_weight=0.1)
    # force a cold plan that uses SOME rented server (tight deadline), then
    # kill exactly that server in round 1.
    probs0 = [SimProblem.build(d, env) for d in dags]
    cold = run_pso_ga_batch(probs0, cfg.pso, seed=0)
    used = [s for r in cold for s in np.unique(r.best_x)
            if env.tier[s] != DEVICE]
    if not used:
        pytest.skip("cold plans stayed on devices; nothing to kill")
    victim = int(used[0])
    import dataclasses as dc
    trace = zero_drift_trace(env, rounds=2)
    down = np.zeros(env.num_servers, bool)
    down[victim] = True
    ev = dc.replace(trace.events[1], down=down,
                    label=f"node-loss[s{victim}]")
    trace = dc.replace(trace, events=(trace.events[0], ev))
    report = replan_fleet(dags, trace, cfg, seed=0, initial=cold)
    (log,) = report.rounds
    for i, r in enumerate(cold):
        if victim in r.best_x:
            assert victim not in report.plans[i]
            assert log.replanned[i]
        assert log.feasible[i]


def test_load_surge_replan_reacts_to_workload_drift(fleet):
    """A load-surge trace leaves the environment bit-still, yet the
    traffic-aware replanner still re-plans (or provably keeps a plan
    that already beats every candidate) — workload drift alone drives
    the loop (DESIGN.md §10)."""
    from repro.core import TrafficConfig
    env, dags = fleet
    trace = sample_trace("load-surge", env, rounds=3, seed=0,
                         severity=1.0)
    cfg = ReplanConfig(
        pso=FAST, migration_weight=0.1,
        traffic=TrafficConfig(kind="bursty", rate=0.3, horizon=20.0,
                              max_requests=4, mc_solver=2, mc_eval=4))
    report = replan_fleet(dags, trace, cfg, seed=0)
    assert len(report.rounds) == 2
    for log in report.rounds:
        # accepted candidates strictly beat the incumbent's traffic key
        acc = np.nonzero(log.replanned)[0]
        assert np.all(log.candidate_key[acc] < log.incumbent_key[acc])
        # traffic-feasible plans report finite load-adjusted cost
        assert np.all(np.isfinite(log.cost[log.feasible]))


def test_incumbent_keys_match_replay(fleet):
    env, dags = fleet
    probs = [SimProblem.build(d, env) for d in dags]
    incs = [np.zeros(d.num_layers, np.int32) + d.pinned[0] for d in dags]
    keys = incumbent_keys(probs, incs, FAST)
    for pr, inc, k in zip(probs, incs, keys):
        r = simulate_np(pr, inc, faithful=FAST.faithful_sim)
        if bool(r.feasible):
            np.testing.assert_allclose(k, np.float32(r.total_cost),
                                       rtol=1e-6)


def test_run_pso_ga_batch_incumbent_validation(fleet):
    env, dags = fleet
    probs = [SimProblem.build(d, env) for d in dags]
    with pytest.raises(ValueError):
        run_pso_ga_batch(probs, FAST, incumbent=[np.zeros(3, np.int32)])
    with pytest.raises(ValueError):
        run_pso_ga_batch(
            probs, FAST,
            incumbent=[np.zeros(3, np.int32)] * (len(probs) + 1))
