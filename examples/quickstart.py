"""Quickstart — the paper's Fig. 2 worked example, end to end.

Builds the 4-layer DNN + 6-server hybrid environment of paper §III-B,
runs Greedy and PSO-GA, and shows PSO-GA finding the cheaper feasible
offloading (the paper's core claim in miniature).

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (PSOGAConfig, SimProblem, greedy_offload,
                        run_pso_ga, sample_environment, simulate_np)
from repro.core.dag import LayerDAG


def main() -> None:
    env = sample_environment()
    print("Servers (power, $/h, tier):")
    for i in range(env.num_servers):
        tier = {0: "cloud", 1: "edge", 2: "device"}[int(env.tier[i])]
        print(f"  s{i}: p={env.power[i]:.2f} "
              f"${env.cost_per_sec[i]*3600:.2f}/h {tier}")

    # Fig. 2: l0 pinned to the end device, deadline 3.7 s
    dag = LayerDAG(
        compute=np.array([1.1, 1.92, 2.35, 2.12]) * env.power[0],
        edges=np.array([[0, 1], [0, 2], [1, 3], [2, 3]]),
        edge_mb=np.array([1.0, 1.0, 0.5, 0.5]),
        app_id=np.zeros(4, np.int32),
        deadline=np.array([3.7]),
        pinned=np.array([0, -1, -1, -1], np.int32))

    prob = SimProblem.build(dag, env)
    for name, x in [("paper greedy  (0,1,2,1)", [0, 1, 2, 1]),
                    ("paper optimal (0,1,2,3)", [0, 1, 2, 3])]:
        r = simulate_np(prob, np.array(x), faithful=False)
        print(f"{name}: completes {float(r.makespan):.2f}s, "
              f"cost ${float(r.total_cost):.5f}, "
              f"feasible={bool(r.feasible)}")

    grd = greedy_offload(dag, env)
    print(f"\nGreedy   -> x={grd.best_x.tolist()} "
          f"cost ${grd.best_cost:.5f}")
    pso = run_pso_ga(dag, env,
                     PSOGAConfig(pop_size=60, max_iters=200), seed=0)
    print(f"PSO-GA   -> x={pso.best_x.tolist()} "
          f"cost ${pso.best_cost:.5f} "
          f"({pso.iterations} iterations)")
    assert pso.best_cost <= grd.best_cost + 1e-9
    print("\nPSO-GA <= Greedy — the paper's Fig. 2 in one script.")


if __name__ == "__main__":
    main()
