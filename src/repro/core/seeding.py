"""Seed coercion shared by every stochastic entry point.

PR 2 fixed ``run_pso_ga_batch`` to accept the int-like scalars that flow
naturally out of configs and RNGs — numpy integer scalars and 0-d arrays
(``np.array(7)``) — which ``np.isscalar`` wrongly rejects. The traffic
and drift samplers grew their own ``np.random.default_rng(...)`` calls
without that discipline, so ``sample_arrivals(seed=np.array(7))`` raised
deep inside numpy and a negative seed (legal arithmetic on a user seed,
e.g. ``seed - 7919``) raised ``ValueError``. These helpers are the one
front door: coerce any int-like scalar, and map it onto the non-negative
entropy word ``np.random.SeedSequence`` demands.
"""
from __future__ import annotations

import numpy as np

__all__ = ["coerce_seed", "rng_entropy"]

#: SeedSequence entropy words are unsigned; fold signed seeds into the
#: 64-bit ring so every int-like scalar is a legal, deterministic seed.
_ENTROPY_MASK = 0xFFFF_FFFF_FFFF_FFFF


def coerce_seed(seed, name: str = "seed") -> int:
    """A plain python int from any int-like scalar.

    Accepts python ints, numpy integer scalars, and 0-d integer arrays;
    rejects floats (silent truncation would de-correlate reruns) and
    anything non-scalar. Mirrors the scalar arm of the fleet solver's
    seed normalization so every sampler fails the same way.
    """
    arr = np.asarray(seed)
    if not np.issubdtype(arr.dtype, np.integer):
        raise TypeError(f"{name} must be int-like, got dtype {arr.dtype}")
    if arr.ndim != 0:
        raise ValueError(
            f"{name} must be a scalar, got shape {arr.shape}")
    return int(arr)


def rng_entropy(seed, name: str = "seed") -> int:
    """A non-negative entropy word for ``np.random.default_rng``.

    Non-negative seeds pass through unchanged (existing golden draws are
    preserved); negative seeds map two's-complement style onto the upper
    half of the 64-bit ring, so distinct negatives stay distinct and
    deterministic instead of raising.
    """
    return coerce_seed(seed, name) & _ENTROPY_MASK
