"""Deterministic, restartable data pipeline.

Design requirements at scale:
  * **Stateless indexing** — batch ``i`` is a pure function of
    ``(seed, i)`` (counter-based Philox), so a job restarted from a step-k
    checkpoint resumes the stream exactly at batch k with no iterator
    state to persist. This is the data-side half of fault tolerance.
  * **Per-host sharding** — every host materializes only its
    ``global_batch / num_processes`` slice (``host_slice``); the arrays
    feed ``jax.make_array_from_process_local_data`` in multi-host runs
    (single-process here, API kept real).
  * **Modality-aware** — LM families get packed token streams; encdec
    gets (audio_embeds, tokens); vlm gets (vision, tokens) — matching
    ``models.input_specs`` exactly.

Two sources: ``synthetic`` (Zipf-ish token draws, always available) and
``bytes`` (any UTF-8 file packed as byte-level tokens + shift).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np

from ..configs.base import ModelConfig, ShapeSpec

__all__ = ["DataConfig", "SyntheticStream", "byte_tokenize", "host_slice",
           "make_stream"]


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    source: str = "synthetic"        # synthetic | bytes
    path: Optional[str] = None       # for source="bytes"
    zipf_a: float = 1.2              # synthetic token skew


def host_slice(global_batch: int, process_index: int = 0,
               process_count: int = 1) -> slice:
    """The batch rows this host materializes."""
    if global_batch % process_count:
        raise ValueError("global_batch must divide process_count")
    per = global_batch // process_count
    return slice(process_index * per, (process_index + 1) * per)


def byte_tokenize(path: str) -> np.ndarray:
    with open(path, "rb") as f:
        return np.frombuffer(f.read(), np.uint8).astype(np.int32)


class SyntheticStream:
    """Infinite stream of training batches; ``batch(i)`` is pure in (seed, i)."""

    def __init__(self, cfg: ModelConfig, shape: ShapeSpec,
                 data: DataConfig = DataConfig(),
                 process_index: int = 0, process_count: int = 1):
        self.cfg = cfg
        self.shape = shape
        self.data = data
        self.sl = host_slice(shape.global_batch, process_index,
                             process_count)
        self.corpus = None
        if data.source == "bytes":
            if not data.path:
                raise ValueError("source='bytes' needs a path")
            self.corpus = byte_tokenize(data.path)
            if self.corpus.size < shape.seq_len + 2:
                raise ValueError("corpus smaller than one sequence")

    # -- pure batch constructor --------------------------------------------
    def batch(self, i: int) -> Dict[str, np.ndarray]:
        rng = np.random.Generator(np.random.Philox(
            key=self.data.seed, counter=[0, 0, 0, i]))
        cfg, shape = self.cfg, self.shape
        b = self.sl.stop - self.sl.start
        s = shape.seq_len
        if self.corpus is not None:
            starts = rng.integers(0, self.corpus.size - s - 1, size=b)
            toks = np.stack([self.corpus[st:st + s + 1] for st in starts])
        else:
            # Zipf draws clipped to the vocab: cheap, heavy-tailed, and
            # deterministic — loss curves behave like natural text enough
            # for throughput/convergence smoke purposes.
            toks = rng.zipf(self.data.zipf_a, size=(b, s + 1))
            toks = np.minimum(toks - 1, cfg.vocab - 1).astype(np.int32)
        toks = toks.astype(np.int32)
        if cfg.family == "encdec":
            frames = rng.standard_normal((b, s, cfg.d_model)).astype(
                np.float32)
            return {"audio_embeds": frames,
                    "tokens": toks[:, : s // 8 + 1]}
        if cfg.family == "vlm":
            tv = min(cfg.vision_tokens, max(s // 4, 8))
            vis = rng.standard_normal((b, tv, cfg.d_model)).astype(
                np.float32)
            return {"vision": vis, "tokens": toks[:, : s - tv + 1]}
        return {"tokens": toks}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        i = 0
        while True:
            yield self.batch(i)
            i += 1

    def at(self, start_step: int) -> Iterator[Dict[str, np.ndarray]]:
        """Resume iterator: yields batch(start_step), batch(start_step+1)…"""
        i = start_step
        while True:
            yield self.batch(i)
            i += 1


def make_stream(cfg: ModelConfig, shape: ShapeSpec,
                data: DataConfig = DataConfig(), **kw) -> SyntheticStream:
    return SyntheticStream(cfg, shape, data, **kw)
