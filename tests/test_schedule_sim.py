"""Fitness backends (DESIGN.md §8): the Pallas Algorithm-2 replay kernel
vs the pure-jnp ref and the numpy oracle, the two-phase scan split vs the
oracle on randomized problems, and padded-vs-unpadded equivalence — for
BOTH fidelity modes and BOTH backends (pallas in interpret mode)."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest
from hypo_compat import given, st
from test_simulator import random_dag, random_env

from repro.core import (PSOGAConfig, SimProblem, pad_problem, run_pso_ga,
                        simulate_np, simulate_padded)
from repro.core.simulator import simulate_swarm
from repro.core.fitness import (INFEASIBLE_OFFSET,
                                make_swarm_fitness, resolve_fitness_backend)
from repro.kernels.ref import schedule_replay_ref
from repro.kernels.schedule_sim import schedule_replay_folded


def _pp_fields(pp):
    return (pp.order, pp.compute, pp.parent_idx, pp.parent_mb, pp.child_idx,
            pp.child_mb, pp.app_id, pp.deadline, pp.pinned, pp.power,
            pp.cost_per_sec, pp.inv_bw, pp.tran_cost, pp.link_ok)


def _random_problem(seed, p=None, s=None, n_apps=1):
    rng = np.random.default_rng(seed)
    p = p or int(rng.integers(2, 20))
    s = s or int(rng.integers(2, 7))
    dag = random_dag(rng, p, n_apps=n_apps)
    env = random_env(rng, s)
    return SimProblem.build(dag, env), rng


def _swarm(rng, P, p, s, max_p):
    X = np.zeros((P, max_p), np.int32)
    X[:, :p] = rng.integers(0, s, size=(P, p))
    return jnp.asarray(X)


# ---------------------------------------------------------------------------
# kernel == pure-jnp ref == numpy oracle, randomized problems
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("faithful", [True, False])
@pytest.mark.parametrize("seed", range(6))
def test_kernel_matches_np_oracle(seed, faithful):
    """Interpret-mode kernel reproduces the numpy Algorithm-2 oracle:
    total cost, feasibility, and Σ T_i^comp, per particle."""
    prob, rng = _random_problem(seed, n_apps=1 + seed % 3)
    pp = pad_problem(prob)
    X = _swarm(rng, 9, prob.num_layers, prob.num_servers, prob.num_layers)
    total, feas, tsum = schedule_replay_folded(
        *_pp_fields(pp), X, faithful=faithful, tile_p=4, interpret=True)
    for i in range(X.shape[0]):
        ref = simulate_np(prob, np.asarray(X[i]), faithful=faithful)
        np.testing.assert_allclose(float(total[i]), float(ref.total_cost),
                                   rtol=2e-5, atol=1e-6)
        assert bool(feas[i]) == bool(ref.feasible)
        np.testing.assert_allclose(float(tsum[i]),
                                   float(ref.app_completion.sum()),
                                   rtol=2e-5)


@pytest.mark.parametrize("faithful", [True, False])
def test_kernel_matches_ref(faithful):
    """Kernel vs the pure-jnp ref on a padded problem (padding exercised)."""
    prob, rng = _random_problem(3, p=12, s=4, n_apps=2)
    pp = pad_problem(prob, max_p=16, max_S=8, max_apps=3)
    X = _swarm(rng, 7, prob.num_layers, prob.num_servers, 16)
    out = schedule_replay_folded(*_pp_fields(pp), X, faithful=faithful,
                                 tile_p=4, interpret=True)
    ref = schedule_replay_ref(*_pp_fields(pp), X, faithful=faithful)
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(ref[0]),
                               rtol=2e-5, atol=1e-6)
    assert np.array_equal(np.asarray(out[1]), np.asarray(ref[1]))
    np.testing.assert_allclose(np.asarray(out[2]), np.asarray(ref[2]),
                               rtol=2e-5)


# ---------------------------------------------------------------------------
# property test: simulate_padded == simulate_np, both modes, both backends
# ---------------------------------------------------------------------------

@given(seed=st.integers(0, 10_000), faithful=st.booleans(),
       backend=st.sampled_from(["scan", "pallas"]))
def test_backends_match_np_oracle_property(seed, faithful, backend):
    _assert_backend_matches_oracle(seed, faithful, backend)


@pytest.mark.parametrize("backend", ["scan", "pallas"])
@pytest.mark.parametrize("faithful", [True, False])
@pytest.mark.parametrize("seed", [0, 7, 42])
def test_backends_match_np_oracle_seeded(seed, faithful, backend):
    """Deterministic fallback sweep for environments without hypothesis."""
    _assert_backend_matches_oracle(seed, faithful, backend)


def _assert_backend_matches_oracle(seed, faithful, backend):
    prob, rng = _random_problem(seed, n_apps=1 + seed % 2)
    pp = pad_problem(prob)
    p, s = prob.num_layers, prob.num_servers
    X = _swarm(rng, 5, p, s, p)
    keys = make_swarm_fitness(pp, faithful, backend)(X)
    for i in range(X.shape[0]):
        ref = simulate_np(prob, np.asarray(X[i]), faithful=faithful)
        expect = float(ref.total_cost) if ref.feasible else \
            INFEASIBLE_OFFSET + np.log1p(float(ref.app_completion.sum()))
        np.testing.assert_allclose(float(keys[i]), expect, rtol=2e-5,
                                   atol=1e-6)


# ---------------------------------------------------------------------------
# padded == unpadded, both backends
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["scan", "pallas"])
@pytest.mark.parametrize("faithful", [True, False])
def test_padding_equivalence_sweep(faithful, backend):
    """Fitness is invariant under arbitrary extra padding for both
    backends (padded genes 0, appended after the real entries)."""
    prob, rng = _random_problem(11, p=10, s=4, n_apps=2)
    p, s = prob.num_layers, prob.num_servers
    tight = pad_problem(prob)
    fit_tight = make_swarm_fitness(tight, faithful, backend)
    X = _swarm(rng, 6, p, s, p)
    base = np.asarray(fit_tight(X))
    for max_p, max_S, max_apps in ((16, 6, 2), (32, 11, 4)):
        loose = pad_problem(prob, max_p=max_p, max_S=max_S,
                            max_apps=max_apps)
        Xp = jnp.zeros((6, max_p), jnp.int32).at[:, :p].set(X)
        out = np.asarray(make_swarm_fitness(loose, faithful, backend)(Xp))
        np.testing.assert_allclose(out, base, rtol=2e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# two-phase scan internals + backend plumbing
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("faithful", [True, False])
def test_simulate_swarm_matches_per_particle(faithful):
    """The swarm-level scan (shared step indices) agrees with the
    per-particle simulate_padded on every summary field."""
    prob, rng = _random_problem(9, p=13, s=5, n_apps=2)
    pp = pad_problem(prob, max_p=16, max_apps=3)
    X = _swarm(rng, 8, prob.num_layers, prob.num_servers, 16)
    total, feas, tsum = simulate_swarm(pp, X, faithful)
    for i in range(X.shape[0]):
        res = simulate_padded(pp, X[i], faithful)
        np.testing.assert_allclose(float(total[i]), float(res.total_cost),
                                   rtol=1e-6)
        assert bool(feas[i]) == bool(res.feasible)
        np.testing.assert_allclose(float(tsum[i]),
                                   float(res.app_completion.sum()),
                                   rtol=1e-6)


def test_two_phase_end_times_match_oracle():
    """The shrunk-carry scan still reproduces per-layer end times (the
    carry-dependent part phase 1 cannot precompute)."""
    prob, rng = _random_problem(5, p=14, s=5)
    pp = pad_problem(prob)
    for faithful in (True, False):
        x = rng.integers(0, prob.num_servers, size=prob.num_layers)
        ref = simulate_np(prob, x, faithful=faithful)
        out = simulate_padded(pp, jnp.asarray(x), faithful=faithful)
        np.testing.assert_allclose(np.asarray(out.end_times), ref.end_times,
                                   rtol=1e-5)


def test_resolve_backend():
    assert resolve_fitness_backend("scan") == "scan"
    assert resolve_fitness_backend("pallas") == "pallas"
    # this container is CPU-only -> auto selects the scan path
    assert resolve_fitness_backend("auto") == "scan"
    with pytest.raises(ValueError):
        resolve_fitness_backend("cuda")


def test_pallas_backend_solver_matches_scan():
    """Full PSO-GA runs agree across backends (same seed, same genes)."""
    cfg = PSOGAConfig(pop_size=16, max_iters=40, stall_iters=15)
    rng = np.random.default_rng(2)
    dag = random_dag(rng, 8)
    env = random_env(rng, 4)
    a = run_pso_ga(dag, env, cfg, seed=0)
    b = run_pso_ga(dag, env,
                   dataclasses.replace(cfg, fitness_backend="pallas"),
                   seed=0)
    assert a.best_fitness == pytest.approx(b.best_fitness, rel=2e-5)
    assert a.iterations == b.iterations
