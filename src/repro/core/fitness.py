"""Feasibility-aware fitness (paper §IV-B.2, Eq. 14–16).

The paper's three comparison cases —
  1. both feasible          → smaller C_total wins          (Eq. 14)
  2. one feasible           → the feasible particle wins     (Eq. 15)
  3. both infeasible        → smaller Σ T_i^comp wins        (Eq. 16)
— are induced by a single scalar key:

    key(X) = C_total(X)                            if feasible(X)
           = INFEASIBLE_OFFSET + log1p(Σ T_i^comp) otherwise

The log compression matters: fitness keys are float32 on device, and an
additive offset big enough to dominate any cost (costs are $ ≤ O(10^2),
completion-time sums can reach 10^9 s when a placement uses a forbidden
link) would otherwise swallow the completion-time differences that drive
Case-3 evolution (float32 has ~1e-3 absolute resolution at 1e4).
``log1p`` is strictly monotone, so the induced order on infeasible
particles is exactly the paper's Eq. 16 order.

Online re-planning (DESIGN.md §9) adds an optional migration term: given
an ``incumbent`` assignment, every *moved* layer (gene differing from the
incumbent's) pays its input-dataset transfer over the old→new link in
Eq. 6 form (∂ · c^tran per MB), scaled by ``mig_weight``:

    key_warm(X) = key(X) + mig_weight · Σ_{j : x_j ≠ inc_j} ∂_j · c^tran(inc_j, x_j)

so replans prefer cheap plan deltas. The term applies to feasible
particles only (Case-3 ordering stays the paper's Eq. 16), and a
``mig_weight`` of exactly 0.0 adds exactly 0.0 — the warm key is then
bit-identical to the cold key, which is what lets the batched runner use
ONE compiled program for cold and warm solves (DESIGN.md §9).

The traffic engine (DESIGN.md §10) swaps the single-shot replay for the
queue-aware Monte-Carlo replay when ``arrivals`` is given: the key then
optimizes the EXPECTED load-adjusted cost subject to a p95
deadline-miss budget,

    key_traffic(X) = mean_seeds C_total(X | arrivals)
                       if static-feasible and p95(miss) <= budget
                   = INFEASIBLE_OFFSET + MISS_PENALTY · p95(miss)
                       + log1p(mean Σ latencies)   otherwise

— the infeasible branch orders particles primarily by their p95 miss
rate (the quantity the budget constrains) and secondarily by total
latency, mirroring the paper's Eq. 16 time ordering, so the swarm
climbs toward the budget even when it is unattainable.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from .simulator import PaddedProblem, SimResult, simulate_swarm

#: Must exceed any attainable C_total; costs in both the paper fleet and the
#: TPU fleet are well under $1e4 per request batch.
INFEASIBLE_OFFSET = 1e4
#: Weight of the p95 miss rate in the traffic-infeasible key: miss is in
#: [0, 1] and the latency tail is log-compressed to <~21 (log1p of the
#: MIN_BW-clamped 1e9 s), so 64 lets a few points of miss rate dominate
#: any latency difference without swamping the offset.
MISS_PENALTY = 64.0

__all__ = ["INFEASIBLE_OFFSET", "MISS_PENALTY", "fitness_key",
           "make_swarm_fitness", "migration_cost",
           "resolve_fitness_backend"]


def fitness_key(res: SimResult) -> jnp.ndarray:
    total_time = jnp.sum(res.app_completion, axis=-1)
    infeasible_key = INFEASIBLE_OFFSET + jnp.log1p(total_time)
    return jnp.where(res.feasible, res.total_cost, infeasible_key)


def resolve_fitness_backend(backend: str) -> str:
    """``"auto"`` → pallas on TPU, scan elsewhere (matching
    ``kernels.ops.interpret_default``); else validate and pass through."""
    if backend == "auto":
        from ..kernels.ops import interpret_default
        return "scan" if interpret_default() else "pallas"
    if backend not in ("scan", "pallas"):
        raise ValueError(f"unknown fitness_backend {backend!r} "
                         "(expected scan | pallas | auto)")
    return backend


def migration_cost(pp: PaddedProblem, X: jnp.ndarray,
                   incumbent: jnp.ndarray) -> jnp.ndarray:
    """Per-particle plan-delta cost (Eq. 6 form, DESIGN.md §9).

    ``X (..., max_p)`` vs ``incumbent (max_p,)``: every moved layer pays
    its input-dataset size (Σ of its incoming edge MBs) over the
    incumbent→candidate link's $/MB rate. Padded layers carry zero
    ``parent_mb`` and identical (zero) genes, so they contribute exactly
    0 — the term is padding-invariant like the simulator itself.
    """
    inc = jnp.asarray(incumbent).astype(jnp.int32)
    input_mb = jnp.sum(pp.parent_mb, axis=-1)                   # (max_p,)
    moved = X != inc
    rate = pp.tran_cost[inc, X]                                 # (..., max_p)
    return jnp.sum(jnp.where(moved, input_mb * rate, 0.0), axis=-1)


def make_swarm_fitness(pp: PaddedProblem, faithful: bool = True,
                       backend: str = "scan",
                       incumbent: Optional[jnp.ndarray] = None,
                       mig_weight: Optional[jnp.ndarray] = None,
                       arrivals: Optional[jnp.ndarray] = None,
                       miss_budget: Optional[float] = None
                       ) -> Callable[[jnp.ndarray], jnp.ndarray]:
    """Swarm-fitness evaluator ``X (P, max_p) -> keys (P,)`` (DESIGN.md §8).

    ``backend="scan"`` is the bit-exact default: the swarm-level
    two-phase scan (``simulator.simulate_swarm`` — shared step indices,
    particle axis inside each op). ``backend="pallas"`` dispatches the
    whole tile to ``kernels.schedule_sim`` (the layer loop lives inside
    the kernel, interpret mode off-TPU). Both return the same
    ``(total_cost, feasible, Σ T_i^comp)`` summary, to which the 3-case
    key (Eq. 14–16) is applied here. Both close over ``pp`` — ``vmap``
    freely over a fleet axis (pallas picks up an outer grid dimension).

    With ``incumbent`` (a (max_p,) assignment) the key gains the
    migration term of ``migration_cost`` scaled by ``mig_weight``
    (DESIGN.md §9); ``incumbent``/``mig_weight`` may be traced arrays so
    the batched runner re-plans drifting fleets without retracing.

    With ``arrivals`` (``(M, max_apps, R)`` Monte-Carlo request
    timestamps, +inf padded — also freely traced, so drifting the load
    never retraces) the single-shot replay is swapped for the
    queue-aware traffic replay (DESIGN.md §10): the key becomes the
    seed-mean load-adjusted cost, feasibility becomes "pins/links legal
    AND p95 deadline-miss rate <= ``miss_budget``", and the infeasible
    branch orders by miss rate then total latency (see module
    docstring). The backend choice covers this path identically:
    ``"scan"`` replays via ``traffic.simulate_traffic_swarm``'s
    merged-order scan, ``"pallas"`` via the fused
    ``kernels.traffic_sim`` event-walk kernel — both reduce to the same
    ``(total, miss_rate, lat_sum, static_ok)`` per-seed summary.
    """
    backend = resolve_fitness_backend(backend)
    if arrivals is not None:
        budget = 0.05 if miss_budget is None else miss_budget
        if backend == "scan":
            from .traffic import simulate_traffic_swarm

            def seed_stats(X, a):
                sims = simulate_traffic_swarm(pp, X, a, faithful)
                return (sims.total_cost, sims.miss_rate, sims.lat_sum,
                        sims.static_ok)
        else:
            from ..kernels.ops import interpret_default
            from ..kernels.traffic_sim import traffic_replay_folded

            def seed_stats(X, a):
                total, miss_rate, lat_sum, static_ok, _ = \
                    traffic_replay_folded(
                        pp.order, pp.compute, pp.parent_idx, pp.parent_mb,
                        pp.child_idx, pp.child_mb, pp.app_id, pp.deadline,
                        pp.pinned, pp.power, pp.cost_per_sec, pp.inv_bw,
                        pp.tran_cost, pp.link_ok, pp.num_apps, X, a,
                        faithful=faithful, interpret=interpret_default())
                return total, miss_rate, lat_sum, static_ok

        def fit_traffic(X: jnp.ndarray) -> jnp.ndarray:
            total, miss_rate, lat_sum, static_ok = jax.vmap(
                lambda a: seed_stats(X, a))(arrivals)
            mean_cost = jnp.mean(total, axis=0)                    # (P,)
            p95_miss = jnp.percentile(miss_rate, 95.0, axis=0)
            ok = static_ok[0] & (p95_miss <= budget)
            if incumbent is not None:
                w = 1.0 if mig_weight is None else mig_weight
                mean_cost = mean_cost + w * migration_cost(pp, X,
                                                           incumbent)
            lat = jnp.mean(lat_sum, axis=0)
            return jnp.where(ok, mean_cost,
                             INFEASIBLE_OFFSET + MISS_PENALTY * p95_miss
                             + jnp.log1p(lat))
        return fit_traffic
    if backend == "scan":
        def raw(X: jnp.ndarray):
            return simulate_swarm(pp, X, faithful)
    else:
        from ..kernels.ops import interpret_default
        from ..kernels.schedule_sim import schedule_replay_folded

        def raw(X: jnp.ndarray):
            return schedule_replay_folded(
                pp.order, pp.compute, pp.parent_idx, pp.parent_mb,
                pp.child_idx, pp.child_mb, pp.app_id, pp.deadline,
                pp.pinned, pp.power, pp.cost_per_sec, pp.inv_bw,
                pp.tran_cost, pp.link_ok, X, faithful=faithful,
                interpret=interpret_default())

    def fit(X: jnp.ndarray) -> jnp.ndarray:
        total, feas, tsum = raw(X)
        if incumbent is not None:
            w = 1.0 if mig_weight is None else mig_weight
            total = total + w * migration_cost(pp, X, incumbent)
        return jnp.where(feas, total, INFEASIBLE_OFFSET + jnp.log1p(tsum))
    return fit
