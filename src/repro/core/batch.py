"""Fleet-scale batched PSO-GA: solve N heterogeneous offloading problems
in ONE jitted program per shape bucket (DESIGN.md §4, §12).

The sequential solver re-traces and re-compiles ``lax.while_loop`` per
problem — fatal when a production planner must place many (DAG, env)
pairs per second. This module packs N heterogeneous ``SimProblem``s into
a ``PackedFleet`` of power-of-two ``(max_p, max_S)`` shape buckets: each
bucket stacks its members into one ``PaddedProblem`` whose leaves carry
a leading problem axis (layers padded to the BUCKET's ``max_p``, servers
to its ``max_S``, with validity encoded so padded layers are zero-cost
no-ops and padded servers unreachable), then runs each bucket's fleet of
swarms as ``vmap``-over-problems of ``swarm_step`` inside ONE
``lax.while_loop``. Bucket rounding is per-group, not fleet-global, so a
mostly-small fleet with one resnet101 no longer pads every problem ~8×
(DESIGN.md §12); results are scattered back through each bucket's
original-index permutation, restoring input order exactly.

Convergence is tracked per problem: a problem whose stall counter hits
``cfg.stall_iters`` (or that reaches ``cfg.max_iters``) is *frozen* — its
whole swarm state passes through unchanged while the rest of the fleet
keeps iterating — so every problem's trajectory is exactly what the
sequential solver would have produced, and the loop exits when the last
problem converges.

Because each problem keeps its own PRNG key (seeded exactly like
``run_pso_ga``), its own link-aware initial swarm, and mutation/crossover
bounds drawn from its TRUE ``(p, S)`` sizes, the batched solver matches
the sequential solver gene-for-gene in fitness — independent of which
bucket (or which co-tenants) a problem lands with (see
``tests/test_batch.py::test_batched_matches_sequential`` and the
bucket/permutation invariants in ``tests/test_fleet.py``).

With a ``mesh`` (``launch.mesh``), each bucket's runner is wrapped in a
``shard_map`` over the mesh's non-"model" axes: the problem axis splits
across the data shards (N padded up to a multiple of the shard count
with masked dummy problems — replicas of row 0 whose results are
discarded), each shard runs its own while_loop to local convergence, and
per-problem freezing makes the sharded solve gene-for-gene identical to
the single-device path (DESIGN.md §12).

Compiled programs are cached per ``(cfg, traffic?, shape-bucket, mesh)``,
with jit specializing on the exact ``(N, max_p, max_S, ...)`` shapes
underneath, so repeated fleets with similar shapes skip retracing
entirely.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import (Callable, Dict, List, NamedTuple, Optional, Sequence,
                    Tuple, Union)

import jax
import jax.numpy as jnp
import numpy as np

from .dag import LayerDAG
from .environment import Environment
from .fitness import make_swarm_fitness, resolve_fitness_backend
from .pso_ga import (PSOGAConfig, PSOGAResult, _SwarmState, init_swarm,
                     swarm_step)
from .seeding import coerce_seed
from .simulator import PaddedProblem, SimProblem, pad_problem, simulate_padded
from .telemetry import get_telemetry, maybe_span

__all__ = ["pack_problems", "pack_arrivals", "run_pso_ga_batch",
           "bucket_size", "FleetBucket", "PackedFleet", "pack_fleet",
           "runner_cache_info", "runner_cache_stats",
           "reset_runner_cache_stats"]

ProblemLike = Union[SimProblem, Tuple[LayerDAG, Environment]]


def bucket_size(n: int, floor: int = 8) -> int:
    """Round up to the next power of two (>= floor) — the shape bucket."""
    return max(floor, 1 << max(0, int(n) - 1).bit_length())


def _as_problems(problems: Sequence[ProblemLike]) -> List[SimProblem]:
    out = []
    for pr in problems:
        if isinstance(pr, SimProblem):
            out.append(pr)
        else:
            dag, env = pr
            out.append(SimProblem.build(dag, env))
    return out


def _normalize_seeds(seed, n: int) -> List[int]:
    """One seed per problem from any int-like scalar or sequence.

    ``np.isscalar`` is the wrong predicate here: it rejects 0-d numpy
    arrays (``np.array(7)``) and, on some numpy versions, numpy integer
    scalars — both of which flow naturally out of configs and RNGs. Treat
    anything 0-d as a broadcast scalar (via the shared ``coerce_seed``
    front door, so samplers and the fleet solver fail identically), any
    1-d integer-like sequence as per-problem seeds.
    """
    arr = np.asarray(seed)
    if not np.issubdtype(arr.dtype, np.integer):
        raise TypeError(f"seed must be int-like, got dtype {arr.dtype}")
    if arr.ndim == 0:
        return [coerce_seed(arr)] * n
    if arr.ndim != 1:
        raise ValueError(f"seed must be a scalar or 1-d sequence, "
                         f"got shape {arr.shape}")
    if arr.shape[0] != n:
        raise ValueError(f"{arr.shape[0]} seeds for {n} problems")
    return [int(s) for s in arr]


def pack_problems(problems: Sequence[ProblemLike],
                  bucket: bool = True) -> PaddedProblem:
    """Pack N heterogeneous problems into one stacked ``PaddedProblem``
    at a single fleet-global shape.

    Every leaf gains a leading ``N`` axis; per-problem true sizes live in
    the ``num_layers`` / ``num_servers`` / ``num_apps`` fields (shape
    (N,)). With ``bucket=True`` the layer/server axes round up to power-
    of-two buckets so fleets of similar shapes share compiled programs.

    This is the single-shape primitive — the fleet solver now groups
    problems into per-size buckets via ``pack_fleet`` instead of padding
    the whole fleet to the global max (DESIGN.md §12).
    """
    probs = _as_problems(problems)
    if not probs:
        raise ValueError("pack_problems needs at least one problem")
    max_p = max(pr.num_layers for pr in probs)
    max_S = max(pr.num_servers for pr in probs)
    if bucket:
        max_p, max_S = bucket_size(max_p), bucket_size(max_S, floor=4)
    max_in = max(pr.parent_idx.shape[1] for pr in probs)
    max_out = max(pr.child_idx.shape[1] for pr in probs)
    max_apps = max(pr.num_apps for pr in probs)
    padded = [pad_problem(pr, max_p=max_p, max_S=max_S, max_in=max_in,
                          max_out=max_out, max_apps=max_apps)
              for pr in probs]
    return jax.tree.map(lambda *leaves: jnp.stack(leaves), *padded)


class FleetBucket(NamedTuple):
    """One shape bucket of a ``PackedFleet``: the members stacked at the
    bucket's padded shape, plus their original fleet indices."""
    ppb: PaddedProblem           # stacked leaves, leading axis = len(idx)
    idx: np.ndarray              # (len,) original problem indices
    max_p: int                   # bucket layer padding (power of two)
    max_S: int                   # bucket server padding (power of two)


@dataclasses.dataclass(frozen=True)
class PackedFleet:
    """N heterogeneous problems grouped into ``(max_p, max_S)`` shape
    buckets (DESIGN.md §12). Bucket membership is a pure function of
    each problem's own true sizes — never of its co-tenants — so the
    same problem lands in the same bucket under any fleet permutation,
    and ``buckets[*].idx`` is the original→bucket permutation used to
    restore input order in results."""
    buckets: Tuple[FleetBucket, ...]
    n_problems: int
    max_apps: int                # fleet-global app padding (arrivals
    #   pack once per bucket against this shared width)


def pack_fleet(problems: Sequence[ProblemLike],
               bucket: bool = True) -> PackedFleet:
    """Group N problems into power-of-two ``(max_p, max_S)`` buckets.

    With ``bucket=True`` each problem's bucket is
    ``(bucket_size(p), bucket_size(S, floor=4))`` of its OWN true sizes —
    per-group rounding, so a fleet of mostly-small DNNs with one huge
    straggler pads only the straggler's bucket large. With
    ``bucket=False`` the whole fleet forms ONE bucket at the exact
    fleet-global ``(max p, max S)`` (the pre-§12 global-padding
    behavior, kept as the A/B baseline in ``bench_pso --mixed-fleet``).

    The in/out-degree and app paddings stay fleet-global: they are tiny
    axes, and a shared ``max_apps`` lets one ``pack_arrivals`` width
    serve every bucket.
    """
    probs = _as_problems(problems)
    if not probs:
        raise ValueError("pack_fleet needs at least one problem")
    max_in = max(pr.parent_idx.shape[1] for pr in probs)
    max_out = max(pr.child_idx.shape[1] for pr in probs)
    max_apps = max(pr.num_apps for pr in probs)
    if bucket:
        def key(pr: SimProblem) -> Tuple[int, int]:
            return (bucket_size(pr.num_layers),
                    bucket_size(pr.num_servers, floor=4))
    else:
        gp = max(pr.num_layers for pr in probs)
        gS = max(pr.num_servers for pr in probs)

        def key(pr: SimProblem) -> Tuple[int, int]:
            return (gp, gS)
    groups: Dict[Tuple[int, int], List[int]] = {}
    for i, pr in enumerate(probs):
        groups.setdefault(key(pr), []).append(i)
    buckets = []
    for bp, bS in sorted(groups):
        idx = np.asarray(groups[(bp, bS)], np.int64)
        padded = [pad_problem(probs[i], max_p=bp, max_S=bS, max_in=max_in,
                              max_out=max_out, max_apps=max_apps)
                  for i in idx]
        ppb = jax.tree.map(lambda *leaves: jnp.stack(leaves), *padded)
        buckets.append(FleetBucket(ppb=ppb, idx=idx, max_p=bp, max_S=bS))
    return PackedFleet(buckets=tuple(buckets), n_problems=len(probs),
                       max_apps=max_apps)


# --------------------------------------------------------------------------
# compiled fleet runner, cached per (cfg, traffic?, shape bucket, mesh)
# --------------------------------------------------------------------------

_RUNNER_CACHE: Dict[tuple, Callable] = {}
#: hits/misses count _fleet_runner lookups; traces counts actual jit
#: re-traces of the fleet loop (incremented from inside the traced body,
#: so it only ticks when XLA really recompiles — the online re-planning
#: invariant "every round after the first hits the compiled runner"
#: (DESIGN.md §9) is asserted against this counter.
_CACHE_STATS = {"hits": 0, "misses": 0, "traces": 0}
#: one lock guards lookups/inserts (and the counters) so N concurrent
#: ``run_service`` loops share one runner per key — the multi-service
#: invariant of DESIGN.md §11 phase 2.
_RUNNER_LOCK = threading.Lock()


def runner_cache_info() -> Tuple[tuple, ...]:
    """(config, traffic?, shape-bucket, mesh) keys currently holding a
    compiled fleet runner."""
    return tuple(_RUNNER_CACHE)


def runner_cache_stats() -> Dict[str, int]:
    """Snapshot of the fleet-runner cache counters (DESIGN.md §9)."""
    return dict(_CACHE_STATS)


def reset_runner_cache_stats() -> None:
    """Zero the counters (the compiled runners themselves are kept)."""
    for k in _CACHE_STATS:
        _CACHE_STATS[k] = 0


def _done(state: _SwarmState, cfg: PSOGAConfig) -> jnp.ndarray:
    """(N,) bool — which problems have hit the paper's stopping rule."""
    return (state.it >= cfg.max_iters) | (state.stall >= cfg.stall_iters)


def _mesh_cache_key(mesh) -> Optional[tuple]:
    """Hashable identity of a mesh for the runner cache: axis names,
    shape, and the device ids in mesh order (two mesh objects over the
    same devices in the same layout share compiled runners)."""
    if mesh is None:
        return None
    return (tuple(mesh.axis_names), tuple(mesh.devices.shape),
            tuple(int(d.id) for d in mesh.devices.flat))


def _fleet_runner(cfg: PSOGAConfig, traffic: bool = False,
                  shape_bucket: Optional[Tuple[int, int]] = None,
                  mesh=None, telemetry=None) -> Callable:
    """Jitted ``(ppb, keys, X0b, incb, migb[, arrb]) -> final _SwarmState``.

    One cache entry per ``(cfg, traffic?, shape-bucket, mesh)`` (the
    config is baked into the traced loop; the traffic flag switches the
    runner's signature — with it, per-problem Monte-Carlo arrivals
    ``arrb (N, M, max_apps, R)`` ride along as one more traced argument,
    DESIGN.md §10; the shape bucket keys each ``(max_p, max_S)`` group
    of a ``PackedFleet`` to its own compiled program, DESIGN.md §12);
    jit's own cache handles exact shape specialization underneath, and
    the power-of-two buckets of ``pack_fleet`` keep the number of
    distinct shapes it sees small. Distinct bucket sizes N still trace
    separately — batch at stable sizes if that matters.

    Cold and warm (re-planning) solves share ONE program per bucket: the
    incumbent genes ``incb (N, max_p)`` and migration weights ``migb
    (N,)`` are ordinary traced arrays, and a zero weight multiplies the
    migration term away bit-exactly (DESIGN.md §9). Drift — of the
    environment OR of the arrival stream — only changes array *values*,
    so every re-planning round after the first reuses the compiled
    runner; ``runner_cache_stats()["traces"]`` counts the actual
    re-traces.

    With a ``mesh``, the runner body is wrapped in ``shard_map`` over
    the mesh's non-"model" axes before jitting: every input/output leaf
    shards its leading problem axis across the data shards, each shard
    runs its own while_loop to local convergence (per-problem freezing
    makes extra iterations no-ops, so shard-local exit is a pure win),
    and the caller guarantees N is a multiple of the shard count
    (``run_pso_ga_batch`` pads with masked dummy problems,
    DESIGN.md §12).

    The backend string is normalized BEFORE the cache key: ``"auto"``
    and whatever it resolves to on this host share one entry (and one
    compiled program), so flipping only the spelling of the backend
    never retraces — pinned by
    ``tests/test_traffic_kernel.py::test_runner_cache_backend_normalized``.

    Thread-safe: lookups, inserts, and the counters sit behind one lock,
    and first calls per shape specialization are serialized, so N
    concurrent ``run_service`` loops (``run_services``) get exactly one
    miss — and one trace — per key (DESIGN.md §11).
    """
    cfg = dataclasses.replace(
        cfg, fitness_backend=resolve_fitness_backend(cfg.fitness_backend))
    cache_key = (cfg, traffic, shape_bucket, _mesh_cache_key(mesh))
    with _RUNNER_LOCK:
        cached = _RUNNER_CACHE.get(cache_key)
        hit = cached is not None
        if hit:
            _CACHE_STATS["hits"] += 1
        else:
            _CACHE_STATS["misses"] += 1
            cached = _build_fleet_runner(cfg, traffic, mesh)
            _RUNNER_CACHE[cache_key] = cached
    # telemetry (DESIGN.md §13): explicit channel first, else the
    # process-global one — direct callers have no config path here.
    # Emitted outside the runner lock so the tracer's lock never nests
    # inside ours. Never part of the cache key: observation only.
    tel = telemetry if telemetry is not None else get_telemetry()
    if tel is not None:
        tel.inc("runner_cache.lookup_hits" if hit
                else "runner_cache.lookup_misses")
        tel.instant("runner_cache_hit" if hit else "runner_cache_miss",
                    bucket=str(shape_bucket), traffic=traffic,
                    mesh=mesh is not None)
    return cached


def _serialize_first_calls(jitted: Callable) -> Callable:
    """Serialize the FIRST call per argument-shape specialization.

    ``jax.jit`` traces lazily at first invocation and drops the GIL
    while XLA compiles, so two service threads hitting a fresh runner
    could each trace the same program — double-counting the ``traces``
    invariant counter and compiling twice. One lock per shape signature
    makes the first call exclusive; warmed signatures take the lock-free
    fast path, so concurrent solves still overlap.
    """
    guard = threading.Lock()
    warmed: set = set()
    locks: Dict[tuple, threading.Lock] = {}

    def wrapper(*args):
        sig = tuple((tuple(leaf.shape), str(leaf.dtype))
                    for leaf in jax.tree.leaves(args)
                    if hasattr(leaf, "shape"))
        with guard:
            warm = sig in warmed
            lock = None if warm else locks.setdefault(sig, threading.Lock())
        if warm:
            return jitted(*args)
        with lock:
            out = jitted(*args)
        with guard:
            warmed.add(sig)
            locks.pop(sig, None)
        return out

    return wrapper


def _build_fleet_runner(cfg: PSOGAConfig, traffic: bool, mesh) -> Callable:
    """Construct (without tracing) the jitted fleet loop for
    ``_fleet_runner`` — see its docstring for the contract."""
    vstep = jax.vmap(lambda pp, st, inc, mw, arr: swarm_step(
        pp, st, cfg, incumbent=inc, mig_weight=mw, arrivals=arr))
    # one swarm-fitness per problem, vmapped over the fleet: the scan
    # backend batches the two-phase simulate_padded; the pallas backend's
    # grid picks up the problem axis as an outer grid dimension.
    vfit = jax.vmap(lambda pp, X, inc, mw, arr: make_swarm_fitness(
        pp, cfg.faithful_sim, cfg.fitness_backend,
        incumbent=inc, mig_weight=mw, arrivals=arr,
        miss_budget=cfg.miss_budget)(X))

    def run_impl(ppb: PaddedProblem, keys: jnp.ndarray, X0b: jnp.ndarray,
                 incb: jnp.ndarray, migb: jnp.ndarray,
                 arrb: Optional[jnp.ndarray]) -> _SwarmState:
        _CACHE_STATS["traces"] += 1        # python side effect: trace-time only
        n = X0b.shape[0]
        f0 = vfit(ppb, X0b, incb, migb, arrb)                  # (N, P)
        i0 = jnp.argmin(f0, axis=1)                            # (N,)
        gbest_x = jnp.take_along_axis(
            X0b, i0[:, None, None], axis=1)[:, 0, :]           # (N, max_p)
        gbest_f = jnp.take_along_axis(f0, i0[:, None], axis=1)[:, 0]
        state = _SwarmState(
            key=keys, X=X0b, pbest_x=X0b, pbest_f=f0,
            gbest_x=gbest_x, gbest_f=gbest_f,
            it=jnp.zeros((n,), jnp.int32), stall=jnp.zeros((n,), jnp.int32))

        def cond(st: _SwarmState) -> jnp.ndarray:
            return jnp.any(~_done(st, cfg))

        def body(st: _SwarmState) -> _SwarmState:
            new = vstep(ppb, st, incb, migb, arrb)
            frozen = _done(st, cfg)                            # (N,)
            return jax.tree.map(
                lambda nw, old: jnp.where(
                    frozen.reshape((-1,) + (1,) * (nw.ndim - 1)), old, nw),
                new, st)

        return jax.lax.while_loop(cond, body, state)

    # fixed arity per traffic flag: shard_map needs in_specs to match the
    # call signature exactly, so the no-traffic runner takes 5 args and
    # the traffic runner 6 (no optional-None juggling inside the spec).
    if traffic:
        def run(ppb, keys, X0b, incb, migb, arrb):
            return run_impl(ppb, keys, X0b, incb, migb, arrb)
    else:
        def run(ppb, keys, X0b, incb, migb):
            return run_impl(ppb, keys, X0b, incb, migb, None)

    if mesh is not None:
        from jax.experimental.shard_map import shard_map

        from ..launch.mesh import data_axes_of
        # P((axes,)) shards dim 0 — the problem axis — over every
        # non-"model" axis jointly; the spec acts as a pytree prefix, so
        # each PaddedProblem/_SwarmState leaf splits its leading axis.
        spec = jax.sharding.PartitionSpec(tuple(data_axes_of(mesh)))
        n_args = 6 if traffic else 5
        run = shard_map(run, mesh=mesh, in_specs=(spec,) * n_args,
                        out_specs=spec, check_rep=False)

    return _serialize_first_calls(jax.jit(run))


def pack_arrivals(arrivals: Sequence[np.ndarray],
                  max_apps: int) -> np.ndarray:
    """Stack per-problem ``(M, n_apps_i, R)`` Monte-Carlo arrival arrays
    into one ``(N, M, max_apps, R)`` traced input, padding the app axis
    with +inf (a padded app never receives a request — the same masked
    no-op discipline as padded layers, DESIGN.md §10). Every problem
    must share the seed count M and the request cap R (one compiled
    runner serves the fleet)."""
    mats = [np.asarray(a, float) for a in arrivals]
    if not mats:
        raise ValueError("pack_arrivals needs at least one arrival set")
    for i, a in enumerate(mats):
        if a.ndim != 3:
            raise ValueError(
                f"arrivals[{i}] has shape {a.shape}; expected a 3-d "
                f"(M, n_apps, R) Monte-Carlo array")
    m0, r0 = mats[0].shape[0], mats[0].shape[2]
    for i, a in enumerate(mats):
        if a.shape[0] != m0 or a.shape[2] != r0:
            raise ValueError(
                f"arrivals[{i}] has shape {a.shape}; expected (M={m0}, "
                f"n_apps, R={r0}) with M and R shared across the fleet")
        if a.shape[1] > max_apps:
            raise ValueError(f"arrivals[{i}] has {a.shape[1]} apps > "
                             f"packed max_apps {max_apps}")
        # +inf is the legal "no more requests" pad; NaN or negative
        # timestamps are corrupt draws and must not reach the kernel
        # (where they'd silently poison every merged-order replay).
        if np.isnan(a).any() or (a < 0.0).any():
            raise ValueError(f"arrivals[{i}] contains NaN or negative "
                             f"request times")
    out = np.full((len(mats), m0, max_apps, r0), np.inf)
    for i, a in enumerate(mats):
        out[i, :, :a.shape[1], :] = a
    return out


def _pad_rows(arr, pad: int):
    """Append ``pad`` copies of row 0 along axis 0 (the masked dummy
    problems of the mesh path, DESIGN.md §12 — every vmap lane is
    independent and the dummies' results are sliced away, so replicating
    any real row is parity-safe)."""
    if isinstance(arr, np.ndarray):
        return np.concatenate(
            [arr, np.broadcast_to(arr[:1], (pad,) + arr.shape[1:])], axis=0)
    return jnp.concatenate(
        [arr, jnp.broadcast_to(arr[:1], (pad,) + arr.shape[1:])], axis=0)


def run_pso_ga_batch(problems: Sequence[ProblemLike],
                     cfg: PSOGAConfig = PSOGAConfig(),
                     seed: Union[int, Sequence[int]] = 0,
                     bucket: bool = True,
                     return_state: bool = False,
                     incumbent: Optional[Sequence[np.ndarray]] = None,
                     migration_weight: Union[float,
                                             Sequence[float]] = 0.0,
                     warm_rescue: Optional[Sequence[bool]] = None,
                     arrivals: Optional[Sequence[np.ndarray]] = None,
                     mesh=None,
                     telemetry=None):
    """Solve N offloading problems with one fleet of swarms per bucket.

    Args:
      problems: ``SimProblem``s or ``(LayerDAG, Environment)`` pairs.
      cfg: shared PSO-GA hyperparameters (one compiled program per cfg
        per shape bucket).
      seed: one seed for every problem, or a per-problem sequence —
        problem i behaves exactly like ``run_pso_ga(..., seed=seed_i)``.
      bucket: group problems into power-of-two ``(max_p, max_S)`` shape
        buckets (``pack_fleet``, DESIGN.md §12) so a mostly-small fleet
        never pads to its largest member and repeated fleet shapes reuse
        compiled runners. ``False`` solves the whole fleet as ONE bucket
        at the exact global max (the A/B baseline).
      return_state: also return the final stacked ``_SwarmState`` in
        ORIGINAL problem order, re-assembled across buckets at the
        fleet's largest bucket ``max_p`` (genes beyond a problem's own
        bucket stay 0 — tests use it to assert padded genes were never
        touched).
      incumbent: per-problem (p_i,) incumbent assignments (online
        re-planning, DESIGN.md §9): swarms are warm-started in the
        incumbent's neighborhood (``init_swarm`` incumbent mode) and the
        fitness pays ``migration_weight`` × the Eq. 6 input-dataset cost
        for every moved layer. ``None`` is a cold solve — bit-identical
        to the pre-warm-start solver, via the SAME compiled runner. A
        per-problem entry of ``None`` demotes only that problem to a
        cold solve (stale-plan guard, DESIGN.md §11): its swarm draws
        the cold init and its migration weight is zeroed, while the
        rest of the fleet stays warm. Incumbents route with their
        problem through re-bucketing — warm state survives any fleet
        composition change that keeps the problem's own shape.
      migration_weight: scalar or per-problem migration-cost weights
        (ignored without ``incumbent``).
      warm_rescue: per-problem flags (with ``incumbent`` only): seed the
        cold tier anchors into that problem's warm swarm tail — the
        re-planner sets it where drift stranded the incumbent
        infeasible, so feasibility recovery starts from the same escape
        hatches a cold solve gets (``init_swarm`` rescue mode).
      arrivals: per-problem ``(M, n_apps_i, R)`` Monte-Carlo request
        timestamps (DESIGN.md §10) — switches every problem's fitness
        to the queue-aware traffic key under ``cfg.miss_budget``. The
        packed arrays are traced runner inputs, so sweeping the load
        (or re-planning under a load surge) never retraces.
      mesh: a ``jax.sharding.Mesh`` (``launch.mesh``) — shard each
        bucket's problem axis across the mesh's non-"model" axes via
        ``shard_map``; each bucket's N is padded to a multiple of the
        data-shard count with masked dummy problems whose results are
        discarded. Gene-for-gene identical to the single-device solve
        (DESIGN.md §12). ``None`` keeps today's single-device path.
      telemetry: a ``Telemetry`` channel (DESIGN.md §13) — each bucket's
        runner dispatch is wrapped in a ``fleet_solve`` span. ``None``
        falls back to the process-global channel (``set_telemetry``);
        with neither, the solve path is bit-identical to pre-telemetry
        behavior.

    Returns a list of per-problem ``PSOGAResult`` in INPUT ORDER (and
    the re-assembled state if asked) — bucket assignment is invisible in
    the output. ``record_history`` is not supported in fleet mode — use
    the sequential solver to trace a single problem's convergence curve.
    ``best_fitness`` is the migration-adjusted key when warm (the
    traffic key when ``arrivals`` is given); ``best_cost`` is always
    the raw zero-load replayed plan cost.
    """
    probs = _as_problems(problems)
    n = len(probs)
    tel = telemetry if telemetry is not None else get_telemetry()
    seeds = _normalize_seeds(seed, n)
    if incumbent is not None and len(incumbent) != n:
        raise ValueError(f"{len(incumbent)} incumbents for {n} problems")
    if arrivals is not None and len(arrivals) != n:
        raise ValueError(f"{len(arrivals)} arrival sets for {n} problems")
    mig_arr = np.broadcast_to(
        np.asarray(migration_weight, np.float32), (n,))

    fleet = pack_fleet(probs, bucket=bucket)
    traffic = arrivals is not None
    shards = 1
    if mesh is not None:
        from ..launch.mesh import data_shard_count
        shards = data_shard_count(mesh)

    results: List[Optional[PSOGAResult]] = [None] * n
    bucket_states: List[Tuple[FleetBucket, _SwarmState]] = []
    for b in fleet.buckets:
        nb = int(b.idx.shape[0])
        # Per-problem init mirrors run_pso_ga exactly: split the
        # problem's own key, draw the link-aware swarm at the TRUE
        # (p, S) shape, then embed into the bucket's padded gene space
        # (padded genes start — and stay — 0). Seeds, incumbents,
        # rescue flags, and arrivals all route by ORIGINAL index, so
        # bucket assignment never reshuffles a problem's inputs.
        keys_l = []
        X0b = np.zeros((nb, cfg.pop_size, b.max_p), np.int32)
        incb = np.zeros((nb, b.max_p), np.int32)
        migb = np.zeros((nb,), np.float32)
        for j, i in enumerate(b.idx):
            pr = probs[i]
            key, k_init = jax.random.split(jax.random.PRNGKey(seeds[i]))
            keys_l.append(np.asarray(key))
            inc_i = None
            rescue_i = False
            if incumbent is not None and incumbent[i] is not None:
                inc_i = np.asarray(incumbent[i], np.int32)
                if inc_i.shape != (pr.num_layers,):
                    raise ValueError(
                        f"incumbent[{i}] has shape {inc_i.shape}, "
                        f"expected ({pr.num_layers},)")
                incb[j, :pr.num_layers] = inc_i
                migb[j] = mig_arr[i]
                rescue_i = bool(warm_rescue[i]) if warm_rescue is not None \
                    else False
            # else: a demoted problem (stale incumbent, DESIGN.md §11)
            # solves cold inside the warm fleet: zero migration weight
            # multiplies the term away bit-exactly, and init_swarm gets
            # no incumbent — identical to a cold solve of problem i.
            X0b[j, :, :pr.num_layers] = np.asarray(
                init_swarm(k_init, pr, cfg, incumbent=inc_i,
                           rescue=rescue_i))
        keys_a = np.stack(keys_l)
        arrb = None
        if traffic:
            arrb = pack_arrivals([arrivals[i] for i in b.idx],
                                 fleet.max_apps)

        ppb = b.ppb
        pad = (-nb) % shards
        if pad:
            ppb = jax.tree.map(lambda a: _pad_rows(a, pad), ppb)
            keys_a = _pad_rows(keys_a, pad)
            X0b = _pad_rows(X0b, pad)
            incb = _pad_rows(incb, pad)
            migb = _pad_rows(migb, pad)
            if arrb is not None:
                arrb = _pad_rows(arrb, pad)

        runner = _fleet_runner(cfg, traffic=traffic,
                               shape_bucket=(b.max_p, b.max_S),
                               mesh=mesh, telemetry=tel)
        args = (ppb, jnp.asarray(keys_a), jnp.asarray(X0b),
                jnp.asarray(incb), jnp.asarray(migb))
        if traffic:
            args = args + (jnp.asarray(arrb),)
        with maybe_span(tel, "fleet_solve",
                        bucket=f"{b.max_p}x{b.max_S}", n=nb,
                        traffic=traffic, sharded=mesh is not None):
            state = runner(*args)
            jax.block_until_ready(state.gbest_f)
        if pad:
            state = jax.tree.map(lambda a: a[:nb], state)

        # Re-simulate each gbest (same as the sequential epilogue).
        res = jax.vmap(
            lambda pp, x: simulate_padded(pp, x, cfg.faithful_sim))(
                b.ppb, state.gbest_x)
        for j, i in enumerate(b.idx):
            pr = probs[i]
            feasible = bool(res.feasible[j])
            results[i] = PSOGAResult(
                best_x=np.asarray(state.gbest_x[j])[:pr.num_layers],
                best_fitness=float(state.gbest_f[j]),
                best_cost=float(res.total_cost[j]) if feasible
                else float("inf"),
                feasible=feasible,
                iterations=int(state.it[j]),
                history=None)
        bucket_states.append((b, state))

    if not return_state:
        return results

    # Re-assemble one fleet-ordered state across buckets at the largest
    # bucket's max_p: genes beyond a problem's own bucket shape are 0 —
    # the same "padded genes untouched" invariant the single-bucket
    # state had (tests/test_batch.py::test_padding_never_selected).
    gmax_p = max(b.max_p for b in fleet.buckets)
    st0 = bucket_states[0][1]
    key_g = np.zeros((n,) + st0.key.shape[1:], np.asarray(st0.key).dtype)
    X_g = np.zeros((n, cfg.pop_size, gmax_p), np.int32)
    pbx_g = np.zeros((n, cfg.pop_size, gmax_p), np.int32)
    pbf_g = np.zeros((n, cfg.pop_size), np.asarray(st0.pbest_f).dtype)
    gbx_g = np.zeros((n, gmax_p), np.int32)
    gbf_g = np.zeros((n,), np.asarray(st0.gbest_f).dtype)
    it_g = np.zeros((n,), np.int32)
    stall_g = np.zeros((n,), np.int32)
    for b, st in bucket_states:
        key_g[b.idx] = np.asarray(st.key)
        X_g[b.idx, :, :b.max_p] = np.asarray(st.X)
        pbx_g[b.idx, :, :b.max_p] = np.asarray(st.pbest_x)
        pbf_g[b.idx] = np.asarray(st.pbest_f)
        gbx_g[b.idx, :b.max_p] = np.asarray(st.gbest_x)
        gbf_g[b.idx] = np.asarray(st.gbest_f)
        it_g[b.idx] = np.asarray(st.it)
        stall_g[b.idx] = np.asarray(st.stall)
    state_out = _SwarmState(
        key=jnp.asarray(key_g), X=jnp.asarray(X_g),
        pbest_x=jnp.asarray(pbx_g), pbest_f=jnp.asarray(pbf_g),
        gbest_x=jnp.asarray(gbx_g), gbest_f=jnp.asarray(gbf_g),
        it=jnp.asarray(it_g), stall=jnp.asarray(stall_g))
    return results, state_out
