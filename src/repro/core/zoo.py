"""Paper benchmark DNN profiles: AlexNet, VGG19, GoogleNet, ResNet101.

The paper's github profile file is not available offline (DESIGN.md §2);
these DAGs are synthesized from the published architectures with compute
amounts in **CPU-seconds** (execution time on a 1-CPU server; the paper's
end devices have p = 2) and inter-layer datasets in **MB**, scaled so the
quoted anchors hold: AlexNet has 11 layers with max inter-layer dataset
< 1.1 MB and ~1-2 s per-layer device times (Table I ballpark); VGG19 is a
pure chain (prePSO collapses it to one layer); GoogleNet has inception
branching with ≈40-50% cut-edge compressibility; ResNet101 is deep
(~340 nodes counting conv/bn/relu/add as the paper does to reach
"more than 1000" across 3 DNNs per device) with skip edges.

Every DNN's input layer is pinned to its originating end-device server.
"""
from __future__ import annotations

from typing import List, Tuple

import numpy as np

from .dag import LayerDAG

__all__ = ["alexnet", "vgg19", "googlenet", "resnet101", "build", "NAMES"]

NAMES = ("alexnet", "vgg19", "googlenet", "resnet101")


class _Builder:
    def __init__(self) -> None:
        self.compute: List[float] = []
        self.edges: List[Tuple[int, int]] = []
        self.mb: List[float] = []
        self.names: List[str] = []

    def node(self, name: str, cpu_sec: float) -> int:
        self.names.append(name)
        self.compute.append(cpu_sec)
        return len(self.compute) - 1

    def edge(self, u: int, v: int, mb: float) -> None:
        self.edges.append((u, v))
        self.mb.append(mb)

    def chain(self, specs: List[Tuple[str, float, float]], start: int) -> int:
        """specs: (name, cpu_sec, incoming_mb); returns last node id."""
        prev = start
        for name, a, mb in specs:
            n = self.node(name, a)
            self.edge(prev, n, mb)
            prev = n
        return prev

    def dag(self, deadline: float, pin_server: int, app_id: int = 0
            ) -> LayerDAG:
        p = len(self.compute)
        pinned = np.full(p, -1, np.int32)
        pinned[0] = pin_server
        return LayerDAG(
            compute=np.asarray(self.compute),
            edges=np.asarray(self.edges, np.int32).reshape(-1, 2),
            edge_mb=np.asarray(self.mb),
            app_id=np.full(p, app_id, np.int32),
            deadline=np.asarray([deadline]),
            pinned=pinned, names=list(self.names))


def alexnet(pin_server: int = 0, deadline: float = np.inf) -> LayerDAG:
    """11 layers: input + 5 conv + 3 fc + softmax + output (pure chain)."""
    b = _Builder()
    inp = b.node("input", 0.05)
    b.chain([
        ("conv1", 1.30, 0.59),   # 227x227x3 uint8
        ("conv2", 2.10, 1.07),   # paper: max dataset < 1.1 MB
        ("conv3", 1.40, 0.71),
        ("conv4", 1.10, 0.50),
        ("conv5", 0.80, 0.38),
        ("fc6", 1.90, 0.21),
        ("fc7", 0.90, 0.031),
        ("fc8", 0.35, 0.016),
        ("softmax", 0.05, 0.004),
        ("output", 0.02, 0.004),
    ], inp)
    return b.dag(deadline, pin_server)


def vgg19(pin_server: int = 0, deadline: float = np.inf) -> LayerDAG:
    """25 nodes: input + 16 conv + 5 pool + 3 fc (chain; prePSO -> 1 node)."""
    b = _Builder()
    inp = b.node("input", 0.05)
    convs = [
        # (name, cpu_sec, incoming MB)
        ("conv1_1", 1.1, 0.59), ("conv1_2", 2.4, 12.3),
        ("pool1", 0.10, 12.3),
        ("conv2_1", 1.9, 3.1), ("conv2_2", 2.6, 6.2),
        ("pool2", 0.08, 6.2),
        ("conv3_1", 1.6, 1.5), ("conv3_2", 2.8, 3.1), ("conv3_3", 2.8, 3.1),
        ("conv3_4", 2.8, 3.1), ("pool3", 0.06, 3.1),
        ("conv4_1", 1.5, 0.77), ("conv4_2", 2.9, 1.5), ("conv4_3", 2.9, 1.5),
        ("conv4_4", 2.9, 1.5), ("pool4", 0.05, 1.5),
        ("conv5_1", 0.9, 0.38), ("conv5_2", 0.9, 0.38), ("conv5_3", 0.9, 0.38),
        ("conv5_4", 0.9, 0.38), ("pool5", 0.03, 0.38),
        ("fc6", 2.5, 0.10), ("fc7", 1.0, 0.016), ("fc8", 0.4, 0.016),
    ]
    b.chain(convs, inp)
    return b.dag(deadline, pin_server)


def googlenet(pin_server: int = 0, deadline: float = np.inf) -> LayerDAG:
    """Stem + 9 inception modules (4 parallel branches each) + classifier.

    Branch chains (1x1->3x3 etc.) are cut-edges; the merge ratio lands in
    the paper's ~48% ballpark.
    """
    b = _Builder()
    inp = b.node("input", 0.05)
    stem_end = b.chain([
        ("conv7x7", 1.2, 0.59), ("pool1", 0.08, 3.1),
        ("conv1x1", 0.5, 0.77), ("conv3x3", 1.5, 0.77),
        ("pool2", 0.06, 2.3),
    ], inp)

    def inception(prev: int, tag: str, scale: float, mb_in: float) -> int:
        # four branches from `prev`, concatenated
        b1 = b.node(f"{tag}_1x1", 0.35 * scale)
        b.edge(prev, b1, mb_in)
        r3 = b.node(f"{tag}_3x3r", 0.15 * scale)
        b.edge(prev, r3, mb_in)
        c3 = b.node(f"{tag}_3x3", 0.80 * scale)
        b.edge(r3, c3, mb_in * 0.6)
        r5 = b.node(f"{tag}_5x5r", 0.08 * scale)
        b.edge(prev, r5, mb_in)
        c5 = b.node(f"{tag}_5x5", 0.40 * scale)
        b.edge(r5, c5, mb_in * 0.15)
        pp = b.node(f"{tag}_pool", 0.05 * scale)
        b.edge(prev, pp, mb_in)
        pc = b.node(f"{tag}_poolproj", 0.10 * scale)
        b.edge(pp, pc, mb_in)
        cat = b.node(f"{tag}_concat", 0.02)
        b.edge(b1, cat, mb_in * 0.35)
        b.edge(c3, cat, mb_in * 0.45)
        b.edge(c5, cat, mb_in * 0.12)
        b.edge(pc, cat, mb_in * 0.18)
        return cat

    prev = stem_end
    mb = 1.2
    for i, (tag, scale) in enumerate([
            ("3a", 1.0), ("3b", 1.3), ("4a", 1.1), ("4b", 1.0), ("4c", 1.0),
            ("4d", 1.1), ("4e", 1.3), ("5a", 1.2), ("5b", 1.4)]):
        prev = inception(prev, tag, scale, mb)
        if tag in ("3b", "4e"):       # maxpool between stages
            pool = b.node(f"pool_{tag}", 0.05)
            b.edge(prev, pool, mb)
            prev = pool
            mb *= 0.55
    b.chain([("avgpool", 0.05, mb), ("fc", 0.30, 0.004),
             ("output", 0.02, 0.004)], prev)
    return b.dag(deadline, pin_server)


def resnet101(pin_server: int = 0, deadline: float = np.inf) -> LayerDAG:
    """Stem + 33 bottlenecks (conv/bn/relu expanded, residual adds) + head.

    ~341 nodes; conv-bn-relu chains are cut-edges, residual adds are not.
    """
    b = _Builder()
    inp = b.node("input", 0.05)
    prev = b.chain([("conv1", 0.9, 0.59), ("bn1", 0.05, 3.1),
                    ("relu1", 0.02, 3.1), ("pool1", 0.06, 3.1)], inp)
    stage_cfg = [(3, 1.0, 0.77), (4, 1.1, 0.42), (23, 1.0, 0.21),
                 (3, 1.3, 0.13)]
    for s_idx, (blocks, scale, mb) in enumerate(stage_cfg):
        for blk in range(blocks):
            tag = f"s{s_idx}b{blk}"
            entry = prev
            chain_end = b.chain([
                (f"{tag}_c1", 0.20 * scale, mb), (f"{tag}_bn1", 0.03, mb),
                (f"{tag}_r1", 0.01, mb),
                (f"{tag}_c2", 0.55 * scale, mb), (f"{tag}_bn2", 0.03, mb),
                (f"{tag}_r2", 0.01, mb),
                (f"{tag}_c3", 0.25 * scale, mb), (f"{tag}_bn3", 0.03, mb),
            ], entry)
            add = b.node(f"{tag}_add", 0.01)
            b.edge(chain_end, add, mb)
            b.edge(entry, add, mb)       # residual skip
            relu = b.node(f"{tag}_relu", 0.01)
            b.edge(add, relu, mb)
            prev = relu
    b.chain([("avgpool", 0.04, 0.13), ("fc", 0.25, 0.008),
             ("output", 0.02, 0.004)], prev)
    return b.dag(deadline, pin_server)


_BUILDERS = {"alexnet": alexnet, "vgg19": vgg19, "googlenet": googlenet,
             "resnet101": resnet101}


def build(name: str, pin_server: int = 0, deadline: float = np.inf
          ) -> LayerDAG:
    return _BUILDERS[name](pin_server=pin_server, deadline=deadline)
