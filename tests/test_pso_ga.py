"""PSO-GA: convergence invariants, optimality on degenerate cases, and
the paper's comparative claims (beats/equals Greedy and GA)."""
import numpy as np
import pytest

from repro.core import (GAConfig, PSOGAConfig, SimProblem, greedy_offload,
                        heft_makespan, merge_dags, paper_environment,
                        pre_pso, run_ga, run_pso_ga, run_pso_linear,
                        sample_environment, simulate_np, zoo)
from repro.core.dag import LayerDAG

FAST = PSOGAConfig(pop_size=40, max_iters=150, stall_iters=40)
FAST_GA = GAConfig(pop_size=40, max_iters=150, stall_iters=40)


@pytest.fixture(scope="module")
def fig2():
    env = sample_environment()
    dag = LayerDAG(
        compute=np.array([1.1, 1.92, 2.35, 2.12]) * env.power[0],
        edges=np.array([[0, 1], [0, 2], [1, 3], [2, 3]]),
        edge_mb=np.array([1.0, 1.0, 0.5, 0.5]),
        app_id=np.zeros(4, np.int32), deadline=np.array([3.7]),
        pinned=np.array([0, -1, -1, -1], np.int32))
    return dag, env


def brute_force_best(dag, env):
    prob = SimProblem.build(dag, env)
    s = env.num_servers
    best_cost, best_x = np.inf, None
    import itertools
    for combo in itertools.product(range(s), repeat=dag.num_layers - 1):
        x = np.array((int(dag.pinned[0]),) + combo)
        r = simulate_np(prob, x, faithful=False)
        if bool(r.feasible) and float(r.total_cost) < best_cost:
            best_cost, best_x = float(r.total_cost), x
    return best_cost, best_x


def test_psoga_finds_global_optimum_fig2(fig2):
    """4 layers x 6 servers = brute-forceable: PSO-GA must hit it."""
    dag, env = fig2
    best_cost, _ = brute_force_best(dag, env)
    res = run_pso_ga(dag, env, PSOGAConfig(pop_size=60, max_iters=200,
                                           stall_iters=60), seed=0)
    assert res.feasible
    assert res.best_cost <= best_cost * 1.0 + 1e-9


def test_gbest_monotone(fig2):
    dag, env = fig2
    res = run_pso_ga(dag, env, PSOGAConfig(pop_size=20, max_iters=50),
                     seed=1, record_history=True)
    hist = res.history
    assert hist is not None
    assert np.all(np.diff(hist) <= 1e-12)   # non-increasing


def test_assignment_respects_pins(fig2):
    dag, env = fig2
    res = run_pso_ga(dag, env, FAST, seed=2)
    assert res.best_x[0] == dag.pinned[0]


def test_single_server_env_is_exact():
    env = sample_environment()
    dag = zoo.alexnet(pin_server=0, deadline=1e9)
    # restrict to one server by pinning everything
    one = LayerDAG(compute=dag.compute, edges=dag.edges,
                   edge_mb=dag.edge_mb, app_id=dag.app_id,
                   deadline=dag.deadline,
                   pinned=np.zeros(dag.num_layers, np.int32))
    res = run_pso_ga(one, env, FAST, seed=0)
    # everything on the free device: zero cost
    assert res.feasible and res.best_cost == 0.0


def test_psoga_beats_or_equals_greedy_alexnet():
    """Paper Fig. 7(a): PSO-GA <= Greedy at every deadline."""
    env = paper_environment()
    base = zoo.alexnet(pin_server=0)
    h, _ = heft_makespan(base, env)
    for r in (1.5, 3.0, 8.0):
        dag = base.with_deadline(np.array([r * h]))
        pso = run_pso_ga(dag, env, FAST, seed=0)
        grd = greedy_offload(dag, env)
        if grd.feasible:
            assert pso.feasible
            assert pso.best_cost <= grd.best_cost + 1e-9, (r, pso, grd)


def test_psoga_beats_or_equals_ga_googlenet():
    """Paper Fig. 7(c): PSO-GA <= GA (branching DAG)."""
    env = paper_environment()
    base = zoo.googlenet(pin_server=0)
    h, _ = heft_makespan(base, env)
    dag = base.with_deadline(np.array([3.0 * h]))
    pso = run_pso_ga(dag, env, FAST, seed=0)
    ga = run_ga(dag, env, FAST_GA, seed=0)
    assert pso.feasible
    if ga.feasible:
        assert pso.best_cost <= ga.best_cost * 1.05   # stochastic margin


def test_pre_pso_expansion_valid():
    env = paper_environment()
    base = zoo.googlenet(pin_server=0)
    h, _ = heft_makespan(base, env)
    dag = base.with_deadline(np.array([5.0 * h]))
    res = pre_pso(dag, env, FAST, seed=0)
    assert res.best_x.shape == (dag.num_layers,)
    assert res.best_x[0] == 0
    # expanded placement cost == re-simulated cost (consistency)
    prob = SimProblem.build(dag, env)
    r = simulate_np(prob, res.best_x, faithful=False)
    if res.feasible:
        np.testing.assert_allclose(res.best_cost, float(r.total_cost),
                                   rtol=1e-6)


def test_pso_linear_runs(fig2):
    dag, env = fig2
    res = run_pso_linear(dag, env, FAST, seed=0)
    assert res.best_x.shape == (4,)
    assert res.iterations >= 1


def test_loose_deadline_all_home_zero_cost():
    """Paper Fig. 8(b): with a loose enough deadline everything stays on
    the (free) end device -> zero system cost."""
    env = paper_environment()
    dag = zoo.alexnet(pin_server=0, deadline=1e9)
    res = run_pso_ga(dag, env, FAST, seed=0)
    assert res.feasible
    assert res.best_cost <= 1e-9
    assert np.all(res.best_x == 0)


def test_multi_dnn_problem():
    """Three DNNs on two devices scheduled jointly (Fig. 8 setting)."""
    env = paper_environment()
    dags = [zoo.alexnet(pin_server=i % 2) for i in range(3)]
    merged = merge_dags(dags)
    h, _ = heft_makespan(merged, env)
    merged = merged.with_deadline(np.full(3, 4.0 * h))
    res = run_pso_ga(merged, env, FAST, seed=0)
    assert res.feasible
    grd = greedy_offload(merged, env)
    if grd.feasible:
        assert res.best_cost <= grd.best_cost + 1e-9
