"""Always-on planning service benchmark (DESIGN.md §11, EXPERIMENTS.md
§Service): the fault-tolerance story in numbers.

  * time-to-plan   — p50/p99/max wall seconds per service round, clean
    vs chaos (the SLO the watchdog budgets against)
  * availability   — fraction of problem-rounds served a valid plan
    while the chaos harness injects solver crashes, NaN env snapshots,
    a mid-round node loss, and a simulated stall (bar: >= 99%)
  * fallback mix   — problem-rounds served per ladder rung
    (warm / burst / pinned / heft / greedy / reject)
  * deadline triage — p95 deadline-miss rate of the SAVABLE apps under
    a shared request stream, admission control on vs off: rejecting
    apps whose deadline even HEFT cannot meet keeps their requests out
    of the shared FCFS queues the admitted apps ride (DESIGN.md §10)
  * telemetry tax  — the same clean run with the unified telemetry
    layer off vs on (DESIGN.md §13); the registry snapshot of the
    instrumented arm is stamped into the JSON (bar: < 2% overhead)

Every run writes ``BENCH_service.json`` so the trajectory is tracked
across PRs.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.core import (ChaosConfig, PlanCacheConfig, PSOGAConfig,
                        ReplanConfig, ServiceConfig, SimProblem,
                        Telemetry, TrafficConfig, heft_makespan,
                        merge_dags, paper_environment, run_service,
                        runner_cache_stats, sample_trace, traffic_replay,
                        zero_drift_trace, zoo)

from .bench_online import _json_safe, make_fleet
from .common import bench_metadata, print_csv

#: CPU-friendly service solver (the warm rung)
SERVICE_CFG = PSOGAConfig(pop_size=32, max_iters=120, stall_iters=30)


def run_availability_cell(kind: str, n: int, rounds: int, seed: int,
                          chaos: bool):
    """One service run, clean or under the full chaos suite."""
    env = paper_environment()
    dags = make_fleet(n, env)
    trace = sample_trace(kind, env, rounds=rounds, seed=seed)
    ccfg = None
    if chaos:
        last = rounds - 1
        ccfg = ChaosConfig(
            crash_rounds=(min(2, last),), p_crash=0.1, seed=seed,
            nan_env_rounds=(min(3, last),),
            stall_rounds=(min(5, last),), stall_s=20.0,
            mid_round_down={min(6, last): env.num_servers - 1})
    cfg = ServiceConfig(replan=ReplanConfig(pso=SERVICE_CFG),
                        retries=2, treat_stalls_as_failures=True,
                        straggler_warmup=2, chaos=ccfg)
    rep = run_service(dags, trace, cfg, seed=seed,
                      sleeper=lambda s: None)
    s = rep.summary()
    row = {
        "cell": "chaos" if chaos else "clean", "kind": kind,
        "n_problems": n, "rounds": rounds,
        "availability": s["availability"],
        "ttp_p50_s": s["time_to_plan_s"]["p50"],
        "ttp_p99_s": s["time_to_plan_s"]["p99"],
        "ttp_max_s": s["time_to_plan_s"]["max"],
    }
    for rung, cnt in s["fallback_counts"].items():
        row[f"rung_{rung}"] = cnt
    return row, s


def _savable_miss_p95(prob, plan, ev, savable, faithful):
    """p95 (across eval seeds) of the savable apps' deadline-miss rate."""
    res = traffic_replay(prob, plan, ev, faithful=faithful)
    n_apps = savable.shape[0]
    miss = np.asarray(res.miss)[:, :n_apps, :][:, savable, :]
    valid = np.isfinite(np.asarray(ev, float))[:, savable, :]
    rates = miss.sum(axis=(1, 2)) / np.maximum(valid.sum(axis=(1, 2)), 1)
    return float(np.percentile(rates, 95))


def run_triage_cell(rounds: int, seed: int):
    """Admission control on vs off, same fleet, same request stream.

    Each problem merges a savable app (deadline 1.5x HEFT) with a
    doomed one (deadline 0.3x HEFT completion — unmeetable even by a
    makespan-minimizing schedule). Without triage the doomed app's
    requests sit in the shared FCFS queues ahead of savable work."""
    env = paper_environment()
    tc = TrafficConfig(rate=1.0, horizon=20.0, max_requests=6,
                       mc_solver=2, mc_eval=12)
    dags, savable_masks = [], []
    for i, (a, b) in enumerate((("alexnet", "googlenet"),
                                ("googlenet", "alexnet"))):
        parts = []
        for j, (net, ratio) in enumerate(((a, 1.5), (b, 0.3))):
            d = zoo.build(net, pin_server=(2 * i + j) % 10)
            h, _ = heft_makespan(d, env)
            parts.append(d.with_deadline(np.array([ratio * h])))
        dags.append(merge_dags(parts))
        savable_masks.append(np.array([True, False]))
    trace = zero_drift_trace(env, rounds=rounds)
    rcfg = ReplanConfig(pso=SERVICE_CFG, traffic=tc)

    out = {}
    for arm, margin in (("no_triage", 0.0), ("triage", 1.0)):
        rep = run_service(dags, trace, ServiceConfig(replan=rcfg,
                                                     triage_margin=margin),
                          seed=seed)
        p95s = []
        for i, (dag, mask) in enumerate(zip(dags, savable_masks)):
            prob = SimProblem.build(dag, env)
            ev = np.asarray(tc.eval_arrivals(dag.num_apps,
                                             seed=seed + 31 * i), float)
            if margin > 0.0:
                # rejected apps never enter the system: mask their
                # eval arrivals exactly like the service masks the
                # solver's (DESIGN.md §11)
                ev = ev.copy()
                ev[:, ~mask, :] = np.inf
            p95s.append(_savable_miss_p95(prob, rep.plans[i], ev, mask,
                                          SERVICE_CFG.faithful_sim))
        out[arm] = {
            "savable_miss_p95": float(np.mean(p95s)),
            "rejected_apps": rep.counters["rejected_apps"],
            "availability": rep.availability(),
        }
    row = {
        "cell": "triage", "kind": "zero-drift", "n_problems": len(dags),
        "rounds": rounds,
        "no_triage_miss_p95": out["no_triage"]["savable_miss_p95"],
        "triage_miss_p95": out["triage"]["savable_miss_p95"],
        "rejected_apps": out["triage"]["rejected_apps"],
    }
    return row, out


def run_cache_cell(n: int, rounds: int, seed: int, arms):
    """Plan-cache A/B on a repeat-scenario trace (DESIGN.md §11 phase
    2): the same epoch recurs every round, so with the cache on every
    round after the first is served through the replay-exact gate
    instead of a warm solve. ``arms`` runs in the given order — put
    ``off`` first so both arms see a hot compiled-runner cache and the
    delta is pure solve-vs-lookup, not compile time."""
    env = paper_environment()
    dags = make_fleet(n, env)
    trace = zero_drift_trace(env, rounds=rounds)
    rows, out = [], {}
    for arm in arms:
        cfg = ServiceConfig(
            replan=ReplanConfig(pso=SERVICE_CFG),
            plan_cache=PlanCacheConfig() if arm == "on" else None)
        rep = run_service(dags, trace, cfg, seed=seed)
        s = rep.summary()
        hit_rate = 0.0
        if rep.cache_stats is not None:
            cs = rep.cache_stats
            n_look = cs["hits"] + cs["misses"]
            hit_rate = cs["hits"] / n_look if n_look else 0.0
        row = {
            "cell": f"cache_{arm}", "kind": "repeat-scenario",
            "n_problems": n, "rounds": rounds,
            "availability": s["availability"],
            "ttp_p50_s": s["time_to_plan_s"]["p50"],
            "ttp_p99_s": s["time_to_plan_s"]["p99"],
            "ttp_max_s": s["time_to_plan_s"]["max"],
            "cache_hit_rate": hit_rate,
        }
        rows.append(row)
        out[arm] = s
    return rows, out


def run_telemetry_cell(n: int, rounds: int, seed: int):
    """Telemetry overhead A/B (DESIGN.md §13): the same clean service
    run with the registry + tracer off vs on. Both arms run after a
    warm-up pass so compile time cancels; the reported fraction is the
    observability tax the off-parity invariant bounds."""
    env = paper_environment()
    dags = make_fleet(n, env)
    trace = sample_trace("wifi-fade", env, rounds=rounds, seed=seed)
    cfg = ServiceConfig(replan=ReplanConfig(pso=SERVICE_CFG))
    run_service(dags, trace, cfg, seed=seed)      # warm the jit caches
    t0 = time.perf_counter()
    off_rep = run_service(dags, trace, cfg, seed=seed)
    off_s = time.perf_counter() - t0
    tel = Telemetry()
    t0 = time.perf_counter()
    on_rep = run_service(dags, trace, cfg, seed=seed, telemetry=tel)
    on_s = time.perf_counter() - t0
    assert on_rep.counters == off_rep.counters    # off-parity invariant
    overhead = on_s / off_s - 1.0 if off_s > 0 else 0.0
    row = {
        "cell": "telemetry", "kind": "wifi-fade", "n_problems": n,
        "rounds": rounds, "wall_off_s": off_s, "wall_on_s": on_s,
        "overhead_frac": overhead,
        "trace_events": len(tel.tracer.events()),
    }
    return row, {"overhead_frac": overhead,
                 "registry": tel.registry.snapshot()}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=6,
                    help="fleet size for the availability cells")
    ap.add_argument("--rounds", type=int, default=8,
                    help="drift events per service run")
    ap.add_argument("--kind", default="node-loss",
                    help="drift family for the chaos cell")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--plan-cache", default="both",
                    choices=("on", "off", "both"),
                    help="which plan-cache arms to run for the "
                         "repeat-scenario A/B cell")
    ap.add_argument("--cache-rounds", type=int, default=32,
                    help="rounds in the repeat-scenario trace (enough "
                         "that one cold miss falls outside the p99)")
    ap.add_argument("--json", default="BENCH_service.json",
                    help="machine-readable results ('' to disable)")
    args = ap.parse_args()

    rows, details = [], {}
    clean_row, clean = run_availability_cell(
        "wifi-fade", args.n, args.rounds, args.seed, chaos=False)
    rows.append(clean_row)
    details["clean"] = clean
    print(f"# clean: availability {clean_row['availability']:.4f}, "
          f"time-to-plan p50 {clean_row['ttp_p50_s']:.2f}s "
          f"p99 {clean_row['ttp_p99_s']:.2f}s", flush=True)

    chaos_row, chaos = run_availability_cell(
        args.kind, args.n, args.rounds, args.seed, chaos=True)
    rows.append(chaos_row)
    details["chaos"] = chaos
    ok = chaos_row["availability"] >= 0.99
    print(f"# chaos ({args.kind}): availability "
          f"{chaos_row['availability']:.4f} (bar >= 0.99) "
          f"-> {'PASS' if ok else 'MISS'}, fallbacks "
          f"{chaos['fallback_counts']}, counters {chaos['counters']}",
          flush=True)

    arms = {"both": ("off", "on"), "on": ("on",),
            "off": ("off",)}[args.plan_cache]
    cache_rows, cache_out = run_cache_cell(
        args.n, args.cache_rounds, args.seed, arms)
    rows.extend(cache_rows)
    details["cache"] = cache_out
    by_arm = {r["cell"]: r for r in cache_rows}
    if "cache_on" in by_arm and "cache_off" in by_arm:
        on, off = by_arm["cache_on"], by_arm["cache_off"]
        ok = on["ttp_p99_s"] < off["ttp_p99_s"]
        print(f"# cache A/B: hit rate {on['cache_hit_rate']:.2f}, "
              f"time-to-plan p99 {off['ttp_p99_s']:.3f}s -> "
              f"{on['ttp_p99_s']:.3f}s (bar: on < off) "
              f"-> {'PASS' if ok else 'MISS'}", flush=True)
    else:
        arm = cache_rows[0]
        print(f"# cache {arms[0]}: hit rate "
              f"{arm['cache_hit_rate']:.2f}, time-to-plan p99 "
              f"{arm['ttp_p99_s']:.3f}s", flush=True)

    triage_row, triage = run_triage_cell(max(4, args.rounds // 2),
                                         args.seed)
    rows.append(triage_row)
    details["triage"] = triage
    print(f"# triage: savable-app miss p95 "
          f"{triage_row['no_triage_miss_p95']:.3f} -> "
          f"{triage_row['triage_miss_p95']:.3f} with admission control "
          f"({triage_row['rejected_apps']} app-rounds rejected)",
          flush=True)

    tel_row, tel_out = run_telemetry_cell(args.n, args.rounds, args.seed)
    rows.append(tel_row)
    details["telemetry"] = tel_out
    print(f"# telemetry: overhead {tel_row['overhead_frac'] * 100:+.2f}% "
          f"({tel_row['wall_off_s']:.2f}s -> {tel_row['wall_on_s']:.2f}s, "
          f"{tel_row['trace_events']} trace events) (bar < 2%)",
          flush=True)

    avail_rows = [clean_row, chaos_row]
    print_csv(avail_rows, ["cell", "kind", "n_problems", "rounds",
                           "availability", "ttp_p50_s", "ttp_p99_s",
                           "ttp_max_s"]
              + [f"rung_{r}" for r in sorted(
                  k[5:] for k in clean_row if k.startswith("rung_"))])
    print_csv(cache_rows, ["cell", "kind", "n_problems", "rounds",
                           "availability", "ttp_p50_s", "ttp_p99_s",
                           "ttp_max_s", "cache_hit_rate"])
    print_csv([triage_row], ["cell", "kind", "n_problems", "rounds",
                             "no_triage_miss_p95", "triage_miss_p95",
                             "rejected_apps"])
    print_csv([tel_row], ["cell", "kind", "n_problems", "rounds",
                          "wall_off_s", "wall_on_s", "overhead_frac",
                          "trace_events"])
    if args.json:
        payload = {
            "bench": "bench_service",
            "meta": bench_metadata(seeds=[args.seed]),
            "device": jax.devices()[0].platform,
            "pso": {"pop_size": SERVICE_CFG.pop_size,
                    "max_iters": SERVICE_CFG.max_iters,
                    "stall_iters": SERVICE_CFG.stall_iters},
            "runner_cache": runner_cache_stats(),
            "cells": rows,
            "details": details,
        }
        with open(args.json, "w") as f:
            json.dump(_json_safe(payload), f, indent=2, allow_nan=False)
        print(f"# wrote {args.json}")


if __name__ == "__main__":
    main()
