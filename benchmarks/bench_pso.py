"""PSO-GA engine throughput: jitted swarm-iterations/second and particle
evaluations/second vs problem size — the performance of the paper's
algorithm as a vmapped/jitted JAX program (the reproduction's own compute
layer; the paper ran seconds-per-iteration on a Pentium G3250).

Also benchmarks fleet planning: the sequential per-problem loop (one
re-traced ``run_pso_ga`` per problem) vs the batched fleet solver
(``run_pso_ga_batch``, DESIGN.md §4) at N ∈ {1, 8, 64} heterogeneous
problems (EXPERIMENTS.md §Perf).

``--backend {scan,pallas}`` selects the swarm-fitness backend
(DESIGN.md §8; pallas runs in interpret mode off-TPU, so its CPU numbers
measure correctness plumbing, not kernel speed). Every run writes a
machine-readable ``BENCH_pso.json`` (per-net µs/iter, fleet speedups) so
the perf trajectory is tracked across PRs (EXPERIMENTS.md §Perf)."""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import numpy as np

from repro.core import (PSOGAConfig, heft_makespan, paper_environment,
                        run_pso_ga, run_pso_ga_batch, zoo)
from repro.core.pso_ga import _SwarmState, _make_step, init_swarm
from repro.core.simulator import SimProblem

from .common import bench_metadata, print_csv

#: moderate budget so the N=64 fleet stays CPU-friendly
FLEET_CFG = PSOGAConfig(pop_size=32, max_iters=80, stall_iters=25)


def make_fleet(n: int, env=None):
    """N heterogeneous problems: mixed nets, pins, and deadline ratios."""
    env = env or paper_environment()
    problems = []
    for i in range(n):
        net = ("alexnet", "vgg19", "googlenet")[i % 3]
        dag = zoo.build(net, pin_server=i % 10)
        h, _ = heft_makespan(dag, env)
        ratio = (1.5, 3.0, 5.0, 8.0)[i % 4]
        problems.append((dag.with_deadline(np.array([ratio * h])), env))
    return problems


def bench_fleet(n: int, cfg: PSOGAConfig = FLEET_CFG):
    problems = make_fleet(n)
    t0 = time.perf_counter()
    seq = [run_pso_ga(dag, env, cfg, seed=i)
           for i, (dag, env) in enumerate(problems)]
    t_seq = time.perf_counter() - t0
    t0 = time.perf_counter()
    bat = run_pso_ga_batch(problems, cfg, seed=list(range(n)))
    t_batch = time.perf_counter() - t0
    t0 = time.perf_counter()                 # second call hits the compiled cache
    run_pso_ga_batch(problems, cfg, seed=list(range(n)))
    t_cached = time.perf_counter() - t0
    match = sum(a.best_fitness == b.best_fitness
                for a, b in zip(seq, bat))
    return {
        "n_problems": n,
        "seq_s": t_seq,
        "batch_s": t_batch,
        "batch_cached_s": t_cached,
        "speedup": t_seq / t_batch,
        "speedup_cached": t_seq / t_cached,
        "fitness_match": f"{match}/{n}",
    }


def bench_net(net: str, pop: int = 100, iters: int = 50,
              backend: str = "scan"):
    env = paper_environment()
    dag = zoo.build(net, deadline=1e9)
    prob = SimProblem.build(dag, env)
    cfg = PSOGAConfig(pop_size=pop, max_iters=iters,
                      fitness_backend=backend)
    step, fit = _make_step(prob, cfg)
    key = jax.random.PRNGKey(0)
    X0 = init_swarm(key, prob, cfg)
    f0 = fit(X0)
    state = _SwarmState(key=key, X=X0, pbest_x=X0, pbest_f=f0,
                        gbest_x=X0[0], gbest_f=f0[0],
                        it=jax.numpy.asarray(0),
                        stall=jax.numpy.asarray(0))
    jstep = jax.jit(step)
    state = jstep(state)                       # compile + warmup
    jax.block_until_ready(state.X)
    t0 = time.perf_counter()
    for _ in range(iters):
        state = jstep(state)
    jax.block_until_ready(state.X)
    dt = (time.perf_counter() - t0) / iters
    return {
        "net": net, "layers": dag.num_layers, "pop": pop,
        "backend": backend,
        "us_per_iter": dt * 1e6,
        "evals_per_s": pop / dt,
        "layersteps_per_s": pop * dag.num_layers / dt,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--pop", type=int, default=100)
    ap.add_argument("--backend", default="scan",
                    choices=("scan", "pallas"),
                    help="swarm-fitness backend (DESIGN.md §8); pallas "
                         "runs in interpret mode off-TPU")
    ap.add_argument("--json", default="BENCH_pso.json",
                    help="write machine-readable results here "
                         "('' to disable)")
    ap.add_argument("--skip-fleet", action="store_true",
                    help="skip the sequential-vs-batched fleet benchmark")
    ap.add_argument("--fleet-sizes", type=int, nargs="*", default=[1, 8, 64])
    args = ap.parse_args()
    rows = [bench_net(n, pop=args.pop, backend=args.backend)
            for n in ("alexnet", "vgg19", "googlenet", "resnet101")]
    print_csv(rows, ["net", "layers", "pop", "backend", "us_per_iter",
                     "evals_per_s", "layersteps_per_s"])
    fleet_rows = []
    if not args.skip_fleet:
        fleet_cfg = dataclasses.replace(FLEET_CFG,
                                        fitness_backend=args.backend)
        for n in args.fleet_sizes:
            row = bench_fleet(n, fleet_cfg)
            print(f"# fleet N={n}: seq {row['seq_s']:.2f}s, "
                  f"batch {row['batch_s']:.2f}s "
                  f"({row['speedup']:.1f}x; cached "
                  f"{row['speedup_cached']:.1f}x), "
                  f"fitness match {row['fitness_match']}", flush=True)
            fleet_rows.append(row)
        print_csv(fleet_rows, ["n_problems", "seq_s", "batch_s",
                               "batch_cached_s", "speedup",
                               "speedup_cached", "fitness_match"])
    if args.json:
        payload = {
            "bench": "bench_pso",
            "meta": bench_metadata(seeds=[0]),
            "backend": args.backend,
            "pop": args.pop,
            "device": jax.devices()[0].platform,
            "nets": rows,
            "fleet": fleet_rows,
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"# wrote {args.json}")


if __name__ == "__main__":
    main()
