"""Optional-hypothesis shim.

The property-based tests use ``hypothesis`` when it is installed; in
environments without it the suite must still collect and run (only the
property-based tests skip — everything else is unaffected). Test modules
import ``given`` / ``st`` / ``assume`` from here instead of from
``hypothesis`` directly.
"""
import pytest

try:
    from hypothesis import assume, given, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        return pytest.mark.skip(reason="hypothesis not installed")

    def assume(_condition):
        return True

    class _AnyStrategy:
        """Stands in for ``strategies``: any attribute/call returns itself,
        so module-level ``@given(x=st.integers(0, 10))`` still evaluates."""

        def __getattr__(self, _name):
            return self

        def __call__(self, *_args, **_kwargs):
            return self

    st = _AnyStrategy()

__all__ = ["HAVE_HYPOTHESIS", "assume", "given", "st"]
