"""Launcher: production mesh, step builders, dry-run, trainer, server."""
from .mesh import data_axes_of, make_production_mesh, make_test_mesh

__all__ = ["make_production_mesh", "make_test_mesh", "data_axes_of"]
