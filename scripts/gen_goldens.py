#!/usr/bin/env python
"""Regenerate tests/golden_costs.json — the seeded end-to-end PSO-GA
costs pinned by tests/test_golden_costs.py.

Run after any INTENDED fitness/simulator/solver change:

    PYTHONPATH=src python scripts/gen_goldens.py

then review the diff: every changed number is a behaviour change the PR
must justify. The goldens catch silent fitness drift that the
backend-vs-backend parity tests cannot see (both backends drifting
together looks like parity).
"""
from __future__ import annotations

import json
from pathlib import Path

import numpy as np

from repro.core import (PSOGAConfig, heft_makespan, paper_environment,
                        run_pso_ga, sample_arrivals, zoo)

OUT = Path(__file__).resolve().parent.parent / "tests" / "golden_costs.json"

#: small-but-nontrivial budget: every case converges via the stall rule
GOLDEN = dict(pop_size=16, max_iters=30, stall_iters=12)
SEED = 42
DEADLINE_RATIO = 2.0
#: queue-aware goldens (DESIGN.md §10): 2 nets × 2 arrival scenarios,
#: fixed seeds — catches traffic-fitness drift the same way the plan
#: goldens catch plan-fitness drift.
TRAFFIC_NETS = ("alexnet", "googlenet")
TRAFFIC_SCENARIOS = ("bursty", "flash-crowd")
TRAFFIC_ARR = dict(rate=0.4, horizon=20.0, max_requests=5, n_seeds=2)
#: generous budget so golden keys are feasible $ values (a tight anchor:
#: rtol on ~1e-2 is far more sensitive than on the 1e4 infeasible offset)
TRAFFIC_MISS_BUDGET = 0.5


def generate() -> dict:
    env = paper_environment()
    out = {
        "_config": {**GOLDEN, "seed": SEED,
                    "deadline_ratio": DEADLINE_RATIO,
                    "env": "paper_environment"},
    }
    for net in zoo.NAMES:
        base = zoo.build(net, pin_server=0)
        h, _ = heft_makespan(base, env)
        dag = base.with_deadline(np.array([DEADLINE_RATIO * h]))
        for faithful in (False, True):
            for backend in ("scan", "pallas"):
                cfg = PSOGAConfig(**GOLDEN, faithful_sim=faithful,
                                  fitness_backend=backend)
                res = run_pso_ga(dag, env, cfg, seed=SEED)
                key = f"{net}|faithful={faithful}|{backend}"
                out[key] = {
                    "best_fitness": float(res.best_fitness),
                    "best_cost": float(res.best_cost),
                    "feasible": bool(res.feasible),
                    # informational: not asserted (hardware-dependent
                    # float rounding may legitimately shift a stall exit)
                    "iterations": int(res.iterations),
                }
                print(f"{key}: cost={res.best_cost:.8g} "
                      f"iters={res.iterations}")
    out["_traffic_config"] = {**GOLDEN, "seed": SEED,
                              "deadline_ratio": DEADLINE_RATIO,
                              "arrivals": TRAFFIC_ARR,
                              "miss_budget": TRAFFIC_MISS_BUDGET,
                              "env": "paper_environment"}
    for net in TRAFFIC_NETS:
        base = zoo.build(net, pin_server=0)
        h, _ = heft_makespan(base, env)
        dag = base.with_deadline(np.array([DEADLINE_RATIO * h]))
        for kind in TRAFFIC_SCENARIOS:
            arr = sample_arrivals(kind, 1, seed=SEED, **TRAFFIC_ARR).t
            for backend in ("scan", "pallas"):
                cfg = PSOGAConfig(**GOLDEN,
                                  miss_budget=TRAFFIC_MISS_BUDGET,
                                  fitness_backend=backend)
                res = run_pso_ga(dag, env, cfg, seed=SEED, arrivals=arr)
                # scan keys keep their pre-kernel spelling (no |scan
                # suffix) so the stored history stays byte-comparable
                key = f"{net}|traffic={kind}" if backend == "scan" \
                    else f"{net}|traffic={kind}|pallas"
                out[key] = {
                    "best_fitness": float(res.best_fitness),
                    "best_cost": float(res.best_cost),
                    "feasible": bool(res.feasible),
                    "iterations": int(res.iterations),
                }
                print(f"{key}: key={res.best_fitness:.8g} "
                      f"iters={res.iterations}")
    # infeasible-branch anchor for the kernel path: an unattainable
    # deadline + zero miss budget force the MISS_PENALTY key (Eq. 16
    # analogue) — pinning it catches drift in the penalty arithmetic
    # that the feasible goldens never exercise.
    base = zoo.build("alexnet", pin_server=0)
    h, _ = heft_makespan(base, env)
    dag = base.with_deadline(np.array([0.5 * h]))
    arr = sample_arrivals("flash-crowd", 1, seed=SEED, **TRAFFIC_ARR).t
    cfg = PSOGAConfig(**GOLDEN, miss_budget=0.0, fitness_backend="pallas")
    res = run_pso_ga(dag, env, cfg, seed=SEED, arrivals=arr)
    key = "alexnet|traffic=flash-crowd|pallas|infeasible"
    out[key] = {
        "best_fitness": float(res.best_fitness),
        "best_cost": float(res.best_cost),
        "feasible": bool(res.feasible),
        "iterations": int(res.iterations),
    }
    print(f"{key}: key={res.best_fitness:.8g} iters={res.iterations}")
    return out


if __name__ == "__main__":
    OUT.write_text(json.dumps(generate(), indent=1) + "\n")
    print(f"wrote {OUT}")
