"""Competitive algorithms (paper §V-B) + HEFT (used to set deadlines).

* ``greedy_offload``   — offload each layer (topological order) to the
  cheapest server that keeps the *partial* schedule within its deadline;
  fall back to next-cheapest (paper's modified Greedy [24]).
* ``run_ga``           — genetic algorithm with tournament selection,
  two-point crossover and uniform mutation over the same encoding and the
  same 3-case fitness (paper's modified GA [18]).
* ``run_pso_linear``   — PSO with the same GA operators but the *linear*
  inertia schedule of Eq. 21 (the non-adaptive ablation; "PSO" in Fig. 8d).
* ``heft_makespan``    — HEFT [35]; the paper derives every deadline as
  D_i = r_i · H(G_i) with r ∈ {1.2, 1.5, 3, 5, 8} (Eq. 24).
* ``pre_pso``          — preprocessing (Alg. 1) + PSO-GA, expanded back to
  per-original-layer placement ("prePSO").
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .dag import LayerDAG, preprocess, topological_order
from .environment import Environment
from .fitness import INFEASIBLE_OFFSET, make_swarm_fitness
from .pso_ga import PSOGAConfig, PSOGAResult, _SwarmState, \
    init_swarm, run_pso_ga
from .simulator import SimProblem, build_simulator, pad_problem, simulate_np

__all__ = ["greedy_offload", "run_ga", "run_pso_linear", "heft_makespan",
           "pre_pso", "GAConfig"]


# ---------------------------------------------------------------------------
# Greedy
# ---------------------------------------------------------------------------

def greedy_offload(dag: LayerDAG, env: Environment, faithful: bool = False
                   ) -> PSOGAResult:
    """Cheapest-server-first greedy (paper §V-B / Alg. 2 line 15).

    Incremental O(p · S · deg): per layer, candidate servers are tried in
    ascending rental rate (ties: descending power, then index); the first
    whose schedule keeps THIS layer's end time within its app deadline
    (exactly Alg. 2's per-layer check) wins. Outgoing-transfer busy time
    is charged to the parent's server when the child is placed (the
    information only exists then — same accounting Alg. 2 line 21 does
    once placements are known).
    """
    prob = SimProblem.build(dag, env)
    order = prob.order
    p, s = prob.num_layers, prob.num_servers
    pref = np.lexsort((np.arange(s), -env.power, env.cost_per_sec))
    x = np.full(p, -1, np.int64)
    lease = np.zeros(s)
    end = np.zeros(p)
    trans_cost = 0.0
    feasible = True

    for j in order:
        dl = prob.deadline[prob.app_id[j]]
        pars = prob.parent_idx[j]
        pmask = pars >= 0
        pidx = pars[pmask]
        pmb = prob.parent_mb[j][pmask]
        cands = ([int(prob.pinned[j])] if prob.pinned[j] >= 0 else
                 [int(c) for c in pref])
        placed_srv, placed_end = -1, np.inf
        for srv in cands:
            if pidx.size:
                psrv = x[pidx]
                if np.any(~prob.link_ok[psrv, srv] & (psrv != srv)):
                    continue
                tt = pmb * prob.inv_bw[psrv, srv]
                if faithful:
                    start = lease[srv] + tt.max()
                else:
                    start = max(lease[srv], float((end[pidx] + tt).max()))
            else:
                start = lease[srv]
            t_end = start + prob.compute[j] / prob.power[srv]
            if t_end <= dl or srv == cands[-1]:
                ok_here = t_end <= dl
                placed_srv, placed_end = srv, t_end
                if not ok_here:
                    feasible = False
                break
        x[j] = placed_srv
        end[j] = placed_end
        # this layer occupies its server; charge incoming-transfer wait to
        # the chosen server per the selected fidelity mode
        lease[placed_srv] = placed_end if not faithful else \
            lease[placed_srv] + prob.compute[j] / prob.power[placed_srv]
        # charge outgoing transfers of each parent now that the link is
        # known (Alg. 2 line 21's `transfer` term) + transmission cost
        if pidx.size:
            psrv = x[pidx]
            tt = pmb * prob.inv_bw[psrv, placed_srv]
            for k, pj in enumerate(pidx):
                if psrv[k] != placed_srv:
                    lease[psrv[k]] += tt[k]
            trans_cost += float(
                np.sum(prob.tran_cost[psrv, placed_srv] * pmb))

    res = simulate_np(prob, x, faithful=faithful)
    ok = bool(res.feasible) and feasible
    return PSOGAResult(best_x=x.astype(np.int32),
                       best_fitness=float(res.total_cost) if ok
                       else float(INFEASIBLE_OFFSET + res.app_completion.sum()),
                       best_cost=float(res.total_cost) if ok else float("inf"),
                       feasible=ok, iterations=1, history=None)


# ---------------------------------------------------------------------------
# GA
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GAConfig:
    pop_size: int = 100
    max_iters: int = 1000
    stall_iters: int = 50
    tournament: int = 3
    p_crossover: float = 0.9
    p_mutation: float = 0.02          # per-gene
    elite: int = 2
    faithful_sim: bool = False        # match PSOGAConfig (paper-consistent)
    fitness_backend: str = "scan"     # scan | pallas | auto (DESIGN.md §8)
    miss_budget: float = 0.05         # p95 miss budget under traffic
    #   (DESIGN.md §10; consulted when run_ga gets ``arrivals``)


def run_ga(dag: LayerDAG, env: Environment, cfg: GAConfig = GAConfig(),
           seed: int = 0,
           arrivals: Optional[np.ndarray] = None) -> PSOGAResult:
    """Paper's modified GA; ``arrivals`` switches its fitness to the
    queue-aware traffic key (DESIGN.md §10) so the baseline competes
    with PSO-GA under the same request stream."""
    prob = SimProblem.build(dag, env)
    sim = build_simulator(prob, faithful=cfg.faithful_sim)
    fit = make_swarm_fitness(pad_problem(prob), cfg.faithful_sim,
                             cfg.fitness_backend,
                             arrivals=None if arrivals is None
                             else jnp.asarray(arrivals),
                             miss_budget=cfg.miss_budget)
    pinned = jnp.asarray(prob.pinned)
    p, s, P = prob.num_layers, prob.num_servers, cfg.pop_size

    def clamp(X):
        return jnp.where(pinned[None, :] >= 0, pinned[None, :], X)

    key = jax.random.PRNGKey(seed)
    key, k0 = jax.random.split(key)
    X = clamp(jax.random.randint(k0, (P, p), 0, s, dtype=jnp.int32))
    f = fit(X)

    def step(state):
        key, X, f, best_f, stall, it = state
        key, kt, kxp, kseg, kmu, kmuv = jax.random.split(key, 6)
        # tournament selection (2 parents per offspring)
        cand = jax.random.randint(kt, (P, 2, cfg.tournament), 0, P)
        cf = f[cand]                                    # (P,2,T)
        parents = jnp.take_along_axis(
            cand, jnp.argmin(cf, axis=-1)[..., None], axis=-1)[..., 0]
        pa, pb = X[parents[:, 0]], X[parents[:, 1]]
        # two-point crossover
        do_x = jax.random.uniform(kxp, (P,)) < cfg.p_crossover
        seg = jax.random.randint(kseg, (P, 2), 0, p)
        lo = jnp.min(seg, axis=1)[:, None]
        hi = jnp.max(seg, axis=1)[:, None]
        in_seg = (jnp.arange(p)[None, :] >= lo) & (jnp.arange(p)[None, :] <= hi)
        child = jnp.where(in_seg & do_x[:, None], pb, pa)
        # uniform mutation
        mu = jax.random.uniform(kmu, (P, p)) < cfg.p_mutation
        rand_vals = jax.random.randint(kmuv, (P, p), 0, s, dtype=jnp.int32)
        child = clamp(jnp.where(mu, rand_vals, child))
        cf_new = fit(child)
        # elitism: keep `elite` best of previous generation
        elite_idx = jnp.argsort(f)[: cfg.elite]
        child = child.at[: cfg.elite].set(X[elite_idx])
        cf_new = cf_new.at[: cfg.elite].set(f[elite_idx])
        new_best = jnp.min(cf_new)
        improved = new_best < best_f
        stall = jnp.where(improved, 0, stall + 1)
        best_f = jnp.minimum(best_f, new_best)
        return (key, child, cf_new, best_f, stall, it + 1)

    def cond(state):
        _, _, _, _, stall, it = state
        return (it < cfg.max_iters) & (stall < cfg.stall_iters)

    state = (key, X, f, jnp.min(f), jnp.asarray(0), jnp.asarray(0))
    key, X, f, best_f, stall, it = jax.lax.while_loop(cond, step, state)
    i = int(jnp.argmin(f))
    res = sim(X[i])
    ok = bool(res.feasible)
    return PSOGAResult(best_x=np.asarray(X[i]), best_fitness=float(f[i]),
                       best_cost=float(res.total_cost) if ok else float("inf"),
                       feasible=ok, iterations=int(it), history=None)


# ---------------------------------------------------------------------------
# PSO with linear inertia (Eq. 21) — the non-adaptive ablation
# ---------------------------------------------------------------------------

def run_pso_linear(dag: LayerDAG, env: Environment,
                   cfg: PSOGAConfig = PSOGAConfig(), seed: int = 0
                   ) -> PSOGAResult:
    """Same operators as PSO-GA but w follows Eq. 21 (linear decay)."""
    prob = SimProblem.build(dag, env)
    sim = build_simulator(prob, faithful=cfg.faithful_sim)
    fit = make_swarm_fitness(pad_problem(prob), cfg.faithful_sim,
                             cfg.fitness_backend)
    pinned = jnp.asarray(prob.pinned)
    p, s, P = prob.num_layers, prob.num_servers, cfg.pop_size

    def clamp(X):
        return jnp.where(pinned[None, :] >= 0, pinned[None, :], X)

    def step(state: _SwarmState) -> _SwarmState:
        key, kmu, kmu_pos, kmu_val, kc1, kx1, kc2, kx2 = jax.random.split(
            state.key, 8)
        t = state.it.astype(jnp.float32) / cfg.max_iters
        w = cfg.w_max - (cfg.w_max - cfg.w_min) * t        # Eq. 21
        c1 = cfg.c1_start + (cfg.c1_end - cfg.c1_start) * t
        c2 = cfg.c2_start + (cfg.c2_end - cfg.c2_start) * t
        do_mu = jax.random.uniform(kmu, (P,)) < w
        pos = jax.random.randint(kmu_pos, (P,), 0, p)
        val = jax.random.randint(kmu_val, (P,), 0, s, dtype=jnp.int32)
        A = jnp.where(
            (jnp.arange(p)[None, :] == pos[:, None]) & do_mu[:, None],
            val[:, None], state.X)
        do_c1 = jax.random.uniform(kc1, (P,)) < c1
        seg1 = jax.random.randint(kx1, (P, 2), 0, p)
        lo1, hi1 = (jnp.min(seg1, 1)[:, None], jnp.max(seg1, 1)[:, None])
        m1 = (jnp.arange(p)[None, :] >= lo1) & (jnp.arange(p)[None, :] <= hi1)
        B = jnp.where(m1 & do_c1[:, None], state.pbest_x, A)
        do_c2 = jax.random.uniform(kc2, (P,)) < c2
        seg2 = jax.random.randint(kx2, (P, 2), 0, p)
        lo2, hi2 = (jnp.min(seg2, 1)[:, None], jnp.max(seg2, 1)[:, None])
        m2 = (jnp.arange(p)[None, :] >= lo2) & (jnp.arange(p)[None, :] <= hi2)
        C = jnp.where(m2 & do_c2[:, None], state.gbest_x[None, :], B)
        X = clamp(C)
        f = fit(X)
        improved = f < state.pbest_f
        pbest_x = jnp.where(improved[:, None], X, state.pbest_x)
        pbest_f = jnp.where(improved, f, state.pbest_f)
        i_best = jnp.argmin(pbest_f)
        better = pbest_f[i_best] < state.gbest_f
        return _SwarmState(
            key=key, X=X, pbest_x=pbest_x, pbest_f=pbest_f,
            gbest_x=jnp.where(better, pbest_x[i_best], state.gbest_x),
            gbest_f=jnp.where(better, pbest_f[i_best], state.gbest_f),
            it=state.it + 1,
            stall=jnp.where(better, 0, state.stall + 1))

    key = jax.random.PRNGKey(seed)
    key, k_init = jax.random.split(key)
    X0 = init_swarm(k_init, prob, cfg)
    f0 = fit(X0)
    i0 = jnp.argmin(f0)
    state = _SwarmState(key=key, X=X0, pbest_x=X0, pbest_f=f0,
                        gbest_x=X0[i0], gbest_f=f0[i0],
                        it=jnp.asarray(0), stall=jnp.asarray(0))
    state = jax.lax.while_loop(
        lambda s: (s.it < cfg.max_iters) & (s.stall < cfg.stall_iters),
        step, state)
    res = sim(state.gbest_x)
    ok = bool(res.feasible)
    return PSOGAResult(best_x=np.asarray(state.gbest_x),
                       best_fitness=float(state.gbest_f),
                       best_cost=float(res.total_cost) if ok else float("inf"),
                       feasible=ok, iterations=int(state.it), history=None)


# ---------------------------------------------------------------------------
# HEFT
# ---------------------------------------------------------------------------

def heft_makespan(dag: LayerDAG, env: Environment
                  ) -> Tuple[float, np.ndarray]:
    """Classic HEFT [35]: upward-rank priority + earliest-finish-time
    server selection (non-insertion). Pinned layers stay pinned. Returns
    (makespan, assignment). Used for the deadline rule D_i = r_i · H(G_i).
    """
    prob = SimProblem.build(dag, env)
    p, s = prob.num_layers, prob.num_servers
    avg_exec = dag.compute[:, None] / env.power[None, :]
    w_bar = avg_exec.mean(axis=1)                         # (p,)
    # average comm rate over distinct-server pairs with real links
    off_diag = ~np.eye(s, dtype=bool)
    ok = prob.link_ok & off_diag
    inv_bw_avg = prob.inv_bw[ok].mean() if ok.any() else 0.0

    children = [[] for _ in range(p)]
    child_mb = [[] for _ in range(p)]
    for (u, v), mb in zip(dag.edges, dag.edge_mb):
        children[int(u)].append(int(v))
        child_mb[int(u)].append(float(mb))

    rank = np.zeros(p)
    for j in reversed(topological_order(dag)):
        best = 0.0
        for c, mb in zip(children[j], child_mb[j]):
            best = max(best, mb * inv_bw_avg + rank[c])
        rank[j] = w_bar[j] + best

    order = np.argsort(-rank, kind="stable")
    # respect topology: stable-sort by rank is not guaranteed topological
    # for general DAGs; enforce by Kahn with rank priority.
    import heapq
    indeg = dag.in_degree().copy()
    prio = {j: (-rank[j], j) for j in range(p)}
    ready = [prio[j] for j in range(p) if indeg[j] == 0]
    heapq.heapify(ready)
    sched_order = []
    while ready:
        _, j = heapq.heappop(ready)
        sched_order.append(j)
        for c in children[j]:
            indeg[c] -= 1
            if indeg[c] == 0:
                heapq.heappush(ready, prio[c])

    parents = [[] for _ in range(p)]
    parent_mb = [[] for _ in range(p)]
    for (u, v), mb in zip(dag.edges, dag.edge_mb):
        parents[int(v)].append(int(u))
        parent_mb[int(v)].append(float(mb))

    ready_srv = np.zeros(s)
    aft = np.zeros(p)
    x = np.zeros(p, np.int64)
    for j in sched_order:
        cands = ([int(prob.pinned[j])] if prob.pinned[j] >= 0
                 else list(range(s)))
        best_ft, best_srv = np.inf, cands[0]
        for srv in cands:
            gate = ready_srv[srv]
            bad = False
            for pj, mb in zip(parents[j], parent_mb[j]):
                if x[pj] != srv and not prob.link_ok[x[pj], srv]:
                    bad = True
                    break
                gate = max(gate, aft[pj] + mb * prob.inv_bw[x[pj], srv])
            if bad:
                continue
            ft = gate + dag.compute[j] / env.power[srv]
            if ft < best_ft:
                best_ft, best_srv = ft, srv
        x[j] = best_srv
        aft[j] = best_ft
        ready_srv[best_srv] = best_ft
    return float(aft.max() if p else 0.0), x


# ---------------------------------------------------------------------------
# prePSO
# ---------------------------------------------------------------------------

def pre_pso(dag: LayerDAG, env: Environment,
            cfg: PSOGAConfig = PSOGAConfig(), seed: int = 0) -> PSOGAResult:
    """Alg. 1 preprocessing, PSO-GA on the compressed DAG, then expansion
    of the placement back to original layers (every member of a merged
    group runs on the group's server)."""
    small, group = preprocess(dag)
    res = run_pso_ga(small, env, cfg, seed=seed)
    expanded = res.best_x[group]
    # Re-evaluate on the ORIGINAL problem for apples-to-apples cost:
    # merged execution removes intra-group transfers, which is exactly
    # what same-server placement does in the original DAG too.
    prob = SimProblem.build(dag, env)
    r = simulate_np(prob, expanded, faithful=cfg.faithful_sim)
    ok = bool(r.feasible)
    return PSOGAResult(best_x=expanded.astype(np.int32),
                       best_fitness=float(r.total_cost) if ok
                       else float(INFEASIBLE_OFFSET + r.app_completion.sum()),
                       best_cost=float(r.total_cost) if ok else float("inf"),
                       feasible=ok, iterations=res.iterations, history=None)
