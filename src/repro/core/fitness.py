"""Feasibility-aware fitness (paper §IV-B.2, Eq. 14–16).

The paper's three comparison cases —
  1. both feasible          → smaller C_total wins          (Eq. 14)
  2. one feasible           → the feasible particle wins     (Eq. 15)
  3. both infeasible        → smaller Σ T_i^comp wins        (Eq. 16)
— are induced by a single scalar key:

    key(X) = C_total(X)                            if feasible(X)
           = INFEASIBLE_OFFSET + log1p(Σ T_i^comp) otherwise

The log compression matters: fitness keys are float32 on device, and an
additive offset big enough to dominate any cost (costs are $ ≤ O(10^2),
completion-time sums can reach 10^9 s when a placement uses a forbidden
link) would otherwise swallow the completion-time differences that drive
Case-3 evolution (float32 has ~1e-3 absolute resolution at 1e4).
``log1p`` is strictly monotone, so the induced order on infeasible
particles is exactly the paper's Eq. 16 order.
"""
from __future__ import annotations

import jax.numpy as jnp

from .simulator import SimResult

#: Must exceed any attainable C_total; costs in both the paper fleet and the
#: TPU fleet are well under $1e4 per request batch.
INFEASIBLE_OFFSET = 1e4

__all__ = ["INFEASIBLE_OFFSET", "fitness_key"]


def fitness_key(res: SimResult) -> jnp.ndarray:
    total_time = jnp.sum(res.app_completion, axis=-1)
    infeasible_key = INFEASIBLE_OFFSET + jnp.log1p(total_time)
    return jnp.where(res.feasible, res.total_cost, infeasible_key)
