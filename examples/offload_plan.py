"""Cost-driven placement of a modern LM over a heterogeneous TPU fleet —
the paper's technique as a framework feature (DESIGN.md §3).

Lowers an assigned architecture to a layer DAG (FLOPs + activation MB),
instantiates the cloud/edge/device TPU fleet, and asks PSO-GA for the
cheapest placement meeting a latency SLO. Compares against Greedy and a
uniform depth-split.

    PYTHONPATH=src python examples/offload_plan.py --arch whisper-medium \
        --deadline-ratio 1.5
"""
import argparse

import numpy as np

from repro.configs import SHAPES, get
from repro.core import (PSOGAConfig, plan_offload, stage_cut_cost,
                        tpu_fleet_environment, uniform_stages)
from repro.core.simulator import SimProblem, simulate_np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="whisper-medium")
    ap.add_argument("--shape", default="prefill_32k")
    ap.add_argument("--deadline-ratio", type=float, default=1.5)
    args = ap.parse_args()

    cfg = get(args.arch)
    shape = next(s for s in SHAPES if s.name == args.shape)
    env = tpu_fleet_environment()
    print(f"Fleet: {env.num_servers} nodes "
          f"(cloud {np.sum(env.tier==0)}, edge {np.sum(env.tier==1)}, "
          f"device {np.sum(env.tier==2)})")

    pso = plan_offload(cfg, shape, env=env,
                       deadline_ratio=args.deadline_ratio,
                       pso=PSOGAConfig(pop_size=64, max_iters=300,
                                       stall_iters=40), seed=0)
    print(f"\n== PSO-GA plan for {args.arch} @ {args.shape} ==")
    print(pso.summary())

    grd = plan_offload(cfg, shape, env=env,
                       deadline_ratio=args.deadline_ratio, algo="greedy")
    print(f"\nGreedy: ${grd.cost:.4f} ({len(grd.stages)} stages, "
          f"feasible={grd.result.feasible})")

    dag = pso.dag
    servers = [int(env.servers_of_tier(0)[0]),
               int(env.servers_of_tier(1)[0]), int(dag.pinned[0])]
    xu = uniform_stages(dag, servers)
    xu[0] = dag.pinned[0]
    ru = simulate_np(SimProblem.build(dag, env), xu, faithful=False)
    print(f"Uniform depth-split: ${float(ru.total_cost):.4f} "
          f"(feasible={bool(ru.feasible)})")
    stats = stage_cut_cost(dag, env, pso.result.best_x)
    print(f"\nPSO-GA boundary traffic: {stats['cross_mb']:.1f} MB across "
          f"{stats['n_stages']} stages")


if __name__ == "__main__":
    main()
