"""Mixture-of-Experts FFN (mixtral-style top-k; arctic adds a dense
residual branch).

Two interchangeable dispatch implementations:

* ``scatter`` (default, used by smoke tests and the baseline dry-run) —
  capacity-dropped dispatch via scatter/gather: tokens are ranked within
  their chosen expert by a cumsum over a (tokens, E) one-hot, written into
  an (E, C, D) buffer, processed by a batched (E,C,D)x(E,D,F) matmul, and
  combined with their router weights. Unlike the classic one-hot-matmul
  dispatch (Mesh-TF/GSPMD MoE) this adds **zero** fake matmul FLOPs, so
  the roofline compute term reflects useful work. Cross-device routing is
  left to GSPMD.

* ``a2a`` — explicit expert parallelism under ``shard_map``: experts are
  sharded over the "model" axis; each device ranks its local tokens,
  exchanges fixed-capacity buffers with ``jax.lax.all_to_all``, runs its
  local expert shard, and reverses the exchange. This is the
  collective-exact formulation used at scale (the §Perf iterations
  measure it against the scatter baseline).

Router is fp32; top-k probabilities are softmax-renormalized over the
selected logits (mixtral); the switch-style load-balance auxiliary loss is
returned for the train step.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..configs.base import ModelConfig
from .layers import Params, dense_init, he_init

__all__ = ["moe_init", "moe_pspec", "moe_apply"]


def moe_init(key: jax.Array, cfg: ModelConfig, dtype: jnp.dtype) -> Params:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], d, e, jnp.float32),
        "wi": he_init(ks[1], (e, d, f), d, dtype),
        "wg": he_init(ks[2], (e, d, f), d, dtype),
        "wo": he_init(ks[3], (e, f, d), f, dtype),
    }
    if cfg.moe_dense_residual:
        from .layers import mlp_init
        p["dense"] = mlp_init(ks[4], d, cfg.d_ff_dense, cfg.act, dtype)
    return p


def moe_pspec(cfg: ModelConfig, tp: Optional[int] = None) -> Params:
    """Expert parallelism when n_experts % tp == 0 (arctic: 128e/16);
    otherwise shard the FFN hidden dim inside every expert (mixtral: 8e
    replicated across a 16-way axis would 16x the memory — d_ff TP keeps
    the footprint flat and GSPMD reduces the partial sums)."""
    from .layers import divisible
    if divisible(cfg.n_experts, tp):
        # EP over "model" + a second shard over "data": arctic's
        # 128x3x7168x4864 expert bank is 58 GB/device with EP alone on a
        # 16-way axis; the data-axis shard brings it to 3.7 GB. Three
        # layouts for the second axis (§Perf ablates them):
        #   ep_ftp  — FFN hidden dim F over data: wo's contraction is
        #             sharded, GSPMD reduces token ACTIVATIONS (cheap when
        #             tokens/device << expert bytes);
        #   ep_fsdp — contraction/model dim D over data: weights are
        #             all-gathered just-in-time per layer (classic FSDP);
        #   ep_only — no second shard (zero weight collectives, 16x mem).
        second = cfg.moe_shard if cfg.moe_shard in ("ep_ftp", "ep_fsdp",
                                                    "ep_only") else "ep_ftp"
        if second == "ep_ftp":
            p = {"router": P(None, None),
                 "wi": P("model", None, "data"),
                 "wg": P("model", None, "data"),
                 "wo": P("model", "data", None)}
        elif second == "ep_fsdp":
            p = {"router": P(None, None),
                 "wi": P("model", "data", None),
                 "wg": P("model", "data", None),
                 "wo": P("model", None, "data")}
        else:
            p = {"router": P(None, None),
                 "wi": P("model", None, None),
                 "wg": P("model", None, None),
                 "wo": P("model", None, None)}
    else:
        p = {"router": P(None, None),
             "wi": P(None, None, "model"),     # per-expert d_ff TP
             "wg": P(None, None, "model"),
             "wo": P(None, "model", None)}
    if cfg.moe_dense_residual:
        from .layers import mlp_pspec
        p["dense"] = mlp_pspec(cfg.act, cfg.d_ff_dense, tp)
    return p


def _route(p: Params, x2d: jnp.ndarray, cfg: ModelConfig
           ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """x2d: (T, D) -> (probs (T,k), idx (T,k) int32, aux_loss ())."""
    logits = (x2d.astype(jnp.float32) @ p["router"])        # (T, E)
    gates = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(logits, cfg.top_k)
    top_p = jax.nn.softmax(top_p, axis=-1)                  # renormalize
    # switch-style load-balance loss: E * sum_e fraction_e * prob_e
    e = cfg.n_experts
    frac = jnp.mean(
        jax.nn.one_hot(top_i[:, 0], e, dtype=jnp.float32), axis=0)
    prob = jnp.mean(gates, axis=0)
    aux = e * jnp.sum(frac * prob)
    return top_p, top_i.astype(jnp.int32), aux


def _expert_ffn(wi: jnp.ndarray, wg: jnp.ndarray, wo: jnp.ndarray,
                xs: jnp.ndarray, act: str) -> jnp.ndarray:
    """xs: (E, C, D) -> (E, C, D) with per-expert weights."""
    h = jnp.einsum("ecd,edf->ecf", xs, wg)
    hi = jnp.einsum("ecd,edf->ecf", xs, wi)
    if act == "geglu":
        h = jax.nn.gelu(h, approximate=True) * hi
    else:
        h = jax.nn.silu(h) * hi
    return jnp.einsum("ecf,efd->ecd", h, wo)


def _dispatch_ranks(top_i: jnp.ndarray, e: int) -> jnp.ndarray:
    """Position of each (token, k) entry within its expert's queue.

    top_i: (T, k) -> ranks (T, k) int32. Entries are ordered token-major
    (the order combine must reproduce). Uses a cumsum over a (T*k, E)
    one-hot — O(T·k·E) adds, no matmul FLOPs.
    """
    flat = top_i.reshape(-1)                                  # (T*k,)
    onehot = jax.nn.one_hot(flat, e, dtype=jnp.int32)         # (T*k, E)
    ranks = jnp.cumsum(onehot, axis=0) - onehot
    return jnp.take_along_axis(ranks, flat[:, None], axis=1
                               ).reshape(top_i.shape)


def _moe_scatter(p: Params, x2d: jnp.ndarray, cfg: ModelConfig,
                 capacity: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    t, d = x2d.shape
    e, k = cfg.n_experts, cfg.top_k
    top_p, top_i, aux = _route(p, x2d, cfg)
    ranks = _dispatch_ranks(top_i, e)                         # (T, k)
    keep = ranks < capacity
    # scatter tokens into (E, C, D); dropped entries write to a spill row
    buf = jnp.zeros((e * capacity + 1, d), x2d.dtype)
    slot = jnp.where(keep, top_i * capacity + ranks, e * capacity)
    buf = buf.at[slot.reshape(-1)].set(
        jnp.repeat(x2d, k, axis=0))                           # token-major
    xs = buf[:-1].reshape(e, capacity, d)
    ys = _expert_ffn(p["wi"], p["wg"], p["wo"], xs, cfg.act)
    flat = jnp.concatenate(
        [ys.reshape(e * capacity, d), jnp.zeros((1, d), ys.dtype)])
    gathered = flat[slot.reshape(-1)].reshape(t, k, d)
    y = jnp.sum(gathered * top_p[..., None].astype(gathered.dtype), axis=1)
    return y, aux


def _moe_a2a(p: Params, x2d: jnp.ndarray, cfg: ModelConfig,
             capacity: int, mesh: jax.sharding.Mesh,
             data_axes: Tuple[str, ...], model_axis: str
             ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Expert-parallel dispatch with explicit all_to_all along the model
    axis. Experts are sharded over `model_axis`; tokens over `data_axes`.
    Capacity here is per (device, remote-device) lane.
    """
    e, k = cfg.n_experts, cfg.top_k
    m = mesh.shape[model_axis]
    e_local = e // m
    assert e % m == 0, "n_experts must divide model axis"

    def local_fn(router, wi, wg, wo, x_loc):
        # x_loc: (t_l, D) tokens local to this device
        t_l, d = x_loc.shape
        pp = {"router": router, "wi": wi, "wg": wg, "wo": wo}
        top_p, top_i, aux = _route(pp, x_loc, cfg)
        ranks = _dispatch_ranks(top_i, e)
        # lane layout: (m dest devices, e_local experts, capacity)
        dest = top_i // e_local
        eloc = top_i % e_local
        keep = ranks < capacity
        slot = jnp.where(keep,
                         dest * (e_local * capacity) + eloc * capacity
                         + ranks,
                         m * e_local * capacity)
        buf = jnp.zeros((m * e_local * capacity + 1, d), x_loc.dtype)
        buf = buf.at[slot.reshape(-1)].set(jnp.repeat(x_loc, k, axis=0))
        send = buf[:-1].reshape(m, e_local * capacity, d)
        recv = jax.lax.all_to_all(send, model_axis, split_axis=0,
                                  concat_axis=0, tiled=False)
        # recv: (m, e_local*capacity, d) tokens for OUR local experts
        xs = recv.reshape(m, e_local, capacity, d).transpose(1, 0, 2, 3) \
            .reshape(e_local, m * capacity, d)
        ys = _expert_ffn(wi, wg, wo, xs, cfg.act)
        back = ys.reshape(e_local, m, capacity, d).transpose(1, 0, 2, 3)
        back = jax.lax.all_to_all(back, model_axis, split_axis=0,
                                  concat_axis=0, tiled=False)
        flat = jnp.concatenate([back.reshape(-1, d),
                                jnp.zeros((1, d), ys.dtype)])
        gathered = flat[slot.reshape(-1)].reshape(t_l, k, d)
        y = jnp.sum(gathered * top_p[..., None].astype(gathered.dtype),
                    axis=1)
        return y, jax.lax.pmean(aux, model_axis)

    from jax.experimental.shard_map import shard_map
    spec_x = P(data_axes, None)
    fn = shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(None, None), P(model_axis, None, None),
                  P(model_axis, None, None), P(model_axis, None, None),
                  spec_x),
        out_specs=(spec_x, P()),
        check_rep=False)
    y, aux = fn(p["router"], p["wi"], p["wg"], p["wo"], x2d)
    return y, aux


def moe_apply(p: Params, x: jnp.ndarray, cfg: ModelConfig,
              impl: str = "scatter",
              mesh: Optional[jax.sharding.Mesh] = None,
              data_axes: Tuple[str, ...] = ("data",),
              model_axis: str = "model"
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, D) -> (y (B,S,D), aux_loss ()). Dense residual included."""
    b, s, d = x.shape
    t = b * s
    x2d = x.reshape(t, d)
    # Exact (drop-free) routing whenever affordable: the worst case is all
    # tokens picking the same expert, so capacity == t guarantees no drops.
    # Decode/small-prefill batches stay exact; large training batches use
    # the standard capacity-factor dropping.
    exact = t <= 8192
    cap = t if exact else max(1, int(cfg.capacity_factor * cfg.top_k * t
                                     / cfg.n_experts))
    if impl == "a2a":
        assert mesh is not None
        m = mesh.shape[model_axis]
        n_data = 1
        for a in data_axes:
            n_data *= mesh.shape[a]
        t_l = t // max(1, n_data)
        cap_l = t_l if exact else max(
            1, int(cfg.capacity_factor * cfg.top_k * t_l
                   / (cfg.n_experts * max(1, m))))
        y, aux = _moe_a2a(p, x2d, cfg, cap_l, mesh, data_axes, model_axis)
    else:
        y, aux = _moe_scatter(p, x2d, cfg, cap)
    y = y.reshape(b, s, d)
    if cfg.moe_dense_residual:
        from .layers import mlp_apply
        y = y + mlp_apply(p["dense"], x, cfg.act)
    return y, aux
