"""End-to-end driver: train a ~100M-parameter qwen3-family LM for a few
hundred steps on CPU with the full production stack — sharded train step,
ZeRO-1 AdamW, deterministic data stream, async checkpointing, an injected
mid-run crash, and automatic restart from the latest checkpoint.

    PYTHONPATH=src python examples/train_lm.py --steps 200
    (use --steps 30 for a fast smoke)
"""
import argparse
import dataclasses
import tempfile

from repro.configs import get
from repro.configs.base import ShapeSpec
from repro.launch.train import Trainer, TrainerConfig
from repro.optim import AdamWConfig
from repro.runtime import FailureInjector


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--crash-at", type=int, default=None,
                    help="inject a failure at this step (default midway)")
    args = ap.parse_args()

    # ~100M params: qwen3 dims shrunk to 12 layers x 768 wide, 32k vocab
    cfg = dataclasses.replace(
        get("qwen3-0.6b"), name="qwen3-100m", n_layers=12, d_model=768,
        n_heads=12, n_kv_heads=4, head_dim=64, d_ff=2048, vocab=32_000,
        dtype="float32")
    from repro.models import param_count
    print(f"model: {cfg.name}  params={param_count(cfg)/1e6:.1f}M")

    ckpt = args.ckpt_dir or tempfile.mkdtemp(prefix="train_lm_")
    crash = args.crash_at if args.crash_at is not None else args.steps // 2
    shape = ShapeSpec("train", args.seq, args.batch, "train")
    trainer = Trainer(
        cfg, shape,
        TrainerConfig(steps=args.steps, ckpt_dir=ckpt,
                      ckpt_every=max(args.steps // 10, 5), log_every=10),
        AdamWConfig(lr=6e-4, warmup_steps=args.steps // 10,
                    total_steps=args.steps),
        injector=FailureInjector(fail_at=(crash,)))
    print(f"checkpoints -> {ckpt}; simulated crash at step {crash}")
    out = trainer.train()
    losses = [m["loss"] for m in out["metrics"]]
    print(f"\nfinal step {out['final_step']}: "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"(stragglers flagged: {out['stragglers']})")
    assert losses[-1] < losses[0], "loss must decrease"


if __name__ == "__main__":
    main()
