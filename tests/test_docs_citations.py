"""Docs can't dangle: every `DESIGN.md §N` / `EXPERIMENTS.md §X` citation
in the sources must resolve to a real heading (scripts/check_docs.py)."""
import importlib.util
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
CHECKER = REPO / "scripts" / "check_docs.py"


def _load_checker():
    spec = importlib.util.spec_from_file_location("check_docs", CHECKER)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_no_dangling_doc_citations():
    mod = _load_checker()
    problems = mod.find_dangling(REPO)
    assert not problems, "\n".join(problems)


def test_citations_actually_found():
    """The checker must actually see the known citations — if the regex
    rots, this fails before the no-dangling assert goes vacuous."""
    mod = _load_checker()
    cites = {(doc, sec) for _, _, doc, sec in mod.find_citations(REPO)}
    for expected in [("DESIGN.md", "2"), ("DESIGN.md", "3"),
                     ("DESIGN.md", "4"), ("DESIGN.md", "5"),
                     ("DESIGN.md", "6"), ("DESIGN.md", "7"),
                     ("EXPERIMENTS.md", "Perf")]:
        assert expected in cites, f"lost citation {expected}"


def test_checker_cli_green():
    out = subprocess.run([sys.executable, str(CHECKER)], cwd=REPO,
                         capture_output=True, text=True)
    assert out.returncode == 0, out.stdout + out.stderr
