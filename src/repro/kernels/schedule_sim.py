"""Pallas TPU kernel replaying paper Algorithm 2 for a whole particle tile.

The PSO-GA fitness hot path evaluates P particles (server-assignment
vectors) against one padded problem per swarm iteration. The scan-based
path (``core.simulator.simulate_padded`` under ``vmap``) pays per-layer
dispatch for every step of the schedule replay; this kernel moves the
layer loop *inside* one ``pallas_call`` so the whole replay of a particle
tile is a single fused program (DESIGN.md §8):

  * grid ``(num_particle_tiles,)`` — one grid cell replays ``tile_p``
    particles; ``jax.vmap`` adds the fleet's problem axis as an outer
    grid dimension (``core.batch._fleet_runner`` relies on this).
  * ``lease (tile_p, S)`` / ``t_on (tile_p, S)`` / ``end (tile_p, p)``
    are held in VMEM scratch across the ``fori_loop`` over layers;
    scalar accumulators (transmission cost, link violations) ride in a
    ``(tile_p, 2)`` scratch strip.
  * server-indexed lookups (``inv_bw[x[parent], x[j]]`` etc.) are
    expressed as one-hot row selections — ``(tile_p, S) @ (S, S)``
    contractions that hit the MXU — instead of gathers, which Mosaic
    supports poorly; per-layer DAG structure (parent/child ids, datasets)
    is read as scalars since it is shared by every particle in the tile.

The kernel returns the per-particle summary the fitness key needs —
``(total_cost, feasible, Σ app_completion)`` — not the full ``SimResult``
(the solver epilogue re-simulates only the single gbest). Feasibility
folds deadlines, pins, and link violations, exactly like the scan path.

No ``repro.core`` imports here: the kernel layer stays below core
(DESIGN.md §1), so the problem arrives as raw padded arrays and the
3-case fitness key (Eq. 14–16) is applied by ``core.fitness``.

Validated in interpret mode against ``ref.schedule_replay_ref`` and the
numpy oracle (``tests/test_schedule_sim.py``); this container is
CPU-only, TPU is the TARGET.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["schedule_replay_folded", "DEFAULT_TILE_P"]

#: particles per grid cell; swarm sizes are padded up to a multiple.
DEFAULT_TILE_P = 32


def _row(one_hot_f: jnp.ndarray, mat: jnp.ndarray) -> jnp.ndarray:
    """(T, S) one-hot @ (S, S) matrix -> (T, S): row ``mat[sel, :]``."""
    return jax.lax.dot_general(one_hot_f, mat, (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)


def _schedule_kernel(x_ref, order_ref, compute_ref, parent_idx_ref,
                     parent_mb_ref, child_idx_ref, child_mb_ref, app_id_ref,
                     deadline_ref, pinned_ref, power_ref, cost_ref,
                     inv_bw_ref, tran_ref, link_ref,
                     total_ref, feas_ref, tsum_ref,
                     lease_s, t_on_s, end_s, acc_s, *,
                     tile_p: int, max_p: int, max_in: int, max_out: int,
                     max_S: int, max_apps: int, faithful: bool):
    X = x_ref[:]                                   # (T, max_p) int32
    inv_bw = inv_bw_ref[:]                         # (S, S) f32
    tran = tran_ref[:]
    link = link_ref[:]                             # (S, S) f32 (1 = ok)
    power = power_ref[:]                           # (S,)
    col_S = jax.lax.broadcasted_iota(jnp.int32, (tile_p, max_S), 1)
    # transposed copies: parent-side lookups select column `srv`, i.e. a
    # row of the transpose — keeps every select a row-select.
    inv_bw_t = inv_bw.T
    tran_t = tran.T
    link_t = link.T

    lease_s[:] = jnp.zeros((tile_p, max_S), jnp.float32)
    t_on_s[:] = jnp.full((tile_p, max_S), jnp.inf, jnp.float32)
    end_s[:] = jnp.zeros((tile_p, max_p), jnp.float32)
    acc_s[:] = jnp.zeros((tile_p, 2), jnp.float32)  # [trans_cost, n_bad]

    def body(t, _):
        j = order_ref[t]                           # scalar int32
        valid = j >= 0
        jsafe = jnp.maximum(j, 0)
        srv = jax.lax.dynamic_slice(X, (0, jsafe), (tile_p, 1))[:, 0]
        srv_ohf = (col_S == srv[:, None]).astype(jnp.float32)  # (T, S)
        lease = lease_s[:]
        end = end_s[:]
        lease_srv = jnp.sum(lease * srv_ohf, axis=1)           # (T,)
        exe = compute_ref[jsafe] / jnp.sum(power[None, :] * srv_ohf, axis=1)
        # rows of the (transposed) link matrices for this layer's server
        in_ibw = _row(srv_ohf, inv_bw_t)           # inv_bw[:, srv]
        in_tc = _row(srv_ohf, tran_t)              # tran_cost[:, srv]
        in_lk = _row(srv_ohf, link_t)              # link_ok[:, srv]
        out_ibw = _row(srv_ohf, inv_bw)            # inv_bw[srv, :]
        out_lk = _row(srv_ohf, link)               # link_ok[srv, :]

        max_trans = jnp.zeros((tile_p,), jnp.float32)
        gate = jnp.zeros((tile_p,), jnp.float32)
        trans_add = jnp.zeros((tile_p,), jnp.float32)
        bad_add = jnp.zeros((tile_p,), jnp.float32)
        for k in range(max_in):                    # DAG structure: scalars
            pj = parent_idx_ref[jsafe, k]
            pmask = (pj >= 0) & valid
            pjs = jnp.maximum(pj, 0)
            mb = parent_mb_ref[jsafe, k]
            psrv = jax.lax.dynamic_slice(X, (0, pjs), (tile_p, 1))[:, 0]
            psrv_ohf = (col_S == psrv[:, None]).astype(jnp.float32)
            tt = mb * jnp.sum(in_ibw * psrv_ohf, axis=1)
            lk = jnp.sum(in_lk * psrv_ohf, axis=1)
            max_trans = jnp.maximum(max_trans, jnp.where(pmask, tt, 0.0))
            if not faithful:   # faithful recurrence never reads `end`
                ep = jax.lax.dynamic_slice(end, (0, pjs), (tile_p, 1))[:, 0]
                gate = jnp.maximum(gate, jnp.where(pmask, ep + tt, 0.0))
            trans_add += jnp.where(
                pmask, mb * jnp.sum(in_tc * psrv_ohf, axis=1), 0.0)
            bad_add += jnp.where(pmask & (psrv != srv), 1.0 - lk, 0.0)

        out_t = jnp.zeros((tile_p,), jnp.float32)
        for k in range(max_out):
            cj = child_idx_ref[jsafe, k]
            cmask = (cj >= 0) & valid
            cjs = jnp.maximum(cj, 0)
            csrv = jax.lax.dynamic_slice(X, (0, cjs), (tile_p, 1))[:, 0]
            csrv_ohf = (col_S == csrv[:, None]).astype(jnp.float32)
            out_t += jnp.where(
                cmask,
                child_mb_ref[jsafe, k] * jnp.sum(out_ibw * csrv_ohf, axis=1),
                0.0)
            bad_add += jnp.where(
                cmask & (csrv != srv),
                1.0 - jnp.sum(out_lk * csrv_ohf, axis=1), 0.0)

        if faithful:
            start = lease_srv + max_trans
            new_lease = lease_srv + exe + out_t
        else:
            start = jnp.maximum(lease_srv, gate)
            new_lease = start + exe + out_t
        t_end = start + exe
        upd = srv_ohf * valid.astype(jnp.float32)              # (T, S)
        lease_s[:] = jnp.where(upd > 0, new_lease[:, None], lease)
        t_on_s[:] = jnp.minimum(
            t_on_s[:], jnp.where(upd > 0, start[:, None], jnp.inf))
        old_end = jax.lax.dynamic_slice(end, (0, jsafe), (tile_p, 1))[:, 0]
        end_s[:, pl.ds(jsafe, 1)] = jnp.where(valid, t_end,
                                              old_end)[:, None]
        acc_s[:] = acc_s[:] + jnp.concatenate(
            [trans_add[:, None], bad_add[:, None]], axis=1)
        return 0

    jax.lax.fori_loop(0, max_p, body, 0)

    end = end_s[:]
    lease = lease_s[:]
    t_on = t_on_s[:]
    acc = acc_s[:]
    app_id = app_id_ref[:]                         # (max_p,)
    pinned = pinned_ref[:]                         # (max_p,)
    deadline_ok = jnp.ones((tile_p,), bool)
    tsum = jnp.zeros((tile_p,), jnp.float32)
    for a in range(max_apps):                      # max_apps is small
        sel = (app_id == a)[None, :]
        appc = jnp.maximum(
            jnp.max(jnp.where(sel, end, -jnp.inf), axis=1), 0.0)
        deadline_ok &= appc <= deadline_ref[a]
        tsum += appc
    pin_ok = jnp.all((pinned[None, :] < 0) | (X == pinned[None, :]), axis=1)
    used = ~jnp.isinf(t_on)
    t_on_safe = jnp.where(used, t_on, 0.0)
    comp = jnp.sum(jnp.where(used, cost_ref[:][None, :] * (lease - t_on_safe),
                             0.0), axis=1)
    total_ref[:] = comp + acc[:, 0]
    feas_ref[:] = deadline_ok & pin_ok & (acc[:, 1] == 0.0)
    tsum_ref[:] = tsum


def schedule_replay_folded(
        order: jnp.ndarray, compute: jnp.ndarray, parent_idx: jnp.ndarray,
        parent_mb: jnp.ndarray, child_idx: jnp.ndarray,
        child_mb: jnp.ndarray, app_id: jnp.ndarray, deadline: jnp.ndarray,
        pinned: jnp.ndarray, power: jnp.ndarray, cost_per_sec: jnp.ndarray,
        inv_bw: jnp.ndarray, tran_cost: jnp.ndarray, link_ok: jnp.ndarray,
        X: jnp.ndarray, *, faithful: bool = True,
        tile_p: int = DEFAULT_TILE_P, interpret: bool = True
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Replay Algorithm 2 for every particle in ``X``.

    Args use the padded-problem layout of ``core.simulator.PaddedProblem``
    (``order`` padded -1, parent/child ids padded -1, servers padded
    unreachable, apps padded deadline +inf); ``X`` is ``(P, max_p)``
    int32 server assignments. Returns per-particle
    ``(total_cost (P,) f32, feasible (P,) bool, time_sum (P,) f32)`` where
    ``time_sum`` is ``Σ_i T_i^comp`` (the Case-3 fitness input, Eq. 16).
    """
    P, max_p = X.shape
    max_S = power.shape[0]
    max_in = parent_idx.shape[1]
    max_out = child_idx.shape[1]
    max_apps = deadline.shape[0]
    tile_p = min(tile_p, max(P, 1))
    n_tiles = pl.cdiv(P, tile_p)
    p_pad = n_tiles * tile_p
    if p_pad != P:                                 # pad with copies of row 0
        X = jnp.concatenate(
            [X, jnp.broadcast_to(X[:1], (p_pad - P, max_p))], axis=0)

    f32 = functools.partial(jnp.asarray, dtype=jnp.float32)
    i32 = functools.partial(jnp.asarray, dtype=jnp.int32)
    kernel = functools.partial(
        _schedule_kernel, tile_p=tile_p, max_p=max_p, max_in=max_in,
        max_out=max_out, max_S=max_S, max_apps=max_apps, faithful=faithful)
    full = lambda shape: pl.BlockSpec(shape, lambda i: (0,) * len(shape))
    total, feas, tsum = pl.pallas_call(
        kernel,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((tile_p, max_p), lambda i: (i, 0)),   # X tile
            full((max_p,)),                                    # order
            full((max_p,)),                                    # compute
            full((max_p, max_in)),                             # parent_idx
            full((max_p, max_in)),                             # parent_mb
            full((max_p, max_out)),                            # child_idx
            full((max_p, max_out)),                            # child_mb
            full((max_p,)),                                    # app_id
            full((max_apps,)),                                 # deadline
            full((max_p,)),                                    # pinned
            full((max_S,)),                                    # power
            full((max_S,)),                                    # cost_per_sec
            full((max_S, max_S)),                              # inv_bw
            full((max_S, max_S)),                              # tran_cost
            full((max_S, max_S)),                              # link_ok
        ],
        out_specs=[pl.BlockSpec((tile_p,), lambda i: (i,))] * 3,
        out_shape=[
            jax.ShapeDtypeStruct((p_pad,), jnp.float32),
            jax.ShapeDtypeStruct((p_pad,), jnp.bool_),
            jax.ShapeDtypeStruct((p_pad,), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((tile_p, max_S), jnp.float32),          # lease
            pltpu.VMEM((tile_p, max_S), jnp.float32),          # t_on
            pltpu.VMEM((tile_p, max_p), jnp.float32),          # end
            pltpu.VMEM((tile_p, 2), jnp.float32),              # accumulators
        ],
        interpret=interpret,
    )(i32(X), i32(order), f32(compute), i32(parent_idx), f32(parent_mb),
      i32(child_idx), f32(child_mb), i32(app_id), f32(deadline), i32(pinned),
      f32(power), f32(cost_per_sec), f32(inv_bw), f32(tran_cost),
      f32(link_ok))
    return total[:P], feas[:P], tsum[:P]
