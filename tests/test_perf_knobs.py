"""§Perf knobs are semantics-preserving: chunked CE == CE, int8 KV decode
tracks fp decode (top-1 agreement), MoE shard layouts are math-invariant."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get
from repro.models import build_model


def test_chunked_ce_matches_plain():
    c0 = get("qwen3-0.6b").reduced()
    c1 = dataclasses.replace(c0, ce_chunk=4)
    m0, m1 = build_model(c0), build_model(c1)
    p = m0.init(jax.random.PRNGKey(0))
    batch = {"tokens": np.random.default_rng(0).integers(
        0, c0.vocab, (2, 33)).astype(np.int32)}
    l0, _ = jax.jit(m0.loss_fn)(p, batch)
    l1, _ = jax.jit(m1.loss_fn)(p, batch)
    assert abs(float(l0) - float(l1)) < 2e-5


def test_chunked_ce_unrolled_matches():
    c0 = get("qwen3-0.6b").reduced()
    c1 = dataclasses.replace(c0, ce_chunk=4, scan_layers=False)
    m0, m1 = build_model(c0), build_model(c1)
    p = m0.init(jax.random.PRNGKey(0))
    batch = {"tokens": np.random.default_rng(1).integers(
        0, c0.vocab, (2, 30)).astype(np.int32)}   # ragged vs 4 chunks
    l0, _ = jax.jit(m0.loss_fn)(p, batch)
    l1, _ = jax.jit(m1.loss_fn)(p, batch)
    assert abs(float(l0) - float(l1)) < 2e-5


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "gemma3-27b", "zamba2-7b"])
def test_int8_kv_decode_top1_agrees(arch):
    c0 = dataclasses.replace(get(arch).reduced(), dtype="float32")
    c1 = dataclasses.replace(c0, kv_dtype="int8")
    m0, m1 = build_model(c0), build_model(c1)
    p = m0.init(jax.random.PRNGKey(0))
    batch = {"tokens": np.random.default_rng(2).integers(
        0, c0.vocab, (2, 12)).astype(np.int32)}
    lg0, cc0 = jax.jit(lambda pp, bb: m0.prefill(pp, bb, cache_len=20))(
        p, batch)
    lg1, cc1 = jax.jit(lambda pp, bb: m1.prefill(pp, bb, cache_len=20))(
        p, batch)
    tok = jnp.argmax(lg0[:, -1], -1).astype(jnp.int32)[:, None]
    d0, _ = jax.jit(m0.decode_step)(p, cc0,
                                    {"token": tok,
                                     "pos": jnp.asarray(12, jnp.int32)})
    d1, _ = jax.jit(m1.decode_step)(p, cc1,
                                    {"token": tok,
                                     "pos": jnp.asarray(12, jnp.int32)})
    assert float(jnp.max(jnp.abs(d0 - d1))) < 0.6
    assert bool(jnp.all(jnp.argmax(d0[:, -1], -1)
                        == jnp.argmax(d1[:, -1], -1)))
    # cache really is int8
    leaves = jax.tree.leaves(cc1)
    assert any(l.dtype == jnp.int8 for l in leaves)


def test_moe_shard_layouts_invariant():
    c0 = get("mixtral-8x7b").reduced()
    m0 = build_model(c0)
    p = m0.init(jax.random.PRNGKey(0))
    batch = {"tokens": np.random.default_rng(3).integers(
        0, c0.vocab, (2, 17)).astype(np.int32)}
    ref = None
    for shard in ("ep_ftp", "ep_fsdp", "ep_only"):
        m = build_model(dataclasses.replace(c0, moe_shard=shard))
        l, _ = jax.jit(m.loss_fn)(p, batch)
        ref = float(l) if ref is None else ref
        assert abs(float(l) - ref) < 1e-6
