"""Shared building blocks: RMSNorm, RoPE, gated MLPs, embeddings.

Parameters are plain pytrees (nested dicts of jnp arrays). Every creation
helper has a sibling ``*_pspec`` returning the PartitionSpec tree for the
production mesh (axes "data"/"model", with the batch additionally sharded
over "pod" when present — activations only, parameters never shard over
"pod"/"data" except ZeRO-1 optimizer state).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

Params = Dict[str, Any]

__all__ = ["Params", "P", "rms_norm", "rope", "mlp_apply", "mlp_init",
           "mlp_pspec", "dense_init", "embed_init", "embed_pspec",
           "cross_entropy", "he_init", "stack_layers", "divisible"]


def divisible(n: int, tp: Optional[int]) -> bool:
    """True when dimension ``n`` can shard evenly over a model axis of
    size ``tp`` (tp=None: assume yes — single-device smoke paths)."""
    return tp is None or (tp > 0 and n % tp == 0)


def embed_pspec(vocab: int, tp: Optional[int] = None) -> P:
    """Vocab-sharded embedding when divisible; replicated otherwise
    (whisper 51865 / internvl2 92553 don't divide a 16-way model axis —
    at ~100-200 MB replication is the cheaper choice vs padded shards)."""
    return P("model", None) if divisible(vocab, tp) else P(None, None)


def he_init(key: jax.Array, shape: Tuple[int, ...], fan_in: Optional[int]
            = None, dtype: jnp.dtype = jnp.float32) -> jnp.ndarray:
    fan_in = fan_in or shape[0]
    return (jax.random.normal(key, shape, jnp.float32)
            * (1.0 / jnp.sqrt(fan_in))).astype(dtype)


def dense_init(key: jax.Array, d_in: int, d_out: int,
               dtype: jnp.dtype) -> jnp.ndarray:
    return he_init(key, (d_in, d_out), d_in, dtype)


def embed_init(key: jax.Array, vocab: int, d: int,
               dtype: jnp.dtype) -> jnp.ndarray:
    return he_init(key, (vocab, d), d, dtype)


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6
             ) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * (1.0 + scale.astype(jnp.float32))
            ).astype(dt)


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float
         ) -> jnp.ndarray:
    """Rotary embedding. x: (..., seq, heads, head_dim); positions: (..., seq)."""
    head_dim = x.shape[-1]
    half = head_dim // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., :, None].astype(jnp.float32) * freq  # (..., s, half)
    cos = jnp.cos(angles)[..., :, None, :]   # broadcast over heads
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def mlp_init(key: jax.Array, d: int, d_ff: int, act: str,
             dtype: jnp.dtype) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    if act in ("swiglu", "geglu"):
        return {"wi": dense_init(k1, d, d_ff, dtype),
                "wg": dense_init(k2, d, d_ff, dtype),
                "wo": dense_init(k3, d_ff, d, dtype)}
    return {"wi": dense_init(k1, d, d_ff, dtype),
            "wo": dense_init(k3, d_ff, d, dtype)}


def mlp_pspec(act: str, d_ff: int = 0, tp: Optional[int] = None) -> Params:
    ok = d_ff == 0 or divisible(d_ff, tp)
    hid = P(None, "model") if ok else P("model", None)
    out = P("model", None) if ok else P(None, "model")
    if act in ("swiglu", "geglu"):
        return {"wi": hid, "wg": hid, "wo": out}
    return {"wi": hid, "wo": out}


def mlp_apply(p: Params, x: jnp.ndarray, act: str) -> jnp.ndarray:
    if act == "swiglu":
        h = jax.nn.silu(x @ p["wg"]) * (x @ p["wi"])
    elif act == "geglu":
        h = jax.nn.gelu(x @ p["wg"], approximate=True) * (x @ p["wi"])
    elif act == "gelu":
        h = jax.nn.gelu(x @ p["wi"], approximate=True)
    else:
        raise ValueError(f"unknown act {act}")
    return h @ p["wo"]


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  mask: Optional[jnp.ndarray] = None,
                  z_loss: float = 1e-4) -> jnp.ndarray:
    """Token-mean CE in fp32 with optional z-loss (stabilizes large vocabs)."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    loss = lse - gold
    if z_loss:
        loss = loss + z_loss * jnp.square(lse)
    if mask is not None:
        loss = loss * mask
        return loss.sum() / jnp.maximum(mask.sum(), 1.0)
    return loss.mean()


def chunked_ce(h: jnp.ndarray, unembed: jnp.ndarray, labels: jnp.ndarray,
               n_chunks: int, z_loss: float = 1e-4,
               scan: bool = True) -> jnp.ndarray:
    """Sequence-chunked CE: the (B, S, V) fp32 logits tensor — 4.3 GB/dev
    for gemma3 train_4k — is never materialized; each chunk's logits are
    (re)computed inside a remat'd body so the backward holds one chunk at
    a time. FLOPs: +1 extra head matmul on the backward (the standard
    memory/recompute trade; §Perf logs the measured delta).

    h: (B, S, D) final hidden states; unembed: (D, V); labels: (B, S).
    """
    b, s, d = h.shape
    n_chunks = max(1, min(n_chunks, s))
    pad = (-s) % n_chunks
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
    q = (s + pad) // n_chunks
    hc = h.reshape(b, n_chunks, q, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(b, n_chunks, q).transpose(1, 0, 2)
    valid = (jnp.arange(s + pad) < s).reshape(n_chunks, q)

    @jax.checkpoint
    def body(carry, xs):
        h_i, l_i, v_i = xs
        logits = (h_i @ unembed).astype(jnp.float32)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, l_i[..., None], axis=-1)[..., 0]
        loss = lse - gold
        if z_loss:
            loss = loss + z_loss * jnp.square(lse)
        loss = jnp.where(v_i[None, :], loss, 0.0)
        return carry + loss.sum(), None

    total, _ = scan_blocks(body, jnp.asarray(0.0, jnp.float32),
                           (hc, lc, valid), scan)
    return total / (b * s)


def stack_layers(init_fn, key: jax.Array, n: int) -> Params:
    """Initialize ``n`` layers with stacked (leading-axis) parameters, the
    layout ``lax.scan`` consumes."""
    keys = jax.random.split(key, n)
    return jax.vmap(init_fn)(keys)


def scan_blocks(body, carry, xs, scan: bool = True):
    """``lax.scan`` over layer-stacked params/caches, or an unrolled
    python loop with identical semantics.

    Production lowering scans (HLO size O(1) in depth). The roofline pass
    unrolls instead: XLA's HloCostAnalysis counts a while-loop body ONCE,
    not x trip-count, so scanned HLO under-reports FLOPs/bytes by ~L x —
    unrolling makes cost_analysis() truthful (verified: scan of 8 matmuls
    reports 1/8th of the unrolled flops).
    """
    if scan:
        return jax.lax.scan(body, carry, xs)
    n = jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(n):
        x_i = jax.tree.map(lambda a: a[i], xs)
        carry, y = body(carry, x_i)
        ys.append(y)
    if ys and jax.tree.leaves(ys[0]):
        stacked = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    else:
        stacked = None
    return carry, stacked
