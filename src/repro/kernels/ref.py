"""Pure-jnp oracles for every Pallas kernel (the contract the kernels are
property-tested against — tests/test_kernels.py sweeps shapes & dtypes).

These are *definitions*, not fast paths: O(S^2) score materialization is
fine here.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -2.0 ** 30

__all__ = ["flash_attention_ref", "ssd_intra_ref", "decode_attention_ref",
           "schedule_replay_ref", "traffic_replay_ref", "NEG_INF"]


def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        causal: bool = True, window: int = 0) -> jnp.ndarray:
    """q: (B,S,K,G,hd); k/v: (B,S,K,hd) -> out (B,S,K,G,hd) (fp32 math)."""
    b, s, kh, g, hd = q.shape
    scale = hd ** -0.5
    scores = jnp.einsum("bqkgd,bckd->bkgqc", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(k.shape[1])[None, :]
    ok = jnp.ones((s, k.shape[1]), bool)
    if causal:
        ok &= kpos <= qpos
    if window:
        ok &= kpos > qpos - window
    scores = jnp.where(ok[None, None, None], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqc,bckd->bqkgd", w, v.astype(jnp.float32))
    return out


def ssd_intra_ref(xc: jnp.ndarray, cum: jnp.ndarray, Bc: jnp.ndarray,
                  Cc: jnp.ndarray) -> jnp.ndarray:
    """Intra-chunk SSD quadratic form.

    xc: (b,c,q,h,p) fp32; cum: (b,c,q,h) inclusive cumsum of log-decay;
    Bc/Cc: (b,c,q,n). Returns (b,c,q,h,p):
        out[i] = sum_{j<=i} (C_i . B_j) * exp(cum_i - cum_j) * xc[j]
    """
    q = xc.shape[2]
    li = cum[:, :, :, None, :]
    lj = cum[:, :, None, :, :]
    mask = jnp.tril(jnp.ones((q, q), bool))
    L = jnp.where(mask[None, None, :, :, None], jnp.exp(li - lj), 0.0)
    scores = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)
    return jnp.einsum("bcij,bcijh,bcjhp->bcihp", scores, L, xc)


def decode_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                         valid_len: jnp.ndarray) -> jnp.ndarray:
    """One-token decode. q: (B,K,G,hd); k/v: (B,C,K,hd);
    valid_len: () int32 — slots [0, valid_len) are live. -> (B,K,G,hd)."""
    b, c, kh, hd = k.shape
    scale = hd ** -0.5
    s = jnp.einsum("bkgd,bckd->bkgc", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    ok = jnp.arange(c)[None, None, None, :] < valid_len
    s = jnp.where(ok, s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bkgc,bckd->bkgd", w, v.astype(jnp.float32))


def schedule_replay_ref(order, compute, parent_idx, parent_mb, child_idx,
                        child_mb, app_id, deadline, pinned, power,
                        cost_per_sec, inv_bw, tran_cost, link_ok, X,
                        faithful: bool = True
                        ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Oracle for ``schedule_sim.schedule_replay_folded`` — Algorithm 2
    replayed with a plain Python layer loop, vectorized over particles.

    Same padded contract as the kernel: ``order``/parent/child ids padded
    -1, apps padded deadline +inf; ``X`` is (P, max_p) int32. Returns
    per-particle ``(total_cost, feasible, time_sum)``. The DAG structure
    is concretized with numpy (this is a definition, not a fast path).
    """
    order = np.asarray(order)
    parent_idx_np = np.asarray(parent_idx)
    child_idx_np = np.asarray(child_idx)
    X = jnp.asarray(X, jnp.int32)
    P, max_p = X.shape
    S = power.shape[0]
    rows = jnp.arange(P)
    lease = jnp.zeros((P, S))
    t_on = jnp.full((P, S), jnp.inf)
    end = jnp.zeros((P, max_p))
    trans = jnp.zeros(P)
    bad = jnp.zeros(P, bool)

    for j in order:
        if j < 0:
            continue
        srv = X[:, j]
        exe = compute[j] / power[srv]
        max_tr = jnp.zeros(P)
        gate = jnp.zeros(P)
        for k in range(parent_idx_np.shape[1]):
            pj = int(parent_idx_np[j, k])
            if pj < 0:
                continue
            psrv = X[:, pj]
            tt = parent_mb[j, k] * inv_bw[psrv, srv]
            max_tr = jnp.maximum(max_tr, tt)
            gate = jnp.maximum(gate, end[:, pj] + tt)
            trans = trans + tran_cost[psrv, srv] * parent_mb[j, k]
            bad = bad | (~link_ok[psrv, srv].astype(bool) & (psrv != srv))
        lease_srv = lease[rows, srv]
        start = lease_srv + max_tr if faithful \
            else jnp.maximum(lease_srv, gate)
        t_end = start + exe
        out_t = jnp.zeros(P)
        for k in range(child_idx_np.shape[1]):
            cj = int(child_idx_np[j, k])
            if cj < 0:
                continue
            csrv = X[:, cj]
            out_t = out_t + child_mb[j, k] * inv_bw[srv, csrv]
            bad = bad | (~link_ok[srv, csrv].astype(bool) & (csrv != srv))
        end = end.at[:, j].set(t_end)
        t_on = t_on.at[rows, srv].min(start)
        lease = lease.at[rows, srv].set(
            lease_srv + exe + out_t if faithful else t_end + out_t)

    app_id_np = np.asarray(app_id)
    feas = jnp.ones(P, bool)
    tsum = jnp.zeros(P)
    for a in range(deadline.shape[0]):
        sel = app_id_np == a
        appc = jnp.maximum(
            jnp.max(jnp.where(jnp.asarray(sel)[None, :], end, -jnp.inf),
                    axis=1), 0.0)
        feas &= appc <= deadline[a]
        tsum += appc
    pin = jnp.asarray(pinned)[None, :]
    feas &= jnp.all((pin < 0) | (X == pin), axis=1)
    used = ~jnp.isinf(t_on)
    comp = jnp.sum(jnp.where(used, cost_per_sec[None, :]
                             * (lease - jnp.where(used, t_on, 0.0)), 0.0),
                   axis=1)
    return comp + trans, feas & ~bad, tsum


def traffic_replay_ref(order, compute, parent_idx, parent_mb, child_idx,
                       child_mb, app_id, deadline, pinned, power,
                       cost_per_sec, inv_bw, tran_cost, link_ok, num_apps,
                       X, arr, faithful: bool = True
                       ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray,
                                  jnp.ndarray, jnp.ndarray]:
    """Oracle for ``traffic_sim.traffic_replay_folded`` — the merged-order
    FCFS traffic replay with a plain Python event loop, vectorized over
    particles.

    Same padded contract as the kernel plus the true app count
    ``num_apps`` and one arrival draw ``arr (max_apps, R)`` (+inf
    padded). The merged order is rebuilt independently here: one
    ``(arrival, request slot, topo position)`` sorted Python list of
    only the REAL steps, so padding never even appears in the walk.
    Static feasibility (pins, links) covers ALL valid layers regardless
    of the arrivals. Returns ``(total_cost, miss_rate, lat_sum,
    static_ok, latency (P, max_apps, R))``.
    """
    order_np = np.asarray(order)
    parent_idx_np = np.asarray(parent_idx)
    child_idx_np = np.asarray(child_idx)
    app_id_np = np.asarray(app_id)
    arr_np = np.asarray(arr, float)
    n_apps = int(num_apps)
    X = jnp.asarray(X, jnp.int32)
    P, max_p = X.shape
    S = power.shape[0]
    max_apps, R = arr_np.shape
    rows = jnp.arange(P)

    # static pass: pins / links over every valid layer (arrival-free)
    bad = jnp.zeros(P, bool)
    for j in order_np:
        if j < 0:
            continue
        srv = X[:, j]
        for k in range(parent_idx_np.shape[1]):
            pj = int(parent_idx_np[j, k])
            if pj >= 0:
                psrv = X[:, pj]
                bad = bad | (~link_ok[psrv, srv].astype(bool)
                             & (psrv != srv))
        for k in range(child_idx_np.shape[1]):
            cj = int(child_idx_np[j, k])
            if cj >= 0:
                csrv = X[:, cj]
                bad = bad | (~link_ok[srv, csrv].astype(bool)
                             & (csrv != srv))
    pin = jnp.asarray(pinned)[None, :]
    static_ok = jnp.all((pin < 0) | (X == pin), axis=1) & ~bad

    # merged (arrival, slot, topo) order over the real steps only
    steps = []
    for m, j in enumerate(order_np):
        if j < 0:
            continue
        a = int(app_id_np[j])
        for r in range(R):
            if a < n_apps and np.isfinite(arr_np[a, r]):
                steps.append((float(arr_np[a, r]), r, m, int(j)))
    steps.sort(key=lambda s: (s[0], s[1], s[2]))

    lease = jnp.zeros((P, S))
    t_on = jnp.full((P, S), jnp.inf)
    end = jnp.zeros((P, R, max_p))
    trans = jnp.zeros(P)
    for a_t, r, _m, j in steps:
        srv = X[:, j]
        exe = compute[j] / power[srv]
        max_tr = jnp.zeros(P)
        gate = jnp.zeros(P)
        for k in range(parent_idx_np.shape[1]):
            pj = int(parent_idx_np[j, k])
            if pj < 0:
                continue
            psrv = X[:, pj]
            tt = parent_mb[j, k] * inv_bw[psrv, srv]
            max_tr = jnp.maximum(max_tr, tt)
            gate = jnp.maximum(gate, end[:, r, pj] + tt)
            trans = trans + tran_cost[psrv, srv] * parent_mb[j, k]
        out_t = jnp.zeros(P)
        for k in range(child_idx_np.shape[1]):
            cj = int(child_idx_np[j, k])
            if cj < 0:
                continue
            out_t = out_t + child_mb[j, k] * inv_bw[srv, X[:, cj]]
        lease_srv = lease[rows, srv]
        if faithful:
            base = jnp.maximum(lease_srv, a_t)
            start = base + max_tr
            new_lease = base + exe + out_t
        else:
            start = jnp.maximum(lease_srv, jnp.maximum(gate, a_t))
            new_lease = start + exe + out_t
        t_end = start + exe
        end = end.at[:, r, j].set(t_end)
        t_on = t_on.at[rows, srv].min(start)
        lease = lease.at[rows, srv].set(new_lease)

    latency = jnp.zeros((P, max_apps, R))
    miss_cnt = jnp.zeros(P)
    n_req = 0
    for a in range(max_apps):
        sel = jnp.asarray(app_id_np == a)[None, None, :]
        for r in range(R):
            if not (a < n_apps and np.isfinite(arr_np[a, r])):
                continue
            n_req += 1
            appc = jnp.max(jnp.where(sel[:, 0], end[:, r], -jnp.inf),
                           axis=1)
            lat = appc - arr_np[a, r]
            latency = latency.at[:, a, r].set(lat)
            miss_cnt = miss_cnt + (lat > deadline[a])
    used = ~jnp.isinf(t_on)
    comp = jnp.sum(jnp.where(used, cost_per_sec[None, :]
                             * (lease - jnp.where(used, t_on, 0.0)), 0.0),
                   axis=1)
    lat_sum = jnp.sum(latency, axis=(1, 2))
    return (comp + trans, miss_cnt / max(n_req, 1), lat_sum, static_ok,
            latency)
