"""Server integration: batched generate on reduced configs, and the
--plan --traffic CLI smoke path (backend plumbing end to end)."""
import dataclasses
import sys

import numpy as np
import pytest

from repro.configs import get
from repro.launch.serve import Server


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "mamba2-2.7b",
                                  "gemma3-27b"])
def test_generate(arch):
    cfg = get(arch).reduced()
    srv = Server(cfg, batch=2, prompt_len=16, max_new=6, eos_id=-1)
    params = srv.init_params()
    rng = np.random.default_rng(0)
    batch = {"tokens": rng.integers(2, cfg.vocab, (2, 16)).astype(np.int32)}
    out = srv.generate(params, batch)
    assert out["tokens"].shape == (2, 6)
    assert out["tokens_generated"] == 12
    assert (out["tokens"] >= 0).all() and (out["tokens"] < cfg.vocab).all()


def test_generate_greedy_deterministic():
    cfg = get("qwen3-0.6b").reduced()
    srv = Server(cfg, batch=2, prompt_len=8, max_new=4, eos_id=-1)
    params = srv.init_params(seed=1)
    rng = np.random.default_rng(1)
    batch = {"tokens": rng.integers(2, cfg.vocab, (2, 8)).astype(np.int32)}
    a = srv.generate(params, batch)["tokens"]
    b = srv.generate(params, batch)["tokens"]
    np.testing.assert_array_equal(a, b)


class _StubServer:
    """Skips the real model build after the plan block (the smoke test
    only exercises the planning CLI, DESIGN.md §10)."""
    def __init__(self, *a, **k):
        pass

    def init_params(self, seed=0):
        return None

    def generate(self, params, batch):
        return {"tokens": np.zeros((1, 16), np.int32),
                "tokens_generated": 0, "prefill_s": 0.0, "decode_s": 0.0,
                "decode_tok_per_s": 0.0}


def test_serve_plan_traffic_backend_smoke(monkeypatch, capsys):
    """`serve --plan --traffic --fitness-backend pallas` stamps the
    RESOLVED backend into every emitted plan and the report line. The
    real batched planner runs (shrunk swarm, first shape only)."""
    import repro.core as core
    import repro.launch.serve as serve_mod

    real = core.plan_offload_batch
    captured = {}

    def spy(items, env, pso, fitness_backend, traffic, mesh=None):
        pso = dataclasses.replace(pso, pop_size=8, max_iters=4,
                                  stall_iters=2)
        plans = real(items[:1], env=env, pso=pso,
                     fitness_backend=fitness_backend, traffic=traffic,
                     mesh=mesh)
        captured["plans"] = plans
        return plans                    # zip(shapes, plans) truncates

    monkeypatch.setattr(core, "plan_offload_batch", spy)
    monkeypatch.setattr(serve_mod, "Server", _StubServer)
    monkeypatch.setattr(sys, "argv",
                        ["serve", "--arch", "qwen3-0.6b", "--reduced",
                         "--plan", "--traffic", "poisson",
                         "--fitness-backend", "pallas"])
    serve_mod.main()
    out = capsys.readouterr().out
    plans = captured["plans"]
    assert plans and all(p.backend == "pallas" for p in plans)
    assert "(backend=pallas)" in out
    assert "poisson traffic" in out


def test_serve_service_cli_smoke(monkeypatch, capsys):
    """`serve --plan --serve wifi-fade --chaos` runs the always-on
    planning service end to end (shrunk swarm, first shape only) and
    prints per-round rungs plus the availability summary — and never
    falls through to LM serving."""
    import repro.core as core
    import repro.launch.serve as serve_mod

    real_plan = core.plan_offload_batch
    real_service = core.run_service
    captured = {}

    def plan_spy(items, env, pso, fitness_backend, traffic, mesh=None):
        pso = dataclasses.replace(pso, pop_size=8, max_iters=4,
                                  stall_iters=2)
        return real_plan(items[:1], env=env, pso=pso,
                         fitness_backend=fitness_backend, traffic=traffic,
                         mesh=mesh)

    def service_spy(dags, trace, cfg, seed=0, **kw):
        small = dataclasses.replace(
            cfg.replan, pso=dataclasses.replace(
                cfg.replan.pso, pop_size=8, max_iters=4, stall_iters=2))
        rep = real_service(dags, trace,
                           dataclasses.replace(cfg, replan=small),
                           seed=seed, **kw)
        captured["report"] = rep
        return rep

    monkeypatch.setattr(core, "plan_offload_batch", plan_spy)
    monkeypatch.setattr(core, "run_service", service_spy)
    monkeypatch.setattr(serve_mod, "Server", _StubServer)
    monkeypatch.setattr(sys, "argv",
                        ["serve", "--arch", "qwen3-0.6b", "--reduced",
                         "--plan", "--serve", "wifi-fade",
                         "--serve-rounds", "3", "--chaos"])
    serve_mod.main()
    out = capsys.readouterr().out
    rep = captured["report"]
    assert len(rep.cold) == 1            # admission plans handed in as-is
    assert len(rep.rounds) == 2
    # --chaos with 3 rounds lands every fault on round 2, deterministic
    assert rep.counters["stale_env_rounds"] == 1
    assert rep.counters["retries"] == 1
    assert "[serve] service round 1" in out
    assert "availability" in out and "fallbacks" in out
