"""Dry-run machinery: HLO collective parser units + a subprocess
mini-matrix on 8 placeholder devices (the full 512-device matrix runs via
``python -m repro.launch.dryrun --all``; results in EXPERIMENTS.md)."""
import json
import os
import subprocess
import sys

import pytest

from repro.launch.analysis import (collective_bytes,
                                   parse_hlo_collectives, roofline_terms)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# parser units
# ---------------------------------------------------------------------------

HLO_SAMPLE = """
  %ag = bf16[4096,3072]{1,0} all-gather(bf16[256,3072]{1,0} %x), replica_groups=[16,16]<=[256], dimensions={0}
  %ar = f32[] all-reduce(f32[] %y), channel_id=1, replica_groups={{0,1,2,3}}, to_apply=%add
  %rs = f32[64,128]{1,0} reduce-scatter(f32[1024,128]{1,0} %z), replica_groups=[1,16]<=[16], dimensions={0}
  %cp = bf16[8,8]{1,0} collective-permute(bf16[8,8]{1,0} %w), source_target_pairs={{0,1}}
  %aa = (f32[16,16]{1,0}, f32[16,16]{1,0}) all-to-all(f32[16,16]{1,0} %a, f32[16,16]{1,0} %b), replica_groups=[2,8]<=[16]
"""


def test_parse_hlo_collectives():
    ops = parse_hlo_collectives(HLO_SAMPLE)
    kinds = [o[0] for o in ops]
    assert kinds == ["all-gather", "all-reduce", "reduce-scatter",
                     "collective-permute", "all-to-all"]
    ag = ops[0]
    assert ag[1] == 4096 * 3072 * 2      # result bytes
    assert ag[2] == 16                   # group size (iota form)
    ar = ops[1]
    assert ar[1] == 4 and ar[2] == 4     # scalar f32, explicit group of 4
    aa = ops[4]
    assert aa[1] == 2 * 16 * 16 * 4      # tuple result summed


def test_collective_bytes_accounting():
    stats = collective_bytes(HLO_SAMPLE)
    assert stats.count == 5
    assert stats.total_dcn == 0.0
    # all-gather: (g-1)/g * result
    ag = 15 / 16 * 4096 * 3072 * 2
    assert abs(stats.per_op["all-gather"] - ag) < 1.0


def test_pod_crossing_detection():
    # explicit group spanning both pods of 8 in a 16-device fleet
    hlo = ("%ar = f32[128]{0} all-reduce(f32[128]{0} %x), "
           "replica_groups={{0,8}}, to_apply=%add")
    stats = collective_bytes(hlo, pod_size=8)
    assert stats.total_dcn > 0 and stats.total_ici == 0.0
    stats1 = collective_bytes(hlo, pod_size=0)
    assert stats1.total_dcn == 0.0


def test_roofline_terms_dominant():
    stats = collective_bytes("")
    t = roofline_terms(197e12, 819e9 * 0.1, stats)
    assert t["compute_s"] == pytest.approx(1.0)
    assert t["dominant"] == "compute"


# ---------------------------------------------------------------------------
# subprocess mini-matrix (8 placeholder devices, full configs)
# ---------------------------------------------------------------------------

def run_dryrun(args, devices="8"):
    env = dict(os.environ, REPRO_DRYRUN_DEVICES=devices,
               PYTHONPATH=os.path.join(REPO, "src"))
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun"] + args,
        capture_output=True, text=True, env=env, cwd=REPO, timeout=1200)


@pytest.mark.slow
def test_mini_dryrun_single_pod(tmp_path):
    out = tmp_path / "cell.json"
    r = run_dryrun(["--arch", "qwen3-0.6b", "--shape", "decode_32k",
                    "--test-mesh", "--out", str(out)])
    assert r.returncode == 0, r.stdout + r.stderr
    rec = json.loads(out.read_text())
    assert rec["status"] == "ok"
    assert rec["mesh"] == {"data": 4, "model": 2}
    assert rec["flops_per_chip"] > 0
    assert rec["roofline"]["dominant"] in ("compute", "memory",
                                           "collective")


@pytest.mark.slow
def test_mini_dryrun_multi_pod(tmp_path):
    out = tmp_path / "cell.json"
    r = run_dryrun(["--arch", "qwen3-0.6b", "--shape", "decode_32k",
                    "--test-mesh", "--multi-pod", "--out", str(out)])
    assert r.returncode == 0, r.stdout + r.stderr
    rec = json.loads(out.read_text())
    assert rec["status"] == "ok"
    assert rec["mesh"] == {"pod": 2, "data": 2, "model": 2}


@pytest.mark.slow
def test_mini_dryrun_skips_long_context_full_attn(tmp_path):
    out = tmp_path / "cell.json"
    r = run_dryrun(["--arch", "gemma-7b", "--shape", "long_500k",
                    "--test-mesh", "--out", str(out)])
    assert r.returncode == 0
    rec = json.loads(out.read_text())
    assert rec["status"] == "skipped"
