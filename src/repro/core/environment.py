"""Hybrid computing environment model (paper §III-A, Tables II–IV).

Servers s_i = <p_i, c_i^com, t_i>:
  * p_i      — compute power (work units / second; Eq. 4: T_exe = a / p)
  * c_com    — rental cost in $/second (paper quotes $/hour; we store $/s)
  * tier t_i — 0 = cloud, 1 = edge, 2 = end device

Bandwidth b_ij = <ℓ_ij, c_ij^tran>:
  * ℓ in MB/s, c_tran in $/MB (paper quotes $/GB; we store $/MB)
  * no device↔device links (no ad-hoc network): ℓ = 0
  * each end device reaches only its (two) adjacent edge servers over WIFI
  * transfers between a server and itself are free and instantaneous.

The paper's experimental fleet (Table IV + Table III) is reproduced by
``paper_environment()``. ``tpu_fleet_environment()`` instantiates the same
*structure* for a heterogeneous TPU fleet (cloud pod / edge slices /
single-chip device nodes) — see DESIGN.md §3.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

CLOUD, EDGE, DEVICE = 0, 1, 2
#: Bandwidth placeholder for "no link" — simulator maps it to +inf time.
NO_LINK = 0.0

__all__ = [
    "Environment", "paper_environment", "sample_environment",
    "tpu_fleet_environment", "CLOUD", "EDGE", "DEVICE",
]


@dataclasses.dataclass
class Environment:
    """A fleet of servers plus dense bandwidth/cost matrices.

    Attributes:
      power: (S,) float64 — work units per second per server.
      cost_per_sec: (S,) float64 — $/second rental while turned on.
      tier: (S,) int32 — 0 cloud / 1 edge / 2 device.
      bandwidth: (S, S) float64 MB/s; 0 means no link (infeasible).
      tran_cost: (S, S) float64 $/MB.
    """

    power: np.ndarray
    cost_per_sec: np.ndarray
    tier: np.ndarray
    bandwidth: np.ndarray
    tran_cost: np.ndarray

    def __post_init__(self) -> None:
        self.power = np.asarray(self.power, np.float64)
        self.cost_per_sec = np.asarray(self.cost_per_sec, np.float64)
        self.tier = np.asarray(self.tier, np.int32)
        self.bandwidth = np.asarray(self.bandwidth, np.float64)
        self.tran_cost = np.asarray(self.tran_cost, np.float64)
        s = self.num_servers
        assert self.bandwidth.shape == (s, s), "bandwidth must be (S,S)"
        assert self.tran_cost.shape == (s, s), "tran_cost must be (S,S)"
        # self-links: free + instantaneous (simulator relies on this)
        np.fill_diagonal(self.bandwidth, np.inf)
        np.fill_diagonal(self.tran_cost, 0.0)

    @property
    def num_servers(self) -> int:
        return int(self.power.shape[0])

    def servers_of_tier(self, t: int) -> np.ndarray:
        return np.nonzero(self.tier == t)[0]


def _tier_matrices(tier: np.ndarray,
                   bw_table: np.ndarray,
                   cost_table: np.ndarray,
                   device_edge_adjacency: Optional[np.ndarray] = None
                   ) -> tuple[np.ndarray, np.ndarray]:
    """Expand 3x3 tier-level tables into per-server matrices.

    device_edge_adjacency: optional (n_device, n_edge) bool mask restricting
    which edge servers each end device can reach (paper: two nearby edge
    servers per device). Devices not adjacent to an edge server get ℓ=0.
    """
    s = tier.shape[0]
    bw = bw_table[tier[:, None], tier[None, :]].astype(np.float64).copy()
    tc = cost_table[tier[:, None], tier[None, :]].astype(np.float64).copy()
    if device_edge_adjacency is not None:
        dev_idx = np.nonzero(tier == DEVICE)[0]
        edge_idx = np.nonzero(tier == EDGE)[0]
        adj = np.asarray(device_edge_adjacency, bool)
        assert adj.shape == (dev_idx.size, edge_idx.size)
        for a, d in enumerate(dev_idx):
            for b, e in enumerate(edge_idx):
                if not adj[a, b]:
                    bw[d, e] = bw[e, d] = NO_LINK
    return bw, tc


# Paper Table III — tier-level bandwidth (MB/s) and cost ($/GB -> $/MB).
_PAPER_BW = np.array([
    [5.0, 2.0, 2.0],   # cloud <-> {cloud, edge, device}
    [2.0, 10.0, 10.0],  # edge  <-> {cloud, edge, device}
    [2.0, 10.0, 0.0],   # device<-> {cloud, edge, device(no ad-hoc)}
])
_PAPER_TC = np.array([
    [0.4, 0.8, 0.8],
    [0.8, 0.16, 0.16],
    [0.8, 0.16, 0.0],
]) / 1024.0  # $/GB -> $/MB


def paper_environment(ring_adjacency: bool = True) -> Environment:
    """The 20-server fleet of paper Table IV.

    s_1..s_10  : end devices, 2 CPUs, free.
    s_11..s_15 : edge, 16 CPUs, $2.43/h.
    s_16..s_20 : cloud, {4,8,16,32,64} CPUs, {0.225,...,3.6}/h.

    Power is measured in CPU counts (the paper: "processing capacity ...
    roughly proportional to its cost"; we use the CPU count directly so
    Eq. 4's a/p has a concrete unit: a = CPU-seconds).
    """
    power = np.array([2.0] * 10 + [16.0] * 5 + [4.0, 8.0, 16.0, 32.0, 64.0])
    cost_h = np.array([0.0] * 10 + [2.43] * 5 + [0.225, 0.45, 0.9, 1.8, 3.6])
    tier = np.array([DEVICE] * 10 + [EDGE] * 5 + [CLOUD] * 5, np.int32)
    adj = None
    if ring_adjacency:
        # device i (0..9) reaches edge servers (i % 5) and ((i+1) % 5)
        adj = np.zeros((10, 5), bool)
        for i in range(10):
            adj[i, i % 5] = True
            adj[i, (i + 1) % 5] = True
    bw, tc = _tier_matrices(tier, _PAPER_BW, _PAPER_TC, adj)
    return Environment(power=power, cost_per_sec=cost_h / 3600.0,
                       tier=tier, bandwidth=bw, tran_cost=tc)


def sample_environment() -> Environment:
    """The 6-server illustrative fleet of paper Fig. 2 / Tables I–III.

    Power calibrated from Table I (execution times of l1..l3 on s0..s5):
    we fit p_k so a_j / p_k reproduces Table I as closely as possible
    with p normalized to the end device having power 1.
    """
    # Table I times for layers l1..l3 on servers s0..s5.
    times = np.array([
        [1.92, 0.98, 0.62, 0.31, 0.19, 0.09],
        [2.35, 1.20, 0.75, 0.67, 0.41, 0.32],
        [2.12, 1.00, 0.80, 0.56, 0.45, 0.21],
    ])
    # Least-squares fit in log space: log t_jk = log a_j - log p_k.
    logt = np.log(times)
    la = logt.mean(axis=1)
    lp = (la[:, None] - logt).mean(axis=0)
    lp -= lp[0]  # normalize p_0 = 1 -> a in device-seconds
    power = np.exp(lp)
    cost_h = np.array([0.0, 10.0, 15.0, 1.0, 2.0, 3.0])
    tier = np.array([DEVICE, CLOUD, CLOUD, EDGE, EDGE, EDGE], np.int32)
    bw, tc = _tier_matrices(tier, _PAPER_BW, _PAPER_TC)
    return Environment(power=power, cost_per_sec=cost_h / 3600.0,
                       tier=tier, bandwidth=bw, tran_cost=tc)


def tpu_fleet_environment(
    cloud_slices: Sequence[int] = (256, 256),
    edge_slices: Sequence[int] = (8, 8, 8, 8),
    device_nodes: int = 8,
    chip_flops: float = 197e12,          # bf16 peak / chip (v5e)
    mfu: float = 0.4,
    cloud_cost_chip_h: float = 1.20,     # on-demand $/chip-hour
    edge_cost_chip_h: float = 2.40,      # edge capacity is scarcer
) -> Environment:
    """The paper's environment structure instantiated for a TPU fleet.

    Power is *effective* TFLOP/s (peak × MFU) so a layer's compute amount
    is its FLOP count. Bandwidths: DCN between cloud slices 25 GB/s, WAN
    cloud↔edge 1 GB/s, edge↔edge 10 GB/s metro, edge↔device 100 MB/s
    (5G/WIFI), cloud↔device 50 MB/s. $/MB transfer costs follow typical
    egress pricing (cloud egress dominates).
    """
    n_c, n_e, n_d = len(cloud_slices), len(edge_slices), device_nodes
    power = np.array(
        [c * chip_flops * mfu for c in cloud_slices]
        + [e * chip_flops * mfu for e in edge_slices]
        # device tier = Jetson-class edge SoC, ~2% of a v5e chip effective
        + [1 * chip_flops * mfu * 0.02] * n_d)
    cost_h = np.array(
        [c * cloud_cost_chip_h for c in cloud_slices]
        + [e * edge_cost_chip_h for e in edge_slices]
        + [0.0] * n_d)
    tier = np.array([CLOUD] * n_c + [EDGE] * n_e + [DEVICE] * n_d, np.int32)
    bw_table = np.array([
        [25e3, 1e3, 50.0],
        [1e3, 10e3, 100.0],
        [50.0, 100.0, 0.0],
    ])  # MB/s
    tc_table = np.array([
        [0.01, 0.09, 0.09],
        [0.09, 0.02, 0.0],
        [0.09, 0.0, 0.0],
    ]) / 1024.0  # $/GB -> $/MB (egress-style pricing)
    adj = np.zeros((n_d, n_e), bool)
    for i in range(n_d):
        adj[i, i % n_e] = True
        adj[i, (i + 1) % n_e] = True
    bw, tc = _tier_matrices(tier, bw_table, tc_table, adj)
    return Environment(power=power, cost_per_sec=cost_h / 3600.0,
                       tier=tier, bandwidth=bw, tran_cost=tc)
