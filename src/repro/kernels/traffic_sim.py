"""Pallas TPU kernel replaying the merged-order FCFS traffic replay for a
whole particle tile (DESIGN.md §10).

``core.traffic.simulate_traffic_swarm`` is the hot loop of every
traffic-aware solve: R request copies of the schedule × the swarm × every
PSO-GA iteration. Its scan pays per-step dispatch for all ``R·max_p``
merged steps — including every padded-layer and padded-request no-op.
This kernel is the queue-aware twin of ``kernels/schedule_sim.py``: the
merged event walk moves *inside* one ``pallas_call`` so the whole
``(P, R·max_p)`` replay is a single fused program, and the walk itself
only covers the ``n_valid`` REAL steps (see below).

  * grid ``(num_particle_tiles,)`` — one grid cell replays ``tile_p``
    particles; ``jax.vmap`` over Monte-Carlo arrival seeds (and over the
    fleet axis in ``core.batch._fleet_runner``) adds outer grid
    dimensions.
  * VMEM carry per tile: per-server queue tails ``lease (tile_p, S)``,
    first-use ``t_on (tile_p, S)``, per-(request, layer) end times
    ``end (tile_p, R·max_p)``, plus a ``(tile_p, 1)`` transmission-cost
    accumulator strip.
  * the merged ``(arrival, slot, topo)`` event order is precomputed on
    the host side of the call with padding COMPACTED to the tail: the
    sort key is ``arrival`` for real steps and +inf for padded-layer /
    padded-request steps, so all valid steps form a contiguous prefix
    and the kernel's ``fori_loop`` runs ``n_valid`` iterations instead
    of ``R·max_p``. Compaction is order-preserving — valid steps keep
    their exact keys and the ``(request slot, topo position)``
    tie-break, so the lease/end/t_on evolution is step-for-step the
    scan's (masked no-ops were exact identities).
  * each step applies the arrival start-gate on-chip —
    ``max(lease[s], a_r)`` in faithful mode, ``max(lease[s], a_r,
    parent end + transfer)`` in corrected mode — and the epilogue folds
    the per-(app, request) completion latencies into the deadline-miss
    rate and Σ-latency reductions the contention fitness key needs.

Static feasibility (pins honored, links legal) is arrival-independent,
so it is computed OUTSIDE the walk from ALL valid layers — a plan with
an illegal link is infeasible even for requests that never arrive.

No ``repro.core`` imports here: the kernel layer stays below core
(DESIGN.md §1); the problem arrives as raw padded arrays and the
contention key (miss budget, MISS_PENALTY branch) is applied by
``core.fitness``. Validated in interpret mode against
``ref.traffic_replay_ref``, the scan engine, and the numpy DES oracle
(``tests/test_traffic_kernel.py``). This container is CPU-only and TPU
is the TARGET, but the fusion already pays off here: interpret mode
lowers to plain XLA and beats the scan backend 1.5–1.8× (EXPERIMENTS.md
§Traffic) because the kernel never materializes the scan's per-step
``(T, …)`` gathers or ``(P, T)`` one-hot selects.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .schedule_sim import DEFAULT_TILE_P

__all__ = ["traffic_replay_folded"]


def _traffic_kernel(srv_ref, exe_ref, mt_ref, ot_ref, tr_ref, tt_ref,
                    pstep_ref, qm_ref, slotm_ref, slot0_ref, arrm_ref,
                    nv_ref, app_id_ref, deadline_ref, rv_ref, arr2_ref,
                    cost_ref,
                    total_ref, miss_ref, lat_ref, latency_ref,
                    lease_s, t_on_s, end_s, acc_s, *,
                    tile_p: int, max_p: int, max_in: int, max_S: int,
                    max_apps: int, R: int, faithful: bool):
    SRV = srv_ref[:]                               # (T, max_p) int32
    EXE = exe_ref[:]                               # (T, max_p) f32
    MT = mt_ref[:]
    OT = ot_ref[:]
    TR = tr_ref[:]
    TT = tt_ref[:]                                 # (T, max_p, max_in)
    col_S = jax.lax.broadcasted_iota(jnp.int32, (tile_p, max_S), 1)

    lease_s[:] = jnp.zeros((tile_p, max_S), jnp.float32)
    t_on_s[:] = jnp.full((tile_p, max_S), jnp.inf, jnp.float32)
    end_s[:] = jnp.zeros((tile_p, R * max_p), jnp.float32)
    acc_s[:] = jnp.zeros((tile_p, 1), jnp.float32)  # [trans_cost]

    def body(t, _):
        q = qm_ref[t]                              # topo position, scalar
        slot = slotm_ref[t]                        # r·max_p + layer id
        slot0 = slot0_ref[t]                       # r·max_p
        a_t = arrm_ref[t]                          # request arrival time
        srv = jax.lax.dynamic_slice(SRV, (0, q), (tile_p, 1))[:, 0]
        srv_ohf = (col_S == srv[:, None]).astype(jnp.float32)
        lease = lease_s[:]
        lease_srv = jnp.sum(lease * srv_ohf, axis=1)
        exe = jax.lax.dynamic_slice(EXE, (0, q), (tile_p, 1))[:, 0]
        ot = jax.lax.dynamic_slice(OT, (0, q), (tile_p, 1))[:, 0]
        tr = jax.lax.dynamic_slice(TR, (0, q), (tile_p, 1))[:, 0]
        if faithful:
            mt = jax.lax.dynamic_slice(MT, (0, q), (tile_p, 1))[:, 0]
            base = jnp.maximum(lease_srv, a_t)
            start = base + mt
            new_lease = base + exe + ot
        else:
            end = end_s[:]
            gate = jnp.zeros((tile_p,), jnp.float32)
            for k in range(max_in):                # DAG structure: scalars
                pj = pstep_ref[q, k]
                pmask = pj >= 0
                pslot = slot0 + jnp.maximum(pj, 0)
                ep = jax.lax.dynamic_slice(end, (0, pslot),
                                           (tile_p, 1))[:, 0]
                ttk = jax.lax.dynamic_slice(TT, (0, q, k),
                                            (tile_p, 1, 1))[:, 0, 0]
                gate = jnp.maximum(gate, jnp.where(pmask, ep + ttk, 0.0))
            gate = jnp.maximum(gate, a_t)
            start = jnp.maximum(lease_srv, gate)
            new_lease = start + exe + ot
        t_end = start + exe
        lease_s[:] = jnp.where(srv_ohf > 0, new_lease[:, None], lease)
        t_on_s[:] = jnp.minimum(
            t_on_s[:], jnp.where(srv_ohf > 0, start[:, None], jnp.inf))
        end_s[:, pl.ds(slot, 1)] = t_end[:, None]
        acc_s[:] = acc_s[:] + tr[:, None]
        return 0

    # only the compacted valid prefix is walked — padded-layer and
    # +inf-request steps sort past n_valid and are never touched.
    jax.lax.fori_loop(0, nv_ref[0], body, 0)

    end = end_s[:]
    lease = lease_s[:]
    t_on = t_on_s[:]
    app_id = app_id_ref[:]                         # (max_p,)
    rv = rv_ref[:]                                 # (max_apps·R,) 1 = real
    arr2 = arr2_ref[:]                             # arrivals, 0 if padded
    miss_cnt = jnp.zeros((tile_p,), jnp.float32)
    lat_sum = jnp.zeros((tile_p,), jnp.float32)
    for a in range(max_apps):                      # small static loops
        sel = (app_id == a)[None, :]
        for r in range(R):
            seg = end[:, r * max_p:(r + 1) * max_p]
            appc = jnp.max(jnp.where(sel, seg, -jnp.inf), axis=1)
            real = rv[a * R + r] > 0
            latv = jnp.where(real, appc - arr2[a * R + r], 0.0)
            latency_ref[:, a * R + r] = latv
            miss_cnt += jnp.where(real & (latv > deadline_ref[a]), 1.0, 0.0)
            lat_sum += latv
    n_req = jnp.maximum(jnp.sum(rv), 1.0)
    used = ~jnp.isinf(t_on)
    t_on_safe = jnp.where(used, t_on, 0.0)
    comp = jnp.sum(jnp.where(used, cost_ref[:][None, :]
                             * (lease - t_on_safe), 0.0), axis=1)
    total_ref[:] = comp + acc_s[:][:, 0]
    miss_ref[:] = miss_cnt / n_req
    lat_ref[:] = lat_sum


def traffic_replay_folded(
        order: jnp.ndarray, compute: jnp.ndarray, parent_idx: jnp.ndarray,
        parent_mb: jnp.ndarray, child_idx: jnp.ndarray,
        child_mb: jnp.ndarray, app_id: jnp.ndarray, deadline: jnp.ndarray,
        pinned: jnp.ndarray, power: jnp.ndarray, cost_per_sec: jnp.ndarray,
        inv_bw: jnp.ndarray, tran_cost: jnp.ndarray, link_ok: jnp.ndarray,
        num_apps: jnp.ndarray, X: jnp.ndarray, arr: jnp.ndarray, *,
        faithful: bool = True, tile_p: int = DEFAULT_TILE_P,
        interpret: bool = True
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray,
           jnp.ndarray]:
    """Queue-aware FCFS replay of one arrival draw for every particle.

    Args use the padded-problem layout of ``core.simulator.PaddedProblem``
    plus its true app count ``num_apps`` (a 0-d int32, traced per problem
    under the fleet vmap); ``X`` is ``(P, max_p)`` int32 assignments and
    ``arr`` is ``(max_apps, R)`` per-app request timestamps, +inf padded.
    Returns per-particle ``(total_cost (P,), miss_rate (P,), lat_sum
    (P,), static_ok (P,) bool, latency (P, max_apps, R))`` — the summary
    ``core.fitness.make_swarm_fitness(arrivals=...)`` folds into the
    contention key, with the full latency grid kept for request-level
    differential testing.
    """
    X = jnp.asarray(X).astype(jnp.int32)
    arr = jnp.asarray(arr).astype(jnp.float32)
    P, max_p = X.shape
    max_S = power.shape[0]
    max_in = parent_idx.shape[1]
    max_apps = deadline.shape[0]
    R = arr.shape[-1]
    T = R * max_p

    f32 = functools.partial(jnp.asarray, dtype=jnp.float32)
    i32 = functools.partial(jnp.asarray, dtype=jnp.int32)
    order = i32(order)
    app_ids = i32(app_id)
    inv_bw_f = f32(inv_bw)
    link_b = jnp.asarray(link_ok).astype(bool)

    # ---- phase 1: carry-independent per-(particle, layer) quantities —
    # the kernel-layer twin of ``core.simulator._swarm_phase1`` ----
    valid = order >= 0
    jsafe = jnp.where(valid, order, 0)
    srv = jnp.take(X, jsafe, axis=1)                       # (P, max_p)
    exe = f32(compute)[jsafe][None, :] / f32(power)[srv]
    pars = i32(parent_idx)[jsafe]                          # (max_p, max_in)
    pmask = (pars >= 0) & valid[:, None]
    psafe = jnp.where(pmask, pars, 0)
    psrv = jnp.take(X, psafe, axis=1)                      # (P, max_p, max_in)
    srv_b = srv[:, :, None]
    mb = f32(parent_mb)[jsafe][None, :, :]
    tt = mb * inv_bw_f[psrv, srv_b]
    pm = pmask[None, :, :]
    max_trans = jnp.max(jnp.where(pm, tt, 0.0), axis=2, initial=0.0)
    tr_step = jnp.sum(jnp.where(pm, f32(tran_cost)[psrv, srv_b] * mb, 0.0),
                      axis=2)                              # (P, max_p)
    link_bad = jnp.any(pm & ~link_b[psrv, srv_b] & (psrv != srv_b),
                       axis=(1, 2))
    kids = i32(child_idx)[jsafe]
    kmask = ((kids >= 0) & valid[:, None])[None, :, :]
    ksrv = jnp.take(X, jnp.where(kmask[0], kids, 0), axis=1)
    out_t = jnp.sum(jnp.where(kmask, f32(child_mb)[jsafe][None]
                              * inv_bw_f[srv_b, ksrv], 0.0), axis=2)
    link_bad = link_bad | jnp.any(
        kmask & ~link_b[srv_b, ksrv] & (ksrv != srv_b), axis=(1, 2))
    pin = i32(pinned)[None, :]
    # arrival-independent: covers ALL valid layers, walked or not
    static_ok = jnp.all((pin < 0) | (X == pin), axis=1) & ~link_bad

    # ---- merged (arrival, slot, topo) order, padding compacted ----
    # padded-layer steps take key +inf, joining +inf-request steps at
    # the tail; valid steps keep their exact keys so the stable
    # (arrival, request slot, topo position) order among them is
    # unchanged — the walk covers exactly the first n_valid entries.
    app = app_ids[jsafe]
    rep_t = jnp.tile(jnp.arange(max_p), R)
    rep_r = jnp.repeat(jnp.arange(R), max_p)
    key = jnp.where(valid[rep_t], arr[app[rep_t], rep_r], jnp.inf)
    perm = jnp.lexsort((rep_t, rep_r, key))
    q_m = rep_t[perm].astype(jnp.int32)                    # (T,)
    r_m = rep_r[perm]
    key_m = key[perm]
    valid_m = jnp.isfinite(key_m)
    nv = jnp.sum(valid_m).astype(jnp.int32)[None]          # (1,)
    slot_m = (r_m * max_p + jsafe[q_m]).astype(jnp.int32)
    slot0_m = (r_m * max_p).astype(jnp.int32)
    arr_m = jnp.where(valid_m, key_m, 0.0).astype(jnp.float32)
    pstep = jnp.where(pmask, psafe, -1).astype(jnp.int32)  # (max_p, max_in)

    app_real = jnp.arange(max_apps) < num_apps
    req_valid = jnp.isfinite(arr) & app_real[:, None]      # (max_apps, R)
    rv = req_valid.astype(jnp.float32).reshape(-1)
    arr2 = jnp.where(req_valid, arr, 0.0).reshape(-1)

    tile_p = min(tile_p, max(P, 1))
    n_tiles = pl.cdiv(P, tile_p)
    p_pad = n_tiles * tile_p
    if p_pad != P:                                 # pad with copies of row 0
        pad = lambda a: jnp.concatenate(
            [a, jnp.broadcast_to(a[:1], (p_pad - P,) + a.shape[1:])], axis=0)
        srv, exe, max_trans, out_t, tr_step, tt = map(
            pad, (srv, exe, max_trans, out_t, tr_step, tt))

    kernel = functools.partial(
        _traffic_kernel, tile_p=tile_p, max_p=max_p, max_in=max_in,
        max_S=max_S, max_apps=max_apps, R=R, faithful=faithful)
    full = lambda shape: pl.BlockSpec(shape, lambda i: (0,) * len(shape))
    total, miss_rate, lat_sum, latency = pl.pallas_call(
        kernel,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((tile_p, max_p), lambda i: (i, 0)),         # srv
            pl.BlockSpec((tile_p, max_p), lambda i: (i, 0)),         # exe
            pl.BlockSpec((tile_p, max_p), lambda i: (i, 0)),         # mt
            pl.BlockSpec((tile_p, max_p), lambda i: (i, 0)),         # ot
            pl.BlockSpec((tile_p, max_p), lambda i: (i, 0)),         # tr
            pl.BlockSpec((tile_p, max_p, max_in), lambda i: (i, 0, 0)),
            full((max_p, max_in)),                                   # pstep
            full((T,)),                                              # q_m
            full((T,)),                                              # slot_m
            full((T,)),                                              # slot0_m
            full((T,)),                                              # arr_m
            full((1,)),                                              # nv
            full((max_p,)),                                          # app_id
            full((max_apps,)),                                       # deadline
            full((max_apps * R,)),                                   # rv
            full((max_apps * R,)),                                   # arr2
            full((max_S,)),                                          # cost
        ],
        out_specs=[pl.BlockSpec((tile_p,), lambda i: (i,))] * 3
        + [pl.BlockSpec((tile_p, max_apps * R), lambda i: (i, 0))],
        out_shape=[
            jax.ShapeDtypeStruct((p_pad,), jnp.float32),
            jax.ShapeDtypeStruct((p_pad,), jnp.float32),
            jax.ShapeDtypeStruct((p_pad,), jnp.float32),
            jax.ShapeDtypeStruct((p_pad, max_apps * R), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((tile_p, max_S), jnp.float32),                # lease
            pltpu.VMEM((tile_p, max_S), jnp.float32),                # t_on
            pltpu.VMEM((tile_p, R * max_p), jnp.float32),            # end
            pltpu.VMEM((tile_p, 1), jnp.float32),                    # trans
        ],
        interpret=interpret,
    )(i32(srv), f32(exe), f32(max_trans), f32(out_t), f32(tr_step), f32(tt),
      pstep, q_m, slot_m, slot0_m, arr_m, nv, app_ids, f32(deadline),
      rv, arr2, f32(cost_per_sec))
    return (total[:P], miss_rate[:P], lat_sum[:P], static_ok,
            latency[:P].reshape(P, max_apps, R))
