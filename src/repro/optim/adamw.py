"""AdamW with fp32 master state, global-norm clipping, warmup+cosine LR,
and ZeRO-1 optimizer-state sharding.

The optimizer state is a plain pytree mirroring the params, so the same
``jax.jit(in_shardings=...)`` machinery that shards params shards it.
``zero1_pspecs`` derives the state PartitionSpecs from the param specs by
additionally sharding each leaf's largest unsharded axis over the data
axes when divisible — the ZeRO-1 trick (state lives sliced across data
ranks; the update is computed on the slice and params are re-broadcast by
GSPMD where needed).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

Params = Any

__all__ = ["AdamWConfig", "OptState", "adamw_init", "adamw_update",
           "cosine_schedule", "global_norm", "zero1_pspecs"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


class OptState(NamedTuple):
    mu: Params          # fp32 first moment
    nu: Params          # fp32 second moment
    count: jnp.ndarray  # () int32


def cosine_schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    decay_steps = jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1)
    t = jnp.clip((step - cfg.warmup_steps) / decay_steps, 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 \
        * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def global_norm(tree: Params) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def adamw_init(params: Params) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return OptState(mu=zeros,
                    nu=jax.tree.map(jnp.copy, zeros),
                    count=jnp.zeros((), jnp.int32))


def adamw_update(grads: Params, state: OptState, params: Params,
                 cfg: AdamWConfig) -> Tuple[Params, OptState, dict]:
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
    count = state.count + 1
    lr = cosine_schedule(cfg, count)
    b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m / b1c
        vhat = v / b2c
        step = mhat / (jnp.sqrt(vhat) + cfg.eps)
        step = step + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * step).astype(p.dtype)
        return new_p, m, v

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    flat_p = treedef.flatten_up_to(params)
    out = [upd(g, m, v, p) for g, m, v, p
           in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, OptState(new_m, new_v, count), metrics


# ---------------------------------------------------------------------------
# ZeRO-1 sharding
# ---------------------------------------------------------------------------

def zero1_pspecs(param_pspecs: Params, params: Params,
                 mesh: jax.sharding.Mesh,
                 data_axes: Tuple[str, ...] = ("data",)) -> Any:
    """Optimizer-state PartitionSpecs: the param spec PLUS the data axes on
    the largest axis that is unsharded and divisible by the data-axis size.

    params may be concrete arrays or ShapeDtypeStructs (dry-run).
    """
    n_data = 1
    for a in data_axes:
        n_data *= mesh.shape[a]
    extra = data_axes if len(data_axes) > 1 else data_axes[0]

    def leaf_spec(spec: P, p) -> P:
        shape = p.shape
        if len(shape) == 0:
            return spec
        entries = list(spec) + [None] * (len(shape) - len(spec))
        # a mesh axis may appear at most once per spec — params already
        # FSDP-sharded over data (e.g. MoE expert banks) stay as-is
        used = set()
        for e in entries:
            for a in (e if isinstance(e, tuple) else (e,)):
                used.add(a)
        if any(a in used for a in data_axes):
            return spec
        # pick the largest unsharded, divisible axis
        best, best_size = -1, 0
        for i, (e, s) in enumerate(zip(entries, shape)):
            if e is None and s % n_data == 0 and s > best_size and s >= n_data:
                best, best_size = i, s
        if best >= 0:
            entries[best] = extra
        return P(*entries)

    return jax.tree.map(leaf_spec, param_pspecs, params,
                        is_leaf=lambda x: isinstance(x, P))
