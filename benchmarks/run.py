"""Benchmark driver — one section per paper table/figure + the framework
benches. CSV blocks to stdout.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only X]

Sections:
    fig7       paper Fig. 7  (1 DNN/device, 4 nets x 5 deadlines x 4 algos)
    fig8       paper Fig. 8  (3 DNNs/device)
    fig9       paper Fig. 9  (edge/cloud power scaling, AlexNet @ D2)
    pso        PSO-GA engine throughput (jitted swarm iterations/s)
    fleet      the technique on the TPU fleet (PSO-GA vs greedy vs uniform)
    roofline   §Roofline table from the dry-run artifacts

--quick trims fig7/fig8 to 2 nets x 3 deadlines (CI-sized); the default
runs everything at the CPU protocol; --paper-protocol uses the paper's
pop=100/iters=1000/50-seed settings — hours on this container."""
from __future__ import annotations

import argparse
import time

from .common import PAPER, QUICK, RATIOS, print_csv


def section(name: str) -> None:
    print(f"\n## {name}", flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="fig7|fig8|fig9|pso|fleet|roofline")
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--paper-protocol", action="store_true")
    args = ap.parse_args()
    proto = PAPER if args.paper_protocol else QUICK

    want = lambda s: args.only in (None, s)
    t00 = time.time()

    if want("fig7"):
        from .fig7 import NETS, run as run7
        section("fig7: one DNN per device (paper Fig. 7)")
        nets = ("alexnet", "googlenet") if args.quick else NETS
        ratios = (1.2, 3.0, 8.0) if args.quick else RATIOS
        rows = run7(nets=nets, ratios=ratios, proto=proto)
        print_csv(rows, ["net", "ratio", "algo", "layers", "cost",
                         "feasible_frac", "wall_s"])

    if want("fig8"):
        from .fig7 import NETS, run as run7
        section("fig8: three DNNs per device (paper Fig. 8)")
        nets = ("alexnet",) if args.quick else ("alexnet", "vgg19",
                                                "googlenet")
        ratios = (1.5, 5.0) if args.quick else RATIOS
        rows = run7(nets=nets, ratios=ratios, proto=proto, per_device=3)
        print_csv(rows, ["net", "ratio", "algo", "layers", "cost",
                         "feasible_frac", "wall_s"])

    if want("fig9"):
        from .fig9 import run as run9
        section("fig9: computing-power scaling (paper Fig. 9)")
        rows = run9(proto=proto)
        print_csv(rows, ["tier", "mult", "algo", "cost", "feasible_frac",
                         "wall_s"])

    if want("pso"):
        from .bench_pso import bench_net
        section("pso: PSO-GA engine throughput")
        nets = ("alexnet", "googlenet") if args.quick \
            else ("alexnet", "vgg19", "googlenet", "resnet101")
        rows = [bench_net(n) for n in nets]
        print_csv(rows, ["net", "layers", "pop", "us_per_iter",
                         "evals_per_s", "layersteps_per_s"])

    if want("fleet"):
        from .fleet_plan import run as runf
        section("fleet: cost-driven placement over the TPU fleet")
        if args.quick:
            archs = ["qwen3-0.6b", "whisper-medium"]
        else:
            from repro.configs import names
            archs = list(names())
        rows = runf(archs)
        print_csv(rows, ["arch", "ratio", "psoga_cost", "greedy_cost",
                         "uniform_cost", "psoga_stages", "wall_s"])

    if want("roofline"):
        from .roofline import load
        section("roofline: dry-run derived terms (fit pass)")
        rows = load("results/dryrun")
        if rows:
            print_csv(rows, ["arch", "shape", "mesh", "compute_s",
                             "memory_s", "collective_s", "dominant",
                             "useful_ratio", "fits_hbm", "peak_gb"])
        else:
            print("# (no dry-run artifacts; see EXPERIMENTS.md)")
        rows = load("results/dryrun", tag="roofline")
        if rows:
            section("roofline: unrolled accum=1 pass (truthful HLO counts)")
            print_csv(rows, ["arch", "shape", "mesh", "compute_s",
                             "memory_s", "collective_s", "dominant",
                             "useful_ratio", "fits_hbm", "peak_gb"])

    print(f"\n# total bench wall time: {time.time()-t00:.1f}s")


if __name__ == "__main__":
    main()
