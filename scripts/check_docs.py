#!/usr/bin/env python
"""Fail if any `DESIGN.md §N` / `EXPERIMENTS.md §X` citation dangles.

Source docstrings cite design/experiment docs by section
(e.g. ``see DESIGN.md §2``). This checker greps the python sources for
those citations and verifies (a) the cited file exists and (b) it
contains a markdown heading carrying the cited section token (a heading
line matching ``#... §<token>``). Run directly, or via
``tests/test_docs_citations.py`` so the suite keeps docs honest.

Exit status: 0 clean, 1 dangling citations (listed on stdout).
"""
from __future__ import annotations

import re
import sys
from pathlib import Path
from typing import Dict, List, Set, Tuple

REPO = Path(__file__).resolve().parent.parent
#: directories whose python files may cite the docs
SCAN_DIRS = ("src", "benchmarks", "tests", "examples", "scripts")
#: a citation: the doc name, optionally followed by a §section token
CITE_RE = re.compile(r"(DESIGN|EXPERIMENTS)\.md(?:\s*§([A-Za-z0-9_]+))?")
#: meta-syntax placeholders ("DESIGN.md §N") used when talking ABOUT the
#: citation convention itself — not citations of a concrete section
PLACEHOLDER_SECTIONS = {"N", "X"}


def doc_sections(doc_path: Path) -> Set[str]:
    """Section tokens present as headings in a markdown file."""
    if not doc_path.exists():
        return set()
    tokens: Set[str] = set()
    for line in doc_path.read_text().splitlines():
        if line.lstrip().startswith("#"):
            tokens.update(re.findall(r"§([A-Za-z0-9_]+)", line))
    return tokens


def find_citations(repo: Path = REPO) -> List[Tuple[str, int, str, str]]:
    """All (relpath, lineno, doc, section) citations in scanned sources.

    ``section`` is '' for bare mentions (``see DESIGN.md``), which only
    require the file to exist.
    """
    cites = []
    for d in SCAN_DIRS:
        for py in sorted((repo / d).rglob("*.py")):
            rel = py.relative_to(repo).as_posix()
            for lineno, line in enumerate(py.read_text().splitlines(), 1):
                for m in CITE_RE.finditer(line):
                    cites.append((rel, lineno, f"{m.group(1)}.md",
                                  m.group(2) or ""))
    return cites


def find_dangling(repo: Path = REPO) -> List[str]:
    """Human-readable complaints for every citation that doesn't resolve."""
    sections: Dict[str, Set[str]] = {
        doc: doc_sections(repo / doc) for doc in ("DESIGN.md",
                                                  "EXPERIMENTS.md")}
    problems = []
    for rel, lineno, doc, sec in find_citations(repo):
        if sec in PLACEHOLDER_SECTIONS:
            sec = ""
        if not (repo / doc).exists():
            problems.append(f"{rel}:{lineno}: cites missing file {doc}")
        elif sec and sec not in sections[doc]:
            problems.append(
                f"{rel}:{lineno}: cites {doc} §{sec} but {doc} has no "
                f"heading with §{sec} (has: "
                f"{', '.join(sorted(sections[doc])) or 'none'})")
    return problems


def main() -> int:
    cites = find_citations()
    problems = find_dangling()
    for p in problems:
        print(p)
    print(f"# check_docs: {len(cites)} citations, "
          f"{len(problems)} dangling")
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main())
