"""Golden-cost regression: seeded end-to-end ``run_pso_ga`` for all four
zoo DNNs on ``paper_environment()``, parameterized over both fidelity
modes × both fitness backends, pinned to the stored values in
``golden_costs.json``.

The existing parity tests compare backend AGAINST backend — if a change
drifts the fitness of both (a simulator tweak, a cost-model slip, an
accidental operator-order change), parity still passes. These goldens
anchor the absolute numbers. Regenerate after an INTENDED behaviour
change with ``PYTHONPATH=src python scripts/gen_goldens.py`` and justify
the diff in the PR.
"""
import json
from pathlib import Path

import numpy as np
import pytest

from repro.core import (PSOGAConfig, heft_makespan, paper_environment,
                        run_pso_ga, sample_arrivals, zoo)

GOLDENS = json.loads(
    (Path(__file__).parent / "golden_costs.json").read_text())
_CFG = GOLDENS["_config"]
_TCFG = GOLDENS["_traffic_config"]
TRAFFIC_NETS = ("alexnet", "googlenet")
TRAFFIC_SCENARIOS = ("bursty", "flash-crowd")


@pytest.fixture(scope="module")
def golden_env():
    return paper_environment()


@pytest.fixture(scope="module")
def golden_dags(golden_env):
    dags = {}
    for net in zoo.NAMES:
        base = zoo.build(net, pin_server=0)
        h, _ = heft_makespan(base, golden_env)
        dags[net] = base.with_deadline(
            np.array([_CFG["deadline_ratio"] * h]))
    return dags


@pytest.mark.parametrize("backend", ["scan", "pallas"])
@pytest.mark.parametrize("faithful", [False, True])
@pytest.mark.parametrize("net", zoo.NAMES)
def test_golden_cost(net, faithful, backend, golden_env, golden_dags):
    want = GOLDENS[f"{net}|faithful={faithful}|{backend}"]
    cfg = PSOGAConfig(pop_size=_CFG["pop_size"],
                      max_iters=_CFG["max_iters"],
                      stall_iters=_CFG["stall_iters"],
                      faithful_sim=faithful, fitness_backend=backend)
    res = run_pso_ga(golden_dags[net], golden_env, cfg,
                     seed=_CFG["seed"])
    assert res.feasible == want["feasible"]
    # rtol absorbs cross-platform float noise; any real fitness drift is
    # orders of magnitude larger than 1e-5 relative.
    np.testing.assert_allclose(res.best_fitness, want["best_fitness"],
                               rtol=1e-5)
    np.testing.assert_allclose(res.best_cost, want["best_cost"],
                               rtol=1e-5)


@pytest.mark.parametrize("backend", ["scan", "pallas"])
@pytest.mark.parametrize("kind", TRAFFIC_SCENARIOS)
@pytest.mark.parametrize("net", TRAFFIC_NETS)
def test_golden_traffic_key(net, kind, backend, golden_env, golden_dags):
    """Queue-aware goldens (DESIGN.md §10): seeded traffic-fitness solves
    pinned end-to-end for BOTH backends (the pallas column runs the
    kernels.traffic_sim event walk in interpret mode), so contention-
    scoring drift is caught the same way plan-fitness drift is (both
    the feasible mean-load-cost branch and the miss-penalty infeasible
    branch are anchored)."""
    suffix = "" if backend == "scan" else "|pallas"
    want = GOLDENS[f"{net}|traffic={kind}{suffix}"]
    arr = sample_arrivals(kind, 1, seed=_TCFG["seed"],
                          **_TCFG["arrivals"]).t
    cfg = PSOGAConfig(pop_size=_TCFG["pop_size"],
                      max_iters=_TCFG["max_iters"],
                      stall_iters=_TCFG["stall_iters"],
                      miss_budget=_TCFG["miss_budget"],
                      fitness_backend=backend)
    res = run_pso_ga(golden_dags[net], golden_env, cfg,
                     seed=_TCFG["seed"], arrivals=arr)
    assert res.feasible == want["feasible"]
    np.testing.assert_allclose(res.best_fitness, want["best_fitness"],
                               rtol=1e-5)
    np.testing.assert_allclose(res.best_cost, want["best_cost"],
                               rtol=1e-5)


def test_golden_traffic_infeasible_anchor(golden_env):
    """The MISS_PENALTY branch of the kernel path, anchored: a 0.5×HEFT
    deadline with a zero miss budget is unattainable, so the pinned key
    must sit above INFEASIBLE_OFFSET — drift in the penalty arithmetic
    (offset + 64·p95 + log1p latency) is invisible to the feasible
    goldens and to backend-vs-backend parity."""
    from repro.core.fitness import INFEASIBLE_OFFSET
    want = GOLDENS["alexnet|traffic=flash-crowd|pallas|infeasible"]
    base = zoo.build("alexnet", pin_server=0)
    h, _ = heft_makespan(base, golden_env)
    dag = base.with_deadline(np.array([0.5 * h]))
    arr = sample_arrivals("flash-crowd", 1, seed=_TCFG["seed"],
                          **_TCFG["arrivals"]).t
    cfg = PSOGAConfig(pop_size=_TCFG["pop_size"],
                      max_iters=_TCFG["max_iters"],
                      stall_iters=_TCFG["stall_iters"],
                      miss_budget=0.0, fitness_backend="pallas")
    res = run_pso_ga(dag, golden_env, cfg, seed=_TCFG["seed"],
                     arrivals=arr)
    assert not want["feasible"] and not res.feasible
    assert want["best_fitness"] > INFEASIBLE_OFFSET
    np.testing.assert_allclose(res.best_fitness, want["best_fitness"],
                               rtol=1e-5)


def test_goldens_cover_full_matrix():
    """The stored file must span nets × fidelity × backends plus the
    traffic nets × scenarios — a silently shrunken matrix would quietly
    stop guarding part of the surface."""
    keys = [k for k in GOLDENS if not k.startswith("_")]
    assert len(keys) == len(zoo.NAMES) * 2 * 2 \
        + len(TRAFFIC_NETS) * len(TRAFFIC_SCENARIOS) * 2 + 1
    for net in zoo.NAMES:
        for faithful in (False, True):
            for backend in ("scan", "pallas"):
                assert f"{net}|faithful={faithful}|{backend}" in GOLDENS
    for net in TRAFFIC_NETS:
        for kind in TRAFFIC_SCENARIOS:
            assert f"{net}|traffic={kind}" in GOLDENS
            assert f"{net}|traffic={kind}|pallas" in GOLDENS
    assert "alexnet|traffic=flash-crowd|pallas|infeasible" in GOLDENS
