"""mixtral-8x7b — MoE 8e top-2, SWA 4096. [arXiv:2401.04088; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b", family="moe", n_layers=32, d_model=4096,
    n_heads=32, n_kv_heads=8, head_dim=128, d_ff=14336, vocab=32_000,
    act="swiglu", n_experts=8, top_k=2, window=4096,
    rope_theta=1_000_000.0)
