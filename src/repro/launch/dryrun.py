"""Multi-pod dry-run: prove every (arch x shape x mesh) cell lowers,
partitions and compiles for the production fleet, and extract the
roofline inputs from the compiled artifact.

MUST be imported/run fresh: the first two lines pin 512 placeholder host
devices BEFORE jax initializes (jax locks the device count on first
backend touch). Tests shrink the fleet via REPRO_DRYRUN_DEVICES (also
honored before any jax import).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-0.6b \
        --shape train_4k [--multi-pod] [--out out.json]
    PYTHONPATH=src python -m repro.launch.dryrun --all --out-dir results/
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

if os.environ.get("REPRO_DRYRUN_DEVICES"):          # test hook (pre-init)
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                               + os.environ["REPRO_DRYRUN_DEVICES"])

import argparse
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax

from ..configs import SHAPES, get, names
from ..models import model_flops, param_count, skip_reason, supports_shape
from .analysis import HW, collective_bytes, roofline_terms
from .mesh import data_axes_of, make_production_mesh, make_test_mesh
from .steps import make_decode_objects, make_prefill_objects, \
    make_train_objects

__all__ = ["run_cell", "main"]


def _mem_dict(ma) -> Dict[str, float]:
    return {
        "argument_bytes": float(ma.argument_size_in_bytes),
        "output_bytes": float(ma.output_size_in_bytes),
        "temp_bytes": float(ma.temp_size_in_bytes),
        "alias_bytes": float(ma.alias_size_in_bytes),
        "code_bytes": float(ma.generated_code_size_in_bytes),
        "peak_bytes": float(ma.argument_size_in_bytes
                            + ma.output_size_in_bytes
                            + ma.temp_size_in_bytes
                            - ma.alias_size_in_bytes),
    }


def run_cell(arch: str, shape_name: str, multi_pod: bool = False,
             moe_impl: str = "scatter", accum: int = 1,
             test_mesh: bool = False, extra: Optional[Dict] = None
             ) -> Dict[str, Any]:
    """Lower + compile one cell; returns the §Dry-run/§Roofline record."""
    cfg = get(arch)
    shape = next(s for s in SHAPES if s.name == shape_name)
    if extra:
        import dataclasses as _dc
        cfg = _dc.replace(cfg, **{k: v for k, v in extra.items()
                                  if hasattr(cfg, k)})
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "kind": shape.kind,
        "multi_pod": multi_pod, "moe_impl": moe_impl, "accum": accum,
    }
    if not supports_shape(cfg, shape):
        rec["status"] = "skipped"
        rec["reason"] = skip_reason(cfg, shape)
        return rec

    mesh = (make_test_mesh(multi_pod=multi_pod) if test_mesh
            else make_production_mesh(multi_pod=multi_pod))
    daxes = data_axes_of(mesh)
    n_chips = mesh.size
    rec["mesh"] = dict(zip(mesh.axis_names,
                           [int(mesh.shape[a]) for a in mesh.axis_names]))

    t0 = time.time()
    if shape.kind == "train":
        _, step, in_sh, out_sh, shapes = make_train_objects(
            cfg, shape, mesh, daxes, moe_impl=moe_impl, accum=accum)
        donate = (0, 1)
    elif shape.kind == "prefill":
        _, step, in_sh, out_sh, shapes = make_prefill_objects(
            cfg, shape, mesh, daxes, moe_impl=moe_impl)
        donate = ()
    else:
        _, step, in_sh, out_sh, shapes = make_decode_objects(
            cfg, shape, mesh, daxes, moe_impl=moe_impl)
        donate = (1,)

    with mesh:
        jitted = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                         donate_argnums=donate)
        lowered = jitted.lower(*shapes)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):   # older jax: one dict per computation
        ca = ca[0] if ca else {}
    hlo = compiled.as_text()
    pod_size = 0
    if multi_pod:
        pod_size = n_chips // int(mesh.shape["pod"])
    coll = collective_bytes(hlo, pod_size=pod_size)

    from .analysis import parse_hlo_collectives
    ops = parse_hlo_collectives(hlo)
    top = sorted(((o, b, g) for o, b, g, _ in ops),
                 key=lambda t: -t[1])[:10]
    flops_chip = float(ca.get("flops", 0.0))
    bytes_chip = float(ca.get("bytes accessed", 0.0))
    rec.update({
        "status": "ok",
        "n_chips": n_chips,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": _mem_dict(ma),
        "flops_per_chip": flops_chip,
        "hbm_bytes_per_chip": bytes_chip,
        "collective": {
            "per_op": coll.per_op, "ici_bytes": coll.total_ici,
            "dcn_bytes": coll.total_dcn, "count": coll.count,
            "top": [{"op": o, "result_bytes": b, "group": g}
                    for o, b, g in top],
        },
        "hlo_bytes": len(hlo),
    })
    rec["roofline"] = roofline_terms(flops_chip, bytes_chip, coll)
    mf = model_flops(cfg, shape)
    rec["model_flops_total"] = mf
    rec["model_flops_per_chip"] = mf / n_chips
    rec["useful_compute_ratio"] = (mf / n_chips / flops_chip
                                   if flops_chip else 0.0)
    rec["params_total"] = param_count(cfg)
    rec["params_active"] = param_count(cfg, active_only=True)
    hw = HW()
    fits = rec["memory"]["peak_bytes"] <= hw.hbm_bytes
    rec["fits_hbm"] = bool(fits)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--moe-impl", default="scatter",
                    choices=["scatter", "a2a"])
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--test-mesh", action="store_true",
                    help="scaled-down mesh (CI)")
    ap.add_argument("--out", default=None)
    ap.add_argument("--out-dir", default=None)
    ap.add_argument("--extra", default=None,
                    help="JSON dict of ModelConfig overrides (perf ablations)")
    ap.add_argument("--skip-existing", action="store_true",
                    help="resume an interrupted matrix run")
    ap.add_argument("--tag", default="",
                    help="suffix for out-dir filenames (e.g. 'roofline')")
    args = ap.parse_args()
    extra = json.loads(args.extra) if args.extra else None

    cells = []
    if args.all:
        for a in names():
            for s in SHAPES:
                cells.append((a, s.name))
    else:
        if not (args.arch and args.shape):
            ap.error("--arch and --shape (or --all) required")
        cells = [(args.arch, args.shape)]

    results = []
    for arch, shape in cells:
        # accumulation applies to train cells only (memory-fit policy)
        accum = args.accum if shape.startswith("train") else 1
        tag = f"{arch}_{shape}_{'mp' if args.multi_pod else 'sp'}" \
            + (f"_{args.tag}" if args.tag else "")
        if args.out_dir and args.skip_existing:
            path = os.path.join(args.out_dir, tag + ".json")
            if os.path.exists(path):
                print(f"[dryrun] {arch} x {shape}: exists, skipped",
                      flush=True)
                continue
        try:
            rec = run_cell(arch, shape, multi_pod=args.multi_pod,
                           moe_impl=args.moe_impl, accum=accum,
                           test_mesh=args.test_mesh, extra=extra)
        except Exception as e:  # noqa: BLE001 — record, keep matrix going
            rec = {"arch": arch, "shape": shape, "status": "error",
                   "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-2000:]}
        results.append(rec)
        jax.clear_caches()        # one process runs the whole matrix
        status = rec["status"]
        extra_txt = ""
        if status == "ok":
            r = rec["roofline"]
            extra_txt = (f" compile={rec['compile_s']}s "
                         f"dominant={r['dominant']} "
                         f"fits_hbm={rec['fits_hbm']}")
        elif status == "skipped":
            extra_txt = f" ({rec['reason']})"
        else:
            extra_txt = f" {rec['error'][:120]}"
        print(f"[dryrun] {arch} x {shape} "
              f"{'pod2' if args.multi_pod else 'pod1'}: "
              f"{status}{extra_txt}", flush=True)
        if args.out_dir:
            os.makedirs(args.out_dir, exist_ok=True)
            with open(os.path.join(args.out_dir, tag + ".json"), "w") as f:
                json.dump(rec, f, indent=1)

    if args.out:
        with open(args.out, "w") as f:
            json.dump(results if len(results) > 1 else results[0], f,
                      indent=1)
    bad = [r for r in results if r["status"] == "error"]
    raise SystemExit(1 if bad else 0)


if __name__ == "__main__":
    main()
