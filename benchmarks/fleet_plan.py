"""The paper's technique as a framework feature: cost-driven placement of
each assigned architecture's serving DAG over the heterogeneous TPU fleet
(PSO-GA vs Greedy vs uniform depth-split), per deadline ratio.

All (arch × ratio) PSO-GA problems are solved in ONE batched fleet via
``plan_offload_batch`` (DESIGN.md §4) — one compiled program instead of a
re-traced ``while_loop`` per cell.
"""
from __future__ import annotations

import argparse
import time

from repro.configs import SHAPES, get, names
from repro.core import (PSOGAConfig, plan_offload, plan_offload_batch,
                        tpu_fleet_environment, uniform_stages)
from repro.core.simulator import SimProblem, simulate_np

from .common import print_csv

FAST = PSOGAConfig(pop_size=48, max_iters=200, stall_iters=40)


def run(archs, ratios=(1.2, 1.5, 3.0), mesh=None):
    env = tpu_fleet_environment()
    shape = SHAPES[1]                              # prefill_32k
    cells = [(arch, ratio) for arch in archs for ratio in ratios]

    # one batched PSO-GA fleet for every (arch, ratio) cell, optionally
    # sharded over the device mesh (DESIGN.md §12)
    t0 = time.perf_counter()
    plans = plan_offload_batch(
        [(get(arch), shape, ratio) for arch, ratio in cells],
        env=env, pso=FAST, seed=0, mesh=mesh)
    batch_wall = time.perf_counter() - t0
    print(f"# batched PSO-GA: {len(cells)} problems in {batch_wall:.2f}s "
          f"({batch_wall / len(cells):.3f}s/problem)", flush=True)

    rows = []
    for (arch, ratio), pso in zip(cells, plans):
        cfg = get(arch)
        t0 = time.perf_counter()
        grd = plan_offload(cfg, shape, env=env, deadline_ratio=ratio,
                           algo="greedy")
        # uniform depth split across 1 cloud + 1 edge + home device
        dag = pso.dag
        servers = [int(env.servers_of_tier(0)[0]),
                   int(env.servers_of_tier(1)[0]),
                   int(dag.pinned[0])]
        xu = uniform_stages(dag, servers)
        xu[0] = dag.pinned[0]
        prob = SimProblem.build(dag, env)
        ru = simulate_np(prob, xu, faithful=False)
        rows.append({
            "arch": arch, "ratio": ratio,
            "psoga_cost": pso.cost,
            "greedy_cost": grd.cost if grd.result.feasible else -1.0,
            "uniform_cost": float(ru.total_cost)
            if bool(ru.feasible) else -1.0,
            "psoga_stages": len(pso.stages),
            "wall_s": (time.perf_counter() - t0) + batch_wall / len(cells),
        })
        print(f"# {arch} r={ratio}: psoga=${pso.cost:.4f} "
              f"greedy=${rows[-1]['greedy_cost']:.4f} "
              f"uniform=${rows[-1]['uniform_cost']:.4f}", flush=True)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--archs", nargs="*", default=list(names()))
    ap.add_argument("--mesh", default="none",
                    choices=("none", "host", "prod"),
                    help="shard the batched solve over this device mesh "
                         "(DESIGN.md §12); plans are identical either way")
    args = ap.parse_args()
    from repro.launch.mesh import resolve_mesh
    rows = run(args.archs, mesh=resolve_mesh(args.mesh))
    print_csv(rows, ["arch", "ratio", "psoga_cost", "greedy_cost",
                     "uniform_cost", "psoga_stages", "wall_s"])


if __name__ == "__main__":
    main()
