"""Fleet-scale batched PSO-GA: solve N heterogeneous offloading problems
in ONE jitted program (DESIGN.md §4).

The sequential solver re-traces and re-compiles ``lax.while_loop`` per
problem — fatal when a production planner must place many (DAG, env)
pairs per second. This module packs N heterogeneous ``SimProblem``s into
a single ``PaddedProblem`` whose leaves carry a leading problem axis
(layers padded to ``max_p``, servers to ``max_S``, with validity encoded
so padded layers are zero-cost no-ops and padded servers unreachable),
then runs the entire fleet of swarms as ``vmap``-over-problems of
``swarm_step`` inside ONE ``lax.while_loop``.

Convergence is tracked per problem: a problem whose stall counter hits
``cfg.stall_iters`` (or that reaches ``cfg.max_iters``) is *frozen* — its
whole swarm state passes through unchanged while the rest of the fleet
keeps iterating — so every problem's trajectory is exactly what the
sequential solver would have produced, and the loop exits when the last
problem converges.

Because each problem keeps its own PRNG key (seeded exactly like
``run_pso_ga``), its own link-aware initial swarm, and mutation/crossover
bounds drawn from its TRUE ``(p, S)`` sizes, the batched solver matches
the sequential solver gene-for-gene in fitness (see
``tests/test_batch.py::test_batched_matches_sequential``).

Compiled programs are cached per config, with jit specializing on the
``(N, max_p, max_S, ...)`` shape bucket underneath (``max_p``/``max_S``
round up to powers of two in ``pack_problems``), so repeated fleets with
similar shapes skip retracing entirely.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from .dag import LayerDAG
from .environment import Environment
from .fitness import make_swarm_fitness, resolve_fitness_backend
from .pso_ga import (PSOGAConfig, PSOGAResult, _SwarmState, init_swarm,
                     swarm_step)
from .simulator import PaddedProblem, SimProblem, pad_problem, simulate_padded

__all__ = ["pack_problems", "pack_arrivals", "run_pso_ga_batch",
           "bucket_size", "runner_cache_info", "runner_cache_stats",
           "reset_runner_cache_stats"]

ProblemLike = Union[SimProblem, Tuple[LayerDAG, Environment]]


def bucket_size(n: int, floor: int = 8) -> int:
    """Round up to the next power of two (>= floor) — the shape bucket."""
    return max(floor, 1 << max(0, int(n) - 1).bit_length())


def _as_problems(problems: Sequence[ProblemLike]) -> List[SimProblem]:
    out = []
    for pr in problems:
        if isinstance(pr, SimProblem):
            out.append(pr)
        else:
            dag, env = pr
            out.append(SimProblem.build(dag, env))
    return out


def _normalize_seeds(seed, n: int) -> List[int]:
    """One seed per problem from any int-like scalar or sequence.

    ``np.isscalar`` is the wrong predicate here: it rejects 0-d numpy
    arrays (``np.array(7)``) and, on some numpy versions, numpy integer
    scalars — both of which flow naturally out of configs and RNGs. Treat
    anything 0-d as a broadcast scalar, any 1-d integer-like sequence as
    per-problem seeds.
    """
    arr = np.asarray(seed)
    if not np.issubdtype(arr.dtype, np.integer):
        raise TypeError(f"seed must be int-like, got dtype {arr.dtype}")
    if arr.ndim == 0:
        return [int(arr)] * n
    if arr.ndim != 1:
        raise ValueError(f"seed must be a scalar or 1-d sequence, "
                         f"got shape {arr.shape}")
    if arr.shape[0] != n:
        raise ValueError(f"{arr.shape[0]} seeds for {n} problems")
    return [int(s) for s in arr]


def pack_problems(problems: Sequence[ProblemLike],
                  bucket: bool = True) -> PaddedProblem:
    """Pack N heterogeneous problems into one stacked ``PaddedProblem``.

    Every leaf gains a leading ``N`` axis; per-problem true sizes live in
    the ``num_layers`` / ``num_servers`` / ``num_apps`` fields (shape
    (N,)). With ``bucket=True`` the layer/server axes round up to power-
    of-two buckets so fleets of similar shapes share compiled programs.
    """
    probs = _as_problems(problems)
    if not probs:
        raise ValueError("pack_problems needs at least one problem")
    max_p = max(pr.num_layers for pr in probs)
    max_S = max(pr.num_servers for pr in probs)
    if bucket:
        max_p, max_S = bucket_size(max_p), bucket_size(max_S, floor=4)
    max_in = max(pr.parent_idx.shape[1] for pr in probs)
    max_out = max(pr.child_idx.shape[1] for pr in probs)
    max_apps = max(pr.num_apps for pr in probs)
    padded = [pad_problem(pr, max_p=max_p, max_S=max_S, max_in=max_in,
                          max_out=max_out, max_apps=max_apps)
              for pr in probs]
    return jax.tree.map(lambda *leaves: jnp.stack(leaves), *padded)


# --------------------------------------------------------------------------
# compiled fleet runner, cached per shape bucket
# --------------------------------------------------------------------------

_RUNNER_CACHE: Dict[tuple, Callable] = {}
#: hits/misses count _fleet_runner lookups; traces counts actual jit
#: re-traces of the fleet loop (incremented from inside the traced body,
#: so it only ticks when XLA really recompiles — the online re-planning
#: invariant "every round after the first hits the compiled runner"
#: (DESIGN.md §9) is asserted against this counter.
_CACHE_STATS = {"hits": 0, "misses": 0, "traces": 0}


def runner_cache_info() -> Tuple[tuple, ...]:
    """(config, traffic?) keys currently holding a compiled fleet runner."""
    return tuple(_RUNNER_CACHE)


def runner_cache_stats() -> Dict[str, int]:
    """Snapshot of the fleet-runner cache counters (DESIGN.md §9)."""
    return dict(_CACHE_STATS)


def reset_runner_cache_stats() -> None:
    """Zero the counters (the compiled runners themselves are kept)."""
    for k in _CACHE_STATS:
        _CACHE_STATS[k] = 0


def _done(state: _SwarmState, cfg: PSOGAConfig) -> jnp.ndarray:
    """(N,) bool — which problems have hit the paper's stopping rule."""
    return (state.it >= cfg.max_iters) | (state.stall >= cfg.stall_iters)


def _fleet_runner(cfg: PSOGAConfig, traffic: bool = False) -> Callable:
    """Jitted ``(ppb, keys, X0b, incb, migb[, arrb]) -> final _SwarmState``.

    One cache entry per ``(cfg, traffic?)`` (the config is baked into
    the traced loop; the traffic flag switches the runner's signature —
    with it, per-problem Monte-Carlo arrivals ``arrb (N, M, max_apps,
    R)`` ride along as one more traced argument, DESIGN.md §10); jit's
    own cache handles shape specialization underneath, and the
    power-of-two buckets of ``pack_problems`` keep the number of
    distinct ``(max_p, max_S)`` shapes it sees small. Distinct fleet
    sizes N still trace separately — batch at stable sizes if that
    matters.

    Cold and warm (re-planning) solves share this ONE program: the
    incumbent genes ``incb (N, max_p)`` and migration weights ``migb
    (N,)`` are ordinary traced arrays, and a zero weight multiplies the
    migration term away bit-exactly (DESIGN.md §9). Drift — of the
    environment OR of the arrival stream — only changes array *values*,
    so every re-planning round after the first reuses the compiled
    runner; ``runner_cache_stats()["traces"]`` counts the actual
    re-traces.

    The backend string is normalized BEFORE the cache key: ``"auto"``
    and whatever it resolves to on this host share one entry (and one
    compiled program), so flipping only the spelling of the backend
    never retraces — pinned by
    ``tests/test_traffic_kernel.py::test_runner_cache_backend_normalized``.
    """
    cfg = dataclasses.replace(
        cfg, fitness_backend=resolve_fitness_backend(cfg.fitness_backend))
    cache_key = (cfg, traffic)
    cached = _RUNNER_CACHE.get(cache_key)
    if cached is not None:
        _CACHE_STATS["hits"] += 1
        return cached
    _CACHE_STATS["misses"] += 1

    vstep = jax.vmap(lambda pp, st, inc, mw, arr: swarm_step(
        pp, st, cfg, incumbent=inc, mig_weight=mw, arrivals=arr))
    # one swarm-fitness per problem, vmapped over the fleet: the scan
    # backend batches the two-phase simulate_padded; the pallas backend's
    # grid picks up the problem axis as an outer grid dimension.
    vfit = jax.vmap(lambda pp, X, inc, mw, arr: make_swarm_fitness(
        pp, cfg.faithful_sim, cfg.fitness_backend,
        incumbent=inc, mig_weight=mw, arrivals=arr,
        miss_budget=cfg.miss_budget)(X))

    def run(ppb: PaddedProblem, keys: jnp.ndarray, X0b: jnp.ndarray,
            incb: jnp.ndarray, migb: jnp.ndarray,
            arrb: Optional[jnp.ndarray] = None) -> _SwarmState:
        _CACHE_STATS["traces"] += 1        # python side effect: trace-time only
        n = X0b.shape[0]
        f0 = vfit(ppb, X0b, incb, migb, arrb)                  # (N, P)
        i0 = jnp.argmin(f0, axis=1)                            # (N,)
        gbest_x = jnp.take_along_axis(
            X0b, i0[:, None, None], axis=1)[:, 0, :]           # (N, max_p)
        gbest_f = jnp.take_along_axis(f0, i0[:, None], axis=1)[:, 0]
        state = _SwarmState(
            key=keys, X=X0b, pbest_x=X0b, pbest_f=f0,
            gbest_x=gbest_x, gbest_f=gbest_f,
            it=jnp.zeros((n,), jnp.int32), stall=jnp.zeros((n,), jnp.int32))

        def cond(st: _SwarmState) -> jnp.ndarray:
            return jnp.any(~_done(st, cfg))

        def body(st: _SwarmState) -> _SwarmState:
            new = vstep(ppb, st, incb, migb, arrb)
            frozen = _done(st, cfg)                            # (N,)
            return jax.tree.map(
                lambda nw, old: jnp.where(
                    frozen.reshape((-1,) + (1,) * (nw.ndim - 1)), old, nw),
                new, st)

        return jax.lax.while_loop(cond, body, state)

    jitted = jax.jit(run)
    _RUNNER_CACHE[cache_key] = jitted
    return jitted


def pack_arrivals(arrivals: Sequence[np.ndarray],
                  max_apps: int) -> np.ndarray:
    """Stack per-problem ``(M, n_apps_i, R)`` Monte-Carlo arrival arrays
    into one ``(N, M, max_apps, R)`` traced input, padding the app axis
    with +inf (a padded app never receives a request — the same masked
    no-op discipline as padded layers, DESIGN.md §10). Every problem
    must share the seed count M and the request cap R (one compiled
    runner serves the fleet)."""
    mats = [np.asarray(a, float) for a in arrivals]
    if not mats:
        raise ValueError("pack_arrivals needs at least one arrival set")
    for i, a in enumerate(mats):
        if a.ndim != 3:
            raise ValueError(
                f"arrivals[{i}] has shape {a.shape}; expected a 3-d "
                f"(M, n_apps, R) Monte-Carlo array")
    m0, r0 = mats[0].shape[0], mats[0].shape[2]
    for i, a in enumerate(mats):
        if a.shape[0] != m0 or a.shape[2] != r0:
            raise ValueError(
                f"arrivals[{i}] has shape {a.shape}; expected (M={m0}, "
                f"n_apps, R={r0}) with M and R shared across the fleet")
        if a.shape[1] > max_apps:
            raise ValueError(f"arrivals[{i}] has {a.shape[1]} apps > "
                             f"packed max_apps {max_apps}")
        # +inf is the legal "no more requests" pad; NaN or negative
        # timestamps are corrupt draws and must not reach the kernel
        # (where they'd silently poison every merged-order replay).
        if np.isnan(a).any() or (a < 0.0).any():
            raise ValueError(f"arrivals[{i}] contains NaN or negative "
                             f"request times")
    out = np.full((len(mats), m0, max_apps, r0), np.inf)
    for i, a in enumerate(mats):
        out[i, :, :a.shape[1], :] = a
    return out


def run_pso_ga_batch(problems: Sequence[ProblemLike],
                     cfg: PSOGAConfig = PSOGAConfig(),
                     seed: Union[int, Sequence[int]] = 0,
                     bucket: bool = True,
                     return_state: bool = False,
                     incumbent: Optional[Sequence[np.ndarray]] = None,
                     migration_weight: Union[float,
                                             Sequence[float]] = 0.0,
                     warm_rescue: Optional[Sequence[bool]] = None,
                     arrivals: Optional[Sequence[np.ndarray]] = None):
    """Solve N offloading problems with one fleet of swarms.

    Args:
      problems: ``SimProblem``s or ``(LayerDAG, Environment)`` pairs.
      cfg: shared PSO-GA hyperparameters (one compiled program per cfg).
      seed: one seed for every problem, or a per-problem sequence —
        problem i behaves exactly like ``run_pso_ga(..., seed=seed_i)``.
      bucket: round padded shapes up to power-of-two buckets so repeated
        fleet shapes reuse the compiled runner.
      return_state: also return the final stacked ``_SwarmState`` (tests
        use it to assert padded genes were never touched).
      incumbent: per-problem (p_i,) incumbent assignments (online
        re-planning, DESIGN.md §9): swarms are warm-started in the
        incumbent's neighborhood (``init_swarm`` incumbent mode) and the
        fitness pays ``migration_weight`` × the Eq. 6 input-dataset cost
        for every moved layer. ``None`` is a cold solve — bit-identical
        to the pre-warm-start solver, via the SAME compiled runner. A
        per-problem entry of ``None`` demotes only that problem to a
        cold solve (stale-plan guard, DESIGN.md §11): its swarm draws
        the cold init and its migration weight is zeroed, while the
        rest of the fleet stays warm.
      migration_weight: scalar or per-problem migration-cost weights
        (ignored without ``incumbent``).
      warm_rescue: per-problem flags (with ``incumbent`` only): seed the
        cold tier anchors into that problem's warm swarm tail — the
        re-planner sets it where drift stranded the incumbent
        infeasible, so feasibility recovery starts from the same escape
        hatches a cold solve gets (``init_swarm`` rescue mode).
      arrivals: per-problem ``(M, n_apps_i, R)`` Monte-Carlo request
        timestamps (DESIGN.md §10) — switches every problem's fitness
        to the queue-aware traffic key under ``cfg.miss_budget``. The
        packed arrays are traced runner inputs, so sweeping the load
        (or re-planning under a load surge) never retraces.

    Returns a list of per-problem ``PSOGAResult`` (and the state if asked).
    ``record_history`` is not supported in fleet mode — use the sequential
    solver to trace a single problem's convergence curve.
    ``best_fitness`` is the migration-adjusted key when warm (the
    traffic key when ``arrivals`` is given); ``best_cost`` is always
    the raw zero-load replayed plan cost.
    """
    probs = _as_problems(problems)
    n = len(probs)
    seeds = _normalize_seeds(seed, n)
    if incumbent is not None and len(incumbent) != n:
        raise ValueError(f"{len(incumbent)} incumbents for {n} problems")
    if arrivals is not None and len(arrivals) != n:
        raise ValueError(f"{len(arrivals)} arrival sets for {n} problems")

    ppb = pack_problems(probs, bucket=bucket)
    max_p = int(ppb.compute.shape[1])

    # Per-problem init mirrors run_pso_ga exactly: split the problem's own
    # key, draw the link-aware swarm at the TRUE (p, S) shape, then embed
    # into the padded gene space (padded genes start — and stay — 0).
    keys = []
    X0b = np.zeros((n, cfg.pop_size, max_p), np.int32)
    incb = np.zeros((n, max_p), np.int32)
    migb = np.zeros((n,), np.float32)
    if incumbent is not None:
        migb[:] = np.asarray(migration_weight, np.float32)
    for i, pr in enumerate(probs):
        key, k_init = jax.random.split(jax.random.PRNGKey(seeds[i]))
        keys.append(np.asarray(key))
        inc_i = None
        rescue_i = False
        if incumbent is not None and incumbent[i] is not None:
            inc_i = np.asarray(incumbent[i], np.int32)
            if inc_i.shape != (pr.num_layers,):
                raise ValueError(
                    f"incumbent[{i}] has shape {inc_i.shape}, expected "
                    f"({pr.num_layers},)")
            incb[i, :pr.num_layers] = inc_i
            rescue_i = bool(warm_rescue[i]) if warm_rescue is not None \
                else False
        elif incumbent is not None:
            # a demoted problem (stale incumbent, DESIGN.md §11) solves
            # cold inside the warm fleet: zero migration weight
            # multiplies the term away bit-exactly, and init_swarm gets
            # no incumbent — identical to a cold solve of problem i.
            migb[i] = 0.0
        X0b[i, :, :pr.num_layers] = np.asarray(
            init_swarm(k_init, pr, cfg, incumbent=inc_i,
                       rescue=rescue_i))

    runner = _fleet_runner(cfg, traffic=arrivals is not None)
    arrb = None
    if arrivals is not None:
        arrb = jnp.asarray(
            pack_arrivals(arrivals, int(ppb.deadline.shape[1])))
    state = runner(ppb, jnp.asarray(np.stack(keys)), jnp.asarray(X0b),
                   jnp.asarray(incb), jnp.asarray(migb), arrb)
    jax.block_until_ready(state.gbest_f)

    # Re-simulate each gbest (same as the sequential epilogue).
    res = jax.vmap(
        lambda pp, x: simulate_padded(pp, x, cfg.faithful_sim))(
            ppb, state.gbest_x)
    results: List[PSOGAResult] = []
    for i, pr in enumerate(probs):
        feasible = bool(res.feasible[i])
        results.append(PSOGAResult(
            best_x=np.asarray(state.gbest_x[i])[:pr.num_layers],
            best_fitness=float(state.gbest_f[i]),
            best_cost=float(res.total_cost[i]) if feasible else float("inf"),
            feasible=feasible,
            iterations=int(state.it[i]),
            history=None))
    if return_state:
        return results, state
    return results
