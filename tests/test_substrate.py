"""optim / data / checkpoint / runtime unit + property tests."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypo_compat import given, st

from repro.checkpoint import CheckpointManager
from repro.configs import get
from repro.configs.base import ShapeSpec
from repro.data import DataConfig, host_slice, make_stream
from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                         cosine_schedule, global_norm, zero1_pspecs)
from repro.optim.compression import (compress_error_feedback,
                                     init_compression, quantize_int8)
from repro.runtime import (FailureInjector, SimulatedFailure,
                           StragglerDetector, best_mesh_shape,
                           run_with_restarts)

# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------


def test_adamw_converges_quadratic():
    params = {"w": jnp.ones((8, 8)) * 3.0}
    state = adamw_init(params)
    cfg = AdamWConfig(lr=0.2, warmup_steps=5, total_steps=100,
                      weight_decay=0.0)
    loss = lambda p: jnp.sum(jnp.square(p["w"]))
    l0 = float(loss(params))
    for _ in range(60):
        params, state, _ = adamw_update(jax.grad(loss)(params), state,
                                        params, cfg)
    assert float(loss(params)) < 1e-2 * l0


def test_grad_clip_applied():
    params = {"w": jnp.zeros((4,))}
    state = adamw_init(params)
    cfg = AdamWConfig(clip_norm=1.0, warmup_steps=0, total_steps=10)
    g = {"w": jnp.full((4,), 100.0)}
    _, _, m = adamw_update(g, state, params, cfg)
    assert float(m["grad_norm"]) == pytest.approx(200.0)
    # effective update uses clipped grad; second moment small
    assert float(global_norm(g)) == pytest.approx(200.0)


def test_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_ratio=0.1)
    lrs = [float(cosine_schedule(cfg, jnp.asarray(s)))
           for s in range(0, 101, 10)]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(1.0)
    assert lrs[-1] == pytest.approx(0.1, rel=1e-2)
    assert all(a >= b - 1e-9 for a, b in zip(lrs[1:], lrs[2:]))


def test_zero1_pspecs_no_duplicate_axes():
    from jax.sharding import PartitionSpec as P
    import numpy as np
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                             ("data", "model"))
    params = {"a": jax.ShapeDtypeStruct((16, 8), jnp.float32),
              "b": jax.ShapeDtypeStruct((3,), jnp.float32),
              "c": jax.ShapeDtypeStruct((4, 4, 4), jnp.float32)}
    specs = {"a": P(None, "model"), "b": P(None),
             "c": P("model", "data", None)}
    z = zero1_pspecs(specs, params, mesh, ("data",))
    # "a": data added on the largest free divisible axis (16)
    assert z["a"] == P("data", "model")
    # "c": data already used -> untouched
    assert z["c"] == P("model", "data", None)
    # every axis appears at most once per spec
    for spec in jax.tree.leaves(z, is_leaf=lambda x: isinstance(x, P)):
        flat = [a for e in spec for a in
                (e if isinstance(e, tuple) else (e,)) if a]
        assert len(flat) == len(set(flat))


@given(seed=st.integers(0, 1000))
def test_int8_quantization_bounded_error(seed):
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.standard_normal(256).astype(np.float32))
    q, scale = quantize_int8(g)
    err = np.abs(np.asarray(q, np.float32) * float(scale) - np.asarray(g))
    assert err.max() <= float(scale) * 0.5 + 1e-7


def test_error_feedback_preserves_signal():
    """Sum of decompressed grads over steps tracks the true sum (the
    residual never grows unboundedly)."""
    rng = np.random.default_rng(0)
    true_sum = np.zeros(64, np.float32)
    sent_sum = np.zeros(64, np.float32)
    state = init_compression({"g": jnp.zeros(64)})
    for _ in range(50):
        g = rng.standard_normal(64).astype(np.float32)
        true_sum += g
        out, state = compress_error_feedback({"g": jnp.asarray(g)}, state)
        sent_sum += np.asarray(out["g"])
    resid = np.abs(np.asarray(state.error["g"]))
    np.testing.assert_allclose(sent_sum + np.asarray(state.error["g"]),
                               true_sum, atol=1e-3)
    assert resid.max() < 0.2      # residual stays one-quantum sized


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------

def test_stream_deterministic_and_resumable():
    cfg = get("qwen3-0.6b").reduced()
    shape = ShapeSpec("t", 32, 4, "train")
    s1, s2 = make_stream(cfg, shape), make_stream(cfg, shape)
    for i in (0, 7, 123):
        np.testing.assert_array_equal(s1.batch(i)["tokens"],
                                      s2.batch(i)["tokens"])
    it = s1.at(7)
    np.testing.assert_array_equal(next(it)["tokens"],
                                  s2.batch(7)["tokens"])
    assert s1.batch(0)["tokens"].shape == (4, 33)
    assert s1.batch(0)["tokens"].max() < cfg.vocab


def test_stream_modalities():
    shape = ShapeSpec("t", 32, 2, "train")
    enc = make_stream(get("whisper-medium").reduced(), shape).batch(0)
    assert "audio_embeds" in enc and enc["tokens"].shape[1] == 32 // 8 + 1
    vlm = make_stream(get("internvl2-2b").reduced(), shape).batch(0)
    assert "vision" in vlm


def test_host_slice():
    assert host_slice(16, 0, 4) == slice(0, 4)
    assert host_slice(16, 3, 4) == slice(12, 16)
    with pytest.raises(ValueError):
        host_slice(10, 0, 4)


def test_bytes_source(tmp_path):
    p = tmp_path / "corpus.txt"
    p.write_text("hello world " * 100)
    cfg = get("qwen3-0.6b").reduced()
    shape = ShapeSpec("t", 16, 2, "train")
    s = make_stream(cfg, shape, DataConfig(source="bytes", path=str(p)))
    b = s.batch(0)["tokens"]
    assert b.shape == (2, 17)
    assert b.max() < 256                       # byte-level


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip_and_keep_n(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_n=2)
    tree = {"params": {"w": jnp.arange(6.0).reshape(2, 3)},
            "opt": (jnp.asarray(1), [jnp.ones(2)] )}
    for s in (1, 5, 9):
        mgr.save(s, tree, blocking=True)
    assert mgr.steps() == [5, 9]
    assert mgr.latest_step() == 9
    back = mgr.restore()
    np.testing.assert_array_equal(back["params"]["w"],
                                  np.arange(6.0).reshape(2, 3))
    assert isinstance(back["opt"], tuple)


def test_checkpoint_ignores_partial_tmp(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(3, {"x": jnp.zeros(2)}, blocking=True)
    os.makedirs(tmp_path / "step_00000007.tmp")     # crashed save
    assert mgr.latest_step() == 3
    mgr.restore()                                    # no error


def test_checkpoint_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"x": jnp.ones(4)})
    mgr.wait()
    assert mgr.latest_step() == 1


# ---------------------------------------------------------------------------
# runtime
# ---------------------------------------------------------------------------

def test_failure_injection_and_restart():
    inj = FailureInjector(fail_at=(2, 5))
    seen = []

    latest = {"v": None}

    def body(start):
        for s in range(start, 8):
            inj.maybe_fail(s)
            seen.append(s)
            latest["v"] = s
        return 7

    assert run_with_restarts(body, lambda: latest["v"]) == 7
    assert seen == [0, 1, 2, 3, 4, 5, 6, 7]   # 2 and 5 retried post-crash


def test_restart_gives_up():
    inj = FailureInjector(p_fail=1.0)

    def body(start):
        inj.maybe_fail(start)
        return start

    with pytest.raises(SimulatedFailure):
        run_with_restarts(body, lambda: None, max_restarts=3)


def test_straggler_detector():
    det = StragglerDetector(warmup=3)
    flags = [det.update(1.0 + 0.01 * i) for i in range(20)]
    assert not any(flags)
    assert det.update(10.0)
    assert det.flagged == 1
    # stats not polluted by the outlier
    assert det.mean < 2.0


def test_best_mesh_shape():
    assert best_mesh_shape(512, 16, pod=2) == (2, 16, 16)
    assert best_mesh_shape(256, 16) == (16, 16)
    assert best_mesh_shape(7, 2) == (3, 2)
    with pytest.raises(ValueError):
        best_mesh_shape(8, 16)
