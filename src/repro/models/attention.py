"""Grouped-query attention: triangular-chunked prefill, sliding-window
(local) banded attention, single-token decode against full or ring caches,
and cross-attention (enc-dec).

Memory/computation design (TPU-first, validated on CPU):
  * Prefill/train attention is *chunked* flash-style: fp32 running
    (max, sum, acc) over KV blocks, so the (S×S) score matrix is never
    materialized — the live working set is (q_chunk × kv_chunk) per head.
    Chunk loops are Python-static, and causal chunking is *triangular*:
    a query chunk only visits KV chunks at or below its diagonal, so the
    compiled FLOPs are the ~S²/2 a causal kernel actually needs, not S².
  * GQA uses a grouped einsum (B,S,K,G,hd × B,S,K,hd) — KV heads are never
    broadcast to H.
  * Local (sliding-window) layers visit only the in-window KV chunks and
    carry ring caches of length ``min(window, S)`` at decode.
  * With ``cfg.use_pallas`` the prefill path dispatches to the Pallas
    flash kernel (kernels/flash_attention.py); the pure-jnp path here is
    its oracle and the XLA path used by the multi-pod dry-run.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..configs.base import ModelConfig
from .layers import Params, dense_init, rms_norm, rope

__all__ = ["attn_init", "attn_pspec", "attn_prefill", "attn_decode",
           "cross_attn_apply", "init_cache", "cache_pspec", "NEG_INF"]

NEG_INF = -2.0 ** 30   # large-but-finite; keeps bf16/fp32 math NaN-free


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------

def attn_init(key: jax.Array, cfg: ModelConfig, dtype: jnp.dtype) -> Params:
    d, h, k, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, h * hd, dtype).reshape(d, h, hd),
        "wk": dense_init(ks[1], d, k * hd, dtype).reshape(d, k, hd),
        "wv": dense_init(ks[2], d, k * hd, dtype).reshape(d, k, hd),
        "wo": dense_init(ks[3], h * hd, d, dtype).reshape(h, hd, d),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), dtype)
        p["k_norm"] = jnp.zeros((hd,), dtype)
    return p


def attn_pspec(cfg: ModelConfig, tp: Optional[int] = None) -> Params:
    """Tensor-parallel attention sharding with divisibility fallbacks:
      * q heads % tp == 0  -> heads on "model" (Megatron-style);
        else shard the d_model contraction dim (partial-sum TP; GSPMD
        inserts the reduce) — arctic's 56 heads on a 16-way axis.
      * kv heads % tp == 0 -> kv heads on "model"; else REPLICATE kv
        (standard GQA practice when tp > n_kv_heads: kv is small).
    """
    from .layers import divisible
    q_ok = divisible(cfg.n_heads, tp)
    kv_ok = divisible(cfg.n_kv_heads, tp)
    p = {
        "wq": P(None, "model", None) if q_ok else P("model", None, None),
        "wk": P(None, "model", None) if kv_ok else P(None, None, None),
        "wv": P(None, "model", None) if kv_ok else P(None, None, None),
        "wo": P("model", None, None) if q_ok else P(None, None, "model"),
    }
    if cfg.qk_norm:
        p["q_norm"] = P(None)
        p["k_norm"] = P(None)
    return p


def _project_qkv(p: Params, x: jnp.ndarray, positions: jnp.ndarray,
                 cfg: ModelConfig
                 ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """x: (B,S,D) -> q (B,S,H,hd), k/v (B,S,K,hd), with qk-norm + RoPE."""
    q = jnp.einsum("bsd,dhq->bshq", x, p["wq"])
    k = jnp.einsum("bsd,dkq->bskq", x, p["wk"])
    v = jnp.einsum("bsd,dkq->bskq", x, p["wv"])
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if cfg.head_dim:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    return q, k, v


# ---------------------------------------------------------------------------
# chunked (flash-style) attention core
# ---------------------------------------------------------------------------

def _block_attn(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                mask: Optional[jnp.ndarray], scale: float,
                state: Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]
                ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One (q_chunk, kv_chunk) block with running-softmax state.

    q: (B,Q,K,G,hd)  k/v: (B,C,K,hd)  mask: (Q,C) or None
    state: m (B,K,G,Q), l (B,K,G,Q), acc (B,Q,K,G,hd) — fp32.
    """
    m, l, acc = state
    s = jnp.einsum("bqkgd,bckd->bkgqc", q, k).astype(jnp.float32) * scale
    if mask is not None:
        s = jnp.where(mask[None, None, None, :, :], s, NEG_INF)
    m_new = jnp.maximum(m, s.max(axis=-1))
    p = jnp.exp(s - m_new[..., None])
    corr = jnp.exp(m - m_new)
    l_new = l * corr + p.sum(axis=-1)
    pv = jnp.einsum("bkgqc,bckd->bqkgd", p.astype(v.dtype), v
                    ).astype(jnp.float32)
    acc_new = acc * corr.transpose(0, 3, 1, 2)[..., None] + pv
    return m_new, l_new, acc_new


def _chunked_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                       causal: bool, window: int,
                       q_chunk: int = 1024, kv_chunk: int = 1024
                       ) -> jnp.ndarray:
    """q: (B,S,K,G,hd), k/v: (B,S,K,hd) -> out (B,S,K,G,hd).

    Python-static triangular/banded chunk schedule; runs the ~S²/2
    (causal) or ~S·2w (local) FLOPs a real kernel would.
    """
    b, s, kh, g, hd = q.shape
    sk = k.shape[1]                     # kv length (cross-attn: != s)
    if causal or window:
        assert sk == s, "causal/local attention requires matched q/kv len"
    scale = hd ** -0.5
    q_chunk = min(q_chunk, s)
    kv_chunk = min(kv_chunk, sk)
    n_q = -(-s // q_chunk)
    outs = []
    for i in range(n_q):
        q0, q1 = i * q_chunk, min((i + 1) * q_chunk, s)
        qi = q[:, q0:q1]
        qlen = q1 - q0
        m = jnp.full((b, kh, g, qlen), NEG_INF, jnp.float32)
        l = jnp.zeros((b, kh, g, qlen), jnp.float32)
        acc = jnp.zeros((b, qlen, kh, g, hd), jnp.float32)
        # which kv chunks does this q chunk need?
        hi = q1 if causal else sk
        lo = max(0, q0 - window + 1) if window else 0
        j0, j1 = lo // kv_chunk, -(-hi // kv_chunk)
        for j in range(j0, j1):
            k0, k1 = j * kv_chunk, min((j + 1) * kv_chunk, sk)
            kj, vj = k[:, k0:k1], v[:, k0:k1]
            qpos = jnp.arange(q0, q1)[:, None]
            kpos = jnp.arange(k0, k1)[None, :]
            mask = None
            if causal or window:
                ok = jnp.ones((qlen, k1 - k0), bool)
                if causal:
                    ok &= kpos <= qpos
                if window:
                    ok &= kpos > qpos - window
                mask = ok
            m, l, acc = _block_attn(qi, kj, vj, mask, scale, (m, l, acc))
        o = acc / jnp.maximum(l, 1e-30).transpose(0, 3, 1, 2)[..., None]
        outs.append(o)
    return jnp.concatenate(outs, axis=1) if len(outs) > 1 else outs[0]


# ---------------------------------------------------------------------------
# int8 KV-cache quantization (cfg.kv_dtype == "int8")
# ---------------------------------------------------------------------------

def quantize_kv(k: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-(token, head) symmetric int8: k (..., S, K, hd) ->
    (int8 same shape, f32 scales (..., S, K, 1)). Halves the resident
    cache (+12.5% for scales at hd=32; ~3% at hd=128/256) — the decode
    roofline is cache-bandwidth-bound, so this is a direct ~2x on the
    memory term when the dequant fuses into the attention kernel."""
    a = jnp.max(jnp.abs(k.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(a / 127.0, 1e-8)
    q = jnp.clip(jnp.round(k.astype(jnp.float32) / scale), -127, 127
                 ).astype(jnp.int8)
    return q, scale


def dequantize_kv(q: jnp.ndarray, scale: jnp.ndarray,
                  dtype: jnp.dtype) -> jnp.ndarray:
    return (q.astype(jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# public ops
# ---------------------------------------------------------------------------

def attn_prefill(p: Params, x: jnp.ndarray, positions: jnp.ndarray,
                 cfg: ModelConfig, is_global: bool,
                 with_cache: bool = False, causal: bool = True
                 ) -> Tuple[jnp.ndarray, Optional[Params]]:
    """Causal (or sliding-window, or bidirectional) self-attention over a
    full sequence.

    Returns (out (B,S,D), cache or None). The cache holds roped keys —
    decode queries rope at their absolute position, so q·k stays relative.
    """
    b, s, _ = x.shape
    h, kh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    g = h // kh
    q, k, v = _project_qkv(p, x, positions, cfg)
    qg = q.reshape(b, s, kh, g, hd)
    window = 0 if is_global else cfg.window
    if cfg.use_pallas and causal:
        from ..kernels import ops as kops
        out = kops.flash_attention(qg, k, v, causal=True, window=window)
    else:
        out = _chunked_attention(qg, k, v, causal=causal, window=window)
    out = out.reshape(b, s, h, hd).astype(x.dtype)
    y = jnp.einsum("bshq,hqd->bsd", out, p["wo"])
    cache = None
    if with_cache:
        if window and s > window:
            # ring cache keeps the last `window` roped keys/values
            k = k[:, -window:]
            v = v[:, -window:]
        if cfg.kv_dtype == "int8":
            qk, sk = quantize_kv(k)
            qv, sv = quantize_kv(v)
            cache = {"k": qk, "k_s": sk, "v": qv, "v_s": sv}
        else:
            cache = {"k": k, "v": v}
    return y, cache


def grow_cache(cache: Params, cfg: ModelConfig, is_global: bool,
               cache_len: int, prefill_len: int) -> Params:
    """Grow a prefill-produced cache to its serving capacity.

    Global caches are zero-padded to ``cache_len`` (writes continue at slot
    ``pos``). Local ring caches are rolled so slot ``p % window`` holds
    position ``p``, matching ``attn_decode``'s ring indexing.
    """
    w = 0 if (is_global or not cfg.window) else cfg.window
    tgt = min(w, cache_len) if w else cache_len

    def fix(a: jnp.ndarray) -> jnp.ndarray:
        axis = a.ndim - 3                 # (..., B, C, K, hd): seq at -3
        cur = a.shape[axis]
        if w and prefill_len >= w:
            return jnp.roll(a, prefill_len % w, axis=axis)
        if tgt > cur:
            pad = [(0, 0)] * a.ndim
            pad[axis] = (0, tgt - cur)
            return jnp.pad(a, pad)
        return a

    return jax.tree.map(fix, cache)


def init_cache(cfg: ModelConfig, batch: int, cache_len: int, is_global: bool,
               dtype: jnp.dtype) -> Params:
    eff = cache_len if (is_global or not cfg.window) \
        else min(cfg.window, cache_len)
    shape = (batch, eff, cfg.n_kv_heads, cfg.head_dim)
    if cfg.kv_dtype == "int8":
        sshape = shape[:-1] + (1,)
        return {"k": jnp.zeros(shape, jnp.int8),
                "k_s": jnp.zeros(sshape, jnp.float32),
                "v": jnp.zeros(shape, jnp.int8),
                "v_s": jnp.zeros(sshape, jnp.float32)}
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def cache_pspec(batch_axes, shard_seq: bool, kv_ok: bool = True,
                quantized: bool = False) -> Params:
    """Cache (B, S, K, hd): batch on data axes for batched decode; for
    batch=1 long-context decode, shard the sequence dim instead (sequence
    parallelism). KV-head dim shards on "model" when divisible; otherwise
    the head_dim shards instead (always a multiple of 16 here) — a
    32k-cache arctic decode is 600 GB and MUST split over both axes.
    Quantized caches carry per-(token, head) f32 scales whose trailing
    dim (1) never shards."""
    kh, hd = ("model", None) if kv_ok else (None, "model")
    if shard_seq:
        spec = P(None, batch_axes, kh, hd)
        sspec = P(None, batch_axes, kh, None)
    else:
        spec = P(batch_axes, None, kh, hd)
        sspec = P(batch_axes, None, kh, None)
    if quantized:
        return {"k": spec, "k_s": sspec, "v": spec, "v_s": sspec}
    return {"k": spec, "v": spec}


def attn_decode(p: Params, x: jnp.ndarray, cache: Params, pos: jnp.ndarray,
                cfg: ModelConfig, is_global: bool
                ) -> Tuple[jnp.ndarray, Params]:
    """One-token decode. x: (B,1,D); cache k/v: (B,C,K,hd); pos: () int32
    — number of tokens already in the cache (same for the whole batch).

    Global layers: C == full seq; the new k/v is written at slot ``pos``.
    Local layers: C == window; ring write at ``pos % window``.
    """
    b, one, d = x.shape
    h, kh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    g = h // kh
    positions = jnp.full((b, 1), pos, jnp.int32)
    q, k_new, v_new = _project_qkv(p, x, positions, cfg)
    c = cache["k"].shape[1]
    window = 0 if is_global else cfg.window
    slot = jnp.mod(pos, c) if (window and window == c) else pos
    quantized = "k_s" in cache
    if quantized:
        qk, sk = quantize_kv(k_new)
        qv, sv = quantize_kv(v_new)
        new_cache = {
            "k": jax.lax.dynamic_update_slice_in_dim(
                cache["k"], qk, slot, axis=1),
            "k_s": jax.lax.dynamic_update_slice_in_dim(
                cache["k_s"], sk, slot, axis=1),
            "v": jax.lax.dynamic_update_slice_in_dim(
                cache["v"], qv, slot, axis=1),
            "v_s": jax.lax.dynamic_update_slice_in_dim(
                cache["v_s"], sv, slot, axis=1),
        }
        # on TPU the dequant fuses into the attention reads (the Pallas
        # decode kernel takes int8 + scales directly); the XLA path
        # dequantizes explicitly.
        k = dequantize_kv(new_cache["k"], new_cache["k_s"], x.dtype)
        v = dequantize_kv(new_cache["v"], new_cache["v_s"], x.dtype)
    else:
        k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new, slot,
                                                axis=1)
        v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new, slot,
                                                axis=1)

    # ring layout: every written slot holds one of the last `window`
    # positions (all ≤ pos), so slots [0, min(pos+1, c)) are valid;
    # linear layout: slots [0, pos+1).
    if window and window == c:
        valid_len = jnp.minimum(pos + 1, c)
    else:
        valid_len = pos + 1
    if cfg.use_pallas:
        from ..kernels import ops as kops
        o = kops.decode_attention(q.reshape(b, kh, g, hd), k, v,
                                  valid_len).astype(x.dtype)
        o = o.reshape(b, 1, h, hd)
    else:
        qg = q.reshape(b, 1, kh, g, hd)
        s = jnp.einsum("bqkgd,bckd->bkgqc", qg, k).astype(jnp.float32) \
            * (hd ** -0.5)
        idx = jnp.arange(c)[None, None, None, None, :]
        s = jnp.where(idx < valid_len, s, NEG_INF)
        w = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bkgqc,bckd->bqkgd", w.astype(v.dtype), v)
        o = o.reshape(b, 1, h, hd)
    y = jnp.einsum("bshq,hqd->bsd", o, p["wo"])
    return y, (new_cache if quantized else {"k": k, "v": v})


# ---------------------------------------------------------------------------
# cross-attention (whisper decoder)
# ---------------------------------------------------------------------------

def cross_attn_apply(p: Params, x: jnp.ndarray, enc_k: jnp.ndarray,
                     enc_v: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """x: (B,S,D) queries; enc_k/enc_v: (B,Se,K,hd) precomputed from the
    encoder output (no mask, no rope on cross path)."""
    b, s, _ = x.shape
    h, kh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    g = h // kh
    q = jnp.einsum("bsd,dhq->bshq", x, p["wq"]).reshape(b, s, kh, g, hd)
    out = _chunked_attention(q, enc_k, enc_v, causal=False, window=0)
    out = out.reshape(b, s, h, hd).astype(x.dtype)
    return jnp.einsum("bshq,hqd->bsd", out, p["wo"])


def cross_kv(p: Params, enc_out: jnp.ndarray, cfg: ModelConfig
             ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    k = jnp.einsum("bsd,dkq->bskq", enc_out, p["wk"])
    v = jnp.einsum("bsd,dkq->bskq", enc_out, p["wv"])
    return k, v
