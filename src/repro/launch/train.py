"""Fault-tolerant trainer (single-controller).

Wires every substrate layer together: mesh (elastic), data stream
(stateless-resumable), jitted sharded train step (ZeRO-1, optional
microbatch accumulation + int8 error-feedback grad compression),
async checkpointing (atomic, keep-N), failure injection + restart
supervision, straggler detection.

CLI (reduced configs run on CPU — see examples/train_lm.py):
    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b \
        --reduced --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from ..checkpoint import CheckpointManager
from ..configs import get
from ..configs.base import ModelConfig, ShapeSpec
from ..data import DataConfig, make_stream
from ..optim import AdamWConfig, OptState, adamw_init
from ..optim.compression import (CompressionState, compress_error_feedback,
                                 init_compression)
from ..runtime import (FailureInjector, StragglerDetector, elastic_mesh,
                       run_with_restarts)
from .mesh import data_axes_of
from .steps import make_train_objects, named

__all__ = ["TrainerConfig", "Trainer", "main"]


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: Optional[str] = None
    keep_n: int = 3
    accum: int = 1
    compress_grads: bool = False
    log_every: int = 10
    seed: int = 0
    model_axis: int = 1              # TP degree for the elastic mesh


class Trainer:
    def __init__(self, cfg: ModelConfig, shape: ShapeSpec,
                 tcfg: TrainerConfig = TrainerConfig(),
                 acfg: AdamWConfig = AdamWConfig(),
                 data: DataConfig = DataConfig(),
                 injector: Optional[FailureInjector] = None,
                 mesh: Optional[jax.sharding.Mesh] = None):
        self.cfg, self.shape, self.tcfg, self.acfg = cfg, shape, tcfg, acfg
        self.mesh = mesh or elastic_mesh(model=tcfg.model_axis)
        self.daxes = data_axes_of(self.mesh)
        self.stream = make_stream(cfg, shape, data)
        self.injector = injector or FailureInjector()
        self.straggler = StragglerDetector()
        self.mgr = (CheckpointManager(tcfg.ckpt_dir, keep_n=tcfg.keep_n)
                    if tcfg.ckpt_dir else None)
        self.metrics_log: list = []

        (self.model, step_fn, in_sh, out_sh, _shapes) = make_train_objects(
            cfg, shape, self.mesh, self.daxes, acfg=acfg, accum=tcfg.accum)
        self._param_sh, self._opt_sh, self._batch_sh = in_sh
        if tcfg.compress_grads:
            base = step_fn

            def step_fn(params, opt_and_comp, batch):  # noqa: F811
                opt, comp = opt_and_comp
                (loss, _), grads = jax.value_and_grad(
                    self.model.loss_fn, has_aux=True)(params, batch)
                grads, comp = compress_error_feedback(grads, comp)
                from ..optim import adamw_update
                params, opt, om = adamw_update(grads, opt, params, acfg)
                return params, (opt, comp), {"loss": loss, **om}

            comp_sh = CompressionState(
                error=named(self.mesh, self.model.param_pspecs()))
            self._opt_sh = (self._opt_sh, comp_sh)
            in_sh = (self._param_sh, self._opt_sh, self._batch_sh)
            out_sh = (self._param_sh, self._opt_sh,
                      out_sh[2])
        self._step = jax.jit(step_fn, in_shardings=in_sh,
                             out_shardings=out_sh, donate_argnums=(0, 1))

    # ------------------------------------------------------------- state
    def init_state(self):
        with self.mesh:
            params = jax.jit(
                self.model.init,
                out_shardings=self._param_sh)(
                    jax.random.PRNGKey(self.tcfg.seed))
            opt = adamw_init(params)
            if self.tcfg.compress_grads:
                opt = (opt, init_compression(params))
        return params, opt

    def _restore(self, step: int):
        tree = self.mgr.restore(step)
        params = jax.tree.map(jax.device_put, tree["params"],
                              self._param_sh)
        o = tree["opt"]
        opt = OptState(mu=o["mu"], nu=o["nu"],
                       count=jnp.asarray(o["count"]))
        opt = jax.tree.map(jax.device_put, opt, self._opt_sh) \
            if not self.tcfg.compress_grads else None
        if self.tcfg.compress_grads:
            comp = CompressionState(error=tree["comp"])
            opt = jax.tree.map(
                jax.device_put,
                (OptState(mu=o["mu"], nu=o["nu"],
                          count=jnp.asarray(o["count"])), comp),
                self._opt_sh)
        return params, opt

    def _save(self, step: int, params, opt, blocking=False):
        if self.mgr is None:
            return
        if self.tcfg.compress_grads:
            (o, comp) = opt
            tree = {"params": params,
                    "opt": {"mu": o.mu, "nu": o.nu, "count": o.count},
                    "comp": comp.error}
        else:
            tree = {"params": params,
                    "opt": {"mu": opt.mu, "nu": opt.nu,
                            "count": opt.count}}
        self.mgr.save(step, tree, blocking=blocking)

    # -------------------------------------------------------------- train
    def train(self, max_restarts: int = 5) -> Dict[str, Any]:
        def body(start_step: int) -> int:
            if start_step > 0 and self.mgr is not None:
                params, opt = self._restore(start_step - 1)
            else:
                params, opt = self.init_state()
            it = self.stream.at(start_step)
            step = start_step
            for batch in it:
                if step >= self.tcfg.steps:
                    break
                self.injector.maybe_fail(step)
                t0 = time.time()
                params, opt, m = self._step(params, opt, batch)
                jax.block_until_ready(m["loss"])
                dt = time.time() - t0
                slow = self.straggler.update(dt)
                if step % self.tcfg.log_every == 0 or slow:
                    rec = {"step": step, "loss": float(m["loss"]),
                           "lr": float(m["lr"]),
                           "grad_norm": float(m["grad_norm"]),
                           "dt": dt, "straggler": slow}
                    self.metrics_log.append(rec)
                    print(f"[train] step {step} loss {rec['loss']:.4f} "
                          f"gnorm {rec['grad_norm']:.3f} {dt*1e3:.0f}ms"
                          + (" STRAGGLER" % () if slow else ""),
                          flush=True)
                if (self.mgr is not None
                        and step % self.tcfg.ckpt_every == 0):
                    self._save(step, params, opt)
                step += 1
            if self.mgr is not None:
                self._save(step - 1, params, opt, blocking=True)
            self._final = (params, opt)
            return step - 1

        latest = (self.mgr.latest_step if self.mgr is not None
                  else (lambda: None))
        final = run_with_restarts(body, latest, max_restarts=max_restarts)
        return {"final_step": final, "metrics": self.metrics_log,
                "stragglers": self.straggler.flagged}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--model-axis", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--fail-at", type=int, nargs="*", default=[])
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()

    cfg = get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    shape = ShapeSpec("cli", args.seq, args.batch, "train")
    tcfg = TrainerConfig(steps=args.steps, ckpt_dir=args.ckpt_dir,
                         ckpt_every=args.ckpt_every, accum=args.accum,
                         compress_grads=args.compress_grads,
                         model_axis=args.model_axis)
    acfg = AdamWConfig(lr=args.lr, total_steps=args.steps,
                       warmup_steps=max(args.steps // 10, 1))
    inj = FailureInjector(fail_at=tuple(args.fail_at))
    out = Trainer(cfg, shape, tcfg, acfg, injector=inj).train()
    print(f"[train] done: final_step={out['final_step']} "
          f"stragglers={out['stragglers']}")


if __name__ == "__main__":
    main()
