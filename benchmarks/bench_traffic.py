"""Traffic engine benchmark (DESIGN.md §10, EXPERIMENTS.md §Traffic):
sweep arrival intensity × scenario family and compare, at MATCHED solver
budgets (same PSOGAConfig, same seed):

  * **zero-load plan** — the paper's single-shot solve, then evaluated
    under the request stream it never saw;
  * **traffic-aware plan** — the same solver with the queue-aware
    Monte-Carlo fitness (p95 deadline-miss budget);
  * **greedy baseline** — the paper's greedy competitor, evaluated
    under the same stream (HEFT's makespan anchors every deadline).

Both plans are scored on a HELD-OUT arrival set (disjoint seed stream
from the solver's draws), reporting p50/p95/p99 deadline-miss rates,
load-adjusted cost, and solver wall-clock. Acceptance bar (ISSUE-5):
the traffic-aware plan's p95 miss rate must be STRICTLY below the
zero-load plan's on the bursty and flash-crowd families. Every run
writes machine-readable ``BENCH_traffic.json``.

A backend microbench (ISSUE-6) also times the traffic-replay fitness
itself — the default merged-order scan, its compacted-prefix variant
(``compact=True``, the kernel's scan twin), and the fused Pallas
event-walk kernel (``kernels.traffic_sim``; interpret mode lowers it
to plain XLA on CPU, native Pallas on TPU) — in swarm fitness
evaluations/s, stamped into the ``backends`` section of the JSON.
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.core import (PSOGAConfig, SimProblem, TRAFFIC_KINDS,
                        TrafficConfig, greedy_offload, heft_makespan,
                        paper_environment, run_pso_ga_batch,
                        traffic_replay, traffic_stats, zoo)

from .common import bench_metadata, print_csv

#: CPU-friendly matched budget for both arms
TRAFFIC_CFG = PSOGAConfig(pop_size=24, max_iters=60, stall_iters=20)
NETS = ("alexnet", "googlenet")


def build_problems(ratio: float):
    env = paper_environment()
    dags, probs = [], []
    for i, net in enumerate(NETS):
        dag = zoo.build(net, pin_server=i)
        h, _ = heft_makespan(dag, env)
        dag = dag.with_deadline(np.array([ratio * h]))
        dags.append(dag)
        probs.append(SimProblem.build(dag, env))
    return env, dags, probs


def run_cell(kind: str, rate: float, cfg: PSOGAConfig, ratio: float,
             seed: int, mc_eval: int):
    env, dags, probs = build_problems(ratio)
    tc = TrafficConfig(kind=kind, rate=rate, horizon=30.0, max_requests=8,
                       mc_solver=3, mc_eval=mc_eval,
                       miss_budget=cfg.miss_budget)
    n = len(probs)
    t0 = time.perf_counter()
    zero = run_pso_ga_batch(probs, cfg, seed=seed)
    wall_zero = time.perf_counter() - t0
    arrs = [tc.solver_arrivals(1, seed=seed + 31 * i) for i in range(n)]
    t0 = time.perf_counter()
    aware = run_pso_ga_batch(probs, cfg, seed=seed, arrivals=arrs)
    wall_aware = time.perf_counter() - t0

    rows = []
    for i, net in enumerate(NETS):
        ev = tc.eval_arrivals(1, seed=seed + 31 * i)
        stats = {}
        plans = {
            "zero": zero[i].best_x,
            "aware": aware[i].best_x,
            "greedy": greedy_offload(dags[i], env,
                                     faithful=cfg.faithful_sim).best_x,
        }
        for arm, x in plans.items():
            stats[arm] = traffic_stats(traffic_replay(
                probs[i], x, ev, faithful=cfg.faithful_sim))
        rows.append({
            "kind": kind, "rate": rate, "net": net,
            "zero_miss_p95": stats["zero"]["miss_p95"],
            "aware_miss_p95": stats["aware"]["miss_p95"],
            "greedy_miss_p95": stats["greedy"]["miss_p95"],
            "zero_miss_mean": stats["zero"]["miss_mean"],
            "aware_miss_mean": stats["aware"]["miss_mean"],
            "zero_load_cost": stats["zero"]["cost_mean"],
            "aware_load_cost": stats["aware"]["cost_mean"],
            "greedy_load_cost": stats["greedy"]["cost_mean"],
            "requests": stats["zero"]["requests"],
            "zero_wall_s": wall_zero,
            "aware_wall_s": wall_aware,
            "aware_iters": int(aware[i].iterations),
        })
    return rows


def bench_backends(ratio: float, seed: int, P: int = 64, reps: int = 20):
    """Traffic-fitness replay throughput per backend, per zoo net.

    One "iter" is a full swarm evaluation: P particles × the solver's
    Monte-Carlo seeds, through the per-seed ``(total, miss, lat_sum)``
    summary that dominates ``make_swarm_fitness``'s traffic key. The
    headline ``speedup`` column is the fused Pallas event-walk kernel
    over the default scan backend — the kernel never materializes the
    scan's per-step ``(T, …)`` gathers or ``(P, T)`` one-hot selects,
    which is what makes contention fitness track the zero-load path
    even in interpret mode (lowered to XLA) on CPU; ``scan_compact``
    (the kernel's scan twin, ``compact=True``) is reported for
    completeness — it wins only when +inf padding dominates the merged
    step sequence.
    """
    import functools

    import jax
    import jax.numpy as jnp

    from repro.core import sample_arrivals
    from repro.core.simulator import pad_problem
    from repro.core.traffic import simulate_traffic_swarm
    from repro.kernels.traffic_sim import traffic_replay_folded

    env = paper_environment()
    rows = []
    for net in NETS:
        dag = zoo.build(net, pin_server=0)
        h, _ = heft_makespan(dag, env)
        dag = dag.with_deadline(np.array([ratio * h]))
        prob = SimProblem.build(dag, env)
        pp = pad_problem(prob)
        arr = jnp.asarray(sample_arrivals(
            "bursty", 1, rate=0.5, horizon=30.0, max_requests=8,
            n_seeds=3, seed=seed).t)
        rng = np.random.default_rng(seed)
        X = jnp.asarray(rng.integers(
            0, prob.num_servers, size=(P, prob.num_layers)), jnp.int32)

        def scan_stats(X, compact):
            def one(a):
                s = simulate_traffic_swarm(pp, X, a, True, compact=compact)
                return s.total_cost, s.miss_rate, s.lat_sum
            return jax.vmap(one)(arr)

        def kernel_stats(X):
            def one(a):
                t, m, l, _, _ = traffic_replay_folded(
                    pp.order, pp.compute, pp.parent_idx, pp.parent_mb,
                    pp.child_idx, pp.child_mb, pp.app_id, pp.deadline,
                    pp.pinned, pp.power, pp.cost_per_sec, pp.inv_bw,
                    pp.tran_cost, pp.link_ok, pp.num_apps, X, a,
                    faithful=True, interpret=True)
                return t, m, l
            return jax.vmap(one)(arr)

        arms = {
            "scan": jax.jit(functools.partial(scan_stats, compact=False)),
            "scan_compact": jax.jit(functools.partial(scan_stats,
                                                      compact=True)),
            "pallas": jax.jit(kernel_stats),
        }
        row = {"net": net, "P": P, "mc": int(arr.shape[0]), "reps": reps}
        for arm, fn in arms.items():
            jax.block_until_ready(fn(X))            # compile outside timer
            t0 = time.perf_counter()
            out = None
            for _ in range(reps):
                out = fn(X)
            jax.block_until_ready(out)
            row[f"{arm}_iters_s"] = reps / (time.perf_counter() - t0)
        row["speedup"] = row["pallas_iters_s"] / row["scan_iters_s"]
        print(f"# backends {net}: scan {row['scan_iters_s']:.1f}/s, "
              f"scan_compact {row['scan_compact_iters_s']:.1f}/s, "
              f"pallas-interpret {row['pallas_iters_s']:.1f}/s "
              f"({row['speedup']:.2f}x over scan)", flush=True)
        rows.append(row)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--kinds", nargs="*", default=["all"],
                    choices=list(TRAFFIC_KINDS) + ["all"])
    ap.add_argument("--rates", type=float, nargs="*",
                    default=[0.2, 0.5])
    ap.add_argument("--ratio", type=float, default=1.5,
                    help="deadline ratio r in D = r · HEFT (Eq. 24)")
    ap.add_argument("--mc-eval", type=int, default=16,
                    help="held-out Monte-Carlo arrival seeds per cell")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--backend-reps", type=int, default=20,
                    help="timed fitness evaluations per backend arm "
                         "(0 skips the backend microbench)")
    ap.add_argument("--json", default="BENCH_traffic.json",
                    help="machine-readable results ('' to disable)")
    args = ap.parse_args()
    kinds = TRAFFIC_KINDS if "all" in args.kinds else args.kinds

    backend_rows = []
    if args.backend_reps > 0:
        backend_rows = bench_backends(args.ratio, args.seed,
                                      reps=args.backend_reps)

    all_rows, summaries = [], []
    for kind in kinds:
        kind_rows = []
        for rate in args.rates:
            rows = run_cell(kind, rate, TRAFFIC_CFG, args.ratio,
                            args.seed, args.mc_eval)
            for r in rows:
                print(f"# {kind} rate={rate} {r['net']}: miss p95 "
                      f"zero {r['zero_miss_p95']:.3f} -> aware "
                      f"{r['aware_miss_p95']:.3f} (greedy "
                      f"{r['greedy_miss_p95']:.3f}), load cost "
                      f"${r['zero_load_cost']:.4f} -> "
                      f"${r['aware_load_cost']:.4f}, solver "
                      f"{r['zero_wall_s']:.1f}s -> {r['aware_wall_s']:.1f}s",
                      flush=True)
            kind_rows.extend(rows)
        zero_p95 = float(np.mean([r["zero_miss_p95"] for r in kind_rows]))
        aware_p95 = float(np.mean([r["aware_miss_p95"] for r in kind_rows]))
        summaries.append({
            "kind": kind,
            "zero_miss_p95_mean": zero_p95,
            "aware_miss_p95_mean": aware_p95,
            "aware_strictly_better": bool(aware_p95 < zero_p95),
            "aware_wall_mean_s": float(np.mean(
                [r["aware_wall_s"] for r in kind_rows])),
            "zero_wall_mean_s": float(np.mean(
                [r["zero_wall_s"] for r in kind_rows])),
        })
        bar = kind in ("bursty", "flash-crowd")
        ok = aware_p95 < zero_p95
        print(f"# {kind}: mean p95 miss zero {zero_p95:.3f} vs aware "
              f"{aware_p95:.3f} -> "
              f"{'PASS' if ok else ('MISS' if bar else 'info')}",
              flush=True)
        all_rows.extend(kind_rows)
    print_csv(all_rows, ["kind", "rate", "net", "zero_miss_p95",
                         "aware_miss_p95", "greedy_miss_p95",
                         "zero_load_cost", "aware_load_cost",
                         "requests", "zero_wall_s", "aware_wall_s"])
    if args.json:
        payload = {
            "bench": "bench_traffic",
            "meta": bench_metadata(seeds=[args.seed]),
            "pso": {"pop_size": TRAFFIC_CFG.pop_size,
                    "max_iters": TRAFFIC_CFG.max_iters,
                    "stall_iters": TRAFFIC_CFG.stall_iters,
                    "miss_budget": TRAFFIC_CFG.miss_budget},
            "ratio": args.ratio,
            "rates": args.rates,
            "mc_eval": args.mc_eval,
            "rows": all_rows,
            "scenarios": summaries,
        }
        if backend_rows:
            payload["backends"] = {
                "headline": "pallas event-walk kernel (interpret mode "
                            "-> XLA on this CPU host; native on TPU) "
                            "vs the default merged-order scan backend",
                "rows": backend_rows,
                "best_speedup": max(r["speedup"] for r in backend_rows),
            }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"# wrote {args.json}")


if __name__ == "__main__":
    main()
