"""Pure-jnp oracles for every Pallas kernel (the contract the kernels are
property-tested against — tests/test_kernels.py sweeps shapes & dtypes).

These are *definitions*, not fast paths: O(S^2) score materialization is
fine here.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -2.0 ** 30

__all__ = ["flash_attention_ref", "ssd_intra_ref", "decode_attention_ref",
           "NEG_INF"]


def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        causal: bool = True, window: int = 0) -> jnp.ndarray:
    """q: (B,S,K,G,hd); k/v: (B,S,K,hd) -> out (B,S,K,G,hd) (fp32 math)."""
    b, s, kh, g, hd = q.shape
    scale = hd ** -0.5
    scores = jnp.einsum("bqkgd,bckd->bkgqc", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(k.shape[1])[None, :]
    ok = jnp.ones((s, k.shape[1]), bool)
    if causal:
        ok &= kpos <= qpos
    if window:
        ok &= kpos > qpos - window
    scores = jnp.where(ok[None, None, None], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqc,bckd->bqkgd", w, v.astype(jnp.float32))
    return out


def ssd_intra_ref(xc: jnp.ndarray, cum: jnp.ndarray, Bc: jnp.ndarray,
                  Cc: jnp.ndarray) -> jnp.ndarray:
    """Intra-chunk SSD quadratic form.

    xc: (b,c,q,h,p) fp32; cum: (b,c,q,h) inclusive cumsum of log-decay;
    Bc/Cc: (b,c,q,n). Returns (b,c,q,h,p):
        out[i] = sum_{j<=i} (C_i . B_j) * exp(cum_i - cum_j) * xc[j]
    """
    q = xc.shape[2]
    li = cum[:, :, :, None, :]
    lj = cum[:, :, None, :, :]
    mask = jnp.tril(jnp.ones((q, q), bool))
    L = jnp.where(mask[None, None, :, :, None], jnp.exp(li - lj), 0.0)
    scores = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)
    return jnp.einsum("bcij,bcijh,bcjhp->bcihp", scores, L, xc)


def decode_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                         valid_len: jnp.ndarray) -> jnp.ndarray:
    """One-token decode. q: (B,K,G,hd); k/v: (B,C,K,hd);
    valid_len: () int32 — slots [0, valid_len) are live. -> (B,K,G,hd)."""
    b, c, kh, hd = k.shape
    scale = hd ** -0.5
    s = jnp.einsum("bkgd,bckd->bkgc", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    ok = jnp.arange(c)[None, None, None, :] < valid_len
    s = jnp.where(ok, s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bkgc,bckd->bkgd", w, v.astype(jnp.float32))
