"""PSO-GA — self-adaptive discrete PSO with GA operators (paper §IV-B).

The particle position is the server-assignment vector (the order genes φ
are frozen to the topological order at init — §IV-B.3). One iteration
applies, per particle (Eq. 17–20):

    A = w  ⊕ Mu(X)            mutation       (inertia component)
    B = c1 ⊕ Cp(A, pBest)     crossover      (individual cognition)
    C = c2 ⊕ Cg(B, gBest)     crossover      (social cognition)

with the self-adaptive inertia weight (Eq. 22–23)

    w = w_max − (w_max − w_min) · exp(d / (d − 1.01)),
    d = div(gBest, X) / p_dims       (fraction of differing genes)

(d→0 ⇒ w→w_min: converged particles mutate rarely; d→1 ⇒ w→w_max).
Acceleration coefficients ramp linearly: c1 0.9→0.2, c2 0.4→0.9 [34].

The whole swarm advances in one jitted step: fitness is the swarm-level
Algorithm-2 simulator (``fitness.make_swarm_fitness`` — two-phase scan
or the Pallas replay kernel, per ``PSOGAConfig.fitness_backend``,
DESIGN.md §8), mutation/crossover are vectorized index ops, and the
iteration loop is a ``lax.while_loop`` with the paper's stopping rule
(terminate when gBest is unchanged for ``stall_iters`` iterations, or at
``max_iters``).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .dag import LayerDAG
from .environment import Environment
from .fitness import make_swarm_fitness
from .simulator import (PaddedProblem, SimProblem, build_simulator,
                        pad_problem)
from .telemetry import get_telemetry

__all__ = ["PSOGAConfig", "PSOGAResult", "run_pso_ga", "init_swarm",
           "swarm_step"]


@dataclasses.dataclass(frozen=True)
class PSOGAConfig:
    pop_size: int = 100
    max_iters: int = 1000
    stall_iters: int = 50           # paper §V-C: stop after 50 unchanged
    w_max: float = 0.9
    w_min: float = 0.4
    c1_start: float = 0.9
    c1_end: float = 0.2
    c2_start: float = 0.4
    c2_end: float = 0.9
    faithful_sim: bool = False      # False = parent-gated recurrence, which
    #   matches the paper's own worked example (Fig. 2: 3.41 s / 3.65 s /
    #   ">4 s" are only reproduced with parent gating); True = the printed
    #   Alg. 2 line-21 recurrence verbatim (see DESIGN.md §2).
    bias_init_to_tiers: bool = True  # seed swarm with tier-aware particles
    fitness_backend: str = "scan"   # scan | pallas | auto (DESIGN.md §8):
    #   "scan" = two-phase simulate_padded under vmap (bit-exact default);
    #   "pallas" = kernels/schedule_sim tile kernel (interpret off-TPU);
    #   "auto" = pallas on TPU, scan elsewhere.
    # -- incumbent ("warm") seeding, used by online re-planning
    #    (DESIGN.md §9); only consulted when init_swarm gets an incumbent.
    warm_elite: int = 2             # exact clones of the incumbent plan
    warm_fraction: float = 0.5      # swarm share seeded in the incumbent's
    #   mutated neighborhood (per-gene redraw with prob warm_mutation)
    warm_mutation: float = 0.1      # per-gene neighborhood redraw prob
    # -- contention-aware fitness (DESIGN.md §10); only consulted when a
    #    solve is handed Monte-Carlo ``arrivals``: the p95 deadline-miss
    #    budget the plan must satisfy under the request stream.
    miss_budget: float = 0.05


class PSOGAResult(NamedTuple):
    best_x: np.ndarray           # (p,) best server assignment found
    best_fitness: float          # scalar key (cost if feasible)
    best_cost: float             # C_total of best (inf if infeasible)
    feasible: bool
    iterations: int              # iterations actually executed
    history: Optional[np.ndarray] = None  # (max_iters,) gBest key per iter


class _SwarmState(NamedTuple):
    key: jnp.ndarray
    X: jnp.ndarray               # (P, p) int32
    pbest_x: jnp.ndarray         # (P, p)
    pbest_f: jnp.ndarray         # (P,)
    gbest_x: jnp.ndarray         # (p,)
    gbest_f: jnp.ndarray         # ()
    it: jnp.ndarray              # ()
    stall: jnp.ndarray           # ()


def _clamp_pins(X: jnp.ndarray, pinned: jnp.ndarray) -> jnp.ndarray:
    return jnp.where(pinned[None, :] >= 0, pinned[None, :], X)


def _home_servers(prob: SimProblem) -> np.ndarray:
    """Per-layer home server: the pinned server of the layer's app (or 0)."""
    pin_per_app = {}
    pinned_np = np.asarray(prob.pinned)
    app_np = np.asarray(prob.app_id)
    for j in range(prob.num_layers):
        if pinned_np[j] >= 0:
            pin_per_app.setdefault(int(app_np[j]), int(pinned_np[j]))
    return np.array([pin_per_app.get(int(a), 0) for a in app_np], np.int32)


def init_swarm(key: jax.Array, prob: SimProblem, cfg: PSOGAConfig,
               incumbent: Optional[np.ndarray] = None,
               rescue: bool = False) -> jnp.ndarray:
    """Link-aware random initialization.

    Genes are drawn uniformly over the servers *reachable from the app's
    home device* ({home} ∪ {s : ℓ(home, s) > 0}) so the initial swarm has
    zero forbidden-link placements. Mutation still draws from ALL servers,
    so the full space remains reachable — this is a search-space seeding
    choice the paper leaves unspecified, not a restriction of the encoding
    (see EXPERIMENTS.md §Perf for its ablation). One particle is seeded
    with the everything-stays-home placement: the paper's own limiting
    solution (zero cost when the deadline is loose, Fig. 8(b)).

    With ``incumbent`` (a (p,) assignment — online re-planning,
    DESIGN.md §9) the seeding switches to incumbent mode:
    ``cfg.warm_elite`` exact clones of the incumbent, then
    ``cfg.warm_fraction`` of the swarm in its mutated neighborhood
    (per-gene redraw with prob ``cfg.warm_mutation`` from the link-aware
    allowed set), and the remaining particles keep the cold random draw
    for diversity. ``rescue=True`` (the re-planner sets it per problem
    when drift has stranded the incumbent infeasible — node-loss, heavy
    congestion) additionally re-applies the cold tier anchors at the
    tail, single-server placements ordered by DESCENDING power so the
    strongest escape hatches survive tail truncation: recovering
    feasibility then starts from the same anchors a cold solve gets. A
    healthy incumbent skips the anchors — they only slow convergence
    toward a plan that is already near-optimal. The cold draw consumes
    the same key split either way, so passing ``incumbent=None`` is
    bit-identical to the pre-warm-start initialization.
    """
    p, s = prob.num_layers, prob.num_servers
    home = _home_servers(prob)
    link_ok = np.asarray(prob.link_ok)
    allowed = link_ok[home, :].copy()            # (p, S)
    allowed[np.arange(p), home] = True
    # never initialize onto a *foreign* end device (free but slowest and
    # behind two WIFI hops); mutation may still propose them.
    logits = jnp.where(jnp.asarray(allowed), 0.0, -jnp.inf)   # (p, S)
    k1, k_warm = jax.random.split(key)
    # categorical broadcasts logits over the requested sample shape: the
    # gumbel draw is (P, p, S) either way, so this samples bit-identically
    # to materializing a (P, p, S) logits tensor — without the copy.
    X = jax.random.categorical(
        k1, logits, axis=-1, shape=(cfg.pop_size, p)).astype(jnp.int32)
    if incumbent is not None:
        inc = jnp.asarray(incumbent, jnp.int32)
        n_elite = max(1, min(cfg.warm_elite, cfg.pop_size))
        n_neigh = min(int(round(cfg.warm_fraction * cfg.pop_size)),
                      cfg.pop_size - n_elite)
        X = X.at[:n_elite].set(inc[None, :])
        if n_neigh > 0:
            k_mask, k_val = jax.random.split(k_warm)
            mut = jax.random.uniform(
                k_mask, (n_neigh, p)) < cfg.warm_mutation
            vals = jax.random.categorical(
                k_val, logits, axis=-1, shape=(n_neigh, p)
            ).astype(jnp.int32)
            X = X.at[n_elite:n_elite + n_neigh].set(
                jnp.where(mut, vals, inc[None, :]))
        tail = n_elite + n_neigh
        if rescue and cfg.bias_init_to_tiers and tail < cfg.pop_size:
            n_anchor = min(s + 1, cfg.pop_size - tail)
            X = X.at[tail].set(jnp.asarray(home))
            by_power = np.argsort(-np.asarray(prob.power), kind="stable")
            for k in range(n_anchor - 1):
                X = X.at[tail + 1 + k].set(
                    jnp.full((p,), int(by_power[k]), jnp.int32))
    elif cfg.bias_init_to_tiers:
        # Warm-start anchors (standard metaheuristic practice; ≤ S+1 of the
        # swarm): the all-home placement (the paper's loose-deadline
        # limiting solution) and the S single-server placements. The
        # remaining ~P−S−1 particles stay random — diversity is preserved
        # and every anchor can be displaced by a fitter random particle.
        n_anchor = min(s + 1, cfg.pop_size - 1)
        X = X.at[0].set(jnp.asarray(home))
        for k in range(n_anchor - 1):
            X = X.at[1 + k].set(jnp.full((p,), k, jnp.int32))
    return _clamp_pins(X, jnp.asarray(prob.pinned))


def swarm_step(pp: PaddedProblem, state: _SwarmState,
               cfg: PSOGAConfig,
               incumbent: Optional[jnp.ndarray] = None,
               mig_weight: Optional[jnp.ndarray] = None,
               arrivals: Optional[jnp.ndarray] = None) -> _SwarmState:
    """One PSO-GA iteration on the padded representation (Eq. 17–23).

    Pure in ``(pp, state)`` — ``repro.core.batch`` vmaps it over a fleet of
    problems. Mutation/crossover positions and mutation values draw their
    bounds from ``pp.num_layers`` / ``pp.num_servers`` (the TRUE sizes,
    traced per problem under vmap), so a padded layer is never mutated and
    a padded server is never proposed: padded genes stay at their initial
    value and padding is invisible to the search (DESIGN.md §4).

    ``incumbent`` / ``mig_weight`` (both traceable arrays) switch the
    fitness to the migration-aware warm key (DESIGN.md §9); a zero
    ``mig_weight`` reproduces the cold key bit-for-bit. ``arrivals``
    (``(M, max_apps, R)``, traceable) switches it to the queue-aware
    traffic key under ``cfg.miss_budget`` (DESIGN.md §10).
    """
    max_p = pp.pinned.shape[-1]
    p = pp.num_layers                 # true sizes; 0-d, traced under vmap
    s = pp.num_servers
    P = cfg.pop_size
    fit = make_swarm_fitness(pp, cfg.faithful_sim, cfg.fitness_backend,
                             incumbent=incumbent, mig_weight=mig_weight,
                             arrivals=arrivals,
                             miss_budget=cfg.miss_budget)

    key, kmu, kmu_pos, kmu_val, kc1, kx1, kc2, kx2 = jax.random.split(
        state.key, 8)
    t = state.it.astype(jnp.float32) / cfg.max_iters
    c1 = cfg.c1_start + (cfg.c1_end - cfg.c1_start) * t
    c2 = cfg.c2_start + (cfg.c2_end - cfg.c2_start) * t

    # --- adaptive inertia (Eq. 22-23): per-particle w from divergence.
    # Padded genes never differ from gBest's (both frozen at init value),
    # so the sum only counts real genes; divide by the TRUE gene count.
    d = jnp.sum((state.X != state.gbest_x[None, :]).astype(jnp.float32),
                axis=1) / p.astype(jnp.float32)                # (P,)
    w = cfg.w_max - (cfg.w_max - cfg.w_min) * jnp.exp(d / (d - 1.01))

    # --- inertia: mutation Mu with prob w (Eq. 20)
    do_mu = jax.random.uniform(kmu, (P,)) < w
    pos = jax.random.randint(kmu_pos, (P,), 0, p)
    val = jax.random.randint(kmu_val, (P,), 0, s, dtype=jnp.int32)
    A = jnp.where(
        (jnp.arange(max_p)[None, :] == pos[:, None]) & do_mu[:, None],
        val[:, None], state.X)

    # --- individual cognition: crossover with pBest (Eq. 18)
    do_c1 = jax.random.uniform(kc1, (P,)) < c1
    seg1 = jax.random.randint(kx1, (P, 2), 0, p)
    lo1 = jnp.min(seg1, axis=1)[:, None]
    hi1 = jnp.max(seg1, axis=1)[:, None]
    in_seg1 = (jnp.arange(max_p)[None, :] >= lo1) \
        & (jnp.arange(max_p)[None, :] <= hi1)
    B = jnp.where(in_seg1 & do_c1[:, None], state.pbest_x, A)

    # --- social cognition: crossover with gBest (Eq. 19)
    do_c2 = jax.random.uniform(kc2, (P,)) < c2
    seg2 = jax.random.randint(kx2, (P, 2), 0, p)
    lo2 = jnp.min(seg2, axis=1)[:, None]
    hi2 = jnp.max(seg2, axis=1)[:, None]
    in_seg2 = (jnp.arange(max_p)[None, :] >= lo2) \
        & (jnp.arange(max_p)[None, :] <= hi2)
    C = jnp.where(in_seg2 & do_c2[:, None], state.gbest_x[None, :], B)

    X = _clamp_pins(C, pp.pinned)
    f = fit(X)

    improved = f < state.pbest_f
    pbest_x = jnp.where(improved[:, None], X, state.pbest_x)
    pbest_f = jnp.where(improved, f, state.pbest_f)
    i_best = jnp.argmin(pbest_f)
    cand_f = pbest_f[i_best]
    better = cand_f < state.gbest_f
    gbest_x = jnp.where(better, pbest_x[i_best], state.gbest_x)
    gbest_f = jnp.where(better, cand_f, state.gbest_f)
    stall = jnp.where(better, 0, state.stall + 1)
    return _SwarmState(key=key, X=X, pbest_x=pbest_x, pbest_f=pbest_f,
                       gbest_x=gbest_x, gbest_f=gbest_f,
                       it=state.it + 1, stall=stall)


def _make_step(prob: SimProblem, cfg: PSOGAConfig,
               arrivals: Optional[np.ndarray] = None):
    """Unbatched (zero-padding) step + swarm-fitness for one problem."""
    pp = pad_problem(prob)
    arr = None if arrivals is None else jnp.asarray(arrivals)
    fit = make_swarm_fitness(pp, cfg.faithful_sim, cfg.fitness_backend,
                             arrivals=arr, miss_budget=cfg.miss_budget)
    return partial(swarm_step, pp, cfg=cfg, arrivals=arr), fit


def run_pso_ga(dag: LayerDAG, env: Environment,
               cfg: PSOGAConfig = PSOGAConfig(),
               seed: int = 0,
               record_history: bool = False,
               arrivals: Optional[np.ndarray] = None,
               telemetry=None) -> PSOGAResult:
    """Run PSO-GA to convergence. Returns the best assignment found.

    ``arrivals`` (``(M, n_apps, R)`` Monte-Carlo request timestamps,
    DESIGN.md §10) switches the fitness to the queue-aware traffic key:
    ``best_fitness`` is then the traffic key (seed-mean load-adjusted
    cost when the p95 miss budget is met); ``best_cost`` / ``feasible``
    still report the zero-load replay of the winning plan so results
    stay comparable across modes — use ``traffic.traffic_replay`` for
    the plan's load metrics.

    With ``record_history`` and a telemetry channel (explicit arg, or
    the process-global one from ``telemetry_scope``) the per-iteration
    gBest curve is published as the ``solver.gbest`` metric series
    (DESIGN.md §13) alongside the returned ``history`` array.
    """
    prob = SimProblem.build(dag, env)
    step, fit = _make_step(prob, cfg, arrivals=arrivals)
    key = jax.random.PRNGKey(seed)
    key, k_init = jax.random.split(key)
    X0 = init_swarm(k_init, prob, cfg)
    f0 = fit(X0)
    i0 = jnp.argmin(f0)
    state = _SwarmState(key=key, X=X0, pbest_x=X0, pbest_f=f0,
                        gbest_x=X0[i0], gbest_f=f0[i0],
                        it=jnp.asarray(0), stall=jnp.asarray(0))

    if record_history:
        def body(state, _):
            state = step(state)
            return state, state.gbest_f
        # scan traces (and the surrounding dispatch jit-compiles) the body
        # itself — wrapping it in jax.jit would only re-enter the jit
        # cache every iteration.
        state, hist = jax.lax.scan(body, state, None, length=cfg.max_iters)
        history = np.asarray(hist)
        iters = cfg.max_iters
        tel = telemetry if telemetry is not None else get_telemetry()
        if tel is not None:
            tel.record_series("solver.gbest", history)
            tel.inc("solver.history_runs")
    else:
        def cond(s: _SwarmState):
            return (s.it < cfg.max_iters) & (s.stall < cfg.stall_iters)
        state = jax.lax.while_loop(cond, step, state)
        history = None
        iters = int(state.it)

    sim = build_simulator(prob, faithful=cfg.faithful_sim)
    res = sim(state.gbest_x)
    feasible = bool(res.feasible)
    return PSOGAResult(
        best_x=np.asarray(state.gbest_x),
        best_fitness=float(state.gbest_f),
        best_cost=float(res.total_cost) if feasible else float("inf"),
        feasible=feasible,
        iterations=iters,
        history=history)
