"""Front-door input validation (DESIGN.md §11): malformed rates, drift
events, and arrival arrays must die at the boundary with a clear
ValueError — not as NaN fitness keys inside a jitted solver. One
regression test per rejection."""
import numpy as np
import pytest

from repro.core import (DriftEvent, EnvTrace, TrafficConfig, coerce_seed,
                        paper_environment, rng_entropy, sample_arrivals,
                        sample_trace)
from repro.core.batch import pack_arrivals


# ---------------------------------------------------------------------------
# sample_arrivals
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("rate", [float("nan"), 0.0, -0.5, float("inf")])
def test_sample_arrivals_rejects_bad_rate(rate):
    with pytest.raises(ValueError, match="rate"):
        sample_arrivals("poisson", n_apps=2, rate=rate)


@pytest.mark.parametrize("horizon", [float("nan"), 0.0, -1.0])
def test_sample_arrivals_rejects_bad_horizon(horizon):
    with pytest.raises(ValueError, match="horizon"):
        sample_arrivals("poisson", n_apps=2, horizon=horizon)


@pytest.mark.parametrize("field,kwargs", [
    ("n_apps", {"n_apps": 0}),
    ("max_requests", {"n_apps": 1, "max_requests": 0}),
    ("n_seeds", {"n_apps": 1, "n_seeds": 0}),
])
def test_sample_arrivals_rejects_bad_counts(field, kwargs):
    with pytest.raises(ValueError, match=field):
        sample_arrivals("poisson", **kwargs)


# ---------------------------------------------------------------------------
# TrafficConfig
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kwargs,match", [
    ({"kind": "tsunami"}, "kind"),
    ({"rate": float("nan")}, "rate"),
    ({"rate": 0.0}, "rate"),
    ({"rate": -1.0}, "rate"),
    ({"horizon": 0.0}, "horizon"),
    ({"max_requests": 0}, "max_requests"),
    ({"mc_solver": 0}, "mc_solver"),
    ({"mc_eval": 0}, "mc_eval"),
    ({"miss_budget": float("nan")}, "miss_budget"),
    ({"miss_budget": 1.5}, "miss_budget"),
    ({"miss_budget": -0.1}, "miss_budget"),
])
def test_traffic_config_rejects(kwargs, match):
    with pytest.raises(ValueError, match=match):
        TrafficConfig(**kwargs)


def test_traffic_config_accepts_defaults():
    cfg = TrafficConfig()
    assert cfg.rate > 0.0


# ---------------------------------------------------------------------------
# sample_trace
# ---------------------------------------------------------------------------

def test_sample_trace_rejects_zero_rounds():
    with pytest.raises(ValueError, match="rounds"):
        sample_trace("wifi-fade", paper_environment(), rounds=0)


@pytest.mark.parametrize("period", [float("nan"), 0.0, -3.0])
def test_sample_trace_rejects_bad_period(period):
    with pytest.raises(ValueError, match="period"):
        sample_trace("wifi-fade", paper_environment(), rounds=2,
                     period=period)


@pytest.mark.parametrize("severity", [float("nan"), 0.0, -0.2, 1.5])
def test_sample_trace_rejects_bad_severity(severity):
    with pytest.raises(ValueError, match="severity"):
        sample_trace("congestion", paper_environment(), rounds=2,
                     severity=severity)


# ---------------------------------------------------------------------------
# DriftEvent / EnvTrace
# ---------------------------------------------------------------------------

def _event(s=3, **overrides):
    base = dict(t=0.0, label="test",
                bw_scale=np.ones((s, s)), power_scale=np.ones(s),
                price_scale=np.ones(s), down=np.zeros(s, bool))
    base.update(overrides)
    return DriftEvent(**base)


def test_drift_event_rejects_shape_mismatch():
    with pytest.raises(ValueError, match="malformed drift event"):
        _event(bw_scale=np.ones((3, 4)))
    with pytest.raises(ValueError, match="malformed drift event"):
        _event(power_scale=np.ones(5))


def test_drift_event_rejects_nan_scales():
    bad = np.ones((3, 3))
    bad[0, 1] = np.nan
    with pytest.raises(ValueError, match="bw_scale"):
        _event(bw_scale=bad)


def test_drift_event_rejects_negative_scales():
    with pytest.raises(ValueError, match="power_scale"):
        _event(power_scale=np.array([1.0, -0.5, 1.0]))


@pytest.mark.parametrize("t", [float("nan"), -1.0])
def test_drift_event_rejects_bad_time(t):
    with pytest.raises(ValueError, match="t must be"):
        _event(t=t)


@pytest.mark.parametrize("load", [float("nan"), 0.0, -2.0, float("inf")])
def test_drift_event_rejects_bad_load_scale(load):
    with pytest.raises(ValueError, match="load_scale"):
        _event(load_scale=load)


def test_env_trace_rejects_empty_events():
    with pytest.raises(ValueError, match="at least one event"):
        EnvTrace(base=paper_environment(), events=())


def test_env_trace_rejects_server_count_mismatch():
    env = paper_environment()
    with pytest.raises(ValueError, match="servers"):
        EnvTrace(base=env, events=(_event(s=env.num_servers + 1),))


# ---------------------------------------------------------------------------
# pack_arrivals
# ---------------------------------------------------------------------------

def test_pack_arrivals_rejects_nan_times():
    a = np.zeros((2, 1, 3))
    a[0, 0, 1] = np.nan
    with pytest.raises(ValueError, match="NaN or negative"):
        pack_arrivals([a], max_apps=2)


def test_pack_arrivals_rejects_negative_times():
    a = np.zeros((2, 1, 3))
    a[1, 0, 0] = -0.25
    with pytest.raises(ValueError, match="NaN or negative"):
        pack_arrivals([a], max_apps=2)


def test_pack_arrivals_accepts_inf_padding():
    a = np.full((2, 1, 3), np.inf)
    a[:, 0, 0] = 0.5
    out = pack_arrivals([a], max_apps=2)
    assert out.shape == (1, 2, 2, 3)
    assert np.isinf(out[0, :, 1, :]).all()    # padded app never arrives


# ---------------------------------------------------------------------------
# seed coercion (coerce_seed / rng_entropy)
# ---------------------------------------------------------------------------

def test_sample_arrivals_accepts_numpy_seeds():
    """Regression: ``default_rng([seed, s])`` rejects int-like numpy
    scalars and 0-d arrays — the seed must be coerced first."""
    ref = sample_arrivals("poisson", n_apps=2, seed=7).t
    for seed in (np.int32(7), np.int64(7), np.array(7)):
        assert np.array_equal(sample_arrivals("poisson", n_apps=2,
                                              seed=seed).t, ref)


def test_sample_arrivals_accepts_negative_seeds():
    """Regression: ``default_rng`` rejects negative entropy outright."""
    a = sample_arrivals("poisson", n_apps=2, seed=-3).t
    b = sample_arrivals("poisson", n_apps=2, seed=-3).t
    assert np.array_equal(a, b)
    assert not np.array_equal(a, sample_arrivals("poisson", n_apps=2,
                                                 seed=-4).t)
    assert np.array_equal(
        sample_arrivals("poisson", n_apps=2, seed=np.array(-5)).t,
        sample_arrivals("poisson", n_apps=2, seed=-5).t)


def test_coerce_seed_rejects_non_int_like():
    with pytest.raises(TypeError, match="int-like"):
        coerce_seed(1.5)
    with pytest.raises(TypeError, match="int-like"):
        coerce_seed(np.float64(2.0))
    with pytest.raises(ValueError, match="scalar"):
        coerce_seed(np.array([1, 2]))


def test_rng_entropy_preserves_non_negative_seeds():
    """Golden-draw compatibility: non-negative seeds pass through
    unchanged, so every existing trace is reproduced bit for bit."""
    for s in (0, 1, 7, 2**40):
        assert rng_entropy(s) == s
    assert rng_entropy(np.array(7)) == 7
    assert 0 <= rng_entropy(-1) < 2**64


def test_sample_trace_accepts_numpy_seeds():
    env = paper_environment()
    ref = sample_trace("wifi-fade", env, rounds=3, seed=3)
    got = sample_trace("wifi-fade", env, rounds=3, seed=np.int64(3))
    for a, b in zip(ref.events, got.events):
        assert np.array_equal(a.bw_scale, b.bw_scale)
        assert np.array_equal(a.power_scale, b.power_scale)
