"""The bridge: any assigned architecture -> the paper's offloading problem.

A model config is *lowered* to a layer DAG whose node weights are FLOPs
(the TPU-fleet environment's server power is effective FLOP/s, so Eq. 4's
``a/p`` is seconds) and whose edge datasets are activation bytes in MB
(Eq. 6 divides by MB/s). PSO-GA then emits a min-$ placement of model
layers across a heterogeneous fleet (cloud pods / edge slices / device
nodes) under a latency SLO — the paper's decision, on TPU metal
(DESIGN.md §3).

Granularity: one node per transformer/mamba block, plus embed (pinned to
the request's origin device, like the paper pins each DNN's input layer)
and the LM head. Enc-dec lowers to the paper's *branching* structure:
the encoder output fans out to every decoder block (cross-attention), so
the DAG is not a chain — exactly the regime where PSO-GA beats Greedy.

``plan_offload`` = lower + deadline(HEFT × ratio) + optimize + partition.
``plan_offload_batch`` plans MANY requests in one batched PSO-GA fleet
(DESIGN.md §4) — the serve path and ``benchmarks/fleet_plan.py`` use it so
heterogeneous (arch, shape, deadline) requests share one compiled solver.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from ..configs.base import ModelConfig, ShapeSpec
from .baselines import greedy_offload, heft_makespan, run_ga
from .dag import LayerDAG
from .environment import DEVICE, Environment, tpu_fleet_environment
from .partition import Stage, contiguous_stages
from .pso_ga import PSOGAConfig, PSOGAResult, run_pso_ga

__all__ = ["arch_to_dag", "block_flops", "OffloadPlan", "plan_offload",
           "plan_offload_batch"]


def _glu_mult(act: str) -> int:
    return 3 if act in ("swiglu", "geglu") else 2


def block_flops(cfg: ModelConfig, seq: int, kind: str = "block",
                causal: bool = True) -> float:
    """Forward FLOPs of one block for a single request of ``seq`` tokens."""
    d, hd = cfg.d_model, cfg.head_dim
    h, k = cfg.n_heads, cfg.n_kv_heads
    if kind == "mamba":
        din, n = cfg.d_inner, cfg.ssm_state
        proj = 2 * seq * d * (2 * din + 2 * n + cfg.ssm_heads)
        ssd = 2 * seq * din * (2 * n) + 2 * seq * cfg.ssm_chunk * din
        out = 2 * seq * din * d
        return float(proj + ssd + out)
    if kind == "head":
        return float(2 * seq * d * cfg.vocab)
    if kind == "embed":
        return float(seq * d)                      # lookup + scale, no matmul
    # attention + ffn block
    qkvo = 2 * seq * d * (h + 2 * k) * hd + 2 * seq * h * hd * d
    kv_len = seq if cfg.window == 0 else min(seq, cfg.window)
    score = 2 * 2 * seq * kv_len * h * hd * (0.5 if causal else 1.0)
    if cfg.n_experts:
        ffn = 2 * seq * _glu_mult(cfg.act) * d * cfg.d_ff * cfg.top_k \
            + 2 * seq * d * cfg.n_experts
        if cfg.moe_dense_residual:
            ffn += 2 * seq * _glu_mult(cfg.act) * d * cfg.d_ff_dense
    else:
        ffn = 2 * seq * _glu_mult(cfg.act) * d * cfg.d_ff
    if kind == "xattn_block":                      # decoder block w/ cross
        qkvo *= 2
        score *= 2
    return float(qkvo + score + ffn)


def arch_to_dag(cfg: ModelConfig, shape: ShapeSpec,
                pin_server: int = 0, deadline: float = np.inf,
                dtype_bytes: int = 2, app_id: int = 0) -> LayerDAG:
    """Lower one request (batch=1, seq=shape.seq_len) to a layer DAG."""
    s = shape.seq_len
    act_mb = s * cfg.d_model * dtype_bytes / 1e6   # boundary activation

    compute: List[float] = []
    edges: List[Tuple[int, int]] = []
    mbs: List[float] = []
    names: List[str] = []

    def node(name: str, fl: float) -> int:
        names.append(name)
        compute.append(fl)
        return len(compute) - 1

    def edge(u: int, v: int, mb: float) -> None:
        edges.append((u, v))
        mbs.append(mb)

    if cfg.family == "encdec":
        inp = node("frames", block_flops(cfg, s, "embed"))
        prev = inp
        in_mb = s * cfg.d_model * dtype_bytes / 1e6
        for i in range(cfg.enc_layers):
            n = node(f"enc{i}", block_flops(cfg, s, "block", causal=False))
            edge(prev, n, in_mb)
            prev = n
        enc_out = prev
        dec_len = max(s // 8, 1)
        dec_mb = dec_len * cfg.d_model * dtype_bytes / 1e6
        prev = node("dec_embed", block_flops(cfg, dec_len, "embed"))
        edge(inp, prev, dec_len * 4 / 1e6)         # token ids
        for i in range(cfg.dec_layers):
            n = node(f"dec{i}", block_flops(cfg, dec_len, "xattn_block"))
            edge(prev, n, dec_mb)
            edge(enc_out, n, in_mb)                # cross-attention fan-out
            prev = n
        head = node("head", block_flops(cfg, dec_len, "head"))
        edge(prev, head, dec_mb)
    elif cfg.family == "hybrid":
        inp = node("embed", block_flops(cfg, s, "embed"))
        prev = inp
        every = cfg.hybrid_attn_every
        for i in range(cfg.n_layers):
            n = node(f"mamba{i}", block_flops(cfg, s, "mamba"))
            edge(prev, n, act_mb)
            prev = n
            if every and (i + 1) % every == 0:
                a = node(f"attn{i}", block_flops(cfg, s, "block"))
                edge(prev, a, act_mb)
                prev = a
        head = node("head", block_flops(cfg, s, "head"))
        edge(prev, head, act_mb)
    elif cfg.family == "ssm":
        inp = node("embed", block_flops(cfg, s, "embed"))
        prev = inp
        for i in range(cfg.n_layers):
            n = node(f"mamba{i}", block_flops(cfg, s, "mamba"))
            edge(prev, n, act_mb)
            prev = n
        head = node("head", block_flops(cfg, s, "head"))
        edge(prev, head, act_mb)
    else:                                          # dense / moe / vlm
        inp = node("embed", block_flops(cfg, s, "embed"))
        prev = inp
        if cfg.family == "vlm":
            vis = node("vision_stub", 2.0 * cfg.vision_tokens
                       * cfg.d_model * cfg.d_model)
            edge(inp, vis, cfg.vision_tokens * cfg.d_model
                 * dtype_bytes / 1e6)
            prev = vis
        for i in range(cfg.n_layers):
            n = node(f"block{i}", block_flops(cfg, s, "block"))
            edge(prev, n, act_mb)
            prev = n
        head = node("head", block_flops(cfg, s, "head"))
        edge(prev, head, act_mb)

    p = len(compute)
    pinned = np.full(p, -1, np.int32)
    pinned[0] = pin_server
    return LayerDAG(compute=np.asarray(compute),
                    edges=np.asarray(edges, np.int32).reshape(-1, 2),
                    edge_mb=np.asarray(mbs),
                    app_id=np.full(p, app_id, np.int32),
                    deadline=np.asarray([deadline]),
                    pinned=pinned, names=names)


@dataclasses.dataclass
class OffloadPlan:
    dag: LayerDAG
    env: Environment
    result: PSOGAResult
    stages: List[Stage]
    deadline: float
    heft: float
    #: the fitness backend the solver ACTUALLY ran ("scan"/"pallas" —
    #: "auto" is resolved before solving, so reports never lie about it)
    backend: str = "scan"
    #: queue-aware evaluation of the plan (``traffic_stats`` dict) when
    #: planning ran under a request stream (DESIGN.md §10)
    traffic: Optional[dict] = None

    @property
    def cost(self) -> float:
        return self.result.best_cost

    def summary(self) -> str:
        tiers = {0: "cloud", 1: "edge", 2: "device"}
        lines = [f"cost ${self.cost:.4f}  deadline {self.deadline:.3f}s "
                 f"(HEFT {self.heft:.3f}s)  feasible={self.result.feasible}"
                 f"  backend={self.backend}"]
        if self.traffic is not None:
            lines.append(
                f"  traffic: miss p50/p95/p99 "
                f"{self.traffic['miss_p50']:.3f}/"
                f"{self.traffic['miss_p95']:.3f}/"
                f"{self.traffic['miss_p99']:.3f}  "
                f"load cost ${self.traffic['cost_mean']:.4f} "
                f"({self.traffic['requests']} reqs)")
        for st in self.stages:
            t = tiers[int(self.env.tier[st.server])]
            lines.append(
                f"  stage[{st.layers[0]}..{st.layers[-1]}] "
                f"({len(st.layers)} layers) -> s{st.server} ({t})")
        return "\n".join(lines)


def plan_offload(cfg: ModelConfig, shape: ShapeSpec,
                 env: Optional[Environment] = None,
                 deadline_ratio: float = 3.0,
                 pin_server: Optional[int] = None,
                 algo: str = "pso_ga",
                 pso: PSOGAConfig = PSOGAConfig(pop_size=64, max_iters=300,
                                                stall_iters=40),
                 seed: int = 0) -> OffloadPlan:
    """Lower + schedule one serving request of ``cfg`` at ``shape``.

    ``algo``: pso_ga | greedy | ga (the paper's competitors, for A/B)."""
    env = env or tpu_fleet_environment()
    if pin_server is None:
        pin_server = int(env.servers_of_tier(DEVICE)[0])
    dag = arch_to_dag(cfg, shape, pin_server=pin_server)
    heft, _ = heft_makespan(dag, env)
    deadline = deadline_ratio * heft
    dag = dag.with_deadline(np.asarray([deadline]))
    if algo == "pso_ga":
        res = run_pso_ga(dag, env, pso, seed=seed)
    elif algo == "greedy":
        res = greedy_offload(dag, env)
    elif algo == "ga":
        res = run_ga(dag, env, seed=seed)
    else:
        raise ValueError(f"unknown algo {algo!r}")
    stages = contiguous_stages(dag, res.best_x)
    return OffloadPlan(dag=dag, env=env, result=res, stages=stages,
                       deadline=float(deadline), heft=float(heft))


def plan_offload_batch(requests: Sequence[Tuple[ModelConfig, ShapeSpec,
                                                float]],
                       env: Optional[Environment] = None,
                       pin_server: Optional[int] = None,
                       pso: PSOGAConfig = PSOGAConfig(pop_size=64,
                                                      max_iters=300,
                                                      stall_iters=40),
                       seed: int = 0,
                       fitness_backend: Optional[str] = None,
                       warm: Optional[Sequence[np.ndarray]] = None,
                       migration_weight: float = 1.0,
                       traffic: Optional["TrafficConfig"] = None,
                       mesh=None
                       ) -> List[OffloadPlan]:
    """Plan many serving requests with ONE batched PSO-GA fleet.

    ``requests``: sequence of (cfg, shape, deadline_ratio). All requests
    share the environment; each is lowered to its own DAG with its own
    HEFT-derived deadline, then the whole fleet is solved by
    ``run_pso_ga_batch`` (each problem matches a sequential
    ``run_pso_ga(..., seed=seed)`` gene-for-gene; see DESIGN.md §4).
    ``fitness_backend`` (scan | pallas | auto, DESIGN.md §8) overrides
    ``pso.fitness_backend`` when given — the serve path exposes it as
    ``--fitness-backend`` without rebuilding the whole config.

    ``warm``: per-request incumbent assignments (online re-planning,
    DESIGN.md §9) — swarms warm-start in the incumbent neighborhood and
    pay ``migration_weight`` × the Eq. 6 input-dataset cost per moved
    layer, so the new plans prefer cheap deltas against the ones already
    deployed. Deadlines are still re-derived from HEFT on the CURRENT
    ``env``, so pass the drifted environment when re-planning.

    ``traffic`` (a ``TrafficConfig``, DESIGN.md §10): plan under a
    request stream instead of a single isolated execution — the solver
    optimizes expected load-adjusted cost under the config's p95
    deadline-miss budget, and every returned plan carries its held-out
    queue-aware evaluation in ``OffloadPlan.traffic``
    (``traffic_stats`` dict). The resolved fitness backend is stamped
    into ``OffloadPlan.backend`` either way, so ``"auto"`` is never
    reported back as "auto".

    ``mesh`` (a ``jax.sharding.Mesh``, e.g. ``launch.mesh.resolve_mesh``,
    DESIGN.md §12): shard the fleet solve's shape buckets across the
    mesh's data axes — gene-for-gene identical plans, more devices.
    """
    from .batch import run_pso_ga_batch      # local: avoid import cycle
    from .fitness import resolve_fitness_backend
    from .simulator import SimProblem
    from .traffic import traffic_replay, traffic_stats

    if fitness_backend is not None:
        pso = dataclasses.replace(pso, fitness_backend=fitness_backend)
    # resolve "auto" ONCE, before solving: the solver then runs exactly
    # the backend the returned plans report (observability, ISSUE-5).
    backend = resolve_fitness_backend(pso.fitness_backend)
    if traffic is not None:
        pso = dataclasses.replace(pso, miss_budget=traffic.miss_budget)
    pso = dataclasses.replace(pso, fitness_backend=backend)
    env = env or tpu_fleet_environment()
    if pin_server is None:
        pin_server = int(env.servers_of_tier(DEVICE)[0])
    dags, hefts, deadlines = [], [], []
    for mcfg, shape, ratio in requests:
        dag = arch_to_dag(mcfg, shape, pin_server=pin_server)
        heft, _ = heft_makespan(dag, env)
        deadline = ratio * heft
        dags.append(dag.with_deadline(np.asarray([deadline])))
        hefts.append(float(heft))
        deadlines.append(float(deadline))
    arrivals = None
    if traffic is not None:
        arrivals = [traffic.solver_arrivals(d.num_apps, seed=seed + 31 * i)
                    for i, d in enumerate(dags)]
    results = run_pso_ga_batch([(d, env) for d in dags], cfg=pso, seed=seed,
                               incumbent=warm,
                               migration_weight=migration_weight,
                               arrivals=arrivals, mesh=mesh)
    reports: List[Optional[dict]] = [None] * len(dags)
    if traffic is not None:
        for i, (d, r) in enumerate(zip(dags, results)):
            res = traffic_replay(
                SimProblem.build(d, env), r.best_x,
                traffic.eval_arrivals(d.num_apps, seed=seed + 31 * i),
                faithful=pso.faithful_sim)
            reports[i] = traffic_stats(res)
    return [OffloadPlan(dag=d, env=env, result=r,
                        stages=contiguous_stages(d, r.best_x),
                        deadline=dl, heft=h, backend=backend,
                        traffic=rep)
            for d, r, dl, h, rep in zip(dags, results, deadlines, hefts,
                                        reports)]
