"""Plan cache for the always-on planning service (DESIGN.md §11).

At service scale most rounds re-solve scenarios the fleet has already
seen — the same DNNs under a bandwidth/price snapshot and load level
that recur as conditions oscillate. The cache amortizes the PSO-GA
solve away for those rounds: entries are keyed by

    (DNN identity, env bucket, load bucket)

where the DNN identity is a content fingerprint of the layer DAG and
the env/load buckets quantize the environment matrices and the offered
load onto a log grid (two snapshots within the quantization step share
a key). Quantization is only a cheap pre-filter, never a correctness
argument: every hit passes a **replay-exact revalidation gate** before
it is served —

  1. ``plan_is_valid(prob, plan)`` — the stale-plan guard's static
     gate (shape, ranges, pins, live links) against the LIVE env;
  2. replaying the stored plan through ``simulate_np`` under the live
     env must reproduce the total cost and makespan recorded at store
     time bit-for-bit.

A snapshot that drifted inside the bucket (or a fingerprint collision)
changes the replayed cost, fails gate 2, and the entry is dropped and
counted as a miss — so a served hit is exactly the plan a fresh
warm-started solve would keep, and cache-on rounds stay bit-identical
to cache-off rounds. Eviction is plain LRU under a capacity bound; all
operations are thread-safe so N concurrent services can share one
cache (DESIGN.md §11 phase 2).
"""
from __future__ import annotations

import dataclasses
import hashlib
import threading
from collections import OrderedDict
from typing import Dict, List, NamedTuple, Optional, Sequence, Union

import numpy as np

from .dag import LayerDAG
from .environment import Environment
from .simulator import SimProblem, simulate_np

__all__ = ["PlanCache", "PlanCacheConfig", "dag_fingerprint"]

#: quantization sentinels for non-positive / infinite matrix entries
#: (a severed link — bandwidth 0 — must land in its own bucket).
_NEG_BUCKET = -(2 ** 62)
_INF_BUCKET = 2 ** 62


@dataclasses.dataclass(frozen=True)
class PlanCacheConfig:
    """Knobs for :class:`PlanCache`.

    capacity:   max entries before LRU eviction.
    env_quant:  log-grid step for environment matrices — 0.05 buckets
                values at ~5% relative resolution.
    load_quant: log-grid step for the offered-load scale.
    """

    capacity: int = 64
    env_quant: float = 0.05
    load_quant: float = 0.1

    def __post_init__(self) -> None:
        if int(self.capacity) < 1:
            raise ValueError(
                f"capacity must be >= 1, got {self.capacity!r}")
        for name in ("env_quant", "load_quant"):
            v = getattr(self, name)
            if not np.isfinite(v) or v <= 0.0:
                raise ValueError(f"{name} must be positive finite, "
                                 f"got {v!r}")


def dag_fingerprint(dag: LayerDAG) -> bytes:
    """Content fingerprint of a layer DAG — the "DNN identity" part of
    the cache key. Two structurally identical DAGs (same layers, edges,
    datasets, pins, deadlines) share a fingerprint; names don't count.
    """
    h = hashlib.blake2b(digest_size=16)
    for a in (dag.compute, dag.edges, dag.edge_mb, dag.app_id,
              dag.deadline, dag.pinned):
        arr = np.ascontiguousarray(a)
        h.update(str(arr.shape).encode())
        h.update(arr.tobytes())
    return h.digest()


def _quantize(a: np.ndarray, q: float) -> np.ndarray:
    """Log-bucket a non-negative array at relative resolution ~q.

    0 (severed link / free resource) and +inf (self-link bandwidth) get
    their own sentinel buckets so topology changes always change the
    key. NaN is rejected — the service validates env snapshots before
    the cache ever sees them.
    """
    a = np.asarray(a, np.float64)
    if np.any(np.isnan(a)):
        raise ValueError("cannot bucket a NaN environment snapshot")
    out = np.full(a.shape, _NEG_BUCKET, np.int64)
    pos = np.isfinite(a) & (a > 0.0)
    out[pos] = np.round(np.log(a[pos]) / q).astype(np.int64)
    out[np.isposinf(a)] = _INF_BUCKET
    return out


class _Entry(NamedTuple):
    plan: np.ndarray
    total_cost: float
    makespan: float


class PlanCache:
    """LRU plan cache with a replay-exact revalidation gate.

    Counters (``stats()``): ``hits`` / ``misses`` are per-problem
    lookup outcomes; ``revalidation_failures`` counts entries dropped
    by the gate (each also counts as a miss); ``stores`` / ``evictions``
    / ``store_rejects`` track the write side.

    With a ``telemetry`` channel every increment is mirrored live onto
    the registry as ``plancache.<name>`` counters (DESIGN.md §13), so
    exported snapshots agree with ``stats()`` at any instant. Telemetry
    only observes — lookup/store outcomes are identical without it.
    """

    def __init__(self, cfg: Optional[PlanCacheConfig] = None, *,
                 telemetry=None) -> None:
        self.cfg = cfg if cfg is not None else PlanCacheConfig()
        self._entries: "OrderedDict[tuple, _Entry]" = OrderedDict()
        self._lock = threading.Lock()
        self._tel = telemetry
        self._stats: Dict[str, int] = {
            "hits": 0, "misses": 0, "revalidation_failures": 0,
            "stores": 0, "evictions": 0, "store_rejects": 0}

    def _bump(self, name: str, n: int = 1) -> None:
        """Count under ``self._lock``; the registry lock is a leaf, so
        mirroring inside ours cannot deadlock."""
        self._stats[name] += n
        if self._tel is not None and n:
            self._tel.inc(f"plancache.{name}", n)

    # -- keys ----------------------------------------------------------
    def key(self, dag: Union[LayerDAG, bytes], env: Environment,
            load_scale: float = 1.0) -> tuple:
        """Cache key for (DNN identity, env bucket, load bucket)."""
        fp = dag_fingerprint(dag) if isinstance(dag, LayerDAG) else dag
        q = self.cfg.env_quant
        h = hashlib.blake2b(digest_size=16)
        h.update(np.ascontiguousarray(env.tier).tobytes())
        for a in (env.power, env.cost_per_sec, env.bandwidth,
                  env.tran_cost):
            h.update(_quantize(a, q).tobytes())
        if not np.isfinite(load_scale) or load_scale <= 0.0:
            raise ValueError(f"load_scale must be positive finite, "
                             f"got {load_scale!r}")
        load_bucket = int(np.round(np.log(load_scale)
                                   / self.cfg.load_quant))
        return (fp, h.digest(), load_bucket)

    # -- read side -----------------------------------------------------
    def _validate(self, entry: _Entry, prob: SimProblem) -> bool:
        """The replay-exact gate: static validity + bit-identical
        replayed cost/makespan under the live env."""
        from .online import plan_is_valid
        if not plan_is_valid(prob, entry.plan):
            return False
        res = simulate_np(prob, entry.plan)
        return (float(res.total_cost) == entry.total_cost
                and float(res.makespan) == entry.makespan)

    def lookup(self, key: tuple, prob: SimProblem
               ) -> Optional[np.ndarray]:
        """The stored plan for ``key`` iff it survives the gate under
        ``prob``'s live env; a failed gate drops the entry."""
        got = self.lookup_fleet([key], [prob])
        return None if got is None else got[0]

    def lookup_fleet(self, keys: Sequence[tuple],
                     probs: Sequence[SimProblem]
                     ) -> Optional[List[np.ndarray]]:
        """All-or-nothing fleet lookup: every problem must hit (and
        survive the gate) or the whole round is a miss — a partial hit
        still needs the fleet solve, so serving it would only fork the
        cache-on/off trajectories. Revalidation failures drop their
        entries either way.
        """
        if len(keys) != len(probs):
            raise ValueError(f"{len(keys)} keys for {len(probs)} "
                             f"problems")
        with self._lock:
            entries = [self._entries.get(k) for k in keys]
        plans: List[Optional[np.ndarray]] = []
        failed: List[tuple] = []
        for key, entry, prob in zip(keys, entries, probs):
            if entry is None:
                plans.append(None)
            elif self._validate(entry, prob):
                plans.append(entry.plan)
            else:
                plans.append(None)
                failed.append(key)
        with self._lock:
            for key in failed:
                self._entries.pop(key, None)
                self._bump("revalidation_failures")
            if all(p is not None for p in plans):
                self._bump("hits", len(keys))
                for key in keys:
                    if key in self._entries:
                        self._entries.move_to_end(key)
                return [np.array(p) for p in plans]
            self._bump("misses", len(keys))
            return None

    # -- write side ----------------------------------------------------
    def store(self, key: tuple, prob: SimProblem, plan) -> bool:
        """Record a solver-produced plan with its replay invariants;
        rejects plans that fail the static gate or replay non-finite."""
        from .online import plan_is_valid
        if not plan_is_valid(prob, plan):
            with self._lock:
                self._bump("store_rejects")
            return False
        res = simulate_np(prob, plan)
        total, make = float(res.total_cost), float(res.makespan)
        if not (np.isfinite(total) and np.isfinite(make)):
            with self._lock:
                self._bump("store_rejects")
            return False
        entry = _Entry(np.array(plan), total, make)
        with self._lock:
            self._entries[key] = entry
            self._entries.move_to_end(key)
            while len(self._entries) > self.cfg.capacity:
                self._entries.popitem(last=False)
                self._bump("evictions")
            self._bump("stores")
        return True

    # -- bookkeeping ---------------------------------------------------
    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def keys(self) -> List[tuple]:
        """Current keys, LRU-oldest first."""
        with self._lock:
            return list(self._entries)

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return dict(self._stats)

    def hit_rate(self) -> float:
        """hits / (hits + misses), 0.0 before any lookup."""
        with self._lock:
            n = self._stats["hits"] + self._stats["misses"]
            return self._stats["hits"] / n if n else 0.0

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()
