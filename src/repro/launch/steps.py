"""Step builders shared by the dry-run, the trainer and the server.

Each builder returns (step_fn, in_shardings, out_shardings, arg_shapes)
where arg_shapes are ShapeDtypeStructs — the dry-run lowers against them
with zero allocation; the trainer/server materialize real arrays with the
same shardings.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from ..configs.base import ModelConfig, ShapeSpec
from ..models import batch_pspecs, build_model, input_specs
from ..models.model_zoo import cache_len_for
from ..optim import (AdamWConfig, OptState, adamw_init, adamw_update,
                     zero1_pspecs)

__all__ = ["named", "make_train_objects", "make_prefill_objects",
           "make_decode_objects"]


def named(mesh, tree):
    """PartitionSpec tree -> NamedSharding tree."""
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                        is_leaf=lambda x: isinstance(x, P))


def _merge_microbatch(tree, accum: int):
    """(B, ...) -> (accum, B/accum, ...) for gradient accumulation."""
    def split(x):
        if x.ndim == 0:
            return x
        b = x.shape[0]
        return x.reshape((accum, b // accum) + x.shape[1:])
    return jax.tree.map(split, tree)


def make_train_objects(cfg: ModelConfig, shape: ShapeSpec, mesh,
                       data_axes: Tuple[str, ...],
                       acfg: AdamWConfig = AdamWConfig(),
                       moe_impl: str = "scatter",
                       accum: int = 1,
                       zero1: bool = True):
    """Full train step: fwd + bwd + AdamW update (+ optional microbatch
    accumulation). State = (params, OptState)."""
    model = build_model(cfg, mesh=mesh, data_axes=data_axes,
                        moe_impl=moe_impl)
    param_shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    opt_shapes = jax.eval_shape(adamw_init, param_shapes)
    pspecs = model.param_pspecs()
    if zero1:
        z = zero1_pspecs(pspecs, param_shapes, mesh, data_axes)
    else:
        z = pspecs
    ospecs = OptState(mu=z, nu=jax.tree.map(lambda s: s, z), count=P())
    bspecs = batch_pspecs(cfg, shape, data_axes)
    batch_shapes = input_specs(cfg, shape)
    mspec = {"loss": P(), "grad_norm": P(), "lr": P()}

    def train_step(params, opt, batch):
        if accum == 1:
            (loss, _), grads = jax.value_and_grad(
                model.loss_fn, has_aux=True)(params, batch)
        else:
            micro = _merge_microbatch(batch, accum)

            def acc_fn(carry, mb):
                g_sum, l_sum = carry
                (l, _), g = jax.value_and_grad(
                    model.loss_fn, has_aux=True)(params, mb)
                return (jax.tree.map(jnp.add, g_sum, g), l_sum + l), None

            zero_g = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (g_sum, l_sum), _ = jax.lax.scan(
                acc_fn, (zero_g, jnp.asarray(0.0, jnp.float32)), micro)
            grads = jax.tree.map(lambda g: g / accum, g_sum)
            loss = l_sum / accum
        new_params, new_opt, om = adamw_update(grads, opt, params, acfg)
        return new_params, new_opt, {"loss": loss, **om}

    in_sh = (named(mesh, pspecs), named(mesh, ospecs), named(mesh, bspecs))
    out_sh = (named(mesh, pspecs), named(mesh, ospecs), named(mesh, mspec))
    shapes = (param_shapes, opt_shapes, batch_shapes)
    return model, train_step, in_sh, out_sh, shapes


def make_prefill_objects(cfg: ModelConfig, shape: ShapeSpec, mesh,
                         data_axes: Tuple[str, ...],
                         moe_impl: str = "scatter"):
    """Prefill step: forward + KV-cache build + last-token logits."""
    model = build_model(cfg, mesh=mesh, data_axes=data_axes,
                        moe_impl=moe_impl)
    cache_len = cache_len_for(cfg, shape)
    param_shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    pspecs = model.param_pspecs()
    bspecs = batch_pspecs(cfg, shape, data_axes)
    batch_shapes = input_specs(cfg, shape)

    def prefill_step(params, batch):
        return model.prefill(params, batch, cache_len=cache_len)

    in_sh = (named(mesh, pspecs), named(mesh, bspecs))
    # logits + caches: let GSPMD choose (caches produced sharded by input)
    return model, prefill_step, in_sh, None, (param_shapes, batch_shapes)


def make_decode_objects(cfg: ModelConfig, shape: ShapeSpec, mesh,
                        data_axes: Tuple[str, ...],
                        moe_impl: str = "scatter"):
    """Single-token serve step against a seq_len cache. batch=1 long-
    context cells shard the cache sequence dim (sequence parallelism)."""
    model = build_model(cfg, mesh=mesh, data_axes=data_axes,
                        moe_impl=moe_impl)
    cache_len = cache_len_for(cfg, shape)
    shard_seq = shape.global_batch == 1
    param_shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    cache_shapes = jax.eval_shape(
        lambda: model.init_caches(shape.global_batch, cache_len))
    pspecs = model.param_pspecs()
    cspecs = model.cache_pspecs(shard_seq=shard_seq)
    bspecs = batch_pspecs(cfg, shape, data_axes)
    batch_shapes = input_specs(cfg, shape)

    def serve_step(params, caches, batch):
        return model.decode_step(params, caches, batch)

    in_sh = (named(mesh, pspecs), named(mesh, cspecs), named(mesh, bspecs))
    out_sh = (None, named(mesh, cspecs))
    shapes = (param_shapes, cache_shapes, batch_shapes)
    return model, serve_step, in_sh, out_sh, shapes
