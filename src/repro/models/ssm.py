"""Mamba2 (SSD — state-space duality) blocks.

The block follows Mamba2 (arXiv:2405.21060): fused input projection into
(z, x, B, C, dt), causal depthwise conv over (x,B,C), silu, selective SSM
with scalar-per-head decay A, gated RMSNorm, output projection.

The sequence path uses the **chunked SSD algorithm**: within chunks of
``cfg.ssm_chunk`` the recurrence is computed as a decay-masked
attention-like quadratic form (MXU-friendly); across chunks a short
``lax.scan`` carries the (heads, head_dim, state) recurrent state. Total
work is O(S·Q) intra + O(S·N·P) state math — sub-quadratic in S, which is
what qualifies the SSM/hybrid archs for the ``long_500k`` cell.

``ssd_sequential`` is the O(S)-step scan oracle used by tests, and
``kernels/ssd_scan.py`` is the Pallas TPU kernel for the intra-chunk part
(validated against these in interpret mode).

Sharding: heads (and therefore d_inner = heads × head_dim) shard over
"model"; B/C (state projections, shared across heads) replicate; all SSD
contractions are head-local so the only collective is the out-projection
reduce.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..configs.base import ModelConfig
from .layers import Params, dense_init, he_init, rms_norm

__all__ = ["mamba_init", "mamba_pspec", "mamba_seq", "mamba_decode",
           "init_ssm_state", "ssm_state_pspec", "ssd_chunked",
           "ssd_sequential"]


def mamba_init(key: jax.Array, cfg: ModelConfig, dtype: jnp.dtype) -> Params:
    d, din, n, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    k = jax.random.split(key, 8)
    conv_ch = din + 2 * n
    return {
        "wz": dense_init(k[0], d, din, dtype),
        "wx": dense_init(k[1], d, din, dtype),
        "wB": dense_init(k[2], d, n, dtype),
        "wC": dense_init(k[3], d, n, dtype),
        "wdt": dense_init(k[4], d, h, dtype),
        "conv_w": he_init(k[5], (cfg.ssm_conv, conv_ch), cfg.ssm_conv,
                          dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.zeros((h,), jnp.float32),       # A = -exp(A_log) = -1
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.full((h,), -2.0, jnp.float32),  # softplus ≈ 0.12
        "norm": jnp.zeros((din,), dtype),
        "wo": dense_init(k[6], din, d, dtype),
    }


def mamba_pspec(cfg: ModelConfig, tp: Optional[int] = None) -> Params:
    from .layers import divisible
    ok = divisible(cfg.ssm_heads, tp) and divisible(cfg.d_inner, tp)
    h = "model" if ok else None
    return {
        "wz": P(None, h), "wx": P(None, h),
        "wB": P(None, None), "wC": P(None, None),
        "wdt": P(None, h),
        "conv_w": P(None, None), "conv_b": P(None),
        "A_log": P(h), "D": P(h), "dt_bias": P(h),
        "norm": P(h), "wo": P(h, None),
    }


# ---------------------------------------------------------------------------
# SSD core
# ---------------------------------------------------------------------------

def ssd_sequential(xdt: jnp.ndarray, a: jnp.ndarray, B: jnp.ndarray,
                   C: jnp.ndarray,
                   h0: Optional[jnp.ndarray] = None
                   ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Reference O(S)-step recurrence (oracle).

    xdt: (b,s,h,p) inputs pre-multiplied by dt; a: (b,s,h) per-step decay
    exp(dt·A); B,C: (b,s,n). Returns (y (b,s,h,p), final state (b,h,p,n)).
    """
    b, s, h, p = xdt.shape
    n = B.shape[-1]
    if h0 is None:
        h0 = jnp.zeros((b, h, p, n), jnp.float32)

    def step(hst, t):
        x_t, a_t, B_t, C_t = t
        hst = hst * a_t[..., None, None] \
            + x_t[..., None] * B_t[:, None, None, :]
        y_t = jnp.einsum("bhpn,bn->bhp", hst, C_t)
        return hst, y_t

    xs = (xdt.transpose(1, 0, 2, 3).astype(jnp.float32),
          a.transpose(1, 0, 2).astype(jnp.float32),
          B.transpose(1, 0, 2).astype(jnp.float32),
          C.transpose(1, 0, 2).astype(jnp.float32))
    hT, ys = jax.lax.scan(step, h0, xs)
    return ys.transpose(1, 0, 2, 3), hT


def ssd_chunked(xdt: jnp.ndarray, a: jnp.ndarray, B: jnp.ndarray,
                C: jnp.ndarray, chunk: int,
                h0: Optional[jnp.ndarray] = None,
                use_pallas: bool = False
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked SSD. Same contract as ``ssd_sequential``.

    Decomposition per chunk c of length Q (cum = inclusive cumsum of log a):
      intra[i]  = Σ_{j≤i} (C_i·B_j) · exp(cum_i − cum_j) · xdt_j
      state_c   = Σ_j exp(cum_Q − cum_j) · B_j ⊗ xdt_j     (chunk outflow)
      inter[i]  = exp(cum_i) · C_i · S_{c-1} ;  S_c = exp(cum_Q)·S_{c-1} + state_c
    """
    b, s, h, p = xdt.shape
    n = B.shape[-1]
    q = min(chunk, s)
    s_orig = s
    if s % q:
        # pad with identity steps: a=1 (no decay), x=0 (no state change) —
        # final state is unaffected; padded outputs are truncated.
        pad = q - s % q
        xdt = jnp.pad(xdt, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)), constant_values=1.0)
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
        s = s + pad
    c = s // q
    if h0 is None:
        h0 = jnp.zeros((b, h, p, n), jnp.float32)

    xc = xdt.reshape(b, c, q, h, p).astype(jnp.float32)
    ac = a.reshape(b, c, q, h).astype(jnp.float32)
    Bc = B.reshape(b, c, q, n).astype(jnp.float32)
    Cc = C.reshape(b, c, q, n).astype(jnp.float32)

    la = jnp.log(jnp.maximum(ac, 1e-30))
    cum = jnp.cumsum(la, axis=2)                       # (b,c,q,h) inclusive
    total = cum[:, :, -1]                              # (b,c,h)

    if use_pallas:
        from ..kernels import ops as kops
        intra = kops.ssd_intra(xc, cum, Bc, Cc)
    else:
        # decay kernel L[i,j] = exp(cum_i - cum_j) for j <= i (i>=j strictly
        # includes a_i ... a_{j+1}; at i==j it is 1)
        li = cum[:, :, :, None, :]                      # (b,c,i,1,h)
        lj = cum[:, :, None, :, :]                      # (b,c,1,j,h)
        mask = jnp.tril(jnp.ones((q, q), bool))
        L = jnp.where(mask[None, None, :, :, None],
                      jnp.exp(li - lj), 0.0)            # (b,c,i,j,h)
        scores = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)  # (b,c,i,j)
        intra = jnp.einsum("bcij,bcijh,bcjhp->bcihp", scores, L, xc)

    # chunk outflow states
    decay_out = jnp.exp(total[:, :, None, :] - cum)     # (b,c,q,h)
    state_c = jnp.einsum("bcqn,bcqhp,bcqh->bchpn", Bc, xc, decay_out)

    # cross-chunk scan
    def scan_fn(hprev, t):
        st, tot = t                                     # (b,h,p,n), (b,h)
        hnew = hprev * jnp.exp(tot)[..., None, None] + st
        return hnew, hprev

    (hT, hprevs) = jax.lax.scan(
        scan_fn, h0, (state_c.transpose(1, 0, 2, 3, 4),
                      total.transpose(1, 0, 2)))
    hprevs = hprevs.transpose(1, 0, 2, 3, 4)            # (b,c,h,p,n)

    inter = jnp.einsum("bcqn,bchpn,bcqh->bcqhp", Cc, hprevs, jnp.exp(cum))
    y = (intra + inter).reshape(b, s, h, p)[:, :s_orig]
    return y, hT


# ---------------------------------------------------------------------------
# block ops
# ---------------------------------------------------------------------------

def _conv1d_causal(xBC: jnp.ndarray, w: jnp.ndarray, bias: jnp.ndarray,
                   state: Optional[jnp.ndarray] = None
                   ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Depthwise causal conv. xBC: (b,s,ch); w: (k,ch). Returns (out,
    new_state (b,k-1,ch))."""
    k = w.shape[0]
    if state is None:
        state = jnp.zeros((xBC.shape[0], k - 1, xBC.shape[-1]), xBC.dtype)
    padded = jnp.concatenate([state, xBC], axis=1)
    out = sum(padded[:, i:i + xBC.shape[1]] * w[i] for i in range(k))
    new_state = padded[:, -(k - 1):] if k > 1 else state
    return out + bias, new_state


def _split_proj(p: Params, x: jnp.ndarray, cfg: ModelConfig):
    z = x @ p["wz"]
    xs = x @ p["wx"]
    B = x @ p["wB"]
    C = x @ p["wC"]
    dt = jax.nn.softplus((x @ p["wdt"]).astype(jnp.float32)
                         + p["dt_bias"])
    return z, xs, B, C, dt


def mamba_seq(p: Params, x: jnp.ndarray, cfg: ModelConfig,
              conv_state: Optional[jnp.ndarray] = None,
              ssm_state: Optional[jnp.ndarray] = None,
              ) -> Tuple[jnp.ndarray, Tuple[jnp.ndarray, jnp.ndarray]]:
    """Full-sequence mamba2 block. x: (B,S,D) -> (y (B,S,D),
    (conv_state, ssm_state))."""
    b, s, d = x.shape
    h, pdim, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    z, xs, B, C, dt = _split_proj(p, x, cfg)
    xBC = jnp.concatenate([xs, B, C], axis=-1)
    xBC, conv_state = _conv1d_causal(xBC, p["conv_w"], p["conv_b"],
                                     conv_state)
    xBC = jax.nn.silu(xBC)
    xs = xBC[..., :cfg.d_inner]
    B = xBC[..., cfg.d_inner:cfg.d_inner + n]
    C = xBC[..., cfg.d_inner + n:]
    xh = xs.reshape(b, s, h, pdim)
    A = -jnp.exp(p["A_log"])                            # (h,)
    a = jnp.exp(dt * A)                                 # (b,s,h)
    xdt = xh * dt[..., None].astype(xh.dtype)
    y, ssm_state = ssd_chunked(xdt, a, B, C, cfg.ssm_chunk, h0=ssm_state,
                               use_pallas=cfg.use_pallas)
    y = y + xh.astype(jnp.float32) * p["D"][:, None]
    y = y.reshape(b, s, cfg.d_inner).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    return y @ p["wo"], (conv_state, ssm_state)


def mamba_decode(p: Params, x: jnp.ndarray, cfg: ModelConfig,
                 conv_state: jnp.ndarray, ssm_state: jnp.ndarray
                 ) -> Tuple[jnp.ndarray, Tuple[jnp.ndarray, jnp.ndarray]]:
    """One-token recurrent step. x: (B,1,D); states as in mamba_seq."""
    b, one, d = x.shape
    h, pdim, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    z, xs, B, C, dt = _split_proj(p, x, cfg)
    xBC = jnp.concatenate([xs, B, C], axis=-1)
    xBC, conv_state = _conv1d_causal(xBC, p["conv_w"], p["conv_b"],
                                     conv_state)
    xBC = jax.nn.silu(xBC)
    xs = xBC[..., :cfg.d_inner]
    B = xBC[..., cfg.d_inner:cfg.d_inner + n]
    C = xBC[..., cfg.d_inner + n:]
    xh = xs.reshape(b, h, pdim).astype(jnp.float32)     # squeeze s=1
    dt1 = dt[:, 0]                                      # (b,h)
    A = -jnp.exp(p["A_log"])
    a = jnp.exp(dt1 * A)                                # (b,h)
    ssm_state = ssm_state * a[..., None, None] \
        + (xh * dt1[..., None])[..., None] * B[:, 0][:, None, None, :]
    y = jnp.einsum("bhpn,bn->bhp", ssm_state, C[:, 0])
    y = y + xh * p["D"][:, None]
    y = y.reshape(b, 1, cfg.d_inner).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    return y @ p["wo"], (conv_state, ssm_state)


def init_ssm_state(cfg: ModelConfig, batch: int, dtype: jnp.dtype
                   ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    conv = jnp.zeros((batch, cfg.ssm_conv - 1, cfg.d_inner + 2 * cfg.ssm_state),
                     dtype)
    ssm = jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state),
                    jnp.float32)
    return conv, ssm


def ssm_state_pspec(batch_axes, replicate_batch: bool = False
                    ) -> Tuple[Any, Any]:
    """(conv_state, ssm_state) specs. SSM state is O(1) in sequence, so
    batch=1 long-context cells replicate the batch dim (nothing to shard)
    and rely on the model-axis shard of heads/channels."""
    ba = None if replicate_batch else batch_axes
    return (P(ba, None, "model"),
            P(ba, "model", None, None))
