from .base import SHAPES, ModelConfig, ShapeSpec
from .registry import ARCHS, get, names

__all__ = ["SHAPES", "ModelConfig", "ShapeSpec", "ARCHS", "get", "names"]
