"""Pallas traffic-replay kernel (kernels.traffic_sim, DESIGN.md §10):
differential fuzzing of the four implementations of the merged-order
FCFS replay — compacted scan, full-T scan, interpret-mode Pallas kernel,
pure-jnp/numpy ref — against the independent discrete-event oracle from
test_traffic, request-for-request, both fidelity modes; plus the
merged-order compaction invariant, the padded-tail regression, and the
backend plumbing (auto resolution, runner-cache normalization, solver
parity across backends)."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest
from hypo_compat import given, st
from test_simulator import random_dag, random_env
from test_traffic import traffic_np

from repro.core import (PSOGAConfig, SimProblem, TRAFFIC_KINDS, merge_dags,
                        run_pso_ga, run_pso_ga_batch, sample_arrivals,
                        simulate_traffic_swarm, zero_contention_arrivals)
from repro.core.batch import (pack_arrivals, pack_problems,
                              reset_runner_cache_stats, runner_cache_stats)
from repro.core.fitness import make_swarm_fitness
from repro.core.simulator import pad_problem, simulate_swarm
from repro.core.traffic import _merged_order
from repro.kernels.ref import traffic_replay_ref
from repro.kernels.traffic_sim import traffic_replay_folded


def _tfields(pp):
    """The 15 positional args shared by traffic_replay_folded and
    traffic_replay_ref (the schedule-replay 14 + the traced num_apps)."""
    return (pp.order, pp.compute, pp.parent_idx, pp.parent_mb, pp.child_idx,
            pp.child_mb, pp.app_id, pp.deadline, pp.pinned, pp.power,
            pp.cost_per_sec, pp.inv_bw, pp.tran_cost, pp.link_ok, pp.num_apps)


def _traffic_dag(rng, sizes):
    """Independent per-app random DAGs merged into one problem — the
    traffic replay (and its DES oracle) requires app-disjoint dependency
    components, which random_dag's n_apps labeling does not give."""
    return merge_dags([random_dag(rng, sz) for sz in sizes])


def _problem_and_arrivals(seed):
    """Random DNN + random fleet + random arrival trace, LOOSELY padded
    on every axis (layers, servers, apps) so the kernel's padded-tail
    handling is always in play. Arrival families rotate through all
    four generators; app rows past num_apps are +inf (padding)."""
    rng = np.random.default_rng(seed)
    s = int(rng.integers(2, 7))
    n_apps = int(rng.integers(1, 4))
    dag = _traffic_dag(rng, [int(rng.integers(2, 8))
                             for _ in range(n_apps)])
    p = dag.compute.shape[0]
    env = random_env(rng, s)
    prob = SimProblem.build(dag, env)
    pp = pad_problem(prob, max_p=p + int(rng.integers(0, 9)),
                     max_S=s + int(rng.integers(0, 4)),
                     max_apps=n_apps + int(rng.integers(0, 3)))
    kind = TRAFFIC_KINDS[seed % len(TRAFFIC_KINDS)]
    R = int(rng.integers(1, 7))
    tr = sample_arrivals(kind, n_apps, rate=0.5, horizon=15.0,
                         max_requests=R, n_seeds=1, seed=seed)
    t = np.asarray(tr.t[0], np.float64)
    if not np.isfinite(t).any():
        t[0, 0] = 0.0           # keep the replay non-trivial
    max_apps = int(pp.deadline.shape[0])
    arr = np.full((max_apps, R), np.inf)
    arr[:n_apps] = t
    return prob, pp, jnp.asarray(arr), rng


def _swarm(rng, prob, pp, P=5):
    max_p = int(pp.order.shape[0])
    X = np.zeros((P, max_p), np.int32)
    X[:, :prob.num_layers] = rng.integers(0, prob.num_servers,
                                          size=(P, prob.num_layers))
    return jnp.asarray(X)


def _assert_four_way(seed, faithful):
    """compact scan == full scan == Pallas kernel == ref == DES oracle,
    on total cost, miss rate, latency-sum, per-request latency, and
    (oracle aside, which has no padding concept) static feasibility."""
    prob, pp, arr, rng = _problem_and_arrivals(seed)
    X = _swarm(rng, prob, pp)
    sim = simulate_traffic_swarm(pp, X, arr, faithful, compact=True)
    simf = simulate_traffic_swarm(pp, X, arr, faithful, compact=False)
    ker = traffic_replay_folded(*_tfields(pp), X, arr, faithful=faithful,
                                tile_p=4, interpret=True)
    ref = traffic_replay_ref(*_tfields(pp), X, arr, faithful=faithful)

    # compaction is a pure reindexing of the same walk
    np.testing.assert_allclose(np.asarray(sim.total_cost),
                               np.asarray(simf.total_cost), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(sim.miss_rate),
                                  np.asarray(simf.miss_rate))
    np.testing.assert_array_equal(np.asarray(sim.latency),
                                  np.asarray(simf.latency))
    np.testing.assert_array_equal(np.asarray(sim.static_ok),
                                  np.asarray(simf.static_ok))

    for name, out in (("kernel", ker), ("ref", ref)):
        total, miss, lat_sum, static_ok, latency = out
        np.testing.assert_allclose(np.asarray(total),
                                   np.asarray(sim.total_cost),
                                   rtol=2e-5, atol=1e-6, err_msg=name)
        np.testing.assert_allclose(np.asarray(miss),
                                   np.asarray(sim.miss_rate),
                                   atol=1e-9, err_msg=name)
        np.testing.assert_allclose(np.asarray(lat_sum),
                                   np.asarray(sim.lat_sum),
                                   rtol=2e-5, atol=1e-4, err_msg=name)
        np.testing.assert_array_equal(np.asarray(static_ok),
                                      np.asarray(sim.static_ok),
                                      err_msg=name)
        np.testing.assert_allclose(np.asarray(latency),
                                   np.asarray(sim.latency),
                                   rtol=2e-5, atol=1e-4, err_msg=name)

    n_apps = prob.num_apps
    arr_np = np.asarray(arr)[:n_apps]
    for i in range(X.shape[0]):
        des = traffic_np(prob, np.asarray(X[i, :prob.num_layers]),
                         arr_np, faithful)
        np.testing.assert_allclose(float(ker[0][i]), des["total_cost"],
                                   rtol=2e-5, atol=1e-5)
        np.testing.assert_allclose(float(ker[1][i]), des["miss_rate"],
                                   atol=1e-9)
        np.testing.assert_allclose(np.asarray(ker[4][i, :n_apps]),
                                   des["latency"], rtol=2e-5, atol=1e-4)


# ---------------------------------------------------------------------------
# differential fuzz: seeded sweep + hypothesis + deep CI sweep
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("faithful", [True, False])
@pytest.mark.parametrize("seed", range(6))
def test_four_way_seeded(seed, faithful):
    """Deterministic fallback sweep for environments without hypothesis."""
    _assert_four_way(seed, faithful)


@given(seed=st.integers(0, 10_000), faithful=st.booleans())
def test_four_way_property(seed, faithful):
    _assert_four_way(seed, faithful)


@pytest.mark.slow
@pytest.mark.parametrize("faithful", [True, False])
def test_four_way_deep_sweep(faithful):
    """Deep fuzz tier (CI runs it; local runs skip with -m "not slow")."""
    for seed in range(100, 116):
        _assert_four_way(seed, faithful)


# ---------------------------------------------------------------------------
# degenerate arrival shapes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("faithful", [True, False])
def test_zero_contention_kernel_matches_single_shot(faithful):
    """R=1 @ t=0: the kernel's queue-aware replay IS the zero-load
    replay — total cost matches simulate_swarm on the same swarm."""
    rng = np.random.default_rng(11)
    dag = _traffic_dag(rng, [6, 6])
    env = random_env(rng, 4)
    prob = SimProblem.build(dag, env)
    pp = pad_problem(prob)
    arr = jnp.asarray(zero_contention_arrivals(prob.num_apps)[0])
    X = _swarm(rng, prob, pp, P=6)
    total, _, _, _, _ = traffic_replay_folded(
        *_tfields(pp), X, arr, faithful=faithful, tile_p=4, interpret=True)
    base_total, _, _ = simulate_swarm(pp, X, faithful)
    np.testing.assert_allclose(np.asarray(total), np.asarray(base_total),
                               rtol=2e-5, atol=1e-6)


@pytest.mark.parametrize("faithful", [True, False])
def test_all_inf_app_contributes_nothing(faithful):
    """An app whose every request slot is +inf (padding, or simply no
    arrivals in the horizon) adds no steps, no latency, no misses."""
    rng = np.random.default_rng(17)
    dag = _traffic_dag(rng, [5, 5])
    env = random_env(rng, 3)
    prob = SimProblem.build(dag, env)
    pp = pad_problem(prob)
    arr = np.full((prob.num_apps, 3), np.inf)
    arr[0] = [0.0, 1.5, 4.0]
    arr = jnp.asarray(arr)
    X = _swarm(rng, prob, pp, P=4)
    ker = traffic_replay_folded(*_tfields(pp), X, arr, faithful=faithful,
                                tile_p=4, interpret=True)
    sim = simulate_traffic_swarm(pp, X, arr, faithful)
    np.testing.assert_allclose(np.asarray(ker[0]),
                               np.asarray(sim.total_cost),
                               rtol=2e-5, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(ker[1]),
                                  np.asarray(sim.miss_rate))
    assert np.all(np.asarray(ker[4][:, 1]) == 0.0)


# ---------------------------------------------------------------------------
# merged-order compaction invariant
# ---------------------------------------------------------------------------

def test_merged_order_compaction():
    """Valid steps form a contiguous prefix of length n_valid, and their
    relative order is EXACTLY the pre-compaction merged order (the
    unmasked-key lexsort the full-T scan used)."""
    prob, pp, arr, _ = _problem_and_arrivals(3)
    t_m, r_m, key_m, valid_m, n_valid = _merged_order(pp, arr)
    t_m, r_m = np.asarray(t_m), np.asarray(r_m)
    valid_m, nv = np.asarray(valid_m), int(n_valid)
    assert valid_m[:nv].all() and not valid_m[nv:].any()
    assert np.isfinite(np.asarray(key_m)[:nv]).all()

    # reconstruct the old (uncompacted) order: key is the raw arrival
    # regardless of layer validity
    max_p = int(pp.order.shape[0])
    R = int(arr.shape[-1])
    valid = np.asarray(pp.order) >= 0
    jsafe = np.where(valid, np.asarray(pp.order), 0)
    app = np.asarray(pp.app_id)[jsafe]
    rep_t = np.tile(np.arange(max_p), R)
    rep_r = np.repeat(np.arange(R), max_p)
    key_old = np.asarray(arr)[app[rep_t], rep_r]
    perm_old = np.lexsort((rep_t, rep_r, key_old))
    old_valid = [(int(rep_t[i]), int(rep_r[i])) for i in perm_old
                 if valid[rep_t[i]] and np.isfinite(key_old[i])]
    new_valid = list(zip(t_m[:nv].tolist(), r_m[:nv].tolist()))
    assert new_valid == old_valid


# ---------------------------------------------------------------------------
# padded-tail regression (both backends): fitness invariant under
# arbitrary extra padding on every axis
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["scan", "pallas"])
@pytest.mark.parametrize("faithful", [True, False])
def test_traffic_padding_equivalence(faithful, backend):
    """Regression for the padded-tail bug class: max_p tiles whose tail
    layers are padding must be no-ops inside the event walk. The traffic
    key is invariant under extra layer/server/app padding."""
    rng = np.random.default_rng(23)
    dag = _traffic_dag(rng, [5, 5])
    env = random_env(rng, 4)
    prob = SimProblem.build(dag, env)
    p, n_apps = prob.num_layers, prob.num_apps
    tr = sample_arrivals("bursty", n_apps, rate=0.5, horizon=12.0,
                         max_requests=3, n_seeds=2, seed=5)
    X = _swarm(rng, prob, pad_problem(prob), P=6)
    tight = pad_problem(prob)
    base = np.asarray(make_swarm_fitness(
        tight, faithful, backend, arrivals=jnp.asarray(tr.t),
        miss_budget=0.5)(X))
    for max_p, max_S, max_apps in ((16, 6, 2), (32, 11, 4)):
        loose = pad_problem(prob, max_p=max_p, max_S=max_S,
                            max_apps=max_apps)
        arr = np.full((tr.t.shape[0], max_apps, 3), np.inf)
        arr[:, :n_apps] = tr.t
        Xp = jnp.zeros((6, max_p), jnp.int32).at[:, :p].set(X)
        out = np.asarray(make_swarm_fitness(
            loose, faithful, backend, arrivals=jnp.asarray(arr),
            miss_budget=0.5)(Xp))
        np.testing.assert_allclose(out, base, rtol=2e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# backend plumbing: auto resolution, runner-cache normalization, solver
# parity
# ---------------------------------------------------------------------------

def test_auto_resolves_to_scan_traffic():
    """On this CPU-only host "auto" resolves to the scan path — the
    traffic keys are bit-identical, not merely close."""
    prob, pp, arr, rng = _problem_and_arrivals(7)
    X = _swarm(rng, prob, pp)
    arrivals = arr[None]
    a = make_swarm_fitness(pp, True, "scan", arrivals=arrivals,
                           miss_budget=0.5)(X)
    b = make_swarm_fitness(pp, True, "auto", arrivals=arrivals,
                           miss_budget=0.5)(X)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_runner_cache_backend_normalized():
    """_fleet_runner normalizes the backend string before keying its
    cache: re-solving with "scan" after "auto" (which resolves to scan
    here) is a pure cache hit — no new trace, no new compile."""
    rng = np.random.default_rng(31)
    probs = []
    arrs = []
    for k in range(2):
        dag = _traffic_dag(rng, [4] * (1 + k))
        env = random_env(rng, 4)
        probs.append(SimProblem.build(dag, env))
        arrs.append(np.sort(rng.uniform(0.0, 10.0, size=(2, 1 + k, 3)),
                            axis=-1))
    cfg = PSOGAConfig(pop_size=12, max_iters=7, stall_iters=3,
                      fitness_backend="auto")
    reset_runner_cache_stats()
    ra = run_pso_ga_batch(probs, cfg, seed=0, arrivals=arrs)
    s1 = dict(runner_cache_stats())
    rb = run_pso_ga_batch(probs,
                          dataclasses.replace(cfg, fitness_backend="scan"),
                          seed=0, arrivals=arrs)
    s2 = dict(runner_cache_stats())
    assert s2["misses"] == s1["misses"]
    assert s2["traces"] == s1["traces"]
    assert s2["hits"] > s1["hits"]
    for a, b in zip(ra, rb):
        assert a.best_fitness == b.best_fitness
        assert np.array_equal(a.best_x, b.best_x)


def test_traffic_solver_backend_parity():
    """Full PSO-GA traffic solves agree across backends (same seed,
    same iterations, fitness to float32 round-off)."""
    cfg = PSOGAConfig(pop_size=16, max_iters=24, stall_iters=9)
    rng = np.random.default_rng(2)
    dag = _traffic_dag(rng, [4, 4])
    env = random_env(rng, 4)
    tr = sample_arrivals("poisson", 2, rate=0.5, horizon=12.0,
                         max_requests=3, n_seeds=2, seed=3)
    arr = jnp.asarray(tr.t)
    a = run_pso_ga(dag, env, cfg, seed=0, arrivals=arr)
    b = run_pso_ga(dag, env,
                   dataclasses.replace(cfg, fitness_backend="pallas"),
                   seed=0, arrivals=arr)
    assert a.best_fitness == pytest.approx(b.best_fitness, rel=2e-5)
    assert a.iterations == b.iterations


def test_fleet_vmap_kernel_matches_scan():
    """The kernel composes with the fleet vmap (pack_problems /
    pack_arrivals) exactly like the scan backend does."""
    import jax
    rng = np.random.default_rng(41)
    probs, arrs = [], []
    for k in range(2):
        dag = _traffic_dag(rng, [4 + k] * (1 + k))
        env = random_env(rng, 3 + k)
        probs.append(SimProblem.build(dag, env))
        arrs.append(np.sort(rng.uniform(0.0, 8.0, size=(1 + k, 2)),
                            axis=-1))
    packed = pack_problems(probs)
    max_apps = int(packed.deadline.shape[-1])
    arr = pack_arrivals([a[None] for a in arrs], max_apps)[:, 0]
    max_p = int(packed.order.shape[-1])
    X = jnp.asarray(rng.integers(0, 3, size=(2, 4, max_p)), jnp.int32)

    def kernel_one(pp, x, a):
        return traffic_replay_folded(*_tfields(pp), x, a, faithful=True,
                                     tile_p=4, interpret=True)[:4]

    def scan_one(pp, x, a):
        sim = simulate_traffic_swarm(pp, x, a, True)
        return sim.total_cost, sim.miss_rate, sim.lat_sum, sim.static_ok

    got = jax.vmap(kernel_one)(packed, X, arr)
    want = jax.vmap(scan_one)(packed, X, arr)
    for g, w, name in zip(got, want, ("total", "miss", "lat", "ok")):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=2e-5, atol=1e-6, err_msg=name)
