"""Pallas TPU kernels for the runtime's compute hot-spots.

The paper itself has no kernel-level contribution (it is a scheduling
paper); these kernels are the hot inner loops of the serving/training
substrate its placements execute on (DESIGN.md §3) — plus the planner's
own hot loop:

  * flash_attention — causal / sliding-window prefill attention
  * ssd_scan        — Mamba2 intra-chunk SSD quadratic form
  * decode_attention — flash-decode against long KV caches
  * schedule_sim    — Algorithm-2 swarm-fitness replay for PSO-GA
    (grid over particle tiles, layer loop + lease/end/t_on state inside
    the kernel; DESIGN.md §8)

Each has ``ops.py`` (jit'd layout wrapper) or a folded entry point and
``ref.py`` (pure-jnp oracle); tests sweep shapes/dtypes and assert
allclose in interpret mode.
"""
from . import ops, ref, schedule_sim

__all__ = ["ops", "ref", "schedule_sim"]
