import os
import signal

import numpy as np
import pytest

# Tests run on the single host CPU device; ONLY the dry-run subprocesses
# spawn a placeholder fleet (REPRO_DRYRUN_DEVICES) — never set XLA_FLAGS
# here (smoke tests and benches must see 1 device).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

try:
    from hypothesis import settings
except ModuleNotFoundError:
    # hypothesis is optional: the property-based tests skip themselves via
    # tests/hypo_compat.py, the rest of the suite runs normally.
    pass
else:
    settings.register_profile("ci", deadline=None, max_examples=25,
                              derandomize=True)
    settings.load_profile("ci")


@pytest.fixture
def rng():
    return np.random.default_rng(0)


# ---------------------------------------------------------------------------
# per-test watchdog (DESIGN.md §11): a hung solve must fail ITS test, not
# wedge the whole suite. SIGALRM-based (no external plugin); override per
# test with @pytest.mark.timeout(seconds), 0 disables. The default leaves
# generous room for first-test jit compiles.
# ---------------------------------------------------------------------------

DEFAULT_TEST_TIMEOUT_S = int(os.environ.get("REPRO_TEST_TIMEOUT_S", "900"))


@pytest.hookimpl(wrapper=True)
def pytest_runtest_call(item):
    marker = item.get_closest_marker("timeout")
    seconds = int(marker.args[0]) if marker is not None and marker.args \
        else DEFAULT_TEST_TIMEOUT_S
    armed = hasattr(signal, "SIGALRM") and seconds > 0
    if armed:
        def on_alarm(signum, frame):
            raise TimeoutError(
                f"{item.nodeid} exceeded the {seconds}s test watchdog")
        previous = signal.signal(signal.SIGALRM, on_alarm)
        signal.alarm(seconds)
    try:
        return (yield)
    finally:
        if armed:
            signal.alarm(0)
            signal.signal(signal.SIGALRM, previous)
