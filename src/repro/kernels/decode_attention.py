"""Pallas TPU kernel for single-token decode attention (flash-decode).

Decode is memory-bound: the whole KV cache (B, C, K, hd) streams through
VMEM once while the query is a single token. The kernel tiles the cache
length C and carries the flash running-softmax state across tiles, so
arbitrarily long caches (the 500k-context cells) never materialize a
(1 x C) score row in HBM and the HBM traffic is exactly one read of K and
V — the roofline floor for decode.

Grid = (B*K kv-head rows, cache tiles); the cache-tile axis is innermost
(sequential on TPU) and accumulates in fp32 VMEM scratch. All G grouped
query heads of a kv head ride in the same tile — (G, hd) x (hd, c_blk)
keeps the MXU lanes busier than one-head-at-a-time.

``valid_len`` masks dead cache slots (slots >= pos+1, or ring-cache slots
not yet written); it arrives as a (1,1) int32 tile.

Validated in interpret mode against ``ref.decode_attention_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0 ** 30

__all__ = ["decode_attention_folded"]


def _decode_kernel(valid_ref, q_ref, k_ref, v_ref, o_ref,
                   m_ref, l_ref, acc_ref, *,
                   scale: float, c_blk: int, n_c: int, cache: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    valid = valid_ref[0, 0]
    k0 = j * c_blk

    @pl.when(k0 < valid)
    def _tile():
        q = q_ref[0].astype(jnp.float32) * scale            # (G, hd)
        k = k_ref[0].astype(jnp.float32)                    # (c_blk, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        kpos = k0 + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(kpos < valid, s, NEG_INF)
        m_prev = m_ref[:, 0]
        l_prev = l_ref[:, 0]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + p.sum(axis=-1)
        v = v_ref[0].astype(jnp.float32)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * corr[:, None] + pv
        m_ref[...] = m_new[:, None]
        l_ref[...] = l_new[:, None]

    @pl.when(j == n_c - 1)
    def _finish():
        l = l_ref[:, 0]
        o_ref[0] = (acc_ref[...] / jnp.maximum(l, 1e-30)[:, None]
                    ).astype(o_ref.dtype)


def decode_attention_folded(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                            valid_len: jnp.ndarray, *,
                            c_blk: int = 1024, interpret: bool = True
                            ) -> jnp.ndarray:
    """q: (BK, G, hd); k/v: (BK, C, hd); valid_len: (1,1) int32
    -> (BK, G, hd)."""
    bk, g, hd = q.shape
    c = k.shape[1]
    c_blk = min(c_blk, max(8, c))
    pad = (-c) % c_blk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0)))
    n_c = (c + pad) // c_blk
    kernel = functools.partial(_decode_kernel, scale=hd ** -0.5,
                               c_blk=c_blk, n_c=n_c, cache=c)
    return pl.pallas_call(
        kernel,
        grid=(bk, n_c),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, j: (0, 0)),
            pl.BlockSpec((1, g, hd), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, c_blk, hd), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, c_blk, hd), lambda b, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, g, hd), lambda b, j: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bk, g, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, hd), jnp.float32),
        ],
        interpret=interpret,
    )(valid_len, q, k, v)
