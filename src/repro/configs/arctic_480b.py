"""arctic-480b — MoE 128e top-2 + dense residual. [hf:Snowflake/snowflake-arctic-base; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b", family="moe", n_layers=35, d_model=7168,
    n_heads=56, n_kv_heads=8, head_dim=128, d_ff=4864, vocab=32_000,
    act="swiglu", n_experts=128, top_k=2,
    moe_dense_residual=True, d_ff_dense=4864, rope_theta=10_000.0)
