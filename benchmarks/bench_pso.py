"""PSO-GA engine throughput: jitted swarm-iterations/second and particle
evaluations/second vs problem size — the performance of the paper's
algorithm as a vmapped/jitted JAX program (the reproduction's own compute
layer; the paper ran seconds-per-iteration on a Pentium G3250)."""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.core import (PSOGAConfig, paper_environment, zoo)
from repro.core.pso_ga import _SwarmState, _make_step, init_swarm
from repro.core.simulator import SimProblem

from .common import print_csv


def bench_net(net: str, pop: int = 100, iters: int = 50):
    env = paper_environment()
    dag = zoo.build(net, deadline=1e9)
    prob = SimProblem.build(dag, env)
    cfg = PSOGAConfig(pop_size=pop, max_iters=iters)
    step, fit = _make_step(prob, cfg)
    key = jax.random.PRNGKey(0)
    X0 = init_swarm(key, prob, cfg)
    f0 = fit(X0)
    state = _SwarmState(key=key, X=X0, pbest_x=X0, pbest_f=f0,
                        gbest_x=X0[0], gbest_f=f0[0],
                        it=jax.numpy.asarray(0),
                        stall=jax.numpy.asarray(0))
    jstep = jax.jit(step)
    state = jstep(state)                       # compile + warmup
    jax.block_until_ready(state.X)
    t0 = time.time()
    for _ in range(iters):
        state = jstep(state)
    jax.block_until_ready(state.X)
    dt = (time.time() - t0) / iters
    return {
        "net": net, "layers": dag.num_layers, "pop": pop,
        "us_per_iter": dt * 1e6,
        "evals_per_s": pop / dt,
        "layersteps_per_s": pop * dag.num_layers / dt,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--pop", type=int, default=100)
    args = ap.parse_args()
    rows = [bench_net(n, pop=args.pop)
            for n in ("alexnet", "vgg19", "googlenet", "resnet101")]
    print_csv(rows, ["net", "layers", "pop", "us_per_iter", "evals_per_s",
                     "layersteps_per_s"])


if __name__ == "__main__":
    main()
