"""Whisper-medium backbone: transformer encoder over (stubbed) audio frame
embeddings + causal decoder with cross-attention.

The conv frontend is a STUB per the assignment: ``input_specs()`` feeds
precomputed frame embeddings (B, S_enc, D) directly (the 2×conv1d
mel frontend is not part of the assigned backbone). Sinusoidal positions
on the encoder, learned positions on the decoder (as in Whisper).

Shape adaptation (DESIGN.md §5): for `train_*`/`prefill_*` cells the
assigned seq_len is the ENCODER length and the decoder runs seq_len/8
text tokens; `decode_*` cells decode 1 token against a self-KV cache of
seq_len and a cross-KV computed from a 1500-frame encoder output (the
Whisper encoder emits 1500 frames per 30 s window).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..configs.base import ModelConfig
from . import attention as attn
from .layers import (Params, cross_entropy, divisible, embed_init,
                     embed_pspec, mlp_apply, mlp_init, mlp_pspec, rms_norm,
                     scan_blocks, stack_layers)
from .transformer import REMAT_POLICY, _with_leading, mesh_tp

__all__ = ["EncDecLM", "CROSS_FRAMES"]

CROSS_FRAMES = 1500     # whisper: 30 s of audio -> 1500 encoder frames


def _enc_block_init(key, cfg, dtype):
    k1, k2 = jax.random.split(key)
    return {"ln1": jnp.zeros((cfg.d_model,), dtype),
            "attn": attn.attn_init(k1, cfg, dtype),
            "ln2": jnp.zeros((cfg.d_model,), dtype),
            "mlp": mlp_init(k2, cfg.d_model, cfg.d_ff, cfg.act, dtype)}


def _dec_block_init(key, cfg, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    p = _enc_block_init(k1, cfg, dtype)
    p["ln_x"] = jnp.zeros((cfg.d_model,), dtype)
    p["xattn"] = attn.attn_init(k3, cfg, dtype)
    return p


def _enc_block_pspec(cfg, tp=None):
    return {"ln1": P(None), "attn": attn.attn_pspec(cfg, tp),
            "ln2": P(None), "mlp": mlp_pspec(cfg.act, cfg.d_ff, tp)}


def _dec_block_pspec(cfg, tp=None):
    p = _enc_block_pspec(cfg, tp)
    p["ln_x"] = P(None)
    p["xattn"] = attn.attn_pspec(cfg, tp)
    return p


def _sinusoid(s: int, d: int, dtype) -> jnp.ndarray:
    pos = jnp.arange(s, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos / (10_000.0 ** (2 * dim / d))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1
                           ).astype(dtype)


class EncDecLM:
    def __init__(self, cfg: ModelConfig, mesh=None,
                 data_axes: Tuple[str, ...] = ("data",), **_):
        self.cfg = cfg
        self.tp = mesh_tp(mesh)
        self.data_axes = data_axes
        self.dtype = jnp.dtype(cfg.dtype)

    def init(self, rng: jax.Array) -> Params:
        cfg = self.cfg
        k_e, k_enc, k_dec, k_tok, k_pos = jax.random.split(rng, 5)
        return {
            "enc_blocks": stack_layers(
                lambda k: _enc_block_init(k, cfg, self.dtype), k_enc,
                cfg.enc_layers),
            "enc_norm": jnp.zeros((cfg.d_model,), self.dtype),
            "dec_blocks": stack_layers(
                lambda k: _dec_block_init(k, cfg, self.dtype), k_dec,
                cfg.dec_layers),
            "dec_norm": jnp.zeros((cfg.d_model,), self.dtype),
            "embed": embed_init(k_tok, cfg.vocab, cfg.d_model, self.dtype),
            "dec_pos": embed_init(k_pos, 8192, cfg.d_model, self.dtype),
        }

    def param_pspecs(self) -> Params:
        cfg = self.cfg
        return {
            "enc_blocks": _with_leading(_enc_block_pspec(cfg, self.tp), 1),
            "enc_norm": P(None),
            "dec_blocks": _with_leading(_dec_block_pspec(cfg, self.tp), 1),
            "dec_norm": P(None),
            "embed": embed_pspec(cfg.vocab, self.tp),
            "dec_pos": P(None, None),
        }

    # ------------------------------------------------------------ encoder
    def encode(self, params: Params, audio_embeds: jnp.ndarray
               ) -> jnp.ndarray:
        cfg = self.cfg
        b, s, d = audio_embeds.shape
        x = audio_embeds.astype(self.dtype) + _sinusoid(s, d, self.dtype)
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

        def body(x, p_l):
            h, _ = attn.attn_prefill(
                p_l["attn"], rms_norm(x, p_l["ln1"], cfg.norm_eps),
                positions, cfg, True, False, causal=False)  # bidirectional
            x = x + h
            y = mlp_apply(p_l["mlp"], rms_norm(x, p_l["ln2"], cfg.norm_eps),
                          cfg.act)
            return x + y, None

        body_fn = jax.checkpoint(body, policy=REMAT_POLICY) \
            if cfg.remat else body
        x, _ = scan_blocks(body_fn, x, params["enc_blocks"],
                           cfg.scan_layers)
        return rms_norm(x, params["enc_norm"], cfg.norm_eps)

    # ------------------------------------------------------------ decoder
    def _dec_block_seq(self, p, x, positions, enc_kv, with_cache):
        cfg = self.cfg
        h, cache = attn.attn_prefill(
            p["attn"], rms_norm(x, p["ln1"], cfg.norm_eps), positions, cfg,
            True, with_cache)
        x = x + h
        x = x + attn.cross_attn_apply(
            p["xattn"], rms_norm(x, p["ln_x"], cfg.norm_eps),
            enc_kv[0], enc_kv[1], cfg)
        y = mlp_apply(p["mlp"], rms_norm(x, p["ln2"], cfg.norm_eps),
                      cfg.act)
        return x + y, cache

    def decode_seq(self, params, tokens, enc_out, with_cache=False):
        cfg = self.cfg
        b, s = tokens.shape
        x = params["embed"][tokens] + params["dec_pos"][:s]
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

        def body(x, p_l):
            enc_kv = attn.cross_kv(p_l["xattn"], enc_out, cfg)
            x, cache = self._dec_block_seq(p_l, x, positions, enc_kv,
                                           with_cache)
            return x, cache

        body_fn = jax.checkpoint(body, policy=REMAT_POLICY) \
            if cfg.remat else body
        x, caches = scan_blocks(body_fn, x, params["dec_blocks"],
                                cfg.scan_layers)
        return rms_norm(x, params["dec_norm"], cfg.norm_eps), caches

    # ------------------------------------------------------------- losses
    def loss_fn(self, params, batch):
        tokens = batch["tokens"]
        enc_out = self.encode(params, batch["audio_embeds"])
        h, _ = self.decode_seq(params, tokens[:, :-1], enc_out)
        logits = h @ params["embed"].T
        loss = cross_entropy(logits, tokens[:, 1:])
        return loss, {"ce": loss}

    def prefill(self, params, batch, cache_len=None):
        enc_out = self.encode(params, batch["audio_embeds"])
        h, caches = self.decode_seq(params, batch["tokens"], enc_out,
                                    with_cache=True)
        if cache_len is not None:
            caches = attn.grow_cache(caches, self.cfg, True, cache_len,
                                     batch["tokens"].shape[1])
        # cross-KV is recomputed per decode step from enc_out unless cached;
        # cache it once here (per layer):
        def per_layer_kv(p_l):
            k, v = attn.cross_kv(p_l["xattn"], enc_out, self.cfg)
            return {"k": k, "v": v}
        xkv = jax.vmap(per_layer_kv)(params["dec_blocks"])
        logits = h[:, -1:] @ params["embed"].T
        return logits, {"self": caches, "cross": xkv}

    def decode_step(self, params, caches, batch):
        cfg = self.cfg
        pos = batch["pos"]
        x = params["embed"][batch["token"]] \
            + jax.lax.dynamic_slice_in_dim(params["dec_pos"], pos, 1)

        def body(x, xs):
            p_l, self_c, cross_c = xs
            h, self_c = attn.attn_decode(
                p_l["attn"], rms_norm(x, p_l["ln1"], cfg.norm_eps),
                self_c, pos, cfg, True)
            x = x + h
            x = x + attn.cross_attn_apply(
                p_l["xattn"], rms_norm(x, p_l["ln_x"], cfg.norm_eps),
                cross_c["k"], cross_c["v"], cfg)
            y = mlp_apply(p_l["mlp"], rms_norm(x, p_l["ln2"], cfg.norm_eps),
                          cfg.act)
            return x + y, self_c

        x, new_self = scan_blocks(
            body, x, (params["dec_blocks"], caches["self"],
                      caches["cross"]), cfg.scan_layers)
        x = rms_norm(x, params["dec_norm"], cfg.norm_eps)
        return x @ params["embed"].T, {"self": new_self,
                                       "cross": caches["cross"]}

    def init_caches(self, batch: int, cache_len: int):
        cfg = self.cfg
        self_c = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.dec_layers,) + a.shape),
            attn.init_cache(cfg, batch, cache_len, True, self.dtype))
        cross = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.dec_layers,) + a.shape),
            {"k": jnp.zeros((batch, CROSS_FRAMES, cfg.n_kv_heads,
                             cfg.head_dim), self.dtype),
             "v": jnp.zeros((batch, CROSS_FRAMES, cfg.n_kv_heads,
                             cfg.head_dim), self.dtype)})
        return {"self": self_c, "cross": cross}

    def cache_pspecs(self, shard_seq: bool):
        batch_axes = self.data_axes if len(self.data_axes) > 1 \
            else self.data_axes[0]
        kv_ok = divisible(self.cfg.n_kv_heads, self.tp)
        base = attn.cache_pspec(batch_axes, shard_seq, kv_ok,
                                quantized=self.cfg.kv_dtype == "int8")
        cross = attn.cache_pspec(batch_axes, False, kv_ok)
        return {"self": _with_leading(base, 1),
                "cross": _with_leading(cross, 1)}
