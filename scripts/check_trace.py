"""Chrome trace-event / metrics-snapshot validator (DESIGN.md §13).

CI gate for the telemetry exports: a trace file that Perfetto or
chrome://tracing would reject — or a span stream whose B/E events do
not nest — must fail the job, not ship. Checks:

  * top level is ``{"traceEvents": [...]}`` (JSON object form);
  * every event carries the required fields ``ph``/``ts``/``pid``/
    ``tid``/``name``, with numeric ``ts`` and a known phase;
  * duration events pair up: per (pid, tid) track, every ``E`` matches
    the name of the innermost open ``B`` (proper nesting) and no ``B``
    is left open at the end;
  * complete events (``X``) carry a non-negative ``dur``;
  * ``--require a,b,c`` span names all appear somewhere in the trace;
  * with ``--metrics DIR``: ``metrics.jsonl`` parses line-by-line and
    ``metrics.prom`` is non-empty Prometheus text.

Usage:
    python scripts/check_trace.py trace.json \
        [--metrics DIR] [--require round,solve,replan_round]

Exits 0 when everything validates, 1 with a message otherwise.
"""
import argparse
import json
import os
import sys

#: phases the exporter may legally emit (subset of the trace-event
#: spec): duration B/E, complete X, instant i, metadata M.
KNOWN_PHASES = {"B", "E", "X", "i", "M"}
REQUIRED_FIELDS = ("ph", "ts", "pid", "tid", "name")


def fail(msg: str) -> None:
    print(f"[check_trace] FAIL: {msg}")
    sys.exit(1)


def check_trace(path: str, require: list) -> int:
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        fail(f"cannot load {path}: {e}")
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        fail(f"{path}: top level must be an object with 'traceEvents'")
    events = doc["traceEvents"]
    if not isinstance(events, list) or not events:
        fail(f"{path}: traceEvents must be a non-empty list")

    names = set()
    stacks = {}  # (pid, tid) -> [open span names]
    for i, ev in enumerate(events):
        for field in REQUIRED_FIELDS:
            if field not in ev:
                fail(f"event #{i} missing required field {field!r}: "
                     f"{ev!r}")
        if ev["ph"] not in KNOWN_PHASES:
            fail(f"event #{i} has unknown phase {ev['ph']!r}")
        if not isinstance(ev["ts"], (int, float)):
            fail(f"event #{i} ts must be numeric, got {ev['ts']!r}")
        if ev["ts"] < 0:
            fail(f"event #{i} has negative ts {ev['ts']!r}")
        track = (ev["pid"], ev["tid"])
        if ev["ph"] == "B":
            stacks.setdefault(track, []).append(ev["name"])
        elif ev["ph"] == "E":
            stack = stacks.get(track) or []
            if not stack:
                fail(f"event #{i}: E {ev['name']!r} on track {track} "
                     f"with no open B")
            top = stack.pop()
            if top != ev["name"]:
                fail(f"event #{i}: E {ev['name']!r} does not match "
                     f"innermost open B {top!r} on track {track}")
        elif ev["ph"] == "X":
            if not isinstance(ev.get("dur"), (int, float)) \
                    or ev["dur"] < 0:
                fail(f"event #{i}: X needs a non-negative dur, got "
                     f"{ev.get('dur')!r}")
        elif ev["ph"] == "i":
            if ev.get("s") not in (None, "t", "p", "g"):
                fail(f"event #{i}: instant scope must be t/p/g, got "
                     f"{ev.get('s')!r}")
        if ev["ph"] != "M":
            names.add(ev["name"])
    for track, stack in stacks.items():
        if stack:
            fail(f"track {track} ends with unclosed spans: {stack}")
    missing = [n for n in require if n not in names]
    if missing:
        fail(f"required span names absent from {path}: {missing} "
             f"(present: {sorted(names)})")
    return len(events)


def check_metrics(out_dir: str) -> None:
    jsonl = os.path.join(out_dir, "metrics.jsonl")
    prom = os.path.join(out_dir, "metrics.prom")
    for p in (jsonl, prom):
        if not os.path.isfile(p):
            fail(f"missing metrics export {p}")
    with open(jsonl) as f:
        lines = [ln for ln in f if ln.strip()]
    if not lines:
        fail(f"{jsonl} is empty")
    for i, ln in enumerate(lines):
        try:
            rec = json.loads(ln)
        except json.JSONDecodeError as e:
            fail(f"{jsonl} line {i + 1} is not JSON: {e}")
        if "name" not in rec or "type" not in rec:
            fail(f"{jsonl} line {i + 1} missing name/type: {rec!r}")
    with open(prom) as f:
        text = f.read()
    if "# TYPE" not in text:
        fail(f"{prom} has no '# TYPE' lines — not Prometheus text")
    print(f"[check_trace] metrics ok: {len(lines)} metrics in "
          f"{jsonl}, {len(text.splitlines())} prom lines")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("trace", help="Chrome trace-event JSON file")
    ap.add_argument("--metrics", default=None, metavar="DIR",
                    help="also validate metrics.jsonl/metrics.prom "
                         "in DIR")
    ap.add_argument("--require", default="", metavar="NAMES",
                    help="comma-separated span names that must appear")
    args = ap.parse_args()
    require = [n for n in args.require.split(",") if n]
    n = check_trace(args.trace, require)
    print(f"[check_trace] trace ok: {n} events in {args.trace}")
    if args.metrics:
        check_metrics(args.metrics)
    print("[check_trace] PASS")


if __name__ == "__main__":
    main()
