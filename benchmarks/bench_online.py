"""Online re-planning engine benchmark (DESIGN.md §9, EXPERIMENTS.md
§Online): a 16-problem fleet is driven through drifting-environment
traces and re-planned warm at every event; each round is also re-solved
COLD from scratch (the oracle) to measure

  * cost-vs-oracle regret  — Σ warm realized cost − Σ oracle realized
    cost, where realized = plan cost + the Eq. 6 migration paid to adopt
    it from the deployed incumbent (the oracle's fresh plan pays
    migration too — adopting it moves layers just the same)
  * iterations-to-converge — warm vs cold ``converge_iters`` (iterations
    until the final gbest was found; the stopping rule then burns
    ``stall_iters`` more confirming it, identically in both arms)
  * replan wall-clock      — warm round latency (compiled-runner hot)

Warm-start must converge in ≤ 0.5× the cold iterations at equal-or-
better realized fleet cost (the ISSUE-4 acceptance bar); every run
writes a machine-readable ``BENCH_online.json`` so the trajectory is
tracked across PRs.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.core import (PSOGAConfig, ReplanConfig, TRACE_KINDS,
                        heft_makespan, paper_environment, replan_round,
                        run_pso_ga_batch, runner_cache_stats, sample_trace,
                        zoo)
from repro.core.online import migration_cost_np
from repro.core.simulator import SimProblem

from .common import bench_metadata, print_csv

#: warm rounds should stall out fast; cold solves get the full budget
ONLINE_CFG = PSOGAConfig(pop_size=32, max_iters=200, stall_iters=30)


def _json_safe(obj):
    """Replace non-finite floats with None (JSON null): heavy drift can
    legitimately make a round's plan infeasible (cost inf, regret nan),
    and strict JSON consumers reject bare Infinity/NaN tokens."""
    if isinstance(obj, dict):
        return {k: _json_safe(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_json_safe(v) for v in obj]
    if isinstance(obj, float) and not np.isfinite(obj):
        return None
    return obj


def make_fleet(n: int, env, ratios=(1.2, 1.5, 2.0, 3.0)):
    """N heterogeneous problems (mixed nets / pins / deadline ratios)."""
    dags = []
    for i in range(n):
        net = ("alexnet", "vgg19", "googlenet")[i % 3]
        dag = zoo.build(net, pin_server=i % 10)
        h, _ = heft_makespan(dag, env)
        dags.append(dag.with_deadline(np.array([ratios[i % 4] * h])))
    return dags


def run_scenario(kind: str, n: int, rounds: int, seed: int,
                 cfg: ReplanConfig):
    env = paper_environment()
    dags = make_fleet(n, env)
    trace = sample_trace(kind, env, rounds=rounds, seed=seed)

    probs0 = [SimProblem.build(d, trace.env_at(0)) for d in dags]
    t0 = time.perf_counter()
    cold0 = run_pso_ga_batch(probs0, cfg.pso, seed=seed)
    wall_cold0 = time.perf_counter() - t0
    plans = [np.asarray(r.best_x, np.int32) for r in cold0]

    rows = []
    for k in range(1, rounds):
        probs_k = [SimProblem.build(d, trace.env_at(k)) for d in dags]
        prev = [p.copy() for p in plans]
        plans, log = replan_round(probs_k, plans, cfg, seed=seed + k,
                                  round_no=k, label=trace.events[k].label)
        # the oracle: same round, same seeds, solved cold from scratch —
        # but adopting ITS plan pays migration from the incumbent too
        t0 = time.perf_counter()
        oracle, o_state = run_pso_ga_batch(probs_k, cfg.pso,
                                           seed=seed + k,
                                           return_state=True)
        wall_oracle = time.perf_counter() - t0
        o_mig = np.array([migration_cost_np(pr, pv, r.best_x)
                          for pr, pv, r in zip(probs_k, prev, oracle)])
        o_cost = np.array([r.best_cost for r in oracle])
        o_iters = np.array([r.iterations for r in oracle])
        o_conv = np.maximum(o_iters - np.asarray(o_state.stall), 0)
        warm_real = float(np.sum(log.cost + cfg.migration_weight
                                 * log.migration))
        oracle_real = float(np.sum(o_cost + cfg.migration_weight * o_mig))
        conv_ratio = (float(log.converge_iters.mean())
                      / max(float(o_conv.mean()), 1.0))
        rows.append({
            "kind": kind, "round": k, "label": log.label,
            "replanned": int(log.replanned.sum()),
            "warm_converge_iters": float(log.converge_iters.mean()),
            "cold_converge_iters": float(o_conv.mean()),
            "iters_ratio": conv_ratio,
            "warm_iters_mean": float(log.iterations.mean()),
            "cold_iters_mean": float(o_iters.mean()),
            "warm_cost_sum": warm_real,
            "oracle_cost_sum": oracle_real,
            "warm_plan_cost": float(np.sum(log.cost)),
            "oracle_plan_cost": float(np.sum(o_cost)),
            "regret": warm_real - oracle_real,
            "moved_layers": int(log.moved_layers.sum()),
            "warm_wall_s": log.wall_s,
            "cold_wall_s": wall_oracle,
        })
        print(f"# {kind} round {k} ({log.label}): converge warm "
              f"{rows[-1]['warm_converge_iters']:.1f} / cold "
              f"{rows[-1]['cold_converge_iters']:.1f} "
              f"(ratio {conv_ratio:.2f}), realized cost warm "
              f"{warm_real:.5f} vs oracle {oracle_real:.5f}, "
              f"replan {log.wall_s:.2f}s vs cold {wall_oracle:.2f}s",
              flush=True)
    summary = {
        "kind": kind,
        "n_problems": n,
        "rounds": rounds,
        "cold0_wall_s": wall_cold0,
        "iters_ratio_mean": float(np.mean([r["iters_ratio"]
                                           for r in rows])),
        "warm_cost_total": float(sum(r["warm_cost_sum"] for r in rows)),
        "oracle_cost_total": float(sum(r["oracle_cost_sum"]
                                       for r in rows)),
        "regret_total": float(sum(r["regret"] for r in rows)),
        "warm_wall_mean_s": float(np.mean([r["warm_wall_s"]
                                           for r in rows])),
        "cold_wall_mean_s": float(np.mean([r["cold_wall_s"]
                                           for r in rows])),
    }
    return rows, summary


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=16,
                    help="fleet size (problems per round)")
    ap.add_argument("--rounds", type=int, default=6,
                    help="trace length incl. the round-0 cold solve")
    ap.add_argument("--kinds", nargs="*", default=["wifi-fade"],
                    choices=list(TRACE_KINDS) + ["all"],
                    help="drift scenario families to run")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--migration-weight", type=float, default=1.0)
    ap.add_argument("--json", default="BENCH_online.json",
                    help="machine-readable results ('' to disable)")
    args = ap.parse_args()
    # load-surge drifts the WORKLOAD, not the environment — without a
    # TrafficConfig this bench's replan rounds would be no-ops; the
    # traffic engine's own benchmark (bench_traffic) covers that axis.
    kinds = [k for k in TRACE_KINDS if k != "load-surge"] \
        if "all" in args.kinds else args.kinds
    cfg = ReplanConfig(pso=ONLINE_CFG,
                       migration_weight=args.migration_weight)

    all_rows, summaries = [], []
    for kind in kinds:
        rows, summary = run_scenario(kind, args.n, args.rounds,
                                     args.seed, cfg)
        all_rows.extend(rows)
        summaries.append(summary)
        ok = (summary["iters_ratio_mean"] <= 0.5
              and summary["warm_cost_total"]
              <= summary["oracle_cost_total"] + 1e-9)
        print(f"# {kind}: iters ratio {summary['iters_ratio_mean']:.2f} "
              f"(bar <= 0.50), regret {summary['regret_total']:+.5f} "
              f"-> {'PASS' if ok else 'MISS'}", flush=True)
    print_csv(all_rows, ["kind", "round", "label", "replanned",
                         "warm_converge_iters", "cold_converge_iters",
                         "iters_ratio", "warm_cost_sum",
                         "oracle_cost_sum", "regret", "moved_layers",
                         "warm_wall_s", "cold_wall_s"])
    if args.json:
        payload = {
            "bench": "bench_online",
            "meta": bench_metadata(seeds=[args.seed]),
            "device": jax.devices()[0].platform,
            "n_problems": args.n,
            "rounds": args.rounds,
            "pso": {"pop_size": ONLINE_CFG.pop_size,
                    "max_iters": ONLINE_CFG.max_iters,
                    "stall_iters": ONLINE_CFG.stall_iters},
            "migration_weight": args.migration_weight,
            "runner_cache": runner_cache_stats(),
            "rounds_detail": all_rows,
            "scenarios": summaries,
        }
        with open(args.json, "w") as f:
            json.dump(_json_safe(payload), f, indent=2, allow_nan=False)
        print(f"# wrote {args.json}")


if __name__ == "__main__":
    main()
