"""whisper-medium — enc-dec audio, conv frontend STUB. [arXiv:2212.04356; unverified]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium", family="encdec", n_layers=24, d_model=1024,
    n_heads=16, n_kv_heads=16, head_dim=64, d_ff=4096, vocab=51_865,
    act="gelu", enc_layers=24, dec_layers=24, rope_theta=10_000.0)
