"""Schedule simulator — paper Algorithm 2 ("map from a particle to DNN
layers offloading").

Given an assignment vector ``x`` (server index per layer) the simulator
replays the offloading: layers execute in a fixed topological order (the
paper freezes the order genes φ at initialization — §IV-B.3 "the value of
the order φ_j for each layer remains the same"), each server is a serial
queue, incoming datasets pay ``∂ / ℓ`` transfer time, and the server stays
busy for its outgoing transfers (Alg. 2 line 21).

Two fidelity modes (see DESIGN.md §2):
  * ``faithful=True``  — the printed recurrence, verbatim:
        T_start = T_lease(s) + maxTrans            (lines 4/11)
        T_lease(s) += exe + transfer_out           (line 21)
    (the incoming wait is *not* added to the server busy time, exactly as
    printed in the paper).
  * ``faithful=False`` — "corrected": serial processing is preserved and
    a layer cannot start before its parents finished and shipped:
        T_start = max(T_lease(s), max_p(T_end(p) + trans_p))
        T_lease(s) = T_end + transfer_out

Cost model (Eq. 8): per-server rental  c_com · (T_off − T_on)  with
T_on = first T_start on the server, T_off = final lease (includes trailing
outgoing transfers), plus per-edge transmission  c_tran · ∂  for every
edge crossing two distinct servers.

Missing links (ℓ = 0, e.g. device↔device) are clamped to ``MIN_BW`` MB/s
so infeasible placements get enormous-but-finite times — this keeps the
paper's Case-2 fitness (compare total completion times of two infeasible
particles) a meaningful total order instead of inf == inf.

Both a pure-numpy reference (`simulate_np`) and a jit/vmap-able JAX
implementation (`build_simulator`) are provided; tests assert they agree.

The JAX path operates on a *padded* representation (``PaddedProblem`` +
``simulate_padded``): layers are padded to ``max_p`` (padded ``order``
entries are -1 and execute as zero-cost no-ops), servers to ``max_S``
(padded servers are unreachable: ``link_ok`` false, never selected by the
solver), apps to ``max_apps`` (deadline +inf). ``build_simulator`` is the
zero-padding special case; ``repro.core.batch`` stacks N heterogeneous
``PaddedProblem``s along a leading axis and vmaps the swarm evaluator
over the whole fleet (DESIGN.md §4).

Both JAX entry points use the two-phase split of DESIGN.md §8 —
carry-independent quantities precomputed in one vectorized pass, then a
minimal-carry ``lax.scan``: ``simulate_padded`` evaluates ONE assignment
and returns the full ``SimResult`` (the epilogue/test path);
``simulate_swarm`` evaluates a whole ``(P, max_p)`` swarm with shared
step indices and returns only the fitness summary — the PSO-GA hot path
(``fitness.make_swarm_fitness``'s "scan" backend; the "pallas" backend
is its in-kernel twin, ``kernels/schedule_sim.py``).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .dag import LayerDAG, topological_order
from .environment import Environment

MIN_BW = 1e-9   # MB/s stand-in for "no link"
__all__ = ["SimResult", "SimProblem", "PaddedProblem", "pad_problem",
           "simulate_padded", "simulate_swarm", "simulate_np",
           "build_simulator", "MIN_BW"]


class SimResult(NamedTuple):
    """All fields are jnp/np arrays; scalar fields are 0-d."""
    end_times: jnp.ndarray        # (p,) per-layer completion time
    app_completion: jnp.ndarray   # (n_apps,) T_i^comp
    comp_cost: jnp.ndarray        # $ rental
    trans_cost: jnp.ndarray       # $ transmission
    total_cost: jnp.ndarray       # Eq. 8
    feasible: jnp.ndarray         # bool: all deadlines met AND pins honored
    makespan: jnp.ndarray         # max end time


@dataclasses.dataclass(frozen=True)
class SimProblem:
    """Static, device-ready arrays describing (dag, env) for the simulator."""
    compute: np.ndarray       # (p,)
    order: np.ndarray         # (p,) topological order
    parent_idx: np.ndarray    # (p, max_in) padded -1
    parent_mb: np.ndarray     # (p, max_in)
    child_idx: np.ndarray     # (p, max_out) padded -1
    child_mb: np.ndarray      # (p, max_out)
    app_id: np.ndarray        # (p,)
    deadline: np.ndarray      # (n_apps,)
    pinned: np.ndarray        # (p,)
    power: np.ndarray         # (S,)
    cost_per_sec: np.ndarray  # (S,)
    inv_bw: np.ndarray        # (S, S) seconds per MB (0 on diagonal)
    tran_cost: np.ndarray     # (S, S) $/MB (0 on diagonal)
    link_ok: np.ndarray       # (S, S) bool

    @property
    def num_layers(self) -> int:
        return int(self.compute.shape[0])

    @property
    def num_servers(self) -> int:
        return int(self.power.shape[0])

    @property
    def num_apps(self) -> int:
        return int(self.deadline.shape[0])

    @staticmethod
    def build(dag: LayerDAG, env: Environment) -> "SimProblem":
        pi, pm, ci, cm = dag.padded_relatives()
        bw = np.where(env.bandwidth <= 0.0, MIN_BW, env.bandwidth)
        inv_bw = 1.0 / bw                     # diagonal is 1/inf = 0
        return SimProblem(
            compute=dag.compute, order=topological_order(dag),
            parent_idx=pi, parent_mb=pm, child_idx=ci, child_mb=cm,
            app_id=dag.app_id, deadline=dag.deadline, pinned=dag.pinned,
            power=env.power, cost_per_sec=env.cost_per_sec,
            inv_bw=inv_bw, tran_cost=env.tran_cost,
            link_ok=env.bandwidth > 0.0)


# ---------------------------------------------------------------------------
# numpy reference (oracle for tests)
# ---------------------------------------------------------------------------

def simulate_np(prob: SimProblem, x: np.ndarray, faithful: bool = True
                ) -> SimResult:
    x = np.asarray(x, np.int64)
    p, s = prob.num_layers, prob.num_servers
    lease = np.zeros(s)
    t_on = np.full(s, np.inf)
    used = np.zeros(s, bool)
    end = np.zeros(p)
    trans_cost = 0.0
    link_violation = False

    for j in prob.order:
        srv = x[j]
        exe = prob.compute[j] / prob.power[srv]
        pars = prob.parent_idx[j]
        mask = pars >= 0
        max_trans = 0.0
        parent_gate = 0.0
        for k in np.nonzero(mask)[0]:
            pj = pars[k]
            mb = prob.parent_mb[j, k]
            t = mb * prob.inv_bw[x[pj], srv]
            if not prob.link_ok[x[pj], srv] and x[pj] != srv:
                link_violation = True
            max_trans = max(max_trans, t)
            parent_gate = max(parent_gate, end[pj] + t)
            trans_cost += prob.tran_cost[x[pj], srv] * mb
        if faithful:
            start = lease[srv] + max_trans
        else:
            start = max(lease[srv], parent_gate)
        t_end = start + exe
        end[j] = t_end
        t_on[srv] = min(t_on[srv], start)
        used[srv] = True
        transfer_out = 0.0
        cidx = prob.child_idx[j]
        for k in np.nonzero(cidx >= 0)[0]:
            transfer_out += prob.child_mb[j, k] * prob.inv_bw[srv, x[cidx[k]]]
        if faithful:
            lease[srv] = lease[srv] + exe + transfer_out   # line 21, verbatim
        else:
            lease[srv] = t_end + transfer_out

    app_completion = np.zeros(prob.num_apps)
    np.maximum.at(app_completion, prob.app_id, end)
    comp_cost = float(np.sum(np.where(used, prob.cost_per_sec * (lease - np.where(np.isinf(t_on), 0.0, t_on)), 0.0)))
    pin_ok = np.all((prob.pinned < 0) | (x == prob.pinned))
    feasible = bool(np.all(app_completion <= prob.deadline) and pin_ok
                    and not link_violation)
    total = comp_cost + trans_cost
    return SimResult(end_times=end, app_completion=app_completion,
                     comp_cost=np.float64(comp_cost),
                     trans_cost=np.float64(trans_cost),
                     total_cost=np.float64(total),
                     feasible=np.bool_(feasible),
                     makespan=np.float64(end.max() if p else 0.0))


# ---------------------------------------------------------------------------
# JAX implementation — padded representation, lax.scan over layers,
# vmap over particles (and, in repro.core.batch, over problems)
# ---------------------------------------------------------------------------


class PaddedProblem(NamedTuple):
    """Device-ready padded arrays for one problem (DESIGN.md §4).

    Every field is a jnp array; ``repro.core.batch`` stacks N of these
    along a leading axis and vmaps the simulator/step over it. Padding
    conventions (all padding is appended AFTER the real entries so float
    reductions accumulate identical partial sums):
      * layers  -> ``max_p``:   ``order`` padded -1 (scan no-op), compute 0,
        pinned -1, parent/child idx -1.
      * servers -> ``max_S``:   power 1 (no div-by-0), cost 0, link_ok
        False, inv_bw 1/MIN_BW — and the solver never emits genes >=
        ``num_servers``, so padded servers are unreachable by construction.
      * apps    -> ``max_apps``: deadline +inf (never violated; an empty
        app's completion clamps to 0).
    ``num_layers`` / ``num_servers`` / ``num_apps`` are the TRUE counts as
    0-d int32 arrays — traced per problem under vmap, so PSO-GA mutation
    and crossover draw bounds from the real sizes, not the padded ones.
    """
    compute: jnp.ndarray        # (max_p,)
    order: jnp.ndarray          # (max_p,) topo order, padded -1
    parent_idx: jnp.ndarray     # (max_p, max_in) padded -1
    parent_mb: jnp.ndarray      # (max_p, max_in)
    child_idx: jnp.ndarray      # (max_p, max_out) padded -1
    child_mb: jnp.ndarray       # (max_p, max_out)
    app_id: jnp.ndarray         # (max_p,)
    deadline: jnp.ndarray       # (max_apps,) padded +inf
    pinned: jnp.ndarray         # (max_p,) padded -1
    power: jnp.ndarray          # (max_S,)
    cost_per_sec: jnp.ndarray   # (max_S,)
    inv_bw: jnp.ndarray         # (max_S, max_S)
    tran_cost: jnp.ndarray      # (max_S, max_S)
    link_ok: jnp.ndarray        # (max_S, max_S) bool
    num_layers: jnp.ndarray     # () int32 — true p
    num_servers: jnp.ndarray    # () int32 — true S
    num_apps: jnp.ndarray       # () int32 — true n_apps

    @property
    def max_layers(self) -> int:
        return int(self.compute.shape[-1])

    @property
    def max_servers(self) -> int:
        return int(self.power.shape[-1])


def pad_problem(prob: SimProblem,
                max_p: Optional[int] = None,
                max_S: Optional[int] = None,
                max_in: Optional[int] = None,
                max_out: Optional[int] = None,
                max_apps: Optional[int] = None) -> PaddedProblem:
    """Embed a ``SimProblem`` into the padded representation.

    With all sizes None this is the identity embedding (zero padding) —
    ``build_simulator`` uses exactly that, so the unbatched solver is the
    N=1 case of the batched machinery. Explicit per-axis targets are how
    ``batch.pack_fleet`` pads each problem to its own BUCKET's
    ``(max_p, max_S)`` rather than a fleet-global shape (DESIGN.md §12);
    padded layers are zero-cost no-ops appended after the real entries
    and padded servers are unreachable, so the simulated result is
    bit-identical under any legal target sizes.
    """
    p, s, a = prob.num_layers, prob.num_servers, prob.num_apps
    in0, out0 = prob.parent_idx.shape[1], prob.child_idx.shape[1]
    max_p = p if max_p is None else max_p
    max_S = s if max_S is None else max_S
    max_in = in0 if max_in is None else max_in
    max_out = out0 if max_out is None else max_out
    max_apps = a if max_apps is None else max_apps
    if max_p < p or max_S < s or max_in < in0 or max_out < out0 \
            or max_apps < a:
        raise ValueError("padded sizes smaller than problem sizes")

    def pad(arr, shape, fill):
        out = np.full(shape, fill, dtype=arr.dtype)
        out[tuple(slice(0, n) for n in arr.shape)] = arr
        return jnp.asarray(out)

    return PaddedProblem(
        compute=pad(prob.compute, (max_p,), 0.0),
        order=pad(prob.order, (max_p,), -1),
        parent_idx=pad(prob.parent_idx, (max_p, max_in), -1),
        parent_mb=pad(prob.parent_mb, (max_p, max_in), 0.0),
        child_idx=pad(prob.child_idx, (max_p, max_out), -1),
        child_mb=pad(prob.child_mb, (max_p, max_out), 0.0),
        app_id=pad(prob.app_id, (max_p,), 0),
        deadline=pad(prob.deadline, (max_apps,), np.inf),
        pinned=pad(prob.pinned, (max_p,), -1),
        power=pad(prob.power, (max_S,), 1.0),
        cost_per_sec=pad(prob.cost_per_sec, (max_S,), 0.0),
        inv_bw=pad(prob.inv_bw, (max_S, max_S), 1.0 / MIN_BW),
        tran_cost=pad(prob.tran_cost, (max_S, max_S), 0.0),
        link_ok=pad(prob.link_ok, (max_S, max_S), False),
        num_layers=jnp.asarray(p, jnp.int32),
        num_servers=jnp.asarray(s, jnp.int32),
        num_apps=jnp.asarray(a, jnp.int32))


class _ScanInputs(NamedTuple):
    """Carry-independent per-step quantities (phase 1 of the two-phase
    split, DESIGN.md §8) — everything Algorithm 2 needs at step ``t``
    except the evolving ``(lease, end, t_on)`` state. All leading axes
    are ``max_p`` (one row per scan step, in ``order`` sequence)."""
    valid: jnp.ndarray      # (max_p,) bool — real (non-padded) step
    jsafe: jnp.ndarray      # (max_p,) layer index (0 for padded steps)
    srv: jnp.ndarray        # (max_p,) server executing the layer
    exe: jnp.ndarray        # (max_p,) execution seconds a/p  (Eq. 4)
    max_trans: jnp.ndarray  # (max_p,) max incoming transfer ∂/ℓ (Eq. 6)
    out_t: jnp.ndarray      # (max_p,) total outgoing transfer (line 21)
    psafe: jnp.ndarray      # (max_p, max_in) parent indices (0-safe)
    pmask: jnp.ndarray      # (max_p, max_in) real-parent mask
    tt: jnp.ndarray         # (max_p, max_in) per-edge transfer seconds


def _precompute_scan_inputs(pp: PaddedProblem, x: jnp.ndarray
                            ) -> Tuple[_ScanInputs, jnp.ndarray, jnp.ndarray]:
    """Phase 1: one vectorized O(max_p · max_in) pass over the schedule.

    Returns ``(inputs, trans_cost, link_bad)``. Per-edge transfer times,
    transmission cost, link-violation flags, per-layer execution times and
    the server gathers ``x[parent_idx]`` are all carry-independent, so
    they vectorize over every step at once instead of being recomputed
    one dynamic gather at a time inside the scan (DESIGN.md §8). Masked
    (padded) entries contribute exact zeros, appended after the real
    entries, so reductions are padding-invariant.
    """
    j = pp.order                                   # (max_p,)
    valid = j >= 0
    jsafe = jnp.where(valid, j, 0)
    srv = x[jsafe]                                 # (max_p,)
    exe = pp.compute[jsafe] / pp.power[srv]
    pars = pp.parent_idx[jsafe]                    # (max_p, max_in)
    pmask = (pars >= 0) & valid[:, None]
    psafe = jnp.where(pmask, pars, 0)
    psrv = x[psafe]                                # (max_p, max_in)
    srv_b = srv[:, None]
    mb = pp.parent_mb[jsafe]
    tt = mb * pp.inv_bw[psrv, srv_b]               # (max_p, max_in)
    max_trans = jnp.max(jnp.where(pmask, tt, 0.0), axis=1, initial=0.0)
    trans_cost = jnp.sum(jnp.where(pmask, pp.tran_cost[psrv, srv_b] * mb,
                                   0.0))
    link_bad = jnp.any(pmask & ~pp.link_ok[psrv, srv_b] & (psrv != srv_b))
    kids = pp.child_idx[jsafe]                     # (max_p, max_out)
    kmask = (kids >= 0) & valid[:, None]
    ksrv = x[jnp.where(kmask, kids, 0)]
    out_t = jnp.sum(jnp.where(kmask,
                              pp.child_mb[jsafe] * pp.inv_bw[srv_b, ksrv],
                              0.0), axis=1)
    link_bad = link_bad | jnp.any(
        kmask & ~pp.link_ok[srv_b, ksrv] & (ksrv != srv_b))
    return (_ScanInputs(valid=valid, jsafe=jsafe, srv=srv, exe=exe,
                        max_trans=max_trans, out_t=out_t,
                        psafe=psafe, pmask=pmask, tt=tt),
            trans_cost, link_bad)


def simulate_padded(pp: PaddedProblem, x: jnp.ndarray,
                    faithful: bool = True) -> SimResult:
    """Algorithm 2 on the padded representation. Pure — vmap over particles
    (``x`` axis) and/or problems (leading ``pp`` axis) freely.

    Two-phase evaluation (DESIGN.md §8): phase 1 precomputes every
    carry-independent quantity in one vectorized pass
    (``_precompute_scan_inputs``); phase 2 is a ``lax.scan`` whose carry
    is just ``(lease, end)`` — ``(lease,)`` alone in faithful mode, whose
    recurrence never reads ``end`` — and whose body is one server gather,
    the parent-gate ``end`` gather (corrected mode only), and drop-mode
    scatters (a padded step scatters out of bounds and is dropped, so no
    read-modify-write). ``t_on`` leaves the carry entirely: the scan
    emits per-step start times and ``t_on`` is a post-scan
    ``segment_min`` over servers (min is order-independent, so this is
    bit-identical to the carried version); ``used`` is
    ``isfinite(t_on)``.

    Padded ``order`` entries (-1) leave every piece of carry state
    untouched, so a padded layer is a zero-cost no-op and the result is
    bit-identical to the unpadded simulation of the embedded problem.
    """
    x = jnp.asarray(x).astype(jnp.int32)
    max_p = pp.compute.shape[0]
    max_S = pp.power.shape[0]
    max_apps = pp.deadline.shape[0]

    inputs, trans_cost, link_bad = _precompute_scan_inputs(pp, x)
    # out-of-bounds index for padded steps: drop-mode scatters skip them
    srv_idx = jnp.where(inputs.valid, inputs.srv, max_S)
    j_idx = jnp.where(inputs.valid, inputs.jsafe, max_p)

    def step(carry, inp):
        inp, srv_i, j_i = inp
        if faithful:
            lease, = carry
            lease_srv = lease[inp.srv]
            start = lease_srv + inp.max_trans
            new_lease = lease_srv + inp.exe + inp.out_t
        else:
            lease, end = carry
            parent_gate = jnp.max(
                jnp.where(inp.pmask, end[inp.psafe] + inp.tt, 0.0),
                initial=0.0)
            start = jnp.maximum(lease[inp.srv], parent_gate)
            new_lease = start + inp.exe + inp.out_t
        t_end = start + inp.exe
        lease = lease.at[srv_i].set(new_lease, mode="drop")
        if faithful:
            return (lease,), (start, t_end)
        end = end.at[j_i].set(t_end, mode="drop")
        return (lease, end), (start, t_end)

    init = (jnp.zeros(max_S),) if faithful \
        else (jnp.zeros(max_S), jnp.zeros(max_p))
    carry, (start_seq, t_end_seq) = jax.lax.scan(
        step, init, (inputs, srv_idx, j_idx))
    lease = carry[0]
    if faithful:   # end never feeds back into the faithful recurrence —
        # one vectorized scatter after the scan (padded steps dropped)
        end = jnp.zeros(max_p).at[j_idx].set(t_end_seq, mode="drop")
    else:
        end = carry[1]
    t_on = jax.ops.segment_min(
        jnp.where(inputs.valid, start_seq, jnp.inf), inputs.srv,
        num_segments=max_S)
    used = ~jnp.isinf(t_on)
    # Empty (padded) apps reduce to -inf under segment_max; clamp to 0 —
    # real completions are >= 0, so this changes nothing for real apps.
    app_completion = jnp.maximum(
        jax.ops.segment_max(end, pp.app_id, num_segments=max_apps), 0.0)
    t_on_safe = jnp.where(jnp.isinf(t_on), 0.0, t_on)
    comp_cost = jnp.sum(jnp.where(used,
                                  pp.cost_per_sec * (lease - t_on_safe), 0.0))
    pin_ok = jnp.all((pp.pinned < 0) | (x == pp.pinned))
    feasible = (jnp.all(app_completion <= pp.deadline) & pin_ok & ~link_bad)
    total = comp_cost + trans_cost
    return SimResult(end_times=end, app_completion=app_completion,
                     comp_cost=comp_cost, trans_cost=trans_cost,
                     total_cost=total, feasible=feasible,
                     makespan=jnp.max(end, initial=0.0))


class _SwarmPhase1(NamedTuple):
    """Carry-independent per-layer quantities, swarm-shaped — phase 1
    of DESIGN.md §8 with the particle axis explicit. Shared between
    ``simulate_swarm`` and the traffic engine's queue-aware replay
    (``repro.core.traffic``, DESIGN.md §10): the per-edge transmission
    cost ``tc`` stays un-reduced so the traffic pass can charge it once
    per valid request copy (the single-shot path just sums it)."""
    valid: jnp.ndarray        # (max_p,) shared — real (non-padded) step
    jsafe: jnp.ndarray        # (max_p,) shared
    srv: jnp.ndarray          # (P, max_p)
    exe: jnp.ndarray          # (P, max_p)
    max_trans: jnp.ndarray    # (P, max_p)
    out_t: jnp.ndarray        # (P, max_p)
    psafe: jnp.ndarray        # (max_p, max_in) shared
    pmask: jnp.ndarray        # (max_p, max_in) shared
    tt: jnp.ndarray           # (P, max_p, max_in) per-edge transfer s
    tc: jnp.ndarray           # (P, max_p, max_in) per-edge $ (masked 0)
    link_bad: jnp.ndarray     # (P,)


def _swarm_phase1(pp: PaddedProblem, X: jnp.ndarray) -> _SwarmPhase1:
    """Phase 1, swarm-wide: everything carry-independent, computed once
    for the whole ``(P, max_p)`` swarm with shared step indices."""
    order = pp.order
    valid = order >= 0                                 # (max_p,) shared
    jsafe = jnp.where(valid, order, 0)
    srv = jnp.take(X, jsafe, axis=1)                   # (P, max_p)
    exe = pp.compute[jsafe][None, :] / pp.power[srv]
    pars = pp.parent_idx[jsafe]                        # (max_p, max_in)
    pmask = (pars >= 0) & valid[:, None]               # shared
    psafe = jnp.where(pmask, pars, 0)
    psrv = jnp.take(X, psafe, axis=1)                  # (P, max_p, max_in)
    srv_b = srv[:, :, None]
    mb = pp.parent_mb[jsafe][None, :, :]
    tt = mb * pp.inv_bw[psrv, srv_b]                   # (P, max_p, max_in)
    pm = pmask[None, :, :]
    max_trans = jnp.max(jnp.where(pm, tt, 0.0), axis=2, initial=0.0)
    tc = jnp.where(pm, pp.tran_cost[psrv, srv_b] * mb, 0.0)
    link_bad = jnp.any(pm & ~pp.link_ok[psrv, srv_b] & (psrv != srv_b),
                       axis=(1, 2))
    kids = pp.child_idx[jsafe]
    kmask = ((kids >= 0) & valid[:, None])[None, :, :]
    ksrv = jnp.take(X, jnp.where(kmask[0], kids, 0), axis=1)
    out_t = jnp.sum(jnp.where(kmask,
                              pp.child_mb[jsafe][None] * pp.inv_bw[srv_b,
                                                                   ksrv],
                              0.0), axis=2)
    link_bad = link_bad | jnp.any(
        kmask & ~pp.link_ok[srv_b, ksrv] & (ksrv != srv_b), axis=(1, 2))
    return _SwarmPhase1(valid=valid, jsafe=jsafe, srv=srv, exe=exe,
                        max_trans=max_trans, out_t=out_t, psafe=psafe,
                        pmask=pmask, tt=tt, tc=tc, link_bad=link_bad)


def simulate_swarm(pp: PaddedProblem, X: jnp.ndarray,
                   faithful: bool = True
                   ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Algorithm 2 for a whole swarm at once: ``X (P, max_p)`` int32 →
    per-particle ``(total_cost, feasible, Σ T_i^comp)``.

    This is the ``"scan"`` fitness backend's hot path (DESIGN.md §8) and
    the jnp twin of the Pallas replay kernel: where
    ``vmap(simulate_padded)`` would batch every per-particle dynamic
    gather and recompute the x-independent DAG structure P times, here
    the particle axis is explicit — step indices (layer id, parent ids)
    are *shared* scalars, so per-step reads are column slices, the only
    per-particle indexing is the ``(P, S)`` server one-hot select, and
    phase 1 (``_swarm_phase1``, shared with the traffic engine) runs
    once for the whole swarm. ``t_on`` is recovered post-scan by a
    masked min over steps (order-independent, bit-exact). Returns the
    same summary triple as ``kernels.schedule_sim`` so
    ``fitness.make_swarm_fitness`` treats both backends uniformly.
    """
    X = jnp.asarray(X).astype(jnp.int32)
    P, max_p = X.shape
    max_S = pp.power.shape[0]
    max_apps = pp.deadline.shape[0]

    ph = _swarm_phase1(pp, X)
    valid, jsafe, srv = ph.valid, ph.jsafe, ph.srv
    trans_cost = jnp.sum(ph.tc, axis=(1, 2))
    link_bad = ph.link_bad

    # ---- phase 2: scan over steps, particle axis inside each op ----
    iota_S = jnp.arange(max_S)
    xs = (valid, jsafe, srv.T, ph.exe.T, ph.max_trans.T, ph.out_t.T,
          ph.psafe, ph.pmask, jnp.swapaxes(ph.tt, 0, 1))

    def step(carry, inp):
        valid_t, j_t, srv_t, exe_t, mt_t, ot_t, psafe_t, pmask_t, tt_t = inp
        srv_oh = (srv_t[:, None] == iota_S[None, :]) & valid_t   # (P, S)
        if faithful:
            lease, = carry
        else:
            lease, end = carry
        lease_srv = jnp.take_along_axis(lease, srv_t[:, None], axis=1)[:, 0]
        if faithful:
            start = lease_srv + mt_t
            new_lease = lease_srv + exe_t + ot_t
        else:
            ep = jnp.take(end, psafe_t, axis=1)        # (P, max_in) shared
            gate = jnp.max(jnp.where(pmask_t[None, :], ep + tt_t, 0.0),
                           axis=1, initial=0.0)
            start = jnp.maximum(lease_srv, gate)
            new_lease = start + exe_t + ot_t
        t_end = start + exe_t
        lease = jnp.where(srv_oh, new_lease[:, None], lease)
        if faithful:
            return (lease,), (start, t_end)
        old = jax.lax.dynamic_slice(end, (0, j_t), (P, 1))
        end = jax.lax.dynamic_update_slice(
            end, jnp.where(valid_t, t_end[:, None], old), (0, j_t))
        return (lease, end), (start, t_end)

    init = (jnp.zeros((P, max_S)),) if faithful \
        else (jnp.zeros((P, max_S)), jnp.zeros((P, max_p)))
    carry, (start_seq, t_end_seq) = jax.lax.scan(step, init, xs)
    lease = carry[0]
    if faithful:
        j_idx = jnp.where(valid, jsafe, max_p)
        end = jnp.zeros((P, max_p)).at[:, j_idx].set(t_end_seq.T,
                                                     mode="drop")
    else:
        end = carry[1]
    start_all = start_seq.T                            # (P, max_p)
    t_on = jnp.min(jnp.where((srv[:, :, None] == iota_S) & valid[None, :,
                                                                 None],
                             start_all[:, :, None], jnp.inf), axis=1)

    used = ~jnp.isinf(t_on)
    app_oh = pp.app_id[None, :] == jnp.arange(max_apps)[:, None]
    appc = jnp.maximum(jnp.max(jnp.where(app_oh[None, :, :],
                                         end[:, None, :], -jnp.inf),
                               axis=2), 0.0)          # (P, max_apps)
    t_on_safe = jnp.where(used, t_on, 0.0)
    comp_cost = jnp.sum(jnp.where(used, pp.cost_per_sec[None, :]
                                  * (lease - t_on_safe), 0.0), axis=1)
    pin_ok = jnp.all((pp.pinned[None, :] < 0) | (X == pp.pinned[None, :]),
                     axis=1)
    feasible = jnp.all(appc <= pp.deadline[None, :], axis=1) \
        & pin_ok & ~link_bad
    return comp_cost + trans_cost, feasible, jnp.sum(appc, axis=1)


def build_simulator(prob: SimProblem, faithful: bool = True):
    """Returns a jit-able ``sim(x) -> SimResult`` closed over static arrays.

    ``x``: (p,) int32 server assignment. vmap over a swarm:
    ``jax.vmap(sim)(X)`` with X (P, p). This is the zero-padding case of
    ``simulate_padded``.
    """
    pp = pad_problem(prob)
    return partial(simulate_padded, pp, faithful=faithful)
