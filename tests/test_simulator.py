"""Algorithm-2 simulator: numpy oracle vs JAX scan, invariants, fidelity
modes, and the paper's worked example (Fig. 2 / Tables I-III)."""
import numpy as np
import pytest
from hypo_compat import given, st

from repro.core import (Environment, SimProblem, build_simulator,
                        sample_environment, simulate_np)
from repro.core.dag import LayerDAG

# ---------------------------------------------------------------------------
# random problem generators
# ---------------------------------------------------------------------------


def random_dag(rng: np.random.Generator, p: int, n_apps: int = 1
               ) -> LayerDAG:
    """Random acyclic graph: edges only i -> j with i < j."""
    edges, mbs = [], []
    for j in range(1, p):
        n_par = rng.integers(1, min(j, 3) + 1)
        for u in rng.choice(j, size=n_par, replace=False):
            edges.append((int(u), j))
            mbs.append(float(rng.uniform(0.05, 2.0)))
    app = np.sort(rng.integers(0, n_apps, size=p)).astype(np.int32)
    pinned = np.full(p, -1, np.int32)
    return LayerDAG(compute=rng.uniform(0.1, 3.0, size=p),
                    edges=np.asarray(edges, np.int32).reshape(-1, 2),
                    edge_mb=np.asarray(mbs),
                    app_id=app,
                    deadline=rng.uniform(5.0, 50.0, size=n_apps),
                    pinned=pinned)


def random_env(rng: np.random.Generator, s: int) -> Environment:
    bw = rng.uniform(1.0, 20.0, size=(s, s))
    tier = rng.integers(0, 3, size=s).astype(np.int32)
    return Environment(power=rng.uniform(0.5, 16.0, size=s),
                       cost_per_sec=rng.uniform(0.0, 0.01, size=s),
                       tier=tier, bandwidth=bw,
                       tran_cost=rng.uniform(0.0, 1e-3, size=(s, s)))


# ---------------------------------------------------------------------------
# np == jax
# ---------------------------------------------------------------------------

@given(seed=st.integers(0, 10_000), p=st.integers(2, 24),
       s=st.integers(2, 8), faithful=st.booleans())
def test_np_matches_jax(seed, p, s, faithful):
    rng = np.random.default_rng(seed)
    dag = random_dag(rng, p)
    env = random_env(rng, s)
    prob = SimProblem.build(dag, env)
    x = rng.integers(0, s, size=p)
    ref = simulate_np(prob, x, faithful=faithful)
    sim = build_simulator(prob, faithful=faithful)
    out = sim(x)
    np.testing.assert_allclose(np.asarray(out.end_times), ref.end_times,
                               rtol=1e-5)
    np.testing.assert_allclose(float(out.total_cost), float(ref.total_cost),
                               rtol=1e-5)
    assert bool(out.feasible) == bool(ref.feasible)
    np.testing.assert_allclose(float(out.makespan), float(ref.makespan),
                               rtol=1e-5)


# ---------------------------------------------------------------------------
# invariants
# ---------------------------------------------------------------------------

@given(seed=st.integers(0, 10_000))
def test_makespan_lower_bounds(seed):
    rng = np.random.default_rng(seed)
    dag = random_dag(rng, int(rng.integers(3, 20)))
    env = random_env(rng, int(rng.integers(2, 6)))
    prob = SimProblem.build(dag, env)
    x = rng.integers(0, env.num_servers, size=dag.num_layers)
    res = simulate_np(prob, x, faithful=False)
    # makespan >= bottleneck-server serial compute
    for srv in range(env.num_servers):
        sel = x == srv
        if sel.any():
            assert float(res.makespan) >= \
                dag.compute[sel].sum() / env.power[srv] - 1e-9
    # cost >= pure transmission cost of crossing edges
    tx = sum(prob.tran_cost[x[u], x[v]] * mb
             for (u, v), mb in zip(dag.edges, dag.edge_mb))
    assert float(res.total_cost) >= tx - 1e-12


@given(seed=st.integers(0, 10_000))
def test_infeasible_iff_deadline_violated(seed):
    rng = np.random.default_rng(seed)
    dag = random_dag(rng, 8)
    env = random_env(rng, 4)
    prob = SimProblem.build(dag, env)
    x = rng.integers(0, 4, size=8)
    res = simulate_np(prob, x, faithful=False)
    violated = np.any(res.app_completion > dag.deadline)
    assert bool(res.feasible) == (not violated)


def test_single_server_chain_exact():
    """Chain on one server: makespan = sum of exec times; both modes agree
    (same-server transfers are free/instant)."""
    dag = LayerDAG(compute=np.array([1.0, 2.0, 3.0]),
                   edges=np.array([[0, 1], [1, 2]]),
                   edge_mb=np.array([1.0, 1.0]),
                   app_id=np.zeros(3, np.int32),
                   deadline=np.array([100.0]),
                   pinned=np.full(3, -1, np.int32))
    env = sample_environment()
    prob = SimProblem.build(dag, env)
    for faithful in (True, False):
        res = simulate_np(prob, np.array([3, 3, 3]), faithful=faithful)
        expect = 6.0 / env.power[3]
        np.testing.assert_allclose(float(res.makespan), expect, rtol=1e-9)


def test_forbidden_link_infeasible():
    """device -> device transfers (no ad-hoc) make a placement infeasible."""
    env = sample_environment()
    dag = LayerDAG(compute=np.array([1.0, 1.0]),
                   edges=np.array([[0, 1]]), edge_mb=np.array([1.0]),
                   app_id=np.zeros(2, np.int32), deadline=np.array([1e9]),
                   pinned=np.full(2, -1, np.int32))
    # extend env with a second device by reusing index 0 twice is not
    # possible; instead test edge->? all links exist in the sample env, so
    # fabricate a 2-device env:
    env2 = Environment(power=np.array([1.0, 1.0]),
                       cost_per_sec=np.zeros(2),
                       tier=np.array([2, 2], np.int32),
                       bandwidth=np.zeros((2, 2)),
                       tran_cost=np.zeros((2, 2)))
    prob = SimProblem.build(dag, env2)
    res = simulate_np(prob, np.array([0, 1]))
    assert not bool(res.feasible)
    res_same = simulate_np(prob, np.array([0, 0]))
    assert bool(res_same.feasible)


# ---------------------------------------------------------------------------
# the paper's worked example (Fig. 2)
# ---------------------------------------------------------------------------

@pytest.fixture
def fig2():
    env = sample_environment()
    dag = LayerDAG(
        compute=np.array([1.1, 1.92, 2.35, 2.12]) * env.power[0],
        edges=np.array([[0, 1], [0, 2], [1, 3], [2, 3]]),
        edge_mb=np.array([1.0, 1.0, 0.5, 0.5]),
        app_id=np.zeros(4, np.int32), deadline=np.array([3.7]),
        pinned=np.array([0, -1, -1, -1], np.int32))
    return dag, env


def test_fig2_greedy_matches_paper(fig2):
    """(0,1,2,1) completes ~3.65 s (paper Fig. 2(b))."""
    dag, env = fig2
    prob = SimProblem.build(dag, env)
    res = simulate_np(prob, np.array([0, 1, 2, 1]), faithful=False)
    assert 3.4 <= float(res.makespan) <= 3.8
    assert bool(res.feasible)


def test_fig2_optimal_matches_paper(fig2):
    """(0,1,2,3) completes ~3.41 s (paper Fig. 2(c)) and is feasible."""
    dag, env = fig2
    prob = SimProblem.build(dag, env)
    res = simulate_np(prob, np.array([0, 1, 2, 3]), faithful=False)
    assert 3.1 <= float(res.makespan) <= 3.6
    assert bool(res.feasible)


def test_fig2_property_examples(fig2):
    """(0,0,2,3) exceeds the 3.7 s deadline ('more than 4 s', §IV-B) and
    (0,0,1,1) is ~5 s — the paper's Property 3/4 examples."""
    dag, env = fig2
    prob = SimProblem.build(dag, env)
    r1 = simulate_np(prob, np.array([0, 0, 2, 3]), faithful=False)
    assert float(r1.makespan) > 3.7 and not bool(r1.feasible)
    r2 = simulate_np(prob, np.array([0, 0, 1, 1]), faithful=False)
    assert float(r2.makespan) > 4.5


def test_faithful_mode_drops_parent_gating(fig2):
    """The printed recurrence starts l3 before parents finish — strictly
    earlier makespan (the typo DESIGN.md §2 documents)."""
    dag, env = fig2
    prob = SimProblem.build(dag, env)
    x = np.array([0, 1, 2, 3])
    t_faithful = float(simulate_np(prob, x, faithful=True).makespan)
    t_gated = float(simulate_np(prob, x, faithful=False).makespan)
    assert t_faithful < t_gated
