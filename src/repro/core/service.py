"""Fault-tolerant always-on planning service (DESIGN.md §11).

``replan_fleet`` (DESIGN.md §9) is a batch loop: hand it a complete
drift trace, get back every round's plans. A deployed planner doesn't
get that luxury — it runs *forever*, ingests arrivals as they happen,
and its failure modes are the interesting part: the solver crashes, an
environment snapshot arrives NaN-poisoned, a node churns out between
solve and deploy, a solve stalls past the time-to-plan SLO. This module
wraps the PR-3/PR-4 machinery in the supervision layer that makes it
deployable:

  * **service loop** — ``run_service`` drives a fleet through an
    ``EnvTrace`` one round at a time, warm-starting from the surviving
    plans exactly like ``replan_fleet``; with every protection disabled
    its output is bit-identical to the batch loop (the parity invariant,
    tested in tests/test_service.py).
  * **streaming rate estimation** — with ``estimate_rates`` the service
    ignores the trace's ``load_scale`` and instead *observes* one
    arrival draw per round, slides it into a bounded window
    (``_RateWindow``), and solves against arrivals resampled at the
    estimated rate — the planner reacts to the workload it actually
    sees, not to a generator it was promised.
  * **solver watchdog** — an ``EwmaEstimator`` of per-iteration solve
    seconds converts the remaining SLO slack into an iteration budget;
    a budget below a rung's ``max_iters`` demotes the round down the
    ladder *before* the solve starts (cheaper than killing it mid-way,
    and it never retraces: rungs are two FIXED configs, not a per-round
    ``max_iters``, so the compiled-runner cache stays at two entries).
  * **graceful-degradation ladder** — warm PSO → short-burst PSO →
    HEFT → greedy → reject. Every rung's plan must pass ``_plan_ok``
    (static validity via ``plan_is_valid`` + finite simulated cost)
    under the environment it will actually run on before promotion;
    per-rung counts land in ``ServiceReport.fallback_counts``.
  * **admission control / deadline triage** — ``triage_margin`` rejects
    apps whose deadline not even a HEFT makespan-minimizing schedule
    could meet: their arrival slots are masked to +inf so they never
    poison the shared FCFS queues the admitted apps ride
    (DESIGN.md §10), instead of dragging every co-scheduled request
    over its deadline.
  * **plan cache** (phase 2) — with ``plan_cache`` set, rounds whose
    (DNN, env-bucket, load-bucket) key holds a stored plan that passes
    the replay-exact revalidation gate skip ``replan_round`` entirely
    and serve from cache (rung ``cached``); a hit is bit-identical to
    the plan a fresh warm-started solve would keep, and cached plans
    still walk the ladder's ``_plan_ok`` gate against the post-churn
    env, so node-loss invalidation composes (``core.plancache``).
  * **async request ingestion** (phase 2) — with ``ingest`` set, the
    rate estimator's arrival observations flow through a bounded
    ``ArrivalQueue`` (explicit backpressure counters) instead of
    synchronous per-round draws; ``threads=0`` is the deterministic
    single-thread mode (bit-identical to the synchronous path),
    ``threads>0`` pre-draws observations concurrently.
  * **multi-service sharing** (phase 2) — ``run_services`` runs N
    service loops concurrently against one thread-safe compiled-runner
    pool: ``runner_cache_stats()`` shows one trace per (cfg, bucket,
    mesh) across all of them, and an optional shared ``PlanCache``
    lets services reuse each other's solves.
  * **chaos harness** — ``ChaosConfig`` wires ``runtime.fault``'s
    ``FailureInjector`` and ``runtime.straggler``'s detector into the
    loop: injected solver crashes (retried with backoff, then circuit-
    broken), NaN env snapshots (rejected by ``_env_ok``, last-good env
    substituted), mid-round node loss (plans re-validated against the
    post-drift environment, invalid ones re-laddered), and solve stalls
    (flagged by the straggler detector, optionally treated as solver
    failures). The ``CircuitBreaker`` pins the last-good plans while
    open and half-open-probes its way back.

Everything is deterministic given the seed: injected failures fire at
configured rounds, backoff sleeps go through an injectable sleeper, and
the breaker runs on round numbers, not wall clocks.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import (Dict, List, Mapping, NamedTuple, Optional, Sequence,
                    Tuple, Union)

import numpy as np

from ..runtime.fault import (CircuitBreaker, FailureInjector,
                             SimulatedFailure, retry_with_backoff)
from ..runtime.straggler import EwmaEstimator, StragglerDetector
from .baselines import greedy_offload, heft_makespan
from .batch import run_pso_ga_batch, runner_cache_stats
from .dag import LayerDAG
from .environment import Environment
from .online import (EnvTrace, ReplanConfig, RoundLog, _round_arrivals,
                     plan_is_valid, replan_round)
from .plancache import PlanCache, PlanCacheConfig, dag_fingerprint
from .pso_ga import PSOGAConfig, PSOGAResult
from .simulator import SimProblem, simulate_np
from .telemetry import Telemetry, get_telemetry, maybe_span
from .traffic import ArrivalQueue, IngestConfig

__all__ = ["ChaosConfig", "ServiceConfig", "ServiceRoundLog",
           "ServiceReport", "run_service", "run_services", "LADDER_RUNGS"]

#: the graceful-degradation ladder, best rung first. ``cached`` serves a
#: stored plan that survived the replay-exact gate without solving;
#: ``pinned`` is the circuit-breaker rung (serve the last-good plan
#: without solving).
LADDER_RUNGS = ("cached", "warm", "burst", "pinned", "heft", "greedy",
                "reject")


@dataclasses.dataclass(frozen=True)
class ChaosConfig:
    """Deterministic fault injection for the service loop.

    ``crash_rounds`` / ``p_crash`` feed a ``FailureInjector`` whose
    ``maybe_fail`` runs at the top of every solve attempt — a configured
    round crashes the first attempt and (having fired) lets the retry
    through, while ``p_crash`` failures are persistent enough to exhaust
    retries and trip the breaker. ``nan_env_rounds`` poison the round's
    environment snapshot with NaN bandwidth before validation;
    ``stall_rounds`` add ``stall_s`` simulated seconds to the measured
    solve time (nothing actually sleeps); ``mid_round_down`` churns a
    server out AFTER the round's solve, so the freshly-accepted plans
    must survive re-validation against an environment they never saw.
    """
    crash_rounds: Tuple[int, ...] = ()
    p_crash: float = 0.0
    seed: int = 0
    max_crashes: int = 1_000_000
    nan_env_rounds: Tuple[int, ...] = ()
    stall_rounds: Tuple[int, ...] = ()
    stall_s: float = 30.0
    mid_round_down: Mapping[int, int] = dataclasses.field(
        default_factory=dict)

    def __post_init__(self):
        if not np.isfinite(self.p_crash) or not 0.0 <= self.p_crash <= 1.0:
            raise ValueError(f"p_crash must be in [0, 1], "
                             f"got {self.p_crash!r}")
        if not np.isfinite(self.stall_s) or self.stall_s < 0.0:
            raise ValueError(f"stall_s must be finite and >= 0, "
                             f"got {self.stall_s!r}")


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    """Knobs of the always-on planning service (DESIGN.md §11).

    The defaults disable every protection that could change plans —
    ``slo_s`` infinite (watchdog never cuts), ``triage_margin`` 0
    (admit everything), ``estimate_rates`` off (the solver sees the
    trace's own arrivals), no chaos — which is exactly the configuration
    under which ``run_service`` is bit-identical to ``replan_fleet``.
    """
    replan: ReplanConfig = ReplanConfig()
    #: the short-burst rung's solver. A FIXED config (not a per-round
    #: ``max_iters``) so the fleet-runner cache holds exactly two
    #: compiled programs, warm + burst, instead of one per budget.
    burst: PSOGAConfig = PSOGAConfig(pop_size=16, max_iters=24,
                                     stall_iters=12)
    slo_s: float = float("inf")     # per-round time-to-plan SLO (s)
    triage_margin: float = 0.0      # reject app if margin·HEFT > deadline
    estimate_rates: bool = False    # solve on observed, not configured, rates
    window_rounds: int = 4          # sliding observation window (rounds)
    retries: int = 2                # solve retries before giving up
    backoff_s: float = 0.0          # base backoff between retries
    breaker_threshold: int = 2      # consecutive failures to open
    breaker_cooldown: int = 2       # rounds the breaker stays open
    treat_stalls_as_failures: bool = False
    straggler_warmup: int = 2       # detector warmup (first rounds compile)
    chaos: Optional[ChaosConfig] = None
    #: phase 2: plan cache over (DNN, env-bucket, load-bucket) keys —
    #: None keeps every round solving (the parity configuration).
    plan_cache: Optional[PlanCacheConfig] = None
    #: phase 2: route rate observations through a bounded ArrivalQueue;
    #: requires ``estimate_rates`` (there is no stream to ingest
    #: otherwise). None keeps the legacy synchronous draws.
    ingest: Optional[IngestConfig] = None

    def __post_init__(self):
        if self.slo_s <= 0.0 or np.isnan(self.slo_s):
            raise ValueError(f"slo_s must be > 0, got {self.slo_s!r}")
        if self.ingest is not None and not self.estimate_rates:
            raise ValueError("ingest requires estimate_rates=True — "
                             "without rate estimation there is no "
                             "observation stream to ingest")
        if not np.isfinite(self.triage_margin) or self.triage_margin < 0.0:
            raise ValueError(f"triage_margin must be finite and >= 0, "
                             f"got {self.triage_margin!r}")
        if self.window_rounds < 1:
            raise ValueError(f"window_rounds must be >= 1, "
                             f"got {self.window_rounds!r}")
        if self.retries < 0:
            raise ValueError(f"retries must be >= 0, got {self.retries!r}")


class ServiceRoundLog(NamedTuple):
    """What the service decided for one round, per problem."""
    round: int
    label: str
    rung: Tuple[str, ...]        # ladder rung that served each problem
    wall_s: float                # measured time-to-plan (incl. injected stall)
    budget_iters: float          # watchdog's iteration budget (inf = no cap)
    breaker_state: str           # breaker state when the round started
    solver_failed: bool          # PSO rung crashed/stalled out this round
    retries_used: int            # extra solve attempts consumed
    stale_env: bool              # env snapshot rejected, last-good used
    stalled: bool                # straggler detector flagged the solve
    rejected_apps: int           # apps triaged out of the shared queues
    est_rates: Tuple[float, ...]  # per-DAG observed-rate estimates
                                  # (empty when estimation is off)
    replan: Optional[RoundLog]   # the PSO rung's log (None when skipped)
    cache_hit: bool = False      # every problem served from the plan cache


@dataclasses.dataclass
class ServiceReport:
    """Output of ``run_service``: per-round logs plus the counters the
    availability/SLO story is told from (EXPERIMENTS.md §Service)."""
    cold: List[PSOGAResult]
    rounds: List[ServiceRoundLog]
    plans: List[Optional[np.ndarray]]   # final per-problem plans
    fallback_counts: Dict[str, int]     # problem-rounds served per rung
    counters: Dict[str, int]
    #: plan-cache counters snapshot (None when the cache is off). With a
    #: shared cache the snapshot is taken at this service's exit, so it
    #: includes every sharer's traffic up to that point.
    cache_stats: Optional[Dict[str, int]] = None

    def availability(self) -> float:
        """Fraction of problem-rounds served a valid plan (any rung but
        ``reject``)."""
        total = sum(len(r.rung) for r in self.rounds)
        if total == 0:
            return 1.0
        served = sum(1 for r in self.rounds for g in r.rung
                     if g != "reject")
        return served / total

    def time_to_plan(self) -> Dict[str, float]:
        walls = np.array([r.wall_s for r in self.rounds], float)
        if walls.size == 0:
            return {"p50": 0.0, "p99": 0.0, "max": 0.0}
        return {"p50": float(np.percentile(walls, 50)),
                "p99": float(np.percentile(walls, 99)),
                "max": float(walls.max())}

    def summary(self) -> Dict[str, object]:
        out = {"rounds": len(self.rounds),
               "availability": self.availability(),
               "time_to_plan_s": self.time_to_plan(),
               "fallback_counts": dict(self.fallback_counts),
               "counters": dict(self.counters)}
        if self.cache_stats is not None:
            out["cache_stats"] = dict(self.cache_stats)
        return out


class _RateWindow:
    """Sliding window of observed per-round arrival draws: the
    streaming-ingestion half of the service (DESIGN.md §11). Each round
    contributes one ``(n_apps, R)`` timestamp array; the rate estimate
    is finite-count / (rounds · apps · horizon) over the window."""

    def __init__(self, window_rounds: int, horizon: float, n_apps: int):
        self._obs = collections.deque(maxlen=window_rounds)
        self._horizon = horizon
        self._n_apps = n_apps

    def ingest(self, arrivals: np.ndarray) -> None:
        self._obs.append(int(np.isfinite(arrivals).sum()))

    def rate(self) -> Optional[float]:
        """Estimated requests/s/app, None before the first observation."""
        if not self._obs:
            return None
        span = len(self._obs) * self._horizon * self._n_apps
        return sum(self._obs) / span


def _env_ok(env: Environment) -> bool:
    """A usable environment snapshot: finite positive power, no NaN
    anywhere a cost could flow from (DESIGN.md §11 — a NaN bandwidth
    becomes a NaN fitness key, and a NaN key freezes PSO's argmin).
    Bandwidth of +inf is legal (the self-link convention) and 0 is a
    severed link, so only NaN/negative entries disqualify it."""
    bw = np.asarray(env.bandwidth, float)
    return bool(np.all(np.isfinite(env.power)) and np.all(env.power > 0.0)
                and not np.any(np.isnan(bw)) and np.all(bw >= 0.0)
                and np.all(np.isfinite(env.cost_per_sec))
                and np.all(np.isfinite(env.tran_cost)))


def _poison_env(env: Environment) -> Environment:
    """The chaos harness's stale-snapshot fault: NaN bandwidth."""
    bw = np.asarray(env.bandwidth, float).copy()
    bw[0, -1] = np.nan
    return dataclasses.replace(env, bandwidth=bw)


def _down_env(env: Environment, server: int) -> Environment:
    """Sever every off-diagonal link of ``server`` (mid-round churn)."""
    s = env.num_servers
    bw = np.asarray(env.bandwidth, float).copy()
    off = ~np.eye(s, dtype=bool)
    dead = np.zeros(s, bool)
    dead[server] = True
    bw[(dead[:, None] | dead[None, :]) & off] = 0.0
    return dataclasses.replace(env, bandwidth=bw)


def _select_rung(budget_iters: float, warm_iters: int,
                 burst_iters: int) -> str:
    """The watchdog's rung choice: the best PSO rung whose iteration
    count fits the budget, else skip the solver entirely and pin
    (DESIGN.md §11). Budgets are compared against the rungs' FIXED
    ``max_iters`` — never a per-round cap, which would retrace the
    compiled fleet runner."""
    if budget_iters >= warm_iters:
        return "warm"
    if budget_iters >= burst_iters:
        return "burst"
    return "pinned"


def _plan_ok(prob: SimProblem, plan: Optional[np.ndarray]) -> bool:
    """The ladder's promotion gate: static validity (shape, genes in
    range, pins honored, every edge on a live link) plus a finite
    replayed cost. Deadline misses do NOT fail the gate — a late plan is
    a triage/fitness concern, not an invalid one."""
    if plan is None or not plan_is_valid(prob, plan):
        return False
    res = simulate_np(prob, np.asarray(plan, np.int64))
    return bool(np.isfinite(float(res.total_cost))
                and np.isfinite(float(res.makespan)))


def _triage(dags: Sequence[LayerDAG], probs: Sequence[SimProblem],
            env: Environment, margin: float,
            arrivals: Optional[List[np.ndarray]]
            ) -> Tuple[Optional[List[np.ndarray]], int]:
    """Deadline triage (DESIGN.md §11): an app whose deadline even a
    HEFT makespan-minimizing schedule cannot meet within ``margin`` is
    rejected — its arrival slots go to +inf so the shared FCFS queues
    only carry savable work. Returns (masked arrivals, rejected apps)."""
    if margin <= 0.0 or arrivals is None:
        return arrivals, 0
    rejected = 0
    masked: List[np.ndarray] = []
    for dag, prob, arr in zip(dags, probs, arrivals):
        _, x_h = heft_makespan(dag, env)
        comp = np.asarray(simulate_np(prob, x_h).app_completion, float)
        bad = margin * comp > np.asarray(dag.deadline, float)
        if bad.any():
            arr = np.asarray(arr, float).copy()
            arr[:, bad, :] = np.inf
            rejected += int(bad.sum())
        masked.append(arr)
    return masked, rejected


def _ladder_tail(dag: LayerDAG, prob: SimProblem, env: Environment,
                 faithful: bool) -> Tuple[str, Optional[np.ndarray]]:
    """HEFT → greedy → reject: the solver-free rungs, each validated
    before promotion (greedy's last-candidate fallback can emit a
    link-infeasible plan after node churn — the gate catches it)."""
    _, x_h = heft_makespan(dag, env)
    if _plan_ok(prob, x_h):
        return "heft", np.asarray(x_h, np.int32)
    g = greedy_offload(dag, env, faithful=faithful)
    x_g = np.asarray(g.best_x, np.int32)
    if _plan_ok(prob, x_g):
        return "greedy", x_g
    return "reject", None


def run_service(dags: Sequence[LayerDAG], trace: EnvTrace,
                cfg: ServiceConfig = ServiceConfig(),
                seed: int = 0,
                initial: Optional[Sequence[PSOGAResult]] = None,
                sleeper=None,
                plan_cache: Optional[PlanCache] = None,
                telemetry: Optional[Telemetry] = None,
                track: Optional[int] = None) -> ServiceReport:
    """Drive a fleet through a drift trace as a long-running service.

    Round 0 solves cold exactly like ``replan_fleet``; every later round
    runs the fault-tolerant pipeline: validate the env snapshot →
    estimate arrival rates (or reuse the trace's) → consult the plan
    cache (a full-fleet hit that survives the replay-exact gate serves
    immediately, rung ``cached``) → triage unsavable apps → pick a PSO
    rung within the watchdog's iteration budget → solve with retries
    under the circuit breaker → apply any mid-round churn → walk every
    problem down the ladder until a rung's plan passes ``_plan_ok`` →
    store freshly-solved plans back into the cache. Surviving plans are
    the next round's incumbents; a rejected problem re-enters cold (the
    stale-plan guard accepts ``None`` incumbents).

    With every protection at its default-off setting the loop IS
    ``replan_fleet`` step for step — same seeds, same arrivals, same
    accept-if-better — so plans match bit-for-bit (the parity test).
    ``sleeper`` is handed to ``retry_with_backoff`` (tests inject a
    recorder so chaos runs never block). ``plan_cache`` overrides
    ``cfg.plan_cache`` with a caller-owned (possibly shared) cache
    instance.

    ``telemetry`` (DESIGN.md §13) routes every round through the span
    tracer (round / cache_lookup / solve / ladder spans on the
    service's ``track``) and mirrors the ad-hoc counters onto the
    metrics registry under ``service.*``; all wall measurements come
    from its injectable clock (``time.perf_counter`` with telemetry
    off), so a fake clock makes every ``wall_s`` deterministic. Plans,
    seeds, and every ``ServiceReport`` field are bit-identical with
    telemetry on, off, or globally installed — telemetry observes, it
    never steers.
    """
    tel = telemetry if telemetry is not None else get_telemetry()
    clock = tel.clock if tel is not None else time.perf_counter
    if tel is not None and track is not None:
        tel.set_track(track, label=f"service-{track}")

    def _bump(name: str, n: int = 1) -> None:
        counters[name] += n
        if tel is not None and n:
            tel.inc(f"service.{name}", n)

    rcfg = cfg.replan
    burst_rcfg = dataclasses.replace(rcfg, pso=cfg.burst)
    cache = plan_cache
    if cache is None and cfg.plan_cache is not None:
        cache = PlanCache(cfg.plan_cache, telemetry=tel)
    fps = [dag_fingerprint(d) for d in dags] if cache is not None else None
    injector = None
    if cfg.chaos is not None and (cfg.chaos.crash_rounds
                                  or cfg.chaos.p_crash > 0.0):
        injector = FailureInjector(p_fail=cfg.chaos.p_crash,
                                   seed=cfg.chaos.seed,
                                   fail_at=tuple(cfg.chaos.crash_rounds),
                                   max_failures=cfg.chaos.max_crashes)
    breaker = CircuitBreaker(threshold=cfg.breaker_threshold,
                             cooldown=cfg.breaker_cooldown)
    detector = StragglerDetector(warmup=cfg.straggler_warmup)
    per_iter = EwmaEstimator()
    windows: Optional[List[_RateWindow]] = None
    if cfg.estimate_rates and rcfg.traffic is not None:
        windows = [_RateWindow(cfg.window_rounds, rcfg.traffic.horizon,
                               d.num_apps) for d in dags]

    def _observe(k: int, i: int) -> Tuple[int, int, np.ndarray]:
        """One (round, dag, timestamps) arrival observation — the exact
        draw the synchronous estimate_rates path makes in-loop, so the
        deterministic ingestion mode is bit-identical to it."""
        tc = rcfg.traffic
        obs = tc.solver_arrivals(
            dags[i].num_apps, seed=seed + 7919 * k + 31 * i,
            rate_scale=trace.events[k].load_scale)[0]
        return (k, i, obs)

    # async ingestion (phase 2): observations ride a bounded queue. With
    # threads=0 the round loop enqueues its own round synchronously —
    # deterministic and bit-identical to the legacy path; with threads>0
    # producers pre-draw future rounds' observations concurrently.
    queue: Optional[ArrivalQueue] = None
    producers: List[threading.Thread] = []
    stop = threading.Event()
    if cfg.ingest is not None:
        if windows is None:
            raise ValueError("ingest requires a traffic model "
                             "(cfg.replan.traffic) to observe")
        queue = ArrivalQueue(cfg.ingest.capacity, telemetry=tel)

        def _produce(idxs: List[int]) -> None:
            for kk in range(1, trace.num_rounds):
                for ii in idxs:
                    if stop.is_set():
                        return
                    queue.put(_observe(kk, ii))

        n_threads = min(int(cfg.ingest.threads), len(dags))
        for t in range(n_threads):
            th = threading.Thread(
                target=_produce, args=(list(range(t, len(dags),
                                                  n_threads)),),
                daemon=True)
            producers.append(th)
            th.start()

    # the counters schema is STABLE: every key is present from round 0
    # (ingest_* stay 0 without async ingestion) so downstream consumers
    # never need existence checks.
    counters = {"retries": 0, "crashes": 0, "stale_env_rounds": 0,
                "stalls_flagged": 0, "breaker_opened": 0,
                "watchdog_cuts": 0, "rejected_apps": 0, "demotions": 0,
                "ingest_enqueued": 0, "ingest_dropped": 0,
                "ingest_drained": 0, "ingest_leftover": 0}
    fallback_counts = {r: 0 for r in LADDER_RUNGS}

    # round 0: the cold solve, exactly replan_fleet's (or admission-time
    # plans handed in, e.g. from plan_offload_batch).
    env0 = trace.env_at(0)
    if initial is None:
        probs0 = [SimProblem.build(d, env0) for d in dags]
        with maybe_span(tel, "cold_solve", n=len(dags)):
            cold = run_pso_ga_batch(
                probs0, rcfg.pso, seed=seed,
                arrivals=_round_arrivals(rcfg, dags, trace.events[0],
                                         seed),
                mesh=rcfg.mesh, telemetry=tel)
    else:
        if len(initial) != len(dags):
            raise ValueError(f"{len(initial)} initial results for "
                             f"{len(dags)} dags")
        cold = list(initial)
    plans: List[Optional[np.ndarray]] = [
        np.asarray(r.best_x, np.int32) for r in cold]
    last_good_env = env0
    rounds: List[ServiceRoundLog] = []

    for k in range(1, trace.num_rounds):
      ev = trace.events[k]
      with maybe_span(tel, "round", round=k, label=ev.label):
        env_k = trace.env_at(k)
        if cfg.chaos is not None and k in cfg.chaos.nan_env_rounds:
            env_k = _poison_env(env_k)
        stale_env = not _env_ok(env_k)
        if stale_env:
            _bump("stale_env_rounds")
            if tel is not None:
                tel.instant("stale_env", round=k)
            env_k = last_good_env
        else:
            last_good_env = env_k
        probs = [SimProblem.build(d, env_k) for d in dags]

        # rate estimation: ingest this round's observations — via the
        # bounded queue when async ingestion is on, else the legacy
        # synchronous draws — and slide them into the per-DAG windows
        # (the solver never sees the trace's load_scale).
        est_rates: Tuple[float, ...] = ()
        if windows is not None:
          with maybe_span(tel, "ingest", round=k):
            tc = rcfg.traffic
            if queue is not None:
                if not producers:   # deterministic single-thread mode
                    for i in range(len(dags)):
                        queue.put(_observe(k, i))
                for _, i, obs in queue.drain():
                    windows[i].ingest(obs)
            else:
                for i in range(len(dags)):
                    windows[i].ingest(_observe(k, i)[2])
            ests = [windows[i].rate() for i in range(len(dags))]
            est_rates = tuple(
                tc.rate if e is None else float(e) for e in ests)
            if tel is not None:
                for e in est_rates:
                    tel.observe("service.est_rate", e)

        # plan cache: a full-fleet hit that survives the replay-exact
        # gate serves instantly and skips triage/watchdog/solve.
        cache_hit = False
        keys_k: Optional[List[tuple]] = None
        cached_plans: Optional[List[np.ndarray]] = None
        cache_wall = 0.0
        if cache is not None:
          with maybe_span(tel, "cache_lookup", round=k):
            t_c = clock()
            if windows is not None:
                scales = [max(e / rcfg.traffic.rate, 1e-6)
                          for e in est_rates]
            elif rcfg.traffic is not None:
                scales = [max(float(ev.load_scale), 1e-6)] * len(dags)
            else:
                scales = [1.0] * len(dags)
            keys_k = [cache.key(fps[i], env_k, scales[i])
                      for i in range(len(dags))]
            cached_plans = cache.lookup_fleet(keys_k, probs)
            cache_hit = cached_plans is not None
            cache_wall = clock() - t_c
          if tel is not None:
            tel.instant("cache_hit" if cache_hit else "cache_miss",
                        round=k)
            tel.observe("service.cache_lookup_s", cache_wall)

        rejected = 0
        arrivals = None
        if not cache_hit:
            if windows is not None:
                tc = rcfg.traffic
                arrivals = [tc.solver_arrivals(
                    dags[i].num_apps, seed=seed + 1000 * k + 31 * i,
                    rate_scale=max(est_rates[i] / tc.rate, 1e-6))
                    for i in range(len(dags))]
            else:
                arrivals = _round_arrivals(rcfg, dags, ev,
                                           seed + 1000 * k)
            arrivals, rejected = _triage(dags, probs, env_k,
                                         cfg.triage_margin, arrivals)
        _bump("rejected_apps", rejected)
        if tel is not None and rejected:
            tel.instant("triage_reject", round=k, apps=rejected)

        # watchdog: remaining SLO slack → iteration budget → rung.
        # (iter_est, NOT the rate estimate: per-iteration solve seconds.)
        iter_est = per_iter.value
        budget = float("inf") \
            if iter_est is None or not np.isfinite(cfg.slo_s) \
            else cfg.slo_s / max(iter_est, 1e-12)
        breaker_state = breaker.state
        want: Optional[ReplanConfig] = None
        if cache_hit:
            rung0 = "cached"
        else:
            rung0 = _select_rung(budget, rcfg.pso.max_iters,
                                 cfg.burst.max_iters)
            want = {"warm": rcfg, "burst": burst_rcfg,
                    "pinned": None}[rung0]
            if rung0 != "warm":
                _bump("watchdog_cuts")
                if tel is not None:
                    tel.instant("watchdog_cut", round=k, rung=rung0,
                                budget_iters=min(budget, 1e18))
            if not breaker.allow(k):
                want, rung0 = None, "pinned"
                if tel is not None:
                    tel.instant("breaker_pinned", round=k)

        solver_failed = False
        retries_used = 0
        rlog: Optional[RoundLog] = None
        new_plans: Optional[List[np.ndarray]] = cached_plans
        t0 = clock()
        if want is not None:
            def attempt(a: int, _want=want):
                nonlocal retries_used
                retries_used = a
                if injector is not None:
                    injector.maybe_fail(k)
                return replan_round(probs, plans, _want, seed=seed + k,
                                    round_no=k, label=ev.label,
                                    arrivals=arrivals, telemetry=tel)
            try:
                with maybe_span(tel, "solve", round=k, rung=rung0):
                    new_plans, rlog = retry_with_backoff(
                        attempt, retries=cfg.retries,
                        backoff_s=cfg.backoff_s, sleeper=sleeper)
            except SimulatedFailure:
                solver_failed = True
                _bump("crashes")
                if tel is not None:
                    tel.instant("solver_crash", round=k,
                                retries=retries_used)
            _bump("retries", retries_used)
        wall = clock() - t0
        if cache_hit:
            # time-to-plan for a cached round is the lookup+revalidation
            # time; injected solver stalls can't stall a skipped solve.
            wall = cache_wall
        elif cfg.chaos is not None and k in cfg.chaos.stall_rounds:
            wall += cfg.chaos.stall_s
        if tel is not None:
            tel.observe("service.round_wall_s", wall)
        stalled = False
        if want is not None:
            stalled = detector.update(wall)
            if stalled:
                _bump("stalls_flagged")
                if tel is not None:
                    tel.instant("stall_flagged", round=k, wall_s=wall)
                if cfg.treat_stalls_as_failures:
                    solver_failed = True
                    new_plans, rlog = None, None
        if want is not None and not solver_failed:
            breaker.record_success()
            if rlog is not None:
                it_max = int(np.max(rlog.iterations, initial=1))
                per_iter.update(wall / max(it_max, 1))
            _bump("demotions", int(np.sum(rlog.demoted))
                  if rlog is not None else 0)
        elif want is not None:
            opened = breaker.opened
            breaker.record_failure(k)
            _bump("breaker_opened", breaker.opened - opened)
            if tel is not None and breaker.opened > opened:
                tel.instant("breaker_opened", round=k)

        # mid-round churn: the environment the plans must RUN on.
        probs_post, env_post = probs, env_k
        if cfg.chaos is not None and k in cfg.chaos.mid_round_down:
            env_post = _down_env(env_k, cfg.chaos.mid_round_down[k])
            probs_post = [SimProblem.build(d, env_post) for d in dags]
            if tel is not None:
                tel.instant("mid_round_down", round=k,
                            server=cfg.chaos.mid_round_down[k])

        # the ladder: promote each problem's best available plan.
        rung: List[str] = []
        with maybe_span(tel, "ladder", round=k):
            for i, (d, pr) in enumerate(zip(dags, probs_post)):
                if new_plans is not None:
                    cand, r_i = new_plans[i], rung0
                else:
                    cand, r_i = plans[i], "pinned"
                if _plan_ok(pr, cand):
                    plans[i] = np.asarray(cand, np.int32)
                else:
                    r_i, cand = _ladder_tail(d, pr, env_post,
                                             rcfg.pso.faithful_sim)
                    plans[i] = cand
                    if tel is not None:
                        tel.instant("ladder_demote", round=k,
                                    problem=i, rung=r_i)
                rung.append(r_i)
                fallback_counts[r_i] += 1
                if tel is not None:
                    tel.inc(f"service.rung.{r_i}")

        # store freshly-solved plans for repeat scenarios: only solver
        # rungs (accepted under env_k with their replay invariants) and
        # only when no mid-round churn separated solve-env from
        # serve-env — a post-churn plan belongs to an env the key never
        # saw.
        if (cache is not None and not cache_hit
                and env_post is env_k):
            for i, r_i in enumerate(rung):
                if r_i in ("warm", "burst") and plans[i] is not None:
                    cache.store(keys_k[i], probs[i], plans[i])

        if tel is not None:
            tel.set_gauge("service.breaker_open",
                          0.0 if breaker_state == "closed" else 1.0)
        rounds.append(ServiceRoundLog(
            round=k, label=ev.label, rung=tuple(rung), wall_s=wall,
            budget_iters=budget, breaker_state=breaker_state,
            solver_failed=solver_failed, retries_used=retries_used,
            stale_env=stale_env, stalled=stalled,
            rejected_apps=rejected, est_rates=est_rates,
            replan=rlog, cache_hit=cache_hit))

    if producers:
        stop.set()
        for th in producers:
            th.join()
    if queue is not None:
        qc = queue.counters()
        counters["ingest_enqueued"] = qc["enqueued"]
        counters["ingest_dropped"] = qc["dropped"]
        counters["ingest_drained"] = qc["drained"]
        counters["ingest_leftover"] = qc["depth"]

    if tel is not None:
        # final snapshot stamps: the service.* counters were kept in
        # sync live by _bump (except the ingest_* totals, owned by the
        # queue and finalized just above); plancache.* counters catch up
        # to ``cache.stats()`` — a no-op for a cache this service built
        # (live-mirrored), the missing delta for a shared external cache
        # constructed without telemetry; runner-cache totals land as
        # gauges (its per-lookup counters are runner_cache.lookup_*), so
        # ONE export carries everything the report does (DESIGN.md §13).
        for nm in ("ingest_enqueued", "ingest_dropped",
                   "ingest_drained", "ingest_leftover"):
            c = tel.registry.counter(f"service.{nm}")
            c.inc(counters[nm] - c.value)
        if cache is not None:
            for nm, v in cache.stats().items():
                c = tel.registry.counter(f"plancache.{nm}")
                c.inc(max(0, v - c.value))
        for nm, v in runner_cache_stats().items():
            tel.set_gauge(f"runner_cache.{nm}", v)

    return ServiceReport(cold=cold, rounds=rounds, plans=plans,
                         fallback_counts=fallback_counts,
                         counters=counters,
                         cache_stats=cache.stats() if cache is not None
                         else None)


def run_services(fleets: Sequence[Sequence[LayerDAG]],
                 traces: Union[EnvTrace, Sequence[EnvTrace]],
                 cfgs: Union[ServiceConfig, Sequence[ServiceConfig],
                             None] = None,
                 seeds: Union[int, Sequence[int]] = 0,
                 plan_cache: Optional[PlanCache] = None,
                 max_workers: Optional[int] = None,
                 telemetry: Optional[Telemetry] = None
                 ) -> List[ServiceReport]:
    """Run N planning services concurrently against one runner pool.

    Each fleet gets its own ``run_service`` loop on its own thread; all
    of them dispatch into the shared compiled-runner cache, whose lock +
    first-call serialization guarantee one trace per (cfg, bucket, mesh)
    across services (DESIGN.md §11 phase 2) — and, since each loop's
    solves are seeded and self-contained, every service's report is
    bit-identical to running it alone. ``traces`` / ``cfgs`` / ``seeds``
    broadcast: pass one value for all services or a sequence of
    ``len(fleets)``. An optional shared ``plan_cache`` lets services
    reuse each other's solves (its stats then aggregate all of them).
    A shared ``telemetry`` (DESIGN.md §13) gives service ``j`` its own
    Perfetto track (tid ``j``, labeled ``service-j``): the registry and
    tracer are thread-safe, so the N loops interleave into one timeline.
    """
    n = len(fleets)
    if n == 0:
        return []

    def _bcast(x, name):
        if isinstance(x, (list, tuple)):
            if len(x) != n:
                raise ValueError(f"{len(x)} {name} for {n} fleets")
            return list(x)
        return [x] * n

    traces_l = _bcast(traces, "traces")
    cfgs_l = _bcast(cfgs if cfgs is not None else ServiceConfig(),
                    "configs")
    seeds_l = _bcast(seeds, "seeds")
    with ThreadPoolExecutor(max_workers=max_workers or n) as ex:
        futs = [ex.submit(run_service, fleets[j], traces_l[j],
                          cfgs_l[j], seed=seeds_l[j],
                          plan_cache=plan_cache, telemetry=telemetry,
                          track=j if telemetry is not None else None)
                for j in range(n)]
        return [f.result() for f in futs]
