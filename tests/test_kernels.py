"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs pure-jnp
oracle, per the deliverable contract."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

SEED = np.random.default_rng(42)


def _mk(shape, dtype):
    x = SEED.standard_normal(shape).astype(np.float32)
    return jnp.asarray(x, dtype)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,s,kh,g,hd", [
    (1, 64, 1, 1, 64),       # minimal
    (2, 128, 2, 2, 64),      # GQA
    (1, 300, 1, 4, 64),      # non-multiple seq (padding path)
    (2, 257, 2, 1, 128),     # odd seq, wide head
    (1, 512, 4, 2, 64),      # multi-tile
])
@pytest.mark.parametrize("window", [0, 64])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(b, s, kh, g, hd, window, dtype):
    q = _mk((b, s, kh, g, hd), dtype)
    k = _mk((b, s, kh, hd), dtype)
    v = _mk((b, s, kh, hd), dtype)
    out = ops.flash_attention(q, k, v, causal=True, window=window)
    want = ref.flash_attention_ref(q, k, v, causal=True, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


def test_flash_attention_matches_full_softmax_row():
    """First row attends only to itself: output == v[0]."""
    q = _mk((1, 8, 1, 1, 64), jnp.float32)
    k = _mk((1, 8, 1, 64), jnp.float32)
    v = _mk((1, 8, 1, 64), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out[0, 0, 0, 0]),
                               np.asarray(v[0, 0, 0]), atol=1e-5)


# ---------------------------------------------------------------------------
# SSD intra-chunk
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,c,q,h,p,n", [
    (1, 1, 16, 1, 8, 4),
    (1, 2, 64, 2, 32, 16),
    (2, 3, 37, 1, 16, 8),        # ragged q
    (1, 1, 128, 4, 64, 128),     # production-ish tile
])
def test_ssd_intra_sweep(b, c, q, h, p, n):
    rng = np.random.default_rng(b * 100 + q)
    xc = rng.standard_normal((b, c, q, h, p)).astype(np.float32)
    la = -np.abs(rng.standard_normal((b, c, q, h))).astype(np.float32) * 0.1
    cum = np.cumsum(la, axis=2)
    B = rng.standard_normal((b, c, q, n)).astype(np.float32)
    C = rng.standard_normal((b, c, q, n)).astype(np.float32)
    out = ops.ssd_intra(xc, cum, B, C)
    want = ref.ssd_intra_ref(xc, cum, B, C)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=1e-4, rtol=1e-4)


def test_ssd_intra_is_causal():
    """Changing future inputs must not change past outputs."""
    rng = np.random.default_rng(7)
    xc = rng.standard_normal((1, 1, 32, 1, 8)).astype(np.float32)
    cum = np.cumsum(-np.abs(rng.standard_normal((1, 1, 32, 1))) * 0.1,
                    axis=2).astype(np.float32)
    B = rng.standard_normal((1, 1, 32, 4)).astype(np.float32)
    C = rng.standard_normal((1, 1, 32, 4)).astype(np.float32)
    out1 = np.asarray(ops.ssd_intra(xc, cum, B, C))
    xc2 = xc.copy()
    xc2[:, :, 20:] += 5.0
    out2 = np.asarray(ops.ssd_intra(xc2, cum, B, C))
    np.testing.assert_allclose(out1[:, :, :20], out2[:, :, :20], atol=1e-5)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,c,kh,g,hd,valid", [
    (1, 64, 1, 1, 64, 64),
    (2, 256, 2, 4, 64, 100),
    (1, 2048, 4, 1, 128, 2048),
    (2, 100, 1, 8, 64, 1),          # single valid slot
    (1, 1000, 2, 2, 64, 999),       # ragged cache
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_sweep(b, c, kh, g, hd, valid, dtype):
    q = _mk((b, kh, g, hd), dtype)
    k = _mk((b, c, kh, hd), dtype)
    v = _mk((b, c, kh, hd), dtype)
    out = ops.decode_attention(q, k, v, jnp.asarray(valid, jnp.int32))
    want = ref.decode_attention_ref(q, k, v, jnp.asarray(valid, jnp.int32))
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


def test_decode_attention_ignores_dead_slots():
    """Garbage beyond valid_len must not affect the output."""
    q = _mk((1, 1, 2, 64), jnp.float32)
    k = _mk((1, 128, 1, 64), jnp.float32)
    v = _mk((1, 128, 1, 64), jnp.float32)
    out1 = np.asarray(ops.decode_attention(q, k, v,
                                           jnp.asarray(50, jnp.int32)))
    k2 = k.at[:, 50:].set(1e9)
    v2 = v.at[:, 50:].set(-1e9)
    out2 = np.asarray(ops.decode_attention(q, k2, v2,
                                           jnp.asarray(50, jnp.int32)))
    np.testing.assert_allclose(out1, out2, atol=1e-6)
