"""Placement bridge: arch -> DAG lowering, fleet planning, partitioning."""
import numpy as np
import pytest

from repro.configs import SHAPES, get, names
from repro.core import (PSOGAConfig, arch_to_dag, block_flops,
                        plan_offload, stage_cut_cost,
                        tpu_fleet_environment, uniform_stages)
from repro.core.dag import topological_order

PREFILL = SHAPES[1]
FAST = PSOGAConfig(pop_size=32, max_iters=120, stall_iters=30)


@pytest.mark.parametrize("arch", list(names()))
def test_arch_to_dag_structure(arch):
    cfg = get(arch)
    dag = arch_to_dag(cfg, PREFILL)
    dag.validate_acyclic()
    assert dag.pinned[0] >= 0                      # input pinned (paper)
    assert np.all(dag.compute >= 0)
    assert dag.edge_mb.min() > 0
    if cfg.family == "encdec":
        # cross-attention fan-out: encoder output feeds every decoder block
        out_deg = dag.out_degree()
        assert out_deg.max() >= cfg.dec_layers
    else:
        n_expected = {"dense": cfg.n_layers + 2, "moe": cfg.n_layers + 2,
                      "ssm": cfg.n_layers + 2,
                      "vlm": cfg.n_layers + 3}.get(cfg.family)
        if cfg.family == "hybrid":
            n_expected = (cfg.n_layers
                          + cfg.n_layers // cfg.hybrid_attn_every + 2)
        assert dag.num_layers == n_expected


def test_block_flops_scales_with_seq():
    cfg = get("qwen3-0.6b")
    f1 = block_flops(cfg, 1024)
    f2 = block_flops(cfg, 2048)
    assert 1.9 < f2 / f1 < 4.1          # linear proj + quadratic attn


def test_plan_offload_feasible_and_contiguous():
    env = tpu_fleet_environment()
    plan = plan_offload(get("qwen3-0.6b"), PREFILL, env=env,
                        deadline_ratio=2.0, pso=FAST, seed=0)
    assert plan.result.feasible
    # stages partition the layer set exactly
    covered = np.concatenate([s.layers for s in plan.stages])
    assert sorted(covered.tolist()) == list(range(plan.dag.num_layers))
    # stages follow the topological order
    order = topological_order(plan.dag)
    pos = {int(j): i for i, j in enumerate(order)}
    flat = [pos[int(j)] for s in plan.stages for j in s.layers]
    assert flat == sorted(flat)
    assert "stage[" in plan.summary()


def test_psoga_beats_greedy_on_encdec_fleet():
    """The branching whisper DAG is where global optimization pays
    (paper's core claim, on the TPU fleet instantiation)."""
    env = tpu_fleet_environment()
    pso = plan_offload(get("whisper-medium"), PREFILL, env=env,
                       deadline_ratio=1.5, pso=FAST, seed=0)
    grd = plan_offload(get("whisper-medium"), PREFILL, env=env,
                       deadline_ratio=1.5, algo="greedy")
    assert pso.result.feasible
    if grd.result.feasible:
        assert pso.cost <= grd.cost + 1e-9


def test_uniform_stage_baseline_and_cost():
    env = tpu_fleet_environment()
    dag = arch_to_dag(get("qwen3-0.6b"), PREFILL)
    servers = [0, 1, 2]
    x = uniform_stages(dag, servers)
    assert set(np.unique(x)) <= set(servers)
    stats = stage_cut_cost(dag, env, x)
    assert stats["n_stages"] == len(servers)
    assert stats["cross_mb"] > 0
    # single-server placement: no crossing traffic
    x0 = np.zeros(dag.num_layers, np.int64)
    s0 = stage_cut_cost(dag, env, x0)
    assert s0["cross_mb"] == 0 and s0["n_stages"] == 1


def test_tight_deadline_forces_offload():
    """With a tight SLO the plan cannot stay on the (slow) device."""
    env = tpu_fleet_environment()
    plan = plan_offload(get("gemma-7b"), PREFILL, env=env,
                        deadline_ratio=1.2, pso=FAST, seed=0)
    assert plan.result.feasible
    tiers = {int(env.tier[s.server]) for s in plan.stages}
    assert tiers - {2}, "expected at least one non-device stage"
