"""Pallas TPU kernel for the intra-chunk SSD quadratic form (Mamba2).

One grid cell = one (sequence-chunk, SSM-head) pair. The chunk length Q
(cfg.ssm_chunk, default 256) and state width N (<=128) are sized so the
whole working set lives in VMEM:

    scores (Q,Q) fp32          256 KB
    decay  (Q,Q) fp32          256 KB
    B/C    (Q,N) fp32        2x128 KB
    x/out  (Q,P) fp32        2x 64 KB        (P = ssm_head_dim, 64)

and both contractions hit the MXU: (Q,N)x(N,Q) then (Q,Q)x(Q,P).
The cross-chunk recurrence (a short scan over C chunks carrying the
(H,P,N) state) stays in XLA — it is O(C) tiny steps and fuses fine; the
quadratic intra-chunk term is where the FLOPs are.

Numerics: `cum` is the inclusive cumsum of log-decay (<= 0, monotone
non-increasing within a chunk), so exp(cum_i - cum_j) for j <= i is in
(0, 1] — no overflow; masked entries are exact zeros.

Validated in interpret mode against ``ref.ssd_intra_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["ssd_intra_folded"]


def _ssd_kernel(x_ref, cum_ref, b_ref, c_ref, o_ref, *, q: int):
    xc = x_ref[0, :, 0, :]                       # (Q, P) fp32
    cum = cum_ref[0, :, 0]                       # (Q,)
    B = b_ref[0]                                 # (Q, N)
    C = c_ref[0]                                 # (Q, N)
    scores = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    li = cum[:, None]
    lj = cum[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    L = jnp.where(jj <= ii, jnp.exp(li - lj), 0.0)
    w = scores * L                               # (Q, Q)
    out = jax.lax.dot_general(w, xc, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    o_ref[0, :, 0, :] = out


def ssd_intra_folded(xc: jnp.ndarray, cum: jnp.ndarray, Bc: jnp.ndarray,
                     Cc: jnp.ndarray, *, interpret: bool = True
                     ) -> jnp.ndarray:
    """xc: (BC, Q, H, P) fp32; cum: (BC, Q, H); Bc/Cc: (BC, Q, N)
    -> (BC, Q, H, P). BC = batch x chunks (folded by ops.py)."""
    bc, q, h, p = xc.shape
    n = Bc.shape[-1]
    grid = (bc, h)
    kernel = functools.partial(_ssd_kernel, q=q)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, q, 1, p), lambda b, hh: (b, 0, hh, 0)),
            pl.BlockSpec((1, q, 1), lambda b, hh: (b, 0, hh)),
            pl.BlockSpec((1, q, n), lambda b, hh: (b, 0, 0)),
            pl.BlockSpec((1, q, n), lambda b, hh: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, q, 1, p), lambda b, hh: (b, 0, hh, 0)),
        out_shape=jax.ShapeDtypeStruct((bc, q, h, p), jnp.float32),
        interpret=interpret,
    )(xc, cum, Bc, Cc)
