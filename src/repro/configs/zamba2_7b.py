"""zamba2-7b — Mamba2 backbone + shared attn blocks. [arXiv:2411.15242; unverified]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b", family="hybrid", n_layers=81, d_model=3584,
    n_heads=32, n_kv_heads=32, head_dim=112, d_ff=14336, vocab=32_000,
    act="swiglu", ssm_state=64, ssm_head_dim=64, ssm_expand=2,
    hybrid_attn_every=6)
