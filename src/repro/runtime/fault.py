"""Fault injection + checkpoint-restart supervision + circuit breaking.

At thousand-node scale the MTBF of the *job* is hours even when each node
is months; the only viable posture is: checkpoint often, detect fast,
restart from latest. ``run_with_restarts`` is the single-controller
supervisor loop: it runs ``body(start_step)`` and, on a (simulated or
real) failure, restores from the latest checkpoint and re-enters.

``FailureInjector`` raises ``SimulatedFailure`` with probability
``p_fail`` per step (deterministic in seed — tests inject at exact steps
with ``fail_at``). Real deployments plug hardware signals in instead;
everything downstream is identical.

The always-on planning service (DESIGN.md §11) adds two more supervision
primitives on the same philosophy — detect fast, degrade instead of
dying:

  * ``retry_with_backoff`` — bounded retries of a flaky callable with
    exponential backoff (the sleeper is injectable so tests never
    actually sleep).
  * ``CircuitBreaker`` — after ``threshold`` consecutive failures the
    breaker *opens*: callers skip the failing dependency (the service
    pins its last-good plan) until ``cooldown`` rounds pass, then one
    half-open probe decides between closing and re-opening.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence, TypeVar

import numpy as np

__all__ = ["SimulatedFailure", "FailureInjector", "run_with_restarts",
           "retry_with_backoff", "CircuitBreaker"]

_T = TypeVar("_T")


class SimulatedFailure(RuntimeError):
    pass


@dataclasses.dataclass
class FailureInjector:
    p_fail: float = 0.0
    seed: int = 0
    fail_at: Sequence[int] = ()          # deterministic injection points
    max_failures: int = 1_000_000

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)
        self._failures = 0
        self._fired = set()

    def maybe_fail(self, step: int) -> None:
        if self._failures >= self.max_failures:
            return
        if step in self.fail_at and step not in self._fired:
            self._fired.add(step)
            self._failures += 1
            raise SimulatedFailure(f"injected failure at step {step}")
        if self.p_fail and self._rng.random() < self.p_fail:
            self._failures += 1
            raise SimulatedFailure(f"random failure at step {step}")


def run_with_restarts(body: Callable[[int], int],
                      latest_step: Callable[[], Optional[int]],
                      max_restarts: int = 10) -> int:
    """Supervise ``body(start_step) -> final_step``.

    ``latest_step()`` queries the checkpoint manager. On failure the body
    re-enters from ``latest + 1`` (or 0). Returns the final step. Raises
    after ``max_restarts`` consecutive failures (crash-looping guard).
    """
    restarts = 0
    while True:
        start = latest_step()
        start = 0 if start is None else start + 1
        try:
            return body(start)
        except SimulatedFailure:
            restarts += 1
            if restarts > max_restarts:
                raise


def retry_with_backoff(fn: Callable[[int], _T], retries: int = 2,
                       backoff_s: float = 0.0,
                       sleeper: Optional[Callable[[float], None]] = None,
                       exceptions: tuple = (SimulatedFailure,)) -> _T:
    """Call ``fn(attempt)`` up to ``1 + retries`` times.

    Between attempts sleeps ``backoff_s · 2^attempt`` seconds via
    ``sleeper`` (``time.sleep`` by default; tests inject a recorder so
    nothing actually blocks — and a ``backoff_s`` of 0 never sleeps at
    all). Only ``exceptions`` are retried; anything else propagates
    immediately. Re-raises the last failure when every attempt fails.
    """
    import time as _time
    sleep = _time.sleep if sleeper is None else sleeper
    err: Optional[BaseException] = None
    for attempt in range(1 + max(0, retries)):
        if attempt and backoff_s > 0.0:
            sleep(backoff_s * (2.0 ** (attempt - 1)))
        try:
            return fn(attempt)
        except exceptions as e:          # noqa: PERF203 — bounded loop
            err = e
    assert err is not None
    raise err


@dataclasses.dataclass
class CircuitBreaker:
    """Consecutive-failure circuit breaker (DESIGN.md §11).

    closed → (``threshold`` consecutive failures) → open for ``cooldown``
    rounds → half-open: ``allow`` admits one probe; its outcome closes or
    re-opens the breaker. Round numbers are caller-supplied monotonic
    ints (the service's replan round), so the breaker is deterministic —
    no wall clock involved.
    """
    threshold: int = 2
    cooldown: int = 2

    def __post_init__(self):
        if self.threshold < 1:
            raise ValueError(f"threshold must be >= 1, got {self.threshold}")
        if self.cooldown < 1:
            raise ValueError(f"cooldown must be >= 1, got {self.cooldown}")
        self._consecutive = 0
        self._open_until: Optional[int] = None
        self.opened = 0                  # times the breaker tripped open

    @property
    def state(self) -> str:
        if self._open_until is None:
            return "closed"
        return "open"

    def allow(self, round_no: int) -> bool:
        """May the protected call run this round? Open rounds before the
        cooldown expires are skipped; the first round at/after expiry is
        the half-open probe."""
        return self._open_until is None or round_no >= self._open_until

    def record_failure(self, round_no: int) -> None:
        self._consecutive += 1
        if self._consecutive >= self.threshold or self._open_until is not None:
            # trip (or re-trip after a failed half-open probe)
            self._open_until = round_no + 1 + self.cooldown
            self.opened += 1
            self._consecutive = 0

    def record_success(self) -> None:
        self._consecutive = 0
        self._open_until = None
