"""Elastic re-meshing: rebuild the largest valid mesh from the devices
that are actually alive, and resume from a mesh-agnostic checkpoint.

Policy: keep the model axis fixed (param shards must fit) and shrink the
data axis to ``n_devices // model``; training continues with a smaller
global batch (or more grad-accumulation steps, the trainer's choice).
The checkpoint layer stores host numpy, so restore onto the new mesh is
just ``device_put`` with the new NamedShardings (checkpoint/manager.py).
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax

__all__ = ["best_mesh_shape", "elastic_mesh"]


def best_mesh_shape(n_devices: int, model: int,
                    pod: int = 1) -> Tuple[int, ...]:
    """Largest (pod, data, model) using <= n_devices with fixed model/pod
    axes. Raises if not even one data row fits."""
    if n_devices < model * pod:
        raise ValueError(
            f"{n_devices} devices cannot host model={model} x pod={pod}")
    data = n_devices // (model * pod)
    return (pod, data, model) if pod > 1 else (data, model)


def elastic_mesh(model: int, pod: int = 1,
                 devices: Optional[Sequence] = None) -> jax.sharding.Mesh:
    devices = list(devices if devices is not None else jax.devices())
    shape = best_mesh_shape(len(devices), model, pod)
    n = 1
    for s in shape:
        n *= s
    axes = ("pod", "data", "model") if pod > 1 else ("data", "model")
    import numpy as np
    arr = np.array(devices[:n]).reshape(shape)
    return jax.sharding.Mesh(arr, axes)
