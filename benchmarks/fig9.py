"""Paper Fig. 9 — AlexNet, one per device, D2 deadline, with edge (a) or
cloud (b) computing power scaled by {0.8, 1, 1.5, 3, 5}."""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core import (EDGE, CLOUD, heft_makespan, merge_dags,
                        paper_environment, zoo)
from .common import ALGOS, PAPER, QUICK, print_csv

MULTS = (0.8, 1.0, 1.5, 3.0, 5.0)


def scaled_env(tier: int, mult: float):
    env = paper_environment()
    sel = env.tier == tier
    env.power[sel] = env.power[sel] * mult
    return env


def run(proto=QUICK, algos=("psoga", "ga", "greedy")):
    rows = []
    # D2 is FIXED from the ORIGINAL configuration (paper: "based on the
    # configurations for one AlexNet per device in D2(G)"); recomputing
    # HEFT on the scaled fleet would tighten the deadline as power grows.
    dags0 = [zoo.alexnet(pin_server=d) for d in range(10)]
    h0, _ = heft_makespan(merge_dags(dags0), paper_environment())
    for tier, tname in ((EDGE, "edge"), (CLOUD, "cloud")):
        for mult in MULTS:
            env = scaled_env(tier, mult)
            dags = [zoo.alexnet(pin_server=d) for d in range(10)]
            merged = merge_dags(dags)
            merged = merged.with_deadline(
                np.full(merged.num_apps, 1.5 * h0))    # D2 = 1.5 x HEFT
            for algo in algos:
                costs, feas, times = [], 0, []
                seeds = 1 if algo == "greedy" else proto.seeds
                for seed in range(seeds):
                    t0 = time.perf_counter()
                    res = ALGOS[algo](merged, env, proto, seed)
                    times.append(time.perf_counter() - t0)
                    if res.feasible:
                        feas += 1
                        costs.append(res.best_cost)
                rows.append({
                    "tier": tname, "mult": mult, "algo": algo,
                    "cost": float(np.mean(costs)) if costs else -1.0,
                    "feasible_frac": feas / seeds,
                    "wall_s": float(np.mean(times))})
                print(f"# {tname} x{mult} {algo}: "
                      f"cost={rows[-1]['cost']:.5f}", flush=True)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--paper", action="store_true")
    args = ap.parse_args()
    rows = run(proto=PAPER if args.paper else QUICK)
    print_csv(rows, ["tier", "mult", "algo", "cost", "feasible_frac",
                     "wall_s"])


if __name__ == "__main__":
    main()
