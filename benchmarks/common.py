"""Shared benchmark scaffolding.

Two protocols:
  * quick (default) — CPU-sized swarm (pop 32, <=150 iters, 2 seeds);
    preserves every RELATIVE ordering the paper claims, absolute costs
    are zoo-scaled (DESIGN.md §2).
  * --paper — the paper's §V settings (pop 100, iters 1000, stall 50,
    50 repeats); hours on this 1-core container, provided for fidelity.
"""
from __future__ import annotations

import dataclasses
import platform
import subprocess
import time
from pathlib import Path
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.core import (GAConfig, PSOGAConfig, greedy_offload,
                        heft_makespan, merge_dags, paper_environment,
                        pre_pso, run_ga, run_pso_ga, zoo)

RATIOS = (1.2, 1.5, 3.0, 5.0, 8.0)          # Eq. 24 deadline multipliers


@dataclasses.dataclass(frozen=True)
class Protocol:
    pop: int = 32
    iters: int = 120
    stall: int = 30
    seeds: int = 1
    scale_iters: bool = True     # fewer iters for 1000+-layer problems

    def _iters(self, n_layers: int) -> int:
        if not self.scale_iters or n_layers < 300:
            return self.iters
        return max(40, int(self.iters * (300 / n_layers) ** 0.5))

    def pso(self, n_layers: int = 0) -> PSOGAConfig:
        return PSOGAConfig(pop_size=self.pop,
                           max_iters=self._iters(n_layers),
                           stall_iters=self.stall)

    def ga(self, n_layers: int = 0) -> GAConfig:
        return GAConfig(pop_size=self.pop,
                        max_iters=self._iters(n_layers),
                        stall_iters=self.stall)


QUICK = Protocol()
PAPER = Protocol(pop=100, iters=1000, stall=50, seeds=50,
                 scale_iters=False)


def build_problem(net: str, per_device: int, deadline_ratio: float,
                  n_devices: int = 10):
    """`per_device` DNNs of type `net` on each of the 10 end devices
    (paper Fig. 7: per_device=1; Fig. 8: per_device=3, deadlines x2).

    Eq. 24's H(G_i) is ambiguous between "HEFT of G_i alone on an idle
    fleet" and "HEFT of G_i within the full workload". The idle-fleet
    reading makes every deadline unattainable once 10 DNNs share the
    serial-processing servers (even PSO-GA is infeasible at every r),
    contradicting Fig. 7's feasible mid-range costs; the workload reading
    (HEFT of the merged problem) reproduces the paper's qualitative
    curve — infeasible at D1/D2, costs declining to 0 as r loosens — so
    we use it (recorded in DESIGN.md §2)."""
    env = paper_environment()
    dags = []
    for d in range(n_devices):
        for _ in range(per_device):
            dags.append(zoo.build(net, pin_server=d))
    merged = merge_dags(dags)
    h, _ = heft_makespan(merged, env)
    scale = 2.0 if per_device > 1 else 1.0          # paper §V-C
    merged = merged.with_deadline(
        np.full(merged.num_apps, scale * deadline_ratio * h))
    return merged, env, h


ALGOS: Dict[str, Callable] = {
    "psoga": lambda dag, env, proto, seed:
        run_pso_ga(dag, env, proto.pso(dag.num_layers), seed=seed),
    "ga": lambda dag, env, proto, seed:
        run_ga(dag, env, proto.ga(dag.num_layers), seed=seed),
    "greedy": lambda dag, env, proto, seed: greedy_offload(dag, env),
    "prepso": lambda dag, env, proto, seed:
        pre_pso(dag, env, proto.pso(dag.num_layers), seed=seed),
}


def run_cell(net: str, per_device: int, ratio: float, algo: str,
             proto: Protocol) -> Dict:
    dag, env, h = build_problem(net, per_device, ratio)
    costs, feas, times = [], 0, []
    seeds = 1 if algo == "greedy" else proto.seeds
    for seed in range(seeds):
        t0 = time.perf_counter()
        res = ALGOS[algo](dag, env, proto, seed)
        times.append(time.perf_counter() - t0)
        if res.feasible:
            feas += 1
            costs.append(res.best_cost)
    return {
        "net": net, "per_device": per_device, "ratio": ratio, "algo": algo,
        "layers": dag.num_layers,
        "cost": float(np.mean(costs)) if costs else -1.0,   # paper: -1 =
        "feasible_frac": feas / seeds,                      # infeasible
        "wall_s": float(np.mean(times)),
    }


def print_csv(rows: List[Dict], cols: List[str]) -> None:
    print(",".join(cols))
    for r in rows:
        print(",".join(f"{r[c]:.6g}" if isinstance(r[c], float)
                       else str(r[c]) for c in cols))


def bench_metadata(seeds: Optional[Sequence[int]] = None,
                   mesh=None) -> Dict:
    """Reproducibility stamp for every ``BENCH_*.json`` payload: library
    versions, platform, device COUNT, the repo's git sha (dirty-marked),
    and the protocol seeds the run used — enough to re-run the exact
    cell a number came from months later. Pass the solver ``mesh`` when
    a run sharded the fleet (DESIGN.md §12) so multi-device entries are
    interpretable: its axis names and shape are stamped alongside."""
    import jax

    try:
        repo = Path(__file__).resolve().parent.parent
        sha = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"], cwd=repo,
            capture_output=True, text=True, timeout=10).stdout.strip()
        dirty = subprocess.run(
            ["git", "status", "--porcelain"], cwd=repo,
            capture_output=True, text=True, timeout=10).stdout.strip()
        git_sha = (sha + ("-dirty" if dirty else "")) if sha else "unknown"
    except (OSError, subprocess.SubprocessError):
        git_sha = "unknown"
    meta = {
        "jax_version": jax.__version__,
        "numpy_version": np.__version__,
        "python_version": platform.python_version(),
        "platform": platform.platform(),
        "device": jax.devices()[0].platform,
        "device_count": int(jax.device_count()),
        "git_sha": git_sha,
        "seeds": list(map(int, seeds)) if seeds is not None else [],
    }
    if mesh is not None:
        meta["mesh"] = {
            "axes": list(mesh.axis_names),
            "shape": [int(s) for s in mesh.devices.shape],
        }
    return meta
