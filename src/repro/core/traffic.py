"""Request-stream workload engine: score offloading plans under
concurrent load (DESIGN.md §10).

The paper's cost model (Eq. 4-6) prices ONE isolated execution of a
DNN's layers — a plan that looks cheap at zero load can blow its
deadline once requests queue on a shared edge server (JointDNN and the
Xu et al. survey in PAPERS.md both flag workload intensity as the gap
between single-shot partitioning and deployable offloading). This
module adds the missing workload layer in three pieces:

  * **Arrival traces** — ``ArrivalTrace`` + ``sample_arrivals``:
    per-app request timestamps over a horizon for four scenario
    families (``poisson``, ``diurnal``, ``bursty`` MMPP,
    ``flash-crowd``). Shapes are FIXED at ``(n_seeds, n_apps,
    max_requests)`` with +inf padding for never-arriving slots, so the
    arrays feed straight into jitted programs as traced values —
    drifting the load never retraces (same discipline as the online
    engine's EnvTrace, DESIGN.md §9).
  * **Queue-aware replay** — ``simulate_traffic_swarm``: R request
    copies of the schedule replayed against shared per-server FCFS
    queues. The merged event order (requests in arrival order, layers
    in topo order within a request) is computed as one ``lexsort``;
    the replay itself is the same minimal-carry scan as
    ``simulate_padded`` (lease/end carry, post-scan ``t_on``,
    DESIGN.md §8) with two deltas: a layer additionally gates on its
    request's arrival time, and the ``end`` buffer carries one slot
    per (request, layer). A zero-contention trace (1 request/app at
    t=0) reproduces the single-shot simulator bit-for-bit.
  * **Contention metrics** — per-request completion latencies,
    deadline-miss rate, and the load-adjusted Eq. 8 cost of the whole
    horizon (rental windows now span queued work). ``traffic_replay``
    vmaps the engine over Monte-Carlo arrival seeds for tail
    estimates (p50/p95/p99 via ``traffic_stats``).

Queueing discipline (documented choice): each server serves work in
request-arrival order — all layers of an earlier-arriving request
precede every layer of a later one on the merged timeline, with
head-of-line blocking (a server idles while its next-in-order layer
waits on a transfer, it does not reorder). This keeps the event order
static given the arrivals, which is what makes the whole replay one
``lax.scan`` with shapes independent of the arrival values; tests pin
it against an independent discrete-event reference
(``tests/test_traffic.py``).

``fitness.make_swarm_fitness(arrivals=...)`` turns the replay into the
contention-aware fitness term (expected cost subject to a p95
deadline-miss budget) that PSO-GA, the batched fleet runner, and the
GA baseline optimize — see DESIGN.md §10.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
from functools import partial
from typing import Any, Callable, Dict, List, NamedTuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from .seeding import rng_entropy
from .simulator import (PaddedProblem, SimProblem, _swarm_phase1,
                        pad_problem)

__all__ = ["TRAFFIC_KINDS", "ArrivalTrace", "ArrivalQueue",
           "IngestConfig", "TrafficConfig",
           "sample_arrivals", "TrafficSim", "TrafficResult",
           "simulate_traffic_swarm", "traffic_replay", "traffic_stats",
           "zero_contention_arrivals"]

TRAFFIC_KINDS = ("poisson", "diurnal", "bursty", "flash-crowd")


def _require_positive_finite(name: str, value: float) -> float:
    """Front-door validation (DESIGN.md §11): a NaN or non-positive rate
    fed to the generators would silently propagate into jitted fitness
    (NaN keys freeze PSO's argmin; rate 0 makes every replay vacuously
    feasible) — reject loudly at the boundary instead."""
    v = float(value)
    if not np.isfinite(v) or v <= 0.0:
        raise ValueError(f"{name} must be a positive finite number, "
                         f"got {value!r}")
    return v


def _require_count(name: str, value: int, minimum: int = 1) -> int:
    v = int(value)
    if v < minimum:
        raise ValueError(f"{name} must be >= {minimum}, got {value!r}")
    return v


# ---------------------------------------------------------------------------
# arrival traces
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ArrivalTrace:
    """Per-app request timestamps over ``[0, horizon)``.

    ``t`` is ``(n_seeds, n_apps, max_requests)`` float64, ascending per
    app, padded with +inf — a slot of +inf means "no such request", and
    the replay engine treats it as a masked no-op, so every seed and
    every load level shares ONE array shape (jit-stable by
    construction). Requests beyond ``max_requests`` in a draw are
    dropped (the cap is part of the workload model, like a front-door
    admission limit).
    """
    kind: str
    rate: float                   # mean requests/s per app
    horizon: float                # seconds
    t: np.ndarray                 # (n_seeds, n_apps, max_requests)

    @property
    def n_seeds(self) -> int:
        return int(self.t.shape[0])

    @property
    def n_apps(self) -> int:
        return int(self.t.shape[1])

    @property
    def max_requests(self) -> int:
        return int(self.t.shape[2])

    def counts(self) -> np.ndarray:
        """(n_seeds, n_apps) number of real requests per app."""
        return np.isfinite(self.t).sum(axis=2)


def _draw_poisson(rng: np.random.Generator, rate: float,
                  horizon: float) -> List[float]:
    out: List[float] = []
    if rate <= 0.0:
        return out
    t = float(rng.exponential(1.0 / rate))
    while t < horizon:
        out.append(t)
        t += float(rng.exponential(1.0 / rate))
    return out


def _draw_thinned(rng: np.random.Generator, lam: Callable[[float], float],
                  lam_max: float, horizon: float) -> List[float]:
    """Inhomogeneous Poisson via Lewis-Shedler thinning."""
    out: List[float] = []
    if lam_max <= 0.0:
        return out
    t = float(rng.exponential(1.0 / lam_max))
    while t < horizon:
        if rng.uniform() * lam_max <= lam(t):
            out.append(t)
        t += float(rng.exponential(1.0 / lam_max))
    return out


def _mmpp_intervals(rng: np.random.Generator, horizon: float
                    ) -> List[tuple]:
    """Two-state Markov-modulated intervals (start, end, high?) shared
    by every app of the seed — bursts are correlated across apps, which
    is exactly what makes them hard on a shared server."""
    out = []
    t, high = 0.0, False
    while t < horizon:
        dwell = float(rng.exponential(horizon / (8.0 if high else 4.0)))
        out.append((t, min(t + dwell, horizon), high))
        t += dwell
        high = not high
    return out


def sample_arrivals(kind: str, n_apps: int, rate: float = 0.5,
                    horizon: float = 30.0, max_requests: int = 8,
                    n_seeds: int = 1, seed: int = 0) -> ArrivalTrace:
    """Generate a fixed-shape arrival trace for one scenario family.

    ``poisson``     — homogeneous rate ``rate``, independent per app.
    ``diurnal``     — sinusoidal intensity ``rate·(1 + 0.9·sin)`` with
                      the peak mid-horizon (a compressed day).
    ``bursty``      — 2-state MMPP: λ_low = 0.3·rate, λ_high = 2.4·rate,
                      dwell means horizon/4 and horizon/8; the state
                      path is SHARED across apps (correlated bursts).
    ``flash-crowd`` — 0.5·rate baseline plus a ×4·rate crowd window of
                      0.15·horizon at a random onset, shared across
                      apps (everyone arrives at once).

    Mean intensity is ≈ ``rate`` requests/s/app for every family, so an
    intensity sweep compares like with like. Seeded and deterministic:
    seed index ``s`` draws from ``default_rng([seed, s])``; the seed is
    routed through the fleet solver's int-coercion front door, so numpy
    integer scalars, 0-d arrays, and negative seeds all work.
    """
    if kind not in TRAFFIC_KINDS:
        raise ValueError(f"unknown traffic kind {kind!r} "
                         f"(expected one of {TRAFFIC_KINDS})")
    rate = _require_positive_finite("rate", rate)
    horizon = _require_positive_finite("horizon", horizon)
    n_apps = _require_count("n_apps", n_apps)
    max_requests = _require_count("max_requests", max_requests)
    n_seeds = _require_count("n_seeds", n_seeds)
    entropy = rng_entropy(seed)
    t = np.full((n_seeds, n_apps, max_requests), np.inf)
    for s in range(n_seeds):
        rng = np.random.default_rng([entropy, s])
        if kind == "bursty":
            ivals = _mmpp_intervals(rng, horizon)

            def lam(x: float) -> float:
                for lo, hi, high in ivals:
                    if lo <= x < hi:
                        return (2.4 if high else 0.3) * rate
                return 0.3 * rate
            lam_max = 2.4 * rate
        elif kind == "flash-crowd":
            t0 = float(rng.uniform(0.2, 0.6)) * horizon
            w = 0.15 * horizon

            def lam(x: float) -> float:
                return 0.5 * rate + (4.0 * rate if t0 <= x < t0 + w
                                     else 0.0)
            lam_max = 4.5 * rate
        elif kind == "diurnal":
            def lam(x: float) -> float:
                return rate * (1.0 + 0.9 * np.sin(
                    2.0 * np.pi * x / horizon - np.pi / 2.0))
            lam_max = 1.9 * rate
        else:
            lam, lam_max = None, rate
        for a in range(n_apps):
            if kind == "poisson":
                times = _draw_poisson(rng, rate, horizon)
            else:
                times = _draw_thinned(rng, lam, lam_max, horizon)
            times = times[:max_requests]
            t[s, a, :len(times)] = times
    return ArrivalTrace(kind=kind, rate=rate, horizon=horizon, t=t)


def zero_contention_arrivals(n_apps: int, n_seeds: int = 1) -> np.ndarray:
    """(n_seeds, n_apps, 1) — one request per app at t = 0: the replay
    then reproduces the single-shot simulator bit-for-bit (tested)."""
    return np.zeros((n_seeds, n_apps, 1))


@dataclasses.dataclass(frozen=True)
class TrafficConfig:
    """One knob bundle for every traffic consumer (solver fitness, the
    online re-planner, ``serve --plan --traffic`` and the benchmark).

    ``mc_solver`` arrival seeds flow into the contention-aware fitness
    (small: every PSO-GA iteration replays all of them); ``mc_eval``
    seeds are the reporting/evaluation set (larger, drawn from a
    disjoint seed stream so plans are never scored on the arrivals
    they were optimized against). ``miss_budget`` is the p95
    deadline-miss budget the solver must satisfy (DESIGN.md §10).
    """
    kind: str = "poisson"
    rate: float = 0.5
    horizon: float = 30.0
    max_requests: int = 8
    mc_solver: int = 3
    mc_eval: int = 16
    miss_budget: float = 0.05

    def __post_init__(self):
        if self.kind not in TRAFFIC_KINDS:
            raise ValueError(f"unknown traffic kind {self.kind!r} "
                             f"(expected one of {TRAFFIC_KINDS})")
        _require_positive_finite("rate", self.rate)
        _require_positive_finite("horizon", self.horizon)
        _require_count("max_requests", self.max_requests)
        _require_count("mc_solver", self.mc_solver)
        _require_count("mc_eval", self.mc_eval)
        mb = float(self.miss_budget)
        if not np.isfinite(mb) or not 0.0 <= mb <= 1.0:
            raise ValueError(f"miss_budget must be in [0, 1], "
                             f"got {self.miss_budget!r}")

    def solver_arrivals(self, n_apps: int, seed: int = 0,
                        rate_scale: float = 1.0) -> np.ndarray:
        """(mc_solver, n_apps, max_requests) solver-side arrival draws."""
        return sample_arrivals(
            self.kind, n_apps, rate=self.rate * rate_scale,
            horizon=self.horizon, max_requests=self.max_requests,
            n_seeds=self.mc_solver, seed=seed).t

    def eval_arrivals(self, n_apps: int, seed: int = 0,
                      rate_scale: float = 1.0) -> np.ndarray:
        """(mc_eval, n_apps, max_requests) held-out evaluation draws."""
        return sample_arrivals(
            self.kind, n_apps, rate=self.rate * rate_scale,
            horizon=self.horizon, max_requests=self.max_requests,
            n_seeds=self.mc_eval, seed=seed + 104729).t


# ---------------------------------------------------------------------------
# queue-aware replay: merged-order minimal-carry scan (DESIGN.md §10)
# ---------------------------------------------------------------------------


class TrafficSim(NamedTuple):
    """One arrival draw replayed for a whole swarm. Leading axis P."""
    end: jnp.ndarray          # (P, R, max_p) per-(request, layer) end time
    latency: jnp.ndarray      # (P, max_apps, R) completion − arrival
    miss: jnp.ndarray         # (P, max_apps, R) bool deadline miss
    req_valid: jnp.ndarray    # (max_apps, R) bool — real request slot
    miss_rate: jnp.ndarray    # (P,) missed / valid requests
    comp_cost: jnp.ndarray    # (P,) $ rental over the whole horizon
    trans_cost: jnp.ndarray   # (P,) $ transmission, all request copies
    total_cost: jnp.ndarray   # (P,) load-adjusted Eq. 8
    lat_sum: jnp.ndarray      # (P,) Σ valid latencies (Eq. 16 analogue)
    static_ok: jnp.ndarray    # (P,) bool — pins honored, links legal


def _merged_order(pp: PaddedProblem, arr: jnp.ndarray):
    """Static merged event order over R request copies of the schedule.

    Sort key (stable): request arrival time, then request slot, then
    topo position. All steps of an earlier-arriving request precede
    every step of a later one (whole-request FCFS priority; same-app
    arrival ties serve in slot order, cross-app ties interleave by
    topo position), and a request's own steps stay in topo order — so
    every step's parents precede it and the scan carry is causally
    consistent for ANY arrival values.

    Padding is COMPACTED to the tail: padded-layer steps take the sort
    key +inf (instead of their app's arrival), joining +inf (padded)
    request slots past every real step, so the valid steps form a
    contiguous prefix of length ``n_valid``. The compacted prefix walk
    (``compact=True`` replay, and the Pallas kernel's ``fori_loop``
    bound) then skips the padding entirely instead of executing it as
    masked no-ops. Compaction is order-preserving: valid steps keep
    their exact keys and the ``(slot, topo)`` tie-break is a total
    order, so their relative order — and hence the lease/end/t_on
    evolution — is unchanged from the full-``T`` walk (masked no-ops
    were exact identities: adding 0.0 / min-ing +inf, the DESIGN.md §4
    discipline).
    """
    max_p = pp.order.shape[0]
    R = arr.shape[-1]
    valid = pp.order >= 0
    jsafe = jnp.where(valid, pp.order, 0)
    app = pp.app_id[jsafe]                             # (max_p,)
    rep_t = jnp.tile(jnp.arange(max_p), R)             # (T,)
    rep_r = jnp.repeat(jnp.arange(R), max_p)           # (T,)
    key = jnp.where(valid[rep_t], arr[app[rep_t], rep_r], jnp.inf)
    perm = jnp.lexsort((rep_t, rep_r, key))
    t_m = rep_t[perm]
    r_m = rep_r[perm]
    key_m = key[perm]
    valid_m = jnp.isfinite(key_m)                      # == valid & finite arr
    n_valid = jnp.sum(valid_m).astype(jnp.int32)
    return t_m, r_m, key_m, valid_m, n_valid


def simulate_traffic_swarm(pp: PaddedProblem, X: jnp.ndarray,
                           arr: jnp.ndarray,
                           faithful: bool = True,
                           compact: bool = False) -> TrafficSim:
    """Replay R request copies of every particle's schedule against
    shared per-server FCFS queues — one arrival draw ``arr (max_apps,
    R)``, the whole swarm ``X (P, max_p)`` at once.

    Same two-phase structure as ``simulate_swarm`` (DESIGN.md §8):
    phase 1 runs once per layer (request copies share the plan, so
    per-layer exe/transfer quantities are computed once and gathered
    per merged step); phase 2 replays the merged steps with the
    arrival time as an extra start gate:

        faithful:  start = max(lease[s], a_r) + maxTrans
                   lease[s] = max(lease[s], a_r) + exe + transfer_out
        corrected: start = max(lease[s], a_r, max_p(end[r,p] + trans_p))
                   lease[s] = start + exe + transfer_out

    With ``compact=False`` (the default) the walk is the full-``T``
    minimal-carry ``lax.scan`` in which padded steps execute as masked
    no-ops; ``compact=True`` instead runs a ``fori_loop`` over just the
    ``n_valid`` real steps of the compacted merged order
    (``_merged_order`` sorts every padded step past them), carrying
    ``(lease, end, t_on)``. The two are step-for-step the same replay
    (the no-ops are exact carry identities); the compact walk is the
    scan twin of the Pallas kernel's event loop
    (``kernels.traffic_sim``, whose ``fori_loop`` bound is the same
    ``n_valid``) and is kept as its differential-test reference. It is
    not reliably faster on CPU — a traced-bound ``fori_loop`` of
    dynamic indexing loses the static-``T`` scan's tight compilation
    unless +inf padding dominates the step sequence — which is why the
    fused kernel, not scan compaction, is the fast traffic path
    (DESIGN.md §10, EXPERIMENTS.md §Traffic). The
    ``fori_loop`` bound is traced (it depends on the arrivals), so
    under ``vmap`` — Monte-Carlo seeds, the fleet axis — it runs to the
    longest lane's prefix with finished lanes frozen by select.

    At R = 1 with arrival 0 both modes reduce bit-exactly to the
    single-shot recurrences (``max(lease, 0) = lease``), which is the
    zero-contention acceptance invariant. ``t_on`` is an
    order-independent min over emitted start times, rental cost covers
    the whole horizon window per server, and transmission cost is
    charged once per valid request copy.
    """
    X = jnp.asarray(X).astype(jnp.int32)
    arr = jnp.asarray(arr)
    P, max_p = X.shape
    max_S = pp.power.shape[0]
    max_apps = pp.deadline.shape[0]
    R = arr.shape[-1]

    ph = _swarm_phase1(pp, X)
    t_m, r_m, arr_m, valid_m, n_valid = _merged_order(pp, arr)

    j_m = ph.jsafe[t_m]                                # (T,) shared
    slot_m = r_m * max_p + j_m                         # (T,) end-buffer slot
    eidx_m = r_m[:, None] * max_p + ph.psafe[t_m]      # (T, max_in) shared
    pmask_m = ph.pmask[t_m]                            # (T, max_in) shared
    srv_m = jnp.take(ph.srv, t_m, axis=1)              # (P, T)
    exe_m = jnp.take(ph.exe, t_m, axis=1)
    mt_m = jnp.take(ph.max_trans, t_m, axis=1)
    ot_m = jnp.take(ph.out_t, t_m, axis=1)
    tt_m = jnp.take(ph.tt, t_m, axis=1)                # (P, T, max_in)
    arr_ms = jnp.where(valid_m, arr_m, 0.0)            # finite everywhere

    iota_S = jnp.arange(max_S)
    if compact:
        col = partial(jax.lax.dynamic_index_in_dim, keepdims=False)

        def body(t, carry):
            lease, end, t_on = carry
            srv_t = col(srv_m, t, axis=1)
            exe_t = col(exe_m, t, axis=1)
            ot_t = col(ot_m, t, axis=1)
            arr_t = arr_ms[t]
            slot_t = slot_m[t]
            srv_oh = srv_t[:, None] == iota_S[None, :]           # (P, S)
            lease_srv = jnp.take_along_axis(lease, srv_t[:, None],
                                            axis=1)[:, 0]
            if faithful:
                base = jnp.maximum(lease_srv, arr_t)
                start = base + col(mt_m, t, axis=1)
                new_lease = base + exe_t + ot_t
            else:
                ep = jnp.take(end, eidx_m[t], axis=1)  # (P, max_in)
                gate = jnp.max(jnp.where(pmask_m[t][None, :],
                                         ep + col(tt_m, t, axis=1), 0.0),
                               axis=1, initial=0.0)
                gate = jnp.maximum(gate, arr_t)
                start = jnp.maximum(lease_srv, gate)
                new_lease = start + exe_t + ot_t
            t_end = start + exe_t
            lease = jnp.where(srv_oh, new_lease[:, None], lease)
            end = jax.lax.dynamic_update_slice(end, t_end[:, None],
                                               (0, slot_t))
            t_on = jnp.minimum(t_on, jnp.where(srv_oh, start[:, None],
                                               jnp.inf))
            return lease, end, t_on

        lease, end, t_on = jax.lax.fori_loop(
            0, n_valid, body,
            (jnp.zeros((P, max_S)), jnp.zeros((P, R * max_p)),
             jnp.full((P, max_S), jnp.inf)))
    else:
        xs = (valid_m, slot_m, arr_ms, srv_m.T, exe_m.T, mt_m.T, ot_m.T,
              eidx_m, pmask_m, jnp.swapaxes(tt_m, 0, 1))

        def step(carry, inp):
            (valid_t, slot_t, arr_t, srv_t, exe_t, mt_t, ot_t,
             eidx_t, pmask_t, tt_t) = inp
            if faithful:
                lease, = carry
            else:
                lease, end = carry
            srv_oh = (srv_t[:, None] == iota_S[None, :]) & valid_t  # (P, S)
            lease_srv = jnp.take_along_axis(lease, srv_t[:, None],
                                            axis=1)[:, 0]
            if faithful:
                base = jnp.maximum(lease_srv, arr_t)
                start = base + mt_t
                new_lease = base + exe_t + ot_t
            else:
                ep = jnp.take(end, eidx_t, axis=1)     # (P, max_in)
                gate = jnp.max(jnp.where(pmask_t[None, :], ep + tt_t, 0.0),
                               axis=1, initial=0.0)
                gate = jnp.maximum(gate, arr_t)
                start = jnp.maximum(lease_srv, gate)
                new_lease = start + exe_t + ot_t
            t_end = start + exe_t
            lease = jnp.where(srv_oh, new_lease[:, None], lease)
            if faithful:
                return (lease,), (start, t_end)
            old = jax.lax.dynamic_slice(end, (0, slot_t), (P, 1))
            end = jax.lax.dynamic_update_slice(
                end, jnp.where(valid_t, t_end[:, None], old), (0, slot_t))
            return (lease, end), (start, t_end)

        init = (jnp.zeros((P, max_S)),) if faithful \
            else (jnp.zeros((P, max_S)), jnp.zeros((P, R * max_p)))
        carry, (start_seq, t_end_seq) = jax.lax.scan(step, init, xs)
        lease = carry[0]
        if faithful:
            slot_idx = jnp.where(valid_m, slot_m, R * max_p)
            end = jnp.zeros((P, R * max_p)).at[:, slot_idx].set(
                t_end_seq.T, mode="drop")
        else:
            end = carry[1]

        start_all = start_seq.T                        # (P, T)
        rows = jnp.arange(P)[:, None]
        srv_scatter = jnp.where(valid_m[None, :], srv_m, max_S)
        t_on = jnp.full((P, max_S), jnp.inf).at[rows, srv_scatter].min(
            jnp.where(valid_m[None, :], start_all, jnp.inf), mode="drop")
    used = ~jnp.isinf(t_on)
    t_on_safe = jnp.where(used, t_on, 0.0)
    comp_cost = jnp.sum(jnp.where(used, pp.cost_per_sec[None, :]
                                  * (lease - t_on_safe), 0.0), axis=1)
    tc_m = jnp.take(ph.tc, t_m, axis=1)                # (P, T, max_in)
    trans_cost = jnp.sum(jnp.where(valid_m[None, :, None], tc_m, 0.0),
                         axis=(1, 2))

    # per-request completion: max end over the app's layers per copy
    end_rj = end.reshape(P, R, max_p)
    app_oh = pp.app_id[None, :] == jnp.arange(max_apps)[:, None]
    appc = jnp.max(jnp.where(app_oh[None, None, :, :],
                             end_rj[:, :, None, :], -jnp.inf),
                   axis=3)                             # (P, R, max_apps)
    appc = jnp.swapaxes(appc, 1, 2)                    # (P, max_apps, R)
    app_real = jnp.arange(max_apps) < pp.num_apps
    req_valid = jnp.isfinite(arr) & app_real[:, None]  # (max_apps, R)
    latency = jnp.where(req_valid[None], appc - arr[None], 0.0)
    miss = req_valid[None] & (latency > pp.deadline[None, :, None])
    n_req = jnp.maximum(jnp.sum(req_valid), 1)
    miss_rate = jnp.sum(miss, axis=(1, 2)) / n_req
    lat_sum = jnp.sum(latency, axis=(1, 2))
    pin_ok = jnp.all((pp.pinned[None, :] < 0) | (X == pp.pinned[None, :]),
                     axis=1)
    return TrafficSim(end=end_rj, latency=latency, miss=miss,
                      req_valid=req_valid, miss_rate=miss_rate,
                      comp_cost=comp_cost, trans_cost=trans_cost,
                      total_cost=comp_cost + trans_cost, lat_sum=lat_sum,
                      static_ok=pin_ok & ~ph.link_bad)


# ---------------------------------------------------------------------------
# Monte-Carlo evaluation of ONE plan
# ---------------------------------------------------------------------------


class TrafficResult(NamedTuple):
    """Monte-Carlo replay of one plan. Leading axis = arrival seed."""
    latency: np.ndarray       # (M, max_apps, R)
    miss: np.ndarray          # (M, max_apps, R) bool
    req_valid: np.ndarray     # (M, max_apps, R) bool
    miss_rate: np.ndarray     # (M,)
    total_cost: np.ndarray    # (M,)
    feasible: bool            # static: pins honored, links legal


@partial(jax.jit, static_argnames=("faithful",))
def _replay_mc(pp: PaddedProblem, X1: jnp.ndarray, arr_mc: jnp.ndarray,
               faithful: bool) -> TrafficSim:
    return jax.vmap(
        lambda a: simulate_traffic_swarm(pp, X1, a, faithful))(arr_mc)


def traffic_replay(prob: Union[SimProblem, PaddedProblem], x: np.ndarray,
                   arrivals: np.ndarray,
                   faithful: bool = True) -> TrafficResult:
    """Replay one plan against Monte-Carlo arrival draws.

    ``arrivals``: ``(M, n_apps, R)`` (or ``(n_apps, R)`` for one draw)
    timestamps, +inf padded — e.g. ``ArrivalTrace.t`` or
    ``TrafficConfig.eval_arrivals``. Returns per-seed/per-request
    latencies, deadline misses, and load-adjusted costs; feed the
    result to ``traffic_stats`` for p50/p95/p99 tails.
    """
    pp = prob if isinstance(prob, PaddedProblem) else pad_problem(prob)
    max_p = int(pp.compute.shape[0])
    max_apps = int(pp.deadline.shape[0])
    x = np.asarray(x, np.int32)
    X1 = np.zeros((1, max_p), np.int32)
    X1[0, :x.shape[0]] = x
    arr = np.asarray(arrivals, float)
    if arr.ndim == 2:
        arr = arr[None]
    if arr.shape[1] < max_apps:                 # pad apps with +inf slots
        pad = np.full((arr.shape[0], max_apps - arr.shape[1],
                       arr.shape[2]), np.inf)
        arr = np.concatenate([arr, pad], axis=1)
    sims = _replay_mc(pp, jnp.asarray(X1), jnp.asarray(arr), faithful)
    return TrafficResult(
        latency=np.asarray(sims.latency)[:, 0],
        miss=np.asarray(sims.miss)[:, 0],
        req_valid=np.asarray(sims.req_valid),
        miss_rate=np.asarray(sims.miss_rate)[:, 0],
        total_cost=np.asarray(sims.total_cost)[:, 0],
        feasible=bool(np.asarray(sims.static_ok)[0, 0]))


def traffic_stats(res: TrafficResult) -> dict:
    """Tail summary of a Monte-Carlo replay (numbers for reports)."""
    mr = np.asarray(res.miss_rate, float)
    out = {
        "miss_mean": float(mr.mean()),
        "miss_p50": float(np.percentile(mr, 50)),
        "miss_p95": float(np.percentile(mr, 95)),
        "miss_p99": float(np.percentile(mr, 99)),
        "cost_mean": float(np.asarray(res.total_cost).mean()),
        "requests": int(res.req_valid.sum()),
        "feasible": bool(res.feasible),
    }
    lat = res.latency[res.req_valid]
    out["latency_p95"] = float(np.percentile(lat, 95)) if lat.size else 0.0
    return out


# --------------------------------------------------------------------------
# async request ingestion (DESIGN.md §11 phase 2)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class IngestConfig:
    """Async arrival-ingestion knobs for the planning service.

    The service's rate estimator (`estimate_rates`) historically drew
    one arrival observation per DAG synchronously inside every round.
    With ingestion enabled, observations flow through a bounded
    :class:`ArrivalQueue` instead — the pipelined producer/consumer
    shape of offline-inference servers — and the round loop drains
    whatever has arrived before estimating.

    threads:  0 = deterministic single-thread mode — the round loop
              itself enqueues exactly this round's observations before
              draining, so estimates (and therefore plans) are
              bit-identical to the legacy synchronous path; chaos and
              parity suites run in this mode. >0 = that many producer
              threads pre-draw observations for future rounds and
              enqueue them concurrently (liveness and backpressure are
              deterministic, drain *interleaving* is not).
    capacity: queue slots; a full queue drops the observation and
              counts it (``ingest_dropped``) — backpressure is
              explicit, never blocking the planner.
    """

    threads: int = 0
    capacity: int = 64

    def __post_init__(self) -> None:
        if int(self.threads) < 0:
            raise ValueError(
                f"threads must be >= 0, got {self.threads!r}")
        if int(self.capacity) < 1:
            raise ValueError(
                f"capacity must be >= 1, got {self.capacity!r}")


class ArrivalQueue:
    """Bounded, thread-safe arrival-observation queue.

    ``put`` never blocks: when the queue is full the observation is
    dropped and counted, so a slow planner sheds load instead of
    wedging its producers (rate observations are lossy-tolerant — the
    sliding window just sees fewer samples). Counters are monotonic:
    ``enqueued`` + ``dropped`` = offered, ``drained`` = consumed,
    ``depth`` = enqueued - drained.

    A ``telemetry`` channel mirrors every count live onto the registry
    as ``ingest.*`` counters plus an ``ingest.depth`` gauge
    (DESIGN.md §13); queue behavior is identical without it.
    """

    def __init__(self, capacity: int = 64, *, telemetry=None) -> None:
        if int(capacity) < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity!r}")
        self.capacity = int(capacity)
        self._dq: collections.deque = collections.deque()
        self._lock = threading.Lock()
        self._tel = telemetry
        self.enqueued = 0
        self.dropped = 0
        self.drained = 0
        self.max_depth = 0

    def put(self, item: Any) -> bool:
        """Enqueue; False (and counted) when full."""
        with self._lock:
            if len(self._dq) >= self.capacity:
                self.dropped += 1
                if self._tel is not None:
                    self._tel.inc("ingest.dropped")
                return False
            self._dq.append(item)
            self.enqueued += 1
            self.max_depth = max(self.max_depth, len(self._dq))
            if self._tel is not None:
                self._tel.inc("ingest.enqueued")
                self._tel.set_gauge("ingest.depth", float(len(self._dq)))
            return True

    def drain(self) -> List[Any]:
        """Dequeue everything currently buffered, FIFO order."""
        with self._lock:
            items = list(self._dq)
            self._dq.clear()
            self.drained += len(items)
            if self._tel is not None:
                self._tel.inc("ingest.drained", len(items))
                self._tel.set_gauge("ingest.depth", 0.0)
            return items

    def depth(self) -> int:
        with self._lock:
            return len(self._dq)

    def counters(self) -> Dict[str, int]:
        with self._lock:
            return {"enqueued": self.enqueued, "dropped": self.dropped,
                    "drained": self.drained, "max_depth": self.max_depth,
                    "depth": len(self._dq)}
