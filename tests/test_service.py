"""Fault-tolerant planning service (repro.core.service, DESIGN.md §11):
the parity invariant (protections off ⇒ bit-identical to replan_fleet),
the chaos harness (crashes, NaN envs, stalls, mid-round churn), the
watchdog/ladder/triage paths, the stale-plan guard, and the runtime
fault primitives the loop is built from."""
import numpy as np
import pytest

from repro.core import (ChaosConfig, LADDER_RUNGS, PSOGAConfig,
                        ReplanConfig, ServiceConfig, ServiceReport,
                        ServiceRoundLog, SimProblem, TrafficConfig,
                        heft_makespan, merge_dags, paper_environment,
                        plan_is_valid, replan_fleet, run_pso_ga_batch,
                        run_service, sample_trace, zero_drift_trace, zoo)
from repro.core.batch import reset_runner_cache_stats, runner_cache_stats
from repro.core.online import replan_round
from repro.core.service import _RateWindow, _down_env, _select_rung
from repro.runtime import (CircuitBreaker, EwmaEstimator,
                           SimulatedFailure, retry_with_backoff)

#: distinct from every other test config so this file's first solve is a
#: fresh runner-cache entry (the cache-discipline test relies on that)
FAST = PSOGAConfig(pop_size=20, max_iters=50, stall_iters=18)
BURST = PSOGAConfig(pop_size=12, max_iters=10, stall_iters=6)
RCFG = ReplanConfig(pso=FAST)
TCFG = TrafficConfig(rate=0.4, max_requests=4, mc_solver=2, mc_eval=4)
RCFG_T = ReplanConfig(pso=FAST, traffic=TCFG)


@pytest.fixture(scope="module")
def fleet():
    env = paper_environment()
    dags = []
    for i, net in enumerate(("alexnet", "googlenet")):
        dag = zoo.build(net, pin_server=i)
        h, _ = heft_makespan(dag, env)
        dags.append(dag.with_deadline(np.array([1.5 * h])))
    return env, dags


@pytest.fixture(scope="module")
def trace4(fleet):
    env, _ = fleet
    return sample_trace("wifi-fade", env, rounds=4, seed=3)


@pytest.fixture(scope="module")
def batch_report(fleet, trace4):
    _, dags = fleet
    return replan_fleet(dags, trace4, RCFG, seed=7)


@pytest.fixture(scope="module")
def service_report(fleet, trace4):
    _, dags = fleet
    return run_service(dags, trace4, ServiceConfig(replan=RCFG), seed=7)


# ---------------------------------------------------------------------------
# runtime primitives: breaker, retry, estimators
# ---------------------------------------------------------------------------

def test_circuit_breaker_lifecycle():
    b = CircuitBreaker(threshold=2, cooldown=2)
    assert b.state == "closed" and b.allow(1)
    b.record_failure(1)
    assert b.state == "closed"            # one failure is not a trip
    b.record_failure(2)
    assert b.state == "open" and b.opened == 1
    assert not b.allow(3) and not b.allow(4)
    assert b.allow(5)                     # half-open probe round
    b.record_failure(5)                   # failed probe re-trips
    assert b.opened == 2 and not b.allow(7)
    assert b.allow(8)
    b.record_success()                    # probe succeeded: fully closed
    assert b.state == "closed" and b.allow(9)


def test_circuit_breaker_rejects_bad_knobs():
    with pytest.raises(ValueError, match="threshold"):
        CircuitBreaker(threshold=0)
    with pytest.raises(ValueError, match="cooldown"):
        CircuitBreaker(cooldown=0)


def test_retry_with_backoff_recovers_and_sleeps_exponentially():
    sleeps, attempts = [], []

    def flaky(a):
        attempts.append(a)
        if a < 2:
            raise SimulatedFailure("boom")
        return "ok"

    out = retry_with_backoff(flaky, retries=2, backoff_s=0.1,
                             sleeper=sleeps.append)
    assert out == "ok"
    assert attempts == [0, 1, 2]
    np.testing.assert_allclose(sleeps, [0.1, 0.2])


def test_retry_with_backoff_exhausts_then_raises():
    attempts = []

    def dead(a):
        attempts.append(a)
        raise SimulatedFailure("still dead")

    with pytest.raises(SimulatedFailure):
        retry_with_backoff(dead, retries=1, sleeper=lambda s: None)
    assert attempts == [0, 1]


def test_retry_with_backoff_does_not_catch_other_exceptions():
    attempts = []

    def broken(a):
        attempts.append(a)
        raise ValueError("logic bug, not a fault")

    with pytest.raises(ValueError):
        retry_with_backoff(broken, retries=5, sleeper=lambda s: None)
    assert attempts == [0]                # no retry on non-fault errors


def test_ewma_estimator():
    e = EwmaEstimator(alpha=0.3)
    assert e.value is None
    e.update(1.0)
    assert e.value == pytest.approx(1.0)
    e.update(2.0)
    assert e.value == pytest.approx(1.3)
    e.update(float("nan"))
    e.update(-5.0)
    e.update(float("inf"))
    assert e.value == pytest.approx(1.3)  # junk samples ignored
    assert e.n == 2


def test_rate_window():
    w = _RateWindow(window_rounds=2, horizon=10.0, n_apps=1)
    assert w.rate() is None
    w.ingest(np.array([0.1, 0.2, 0.3, 0.4, 0.5]))
    assert w.rate() == pytest.approx(0.5)          # 5 / (1 * 10 * 1)
    w.ingest(np.concatenate([np.arange(15.0), [np.inf]]))
    assert w.rate() == pytest.approx(1.0)          # (5+15) / (2 * 10)
    w.ingest(np.arange(15.0))
    assert w.rate() == pytest.approx(1.5)          # window slid: (15+15)/20


def test_select_rung():
    assert _select_rung(float("inf"), 50, 10) == "warm"
    assert _select_rung(50.0, 50, 10) == "warm"
    assert _select_rung(49.9, 50, 10) == "burst"
    assert _select_rung(10.0, 50, 10) == "burst"
    assert _select_rung(9.9, 50, 10) == "pinned"


def test_config_validation():
    with pytest.raises(ValueError, match="p_crash"):
        ChaosConfig(p_crash=1.5)
    with pytest.raises(ValueError, match="stall_s"):
        ChaosConfig(stall_s=-1.0)
    with pytest.raises(ValueError, match="slo_s"):
        ServiceConfig(slo_s=0.0)
    with pytest.raises(ValueError, match="triage_margin"):
        ServiceConfig(triage_margin=-1.0)
    with pytest.raises(ValueError, match="window_rounds"):
        ServiceConfig(window_rounds=0)
    with pytest.raises(ValueError, match="retries"):
        ServiceConfig(retries=-1)


def test_service_report_helpers():
    def row(rung, wall):
        return ServiceRoundLog(round=1, label="x", rung=rung, wall_s=wall,
                               budget_iters=float("inf"),
                               breaker_state="closed", solver_failed=False,
                               retries_used=0, stale_env=False,
                               stalled=False, rejected_apps=0,
                               est_rates=(), replan=None)
    rep = ServiceReport(cold=[], rounds=[row(("warm", "reject"), 1.0),
                                         row(("heft", "greedy"), 3.0)],
                        plans=[], fallback_counts={}, counters={})
    assert rep.availability() == pytest.approx(0.75)
    ttp = rep.time_to_plan()
    assert ttp["p50"] == pytest.approx(2.0)
    assert ttp["max"] == pytest.approx(3.0)
    assert rep.summary()["rounds"] == 2


# ---------------------------------------------------------------------------
# stale-plan guard (plan_is_valid + replan_round demotion)
# ---------------------------------------------------------------------------

def test_plan_is_valid(fleet):
    env, dags = fleet
    dag = dags[0]
    prob = SimProblem.build(dag, env)
    _, x_h = heft_makespan(dag, env)
    assert plan_is_valid(prob, x_h)
    assert plan_is_valid(prob, np.asarray(x_h, float))   # integral floats ok
    assert not plan_is_valid(prob, None)
    assert not plan_is_valid(prob, np.asarray(x_h)[:-1])         # shape
    assert not plan_is_valid(prob, np.full(prob.num_layers, np.nan))
    assert not plan_is_valid(prob, np.asarray(x_h, float) + 0.5)
    bad = np.array(x_h, np.int64)
    bad[1] = prob.num_servers                                    # range
    assert not plan_is_valid(prob, bad)
    pin_at = int(np.argmax(np.asarray(prob.pinned) >= 0))
    bad = np.array(x_h, np.int64)
    bad[pin_at] = (int(prob.pinned[pin_at]) + 1) % prob.num_servers
    assert not plan_is_valid(prob, bad)                          # pin


def test_plan_is_valid_rejects_severed_links(fleet):
    env, dags = fleet
    dag = dags[0]
    s_last = env.num_servers - 1
    x = np.where(np.asarray(SimProblem.build(dag, env).pinned) >= 0,
                 np.asarray(SimProblem.build(dag, env).pinned), 0)
    x = np.asarray(x, np.int64)
    x[1] = s_last        # layer 1's parent sits on server 0
    assert plan_is_valid(SimProblem.build(dag, env), x)
    down = _down_env(env, s_last)
    assert not plan_is_valid(SimProblem.build(dag, down), x)


def test_replan_round_demotes_garbage_incumbent(fleet):
    env, dags = fleet
    probs = [SimProblem.build(d, env) for d in dags]
    _, x0 = heft_makespan(dags[0], env)
    garbage = np.full(probs[1].num_layers, np.nan)
    plans, log = replan_round(probs, [np.asarray(x0, np.int32), garbage],
                              RCFG, seed=11, round_no=1, label="chaos")
    assert list(log.demoted) == [False, True]
    assert log.migration[1] == 0.0       # cold start pays no migration
    assert log.moved_layers[1] == probs[1].num_layers
    assert log.replanned[1]
    for pr, x in zip(probs, plans):
        assert plan_is_valid(pr, x)


def test_demoted_incumbent_is_bit_identical_to_cold(fleet):
    """A per-entry None incumbent (the guard's demotion) must reproduce
    the cold solve exactly: migration weight zeroed, no warm seeding."""
    env, dags = fleet
    probs = [SimProblem.build(d, env) for d in dags]
    cold = run_pso_ga_batch(probs, FAST, seed=13)
    demo = run_pso_ga_batch(probs, FAST, seed=13,
                            incumbent=[None, None], migration_weight=1.0)
    for c, d in zip(cold, demo):
        np.testing.assert_array_equal(c.best_x, d.best_x)
        assert c.best_cost == d.best_cost


# ---------------------------------------------------------------------------
# the parity invariant: protections off ⇒ replan_fleet, bit for bit
# ---------------------------------------------------------------------------

def test_service_matches_replan_fleet_bit_for_bit(fleet, trace4,
                                                  batch_report,
                                                  service_report):
    assert len(service_report.rounds) == len(batch_report.rounds)
    for r, b in zip(service_report.rounds, batch_report.rounds):
        assert r.rung == ("warm",) * 2
        assert r.replan is not None
        np.testing.assert_array_equal(r.replan.cost, b.cost)
        np.testing.assert_array_equal(r.replan.replanned, b.replanned)
    for x_s, x_b in zip(service_report.plans, batch_report.plans):
        np.testing.assert_array_equal(x_s, x_b)
    assert service_report.availability() == 1.0
    assert service_report.counters["crashes"] == 0
    assert service_report.counters["stale_env_rounds"] == 0


def test_service_traffic_parity(fleet):
    env, dags = fleet
    trace = sample_trace("load-surge", env, rounds=3, seed=5)
    batch = replan_fleet(dags, trace, RCFG_T, seed=7)
    serv = run_service(dags, trace, ServiceConfig(replan=RCFG_T), seed=7)
    for r, b in zip(serv.rounds, batch.rounds):
        np.testing.assert_array_equal(r.replan.cost, b.cost)
    for x_s, x_b in zip(serv.plans, batch.plans):
        np.testing.assert_array_equal(x_s, x_b)


def test_service_accepts_initial_plans(fleet, trace4, service_report):
    env, dags = fleet
    probs0 = [SimProblem.build(d, trace4.env_at(0)) for d in dags]
    cold = run_pso_ga_batch(probs0, FAST, seed=7)
    rep = run_service(dags, trace4, ServiceConfig(replan=RCFG), seed=7,
                      initial=cold)
    for x_s, x_b in zip(rep.plans, service_report.plans):
        np.testing.assert_array_equal(x_s, x_b)
    with pytest.raises(ValueError, match="initial"):
        run_service(dags, trace4, ServiceConfig(replan=RCFG), seed=7,
                    initial=cold[:1])


def test_service_reuses_compiled_runner(fleet, trace4, service_report):
    """The cache-discipline half of the watchdog design: a full service
    run re-traces NOTHING once the (config, traffic) entry exists."""
    _, dags = fleet
    reset_runner_cache_stats()
    run_service(dags, trace4, ServiceConfig(replan=RCFG), seed=7)
    stats = runner_cache_stats()
    assert stats["traces"] == 0
    assert stats["misses"] == 0
    assert stats["hits"] >= trace4.num_rounds


# ---------------------------------------------------------------------------
# chaos harness
# ---------------------------------------------------------------------------

def test_chaos_crash_is_retried_transparently(fleet, trace4,
                                              service_report):
    _, dags = fleet
    sleeps = []
    cfg = ServiceConfig(replan=RCFG, backoff_s=0.05,
                        chaos=ChaosConfig(crash_rounds=(1,)))
    rep = run_service(dags, trace4, cfg, seed=7, sleeper=sleeps.append)
    assert rep.counters["retries"] == 1
    assert rep.counters["crashes"] == 0      # the retry recovered
    assert rep.rounds[0].retries_used == 1
    np.testing.assert_allclose(sleeps, [0.05])
    # an injected crash before the solve must not perturb the plans
    for x_c, x_p in zip(rep.plans, service_report.plans):
        np.testing.assert_array_equal(x_c, x_p)


def test_chaos_persistent_crash_trips_breaker_and_pins(fleet):
    env, dags = fleet
    trace = zero_drift_trace(env, rounds=6)
    cfg = ServiceConfig(replan=RCFG, retries=1, breaker_threshold=2,
                        breaker_cooldown=2,
                        chaos=ChaosConfig(p_crash=1.0))
    rep = run_service(dags, trace, cfg, seed=7)
    # k=1,2 fail and trip; k=3,4 skipped while open; k=5 probe fails
    assert rep.counters["crashes"] == 3
    assert rep.counters["breaker_opened"] == 2
    assert [r.breaker_state for r in rep.rounds] == \
        ["closed", "closed", "open", "open", "open"]
    assert all(r.rung == ("pinned",) * 2 for r in rep.rounds)
    assert rep.fallback_counts["pinned"] == 10
    # pinned last-good plans keep the service fully available
    assert rep.availability() == 1.0
    for pr_dag, x in zip(dags, rep.plans):
        assert plan_is_valid(SimProblem.build(pr_dag, env), x)


def test_chaos_nan_env_falls_back_to_last_good(fleet, trace4):
    _, dags = fleet
    cfg = ServiceConfig(replan=RCFG,
                        chaos=ChaosConfig(nan_env_rounds=(1,)))
    rep = run_service(dags, trace4, cfg, seed=7)
    assert rep.counters["stale_env_rounds"] == 1
    assert rep.rounds[0].stale_env
    assert not rep.rounds[1].stale_env
    assert rep.availability() == 1.0
    for pr_dag, x in zip(dags, rep.plans):
        assert plan_is_valid(SimProblem.build(pr_dag, trace4.env_at(3)), x)


def test_chaos_stall_is_flagged_and_pinned(fleet):
    env, dags = fleet
    trace = zero_drift_trace(env, rounds=5)
    cfg = ServiceConfig(replan=RCFG, straggler_warmup=2,
                        treat_stalls_as_failures=True,
                        chaos=ChaosConfig(stall_rounds=(3,), stall_s=50.0))
    rep = run_service(dags, trace, cfg, seed=7)
    assert rep.counters["stalls_flagged"] == 1
    assert rep.rounds[2].stalled and rep.rounds[2].solver_failed
    assert rep.rounds[2].rung == ("pinned",) * 2
    assert rep.rounds[2].wall_s > 50.0
    assert not rep.rounds[3].stalled         # next round solves normally
    assert rep.rounds[3].rung == ("warm",) * 2


def test_chaos_mid_round_node_loss_revalidates(fleet):
    env, dags = fleet
    s_last = env.num_servers - 1
    trace = zero_drift_trace(env, rounds=3)
    cfg = ServiceConfig(replan=RCFG,
                        chaos=ChaosConfig(mid_round_down={2: s_last}))
    rep = run_service(dags, trace, cfg, seed=7)
    assert rep.availability() == 1.0
    down = _down_env(env, s_last)
    for dag, x in zip(dags, rep.plans):
        assert x is not None
        # the guarantee: served plans are valid on the env they RUN on
        assert plan_is_valid(SimProblem.build(dag, down), x)
    for r in rep.rounds:
        assert all(g in LADDER_RUNGS for g in r.rung)


def test_chaos_compound_suite_stays_available(fleet):
    """The acceptance gate: every fault class at once, deterministic, no
    raise, availability >= 99%, every served plan valid and finite."""
    env, dags = fleet
    trace = sample_trace("node-loss", env, rounds=8, seed=2)
    cfg = ServiceConfig(
        replan=RCFG, retries=2, treat_stalls_as_failures=True,
        straggler_warmup=2,
        chaos=ChaosConfig(crash_rounds=(2,), nan_env_rounds=(3,),
                          stall_rounds=(5,), stall_s=25.0,
                          mid_round_down={6: env.num_servers - 1}))
    rep = run_service(dags, trace, cfg, seed=7, sleeper=lambda s: None)
    assert rep.availability() >= 0.99
    assert sum(rep.fallback_counts.values()) == 7 * len(dags)
    assert rep.counters["stale_env_rounds"] == 1
    assert rep.counters["stalls_flagged"] == 1
    ttp = rep.time_to_plan()
    assert np.isfinite(ttp["p99"]) and ttp["p99"] > 0.0
    # determinism: the same chaos replays to the same plans
    rep2 = run_service(dags, trace, cfg, seed=7, sleeper=lambda s: None)
    for x1, x2 in zip(rep.plans, rep2.plans):
        np.testing.assert_array_equal(x1, x2)


# ---------------------------------------------------------------------------
# watchdog, triage, rate estimation
# ---------------------------------------------------------------------------

def test_watchdog_cuts_to_pinned_under_tiny_slo(fleet, trace4):
    _, dags = fleet
    cfg = ServiceConfig(replan=RCFG, burst=BURST, slo_s=1e-6)
    rep = run_service(dags, trace4, cfg, seed=7)
    # round 1 has no per-iteration estimate yet: it must run warm
    assert rep.rounds[0].rung == ("warm",) * 2
    assert rep.rounds[0].budget_iters == float("inf")
    # once the estimate exists, a 1 µs SLO can't fit any PSO rung
    for r in rep.rounds[1:]:
        assert r.rung == ("pinned",) * 2
        assert r.budget_iters < BURST.max_iters
        assert r.replan is None
    assert rep.counters["watchdog_cuts"] == len(rep.rounds) - 1
    assert rep.availability() == 1.0


def test_triage_rejects_unsavable_apps(fleet):
    env, _ = fleet
    dags = []
    for i, net in enumerate(("alexnet", "googlenet")):
        dag = zoo.build(net, pin_server=i)
        h, _ = heft_makespan(dag, env)
        # app 0 savable, app 1's deadline is impossible even for HEFT
        dags.append(dag.with_deadline(
            np.array([1.5 * h if i == 0 else 1e-4])))
    trace = zero_drift_trace(env, rounds=3)
    cfg = ServiceConfig(replan=RCFG_T, triage_margin=1.0)
    rep = run_service(dags, trace, cfg, seed=7)
    assert all(r.rejected_apps == 1 for r in rep.rounds)
    assert rep.counters["rejected_apps"] == 2
    # triage masks arrivals; the plans themselves still get served
    assert rep.availability() == 1.0
    no_triage = run_service(dags, trace,
                            ServiceConfig(replan=RCFG_T), seed=7)
    assert no_triage.counters["rejected_apps"] == 0


def test_estimate_rates_solves_on_observed_arrivals(fleet):
    env, dags = fleet
    trace = sample_trace("load-surge", env, rounds=4, seed=5)
    cfg = ServiceConfig(replan=RCFG_T, estimate_rates=True,
                        window_rounds=2)
    rep = run_service(dags, trace, cfg, seed=7)
    assert all(len(r.est_rates) == len(dags) for r in rep.rounds)
    assert all(e > 0.0 for r in rep.rounds for e in r.est_rates)
    assert all(r.rung == ("warm",) * 2 for r in rep.rounds)
    assert rep.availability() == 1.0
    for dag, x in zip(dags, rep.plans):
        assert plan_is_valid(SimProblem.build(dag, trace.env_at(3)), x)


def test_estimate_rates_records_per_dag_estimates(fleet):
    """Regression: the round log must carry ONE estimate per DAG. The
    old scalar ``est_rate`` field was overwritten each DAG iteration,
    so only the last DAG's estimate survived into the record."""
    env, base = fleet
    # genuinely heterogeneous: a 1-app DAG and a 2-app merged DAG, at a
    # rate low enough that draws do NOT saturate max_requests (a
    # saturated window estimates the same per-app rate for everyone)
    dags = [base[0], merge_dags(list(base))]
    tc = TrafficConfig(rate=0.05, horizon=10.0, max_requests=4,
                       mc_solver=2, mc_eval=4)
    trace = sample_trace("load-surge", env, rounds=4, seed=5)
    cfg = ServiceConfig(replan=ReplanConfig(pso=FAST, traffic=tc),
                        estimate_rates=True, window_rounds=2)
    rep = run_service(dags, trace, cfg, seed=7)

    # replay the observation stream independently: the log's tuple must
    # match the per-DAG sliding windows element for element
    wins = [_RateWindow(2, tc.horizon, d.num_apps) for d in dags]
    for r in rep.rounds:
        expected = []
        for i, d in enumerate(dags):
            obs = tc.solver_arrivals(
                d.num_apps, seed=7 + 7919 * r.round + 31 * i,
                rate_scale=trace.events[r.round].load_scale)[0]
            wins[i].ingest(obs)
            expected.append(wins[i].rate())
        assert r.est_rates == pytest.approx(tuple(expected))
    # the per-DAG estimates genuinely differ on some round, so a single
    # scalar cannot represent the record
    assert any(r.est_rates[0] != r.est_rates[1] for r in rep.rounds)
