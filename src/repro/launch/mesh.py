"""Mesh construction. Functions only — importing this module never touches
jax device state (jax locks the device count on first backend init, and
the dry-run must set XLA_FLAGS before that happens)."""
from __future__ import annotations

from typing import Optional, Tuple

import jax

__all__ = ["make_production_mesh", "make_test_mesh", "data_axes_of",
           "data_shard_count", "resolve_mesh"]


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """The target fleet: one v5e pod = 16x16 = 256 chips, axes
    (data, model); multi-pod = 2 pods = 512 chips with a leading "pod"
    axis (DCN-connected)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(*, multi_pod: bool = False,
                   devices=None) -> jax.sharding.Mesh:
    """Scaled-down mesh with the same axis structure for CI (8 host
    devices: (2,2,2) or (4,2))."""
    import numpy as np
    devices = list(devices if devices is not None else jax.devices())
    n = len(devices)
    if multi_pod:
        model = 2
        pod = 2
        if n < pod * model:
            # without this gate the data axis rounds to ZERO and the
            # reshape below dies with an opaque size mismatch — name the
            # actual requirement instead (tests/test_fleet.py pins it).
            raise ValueError(
                f"make_test_mesh(multi_pod=True) needs at least "
                f"{pod * model} devices (pod=2 x model=2 with a "
                f"non-empty data axis); only {n} available")
        data = n // (pod * model)
        shape: Tuple[int, ...] = (pod, data, model)
        axes: Tuple[str, ...] = ("pod", "data", "model")
    else:
        model = 2 if n % 2 == 0 else 1
        data = n // model
        shape = (data, model)
        axes = ("data", "model")
    total = 1
    for s in shape:
        total *= s
    arr = np.array(devices[:total]).reshape(shape)
    return jax.sharding.Mesh(arr, axes)


def data_axes_of(mesh: jax.sharding.Mesh) -> Tuple[str, ...]:
    """Batch-sharding axes: ("pod","data") on a multi-pod mesh."""
    return tuple(a for a in mesh.axis_names if a != "model")


def data_shard_count(mesh: jax.sharding.Mesh) -> int:
    """How many ways the problem axis splits on ``mesh`` — the product
    of every non-"model" axis size. The fleet solver pads each shape
    bucket's N up to a multiple of this (DESIGN.md §12)."""
    count = 1
    for a in data_axes_of(mesh):
        count *= int(mesh.shape[a])
    return count


def resolve_mesh(name: Optional[str]) -> Optional[jax.sharding.Mesh]:
    """CLI spelling -> mesh: "none"/None (single-device fleet solve),
    "host" (the scaled-down test mesh over the visible host devices),
    "prod" (the 16x16 v5e pod — needs 256 real chips)."""
    if name is None or name == "none":
        return None
    if name == "host":
        return make_test_mesh()
    if name == "prod":
        return make_production_mesh()
    raise ValueError(f"unknown mesh {name!r} "
                     f"(expected one of: none, host, prod)")
