"""Paper Fig. 7 — system cost of PSO-GA / GA / Greedy / prePSO for ONE
DNN per end device (10 DNNs), per net type x deadline multiplier."""
from __future__ import annotations

import argparse

from .common import ALGOS, PAPER, QUICK, RATIOS, print_csv, run_cell

NETS = ("alexnet", "vgg19", "googlenet", "resnet101")


#: CPU-budget trims for the deepest problems (full 5-ratio sweeps via
#: --paper-protocol); orderings are asserted per-cell so nothing is lost.
RATIO_TRIM = {
    1: {"resnet101": (1.5, 3.0, 8.0)},
    3: {"googlenet": (1.5, 3.0, 8.0), "resnet101": ()},
}


def run(nets=NETS, ratios=RATIOS, algos=tuple(ALGOS), proto=QUICK,
        per_device: int = 1):
    rows = []
    trim = RATIO_TRIM.get(per_device, {})
    for net in nets:
        net_ratios = trim.get(net, ratios)
        if not net_ratios:
            print(f"# {net} x{per_device}/device skipped "
                  f"(10k-layer problem; --paper-protocol runs it)",
                  flush=True)
            continue
        for ratio in net_ratios:
            for algo in algos:
                r = run_cell(net, per_device, ratio, algo, proto)
                rows.append(r)
                print(f"# {net} r={ratio} {algo}: cost={r['cost']:.5f} "
                      f"feas={r['feasible_frac']:.2f} "
                      f"({r['wall_s']:.1f}s)", flush=True)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--paper", action="store_true")
    ap.add_argument("--nets", nargs="*", default=list(NETS))
    args = ap.parse_args()
    rows = run(nets=args.nets, proto=PAPER if args.paper else QUICK)
    print_csv(rows, ["net", "ratio", "algo", "layers", "cost",
                     "feasible_frac", "wall_s"])


if __name__ == "__main__":
    main()
