"""Algorithm 1 (cut-edge merging) properties + the paper's zoo anchors."""
import numpy as np
from hypo_compat import given, st

from repro.core import merge_dags, preprocess, zoo
from repro.core.dag import topological_order
from tests.test_simulator import random_dag


@given(seed=st.integers(0, 10_000), p=st.integers(2, 30))
def test_preprocess_preserves_compute_and_acyclicity(seed, p):
    rng = np.random.default_rng(seed)
    dag = random_dag(rng, p)
    small, group = preprocess(dag)
    np.testing.assert_allclose(small.total_compute(), dag.total_compute())
    small.validate_acyclic()
    # group maps every original layer to a valid merged layer
    assert group.shape == (p,)
    assert group.min() >= 0 and group.max() < small.num_layers
    # merged endpoints of every surviving edge differ
    if small.num_edges:
        assert np.all(small.edges[:, 0] != small.edges[:, 1])


@given(seed=st.integers(0, 10_000), p=st.integers(2, 30))
def test_preprocess_fixed_point(seed, p):
    """After preprocessing no intra-app cut-edge remains (Alg. 1 step 3)."""
    rng = np.random.default_rng(seed)
    dag = random_dag(rng, p)
    small, _ = preprocess(dag)
    out_deg = small.out_degree()
    in_deg = small.in_degree()
    for (u, v) in small.edges:
        same_app = small.app_id[u] == small.app_id[v]
        assert not (out_deg[u] == 1 and in_deg[v] == 1 and same_app)


def test_chain_collapses_to_single_layer():
    """VGG19/AlexNet are chains -> prePSO's one-node degenerate case."""
    for name in ("alexnet", "vgg19"):
        dag = zoo.build(name)
        small, group = preprocess(dag)
        assert small.num_layers == 1, name
        assert np.all(group == 0)


def test_googlenet_compression_ratio():
    """Paper: ~48% of GoogleNet layers are compressed."""
    dag = zoo.googlenet()
    small, _ = preprocess(dag)
    ratio = 1 - small.num_layers / dag.num_layers
    assert 0.35 <= ratio <= 0.60, ratio


def test_resnet_residuals_not_merged_through_adds():
    dag = zoo.resnet101()
    small, _ = preprocess(dag)
    # residual adds have in-degree 2: they can merge with their successor
    # chain but branch points persist -> strictly more than 1 layer
    assert 1 < small.num_layers < dag.num_layers


def test_merge_dags_offsets():
    a = zoo.alexnet(pin_server=0)
    b = zoo.alexnet(pin_server=1)
    merged = merge_dags([a, b])
    assert merged.num_layers == a.num_layers * 2
    assert merged.num_apps == 2
    assert merged.pinned[0] == 0
    assert merged.pinned[a.num_layers] == 1
    assert set(np.unique(merged.app_id)) == {0, 1}
    merged.validate_acyclic()


def test_zoo_anchors():
    """Paper §V anchors: AlexNet 11 layers, max inter-layer dataset
    < 1.1 MB; ResNet101 deep; all acyclic with pinned input."""
    a = zoo.alexnet()
    assert a.num_layers == 11
    assert a.edge_mb.max() <= 1.1
    v = zoo.vgg19()
    assert v.num_layers == 25
    r = zoo.resnet101()
    assert r.num_layers > 300
    g = zoo.googlenet()
    for dag in (a, v, r, g):
        dag.validate_acyclic()
        assert dag.pinned[0] == 0 and np.all(dag.pinned[1:] == -1)
        order = topological_order(dag)
        assert order.shape[0] == dag.num_layers
