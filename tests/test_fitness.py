"""The 3-case fitness key (paper Eq. 14-16) as a single scalar order."""
import jax.numpy as jnp
from hypo_compat import given, st

from repro.core import INFEASIBLE_OFFSET, fitness_key
from repro.core.simulator import SimResult


def mk_result(cost: float, total_time: float, feasible: bool) -> SimResult:
    return SimResult(
        end_times=jnp.zeros(1), app_completion=jnp.asarray([total_time]),
        comp_cost=jnp.asarray(cost), trans_cost=jnp.asarray(0.0),
        total_cost=jnp.asarray(cost), feasible=jnp.asarray(feasible),
        makespan=jnp.asarray(total_time))


@given(c1=st.floats(0, 1e3), c2=st.floats(0, 1e3))
def test_case1_both_feasible_cheaper_wins(c1, c2):
    k1 = float(fitness_key(mk_result(c1, 1.0, True)))
    k2 = float(fitness_key(mk_result(c2, 99.0, True)))
    assert (k1 < k2) == (c1 < c2) or c1 == c2


@given(c=st.floats(0, 1e3), t=st.floats(0, 1e9))
def test_case2_feasible_beats_infeasible(c, t):
    kf = float(fitness_key(mk_result(c, 1.0, True)))
    ki = float(fitness_key(mk_result(0.0, t, False)))
    assert kf < ki


@given(t1=st.floats(0.0, 1e9), t2=st.floats(0.0, 1e9))
def test_case3_both_infeasible_faster_wins(t1, t2):
    k1 = float(fitness_key(mk_result(0.0, t1, False)))
    k2 = float(fitness_key(mk_result(0.0, t2, False)))
    if abs(t1 - t2) > 1e-3 * max(t1, t2, 1.0):
        assert (k1 < k2) == (t1 < t2)


def test_offset_dominates_costs():
    assert INFEASIBLE_OFFSET > 1e3
