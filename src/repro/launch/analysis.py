"""Roofline analysis from compiled artifacts (DESIGN.md §7).

This container is CPU-only; TPU v5e is the TARGET. The three roofline
terms are derived per (arch x shape x mesh) cell from the dry-run's
compiled module:

    compute    = HLO_FLOPs_per_chip / PEAK_FLOPS          [s]
    memory     = HLO_bytes_per_chip / HBM_BW              [s]
    collective = collective_bytes_per_chip / ICI_BW       [s]

``compiled.cost_analysis()`` gives per-chip FLOPs / bytes (the SPMD
partitioned program is per-device). Collective bytes are NOT in
cost_analysis — ``collective_bytes`` parses the partitioned HLO text and
sums, for every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute, the bytes that cross links *per device*:

    all-gather      (group-1)/group x result bytes   (receives all shards)
    all-reduce      2 x (group-1)/group x bytes      (ring RS + AG)
    reduce-scatter  (group-1)/group x input bytes
    all-to-all      (group-1)/group x bytes
    collective-permute  result bytes

Group sizes parse from both replica_groups formats ({{0,1},...} and the
iota [G,S]<=[N] form). On the multi-pod mesh, groups that span pods are
priced at DCN bandwidth (the "pod" axis rides data-center network, not
ICI).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Tuple

__all__ = ["HW", "collective_bytes", "CollectiveStats", "roofline_terms",
           "parse_hlo_collectives"]


@dataclasses.dataclass(frozen=True)
class HW:
    """TPU v5e (per chip)."""
    peak_flops: float = 197e12        # bf16
    hbm_bw: float = 819e9             # B/s
    ici_bw: float = 50e9              # B/s per link
    dcn_bw: float = 25e9              # B/s inter-pod
    hbm_bytes: float = 16e9


_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:\w+\[[\d,]*\]\S*))\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_GROUPS_ITOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _shape_bytes(text: str) -> int:
    """Sum bytes over every shape token in a result (handles tuples)."""
    total = 0
    for m in _SHAPE_RE.finditer(text):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    per_op: Dict[str, float]
    total_ici: float                  # per-device bytes over ICI
    total_dcn: float                  # per-device bytes over DCN
    count: int

    @property
    def total(self) -> float:
        return self.total_ici + self.total_dcn


def parse_hlo_collectives(hlo: str) -> List[Tuple[str, int, int, str]]:
    """Returns [(op, result_bytes, group_size, line)] for each collective."""
    out = []
    for line in hlo.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        res_bytes = _shape_bytes(m.group(1))
        op = m.group(2)
        group = 1
        gi = _GROUPS_ITOTA_RE.search(line)
        if gi:
            group = int(gi.group(2))
        else:
            gl = _GROUPS_LIST_RE.search(line)
            if gl:
                group = len([x for x in gl.group(1).split(",") if x.strip()])
        out.append((op, res_bytes, group, line))
    return out


def collective_bytes(hlo: str, pod_size: int = 0) -> CollectiveStats:
    """Per-device link bytes. ``pod_size``: devices per pod (0 = single
    pod); a group crossing a pod boundary is priced as DCN."""
    per_op: Dict[str, float] = {}
    ici = dcn = 0.0
    ops = parse_hlo_collectives(hlo)
    for op, res_bytes, group, line in ops:
        g = max(group, 1)
        frac = (g - 1) / g
        if op == "all-gather":
            b = frac * res_bytes
        elif op == "all-reduce":
            b = 2.0 * frac * res_bytes
        elif op == "reduce-scatter":
            b = frac * res_bytes * g          # input volume per device
        elif op == "all-to-all":
            b = frac * res_bytes
        else:                                  # collective-permute
            b = float(res_bytes)
        per_op[op] = per_op.get(op, 0.0) + b
        crosses_pod = bool(pod_size) and _group_crosses_pod(line, g,
                                                            pod_size)
        if crosses_pod:
            dcn += b
        else:
            ici += b
    return CollectiveStats(per_op=per_op, total_ici=ici, total_dcn=dcn,
                           count=len(ops))


def _group_crosses_pod(line: str, group: int, pod_size: int) -> bool:
    """Heuristic pod-crossing test.

    Explicit lists: check ids of the first group straddle a pod boundary.
    Iota form [G,S]<=[dims]T(perm): a group crosses pods iff the iota
    device order interleaves pods within a group — detectable from the
    fastest-varying transposed dims; we conservatively flag any group
    whose SPAN (max-min of the first explicit group) >= pod_size, and for
    iota forms flag when group*stride patterns must include both pods
    (group size > pod_size, or the leading reshape dim participates).
    """
    gl = _GROUPS_LIST_RE.search(line)
    if gl:
        ids = [int(x) for x in gl.group(1).split(",") if x.strip()]
        if not ids:
            return False
        return (max(ids) // pod_size) != (min(ids) // pod_size)
    gi = _GROUPS_ITOTA_RE.search(line)
    if gi:
        n_total = 1
        for d in gi.group(3).split(","):
            n_total *= int(d)
        if n_total <= pod_size:
            return False
        if group > pod_size:
            return True
        # iota groups of size S are consecutive in the (possibly
        # transposed) device order; with a transpose the stride across the
        # leading (pod) dim lands inside groups. Conservative: transposed
        # iota on a >1-pod fleet crosses pods unless the group fits the
        # innermost contiguous run.
        return "T(" in line
    return False


def roofline_terms(flops_per_chip: float, hbm_bytes_per_chip: float,
                   coll: CollectiveStats, hw: HW = HW()) -> Dict[str, float]:
    compute = flops_per_chip / hw.peak_flops
    memory = hbm_bytes_per_chip / hw.hbm_bw
    collective = coll.total_ici / hw.ici_bw + coll.total_dcn / hw.dcn_bw
    dominant = max((("compute", compute), ("memory", memory),
                    ("collective", collective)), key=lambda kv: kv[1])[0]
    return {"compute_s": compute, "memory_s": memory,
            "collective_s": collective, "dominant": dominant}
