"""Plan cache (repro.core.plancache, DESIGN.md §11 phase 2): key
bucketing, the replay-exact revalidation gate, LRU eviction, the
service-loop integration (cache-hit rounds bit-identical to fresh
solves), chaos composition, and multi-service runner-cache sharing."""
import dataclasses

import numpy as np
import pytest

from repro.core import (ChaosConfig, PlanCache, PlanCacheConfig,
                        PSOGAConfig, ReplanConfig, ServiceConfig,
                        SimProblem, dag_fingerprint, plan_is_valid,
                        run_service, run_services, sample_environment,
                        sample_trace, simulate_np, zero_drift_trace)
from repro.core.batch import reset_runner_cache_stats, runner_cache_stats
from repro.core.dag import LayerDAG

#: a converged configuration: the quickstart's 4-layer DAG is small
#: enough that warm PSO finds (and keeps) the optimum from round 1, so
#: cache-off rounds replan nothing — the precondition for bit-identity.
FAST = PSOGAConfig(pop_size=24, max_iters=60, stall_iters=20)
RCFG = ReplanConfig(pso=FAST)


def _tiny_dag(env, pin):
    return LayerDAG(
        compute=np.array([1.1, 1.92, 2.35, 2.12]) * env.power[0],
        edges=np.array([[0, 1], [0, 2], [1, 3], [2, 3]]),
        edge_mb=np.array([1.0, 1.0, 0.5, 0.5]),
        app_id=np.zeros(4, np.int32), deadline=np.array([3.7]),
        pinned=np.array([pin, -1, -1, -1], np.int32))


@pytest.fixture(scope="module")
def tiny_fleet():
    env = sample_environment()
    return env, [_tiny_dag(env, 0), _tiny_dag(env, 1)]


# ---------------------------------------------------------------------------
# config + key unit tests
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kwargs,match", [
    ({"capacity": 0}, "capacity"),
    ({"env_quant": 0.0}, "env_quant"),
    ({"env_quant": float("nan")}, "env_quant"),
    ({"load_quant": -0.1}, "load_quant"),
])
def test_plan_cache_config_rejects(kwargs, match):
    with pytest.raises(ValueError, match=match):
        PlanCacheConfig(**kwargs)


def test_dag_fingerprint_tracks_content(tiny_fleet):
    env, (d0, d1) = tiny_fleet
    assert dag_fingerprint(d0) == dag_fingerprint(_tiny_dag(env, 0))
    assert dag_fingerprint(d0) != dag_fingerprint(d1)   # pins differ
    fatter = dataclasses.replace(d0, edge_mb=d0.edge_mb * 2.0)
    assert dag_fingerprint(d0) != dag_fingerprint(fatter)


def test_key_buckets_env_and_load(tiny_fleet):
    env, (d0, _) = tiny_fleet
    cache = PlanCache(PlanCacheConfig(env_quant=0.05, load_quant=0.1))
    k = cache.key(d0, env)
    # inside the quantization step: same bucket
    near = dataclasses.replace(
        env, bandwidth=np.asarray(env.bandwidth, float) * 1.001)
    assert cache.key(d0, near) == k
    # an order-of-magnitude fade: different bucket
    far = dataclasses.replace(
        env, bandwidth=np.asarray(env.bandwidth, float) * 0.5)
    assert cache.key(d0, far) != k
    # a severed link lands in the sentinel bucket, not log(0)
    bw = np.asarray(env.bandwidth, float).copy()
    bw[0, 1] = 0.0
    assert cache.key(d0, dataclasses.replace(env, bandwidth=bw)) != k
    # load buckets quantize the same way
    assert cache.key(d0, env, 1.0) == cache.key(d0, env, 1.01)
    assert cache.key(d0, env, 1.0) != cache.key(d0, env, 2.0)


def test_key_rejects_bad_inputs(tiny_fleet):
    env, (d0, _) = tiny_fleet
    cache = PlanCache()
    for bad in (0.0, -1.0, float("nan"), float("inf")):
        with pytest.raises(ValueError, match="load_scale"):
            cache.key(d0, env, bad)
    bw = np.asarray(env.bandwidth, float).copy()
    bw[0, -1] = np.nan
    with pytest.raises(ValueError, match="NaN"):
        cache.key(d0, dataclasses.replace(env, bandwidth=bw))


# ---------------------------------------------------------------------------
# store / lookup / revalidation gate
# ---------------------------------------------------------------------------

def test_store_lookup_roundtrip_and_gate(tiny_fleet):
    env, (d0, _) = tiny_fleet
    prob = SimProblem.build(d0, env)
    plan = np.array([0, 1, 1, 1], np.int32)
    assert plan_is_valid(prob, plan)
    cache = PlanCache()
    key = cache.key(d0, env)
    assert cache.store(key, prob, plan)
    got = cache.lookup(key, prob)
    assert got is not None and np.array_equal(got, plan)
    assert cache.stats()["hits"] == 1

    # env drifted INSIDE the bucket: the key still matches but the
    # replayed cost changes, so the gate drops the entry — a hit is
    # never served against an env it would score differently on.
    near = dataclasses.replace(
        env, bandwidth=np.asarray(env.bandwidth, float) * 1.001)
    assert cache.key(d0, near) == key
    assert cache.lookup(key, SimProblem.build(d0, near)) is None
    st = cache.stats()
    assert st["revalidation_failures"] == 1 and st["misses"] == 1
    assert len(cache) == 0                      # entry dropped


def test_store_rejects_invalid_plans(tiny_fleet):
    env, (d0, _) = tiny_fleet
    prob = SimProblem.build(d0, env)
    cache = PlanCache()
    key = cache.key(d0, env)
    bad = np.array([1, 1, 1, 1], np.int32)      # violates the pin
    assert not cache.store(key, prob, bad)
    assert cache.stats()["store_rejects"] == 1 and len(cache) == 0


def test_lookup_fleet_is_all_or_nothing(tiny_fleet):
    env, (d0, d1) = tiny_fleet
    p0, p1 = SimProblem.build(d0, env), SimProblem.build(d1, env)
    cache = PlanCache()
    k0, k1 = cache.key(d0, env), cache.key(d1, env)
    cache.store(k0, p0, np.array([0, 1, 1, 1], np.int32))
    # only one of two problems cached: the whole fleet lookup misses
    assert cache.lookup_fleet([k0, k1], [p0, p1]) is None
    assert cache.stats()["misses"] == 2 and cache.stats()["hits"] == 0
    with pytest.raises(ValueError, match="keys"):
        cache.lookup_fleet([k0], [p0, p1])


def test_lru_eviction_respects_capacity(tiny_fleet):
    env, (d0, _) = tiny_fleet
    prob = SimProblem.build(d0, env)
    plan = np.array([0, 1, 1, 1], np.int32)
    cache = PlanCache(PlanCacheConfig(capacity=2))
    keys = [cache.key(d0, env, s) for s in (1.0, 2.0, 4.0)]
    cache.store(keys[0], prob, plan)
    cache.store(keys[1], prob, plan)
    assert cache.lookup(keys[0], prob) is not None   # bump key 0
    cache.store(keys[2], prob, plan)                 # evicts key 1 (LRU)
    assert len(cache) == 2 and cache.stats()["evictions"] == 1
    assert set(cache.keys()) == {keys[0], keys[2]}
    assert cache.lookup(keys[1], prob) is None


# ---------------------------------------------------------------------------
# service integration: cache hits are bit-identical to fresh solves
# ---------------------------------------------------------------------------

def test_cached_rounds_bit_identical_to_fresh_solves(tiny_fleet):
    env, dags = tiny_fleet
    trace = zero_drift_trace(env, rounds=4)
    off = run_service(dags, trace, ServiceConfig(replan=RCFG), seed=11)
    # precondition: the problem is converged — every cache-off round
    # keeps the incumbent, so serving the stored plan CAN be identical
    assert all(not r.replan.replanned.any() for r in off.rounds)

    on = run_service(dags, trace,
                     ServiceConfig(replan=RCFG,
                                   plan_cache=PlanCacheConfig()),
                     seed=11)
    # round 1 misses (cold cache) and stores; every repeat round hits
    assert on.rounds[0].rung == ("warm", "warm")
    assert not on.rounds[0].cache_hit
    for r in on.rounds[1:]:
        assert r.cache_hit and r.rung == ("cached", "cached")
        assert r.replan is None                 # replan_round skipped
    st = on.cache_stats
    assert st["stores"] == 2 and st["misses"] == 2
    assert st["hits"] == 2 * (len(on.rounds) - 1)
    assert st["revalidation_failures"] == 0

    # the served plans — and their replayed costs — match bit for bit
    assert on.availability() == 1.0
    for x_on, x_off, d in zip(on.plans, off.plans, dags):
        assert np.array_equal(x_on, x_off)
        prob = SimProblem.build(d, trace.env_at(trace.num_rounds - 1))
        assert (float(simulate_np(prob, x_on).total_cost)
                == float(simulate_np(prob, x_off).total_cost))


def test_env_drift_outside_bucket_misses(tiny_fleet):
    env, dags = tiny_fleet
    trace = sample_trace("wifi-fade", env, rounds=4, seed=3)
    cfg_off = ServiceConfig(replan=RCFG)
    cfg_on = ServiceConfig(replan=RCFG, plan_cache=PlanCacheConfig())
    off = run_service(dags, trace, cfg_off, seed=11)
    on = run_service(dags, trace, cfg_on, seed=11)
    # every epoch is a distinct env bucket: no hits, and the cache
    # changes nothing about what gets served
    assert on.cache_stats["hits"] == 0
    assert not any(r.cache_hit for r in on.rounds)
    for x_on, x_off in zip(on.plans, off.plans):
        assert np.array_equal(x_on, x_off)


def test_node_loss_invalidation_composes_with_cache(tiny_fleet):
    """Mid-round churn after a cache hit: the cached plan must still
    pass the ladder's ``_plan_ok`` gate against the POST-churn env, and
    an invalidated one re-ladders instead of being served stale."""
    env, dags = tiny_fleet
    trace = zero_drift_trace(env, rounds=4)
    # find a server the round-1 plans actually route through
    base = run_service(dags, trace, ServiceConfig(replan=RCFG), seed=11)
    pins = {0, 1}
    used = sorted(set(int(s) for x in base.plans for s in x) - pins)
    assert used, "tiny plans collapsed onto the pinned servers"
    down = used[0]
    rep = run_service(
        dags, trace,
        ServiceConfig(replan=RCFG, plan_cache=PlanCacheConfig(),
                      chaos=ChaosConfig(mid_round_down={2: down})),
        seed=11)
    r2 = rep.rounds[1]      # round 2: lookup hits, then the churn lands
    assert r2.cache_hit
    assert any(g != "cached" for g in r2.rung)   # at least one re-laddered
    assert rep.availability() == 1.0
    # final plans are still valid against the (restored) live env
    for d, x in zip(dags, rep.plans):
        assert x is not None
        assert plan_is_valid(SimProblem.build(d, trace.env_at(3)), x)


# ---------------------------------------------------------------------------
# multi-service sharing (run_services)
# ---------------------------------------------------------------------------

def test_run_services_share_one_compiled_runner(tiny_fleet):
    env, dags = tiny_fleet
    trace = zero_drift_trace(env, rounds=3)
    #: distinct from every other test config so this fleet's solves are
    #: fresh runner-cache entries
    pso = PSOGAConfig(pop_size=18, max_iters=40, stall_iters=15)
    cfg = ServiceConfig(replan=ReplanConfig(pso=pso))

    reset_runner_cache_stats()
    reports = run_services([dags] * 3, trace, cfg, seeds=5)
    st = runner_cache_stats()
    solo = run_service(dags, trace, cfg, seed=5)
    # one compiled program per (cfg, bucket, mesh) ACROSS services: both
    # tiny DAGs share one size bucket, so exactly one miss + one trace
    # even with three loops dispatching concurrently
    assert st["misses"] == 1 and st["traces"] == 1
    assert st["hits"] > 0
    # and sharing the runner pool never leaks across solves: each
    # service's report is bit-identical to running alone
    for rep in reports:
        assert rep.availability() == 1.0
        for x, x_solo in zip(rep.plans, solo.plans):
            assert np.array_equal(x, x_solo)


def test_run_services_broadcast_validation(tiny_fleet):
    env, dags = tiny_fleet
    trace = zero_drift_trace(env, rounds=2)
    with pytest.raises(ValueError, match="seeds"):
        run_services([dags] * 2, trace, seeds=[1, 2, 3])
    assert run_services([], trace) == []


def test_run_services_shared_plan_cache(tiny_fleet):
    """Three services over one shared cache: after the first solve
    lands, repeat scenarios hit across service boundaries."""
    env, dags = tiny_fleet
    trace = zero_drift_trace(env, rounds=3)
    cache = PlanCache()
    cfg = ServiceConfig(replan=RCFG, plan_cache=PlanCacheConfig())
    reports = run_services([dags] * 3, trace, cfg, seeds=11,
                           plan_cache=cache)
    st = cache.stats()
    assert st["hits"] + st["misses"] == 3 * 2 * 2   # 3 services × 2 rounds × 2 dags
    assert st["hits"] >= 2 * 2      # at least this service's own repeats
    for rep in reports:
        assert rep.availability() == 1.0
        assert rep.cache_stats is not None
