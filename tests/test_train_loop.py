"""End-to-end trainer: loss goes down, checkpoint-restart is bit-exact,
grad compression trains, straggler counter wires through."""
import numpy as np

from repro.configs import get
from repro.configs.base import ShapeSpec
from repro.launch.train import Trainer, TrainerConfig
from repro.optim import AdamWConfig
from repro.runtime import FailureInjector

SHAPE = ShapeSpec("test", 64, 4, "train")
ACFG = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=24,
                   weight_decay=0.01)


def small_cfg():
    return get("qwen3-0.6b").reduced()


def test_loss_decreases(tmp_path):
    t = Trainer(small_cfg(), SHAPE,
                TrainerConfig(steps=15, ckpt_dir=None, log_every=1),
                ACFG)
    out = t.train()
    losses = [m["loss"] for m in out["metrics"]]
    assert out["final_step"] == 14
    assert losses[-1] < losses[0]


def test_crash_restart_resumes_exactly(tmp_path):
    """Training with an injected crash at step 8 must land on the same
    final loss as an uninterrupted run (stateless data + checkpoints)."""
    k = dict(steps=12, ckpt_every=4, keep_n=5, log_every=1)
    clean = Trainer(small_cfg(), SHAPE,
                    TrainerConfig(ckpt_dir=str(tmp_path / "a"), **k), ACFG)
    out_clean = clean.train()

    crashy = Trainer(small_cfg(), SHAPE,
                     TrainerConfig(ckpt_dir=str(tmp_path / "b"), **k),
                     ACFG, injector=FailureInjector(fail_at=(8,)))
    out_crash = crashy.train()

    assert out_clean["final_step"] == out_crash["final_step"] == 11
    l1 = [m for m in out_clean["metrics"] if m["step"] == 11][0]["loss"]
    l2 = [m for m in out_crash["metrics"] if m["step"] == 11][0]["loss"]
    np.testing.assert_allclose(l1, l2, rtol=1e-5)


def test_grad_accumulation_matches_full_batch():
    """accum=2 over the same global batch ~= accum=1 (mean-of-grads)."""
    t1 = Trainer(small_cfg(), SHAPE,
                 TrainerConfig(steps=6, accum=1, log_every=1), ACFG)
    o1 = t1.train()
    t2 = Trainer(small_cfg(), SHAPE,
                 TrainerConfig(steps=6, accum=2, log_every=1), ACFG)
    o2 = t2.train()
    l1 = [m["loss"] for m in o1["metrics"]]
    l2 = [m["loss"] for m in o2["metrics"]]
    np.testing.assert_allclose(l1, l2, rtol=2e-3)


def test_compressed_grads_still_train():
    t = Trainer(small_cfg(), SHAPE,
                TrainerConfig(steps=12, compress_grads=True, log_every=1),
                ACFG)
    out = t.train()
    losses = [m["loss"] for m in out["metrics"]]
    assert losses[-1] < losses[0]
