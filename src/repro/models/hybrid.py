"""Attention-free Mamba2 LM and the Zamba2 hybrid.

MambaLM: embed → N× mamba2 blocks (scan, remat) → norm → tied head.

Zamba2LM: groups of ``hybrid_attn_every`` mamba2 blocks punctuated by ONE
*shared* attention+MLP block (one parameter set, reused at every site —
Zamba2's signature trick; the per-site LoRA deltas of the released model
are omitted, see DESIGN.md). Each site keeps its own KV cache. Layout:
  [ (mamba ×k, shared-attn) × n_groups, mamba ×tail ]
n_layers counts the mamba blocks (81 = 13 groups of 6 + 3 tail).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..configs.base import ModelConfig
from . import attention as attn
from .layers import (Params, cross_entropy, divisible, embed_init,
                     embed_pspec, mlp_apply, mlp_init, mlp_pspec, rms_norm,
                     scan_blocks, stack_layers)
from .ssm import (init_ssm_state, mamba_decode, mamba_init, mamba_pspec,
                  mamba_seq, ssm_state_pspec)
from .transformer import REMAT_POLICY, _with_leading, mesh_tp

__all__ = ["MambaLM", "Zamba2LM"]


def _mamba_block_init(key, cfg, dtype):
    k1, _ = jax.random.split(key)
    return {"ln": jnp.zeros((cfg.d_model,), dtype),
            "mamba": mamba_init(k1, cfg, dtype)}


def _mamba_block_pspec(cfg, tp=None):
    return {"ln": P(None), "mamba": mamba_pspec(cfg, tp)}


class MambaLM:
    def __init__(self, cfg: ModelConfig, mesh=None,
                 data_axes: Tuple[str, ...] = ("data",), **_):
        self.cfg = cfg
        self.tp = mesh_tp(mesh)
        self.data_axes = data_axes
        self.dtype = jnp.dtype(cfg.dtype)

    def init(self, rng: jax.Array) -> Params:
        cfg = self.cfg
        k_emb, k_blocks = jax.random.split(rng)
        return {
            "embed": embed_init(k_emb, cfg.vocab, cfg.d_model, self.dtype),
            "blocks": stack_layers(
                lambda k: _mamba_block_init(k, cfg, self.dtype), k_blocks,
                cfg.n_layers),
            "final_norm": jnp.zeros((cfg.d_model,), self.dtype),
        }

    def param_pspecs(self) -> Params:
        return {"embed": embed_pspec(self.cfg.vocab, self.tp),
                "blocks": _with_leading(
                    _mamba_block_pspec(self.cfg, self.tp), 1),
                "final_norm": P(None)}

    def _head(self, params, h):
        h = rms_norm(h, params["final_norm"], self.cfg.norm_eps)
        return h @ params["embed"].T

    def forward(self, params, batch, with_cache=False):
        cfg = self.cfg
        x = params["embed"][batch["tokens"]] * jnp.asarray(
            cfg.d_model ** 0.5, self.dtype)

        def body_fn(x, p_l):
            y, (conv, ssm) = mamba_seq(p_l["mamba"],
                                       rms_norm(x, p_l["ln"], cfg.norm_eps),
                                       cfg)
            return x + y, ((conv, ssm) if with_cache else None)

        body = jax.checkpoint(body_fn, policy=REMAT_POLICY) \
            if cfg.remat else body_fn
        x, states = scan_blocks(body, x, params["blocks"],
                                cfg.scan_layers)
        return x, states

    def loss_fn(self, params, batch):
        tokens = batch["tokens"]
        h, _ = self.forward(params, {"tokens": tokens[:, :-1]})
        logits = self._head(params, h)
        loss = cross_entropy(logits, tokens[:, 1:])
        return loss, {"ce": loss}

    def prefill(self, params, batch, cache_len=None):
        h, states = self.forward(params, batch, with_cache=True)
        return self._head(params, h[:, -1:]), states

    def decode_step(self, params, states, batch):
        cfg = self.cfg
        x = params["embed"][batch["token"]] * jnp.asarray(
            cfg.d_model ** 0.5, self.dtype)

        def body_fn(x, xs):
            p_l, (conv, ssm) = xs
            y, st = mamba_decode(p_l["mamba"],
                                 rms_norm(x, p_l["ln"], cfg.norm_eps),
                                 cfg, conv, ssm)
            return x + y, st

        x, new_states = scan_blocks(body_fn, x,
                                    (params["blocks"], states),
                                    cfg.scan_layers)
        return self._head(params, x), new_states

    def init_caches(self, batch: int, cache_len: int):
        conv, ssm = init_ssm_state(self.cfg, batch, self.dtype)
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a, (self.cfg.n_layers,) + a.shape),
            (conv, ssm))

    def cache_pspecs(self, shard_seq: bool):
        batch_axes = self.data_axes if len(self.data_axes) > 1 \
            else self.data_axes[0]
        conv, ssm = ssm_state_pspec(batch_axes, replicate_batch=shard_seq)
        return _with_leading((conv, ssm), 1)


class Zamba2LM:
    def __init__(self, cfg: ModelConfig, mesh=None,
                 data_axes: Tuple[str, ...] = ("data",), **_):
        assert cfg.hybrid_attn_every > 0
        self.cfg = cfg
        self.tp = mesh_tp(mesh)
        self.data_axes = data_axes
        self.dtype = jnp.dtype(cfg.dtype)
        self.n_groups, self.n_tail = divmod(cfg.n_layers,
                                            cfg.hybrid_attn_every)

    def init(self, rng: jax.Array) -> Params:
        cfg = self.cfg
        k_emb, k_g, k_t, k_a, k_m = jax.random.split(rng, 5)
        k_every = cfg.hybrid_attn_every

        def group_init(key):
            return stack_layers(
                lambda k: _mamba_block_init(k, cfg, self.dtype), key,
                k_every)

        params = {
            "embed": embed_init(k_emb, cfg.vocab, cfg.d_model, self.dtype),
            "groups": stack_layers(group_init, k_g, self.n_groups),
            "shared_attn": {
                "ln1": jnp.zeros((cfg.d_model,), self.dtype),
                "attn": attn.attn_init(k_a, cfg, self.dtype),
                "ln2": jnp.zeros((cfg.d_model,), self.dtype),
                "mlp": mlp_init(k_m, cfg.d_model, cfg.d_ff, cfg.act,
                                self.dtype),
            },
            "final_norm": jnp.zeros((cfg.d_model,), self.dtype),
        }
        if self.n_tail:
            params["tail"] = stack_layers(
                lambda k: _mamba_block_init(k, cfg, self.dtype), k_t,
                self.n_tail)
        return params

    def param_pspecs(self) -> Params:
        cfg = self.cfg
        specs = {
            "embed": embed_pspec(cfg.vocab, self.tp),
            "groups": _with_leading(_mamba_block_pspec(cfg, self.tp), 2),
            "shared_attn": {"ln1": P(None),
                            "attn": attn.attn_pspec(cfg, self.tp),
                            "ln2": P(None),
                            "mlp": mlp_pspec(cfg.act, cfg.d_ff, self.tp)},
            "final_norm": P(None),
        }
        if self.n_tail:
            specs["tail"] = _with_leading(
                _mamba_block_pspec(cfg, self.tp), 1)
        return specs

    def _head(self, params, h):
        h = rms_norm(h, params["final_norm"], self.cfg.norm_eps)
        return h @ params["embed"].T

    def _shared_attn_seq(self, p, x, positions, with_cache):
        cfg = self.cfg
        h, cache = attn.attn_prefill(
            p["attn"], rms_norm(x, p["ln1"], cfg.norm_eps), positions, cfg,
            True, with_cache)
        x = x + h
        return x + mlp_apply(p["mlp"],
                             rms_norm(x, p["ln2"], cfg.norm_eps),
                             cfg.act), cache

    def forward(self, params, batch, with_cache=False):
        cfg = self.cfg
        x = params["embed"][batch["tokens"]] * jnp.asarray(
            cfg.d_model ** 0.5, self.dtype)
        b, s, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        shared = params["shared_attn"]

        def group_body(x, p_group):
            for l in range(cfg.hybrid_attn_every):
                p_l = jax.tree.map(lambda a: a[l], p_group)
                y, st = mamba_seq(p_l["mamba"],
                                  rms_norm(x, p_l["ln"], cfg.norm_eps), cfg)
                x = x + y
            x, cache = self._shared_attn_seq(shared, x, positions,
                                             with_cache)
            return x, cache

        body = jax.checkpoint(group_body, policy=REMAT_POLICY) \
            if cfg.remat else group_body
        x, attn_caches = scan_blocks(body, x, params["groups"],
                                     cfg.scan_layers)
        for l in range(self.n_tail):
            p_l = jax.tree.map(lambda a: a[l], params["tail"])
            y, _ = mamba_seq(p_l["mamba"],
                             rms_norm(x, p_l["ln"], cfg.norm_eps), cfg)
            x = x + y
        return x, attn_caches

    def loss_fn(self, params, batch):
        tokens = batch["tokens"]
        h, _ = self.forward(params, {"tokens": tokens[:, :-1]})
        logits = self._head(params, h)
        loss = cross_entropy(logits, tokens[:, 1:])
        return loss, {"ce": loss}

    def prefill(self, params, batch, cache_len=None):
        cfg = self.cfg
        x = params["embed"][batch["tokens"]] * jnp.asarray(
            cfg.d_model ** 0.5, self.dtype)
        b, s, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        shared = params["shared_attn"]

        def group_body(x, p_group):
            states = []
            for l in range(cfg.hybrid_attn_every):
                p_l = jax.tree.map(lambda a: a[l], p_group)
                y, st = mamba_seq(p_l["mamba"],
                                  rms_norm(x, p_l["ln"], cfg.norm_eps), cfg)
                x = x + y
                states.append(st)
            x, cache = self._shared_attn_seq(shared, x, positions, True)
            ys = (jax.tree.map(lambda *a: jnp.stack(a), *states), cache)
            return x, ys

        x, (mamba_states, attn_caches) = scan_blocks(
            group_body, x, params["groups"], cfg.scan_layers)
        tail_states = []
        for l in range(self.n_tail):
            p_l = jax.tree.map(lambda a: a[l], params["tail"])
            y, st = mamba_seq(p_l["mamba"],
                              rms_norm(x, p_l["ln"], cfg.norm_eps), cfg)
            x = x + y
            tail_states.append(st)
        caches = {"mamba": mamba_states, "attn": attn_caches}
        if tail_states:
            caches["tail"] = jax.tree.map(lambda *a: jnp.stack(a),
                                          *tail_states)
        if cache_len is not None:
            caches["attn"] = attn.grow_cache(caches["attn"], cfg, True,
                                             cache_len, s)
        return self._head(params, x[:, -1:]), caches

    def decode_step(self, params, caches, batch):
        cfg = self.cfg
        pos = batch["pos"]
        x = params["embed"][batch["token"]] * jnp.asarray(
            cfg.d_model ** 0.5, self.dtype)
        shared = params["shared_attn"]

        def group_body(x, xs):
            p_group, m_states, a_cache = xs
            new_states = []
            for l in range(cfg.hybrid_attn_every):
                p_l = jax.tree.map(lambda a: a[l], p_group)
                st = jax.tree.map(lambda a: a[l], m_states)
                y, st = mamba_decode(p_l["mamba"],
                                     rms_norm(x, p_l["ln"], cfg.norm_eps),
                                     cfg, *st)
                x = x + y
                new_states.append(st)
            h, a_cache = attn.attn_decode(
                shared["attn"], rms_norm(x, shared["ln1"], cfg.norm_eps),
                a_cache, pos, cfg, True)
            x = x + h
            x = x + mlp_apply(shared["mlp"],
                              rms_norm(x, shared["ln2"], cfg.norm_eps),
                              cfg.act)
            ys = (jax.tree.map(lambda *a: jnp.stack(a), *new_states),
                  a_cache)
            return x, ys

        x, (m_new, a_new) = scan_blocks(
            group_body, x,
            (params["groups"], caches["mamba"], caches["attn"]),
            cfg.scan_layers)
        new_caches = {"mamba": m_new, "attn": a_new}
        if self.n_tail:
            tail_new = []
            for l in range(self.n_tail):
                p_l = jax.tree.map(lambda a: a[l], params["tail"])
                st = jax.tree.map(lambda a: a[l], caches["tail"])
                y, st = mamba_decode(p_l["mamba"],
                                     rms_norm(x, p_l["ln"], cfg.norm_eps),
                                     cfg, *st)
                x = x + y
                tail_new.append(st)
            new_caches["tail"] = jax.tree.map(lambda *a: jnp.stack(a),
                                              *tail_new)
        return self._head(params, x), new_caches

    def init_caches(self, batch: int, cache_len: int):
        cfg = self.cfg
        conv, ssm = init_ssm_state(cfg, batch, self.dtype)
        k_every = cfg.hybrid_attn_every
        mamba = jax.tree.map(
            lambda a: jnp.broadcast_to(
                a, (self.n_groups, k_every) + a.shape), (conv, ssm))
        a_cache = attn.init_cache(cfg, batch, cache_len, True, self.dtype)
        attn_c = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (self.n_groups,) + a.shape),
            a_cache)
        caches = {"mamba": mamba, "attn": attn_c}
        if self.n_tail:
            caches["tail"] = jax.tree.map(
                lambda a: jnp.broadcast_to(a, (self.n_tail,) + a.shape),
                (conv, ssm))
        return caches

    def cache_pspecs(self, shard_seq: bool):
        batch_axes = self.data_axes if len(self.data_axes) > 1 \
            else self.data_axes[0]
        ssm_spec = ssm_state_pspec(batch_axes, replicate_batch=shard_seq)
        a_spec = attn.cache_pspec(batch_axes, shard_seq,
                                  divisible(self.cfg.n_kv_heads, self.tp),
                                  quantized=self.cfg.kv_dtype == "int8")
        caches = {"mamba": _with_leading(ssm_spec, 2),
                  "attn": _with_leading(a_spec, 1)}
        if self.n_tail:
            caches["tail"] = _with_leading(ssm_spec, 1)
        return caches
