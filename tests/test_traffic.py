"""Traffic engine (repro.core.traffic, DESIGN.md §10): arrival-trace
generators, the queue-aware merged-order scan vs an INDEPENDENT numpy
discrete-event reference (request-for-request, both fidelity modes),
the zero-contention bit-exactness invariant, FCFS causality properties,
and the contention-aware fitness / batched-solver wiring."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import (PSOGAConfig, SimProblem, TRAFFIC_KINDS,
                        TrafficConfig, heft_makespan, merge_dags,
                        paper_environment, run_pso_ga, run_pso_ga_batch,
                        sample_arrivals, sample_environment,
                        simulate_np, simulate_traffic_swarm,
                        traffic_replay, traffic_stats,
                        zero_contention_arrivals, zoo)
from repro.core.batch import pack_arrivals
from repro.core.fitness import INFEASIBLE_OFFSET, make_swarm_fitness
from repro.core.simulator import pad_problem, simulate_padded

#: small budget; distinct from other test configs (fresh runner cache)
FAST = PSOGAConfig(pop_size=16, max_iters=26, stall_iters=9)


# ---------------------------------------------------------------------------
# numpy discrete-event reference (independent implementation of the
# documented discipline: per-server FCFS in request-arrival order,
# same-app ties by slot, cross-app ties by topo position)
# ---------------------------------------------------------------------------

def traffic_np(prob: SimProblem, x: np.ndarray, arr: np.ndarray,
               faithful: bool) -> dict:
    x = np.asarray(x, np.int64)
    s = prob.num_servers
    n_apps, R = arr.shape
    steps = []
    for r in range(R):
        for t, j in enumerate(prob.order):
            a = arr[prob.app_id[j], r]
            if np.isfinite(a):
                steps.append((float(a), r, t, int(j)))
    steps.sort(key=lambda z: (z[0], z[1], z[2]))

    lease = np.zeros(s)
    t_on = np.full(s, np.inf)
    end: dict = {}
    trans = 0.0
    for a, r, t, j in steps:
        srv = x[j]
        exe = prob.compute[j] / prob.power[srv]
        max_tr, gate = 0.0, a
        pars = prob.parent_idx[j]
        for k in np.nonzero(pars >= 0)[0]:
            pj = int(pars[k])
            mb = prob.parent_mb[j, k]
            tt = mb * prob.inv_bw[x[pj], srv]
            max_tr = max(max_tr, tt)
            gate = max(gate, end[(r, pj)] + tt)
            trans += prob.tran_cost[x[pj], srv] * mb
        out = 0.0
        cidx = prob.child_idx[j]
        for k in np.nonzero(cidx >= 0)[0]:
            out += prob.child_mb[j, k] * prob.inv_bw[srv, x[cidx[k]]]
        if faithful:
            base = max(lease[srv], a)
            start = base + max_tr
            lease[srv] = base + exe + out
        else:
            start = max(lease[srv], gate)
            lease[srv] = start + exe + out
        end[(r, j)] = start + exe
        t_on[srv] = min(t_on[srv], start)

    used = ~np.isinf(t_on)
    comp = float(np.sum(np.where(used, prob.cost_per_sec
                                 * (lease - np.where(used, t_on, 0.0)),
                                 0.0)))
    latency = np.zeros((n_apps, R))
    miss = np.zeros((n_apps, R), bool)
    for i in range(n_apps):
        for r in range(R):
            if not np.isfinite(arr[i, r]):
                continue
            ends = [end[(r, j)] for j in range(prob.num_layers)
                    if prob.app_id[j] == i and (r, j) in end]
            c = max(ends) if ends else 0.0
            latency[i, r] = c - arr[i, r]
            miss[i, r] = latency[i, r] > prob.deadline[i]
    n_req = max(int(np.isfinite(arr).sum()), 1)
    return {"end": end, "latency": latency, "miss": miss,
            "miss_rate": float(miss.sum()) / n_req,
            "total_cost": comp + trans}


def _merged_fleet():
    """Two apps merged into one problem: cross-app server contention."""
    env = sample_environment()
    merged = merge_dags([zoo.alexnet(pin_server=0, deadline=30.0),
                         zoo.alexnet(pin_server=0, deadline=25.0)])
    return env, SimProblem.build(merged, env)


@pytest.mark.parametrize("faithful", [True, False])
def test_engine_matches_des_oracle(faithful, rng):
    """Seeded random plans × random arrivals: the scan engine agrees
    with the discrete-event reference request-for-request."""
    env, prob = _merged_fleet()
    pp = pad_problem(prob)
    p = prob.num_layers
    for trial in range(4):
        x = rng.integers(0, env.num_servers, size=p).astype(np.int32)
        x[np.asarray(prob.pinned) >= 0] = 0
        arr = np.sort(rng.uniform(0.0, 40.0, size=(2, 4)), axis=1)
        arr[0, 3] = np.inf                    # ragged request counts
        if trial % 2:
            arr[1, 2:] = np.inf
        ref = traffic_np(prob, x, arr, faithful)
        sim = simulate_traffic_swarm(pp, jnp.asarray(x)[None, :],
                                     jnp.asarray(arr), faithful)
        got_end = np.asarray(sim.end[0])              # (R, p)
        for (r, j), e in ref["end"].items():
            np.testing.assert_allclose(got_end[r, j], e, rtol=1e-5,
                                       err_msg=f"end[{r},{j}] trial "
                                               f"{trial}")
        np.testing.assert_allclose(np.asarray(sim.latency[0]),
                                   ref["latency"], rtol=1e-5, atol=1e-4)
        np.testing.assert_allclose(float(sim.miss_rate[0]),
                                   ref["miss_rate"], atol=1e-9)
        np.testing.assert_allclose(float(sim.total_cost[0]),
                                   ref["total_cost"], rtol=1e-5)


@pytest.mark.parametrize("faithful", [True, False])
def test_zero_contention_reproduces_single_shot(faithful, rng):
    """1 request/app at t=0: the queue-aware replay IS the single-shot
    simulator — bit-for-bit against simulate_padded, and equal to the
    float64 simulate_np oracle to float32 round-off."""
    env, prob = _merged_fleet()
    pp = pad_problem(prob)
    p = prob.num_layers
    arr = jnp.asarray(zero_contention_arrivals(prob.num_apps)[0])
    for _ in range(4):
        x = rng.integers(0, env.num_servers, size=p).astype(np.int32)
        x[np.asarray(prob.pinned) >= 0] = 0
        base = simulate_padded(pp, jnp.asarray(x), faithful=faithful)
        sim = simulate_traffic_swarm(pp, jnp.asarray(x)[None, :], arr,
                                     faithful)
        np.testing.assert_array_equal(np.asarray(base.end_times),
                                      np.asarray(sim.end[0, 0]))
        assert float(base.total_cost) == float(sim.total_cost[0])
        ref = simulate_np(prob, x, faithful=faithful)
        np.testing.assert_allclose(float(sim.total_cost[0]),
                                   float(ref.total_cost), rtol=1e-6)
        np.testing.assert_allclose(
            np.asarray(sim.latency[0]).ravel(),
            ref.app_completion, rtol=1e-6)


def test_earlier_requests_immune_to_later_arrivals():
    """Whole-request FCFS: adding later-arriving requests never changes
    an earlier request's completion (causality of the merged order)."""
    env, prob = _merged_fleet()
    pp = pad_problem(prob)
    x = np.zeros(prob.num_layers, np.int32)
    solo = simulate_traffic_swarm(
        pp, jnp.asarray(x)[None, :],
        jnp.asarray([[1.0, np.inf, np.inf], [2.0, np.inf, np.inf]]),
        False)
    crowd = simulate_traffic_swarm(
        pp, jnp.asarray(x)[None, :],
        jnp.asarray([[1.0, 5.0, 6.0], [2.0, 5.5, np.inf]]), False)
    np.testing.assert_array_equal(np.asarray(solo.latency[0, :, 0]),
                                  np.asarray(crowd.latency[0, :, 0]))


def test_queueing_orders_latencies():
    """Simultaneous same-app copies on one server serve in slot order:
    latency grows linearly with queue depth."""
    env = sample_environment()
    dag = zoo.alexnet(pin_server=0, deadline=100.0)
    prob = SimProblem.build(dag, env)
    pp = pad_problem(prob)
    x = np.zeros(prob.num_layers, np.int32)
    sim = simulate_traffic_swarm(pp, jnp.asarray(x)[None, :],
                                 jnp.zeros((1, 3)), False)
    lat = np.asarray(sim.latency[0, 0])
    assert lat[0] < lat[1] < lat[2]
    np.testing.assert_allclose(lat[1], 2 * lat[0], rtol=1e-4)
    np.testing.assert_allclose(lat[2], 3 * lat[0], rtol=1e-4)


# ---------------------------------------------------------------------------
# arrival-trace generators
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", TRAFFIC_KINDS)
def test_sample_arrivals_shapes_and_bounds(kind):
    tr = sample_arrivals(kind, n_apps=3, rate=0.6, horizon=20.0,
                         max_requests=6, n_seeds=4, seed=2)
    assert tr.t.shape == (4, 3, 6)
    finite = tr.t[np.isfinite(tr.t)]
    assert np.all((finite >= 0.0) & (finite < 20.0))
    # ascending per app with +inf padding at the tail
    assert np.all(np.diff(tr.t, axis=2) >= 0)
    assert tr.counts().max() <= 6
    # at least SOME requests arrive across seeds at this intensity
    assert tr.counts().sum() > 0


@pytest.mark.parametrize("kind", TRAFFIC_KINDS)
def test_sample_arrivals_seeded_deterministic(kind):
    a = sample_arrivals(kind, 2, rate=0.5, n_seeds=3, seed=7)
    b = sample_arrivals(kind, 2, rate=0.5, n_seeds=3, seed=7)
    np.testing.assert_array_equal(a.t, b.t)
    c = sample_arrivals(kind, 2, rate=0.5, n_seeds=3, seed=8)
    assert not np.array_equal(a.t, c.t)


def test_sample_arrivals_rate_scales_volume():
    lo = sample_arrivals("poisson", 4, rate=0.1, horizon=30.0,
                         max_requests=32, n_seeds=8, seed=0)
    hi = sample_arrivals("poisson", 4, rate=0.8, horizon=30.0,
                         max_requests=32, n_seeds=8, seed=0)
    assert hi.counts().sum() > 2 * lo.counts().sum()


def test_sample_arrivals_rejects_unknown_kind():
    with pytest.raises(ValueError):
        sample_arrivals("tsunami", 2)


def test_traffic_config_eval_disjoint_from_solver():
    tc = TrafficConfig(kind="bursty", rate=0.5, mc_solver=2, mc_eval=2)
    a = tc.solver_arrivals(2, seed=0)
    b = tc.eval_arrivals(2, seed=0)
    assert a.shape[0] == 2 and b.shape[0] == 2
    assert not np.array_equal(a, b)


# ---------------------------------------------------------------------------
# contention-aware fitness + solver wiring
# ---------------------------------------------------------------------------

def _deadlined(net: str, ratio: float, env, pin: int = 0):
    dag = zoo.build(net, pin_server=pin)
    h, _ = heft_makespan(dag, env)
    return dag.with_deadline(np.array([ratio * h]))


def test_traffic_fitness_zero_contention_equals_base_key(rng):
    """At 1 request/app arriving at 0 with deadlines met, the traffic
    key IS the base cost key (same $ for the same plan)."""
    env = paper_environment()
    dag = _deadlined("alexnet", 3.0, env)
    prob = SimProblem.build(dag, env)
    pp = pad_problem(prob)
    arr = jnp.asarray(zero_contention_arrivals(1, n_seeds=2))
    base = make_swarm_fitness(pp, faithful=False)
    traf = make_swarm_fitness(pp, faithful=False, arrivals=arr,
                              miss_budget=0.0)
    X = rng.integers(0, env.num_servers, size=(8, prob.num_layers)
                     ).astype(np.int32)
    X[:, 0] = 0
    kb = np.asarray(base(jnp.asarray(X)))
    kt = np.asarray(traf(jnp.asarray(X)))
    feas = kb < INFEASIBLE_OFFSET
    assert feas.any()
    np.testing.assert_allclose(kt[feas], kb[feas], rtol=1e-6)
    # infeasible-at-zero-load particles are also traffic-infeasible
    assert np.all(kt[~feas] >= INFEASIBLE_OFFSET)


def test_traffic_fitness_orders_by_miss_rate():
    """Two over-budget plans: the one missing fewer deadlines gets the
    smaller key (the swarm can climb toward the budget)."""
    env = sample_environment()
    dag = zoo.alexnet(pin_server=0, deadline=11.0)
    prob = SimProblem.build(dag, env)
    pp = pad_problem(prob)
    arr = jnp.asarray(np.zeros((1, 1, 4)))    # 4 simultaneous requests
    fit = make_swarm_fitness(pp, faithful=False, arrivals=arr,
                             miss_budget=0.0)
    all_home = np.zeros((1, prob.num_layers), np.int32)
    spread = np.asarray([[0, 3, 3, 4, 4, 5, 5, 5, 3, 3, 3]], np.int32)
    k_home = float(fit(jnp.asarray(all_home))[0])
    k_spread = float(fit(jnp.asarray(spread))[0])
    assert k_home >= INFEASIBLE_OFFSET       # 10 s/request, all queue
    assert k_spread < k_home                 # pipelining misses less


def test_run_pso_ga_traffic_beats_zero_load_plan_on_misses():
    """The tentpole claim at unit scale: under a burst the traffic-aware
    solve yields a strictly lower p95 miss rate than the zero-load plan
    of the SAME solver budget."""
    env = paper_environment()
    dag = _deadlined("alexnet", 1.5, env)
    tc = TrafficConfig(kind="bursty", rate=0.5, horizon=30.0,
                       max_requests=6, mc_solver=2, mc_eval=8)
    zero = run_pso_ga(dag, env, FAST, seed=0)
    aware = run_pso_ga(dag, env, FAST, seed=0,
                       arrivals=tc.solver_arrivals(1, seed=0))
    prob = SimProblem.build(dag, env)
    ev = tc.eval_arrivals(1, seed=0)
    sz = traffic_stats(traffic_replay(prob, zero.best_x, ev,
                                      faithful=FAST.faithful_sim))
    sa = traffic_stats(traffic_replay(prob, aware.best_x, ev,
                                      faithful=FAST.faithful_sim))
    assert sa["miss_p95"] < sz["miss_p95"]
    assert sa["feasible"]


def test_batched_traffic_matches_sequential_genes():
    """Fleet parity under traffic: same seeds, same arrivals — the
    batched solver lands on the sequential solver's genes (keys agree to
    float32 round-off; the fused fleet program may differ in the last
    ulp, unlike the zero-load path's exact-parity guarantee)."""
    env = paper_environment()
    dags = [_deadlined("alexnet", 2.0, env, pin=0),
            _deadlined("googlenet", 2.0, env, pin=1)]
    arrs = [sample_arrivals("flash-crowd", 1, rate=0.4, horizon=20.0,
                            max_requests=5, n_seeds=2, seed=i).t
            for i in range(2)]
    seq = [run_pso_ga(d, env, FAST, seed=i, arrivals=arrs[i])
           for i, d in enumerate(dags)]
    bat = run_pso_ga_batch([(d, env) for d in dags], FAST, seed=[0, 1],
                           arrivals=arrs)
    for a, b in zip(seq, bat):
        assert np.array_equal(a.best_x, b.best_x)
        np.testing.assert_allclose(a.best_fitness, b.best_fitness,
                                   rtol=1e-5)
        assert a.iterations == b.iterations


def test_pack_arrivals_validation():
    ok = [np.zeros((2, 1, 4)), np.zeros((2, 1, 4))]
    packed = pack_arrivals(ok, max_apps=3)
    assert packed.shape == (2, 2, 3, 4)
    assert np.all(np.isinf(packed[:, :, 1:, :]))   # padded apps: never
    with pytest.raises(ValueError):                # arrive
        pack_arrivals([np.zeros((2, 1, 4)), np.zeros((3, 1, 4))], 3)
    with pytest.raises(ValueError):
        pack_arrivals([np.zeros((2, 1, 4)), np.zeros((2, 1, 5))], 3)
    with pytest.raises(ValueError):
        pack_arrivals([np.zeros((2, 7, 4))], 3)
    with pytest.raises(ValueError):
        run_pso_ga_batch(
            [( _deadlined("alexnet", 2.0, paper_environment()),
               paper_environment())], FAST,
            arrivals=[np.zeros((2, 1, 4))] * 2)


def test_traffic_replay_stats_shapes():
    env = paper_environment()
    dag = _deadlined("alexnet", 2.0, env)
    prob = SimProblem.build(dag, env)
    tr = sample_arrivals("diurnal", 1, rate=0.5, horizon=20.0,
                         max_requests=5, n_seeds=3, seed=0)
    res = traffic_replay(prob, np.zeros(dag.num_layers, np.int32), tr.t,
                         faithful=False)
    assert res.miss_rate.shape == (3,)
    assert res.latency.shape == (3, 1, 5)
    st = traffic_stats(res)
    assert 0.0 <= st["miss_p50"] <= st["miss_p95"] <= st["miss_p99"] <= 1.0
    assert st["requests"] == int(tr.counts().sum())
    # a plan on a forbidden link is statically infeasible
    bad = np.full(dag.num_layers, 12, np.int32)   # edge not adjacent? use
    bad[0] = 0                                    # pin + non-reachable mix
    res_bad = traffic_replay(prob, bad, tr.t, faithful=False)
    assert isinstance(res_bad.feasible, bool)
