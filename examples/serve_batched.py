"""Batched serving: PSO-GA picks the fleet placement for the request
shape (the paper's decision), then the server prefills a request batch
and decodes with the jitted sharded serve step.

    PYTHONPATH=src python examples/serve_batched.py --arch qwen3-0.6b
"""
import argparse

import numpy as np

from repro.configs import SHAPES, get
from repro.core import PSOGAConfig, plan_offload
from repro.launch.serve import Server


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=32)
    args = ap.parse_args()

    full = get(args.arch)
    plan = plan_offload(full, SHAPES[1], deadline_ratio=1.5,
                        pso=PSOGAConfig(pop_size=48, max_iters=200),
                        seed=0)
    print(f"== fleet placement for {args.arch} (prefill_32k SLO) ==")
    print(plan.summary())

    cfg = full.reduced()              # CPU-sized model, same family
    print(f"\n== serving {cfg.name} locally ==")
    srv = Server(cfg, args.batch, args.prompt_len, args.max_new,
                 eos_id=-1)
    params = srv.init_params()
    rng = np.random.default_rng(0)
    batch = {"tokens": rng.integers(
        2, cfg.vocab, (args.batch, args.prompt_len)).astype(np.int32)}
    out = srv.generate(params, batch)
    print(f"prefill: {out['prefill_s']*1e3:.0f} ms for "
          f"{args.batch}x{args.prompt_len} tokens")
    print(f"decode:  {out['tokens_generated']} tokens in "
          f"{out['decode_s']*1e3:.0f} ms "
          f"({out['decode_tok_per_s']:.1f} tok/s)")
    print(f"sample continuation (slot 0): {out['tokens'][0][:12]}")


if __name__ == "__main__":
    main()
