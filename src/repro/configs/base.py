"""Model/shape configuration dataclasses shared by all architectures."""
from __future__ import annotations

import dataclasses
from typing import Tuple

__all__ = ["ModelConfig", "ShapeSpec", "SHAPES"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """One architecture. Field values follow the assignment block verbatim;
    reduced smoke variants are produced by ``reduced()``."""
    name: str
    family: str                 # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int

    act: str = "swiglu"         # swiglu | geglu | gelu
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    logit_softcap: float = 0.0

    # attention pattern: period P with `global_every`-th layer global, rest
    # local with `window`. window == 0 -> all layers global full attention.
    window: int = 0
    local_global_period: int = 0   # 0 = uniform (all global, or all local
    #                                if window > 0, e.g. mixtral SWA)

    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_dense_residual: bool = False
    d_ff_dense: int = 0
    capacity_factor: float = 1.25

    # SSM (mamba2 / SSD)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 256

    # hybrid (zamba2): one *shared* attention block after every
    # `hybrid_attn_every` mamba blocks
    hybrid_attn_every: int = 0

    # enc-dec (whisper): n_layers applies to BOTH encoder and decoder
    enc_layers: int = 0
    dec_layers: int = 0

    # vlm: number of (precomputed, stubbed) vision patch embeddings that
    # prefix the token sequence
    vision_tokens: int = 0

    # runtime
    dtype: str = "bfloat16"
    remat: bool = True
    scan_layers: bool = True
    use_pallas: bool = False     # TPU path; dry-run/CPU uses the XLA path

    # perf knobs (§Perf hillclimbs; defaults = paper-faithful baseline)
    moe_shard: str = "ep_ftp"    # ep_ftp | ep_fsdp | ep_only (see moe.py)
    ce_chunk: int = 0            # vocab-chunked CE: sequence chunk count
    kv_dtype: str = "model"      # model | int8 (quantized KV cache)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def layer_is_global(self, i: int) -> bool:
        if self.window == 0:
            return True
        if self.local_global_period == 0:
            return False                      # uniform SWA (mixtral)
        # gemma3 pattern: every `period`-th layer (1-based) is global
        return (i + 1) % self.local_global_period == 0

    def reduced(self) -> "ModelConfig":
        """A tiny same-family config for CPU smoke tests."""
        period = self.local_global_period
        n_layers = max(4, period) if period else 4
        if self.family == "hybrid":
            n_layers = 2 * max(self.hybrid_attn_every and 2 or 2, 2) + 1  # 5
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            n_layers=n_layers,
            d_model=64,
            n_heads=4, n_kv_heads=min(self.n_kv_heads, 2) or 2,
            head_dim=16,
            d_ff=128, d_ff_dense=64 if self.d_ff_dense else 0,
            vocab=256,
            n_experts=4 if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            window=min(self.window, 32) if self.window else 0,
            local_global_period=min(period, 2) if period else 0,
            ssm_state=16 if self.ssm_state else 0,
            ssm_head_dim=16 if self.ssm_state else 64,
            ssm_chunk=16 if self.ssm_state else 256,
            hybrid_attn_every=2 if self.hybrid_attn_every else 0,
            enc_layers=2 if self.enc_layers else 0,
            dec_layers=2 if self.dec_layers else 0,
            vision_tokens=8 if self.vision_tokens else 0,
            dtype="float32",
        )


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One assigned input-shape cell."""
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode


SHAPES: Tuple[ShapeSpec, ...] = (
    ShapeSpec("train_4k", 4_096, 256, "train"),
    ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    ShapeSpec("decode_32k", 32_768, 128, "decode"),
    ShapeSpec("long_500k", 524_288, 1, "decode"),
)
