"""Paper Fig. 8 — THREE DNNs per end device (30 DNNs, deadlines x2)."""
from __future__ import annotations

import argparse

from .common import PAPER, QUICK, print_csv
from .fig7 import NETS, run


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--paper", action="store_true")
    ap.add_argument("--nets", nargs="*", default=list(NETS))
    args = ap.parse_args()
    rows = run(nets=args.nets, proto=PAPER if args.paper else QUICK,
               per_device=3)
    print_csv(rows, ["net", "ratio", "algo", "layers", "cost",
                     "feasible_frac", "wall_s"])


if __name__ == "__main__":
    main()
