"""Fault injection + checkpoint-restart supervision.

At thousand-node scale the MTBF of the *job* is hours even when each node
is months; the only viable posture is: checkpoint often, detect fast,
restart from latest. ``run_with_restarts`` is the single-controller
supervisor loop: it runs ``body(start_step)`` and, on a (simulated or
real) failure, restores from the latest checkpoint and re-enters.

``FailureInjector`` raises ``SimulatedFailure`` with probability
``p_fail`` per step (deterministic in seed — tests inject at exact steps
with ``fail_at``). Real deployments plug hardware signals in instead;
everything downstream is identical.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence

import numpy as np

__all__ = ["SimulatedFailure", "FailureInjector", "run_with_restarts"]


class SimulatedFailure(RuntimeError):
    pass


@dataclasses.dataclass
class FailureInjector:
    p_fail: float = 0.0
    seed: int = 0
    fail_at: Sequence[int] = ()          # deterministic injection points
    max_failures: int = 1_000_000

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)
        self._failures = 0
        self._fired = set()

    def maybe_fail(self, step: int) -> None:
        if self._failures >= self.max_failures:
            return
        if step in self.fail_at and step not in self._fired:
            self._fired.add(step)
            self._failures += 1
            raise SimulatedFailure(f"injected failure at step {step}")
        if self.p_fail and self._rng.random() < self.p_fail:
            self._failures += 1
            raise SimulatedFailure(f"random failure at step {step}")


def run_with_restarts(body: Callable[[int], int],
                      latest_step: Callable[[], Optional[int]],
                      max_restarts: int = 10) -> int:
    """Supervise ``body(start_step) -> final_step``.

    ``latest_step()`` queries the checkpoint manager. On failure the body
    re-enters from ``latest + 1`` (or 0). Returns the final step. Raises
    after ``max_restarts`` consecutive failures (crash-looping guard).
    """
    restarts = 0
    while True:
        start = latest_step()
        start = 0 if start is None else start + 1
        try:
            return body(start)
        except SimulatedFailure:
            restarts += 1
            if restarts > max_restarts:
                raise
