"""End-to-end behaviour: the paper's pipeline (plan -> place -> run)
stitched through the framework on one reduced architecture."""
import numpy as np

from repro.configs import SHAPES, get
from repro.configs.base import ShapeSpec
from repro.core import PSOGAConfig, plan_offload, tpu_fleet_environment
from repro.launch.serve import Server
from repro.launch.train import Trainer, TrainerConfig
from repro.optim import AdamWConfig


def test_plan_then_train_then_serve(tmp_path):
    arch = "qwen3-0.6b"

    # 1. the paper's decision: place the full model over the fleet
    plan = plan_offload(get(arch), SHAPES[1],
                        env=tpu_fleet_environment(), deadline_ratio=1.5,
                        pso=PSOGAConfig(pop_size=24, max_iters=80,
                                        stall_iters=25), seed=0)
    assert plan.result.feasible
    assert len(plan.stages) >= 1

    # 2. train the reduced config with checkpointing
    cfg = get(arch).reduced()
    out = Trainer(
        cfg, ShapeSpec("sys", 64, 4, "train"),
        TrainerConfig(steps=8, ckpt_dir=str(tmp_path), ckpt_every=4,
                      log_every=2),
        AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=8)).train()
    assert out["final_step"] == 7
    losses = [m["loss"] for m in out["metrics"]]
    assert losses[-1] < losses[0]

    # 3. serve the trained family
    srv = Server(cfg, batch=2, prompt_len=8, max_new=4, eos_id=-1)
    params = srv.init_params()
    res = srv.generate(params, {"tokens": np.random.default_rng(0)
                                .integers(2, cfg.vocab, (2, 8))
                                .astype(np.int32)})
    assert res["tokens"].shape == (2, 4)
