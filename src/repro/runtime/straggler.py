"""Straggler detection via EWMA step-time outliers.

In synchronous data parallelism one slow host gates every step (the
collective waits). Detection is cheap: keep an EWMA + EWVar of the step
time; a step slower than ``mean + k·std`` (and ``> ratio × mean``) flags
a straggler. Mitigation at scale is out-of-band (re-schedule the host,
shrink the mesh via runtime.elastic); here the detector reports and the
trainer logs + counts, and the restart/elastic path is exercised by
tests.

Welford-style EWMA keeps no history; O(1) per step.

``EwmaEstimator`` is the bare smoother without outlier logic — the
planning service's solver watchdog (DESIGN.md §11) feeds it observed
per-iteration solve times and divides remaining SLO slack by its value
to derive the iteration budget of the next solve.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

__all__ = ["StragglerDetector", "EwmaEstimator"]


@dataclasses.dataclass
class EwmaEstimator:
    """O(1) exponentially-weighted mean of a nonnegative stream.

    ``value`` is None until the first update (callers treat "no estimate
    yet" as "don't budget"). Non-finite or negative samples are ignored
    rather than poisoning the estimate — the watchdog may be fed wall
    times measured around a crashed solve.
    """
    alpha: float = 0.3

    def __post_init__(self):
        if not 0.0 < self.alpha <= 1.0:
            raise ValueError(f"alpha must be in (0, 1], got {self.alpha}")
        self._mean: Optional[float] = None
        self._n = 0

    @property
    def value(self) -> Optional[float]:
        return self._mean

    @property
    def n(self) -> int:
        return self._n

    def update(self, v: float) -> None:
        v = float(v)
        if not (v >= 0.0) or v != v or v == float("inf"):
            return
        self._n += 1
        if self._mean is None:
            self._mean = v
        else:
            self._mean += self.alpha * (v - self._mean)


@dataclasses.dataclass
class StragglerDetector:
    alpha: float = 0.1          # EWMA smoothing
    k_std: float = 4.0          # sigma threshold
    min_ratio: float = 1.5      # AND step > ratio x mean
    warmup: int = 5             # first steps (compile!) never flag

    def __post_init__(self):
        self._mean: Optional[float] = None
        self._var: float = 0.0
        self._n = 0
        self.flagged = 0

    @property
    def mean(self) -> float:
        return self._mean or 0.0

    @property
    def std(self) -> float:
        return self._var ** 0.5

    def update(self, dt: float) -> bool:
        """Feed one step time (seconds); returns True if it's a straggler
        step. Flagged steps do NOT update the running stats (a straggler
        should not inflate its own threshold)."""
        self._n += 1
        if self._mean is None:
            self._mean = dt
            return False
        is_outlier = (self._n > self.warmup
                      and dt > self._mean + self.k_std * self.std
                      and dt > self.min_ratio * self._mean)
        if is_outlier:
            self.flagged += 1
            return True
        delta = dt - self._mean
        self._mean += self.alpha * delta
        self._var = (1 - self.alpha) * (self._var
                                        + self.alpha * delta * delta)
        return False
