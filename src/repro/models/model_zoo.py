"""Unified model API across the 10 assigned architectures.

``build_model(cfg, ...)`` returns a family object exposing:
    init(rng) -> params
    loss_fn(params, batch) -> (loss, metrics)          [train shapes]
    prefill(params, batch) -> (logits, caches)         [prefill shapes]
    decode_step(params, caches, batch) -> (logits, caches)  [decode shapes]
    param_pspecs() / cache_pspecs(shard_seq) / init_caches(batch, len)

``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins for every
input of the step the shape exercises (weak-type-correct, shardable, no
device allocation) — the dry-run lowers against these.

``supports_shape(cfg, shape)`` implements the assignment's skip rules:
``long_500k`` requires sub-quadratic attention (SSM / hybrid / uniform
sliding-window); pure full-attention archs skip it.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..configs.base import ModelConfig, ShapeSpec
from .encdec import EncDecLM
from .hybrid import MambaLM, Zamba2LM
from .transformer import TransformerLM

__all__ = ["build_model", "input_specs", "batch_pspecs", "supports_shape",
           "skip_reason", "model_flops", "param_count"]


def build_model(cfg: ModelConfig, mesh=None,
                data_axes: Tuple[str, ...] = ("data",),
                moe_impl: str = "scatter"):
    if cfg.family in ("dense", "moe", "vlm"):
        return TransformerLM(cfg, mesh=mesh, data_axes=data_axes,
                             moe_impl=moe_impl)
    if cfg.family == "ssm":
        return MambaLM(cfg, mesh=mesh, data_axes=data_axes)
    if cfg.family == "hybrid":
        return Zamba2LM(cfg, mesh=mesh, data_axes=data_axes)
    if cfg.family == "encdec":
        return EncDecLM(cfg, mesh=mesh, data_axes=data_axes)
    raise ValueError(f"unknown family {cfg.family}")


# ---------------------------------------------------------------------------
# shape applicability
# ---------------------------------------------------------------------------

def supports_shape(cfg: ModelConfig, shape: ShapeSpec) -> bool:
    if shape.name.startswith("long"):
        if cfg.family in ("ssm", "hybrid"):
            return True
        # uniform sliding-window (mixtral) qualifies; periodic local:global
        # (gemma3) still has full-attention layers -> skip
        return cfg.window > 0 and cfg.local_global_period == 0
    return True


def skip_reason(cfg: ModelConfig, shape: ShapeSpec) -> str:
    if supports_shape(cfg, shape):
        return ""
    return ("pure full attention at 512k context (no sub-quadratic path); "
            "skipped per assignment")


# ---------------------------------------------------------------------------
# input specs
# ---------------------------------------------------------------------------

def _sd(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """ShapeDtypeStruct batch for the step this shape lowers."""
    b, s = shape.global_batch, shape.seq_len
    if cfg.family == "encdec":
        if shape.kind == "train":
            return {"audio_embeds": _sd((b, s, cfg.d_model), jnp.float32),
                    "tokens": _sd((b, s // 8 + 1), jnp.int32)}
        if shape.kind == "prefill":
            return {"audio_embeds": _sd((b, s, cfg.d_model), jnp.float32),
                    "tokens": _sd((b, s // 8), jnp.int32)}
        return {"token": _sd((b, 1), jnp.int32),
                "pos": _sd((), jnp.int32)}
    if cfg.family == "vlm":
        tv = min(cfg.vision_tokens, max(s // 4, 8))
        if shape.kind == "train":
            return {"vision": _sd((b, tv, cfg.d_model), jnp.float32),
                    "tokens": _sd((b, s - tv + 1), jnp.int32)}
        if shape.kind == "prefill":
            return {"vision": _sd((b, tv, cfg.d_model), jnp.float32),
                    "tokens": _sd((b, s - tv), jnp.int32)}
        return {"token": _sd((b, 1), jnp.int32), "pos": _sd((), jnp.int32)}
    # lm / moe / ssm / hybrid
    if shape.kind == "train":
        return {"tokens": _sd((b, s + 1), jnp.int32)}
    if shape.kind == "prefill":
        return {"tokens": _sd((b, s), jnp.int32)}
    return {"token": _sd((b, 1), jnp.int32), "pos": _sd((), jnp.int32)}


def batch_pspecs(cfg: ModelConfig, shape: ShapeSpec,
                 data_axes: Tuple[str, ...]) -> Dict[str, Any]:
    ba = data_axes if len(data_axes) > 1 else data_axes[0]
    specs = input_specs(cfg, shape)

    def spec_for(name, sd):
        if name == "pos":
            return P()
        if shape.global_batch == 1:
            return P(*([None] * len(sd.shape)))     # batch 1: replicate
        return P(*([ba] + [None] * (len(sd.shape) - 1)))

    return {k: spec_for(k, v) for k, v in specs.items()}


def cache_len_for(cfg: ModelConfig, shape: ShapeSpec) -> int:
    return shape.seq_len


# ---------------------------------------------------------------------------
# analytic parameter / FLOP counts (roofline MODEL_FLOPS)
# ---------------------------------------------------------------------------

def param_count(cfg: ModelConfig, active_only: bool = False) -> int:
    d, v = cfg.d_model, cfg.vocab
    n = v * d                                   # embed
    if not cfg.tie_embeddings and cfg.family != "ssm":
        n += v * d
    def attn_params():
        return d * cfg.n_heads * cfg.head_dim * 2 \
            + d * cfg.n_kv_heads * cfg.head_dim * 2
    def mlp_params(ff):
        mult = 3 if cfg.act in ("swiglu", "geglu") else 2
        return mult * d * ff
    def mamba_params():
        din, ns, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
        return d * din * 2 + d * ns * 2 + d * h + din * d \
            + cfg.ssm_conv * (din + 2 * ns)
    if cfg.family in ("dense", "vlm"):
        n += cfg.n_layers * (attn_params() + mlp_params(cfg.d_ff))
    elif cfg.family == "moe":
        e = cfg.top_k if active_only else cfg.n_experts
        per = attn_params() + e * 3 * d * cfg.d_ff + d * cfg.n_experts
        if cfg.moe_dense_residual:
            per += mlp_params(cfg.d_ff_dense)
        n += cfg.n_layers * per
    elif cfg.family == "ssm":
        n += cfg.n_layers * mamba_params()
    elif cfg.family == "hybrid":
        n += cfg.n_layers * mamba_params()
        n_sites = 1 if active_only else 1     # shared params count once
        n += n_sites * (attn_params() + mlp_params(cfg.d_ff))
    elif cfg.family == "encdec":
        n += cfg.enc_layers * (attn_params() + mlp_params(cfg.d_ff))
        n += cfg.dec_layers * (2 * attn_params() + mlp_params(cfg.d_ff))
    if cfg.family == "vlm":
        n += d * d                              # vision projection stub
    return int(n)


def model_flops(cfg: ModelConfig, shape: ShapeSpec) -> float:
    """MODEL_FLOPS = 6·N·D (train) or 2·N·D (inference), N = active params
    (matmul params only — embedding lookup excluded), D = tokens."""
    n_active = param_count(cfg, active_only=True)
    n_active -= cfg.vocab * cfg.d_model         # lookup is not a matmul
    if shape.kind == "train":
        toks = shape.global_batch * shape.seq_len
        return 6.0 * n_active * toks
    if shape.kind == "prefill":
        toks = shape.global_batch * shape.seq_len
        return 2.0 * n_active * toks
    toks = shape.global_batch * 1
    return 2.0 * n_active * toks
