from .elastic import best_mesh_shape, elastic_mesh
from .fault import (CircuitBreaker, FailureInjector, SimulatedFailure,
                    retry_with_backoff, run_with_restarts)
from .straggler import EwmaEstimator, StragglerDetector

__all__ = ["CircuitBreaker", "FailureInjector", "SimulatedFailure",
           "retry_with_backoff", "run_with_restarts",
           "EwmaEstimator", "StragglerDetector",
           "best_mesh_shape", "elastic_mesh"]
