"""Per-arch reduced-config smoke tests + serving-path consistency.

Every assigned architecture instantiates its reduced config and runs one
forward/train step on CPU asserting output shapes + no NaNs (deliverable
f). Decode correctness: teacher-forced decode must match a longer prefill
token-for-token (exercises every cache layout: ring, periodic, SSM state,
cross-attention)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, get, names
from repro.models import (build_model, input_specs, model_flops,
                          param_count, supports_shape)

ALL_ARCHS = list(names())


def make_batch(cfg, b=2, s=17, rng=None):
    rng = rng or np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab, (b, s), dtype=np.int32)
    if cfg.family == "encdec":
        return {"audio_embeds": rng.standard_normal(
            (b, 16, cfg.d_model)).astype(np.float32),
            "tokens": toks[:, :9]}
    if cfg.family == "vlm":
        return {"vision": rng.standard_normal(
            (b, 8, cfg.d_model)).astype(np.float32), "tokens": toks}
    return {"tokens": toks}


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_reduced_train_step(arch):
    cfg = get(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg)
    loss, metrics = jax.jit(model.loss_fn)(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), arch
    # one SGD step moves the loss (gradients flow)
    g = jax.grad(lambda p: model.loss_fn(p, batch)[0])(params)
    gnorm = sum(float(jnp.sum(jnp.square(x.astype(jnp.float32))))
                for x in jax.tree.leaves(g))
    assert gnorm > 0.0, arch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_reduced_prefill_decode_shapes(arch):
    cfg = get(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    b, s, cache = 2, 16, 24
    batch = make_batch(cfg, b, s)
    logits, caches = jax.jit(
        lambda p, bb: model.prefill(p, bb, cache_len=cache))(params, batch)
    assert logits.shape == (b, 1, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    pos = jnp.asarray(batch["tokens"].shape[1], jnp.int32)
    logits2, _ = jax.jit(model.decode_step)(
        params, caches, {"token": tok, "pos": pos})
    assert logits2.shape == (b, 1, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits2, np.float32)))


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "gemma3-27b",
                                  "mamba2-2.7b", "zamba2-7b",
                                  "mixtral-8x7b"])
def test_decode_matches_prefill_teacher_forced(arch):
    """prefill(t[:k]) then decode t[k], t[k+1], ... must reproduce the
    last-token logits of prefill(t[:k+j]) — the cache IS the sequence."""
    cfg = get(arch).reduced()
    cfg = dataclasses.replace(cfg, dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    b, k, extra = 2, 12, 4
    toks = rng.integers(0, cfg.vocab, (b, k + extra), dtype=np.int32)
    cache = k + extra
    # decode path
    logits, caches = jax.jit(
        lambda p, bb: model.prefill(p, bb, cache_len=cache))(
            params, {"tokens": toks[:, :k]})
    dec_logits = [np.asarray(logits[:, -1], np.float32)]
    step = jax.jit(model.decode_step)
    for j in range(extra):
        logits, caches = step(params, caches,
                              {"token": toks[:, k + j:k + j + 1],
                               "pos": jnp.asarray(k + j, jnp.int32)})
        dec_logits.append(np.asarray(logits[:, -1], np.float32))
    # prefill path references
    for j in range(extra + 1):
        ref_logits, _ = jax.jit(
            lambda p, bb: model.prefill(p, bb, cache_len=cache))(
                params, {"tokens": toks[:, :k + j]})
        np.testing.assert_allclose(
            dec_logits[j], np.asarray(ref_logits[:, -1], np.float32),
            atol=2e-3, rtol=2e-3, err_msg=f"{arch} step {j}")


def test_supports_shape_matrix():
    """long_500k only for sub-quadratic archs, per the assignment."""
    long = next(s for s in SHAPES if s.name == "long_500k")
    expected_runs = {"mamba2-2.7b", "zamba2-7b", "mixtral-8x7b"}
    runs = {a for a in ALL_ARCHS if supports_shape(get(a), long)}
    assert runs == expected_runs
    for s in SHAPES[:3]:
        assert all(supports_shape(get(a), s) for a in ALL_ARCHS)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_input_specs_cover_all_shapes(arch):
    cfg = get(arch)
    for shape in SHAPES:
        spec = input_specs(cfg, shape)
        assert spec, (arch, shape.name)
        for v in jax.tree.leaves(spec):
            assert isinstance(v, jax.ShapeDtypeStruct)
        if shape.kind == "decode":
            assert "token" in spec and "pos" in spec
        assert model_flops(cfg, shape) > 0


def test_param_counts_sane():
    """Full-config parameter counts land near the advertised sizes."""
    expect = {
        "gemma-7b": (7e9, 10e9),
        "starcoder2-3b": (2.5e9, 4e9),
        "gemma3-27b": (20e9, 30e9),
        "qwen3-0.6b": (0.4e9, 0.8e9),
        "arctic-480b": (350e9, 520e9),
        "mixtral-8x7b": (40e9, 50e9),
        "mamba2-2.7b": (2e9, 3.2e9),
        "zamba2-7b": (5e9, 8.5e9),
        "internvl2-2b": (1.5e9, 2.6e9),
        "whisper-medium": (0.6e9, 0.95e9),   # released medium = 769 M
    }
    for arch, (lo, hi) in expect.items():
        n = param_count(get(arch))
        assert lo <= n <= hi, (arch, n)
    # MoE active << total
    assert param_count(get("arctic-480b"), active_only=True) \
        < 0.2 * param_count(get("arctic-480b"))
