from .model_zoo import (batch_pspecs, build_model, input_specs,
                        model_flops, param_count, skip_reason,
                        supports_shape)

__all__ = ["batch_pspecs", "build_model", "input_specs", "model_flops",
           "param_count", "skip_reason", "supports_shape"]
