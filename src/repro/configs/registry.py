"""The 10 assigned architectures — aggregated from the per-arch modules.

``get(name)`` returns the full config; ``get(name).reduced()`` the smoke
variant used by per-arch CPU smoke tests.
"""
from __future__ import annotations

from typing import Dict, Tuple

from .base import ModelConfig
from .gemma_7b import CONFIG as GEMMA_7B
from .starcoder2_3b import CONFIG as STARCODER2_3B
from .gemma3_27b import CONFIG as GEMMA3_27B
from .qwen3_0p6b import CONFIG as QWEN3_0P6B
from .arctic_480b import CONFIG as ARCTIC_480B
from .mixtral_8x7b import CONFIG as MIXTRAL_8X7B
from .whisper_medium import CONFIG as WHISPER_MEDIUM
from .mamba2_2p7b import CONFIG as MAMBA2_2P7B
from .zamba2_7b import CONFIG as ZAMBA2_7B
from .internvl2_2b import CONFIG as INTERNVL2_2B

__all__ = ["ARCHS", "get", "names"]

ARCHS: Dict[str, ModelConfig] = {
    c.name: c for c in (
        GEMMA_7B, STARCODER2_3B, GEMMA3_27B, QWEN3_0P6B, ARCTIC_480B,
        MIXTRAL_8X7B, WHISPER_MEDIUM, MAMBA2_2P7B, ZAMBA2_7B, INTERNVL2_2B)
}


def get(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    return ARCHS[name]


def names() -> Tuple[str, ...]:
    return tuple(ARCHS.keys())
