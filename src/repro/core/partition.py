"""Turn a per-layer placement into contiguous pipeline stages.

The offloading assignment maps each layer independently; real execution
wants *contiguous* stages (one network hop per cut, monotone over the
topological order). ``contiguous_stages`` walks layers in topological
order and cuts wherever the assigned server changes — for chain DAGs
(every LM lowering) this is exact; for branching DAGs (enc-dec) stages
are cut on the topo-linearized order, which preserves every data
dependency (a stage only consumes outputs of earlier stages).

``stage_cut_cost`` prices a stage plan (boundary MB / bandwidth + per-
stage compute) so §Perf can compare the PSO-GA plan against uniform
depth-split baselines.
"""
from __future__ import annotations

from typing import List, NamedTuple

import numpy as np

from .dag import LayerDAG, topological_order
from .environment import Environment

__all__ = ["Stage", "contiguous_stages", "stage_cut_cost",
           "uniform_stages"]


class Stage(NamedTuple):
    server: int
    layers: np.ndarray          # layer ids, topologically ordered


def contiguous_stages(dag: LayerDAG, x: np.ndarray) -> List[Stage]:
    order = topological_order(dag)
    x = np.asarray(x)
    stages: List[Stage] = []
    cur_srv, cur_layers = int(x[order[0]]), [int(order[0])]
    for j in order[1:]:
        s = int(x[j])
        if s == cur_srv:
            cur_layers.append(int(j))
        else:
            stages.append(Stage(cur_srv, np.asarray(cur_layers)))
            cur_srv, cur_layers = s, [int(j)]
    stages.append(Stage(cur_srv, np.asarray(cur_layers)))
    return stages


def uniform_stages(dag: LayerDAG, servers: List[int]) -> np.ndarray:
    """Baseline: split the topo order into len(servers) equal-compute
    chunks (classic pipeline partitioning ignoring cost/bandwidth).
    Returns a per-layer assignment vector."""
    order = topological_order(dag)
    total = dag.compute.sum()
    per = total / len(servers)
    x = np.zeros(dag.num_layers, np.int64)
    acc, si = 0.0, 0
    for j in order:
        if acc >= per * (si + 1) and si < len(servers) - 1:
            si += 1
        x[j] = servers[si]
        acc += dag.compute[j]
    return x


def stage_cut_cost(dag: LayerDAG, env: Environment, x: np.ndarray
                   ) -> dict:
    """Boundary traffic + per-server compute seconds for a placement."""
    x = np.asarray(x)
    cross_mb = 0.0
    cross_s = 0.0
    for (u, v), mb in zip(dag.edges, dag.edge_mb):
        su, sv = int(x[u]), int(x[v])
        if su != sv:
            cross_mb += float(mb)
            bw = env.bandwidth[su, sv]
            cross_s += float(mb) / bw if bw > 0 else float("inf")
    comp_s = {}
    for j in range(dag.num_layers):
        s = int(x[j])
        comp_s[s] = comp_s.get(s, 0.0) + dag.compute[j] / env.power[s]
    return {"cross_mb": cross_mb, "cross_seconds": cross_s,
            "compute_seconds": comp_s,
            "n_stages": len(contiguous_stages(dag, x))}
