"""Decoder-only transformer LM covering the dense, MoE and VLM families.

Layer stacking:
  * uniform patterns (gemma-7b, starcoder2, qwen3, mixtral, arctic,
    internvl2) — parameters are layer-stacked and the depth loop is a
    single ``lax.scan`` whose body is ``jax.checkpoint``-remat'd: HLO size
    and activation memory are O(1) in depth.
  * periodic local:global patterns (gemma3: 5 local + 1 global) — scan
    over whole periods (params stacked (G, P, ...)), with the ≤P-1 leftover
    layers unrolled at the top of the stack.

Decode carries caches through the same scan structure (stacked leading
layer/period axes).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..configs.base import ModelConfig
from . import attention as attn
from . import moe as moe_mod
from .layers import (Params, cross_entropy, divisible, embed_init,
                     embed_pspec, mlp_apply, mlp_init, mlp_pspec, rms_norm,
                     scan_blocks, stack_layers)


def mesh_tp(mesh) -> "int | None":
    """Model-axis size of a mesh (None when no mesh / no model axis)."""
    if mesh is None or "model" not in mesh.axis_names:
        return None
    return int(mesh.shape["model"])

__all__ = ["TransformerLM"]

REMAT_POLICY = jax.checkpoint_policies.dots_with_no_batch_dims_saveable


def _block_init(key: jax.Array, cfg: ModelConfig, dtype) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"ln1": jnp.zeros((cfg.d_model,), dtype),
         "ln2": jnp.zeros((cfg.d_model,), dtype),
         "attn": attn.attn_init(k1, cfg, dtype)}
    if cfg.n_experts:
        p["moe"] = moe_mod.moe_init(k2, cfg, dtype)
    else:
        p["mlp"] = mlp_init(k3, cfg.d_model, cfg.d_ff, cfg.act, dtype)
    return p


def _block_pspec(cfg: ModelConfig, tp=None) -> Params:
    p = {"ln1": P(None), "ln2": P(None), "attn": attn.attn_pspec(cfg, tp)}
    if cfg.n_experts:
        p["moe"] = moe_mod.moe_pspec(cfg, tp)
    else:
        p["mlp"] = mlp_pspec(cfg.act, cfg.d_ff, tp)
    return p


def _with_leading(pspec_tree, n_axes: int = 1):
    """Prepend `n_axes` unsharded leading axes to every PartitionSpec (for
    layer-stacked parameters)."""
    def add(ps):
        return P(*(([None] * n_axes) + list(ps)))
    return jax.tree.map(add, pspec_tree,
                        is_leaf=lambda x: isinstance(x, P))


class TransformerLM:
    """cfg.family in {dense, moe, vlm}."""

    def __init__(self, cfg: ModelConfig,
                 mesh: Optional[jax.sharding.Mesh] = None,
                 data_axes: Tuple[str, ...] = ("data",),
                 moe_impl: str = "scatter"):
        self.cfg = cfg
        self.mesh = mesh
        self.tp = mesh_tp(mesh)
        self.data_axes = data_axes
        self.moe_impl = moe_impl
        self.dtype = jnp.dtype(cfg.dtype)
        period = cfg.local_global_period
        if period:
            self.n_groups, self.n_tail = divmod(cfg.n_layers, period)
        else:
            self.n_groups, self.n_tail = cfg.n_layers, 0

    # ------------------------------------------------------------- params
    def init(self, rng: jax.Array) -> Params:
        cfg = self.cfg
        k_emb, k_blocks, k_tail, k_head, k_vis = jax.random.split(rng, 5)
        params: Params = {
            "embed": embed_init(k_emb, cfg.vocab, cfg.d_model, self.dtype),
            "final_norm": jnp.zeros((cfg.d_model,), self.dtype),
        }
        period = cfg.local_global_period
        if period:
            def group_init(key):
                return stack_layers(
                    lambda k: _block_init(k, cfg, self.dtype), key, period)
            params["blocks"] = stack_layers(group_init, k_blocks,
                                            self.n_groups)
            if self.n_tail:
                params["tail"] = stack_layers(
                    lambda k: _block_init(k, cfg, self.dtype), k_tail,
                    self.n_tail)
        else:
            params["blocks"] = stack_layers(
                lambda k: _block_init(k, cfg, self.dtype), k_blocks,
                cfg.n_layers)
        if not cfg.tie_embeddings:
            params["unembed"] = embed_init(k_head, cfg.vocab, cfg.d_model,
                                           self.dtype).T
        if cfg.family == "vlm":
            # stub projection applied to the (precomputed) patch embeddings
            params["vision_proj"] = embed_init(k_vis, cfg.d_model,
                                               cfg.d_model, self.dtype).T
        return params

    def param_pspecs(self) -> Params:
        cfg = self.cfg
        emb = embed_pspec(cfg.vocab, self.tp)
        specs: Params = {
            "embed": emb,
            "final_norm": P(None),
        }
        blk = _block_pspec(cfg, self.tp)
        period = cfg.local_global_period
        if period:
            specs["blocks"] = _with_leading(blk, 2)
            if self.n_tail:
                specs["tail"] = _with_leading(blk, 1)
        else:
            specs["blocks"] = _with_leading(blk, 1)
        if not cfg.tie_embeddings:
            specs["unembed"] = P(*reversed(tuple(emb)))
        if cfg.family == "vlm":
            dm = "model" if divisible(cfg.d_model, self.tp) else None
            specs["vision_proj"] = P(None, dm)
        return specs

    # -------------------------------------------------------------- embed
    def embed_inputs(self, params: Params, batch: Dict[str, jnp.ndarray]
                     ) -> jnp.ndarray:
        cfg = self.cfg
        tok = batch["tokens"]
        x = params["embed"][tok] * jnp.asarray(
            cfg.d_model ** 0.5, self.dtype)
        if cfg.family == "vlm" and "vision" in batch:
            vis = batch["vision"].astype(self.dtype) @ params["vision_proj"]
            x = jnp.concatenate([vis, x], axis=1)
        return x

    def logits(self, params: Params, h: jnp.ndarray) -> jnp.ndarray:
        h = rms_norm(h, params["final_norm"], self.cfg.norm_eps)
        w = params["embed"].T if self.cfg.tie_embeddings \
            else params["unembed"]
        return h @ w

    # ----------------------------------------------------------- seq path
    def _block_seq(self, p: Params, x: jnp.ndarray, positions: jnp.ndarray,
                   is_global: bool, with_cache: bool):
        cfg = self.cfg
        h, cache = attn.attn_prefill(
            p["attn"], rms_norm(x, p["ln1"], cfg.norm_eps), positions, cfg,
            is_global, with_cache)
        x = x + h
        aux = jnp.asarray(0.0, jnp.float32)
        if cfg.n_experts:
            y, aux = moe_mod.moe_apply(
                p["moe"], rms_norm(x, p["ln2"], cfg.norm_eps), cfg,
                impl=self.moe_impl, mesh=self.mesh,
                data_axes=self.data_axes)
        else:
            y = mlp_apply(p["mlp"], rms_norm(x, p["ln2"], cfg.norm_eps),
                          cfg.act)
        return x + y, cache, aux

    def forward(self, params: Params, batch: Dict[str, jnp.ndarray],
                with_cache: bool = False):
        """Returns (hidden (B,S,D), caches, aux_loss). Caches pytree layout
        matches ``init_caches``."""
        cfg = self.cfg
        x = self.embed_inputs(params, batch)
        b, s, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        period = cfg.local_global_period

        if period:
            def group_body(carry, p_group):
                x, aux = carry
                caches = []
                for l in range(period):
                    p_l = jax.tree.map(lambda a: a[l], p_group)
                    g = (l + 1) % period == 0
                    x, c, a = self._block_seq(p_l, x, positions, g,
                                              with_cache)
                    caches.append(c)
                    aux = aux + a
                local_c = [c for l, c in enumerate(caches)
                           if (l + 1) % period != 0]
                global_c = caches[period - 1]
                ys = None
                if with_cache:
                    ys = {"local": jax.tree.map(
                        lambda *xs: jnp.stack(xs), *local_c),
                        "global": global_c}
                return (x, aux), ys

            body = jax.checkpoint(group_body, policy=REMAT_POLICY) \
                if cfg.remat else group_body
            (x, aux), group_caches = scan_blocks(
                body, (x, jnp.asarray(0.0, jnp.float32)), params["blocks"],
                cfg.scan_layers)
            tail_caches = []
            for l in range(self.n_tail):
                p_l = jax.tree.map(lambda a: a[l], params["tail"])
                x, c, a = self._block_seq(p_l, x, positions, False,
                                          with_cache)
                aux = aux + a
                tail_caches.append(c)
            caches = None
            if with_cache:
                caches = {"groups": group_caches}
                if tail_caches:
                    caches["tail"] = jax.tree.map(
                        lambda *xs: jnp.stack(xs), *tail_caches)
        else:
            is_global = cfg.window == 0

            def body_fn(carry, p_l):
                x, aux = carry
                x, c, a = self._block_seq(p_l, x, positions, is_global,
                                          with_cache)
                return (x, aux + a), c

            body = jax.checkpoint(body_fn, policy=REMAT_POLICY) \
                if cfg.remat else body_fn
            (x, aux), caches = scan_blocks(
                body, (x, jnp.asarray(0.0, jnp.float32)), params["blocks"],
                cfg.scan_layers)
        return x, caches, aux

    # --------------------------------------------------------------- loss
    def loss_fn(self, params: Params, batch: Dict[str, jnp.ndarray]):
        cfg = self.cfg
        tokens = batch["tokens"]
        inp = dict(batch)
        inp["tokens"] = tokens[:, :-1]
        h, _, aux = self.forward(params, inp, with_cache=False)
        labels = tokens[:, 1:]
        if cfg.family == "vlm" and "vision" in batch:
            h = h[:, batch["vision"].shape[1]:]      # loss on text positions
        if cfg.ce_chunk > 1:
            from .layers import chunked_ce
            hn = rms_norm(h, params["final_norm"], cfg.norm_eps)
            w = params["embed"].T if cfg.tie_embeddings \
                else params["unembed"]
            loss = chunked_ce(hn, w, labels, cfg.ce_chunk,
                              scan=cfg.scan_layers)
        else:
            logits = self.logits(params, h)
            loss = cross_entropy(logits, labels)
        if cfg.n_experts:
            loss = loss + 0.01 * aux
        return loss, {"ce": loss, "aux": aux}

    # ------------------------------------------------------------ serving
    def prefill(self, params: Params, batch: Dict[str, jnp.ndarray],
                cache_len: Optional[int] = None):
        cfg = self.cfg
        h, caches, _ = self.forward(params, batch, with_cache=True)
        logits = self.logits(params, h[:, -1:])
        if cache_len is not None:
            s = h.shape[1]
            if cfg.local_global_period:
                caches["groups"] = {
                    "local": attn.grow_cache(caches["groups"]["local"], cfg,
                                             False, cache_len, s),
                    "global": attn.grow_cache(caches["groups"]["global"],
                                              cfg, True, cache_len, s)}
                if "tail" in caches:
                    caches["tail"] = attn.grow_cache(caches["tail"], cfg,
                                                     False, cache_len, s)
            else:
                caches = attn.grow_cache(caches, cfg, cfg.window == 0,
                                         cache_len, s)
        return logits, caches

    def _block_decode(self, p, x, cache, pos, is_global):
        cfg = self.cfg
        h, cache = attn.attn_decode(
            p["attn"], rms_norm(x, p["ln1"], cfg.norm_eps), cache, pos, cfg,
            is_global)
        x = x + h
        if cfg.n_experts:
            y, _ = moe_mod.moe_apply(
                p["moe"], rms_norm(x, p["ln2"], cfg.norm_eps), cfg,
                impl=self.moe_impl, mesh=self.mesh,
                data_axes=self.data_axes)
        else:
            y = mlp_apply(p["mlp"], rms_norm(x, p["ln2"], cfg.norm_eps),
                          cfg.act)
        return x + y, cache

    def decode_step(self, params: Params, caches, batch):
        """batch: {"token": (B,1) int32, "pos": () int32}. Returns
        (logits (B,1,V), new caches)."""
        cfg = self.cfg
        pos = batch["pos"]
        x = params["embed"][batch["token"]] * jnp.asarray(
            cfg.d_model ** 0.5, self.dtype)
        period = cfg.local_global_period

        if period:
            def group_body(x, xs):
                p_group, cache = xs
                new_local, new_global = [], None
                li = 0
                for l in range(period):
                    p_l = jax.tree.map(lambda a: a[l], p_group)
                    g = (l + 1) % period == 0
                    if g:
                        x, c = self._block_decode(p_l, x, cache["global"],
                                                  pos, True)
                        new_global = c
                    else:
                        c_in = jax.tree.map(lambda a: a[li], cache["local"])
                        x, c = self._block_decode(p_l, x, c_in, pos, False)
                        new_local.append(c)
                        li += 1
                ys = {"local": jax.tree.map(lambda *a: jnp.stack(a),
                                            *new_local),
                      "global": new_global}
                return x, ys

            x, group_caches = scan_blocks(
                group_body, x, (params["blocks"], caches["groups"]),
                cfg.scan_layers)
            new_caches = {"groups": group_caches}
            if self.n_tail:
                tail_new = []
                for l in range(self.n_tail):
                    p_l = jax.tree.map(lambda a: a[l], params["tail"])
                    c_in = jax.tree.map(lambda a: a[l], caches["tail"])
                    x, c = self._block_decode(p_l, x, c_in, pos, False)
                    tail_new.append(c)
                new_caches["tail"] = jax.tree.map(
                    lambda *a: jnp.stack(a), *tail_new)
        else:
            is_global = cfg.window == 0

            def body_fn(x, xs):
                p_l, cache = xs
                x, c = self._block_decode(p_l, x, cache, pos, is_global)
                return x, c

            x, new_caches = scan_blocks(
                body_fn, x, (params["blocks"], caches), cfg.scan_layers)
        logits = self.logits(params, x)
        return logits, new_caches

    # ------------------------------------------------------------- caches
    def init_caches(self, batch: int, cache_len: int) -> Params:
        cfg = self.cfg
        period = cfg.local_global_period

        def one(is_global):
            return attn.init_cache(cfg, batch, cache_len, is_global,
                                   self.dtype)

        if period:
            n_local = period - 1
            group = {
                "local": jax.tree.map(
                    lambda *a: jnp.stack(a), *[one(False)] * n_local),
                "global": one(True)}
            caches = {"groups": jax.tree.map(
                lambda a: jnp.broadcast_to(a, (self.n_groups,) + a.shape),
                group)}
            if self.n_tail:
                caches["tail"] = jax.tree.map(
                    lambda *a: jnp.stack(a), *[one(False)] * self.n_tail)
            return caches
        is_global = cfg.window == 0
        stack = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape),
            one(is_global))
        return stack

    def cache_pspecs(self, shard_seq: bool) -> Params:
        cfg = self.cfg
        batch_axes = self.data_axes if len(self.data_axes) > 1 \
            else self.data_axes[0]
        base = attn.cache_pspec(batch_axes, shard_seq,
                                divisible(cfg.n_kv_heads, self.tp),
                                quantized=cfg.kv_dtype == "int8")
        period = cfg.local_global_period
        if period:
            group = {"local": _with_leading(base, 2),
                     "global": _with_leading(base, 1)}
            caches = {"groups": group}
            if self.n_tail:
                caches["tail"] = _with_leading(base, 1)
            return caches
        return _with_leading(base, 1)
