"""Async request ingestion (repro.core.traffic.ArrivalQueue +
run_service's ingest modes, DESIGN.md §11 phase 2): queue semantics and
backpressure counters, the deterministic single-thread mode's bit-parity
with the legacy synchronous draws, and the threaded producers."""
import numpy as np
import pytest

from repro.core import (ArrivalQueue, IngestConfig, PSOGAConfig,
                        ReplanConfig, ServiceConfig, SimProblem,
                        TrafficConfig, heft_makespan, paper_environment,
                        plan_is_valid, run_service, sample_trace, zoo)

FAST = PSOGAConfig(pop_size=20, max_iters=50, stall_iters=18)
TCFG = TrafficConfig(rate=0.4, max_requests=4, mc_solver=2, mc_eval=4)
RCFG_T = ReplanConfig(pso=FAST, traffic=TCFG)


@pytest.fixture(scope="module")
def fleet():
    env = paper_environment()
    dags = []
    for i, net in enumerate(("alexnet", "googlenet")):
        dag = zoo.build(net, pin_server=i)
        h, _ = heft_makespan(dag, env)
        dags.append(dag.with_deadline(np.array([1.5 * h])))
    return env, dags


# ---------------------------------------------------------------------------
# ArrivalQueue / IngestConfig units
# ---------------------------------------------------------------------------

def test_arrival_queue_fifo_and_counters():
    q = ArrivalQueue(capacity=4)
    for i in range(3):
        assert q.put(i)
    assert q.depth() == 3
    assert q.drain() == [0, 1, 2]
    assert q.depth() == 0 and q.drain() == []
    c = q.counters()
    assert c["enqueued"] == 3 and c["drained"] == 3
    assert c["dropped"] == 0 and c["max_depth"] == 3 and c["depth"] == 0


def test_arrival_queue_drops_when_full():
    q = ArrivalQueue(capacity=2)
    assert q.put("a") and q.put("b")
    assert not q.put("c")               # bounded: drop, don't block
    c = q.counters()
    assert c["enqueued"] == 2 and c["dropped"] == 1
    assert q.drain() == ["a", "b"]


def test_arrival_queue_rejects_bad_capacity():
    with pytest.raises(ValueError, match="capacity"):
        ArrivalQueue(capacity=0)


@pytest.mark.parametrize("kwargs,match", [
    ({"threads": -1}, "threads"),
    ({"capacity": 0}, "capacity"),
])
def test_ingest_config_rejects(kwargs, match):
    with pytest.raises(ValueError, match=match):
        IngestConfig(**kwargs)


def test_service_config_ingest_requires_estimation():
    with pytest.raises(ValueError, match="estimate_rates"):
        ServiceConfig(ingest=IngestConfig())


def test_run_service_ingest_requires_traffic(fleet):
    env, dags = fleet
    trace = sample_trace("load-surge", env, rounds=2, seed=1)
    cfg = ServiceConfig(replan=ReplanConfig(pso=FAST),  # no traffic model
                        estimate_rates=True, ingest=IngestConfig())
    with pytest.raises(ValueError, match="traffic"):
        run_service(dags, trace, cfg, seed=1)


# ---------------------------------------------------------------------------
# service integration
# ---------------------------------------------------------------------------

def test_sync_ingest_bit_identical_to_legacy(fleet):
    """threads=0 is the deterministic mode: same draws in the same
    order as the legacy synchronous estimate_rates path, so estimates,
    rungs and plans all match bit for bit."""
    env, dags = fleet
    trace = sample_trace("load-surge", env, rounds=4, seed=5)
    legacy = run_service(
        dags, trace,
        ServiceConfig(replan=RCFG_T, estimate_rates=True,
                      window_rounds=2),
        seed=7)
    queued = run_service(
        dags, trace,
        ServiceConfig(replan=RCFG_T, estimate_rates=True,
                      window_rounds=2, ingest=IngestConfig(threads=0)),
        seed=7)
    for rl, rq in zip(legacy.rounds, queued.rounds):
        assert rq.est_rates == rl.est_rates
        assert rq.rung == rl.rung
    for xl, xq in zip(legacy.plans, queued.plans):
        assert np.array_equal(xl, xq)
    # all observations flowed through the queue, none dropped
    c = queued.counters
    assert c["ingest_enqueued"] == (trace.num_rounds - 1) * len(dags)
    assert c["ingest_dropped"] == 0
    assert c["ingest_drained"] == c["ingest_enqueued"]
    assert c["ingest_leftover"] == 0


def test_sync_ingest_backpressure_drops_deterministically(fleet):
    """capacity=1 in the deterministic mode: each round enqueues one
    observation per DAG but only the first fits, so the drop count is
    exact — and the service still serves every round."""
    env, dags = fleet
    trace = sample_trace("load-surge", env, rounds=4, seed=5)
    rep = run_service(
        dags, trace,
        ServiceConfig(replan=RCFG_T, estimate_rates=True,
                      window_rounds=2,
                      ingest=IngestConfig(threads=0, capacity=1)),
        seed=7)
    c = rep.counters
    assert c["ingest_dropped"] == (trace.num_rounds - 1) * (len(dags) - 1)
    assert c["ingest_enqueued"] == trace.num_rounds - 1
    assert rep.availability() == 1.0


def test_threaded_ingest_serves_every_round(fleet):
    """threads>0 pre-draws observations concurrently; ordering is no
    longer bit-deterministic but the conservation law and availability
    must hold."""
    env, dags = fleet
    trace = sample_trace("load-surge", env, rounds=4, seed=5)
    rep = run_service(
        dags, trace,
        ServiceConfig(replan=RCFG_T, estimate_rates=True,
                      window_rounds=2,
                      ingest=IngestConfig(threads=2, capacity=64)),
        seed=7)
    assert rep.availability() == 1.0
    c = rep.counters
    assert c["ingest_enqueued"] \
        == c["ingest_drained"] + c["ingest_leftover"]
    assert c["ingest_dropped"] + c["ingest_enqueued"] \
        == (trace.num_rounds - 1) * len(dags)
    assert all(len(r.est_rates) == len(dags) for r in rep.rounds)
    for dag, x in zip(dags, rep.plans):
        assert plan_is_valid(
            SimProblem.build(dag, trace.env_at(trace.num_rounds - 1)), x)
