"""Traffic engine benchmark (DESIGN.md §10, EXPERIMENTS.md §Traffic):
sweep arrival intensity × scenario family and compare, at MATCHED solver
budgets (same PSOGAConfig, same seed):

  * **zero-load plan** — the paper's single-shot solve, then evaluated
    under the request stream it never saw;
  * **traffic-aware plan** — the same solver with the queue-aware
    Monte-Carlo fitness (p95 deadline-miss budget);
  * **greedy baseline** — the paper's greedy competitor, evaluated
    under the same stream (HEFT's makespan anchors every deadline).

Both plans are scored on a HELD-OUT arrival set (disjoint seed stream
from the solver's draws), reporting p50/p95/p99 deadline-miss rates,
load-adjusted cost, and solver wall-clock. Acceptance bar (ISSUE-5):
the traffic-aware plan's p95 miss rate must be STRICTLY below the
zero-load plan's on the bursty and flash-crowd families. Every run
writes machine-readable ``BENCH_traffic.json``.
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.core import (PSOGAConfig, SimProblem, TRAFFIC_KINDS,
                        TrafficConfig, greedy_offload, heft_makespan,
                        paper_environment, run_pso_ga_batch,
                        traffic_replay, traffic_stats, zoo)

from .common import bench_metadata, print_csv

#: CPU-friendly matched budget for both arms
TRAFFIC_CFG = PSOGAConfig(pop_size=24, max_iters=60, stall_iters=20)
NETS = ("alexnet", "googlenet")


def build_problems(ratio: float):
    env = paper_environment()
    dags, probs = [], []
    for i, net in enumerate(NETS):
        dag = zoo.build(net, pin_server=i)
        h, _ = heft_makespan(dag, env)
        dag = dag.with_deadline(np.array([ratio * h]))
        dags.append(dag)
        probs.append(SimProblem.build(dag, env))
    return env, dags, probs


def run_cell(kind: str, rate: float, cfg: PSOGAConfig, ratio: float,
             seed: int, mc_eval: int):
    env, dags, probs = build_problems(ratio)
    tc = TrafficConfig(kind=kind, rate=rate, horizon=30.0, max_requests=8,
                       mc_solver=3, mc_eval=mc_eval,
                       miss_budget=cfg.miss_budget)
    n = len(probs)
    t0 = time.perf_counter()
    zero = run_pso_ga_batch(probs, cfg, seed=seed)
    wall_zero = time.perf_counter() - t0
    arrs = [tc.solver_arrivals(1, seed=seed + 31 * i) for i in range(n)]
    t0 = time.perf_counter()
    aware = run_pso_ga_batch(probs, cfg, seed=seed, arrivals=arrs)
    wall_aware = time.perf_counter() - t0

    rows = []
    for i, net in enumerate(NETS):
        ev = tc.eval_arrivals(1, seed=seed + 31 * i)
        stats = {}
        plans = {
            "zero": zero[i].best_x,
            "aware": aware[i].best_x,
            "greedy": greedy_offload(dags[i], env,
                                     faithful=cfg.faithful_sim).best_x,
        }
        for arm, x in plans.items():
            stats[arm] = traffic_stats(traffic_replay(
                probs[i], x, ev, faithful=cfg.faithful_sim))
        rows.append({
            "kind": kind, "rate": rate, "net": net,
            "zero_miss_p95": stats["zero"]["miss_p95"],
            "aware_miss_p95": stats["aware"]["miss_p95"],
            "greedy_miss_p95": stats["greedy"]["miss_p95"],
            "zero_miss_mean": stats["zero"]["miss_mean"],
            "aware_miss_mean": stats["aware"]["miss_mean"],
            "zero_load_cost": stats["zero"]["cost_mean"],
            "aware_load_cost": stats["aware"]["cost_mean"],
            "greedy_load_cost": stats["greedy"]["cost_mean"],
            "requests": stats["zero"]["requests"],
            "zero_wall_s": wall_zero,
            "aware_wall_s": wall_aware,
            "aware_iters": int(aware[i].iterations),
        })
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--kinds", nargs="*", default=["all"],
                    choices=list(TRAFFIC_KINDS) + ["all"])
    ap.add_argument("--rates", type=float, nargs="*",
                    default=[0.2, 0.5])
    ap.add_argument("--ratio", type=float, default=1.5,
                    help="deadline ratio r in D = r · HEFT (Eq. 24)")
    ap.add_argument("--mc-eval", type=int, default=16,
                    help="held-out Monte-Carlo arrival seeds per cell")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", default="BENCH_traffic.json",
                    help="machine-readable results ('' to disable)")
    args = ap.parse_args()
    kinds = TRAFFIC_KINDS if "all" in args.kinds else args.kinds

    all_rows, summaries = [], []
    for kind in kinds:
        kind_rows = []
        for rate in args.rates:
            rows = run_cell(kind, rate, TRAFFIC_CFG, args.ratio,
                            args.seed, args.mc_eval)
            for r in rows:
                print(f"# {kind} rate={rate} {r['net']}: miss p95 "
                      f"zero {r['zero_miss_p95']:.3f} -> aware "
                      f"{r['aware_miss_p95']:.3f} (greedy "
                      f"{r['greedy_miss_p95']:.3f}), load cost "
                      f"${r['zero_load_cost']:.4f} -> "
                      f"${r['aware_load_cost']:.4f}, solver "
                      f"{r['zero_wall_s']:.1f}s -> {r['aware_wall_s']:.1f}s",
                      flush=True)
            kind_rows.extend(rows)
        zero_p95 = float(np.mean([r["zero_miss_p95"] for r in kind_rows]))
        aware_p95 = float(np.mean([r["aware_miss_p95"] for r in kind_rows]))
        summaries.append({
            "kind": kind,
            "zero_miss_p95_mean": zero_p95,
            "aware_miss_p95_mean": aware_p95,
            "aware_strictly_better": bool(aware_p95 < zero_p95),
            "aware_wall_mean_s": float(np.mean(
                [r["aware_wall_s"] for r in kind_rows])),
            "zero_wall_mean_s": float(np.mean(
                [r["zero_wall_s"] for r in kind_rows])),
        })
        bar = kind in ("bursty", "flash-crowd")
        ok = aware_p95 < zero_p95
        print(f"# {kind}: mean p95 miss zero {zero_p95:.3f} vs aware "
              f"{aware_p95:.3f} -> "
              f"{'PASS' if ok else ('MISS' if bar else 'info')}",
              flush=True)
        all_rows.extend(kind_rows)
    print_csv(all_rows, ["kind", "rate", "net", "zero_miss_p95",
                         "aware_miss_p95", "greedy_miss_p95",
                         "zero_load_cost", "aware_load_cost",
                         "requests", "zero_wall_s", "aware_wall_s"])
    if args.json:
        payload = {
            "bench": "bench_traffic",
            "meta": bench_metadata(seeds=[args.seed]),
            "pso": {"pop_size": TRAFFIC_CFG.pop_size,
                    "max_iters": TRAFFIC_CFG.max_iters,
                    "stall_iters": TRAFFIC_CFG.stall_iters,
                    "miss_budget": TRAFFIC_CFG.miss_budget},
            "ratio": args.ratio,
            "rates": args.rates,
            "mc_eval": args.mc_eval,
            "rows": all_rows,
            "scenarios": summaries,
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"# wrote {args.json}")


if __name__ == "__main__":
    main()
