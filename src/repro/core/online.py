"""Online re-planning for drifting fleets (DESIGN.md §9).

The paper solves a *static* snapshot — one environment, one solve, one
plan — but the quantity its whole cost model hinges on (WAN bandwidth,
Eq. 6) is exactly what drifts in production. This module keeps a fleet's
plans good as the environment changes:

  * ``EnvTrace`` — a piecewise-constant time-varying environment: a base
    ``Environment`` plus a sequence of ``DriftEvent``s, each scaling
    bandwidth / power / price per server (or severing a churned node's
    links). Shapes never change — only array values — so every
    re-planning round after the first reuses the compiled fleet runner
    (``batch.runner_cache_stats()`` proves it).
  * ``sample_trace`` — generators for five drift families: ``wifi-fade``
    (device↔edge fade random walk), ``congestion`` (WAN cloud links),
    ``spot-price`` (cloud rental multipliers), ``node-loss`` (an edge or
    cloud server churns out and recovers), and ``load-surge`` (the
    environment holds still but the REQUEST STREAM surges: each epoch
    scales the arrival intensity of the traffic engine, DESIGN.md §10,
    so replanning reacts to workload drift, not just bandwidth drift).
  * ``replan_round`` / ``replan_fleet`` — the event-driven loop: at each
    drift event the whole fleet is re-solved by ``run_pso_ga_batch``
    **warm-started** from the incumbent plans (``init_swarm`` incumbent
    mode: elite clones + mutated neighborhoods) with the Eq. 6-form
    migration term (``fitness.migration_cost``) so replans prefer cheap
    plan deltas. A candidate replaces the incumbent only when its
    migration-adjusted key strictly beats the incumbent's key under the
    NEW environment — a drift-free round therefore keeps the incumbent
    bit-for-bit.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .batch import pack_arrivals, pack_fleet, run_pso_ga_batch
from .dag import LayerDAG
from .environment import CLOUD, DEVICE, EDGE, Environment
from .fitness import INFEASIBLE_OFFSET, make_swarm_fitness
from .pso_ga import PSOGAConfig, PSOGAResult
from .seeding import rng_entropy
from .simulator import SimProblem
from .telemetry import Telemetry, maybe_span
from .traffic import TrafficConfig

__all__ = ["DriftEvent", "EnvTrace", "ReplanConfig", "RoundLog",
           "OnlineReport", "sample_trace", "zero_drift_trace",
           "replan_round", "replan_fleet", "TRACE_KINDS",
           "incumbent_keys", "migration_cost_np", "plan_is_valid"]

TRACE_KINDS = ("wifi-fade", "congestion", "spot-price", "node-loss",
               "load-surge")


@dataclasses.dataclass(frozen=True)
class DriftEvent:
    """One piecewise-constant epoch of the trace.

    Scales are multiplicative against the BASE environment (not the
    previous epoch), so epochs are order-independent and a scale of 1
    everywhere is exactly the base environment. ``down`` severs every
    off-diagonal link of the flagged servers (node churn): placements on
    them become link-infeasible, which is how Algorithm 2 already treats
    unreachable servers — no new simulator machinery needed.
    ``load_scale`` multiplies the arrival intensity of the traffic
    engine's request stream (DESIGN.md §10) and leaves the environment
    untouched — workload drift rides the same trace machinery.
    """
    t: float                      # event time (s since trace start)
    label: str                    # human tag, e.g. "wifi-fade[0.41]"
    bw_scale: np.ndarray          # (S, S) on bandwidth (MB/s)
    power_scale: np.ndarray      # (S,)  on compute power
    price_scale: np.ndarray      # (S,)  on rental $/s
    down: np.ndarray             # (S,)  bool — server churned out
    load_scale: float = 1.0      # on request arrival rate (traffic)

    def __post_init__(self):
        # malformed drift events must die HERE, not as NaN keys inside a
        # jitted fitness or a shape error three modules away (the
        # service's chaos harness feeds snapshots through this gate,
        # DESIGN.md §11).
        object.__setattr__(self, "bw_scale",
                           np.asarray(self.bw_scale, np.float64))
        object.__setattr__(self, "power_scale",
                           np.asarray(self.power_scale, np.float64))
        object.__setattr__(self, "price_scale",
                           np.asarray(self.price_scale, np.float64))
        object.__setattr__(self, "down", np.asarray(self.down, bool))
        s = self.down.shape[0] if self.down.ndim == 1 else -1
        if s < 1 or self.bw_scale.shape != (s, s) \
                or self.power_scale.shape != (s,) \
                or self.price_scale.shape != (s,):
            raise ValueError(
                f"malformed drift event {self.label!r}: expected "
                f"bw_scale (S, S) with power/price/down (S,), got "
                f"bw={self.bw_scale.shape} power={self.power_scale.shape} "
                f"price={self.price_scale.shape} down={self.down.shape}")
        for name in ("bw_scale", "power_scale", "price_scale"):
            arr = getattr(self, name)
            if not np.all(np.isfinite(arr)) or np.any(arr < 0.0):
                raise ValueError(f"drift event {self.label!r}: {name} "
                                 f"must be finite and >= 0")
        if not np.isfinite(self.t) or self.t < 0.0:
            raise ValueError(f"drift event {self.label!r}: t must be a "
                             f"finite time >= 0, got {self.t!r}")
        if not np.isfinite(self.load_scale) or self.load_scale <= 0.0:
            raise ValueError(f"drift event {self.label!r}: load_scale "
                             f"must be finite and > 0, "
                             f"got {self.load_scale!r}")

    @property
    def num_servers(self) -> int:
        return int(self.down.shape[0])

    def is_identity(self) -> bool:
        return (not self.down.any()
                and np.all(self.bw_scale == 1.0)
                and np.all(self.power_scale == 1.0)
                and np.all(self.price_scale == 1.0)
                and self.load_scale == 1.0)


@dataclasses.dataclass(frozen=True)
class EnvTrace:
    """A base environment plus one ``DriftEvent`` per re-planning round.

    ``events[0]`` is the admission-time epoch (the cold solve);
    ``env_at(k)`` materializes the environment of round ``k``. Every
    epoch has the same server count, so packed problem shapes are
    identical across rounds and the compiled fleet runner is reused
    (DESIGN.md §9).
    """
    base: Environment
    events: Tuple[DriftEvent, ...]

    def __post_init__(self):
        object.__setattr__(self, "events", tuple(self.events))
        if not self.events:
            raise ValueError("EnvTrace needs at least one event "
                             "(round 0 is the admission-time epoch)")
        s = self.base.num_servers
        for k, ev in enumerate(self.events):
            if ev.num_servers != s:
                raise ValueError(
                    f"EnvTrace event {k} ({ev.label!r}) is sized for "
                    f"{ev.num_servers} servers but the base environment "
                    f"has {s} — shapes must never change across a trace")

    @property
    def num_rounds(self) -> int:
        return len(self.events)

    def env_at(self, k: int) -> Environment:
        ev = self.events[k]
        bw = self.base.bandwidth * ev.bw_scale
        if ev.down.any():
            off = ~np.eye(self.base.num_servers, dtype=bool)
            dead = ev.down[:, None] | ev.down[None, :]
            bw = np.where(dead & off, 0.0, bw)
        return Environment(
            power=np.maximum(self.base.power * ev.power_scale, 1e-12),
            cost_per_sec=self.base.cost_per_sec * ev.price_scale,
            tier=self.base.tier,
            bandwidth=bw,
            tran_cost=self.base.tran_cost)


def _identity_event(s: int, t: float, label: str) -> DriftEvent:
    return DriftEvent(t=t, label=label,
                      bw_scale=np.ones((s, s)),
                      power_scale=np.ones(s),
                      price_scale=np.ones(s),
                      down=np.zeros(s, bool))


def zero_drift_trace(env: Environment, rounds: int = 2,
                     period: float = 60.0) -> EnvTrace:
    """A trace whose every epoch IS the base environment (the warm-start
    parity fixture: replans must keep the incumbent bit-for-bit)."""
    s = env.num_servers
    return EnvTrace(base=env, events=tuple(
        _identity_event(s, k * period, "zero-drift")
        for k in range(rounds)))


def _tier_pair_mask(tier: np.ndarray, ta: int, tb: int) -> np.ndarray:
    """(S, S) bool — links whose endpoints are tiers {ta, tb} (symmetric)."""
    a = tier == ta
    b = tier == tb
    return (a[:, None] & b[None, :]) | (b[:, None] & a[None, :])


def sample_trace(kind: str, env: Environment, rounds: int,
                 seed: int = 0, period: float = 60.0,
                 severity: float = 0.6) -> EnvTrace:
    """Generate a drift trace of one of the four scenario families.

    ``wifi-fade``  — WIFI device↔edge bandwidth fades on a bounded random
                     walk in [1 − severity, 1] (Eq. 6's denominator is
                     the drifting quantity).
    ``congestion`` — WAN cloud↔{cloud, edge, device} bandwidth scaled by
                     congestion in [1 − severity, 1].
    ``spot-price`` — cloud-tier rental rates multiplied by a spot factor
                     in [1 − severity/2, 1 + severity].
    ``node-loss``  — one non-device server churns out per drift epoch
                     (links severed), recovering before the next draw.
    ``load-surge`` — the environment holds still; the request stream's
                     arrival rate is scaled by a surge factor in
                     [1, 1 + 7·severity] (traffic drift, DESIGN.md §10 —
                     consumed by ``replan_fleet`` when its config
                     carries a ``TrafficConfig``).

    Round 0 is always the identity epoch (the cold solve's environment).
    ``severity`` ∈ (0, 1] controls drift amplitude; events are ``period``
    seconds apart.
    """
    if kind not in TRACE_KINDS:
        raise ValueError(f"unknown trace kind {kind!r} "
                         f"(expected one of {TRACE_KINDS})")
    if int(rounds) < 1:
        raise ValueError(f"rounds must be >= 1, got {rounds!r}")
    if not np.isfinite(period) or period <= 0.0:
        raise ValueError(f"period must be a positive finite number of "
                         f"seconds, got {period!r}")
    if not np.isfinite(severity) or not 0.0 < severity <= 1.0:
        raise ValueError(f"severity must be finite in (0, 1], "
                         f"got {severity!r}")
    rng = np.random.default_rng(rng_entropy(seed))
    s = env.num_servers
    tier = np.asarray(env.tier)
    events: List[DriftEvent] = [_identity_event(s, 0.0, f"{kind}[base]")]
    lo = 1.0 - severity
    fade = 1.0
    for k in range(1, rounds):
        ev = _identity_event(s, k * period, kind)
        if kind == "wifi-fade":
            fade = float(np.clip(fade + rng.uniform(-0.5, 0.35) * severity,
                                 lo, 1.0))
            m = _tier_pair_mask(tier, DEVICE, EDGE)
            bw = np.ones((s, s))
            bw[m] = fade
            ev = dataclasses.replace(ev, bw_scale=bw,
                                     label=f"wifi-fade[{fade:.2f}]")
        elif kind == "congestion":
            cong = float(rng.uniform(lo, 1.0))
            m = (_tier_pair_mask(tier, CLOUD, CLOUD)
                 | _tier_pair_mask(tier, CLOUD, EDGE)
                 | _tier_pair_mask(tier, CLOUD, DEVICE))
            bw = np.ones((s, s))
            bw[m] = cong
            ev = dataclasses.replace(ev, bw_scale=bw,
                                     label=f"congestion[{cong:.2f}]")
        elif kind == "spot-price":
            spot = float(rng.uniform(1.0 - severity / 2, 1.0 + severity))
            price = np.ones(s)
            price[tier == CLOUD] = spot
            ev = dataclasses.replace(ev, price_scale=price,
                                     label=f"spot-price[{spot:.2f}]")
        elif kind == "load-surge":
            surge = float(rng.uniform(1.0, 1.0 + 7.0 * severity))
            ev = dataclasses.replace(ev, load_scale=surge,
                                     label=f"load-surge[{surge:.1f}x]")
        else:                                   # node-loss
            cands = np.nonzero(tier != DEVICE)[0]
            victim = int(rng.choice(cands))
            down = np.zeros(s, bool)
            down[victim] = True
            ev = dataclasses.replace(ev, down=down,
                                     label=f"node-loss[s{victim}]")
        events.append(ev)
    return EnvTrace(base=env, events=tuple(events))


# ---------------------------------------------------------------------------
# the event-driven re-planning loop
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ReplanConfig:
    """Knobs of the warm-started re-planning loop (DESIGN.md §9)."""
    pso: PSOGAConfig = PSOGAConfig(pop_size=32, max_iters=150,
                                   stall_iters=30)
    migration_weight: float = 1.0   # $ per Eq.6-MB of moved input dataset
    #: queue-aware re-planning (DESIGN.md §10): when set, every round
    #: solves under this request-stream model with the round's arrival
    #: rate scaled by the drift event's ``load_scale`` — the
    #: ``load-surge`` family then drives replans with the environment
    #: bit-still.
    traffic: Optional[TrafficConfig] = None
    #: device mesh for the fleet solver (DESIGN.md §12): every round's
    #: warm solve shards its shape buckets across the mesh's data axes.
    #: Gene-for-gene identical to the single-device path, so replan
    #: decisions are mesh-invariant.
    mesh: Optional[jax.sharding.Mesh] = None


class RoundLog(NamedTuple):
    """Everything one drift event's replan decided, per problem."""
    round: int
    label: str
    replanned: np.ndarray        # (N,) bool — candidate accepted
    incumbent_key: np.ndarray    # (N,) incumbent fitness under NEW env
    candidate_key: np.ndarray    # (N,) warm gbest key (migration-adjusted)
    cost: np.ndarray             # (N,) final plan's raw cost this round
    migration: np.ndarray        # (N,) Eq.6-form $ paid to adopt the plan
    feasible: np.ndarray         # (N,) final plan feasible this round
    moved_layers: np.ndarray     # (N,) genes changed by the accepted plan
    iterations: np.ndarray       # (N,) warm-solve iterations executed
    converge_iters: np.ndarray   # (N,) iterations until the final gbest
    #   was found (it − stall at exit: the stopping rule then confirms it
    #   for stall_iters more) — the warm-vs-cold convergence metric
    wall_s: float                # replan wall-clock for the round
    demoted: np.ndarray = None   # (N,) bool — incumbent failed the
    #   stale-plan guard (plan_is_valid) and was cold-started instead of
    #   warm-seeded (DESIGN.md §11); its migration is 0 and moved_layers
    #   counts the full plan


@dataclasses.dataclass
class OnlineReport:
    """Output of ``replan_fleet``: the cold round-0 results plus one
    ``RoundLog`` per drift event, and the final surviving plans."""
    cold: List[PSOGAResult]
    rounds: List[RoundLog]
    plans: List[np.ndarray]      # final per-problem assignments

    def total_cost(self) -> float:
        """Σ over problems of the last round's plan cost."""
        if self.rounds:
            return float(np.sum(self.rounds[-1].cost))
        return float(sum(r.best_cost for r in self.cold
                         if np.isfinite(r.best_cost)))


@partial(jax.jit, static_argnames=("faithful", "backend"))
def _fleet_keys(ppb, Xb, faithful: bool, backend: str):
    """(N,) fitness keys of one assignment per problem — the incumbent
    re-evaluated under a drifted environment. jit caches on the packed
    shapes, which are constant across rounds."""
    return jax.vmap(
        lambda pp, x: make_swarm_fitness(pp, faithful, backend)(
            x[None, :])[0])(ppb, Xb)


@partial(jax.jit, static_argnames=("faithful", "backend", "miss_budget"))
def _fleet_keys_traffic(ppb, Xb, arrb, faithful: bool, backend: str,
                        miss_budget: float):
    """Traffic twin of ``_fleet_keys``: the incumbent's queue-aware key
    under the round's arrival draws (DESIGN.md §10). Arrivals are traced
    values — a load surge never retraces."""
    return jax.vmap(
        lambda pp, x, arr: make_swarm_fitness(
            pp, faithful, backend, arrivals=arr,
            miss_budget=miss_budget)(x[None, :])[0])(ppb, Xb, arrb)


def migration_cost_np(prob: SimProblem, old: np.ndarray,
                      new: np.ndarray) -> float:
    """Numpy twin of ``fitness.migration_cost`` for one assignment pair:
    every moved layer pays its input-dataset MBs over the old→new link."""
    old = np.asarray(old, np.int64)
    new = np.asarray(new, np.int64)
    input_mb = prob.parent_mb.sum(axis=1)
    moved = old != new
    return float(np.sum(np.where(moved,
                                 input_mb * prob.tran_cost[old, new], 0.0)))


def plan_is_valid(prob: SimProblem, plan) -> bool:
    """Static validity of one assignment under ``prob``'s environment.

    True iff ``plan`` is a 1-d integral vector of shape
    ``(num_layers,)`` whose genes are in ``[0, num_servers)``, honor the
    pins, and route every real DAG edge over a live link (``link_ok`` or
    same-server). This is the stale-plan guard's gate (DESIGN.md §11):
    anything that fails here must not warm-seed a swarm — a stale
    incumbent after node churn, a NaN-poisoned array, a plan sized for a
    different fleet. It deliberately does NOT check deadlines or cost —
    a deadline-stranded incumbent is still a legal warm seed (the rescue
    path handles it); garbage is not.
    """
    x = np.asarray(plan)
    if x.ndim != 1 or x.shape[0] != prob.num_layers:
        return False
    if not np.issubdtype(x.dtype, np.integer):
        if not np.all(np.isfinite(x)) or not np.all(x == np.floor(x)):
            return False
    x = x.astype(np.int64)
    if np.any(x < 0) or np.any(x >= prob.num_servers):
        return False
    if np.any((prob.pinned >= 0) & (x != prob.pinned)):
        return False
    # every real parent edge must ride an OK link (same-server is free)
    pj = np.asarray(prob.parent_idx)
    real = pj >= 0
    src = x[np.where(real, pj, 0)]                 # (p, max_in)
    dst = x[:, None]
    edge_ok = np.asarray(prob.link_ok)[src, dst] | (src == dst)
    return bool(np.all(edge_ok | ~real))


def incumbent_keys(probs: Sequence[SimProblem],
                   incumbent: Sequence[np.ndarray],
                   cfg: PSOGAConfig,
                   arrivals: Optional[Sequence[np.ndarray]] = None
                   ) -> np.ndarray:
    """Fitness keys of the incumbent plans under ``probs``'s environment
    (no migration term: keeping the incumbent moves nothing). With
    ``arrivals`` (per-problem Monte-Carlo draws) the keys are the
    queue-aware traffic keys under ``cfg.miss_budget`` (DESIGN.md §10).
    A ``None`` entry (a demoted incumbent, DESIGN.md §11) keys as +inf —
    any candidate strictly beats it.

    Evaluation is bucketed exactly like the solver (``pack_fleet``,
    DESIGN.md §12): each shape bucket keys through its own jit-cached
    ``_fleet_keys`` at the bucket's padded shape, and the keys scatter
    back to input order — so the incumbent's key and the warm
    candidate's key always come from identically-shaped programs.
    """
    probs = list(probs)
    fleet = pack_fleet(probs)
    keys = np.zeros(len(probs), np.float64)
    missing = np.zeros(len(probs), bool)
    for b in fleet.buckets:
        nb = int(b.idx.shape[0])
        Xb = np.zeros((nb, b.max_p), np.int32)
        for j, i in enumerate(b.idx):
            inc = incumbent[i]
            if inc is None:
                missing[i] = True
            else:
                Xb[j, :probs[i].num_layers] = np.asarray(inc, np.int32)
        if arrivals is not None:
            arrb = jnp.asarray(pack_arrivals(
                [arrivals[i] for i in b.idx], fleet.max_apps))
            kb = np.array(_fleet_keys_traffic(
                b.ppb, jnp.asarray(Xb), arrb, cfg.faithful_sim,
                cfg.fitness_backend, cfg.miss_budget))
        else:
            kb = np.array(_fleet_keys(b.ppb, jnp.asarray(Xb),
                                      cfg.faithful_sim,
                                      cfg.fitness_backend))
        keys[b.idx] = kb
    keys[missing] = np.inf
    return keys


def replan_round(probs: Sequence[SimProblem],
                 incumbent: Sequence[np.ndarray],
                 cfg: ReplanConfig = ReplanConfig(),
                 seed: int = 0,
                 round_no: int = 0,
                 label: str = "",
                 arrivals: Optional[Sequence[np.ndarray]] = None,
                 telemetry: Optional[Telemetry] = None
                 ) -> Tuple[List[np.ndarray], RoundLog]:
    """One drift event: warm re-solve the fleet, accept-if-better.

    ``probs`` carry the NEW (drifted) environment. Each problem's swarm
    is warm-started from its incumbent; the candidate's migration-
    adjusted key must STRICTLY beat the incumbent's key under the new
    environment to be accepted — staying put is free, so a zero-drift
    event keeps every incumbent bit-for-bit (the warm-start parity
    invariant, tested in tests/test_online.py).

    With ``arrivals`` (per-problem Monte-Carlo draws — the round's
    request stream, DESIGN.md §10) both sides of the comparison are
    queue-aware traffic keys: a surge that strands the incumbent over
    the miss budget triggers a replan exactly like an env drift would,
    and ``feasible``/``cost`` then report the traffic key's verdict
    (seed-mean load-adjusted cost).

    Returns the surviving per-problem plans and the round's log.

    ``telemetry`` (DESIGN.md §13) wraps the round in a ``replan_round``
    span (with ``incumbent_keys`` / ``warm_solve`` children), takes
    ``wall_s`` from the injectable clock, and counts replans/demotions
    under ``online.*`` — plans are bit-identical with it on or off.
    """
    n = len(probs)
    clock = telemetry.clock if telemetry is not None \
        else time.perf_counter
    span = maybe_span(telemetry, "replan_round", round=round_no,
                      label=label, n=n)
    with span:
        return _replan_round_body(probs, incumbent, cfg, seed, round_no,
                                  label, arrivals, telemetry, clock)


def _replan_round_body(probs, incumbent, cfg, seed, round_no, label,
                       arrivals, telemetry, clock
                       ) -> Tuple[List[np.ndarray], RoundLog]:
    n = len(probs)
    t0 = clock()
    # stale-plan guard (DESIGN.md §11): an incumbent that fails static
    # validity under the CURRENT environment — wrong shape, NaN genes,
    # out-of-range server, broken pin, or an edge over a severed link —
    # must not warm-seed a swarm. Demote it to a cold solve instead of
    # rescuing garbage.
    checked: List[Optional[np.ndarray]] = []
    demoted = np.zeros(n, bool)
    for i, (pr, inc) in enumerate(zip(probs, incumbent)):
        if inc is not None and plan_is_valid(pr, inc):
            checked.append(np.asarray(inc, np.int32))
        else:
            demoted[i] = True
            checked.append(None)
    with maybe_span(telemetry, "incumbent_keys", round=round_no):
        inc_key = incumbent_keys(probs, checked, cfg.pso,
                                 arrivals=arrivals)
    # an incumbent stranded infeasible by the drift gets the cold tier
    # anchors back in its swarm tail (init_swarm rescue mode): recovery
    # then matches a cold solve's escape hatches, while healthy
    # incumbents keep the pure (faster-converging) neighborhood seeding.
    rescue = inc_key >= INFEASIBLE_OFFSET
    with maybe_span(telemetry, "warm_solve", round=round_no, n=n):
        cand, state = run_pso_ga_batch(
            probs, cfg.pso, seed=seed,
            incumbent=checked,
            migration_weight=cfg.migration_weight,
            warm_rescue=rescue,
            return_state=True,
            arrivals=arrivals,
            mesh=cfg.mesh,
            telemetry=telemetry)
    wall = clock() - t0

    plans: List[np.ndarray] = []
    replanned = np.zeros(n, bool)
    cand_key = np.array([c.best_fitness for c in cand], np.float64)
    cost = np.zeros(n)
    mig = np.zeros(n)
    feas = np.zeros(n, bool)
    moved = np.zeros(n, np.int64)
    iters = np.array([c.iterations for c in cand], np.int64)
    # stall counts iterations since the last gbest improvement, so the
    # final plan was found at it − stall; the rest is the stopping rule
    # confirming it.
    converge = np.maximum(
        iters - np.asarray(state.stall, np.int64), 0)
    for i, (pr, inc, c) in enumerate(zip(probs, checked, cand)):
        if demoted[i] or c.best_fitness < inc_key[i]:  # strict improvement
            replanned[i] = True
            plans.append(np.asarray(c.best_x, np.int32))
            # a demoted problem pays no migration: the incumbent was
            # garbage, so the candidate is a fresh deployment, not a
            # plan delta.
            mig[i] = 0.0 if demoted[i] \
                else migration_cost_np(pr, inc, plans[-1])
            if arrivals is not None:
                # traffic keys: feasibility and $ come from the key
                # (strip the migration term back off for the raw cost)
                feas[i] = c.best_fitness < INFEASIBLE_OFFSET
                cost[i] = (c.best_fitness
                           - cfg.migration_weight * mig[i]
                           if feas[i] else float("inf"))
            else:
                cost[i] = c.best_cost
                feas[i] = c.feasible
            moved[i] = pr.num_layers if demoted[i] \
                else int(np.sum(plans[-1] != inc))
        else:
            plans.append(inc)
            # keeping the incumbent: its key IS its raw cost if feasible
            feas[i] = inc_key[i] < INFEASIBLE_OFFSET
            cost[i] = float(inc_key[i]) if feas[i] else float("inf")
    log = RoundLog(round=round_no, label=label, replanned=replanned,
                   incumbent_key=inc_key, candidate_key=cand_key,
                   cost=cost, migration=mig, feasible=feas,
                   moved_layers=moved, iterations=iters,
                   converge_iters=converge, wall_s=wall,
                   demoted=demoted)
    if telemetry is not None:
        telemetry.inc("online.rounds")
        telemetry.inc("online.replanned", int(replanned.sum()))
        telemetry.inc("online.demotions", int(demoted.sum()))
        telemetry.observe("online.round_wall_s", wall)
    return plans, log


def _round_arrivals(cfg: ReplanConfig, dags: Sequence[LayerDAG],
                    event: DriftEvent, seed: int
                    ) -> Optional[List[np.ndarray]]:
    """Per-problem solver arrival draws for one drift epoch: the base
    ``TrafficConfig`` rate scaled by the event's ``load_scale``. Shapes
    are fixed by the config, so every round's arrays feed the SAME
    compiled runner (DESIGN.md §10)."""
    if cfg.traffic is None:
        return None
    return [cfg.traffic.solver_arrivals(d.num_apps, seed=seed + 31 * i,
                                        rate_scale=event.load_scale)
            for i, d in enumerate(dags)]


def replan_fleet(dags: Sequence[LayerDAG], trace: EnvTrace,
                 cfg: ReplanConfig = ReplanConfig(),
                 seed: int = 0,
                 initial: Optional[Sequence[PSOGAResult]] = None,
                 telemetry: Optional[Telemetry] = None
                 ) -> OnlineReport:
    """Drive a fleet of DNN placements through a drift trace.

    Round 0 solves cold on ``trace.env_at(0)`` (unless ``initial`` hands
    in admission-time plans, e.g. from ``plan_offload_batch``); every
    later round is a warm ``replan_round`` against that round's drifted
    environment. With ``cfg.traffic`` set, every round also carries a
    request stream whose rate is scaled by the round's ``load_scale`` —
    the ``load-surge`` family drifts ONLY that (DESIGN.md §10). All
    rounds share ONE compiled fleet runner — drift, environmental or
    workload, only changes array values (DESIGN.md §9).
    """
    if initial is None:
        probs0 = [SimProblem.build(d, trace.env_at(0)) for d in dags]
        with maybe_span(telemetry, "cold_solve", n=len(dags)):
            cold = run_pso_ga_batch(
                probs0, cfg.pso, seed=seed,
                arrivals=_round_arrivals(cfg, dags, trace.events[0],
                                         seed),
                mesh=cfg.mesh, telemetry=telemetry)
    else:
        if len(initial) != len(dags):
            raise ValueError(f"{len(initial)} initial results for "
                             f"{len(dags)} dags")
        cold = list(initial)
    plans = [np.asarray(r.best_x, np.int32) for r in cold]
    rounds: List[RoundLog] = []
    for k in range(1, trace.num_rounds):
        probs_k = [SimProblem.build(d, trace.env_at(k)) for d in dags]
        plans, log = replan_round(
            probs_k, plans, cfg, seed=seed + k, round_no=k,
            label=trace.events[k].label,
            arrivals=_round_arrivals(cfg, dags, trace.events[k],
                                     seed + 1000 * k),
            telemetry=telemetry)
        rounds.append(log)
    return OnlineReport(cold=cold, rounds=rounds, plans=plans)
