"""Feasibility-aware fitness (paper §IV-B.2, Eq. 14–16).

The paper's three comparison cases —
  1. both feasible          → smaller C_total wins          (Eq. 14)
  2. one feasible           → the feasible particle wins     (Eq. 15)
  3. both infeasible        → smaller Σ T_i^comp wins        (Eq. 16)
— are induced by a single scalar key:

    key(X) = C_total(X)                            if feasible(X)
           = INFEASIBLE_OFFSET + log1p(Σ T_i^comp) otherwise

The log compression matters: fitness keys are float32 on device, and an
additive offset big enough to dominate any cost (costs are $ ≤ O(10^2),
completion-time sums can reach 10^9 s when a placement uses a forbidden
link) would otherwise swallow the completion-time differences that drive
Case-3 evolution (float32 has ~1e-3 absolute resolution at 1e4).
``log1p`` is strictly monotone, so the induced order on infeasible
particles is exactly the paper's Eq. 16 order.
"""
from __future__ import annotations

from typing import Callable

import jax.numpy as jnp

from .simulator import PaddedProblem, SimResult, simulate_swarm

#: Must exceed any attainable C_total; costs in both the paper fleet and the
#: TPU fleet are well under $1e4 per request batch.
INFEASIBLE_OFFSET = 1e4

__all__ = ["INFEASIBLE_OFFSET", "fitness_key", "make_swarm_fitness",
           "resolve_fitness_backend"]


def fitness_key(res: SimResult) -> jnp.ndarray:
    total_time = jnp.sum(res.app_completion, axis=-1)
    infeasible_key = INFEASIBLE_OFFSET + jnp.log1p(total_time)
    return jnp.where(res.feasible, res.total_cost, infeasible_key)


def resolve_fitness_backend(backend: str) -> str:
    """``"auto"`` → pallas on TPU, scan elsewhere (matching
    ``kernels.ops.interpret_default``); else validate and pass through."""
    if backend == "auto":
        from ..kernels.ops import interpret_default
        return "scan" if interpret_default() else "pallas"
    if backend not in ("scan", "pallas"):
        raise ValueError(f"unknown fitness_backend {backend!r} "
                         "(expected scan | pallas | auto)")
    return backend


def make_swarm_fitness(pp: PaddedProblem, faithful: bool = True,
                       backend: str = "scan"
                       ) -> Callable[[jnp.ndarray], jnp.ndarray]:
    """Swarm-fitness evaluator ``X (P, max_p) -> keys (P,)`` (DESIGN.md §8).

    ``backend="scan"`` is the bit-exact default: the swarm-level
    two-phase scan (``simulator.simulate_swarm`` — shared step indices,
    particle axis inside each op). ``backend="pallas"`` dispatches the
    whole tile to ``kernels.schedule_sim`` (the layer loop lives inside
    the kernel, interpret mode off-TPU). Both return the same
    ``(total_cost, feasible, Σ T_i^comp)`` summary, to which the 3-case
    key (Eq. 14–16) is applied here. Both close over ``pp`` — ``vmap``
    freely over a fleet axis (pallas picks up an outer grid dimension).
    """
    backend = resolve_fitness_backend(backend)
    if backend == "scan":
        def raw(X: jnp.ndarray):
            return simulate_swarm(pp, X, faithful)
    else:
        from ..kernels.ops import interpret_default
        from ..kernels.schedule_sim import schedule_replay_folded

        def raw(X: jnp.ndarray):
            return schedule_replay_folded(
                pp.order, pp.compute, pp.parent_idx, pp.parent_mb,
                pp.child_idx, pp.child_mb, pp.app_id, pp.deadline,
                pp.pinned, pp.power, pp.cost_per_sec, pp.inv_bw,
                pp.tran_cost, pp.link_ok, X, faithful=faithful,
                interpret=interpret_default())

    def fit(X: jnp.ndarray) -> jnp.ndarray:
        total, feas, tsum = raw(X)
        return jnp.where(feas, total, INFEASIBLE_OFFSET + jnp.log1p(tsum))
    return fit
