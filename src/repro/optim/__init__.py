from .adamw import (AdamWConfig, OptState, adamw_init, adamw_update,
                    cosine_schedule, global_norm, zero1_pspecs)
from .compression import CompressionState, compress_error_feedback

__all__ = ["AdamWConfig", "OptState", "adamw_init", "adamw_update",
           "cosine_schedule", "global_norm", "zero1_pspecs",
           "CompressionState", "compress_error_feedback"]
