"""Layer-DAG model for DNN-based applications (paper §III-A).

A DNN is a directed acyclic graph G = (L, E, D):
  * L — layers l_j = <a_j, i_j, o_j> with compute amount ``a_j`` (work
    units; execution time on server k is ``a_j / p_k``, Eq. 4),
  * E — data dependencies e^{j,k},
  * D — datasets: one dataset per edge with size in MB (Eq. 6 divides
    by bandwidth in MB/s).

``LayerDAG`` also carries per-layer *pinning* (the paper pins each DNN's
input layer to its originating end device, Fig. 2) and the owning
application id + deadline, so several DNNs can be scheduled jointly as one
flat problem (the paper's "three DNNs per end device" experiments).

Algorithm 1 (preprocessing) contracts *cut-edges*: an edge (u, v) where
out-degree(u) == 1 and in-degree(v) == 1 is merged into a single layer
whose compute amount is the sum and whose external edges are re-wired.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

__all__ = ["LayerDAG", "preprocess", "merge_dags", "topological_order"]


@dataclasses.dataclass
class LayerDAG:
    """A flat, numpy-backed layer DAG (possibly the union of many DNNs).

    Attributes:
      compute: (p,) float64 — compute amount a_j per layer (work units).
      edges: (E, 2) int32 — (src, dst) layer indices, src < dst is NOT
        required but the graph must be acyclic.
      edge_mb: (E,) float64 — dataset size in MB carried by each edge.
      app_id: (p,) int32 — which DNN-based application each layer belongs to.
      deadline: (n_apps,) float64 — D(G_i) per application (seconds).
      pinned: (p,) int32 — server index the layer MUST run on, or -1.
      names: optional layer names for debugging / reports.
    """

    compute: np.ndarray
    edges: np.ndarray
    edge_mb: np.ndarray
    app_id: np.ndarray
    deadline: np.ndarray
    pinned: np.ndarray
    names: Optional[List[str]] = None

    def __post_init__(self) -> None:
        self.compute = np.asarray(self.compute, dtype=np.float64)
        self.edges = np.asarray(self.edges, dtype=np.int32).reshape(-1, 2)
        self.edge_mb = np.asarray(self.edge_mb, dtype=np.float64)
        self.app_id = np.asarray(self.app_id, dtype=np.int32)
        self.deadline = np.atleast_1d(np.asarray(self.deadline, dtype=np.float64))
        self.pinned = np.asarray(self.pinned, dtype=np.int32)
        if self.edges.shape[0] != self.edge_mb.shape[0]:
            raise ValueError("edges and edge_mb length mismatch")
        if self.compute.shape[0] != self.app_id.shape[0]:
            raise ValueError("compute and app_id length mismatch")
        if self.compute.shape[0] != self.pinned.shape[0]:
            raise ValueError("compute and pinned length mismatch")

    # ------------------------------------------------------------------
    @property
    def num_layers(self) -> int:
        return int(self.compute.shape[0])

    @property
    def num_edges(self) -> int:
        return int(self.edges.shape[0])

    @property
    def num_apps(self) -> int:
        return int(self.deadline.shape[0])

    def in_degree(self) -> np.ndarray:
        deg = np.zeros(self.num_layers, dtype=np.int64)
        if self.num_edges:
            np.add.at(deg, self.edges[:, 1], 1)
        return deg

    def out_degree(self) -> np.ndarray:
        deg = np.zeros(self.num_layers, dtype=np.int64)
        if self.num_edges:
            np.add.at(deg, self.edges[:, 0], 1)
        return deg

    def parents(self, j: int) -> np.ndarray:
        return self.edges[self.edges[:, 1] == j, 0]

    def children(self, j: int) -> np.ndarray:
        return self.edges[self.edges[:, 0] == j, 1]

    def total_compute(self) -> float:
        return float(self.compute.sum())

    def validate_acyclic(self) -> None:
        topological_order(self)  # raises on cycle

    def with_deadline(self, deadline: np.ndarray) -> "LayerDAG":
        return dataclasses.replace(self, deadline=np.asarray(deadline, np.float64))

    # Padded parent/child index tables used by the vectorized simulator.
    def padded_relatives(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
        """Returns (parent_idx, parent_mb, child_idx, child_mb).

        parent_idx: (p, max_in) int32, padded with -1.
        parent_mb:  (p, max_in) float64, padded with 0.
        child_idx / child_mb analogous for outgoing edges.
        """
        p = self.num_layers
        par: List[List[Tuple[int, float]]] = [[] for _ in range(p)]
        chi: List[List[Tuple[int, float]]] = [[] for _ in range(p)]
        for (u, v), mb in zip(self.edges, self.edge_mb):
            par[v].append((int(u), float(mb)))
            chi[u].append((int(v), float(mb)))
        max_in = max([len(x) for x in par] + [1])
        max_out = max([len(x) for x in chi] + [1])
        pi = np.full((p, max_in), -1, np.int32)
        pm = np.zeros((p, max_in), np.float64)
        ci = np.full((p, max_out), -1, np.int32)
        cm = np.zeros((p, max_out), np.float64)
        for j in range(p):
            for k, (u, mb) in enumerate(par[j]):
                pi[j, k], pm[j, k] = u, mb
            for k, (v, mb) in enumerate(chi[j]):
                ci[j, k], cm[j, k] = v, mb
        return pi, pm, ci, cm


def topological_order(dag: LayerDAG) -> np.ndarray:
    """Kahn's algorithm; deterministic (smallest index first). Raises on cycle."""
    p = dag.num_layers
    indeg = dag.in_degree().copy()
    children: List[List[int]] = [[] for _ in range(p)]
    for u, v in dag.edges:
        children[int(u)].append(int(v))
    import heapq

    ready = [j for j in range(p) if indeg[j] == 0]
    heapq.heapify(ready)
    order: List[int] = []
    while ready:
        j = heapq.heappop(ready)
        order.append(j)
        for c in children[j]:
            indeg[c] -= 1
            if indeg[c] == 0:
                heapq.heappush(ready, c)
    if len(order) != p:
        raise ValueError("graph has a cycle")
    return np.asarray(order, dtype=np.int32)


def preprocess(dag: LayerDAG) -> Tuple[LayerDAG, np.ndarray]:
    """Algorithm 1 — merge adjacent layers joined by a cut-edge.

    An edge (u, v) is a *cut-edge* when out-degree(u) == 1 and
    in-degree(v) == 1 **and** u, v belong to the same application.
    Merging repeats until no cut-edge remains. The merged layer's compute
    amount is the sum of the group's; the intra-group datasets vanish
    (they never cross servers after merging — Fig. 3(a)).

    Returns (new_dag, group) where ``group[j]`` maps original layer j to
    its merged layer index (usable to expand a compressed placement back
    to per-original-layer placement).
    """
    p = dag.num_layers
    group = np.arange(p, dtype=np.int64)  # union-find
    out_deg = dag.out_degree()
    in_deg = dag.in_degree()

    def find(x: int) -> int:
        while group[x] != x:
            group[x] = group[group[x]]
            x = int(group[x])
        return x

    # A cut-edge's endpoints merge; degrees are on the ORIGINAL graph, which
    # is exactly Alg. 1's fixed point: repeated merging of chains u→v with
    # outdeg(u)==indeg(v)==1 unions every maximal chain into one node.
    for (u, v), _mb in zip(dag.edges, dag.edge_mb):
        u, v = int(u), int(v)
        if out_deg[u] == 1 and in_deg[v] == 1 and dag.app_id[u] == dag.app_id[v]:
            ru, rv = find(u), find(v)
            if ru != rv:
                group[rv] = ru

    roots = np.array([find(j) for j in range(p)], dtype=np.int64)
    uniq, new_index = np.unique(roots, return_inverse=True)
    q = uniq.shape[0]

    compute = np.zeros(q, np.float64)
    np.add.at(compute, new_index, dag.compute)
    app_id = np.zeros(q, np.int32)
    app_id[new_index] = dag.app_id  # all members share app id
    pinned = np.full(q, -1, np.int32)
    for j in range(p):
        if dag.pinned[j] >= 0:
            g = new_index[j]
            if pinned[g] >= 0 and pinned[g] != dag.pinned[j]:
                raise ValueError("merged group has conflicting pins")
            pinned[g] = dag.pinned[j]

    # Re-wire surviving edges (those crossing groups); keep parallel edges
    # collapsed by summing MB (both datasets must cross the same link).
    edge_map: Dict[Tuple[int, int], float] = {}
    for (u, v), mb in zip(dag.edges, dag.edge_mb):
        gu, gv = int(new_index[int(u)]), int(new_index[int(v)])
        if gu == gv:
            continue
        edge_map[(gu, gv)] = edge_map.get((gu, gv), 0.0) + float(mb)
    if edge_map:
        edges = np.array(sorted(edge_map.keys()), np.int32)
        edge_mb = np.array([edge_map[tuple(e)] for e in edges], np.float64)
    else:
        edges = np.zeros((0, 2), np.int32)
        edge_mb = np.zeros((0,), np.float64)

    names = None
    if dag.names is not None:
        names = ["+".join(dag.names[j] for j in range(p) if new_index[j] == g)
                 for g in range(q)]
    new_dag = LayerDAG(compute=compute, edges=edges, edge_mb=edge_mb,
                       app_id=app_id, deadline=dag.deadline.copy(),
                       pinned=pinned, names=names)
    return new_dag, new_index.astype(np.int64)


def merge_dags(dags: Sequence[LayerDAG]) -> LayerDAG:
    """Concatenate several applications into one flat scheduling problem."""
    offset_l = 0
    offset_a = 0
    computes, edges, mbs, apps, pins, deadlines, names = [], [], [], [], [], [], []
    any_names = any(d.names is not None for d in dags)
    for d in dags:
        computes.append(d.compute)
        if d.num_edges:
            edges.append(d.edges + offset_l)
            mbs.append(d.edge_mb)
        apps.append(d.app_id + offset_a)
        pins.append(d.pinned)
        deadlines.append(d.deadline)
        if any_names:
            names.extend(d.names if d.names is not None
                         else [f"l{offset_l + j}" for j in range(d.num_layers)])
        offset_l += d.num_layers
        offset_a += d.num_apps
    return LayerDAG(
        compute=np.concatenate(computes),
        edges=np.concatenate(edges) if edges else np.zeros((0, 2), np.int32),
        edge_mb=np.concatenate(mbs) if mbs else np.zeros((0,), np.float64),
        app_id=np.concatenate(apps),
        deadline=np.concatenate(deadlines),
        pinned=np.concatenate(pins),
        names=names if any_names else None,
    )
