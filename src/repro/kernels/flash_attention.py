"""Pallas TPU flash-attention kernel (causal / sliding-window, GQA-native).

Design for the TPU memory hierarchy (HBM -> VMEM -> MXU):
  * One grid cell owns a (q_block x head_dim) query tile in VMEM and
    streams (kv_block x head_dim) K/V tiles; the (S x S) score matrix is
    never materialized in HBM — the classic flash recurrence runs in fp32
    VMEM scratch (m, l running stats + acc output tile).
  * Grid = (batch x kv_head, q_group, q_blocks, kv_blocks); the kv_blocks
    axis is innermost, which TPU executes sequentially per core, so the
    scratch accumulator carries across kv tiles of the same query tile
    (the standard Pallas accumulation idiom).
  * GQA: queries are laid out (B*K, G, S, hd) and K/V (B*K, S, hd) —
    a kv head's tile is loaded ONCE per (group, q-tile) rather than
    broadcast to all H query heads in HBM.
  * Causal / local masking is applied per tile from program ids;
    fully-masked tiles are skipped with ``pl.when`` (on TPU the whole
    tile's DMA+MXU work is predicated away, giving the ~S^2/2 causal and
    ~S*window local FLOP profile a hand-written kernel gets).
  * Block defaults (q=256, kv=512) keep worst-case VMEM
    (acc 256x256 fp32 + 2 KV tiles 512x256 bf16) ~ 0.8 MB << 16 MB/core,
    and all matmul dims are multiples of the 128-lane MXU.

Validated in interpret mode against ``ref.flash_attention_ref`` (CPU has
no MXU; the TARGET is TPU v5e — see DESIGN.md §3).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0 ** 30

__all__ = ["flash_attention_folded"]


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, window: int, seq: int,
                  q_blk: int, kv_blk: int, n_kv: int):
    i = pl.program_id(2)          # query block
    j = pl.program_id(3)          # kv block (innermost, sequential)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q0 = i * q_blk
    k0 = j * kv_blk
    # tile-level relevance: does any (qpos, kpos) pair in this tile pass
    # the causal/window band?
    run = k0 < seq
    if causal:
        run = jnp.logical_and(run, k0 <= q0 + q_blk - 1)
    if window:
        run = jnp.logical_and(run, k0 + kv_blk - 1 >= q0 - window + 1)

    @pl.when(run)
    def _tile():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # (q_blk, hd)
        k = k_ref[0].astype(jnp.float32)                      # (kv_blk, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        qpos = q0 + jax.lax.broadcasted_iota(jnp.int32, (q_blk, kv_blk), 0)
        kpos = k0 + jax.lax.broadcasted_iota(jnp.int32, (q_blk, kv_blk), 1)
        ok = kpos < seq                                       # seq padding
        if causal:
            ok &= kpos <= qpos
        if window:
            ok &= kpos > qpos - window
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_ref[:, 0]                                  # (q_blk,)
        l_prev = l_ref[:, 0]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + p.sum(axis=-1)
        v = v_ref[0].astype(jnp.float32)                      # (kv_blk, hd)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * corr[:, None] + pv
        m_ref[...] = m_new[:, None]
        l_ref[...] = l_new[:, None]

    @pl.when(j == n_kv - 1)
    def _finish():
        l = l_ref[:, 0]
        o = acc_ref[...] / jnp.maximum(l, 1e-30)[:, None]
        o_ref[0, 0] = o.astype(o_ref.dtype)


def flash_attention_folded(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                           *, causal: bool, window: int,
                           q_blk: int = 256, kv_blk: int = 512,
                           interpret: bool = True) -> jnp.ndarray:
    """q: (BK, G, S, hd); k/v: (BK, S, hd) -> (BK, G, S, hd).

    Sequence length is padded to tile multiples here; masking uses the
    true ``seq`` so padded keys never contribute and padded query rows are
    sliced off.
    """
    bk, g, s, hd = q.shape
    seq = s
    q_blk = min(q_blk, max(8, s))
    kv_blk = min(kv_blk, max(8, s))
    pad_q = (-s) % q_blk
    pad_k = (-s) % kv_blk
    if pad_q or pad_k:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0)))
    sq, sk = s + pad_q, s + pad_k
    n_q, n_kv = sq // q_blk, sk // kv_blk
    grid = (bk, g, n_q, n_kv)

    kernel = functools.partial(
        _flash_kernel, scale=hd ** -0.5, causal=causal, window=window,
        seq=seq, q_blk=q_blk, kv_blk=kv_blk, n_kv=n_kv)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, q_blk, hd), lambda b, g, i, j: (b, g, i, 0)),
            pl.BlockSpec((1, kv_blk, hd), lambda b, g, i, j: (b, j, 0)),
            pl.BlockSpec((1, kv_blk, hd), lambda b, g, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, q_blk, hd),
                               lambda b, g, i, j: (b, g, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bk, g, sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((q_blk, 1), jnp.float32),   # running max m
            pltpu.VMEM((q_blk, 1), jnp.float32),   # running sum l
            pltpu.VMEM((q_blk, hd), jnp.float32),  # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :seq]
