"""Golden-cost regression: seeded end-to-end ``run_pso_ga`` for all four
zoo DNNs on ``paper_environment()``, parameterized over both fidelity
modes × both fitness backends, pinned to the stored values in
``golden_costs.json``.

The existing parity tests compare backend AGAINST backend — if a change
drifts the fitness of both (a simulator tweak, a cost-model slip, an
accidental operator-order change), parity still passes. These goldens
anchor the absolute numbers. Regenerate after an INTENDED behaviour
change with ``PYTHONPATH=src python scripts/gen_goldens.py`` and justify
the diff in the PR.
"""
import json
from pathlib import Path

import numpy as np
import pytest

from repro.core import (PSOGAConfig, heft_makespan, paper_environment,
                        run_pso_ga, zoo)

GOLDENS = json.loads(
    (Path(__file__).parent / "golden_costs.json").read_text())
_CFG = GOLDENS["_config"]


@pytest.fixture(scope="module")
def golden_env():
    return paper_environment()


@pytest.fixture(scope="module")
def golden_dags(golden_env):
    dags = {}
    for net in zoo.NAMES:
        base = zoo.build(net, pin_server=0)
        h, _ = heft_makespan(base, golden_env)
        dags[net] = base.with_deadline(
            np.array([_CFG["deadline_ratio"] * h]))
    return dags


@pytest.mark.parametrize("backend", ["scan", "pallas"])
@pytest.mark.parametrize("faithful", [False, True])
@pytest.mark.parametrize("net", zoo.NAMES)
def test_golden_cost(net, faithful, backend, golden_env, golden_dags):
    want = GOLDENS[f"{net}|faithful={faithful}|{backend}"]
    cfg = PSOGAConfig(pop_size=_CFG["pop_size"],
                      max_iters=_CFG["max_iters"],
                      stall_iters=_CFG["stall_iters"],
                      faithful_sim=faithful, fitness_backend=backend)
    res = run_pso_ga(golden_dags[net], golden_env, cfg,
                     seed=_CFG["seed"])
    assert res.feasible == want["feasible"]
    # rtol absorbs cross-platform float noise; any real fitness drift is
    # orders of magnitude larger than 1e-5 relative.
    np.testing.assert_allclose(res.best_fitness, want["best_fitness"],
                               rtol=1e-5)
    np.testing.assert_allclose(res.best_cost, want["best_cost"],
                               rtol=1e-5)


def test_goldens_cover_full_matrix():
    """The stored file must span nets × fidelity × backends — a silently
    shrunken matrix would quietly stop guarding part of the surface."""
    keys = [k for k in GOLDENS if k != "_config"]
    assert len(keys) == len(zoo.NAMES) * 2 * 2
    for net in zoo.NAMES:
        for faithful in (False, True):
            for backend in ("scan", "pallas"):
                assert f"{net}|faithful={faithful}|{backend}" in GOLDENS
