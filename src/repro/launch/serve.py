"""Batched serving loop: prefill a request batch, decode greedily with a
jitted sharded serve_step, track per-slot completion.

Serving model: static slot batching — a batch of B requests is prefilled
together (left-padded to a common length is unnecessary here: synthetic
prompts share a length), then decoded in lock-step; finished slots (EOS)
are masked but keep flowing until every slot finishes or max_new_tokens.
All slots share the scalar cache position (the decode step writes every
slot at the same slot index), which is what the assigned ``decode_*``
cells lower. Per-slot positions / continuous batching are a documented
non-goal (DESIGN.md §6).

The placement engine picks WHERE this runs: ``--plan`` prints the PSO-GA
offloading plan for the request shape against the TPU fleet and the
tier each stage lands on (the paper's decision), then serves locally.
"""
from __future__ import annotations

import argparse
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import SHAPES, get
from ..configs.base import ModelConfig, ShapeSpec
from ..runtime import elastic_mesh
from .mesh import data_axes_of
from .steps import make_decode_objects, make_prefill_objects

__all__ = ["Server", "main"]


class Server:
    def __init__(self, cfg: ModelConfig, batch: int, prompt_len: int,
                 max_new: int, eos_id: int = 1,
                 mesh: Optional[jax.sharding.Mesh] = None,
                 model_axis: int = 1):
        self.cfg = cfg
        self.eos = eos_id
        self.max_new = max_new
        self.mesh = mesh or elastic_mesh(model=model_axis)
        daxes = data_axes_of(self.mesh)
        cache_len = prompt_len + max_new
        shape = ShapeSpec("serve", cache_len, batch, "decode")
        pshape = ShapeSpec("serve_prefill", prompt_len, batch, "prefill")
        self.model, prefill, in_sh_p, _, _ = make_prefill_objects(
            cfg, pshape, self.mesh, daxes)
        _, decode, in_sh_d, out_sh_d, _ = make_decode_objects(
            cfg, shape, self.mesh, daxes)
        self._prefill = jax.jit(
            lambda p, b: self.model.prefill(p, b, cache_len=cache_len),
            in_shardings=in_sh_p)
        self._decode = jax.jit(decode, in_shardings=in_sh_d,
                               out_shardings=out_sh_d,
                               donate_argnums=(1,))
        self._param_sh = in_sh_p[0]
        self._cache_sh = in_sh_d[1]
        self.prompt_len = prompt_len
        self.batch = batch

    def init_params(self, seed: int = 0):
        with self.mesh:
            return jax.jit(self.model.init,
                           out_shardings=self._param_sh)(
                               jax.random.PRNGKey(seed))

    def generate(self, params, batch: Dict[str, np.ndarray]
                 ) -> Dict[str, Any]:
        t0 = time.time()
        logits, caches = self._prefill(params, batch)
        caches = jax.tree.map(
            lambda c, s: jax.device_put(c, s), caches, self._cache_sh)
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        jax.block_until_ready(tok)
        t_prefill = time.time() - t0

        out_tokens = [np.asarray(tok)]
        done = np.zeros((self.batch,), bool)
        t0 = time.time()
        n_gen = 1
        for i in range(self.max_new - 1):
            pos = jnp.asarray(self.prompt_len + i, jnp.int32)
            logits, caches = self._decode(params, caches,
                                          {"token": tok, "pos": pos})
            tok = jnp.argmax(logits[:, -1], axis=-1
                             ).astype(jnp.int32)[:, None]
            t_np = np.asarray(tok)
            out_tokens.append(t_np)
            n_gen += 1
            done |= (t_np[:, 0] == self.eos)
            if done.all():
                break
        t_decode = time.time() - t0
        toks = np.concatenate(out_tokens, axis=1)
        return {
            "tokens": toks,
            "prefill_s": t_prefill,
            "decode_s": t_decode,
            "tokens_generated": int(n_gen * self.batch),
            "decode_tok_per_s": (n_gen * self.batch / t_decode
                                 if t_decode > 0 else float("inf")),
        }


def main() -> None:
    from ..core import TRAFFIC_KINDS

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--model-axis", type=int, default=1)
    ap.add_argument("--plan", action="store_true",
                    help="print the PSO-GA fleet placement first")
    ap.add_argument("--fitness-backend", default="scan",
                    choices=("scan", "pallas", "auto"),
                    help="swarm-fitness backend for --plan (DESIGN.md §8)")
    ap.add_argument("--mesh", default="none",
                    choices=("none", "host", "prod"),
                    help="device mesh for the fleet SOLVER (DESIGN.md "
                         "§12): shard --plan/--replan/--serve solves "
                         "across the mesh's data axes. 'host' builds the "
                         "test mesh over the visible devices; 'prod' "
                         "needs a real 16x16 pod. Plans are gene-for-"
                         "gene identical to --mesh none.")
    ap.add_argument("--replan", default=None, metavar="SCENARIO",
                    help="after --plan, drive the placements through a "
                         "drift trace (wifi-fade | congestion | "
                         "spot-price | node-loss | load-surge) and "
                         "re-plan warm at each event (DESIGN.md §9)")
    ap.add_argument("--replan-rounds", type=int, default=4,
                    help="drift events in the --replan trace")
    ap.add_argument("--serve", default=None, metavar="SCENARIO",
                    dest="serve_scenario",
                    help="after --plan, run the fault-tolerant always-on "
                         "planning service over a drift trace of this "
                         "family (DESIGN.md §11): watchdog, fallback "
                         "ladder, admission control, circuit breaker. "
                         "Accepts a drift family (wifi-fade | congestion "
                         "| spot-price | node-loss | load-surge) or a "
                         "traffic family (poisson | diurnal | bursty | "
                         "flash-crowd) — the latter serves that request "
                         "stream through a load-surge drift trace. "
                         "Prints per-round rungs and the availability/"
                         "SLO summary, then exits (no LM serving).")
    ap.add_argument("--serve-rounds", type=int, default=6,
                    help="drift events in the --serve trace")
    ap.add_argument("--chaos", action="store_true",
                    help="with --serve: inject a deterministic fault "
                         "script (solver crash, NaN env snapshot, "
                         "mid-round node loss) to exercise the ladder")
    ap.add_argument("--slo-s", type=float, default=float("inf"),
                    help="per-round time-to-plan SLO for the --serve "
                         "watchdog (seconds)")
    ap.add_argument("--triage-margin", type=float, default=0.0,
                    help="with --serve --traffic: reject apps whose "
                         "deadline < margin x HEFT completion instead "
                         "of queueing them (0 disables)")
    ap.add_argument("--estimate-rates", action="store_true",
                    help="with --serve --traffic: plan on arrival rates "
                         "estimated from the observed stream instead of "
                         "the generator's configured rate")
    ap.add_argument("--plan-cache", action="store_true",
                    help="with --serve: cache plans by (DNN, env-bucket, "
                         "load-bucket) and serve repeat scenarios through "
                         "the replay-exact revalidation gate instead of "
                         "re-solving (DESIGN.md §11 phase 2)")
    ap.add_argument("--async-ingest", type=int, default=None,
                    metavar="THREADS",
                    help="with --serve --estimate-rates: route the rate "
                         "observations through the bounded ingestion "
                         "queue; 0 = deterministic single-thread mode, "
                         "N>0 = concurrent producer threads "
                         "(DESIGN.md §11 phase 2)")
    ap.add_argument("--traffic", default=None, metavar="SCENARIO",
                    choices=TRAFFIC_KINDS,
                    help="plan under a request-stream workload of this "
                         "arrival family instead of a single isolated "
                         "execution (DESIGN.md §10); the report then "
                         "shows each plan's held-out p95 deadline-miss "
                         "rate and load-adjusted cost")
    ap.add_argument("--traffic-rate", type=float, default=0.5,
                    help="mean request arrivals/s per app for --traffic")
    ap.add_argument("--trace-out", default=None, metavar="FILE",
                    help="with --plan: write a Chrome trace-event JSON "
                         "of the planning/serving spans — open in "
                         "Perfetto or chrome://tracing (DESIGN.md §13)")
    ap.add_argument("--metrics-out", default=None, metavar="DIR",
                    help="with --plan: write the telemetry registry "
                         "snapshot (metrics.jsonl + metrics.prom) to "
                         "this directory (DESIGN.md §13)")
    args = ap.parse_args()

    cfg = get(args.arch)
    if args.replan and not args.plan:
        ap.error("--replan requires --plan")
    if args.traffic and not args.plan:
        ap.error("--traffic requires --plan")
    if args.replan == "load-surge" and not args.traffic:
        ap.error("--replan load-surge drifts the request stream, which "
                 "only exists with --traffic SCENARIO (DESIGN.md §10)")
    if args.serve_scenario and not args.plan:
        ap.error("--serve requires --plan")
    if args.serve_scenario in TRAFFIC_KINDS:
        # --serve took a traffic family: serve that request stream
        # through a load-surge drift trace (the one drift family that
        # perturbs the stream itself, DESIGN.md §10).
        if args.traffic and args.traffic != args.serve_scenario:
            ap.error(f"--serve {args.serve_scenario} conflicts with "
                     f"--traffic {args.traffic}: pick one arrival "
                     f"family")
        args.traffic = args.serve_scenario
        args.serve_scenario = "load-surge"
    if args.serve_scenario == "load-surge" and not args.traffic:
        ap.error("--serve load-surge drifts the request stream, which "
                 "only exists with --traffic SCENARIO (DESIGN.md §10)")
    if (args.trace_out or args.metrics_out) and not args.plan:
        ap.error("--trace-out / --metrics-out instrument the planning "
                 "path — they require --plan (DESIGN.md §13)")
    if (args.estimate_rates or args.triage_margin > 0.0) \
            and not args.traffic:
        ap.error("--estimate-rates / --triage-margin need --traffic "
                 "(they act on the request stream, DESIGN.md §11)")
    if args.async_ingest is not None and not args.estimate_rates:
        ap.error("--async-ingest needs --estimate-rates (it queues the "
                 "rate observations, DESIGN.md §11)")
    if args.async_ingest is not None and args.async_ingest < 0:
        ap.error("--async-ingest THREADS must be >= 0")
    if args.plan:
        # one batched PSO-GA fleet plans every serving shape at once
        # (DESIGN.md §4) instead of re-compiling the solver per shape.
        from ..core import (PSOGAConfig, Telemetry, TrafficConfig,
                            plan_offload_batch, set_telemetry,
                            tpu_fleet_environment)
        from .mesh import resolve_mesh

        tel: Optional[Telemetry] = None
        if args.trace_out or args.metrics_out:
            # one telemetry channel for the whole planning path; the
            # global hook is how config-less deep layers (runner cache,
            # solver history) reach the same registry (DESIGN.md §13).
            tel = Telemetry()
            set_telemetry(tel)

        def _export_tel() -> None:
            if tel is None:
                return
            set_telemetry(None)
            if args.trace_out:
                tel.export_trace(args.trace_out)
                n_ev = len(tel.tracer.to_chrome_trace()["traceEvents"])
                print(f"[serve] telemetry: wrote {n_ev} trace events to "
                      f"{args.trace_out} (open in Perfetto / "
                      f"chrome://tracing)")
            if args.metrics_out:
                tel.export_metrics(args.metrics_out)
                print(f"[serve] telemetry: wrote metrics snapshot to "
                      f"{args.metrics_out}/metrics.{{jsonl,prom}}")

        fleet_env = tpu_fleet_environment()
        shapes = [s for s in SHAPES if s.kind != "train"]
        pso_cfg = PSOGAConfig(pop_size=48, max_iters=200, stall_iters=40)
        solver_mesh = resolve_mesh(args.mesh)
        if solver_mesh is not None:
            print(f"[serve] solver mesh: "
                  f"{dict(zip(solver_mesh.axis_names, solver_mesh.devices.shape))}"
                  f" over {solver_mesh.devices.size} devices")
        traffic_cfg = None
        if args.traffic:
            # queue-aware planning: score every placement under the
            # request stream it will actually serve (DESIGN.md §10)
            traffic_cfg = TrafficConfig(kind=args.traffic,
                                        rate=args.traffic_rate)
        plans = plan_offload_batch(
            [(cfg, s, 1.5) for s in shapes], env=fleet_env,
            pso=pso_cfg, fitness_backend=args.fitness_backend,
            traffic=traffic_cfg, mesh=solver_mesh)
        for shape, plan in zip(shapes, plans):
            tag = f" under {args.traffic} traffic" if args.traffic else ""
            print(f"[serve] PSO-GA fleet placement for {shape.name}"
                  f"{tag} (backend={plan.backend}):")
            print(plan.summary())
        if args.replan:
            # warm re-planning across a drifting fleet: each event
            # re-solves every shape from its incumbent plan, accepting
            # only migration-adjusted improvements (DESIGN.md §9).
            import dataclasses as _dc

            from ..core import ReplanConfig, replan_fleet, sample_trace
            trace = sample_trace(args.replan, fleet_env,
                                 rounds=args.replan_rounds, seed=0)
            # keep the cold solve's EXACT config (the resolved backend
            # and, under --traffic, its miss budget): a different config
            # would force a second fleet-runner compile mid-replan and
            # silently override the user's --fitness-backend choice
            replan_pso = _dc.replace(pso_cfg,
                                     fitness_backend=plans[0].backend)
            if traffic_cfg is not None:
                replan_pso = _dc.replace(
                    replan_pso, miss_budget=traffic_cfg.miss_budget)
            # with --traffic, replan rounds keep scoring under the same
            # request stream (a load-surge trace then scales its rate,
            # DESIGN.md §10) — without this, round 1 would silently
            # replace the traffic-aware plans with zero-load plans.
            report = replan_fleet(
                [p.dag for p in plans], trace,
                ReplanConfig(pso=replan_pso, traffic=traffic_cfg,
                             mesh=solver_mesh),
                initial=[p.result for p in plans], telemetry=tel)
            for log in report.rounds:
                n_re = int(log.replanned.sum())
                print(f"[serve] replan round {log.round} ({log.label}): "
                      f"{n_re}/{len(plans)} plans changed, "
                      f"fleet cost ${float(np.sum(log.cost)):.4f}, "
                      f"moved layers {log.moved_layers.tolist()}, "
                      f"{log.wall_s * 1e3:.0f}ms")
        if args.serve_scenario:
            # the always-on planning service (DESIGN.md §11): same warm
            # replanning as --replan, wrapped in the watchdog / ladder /
            # breaker supervision — and the one mode that does NOT fall
            # through to LM serving (it IS the serving loop).
            import dataclasses as _dc

            from ..core import (ChaosConfig, IngestConfig,
                                PlanCacheConfig, ReplanConfig,
                                ServiceConfig, run_service, sample_trace)
            trace = sample_trace(args.serve_scenario, fleet_env,
                                 rounds=args.serve_rounds, seed=0)
            serve_pso = _dc.replace(pso_cfg,
                                    fitness_backend=plans[0].backend)
            if traffic_cfg is not None:
                serve_pso = _dc.replace(
                    serve_pso, miss_budget=traffic_cfg.miss_budget)
            chaos = None
            if args.chaos:
                last = max(1, args.serve_rounds - 1)
                chaos = ChaosConfig(
                    crash_rounds=(min(2, last),),
                    nan_env_rounds=(min(3, last),),
                    mid_round_down={min(4, last): 1})
            scfg = ServiceConfig(
                replan=ReplanConfig(pso=serve_pso, traffic=traffic_cfg,
                                    mesh=solver_mesh),
                slo_s=args.slo_s, triage_margin=args.triage_margin,
                estimate_rates=args.estimate_rates, chaos=chaos,
                plan_cache=(PlanCacheConfig() if args.plan_cache
                            else None),
                ingest=(IngestConfig(threads=args.async_ingest)
                        if args.async_ingest is not None else None))
            report = run_service([p.dag for p in plans], trace, scfg,
                                 seed=0,
                                 initial=[p.result for p in plans],
                                 telemetry=tel)
            for r in report.rounds:
                flags = "".join(
                    f" [{f}]" for f, on in
                    (("solver-failed", r.solver_failed),
                     ("stale-env", r.stale_env),
                     ("stalled", r.stalled)) if on)
                print(f"[serve] service round {r.round} ({r.label}): "
                      f"rungs {list(r.rung)}, breaker {r.breaker_state},"
                      f" {r.wall_s * 1e3:.0f}ms{flags}")
            s = report.summary()
            ttp = s["time_to_plan_s"]
            print(f"[serve] service: {s['rounds']} rounds, availability "
                  f"{s['availability']:.4f}, time-to-plan p50 "
                  f"{ttp['p50'] * 1e3:.0f}ms p99 {ttp['p99'] * 1e3:.0f}ms,"
                  f" fallbacks {s['fallback_counts']}")
            if report.cache_stats is not None:
                cs = report.cache_stats
                n_look = cs["hits"] + cs["misses"]
                rate = cs["hits"] / n_look if n_look else 0.0
                print(f"[serve] plan cache: hit rate {rate:.2f} "
                      f"({cs['hits']}/{n_look}), stores {cs['stores']}, "
                      f"evictions {cs['evictions']}, revalidation "
                      f"failures {cs['revalidation_failures']}")
            _export_tel()
            return
        _export_tel()
    if args.reduced:
        cfg = cfg.reduced()
    srv = Server(cfg, args.batch, args.prompt_len, args.max_new,
                 model_axis=args.model_axis)
    params = srv.init_params()
    rng = np.random.default_rng(0)
    batch = {"tokens": rng.integers(
        2, cfg.vocab, (args.batch, args.prompt_len)).astype(np.int32)}
    if cfg.family == "encdec":
        batch = {"audio_embeds": rng.standard_normal(
            (args.batch, args.prompt_len, cfg.d_model)).astype(np.float32),
            "tokens": batch["tokens"][:, : args.prompt_len // 8]}
    elif cfg.family == "vlm":
        tv = min(cfg.vision_tokens, 8)
        batch = {"vision": rng.standard_normal(
            (args.batch, tv, cfg.d_model)).astype(np.float32),
            "tokens": batch["tokens"][:, : args.prompt_len - tv]}
    out = srv.generate(params, batch)
    print(f"[serve] prefill {out['prefill_s']*1e3:.0f}ms  "
          f"decode {out['tokens_generated']} tokens in "
          f"{out['decode_s']*1e3:.0f}ms "
          f"({out['decode_tok_per_s']:.1f} tok/s)")
    print("[serve] first row:", out["tokens"][0][:16])


if __name__ == "__main__":
    main()
