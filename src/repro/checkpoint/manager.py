"""Async, atomic, keep-N checkpointing with elastic (re-sharded) restore.

Layout:
    <dir>/step_000042/               one dir per step
        manifest.json                tree structure + shapes/dtypes
        000000.npy, 000001.npy, ...  one file per leaf (flattened order)
    <dir>/LATEST                     text file: last durably-written step

Durability protocol: leaves are written into ``step_XXXX.tmp``; the dir is
fsync'd and atomically renamed to ``step_XXXX``; only then is LATEST
updated (write-to-temp + rename, crash-safe on POSIX). A crash mid-save
leaves a ``.tmp`` dir that restore ignores and the next save overwrites.

Async: ``save()`` snapshots leaves to host memory synchronously (cheap —
device->host copy) and does file IO on a background thread, overlapping
with the next training step; ``wait()`` joins before the next save or at
exit. This is the single-controller analogue of per-host async
checkpointing; in multi-host each process writes its own shard files
(process_index in the filename) — single-process here, API kept real.

Elastic restore: leaves are loaded as host numpy and re-placed with
``jax.device_put(x, NamedSharding(new_mesh, spec))`` — the checkpoint is
mesh-agnostic, so a job can resume on a *different* device count
(tests/test_checkpoint.py does 8 -> 4 devices in a subprocess).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

__all__ = ["CheckpointManager"]


class CheckpointManager:
    def __init__(self, directory: str, keep_n: int = 3):
        self.dir = directory
        self.keep_n = keep_n
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------- helpers
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:08d}")

    def steps(self) -> list:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name[5:]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        path = os.path.join(self.dir, "LATEST")
        if os.path.exists(path):
            with open(path) as f:
                txt = f.read().strip()
            if txt and os.path.isdir(self._step_dir(int(txt))):
                return int(txt)
        steps = self.steps()
        return steps[-1] if steps else None

    # ---------------------------------------------------------------- save
    def save(self, step: int, tree: Any, blocking: bool = False) -> None:
        self.wait()
        leaves, treedef = jax.tree.flatten(tree)
        # snapshot to host NOW (device buffers may be donated next step)
        host = [np.asarray(l) for l in leaves]
        manifest = {
            "treedef": _treedef_to_json(tree),
            "leaves": [{"shape": list(h.shape), "dtype": str(h.dtype)}
                       for h in host],
            "step": step,
        }

        def write():
            tmp = self._step_dir(step) + ".tmp"
            if os.path.isdir(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            for i, h in enumerate(host):
                np.save(os.path.join(tmp, f"{i:06d}.npy"), h)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            final = self._step_dir(step)
            if os.path.isdir(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            with open(os.path.join(self.dir, "LATEST.tmp"), "w") as f:
                f.write(str(step))
            os.rename(os.path.join(self.dir, "LATEST.tmp"),
                      os.path.join(self.dir, "LATEST"))
            self._prune()

        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _prune(self) -> None:
        steps = self.steps()
        for s in steps[: -self.keep_n] if self.keep_n else []:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # ------------------------------------------------------------- restore
    def restore(self, step: Optional[int] = None,
                shardings: Any = None) -> Any:
        """Load a checkpoint. ``shardings``: optional pytree of
        jax.sharding.Sharding (same structure) for elastic re-placement;
        None returns host numpy arrays in the original tree."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {self.dir}")
        d = self._step_dir(step)
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        host = [np.load(os.path.join(d, f"{i:06d}.npy"))
                for i in range(len(manifest["leaves"]))]
        tree = _treedef_from_json(manifest["treedef"], iter(host))
        if shardings is not None:
            tree = jax.tree.map(
                lambda x, s: jax.device_put(x, s), tree, shardings)
        return tree


# ---------------------------------------------------------------------------
# JSON-able treedef (dicts / lists / tuples / namedtuple-as-dict / leaves)
# ---------------------------------------------------------------------------

def _treedef_to_json(tree: Any) -> Any:
    if isinstance(tree, dict):
        return {"__kind__": "dict",
                "items": {k: _treedef_to_json(v)
                          for k, v in sorted(tree.items())}}
    if hasattr(tree, "_fields"):          # namedtuple
        return {"__kind__": "namedtuple",
                "name": type(tree).__name__,
                "items": {f: _treedef_to_json(getattr(tree, f))
                          for f in tree._fields}}
    if isinstance(tree, (list, tuple)):
        return {"__kind__": "list" if isinstance(tree, list) else "tuple",
                "items": [_treedef_to_json(v) for v in tree]}
    return {"__kind__": "leaf"}


def _treedef_from_json(spec: Any, leaves) -> Any:
    k = spec["__kind__"]
    if k == "dict" or k == "namedtuple":
        # namedtuples restore as dicts keyed by field — callers that need
        # the concrete type rebuild it (OptState(**d)); jit treats mappings
        # with identical keys interchangeably for sharding purposes.
        return {key: _treedef_from_json(v, leaves)
                for key, v in spec["items"].items()}
    if k in ("list", "tuple"):
        seq = [_treedef_from_json(v, leaves) for v in spec["items"]]
        return seq if k == "list" else tuple(seq)
    return next(leaves)
